"""AOT path: lowering produces parseable HLO text with the right inputs."""

import jax.numpy as jnp

from compile import aot


class TestLowering:
    def test_assign_hlo_text_structure(self):
        text = aot.lower_assign(16, 3, 4)
        assert "HloModule" in text
        assert "f64[16,3]" in text  # x input
        assert "f64[4,3]" in text  # centroids input
        assert "s32[16]" in text  # idx output

    def test_lloyd_hlo_text_structure(self):
        text = aot.lower_lloyd(2, 32, 3, 4)
        assert "HloModule" in text
        assert "f64[32,3]" in text
        # the fori_loop lowers to a while op
        assert "while" in text

    def test_spec_parser(self):
        assert aot.parse_spec("256x8x50") == (256, 8, 50)

    def test_no_float32_creep(self):
        # x64 must be on: artifacts are double precision like the Rust side
        assert jnp.zeros(1).dtype == jnp.float64
