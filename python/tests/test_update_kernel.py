"""L1 update kernel vs oracle: cluster sums, counts, empty clusters."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref, update

jax.config.update("jax_enable_x64", True)


def _numpy_sums(x, idx, k):
    x = np.asarray(x)
    idx = np.asarray(idx)
    sums = np.zeros((k, x.shape[1]))
    counts = np.zeros(k)
    for i, j in enumerate(idx):
        sums[j] += x[i]
        counts[j] += 1
    return sums, counts


class TestClusterSums:
    def test_fixed_case(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(64, 5)))
        idx = jnp.asarray(rng.integers(0, 7, size=64), dtype=jnp.int32)
        sums, counts = update.cluster_sums(x, idx, k=7, block=32)
        ws, wc = _numpy_sums(x, idx, 7)
        np.testing.assert_allclose(np.asarray(sums), ws, rtol=1e-12)
        np.testing.assert_allclose(np.asarray(counts), wc)

    @settings(max_examples=20, deadline=None)
    @given(
        blocks=st.integers(1, 4),
        block=st.sampled_from([8, 16, 32]),
        d=st.integers(1, 12),
        k=st.integers(1, 9),
        seed=st.integers(0, 10_000),
    )
    def test_hypothesis_sweep(self, blocks, block, d, k, seed):
        rng = np.random.default_rng(seed)
        m = blocks * block
        x = jnp.asarray(rng.normal(size=(m, d)))
        idx = jnp.asarray(rng.integers(0, k, size=m), dtype=jnp.int32)
        sums, counts = update.cluster_sums(x, idx, k=k, block=block)
        ws, wc = _numpy_sums(x, idx, k)
        np.testing.assert_allclose(np.asarray(sums), ws, rtol=1e-10, atol=1e-12)
        np.testing.assert_allclose(np.asarray(counts), wc)

    def test_empty_cluster_keeps_centroid(self):
        x = jnp.asarray(np.random.default_rng(1).normal(size=(32, 3)))
        idx = jnp.zeros(32, dtype=jnp.int32)  # everything in cluster 0
        sums, counts = update.cluster_sums(x, idx, k=4, block=32)
        old = jnp.asarray([[9.0, 9.0, 9.0]] * 4)
        new_c = update.centroids_from_sums(sums, counts, old)
        np.testing.assert_allclose(np.asarray(new_c)[1:], 9.0)
        np.testing.assert_allclose(
            np.asarray(new_c)[0], np.asarray(x).mean(axis=0), rtol=1e-12
        )

    def test_rejects_ragged(self):
        x = jnp.zeros((20, 2))
        idx = jnp.zeros(20, dtype=jnp.int32)
        try:
            update.cluster_sums(x, idx, k=2, block=16)
            assert False, "expected ValueError"
        except ValueError:
            pass


class TestLloydKernels:
    def test_all_kernel_lloyd_matches_ref(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(128, 4)))
        c0 = x[:6]
        got_c, got_idx = model.lloyd_rounds_kernels(x, c0, rounds=3, block=64)
        want_c = c0
        want_idx = None
        for _ in range(3):
            want_c, want_idx = ref.lloyd_round_ref(x, want_c)
        np.testing.assert_array_equal(np.asarray(got_idx), np.asarray(want_idx))
        np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c), rtol=1e-10)
