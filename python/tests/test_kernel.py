"""L1 correctness: the Pallas assignment kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled artifacts —
hypothesis sweeps shapes and dtypes, numpy checks independently.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import distance, ref

jax.config.update("jax_enable_x64", True)


def _rand(m, d, k, seed, dtype=np.float64):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, d)).astype(dtype)
    c = rng.normal(size=(k, d)).astype(dtype)
    return jnp.asarray(x), jnp.asarray(c)


def _numpy_assign(x, c):
    x = np.asarray(x)
    c = np.asarray(c)
    d2 = ((x[:, None, :] - c[None, :, :]) ** 2).sum(-1)
    idx = d2.argmin(axis=1)
    s = np.sort(d2, axis=1)
    d1 = np.sqrt(s[:, 0])
    d2_ = np.sqrt(s[:, 1]) if c.shape[0] > 1 else np.full(x.shape[0], np.inf)
    return idx, d1, d2_


class TestKernelVsRef:
    @pytest.mark.parametrize(
        "m,d,k,block",
        [
            (16, 3, 4, 16),
            (64, 4, 16, 64),
            (128, 8, 50, 128),
            (256, 8, 50, 128),
            (128, 2, 100, 64),
            (64, 784, 10, 32),
        ],
    )
    def test_fixed_shapes(self, m, d, k, block):
        x, c = _rand(m, d, k, seed=m * 1000 + d * 10 + k)
        ki, kd1, kd2 = distance.assign(x, c, block=block)
        ri, rd1, rd2 = ref.assign_ref(x, c)
        np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
        np.testing.assert_allclose(np.asarray(kd1), np.asarray(rd1), rtol=1e-10, atol=1e-12)
        np.testing.assert_allclose(np.asarray(kd2), np.asarray(rd2), rtol=1e-10, atol=1e-12)

    @settings(max_examples=25, deadline=None)
    @given(
        m_blocks=st.integers(1, 4),
        block=st.sampled_from([8, 16, 32]),
        d=st.integers(1, 24),
        k=st.integers(2, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, m_blocks, block, d, k, seed):
        m = m_blocks * block
        x, c = _rand(m, d, k, seed)
        ki, kd1, kd2 = distance.assign(x, c, block=block)
        ni, nd1, nd2 = _numpy_assign(x, c)
        np.testing.assert_array_equal(np.asarray(ki), ni)
        np.testing.assert_allclose(np.asarray(kd1), nd1, rtol=1e-9, atol=1e-11)
        np.testing.assert_allclose(np.asarray(kd2), nd2, rtol=1e-9, atol=1e-11)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_dtypes(self, dtype):
        x, c = _rand(32, 5, 7, seed=1, dtype=dtype)
        ki, kd1, kd2 = distance.assign(x, c, block=16)
        ni, nd1, nd2 = _numpy_assign(x, c)
        tol = 1e-4 if dtype == np.float32 else 1e-10
        np.testing.assert_array_equal(np.asarray(ki), ni)
        np.testing.assert_allclose(np.asarray(kd1), nd1, rtol=tol, atol=tol)
        assert kd1.dtype == dtype

    def test_k_equals_one(self):
        x, c = _rand(16, 3, 1, seed=2)
        ki, kd1, kd2 = distance.assign(x, c, block=16)
        assert np.all(np.asarray(ki) == 0)
        assert np.all(np.isinf(np.asarray(kd2)))

    def test_duplicate_centroids_tie_break_low_index(self):
        x = jnp.zeros((8, 2), dtype=jnp.float64)
        c = jnp.ones((3, 2), dtype=jnp.float64)
        ki, kd1, kd2 = distance.assign(x, c, block=8)
        assert np.all(np.asarray(ki) == 0)
        np.testing.assert_allclose(np.asarray(kd1), np.asarray(kd2))

    def test_rejects_ragged_block(self):
        x, c = _rand(20, 3, 4, seed=3)
        with pytest.raises(ValueError):
            distance.assign(x, c, block=16)

    def test_exact_on_grid_points(self):
        # samples sitting exactly on centroids → d1 == 0, idx exact
        c = jnp.asarray(np.random.default_rng(0).normal(size=(10, 4)))
        x = jnp.concatenate([c, c], axis=0)[:16]
        ki, kd1, _ = distance.assign(x, c, block=16)
        np.testing.assert_array_equal(np.asarray(ki)[:10], np.arange(10))
        # norm-decomposition cancellation leaves ~sqrt(eps) residue
        np.testing.assert_allclose(np.asarray(kd1), 0.0, atol=1e-6)


class TestVmemEstimate:
    def test_footprint_formula(self):
        b = distance.vmem_bytes(128, 8, 50)
        assert b == 8 * (128 * 8 + 50 * 8 + 128 * 50 + 3 * 128)

    def test_production_shape_fits_16mb(self):
        # the largest default artifact must fit a TPU core's VMEM budget
        assert distance.vmem_bytes(256, 8, 50) < 16 * 2**20
        # and the biggest paper-ish shape documented in DESIGN.md
        assert distance.vmem_bytes(128, 784, 100) < 16 * 2**20
