"""L2 correctness: the Lloyd-round graph vs the oracle, and objective
monotonicity."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def _blobs(m, d, k, seed):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 3.0
    x = centers[rng.integers(0, k, size=m)] + rng.normal(size=(m, d)) * 0.3
    c0 = x[rng.choice(m, size=k, replace=False)]
    return jnp.asarray(x), jnp.asarray(c0)


class TestLloydRounds:
    def test_single_round_matches_ref(self):
        x, c = _blobs(128, 4, 6, seed=0)
        got_c, got_idx = model.lloyd_rounds(x, c, rounds=1, block=64)
        want_c, want_idx = ref.lloyd_round_ref(x, c)
        np.testing.assert_array_equal(np.asarray(got_idx), np.asarray(want_idx))
        np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c), rtol=1e-12)

    @settings(max_examples=10, deadline=None)
    @given(
        rounds=st.integers(1, 5),
        k=st.integers(2, 8),
        seed=st.integers(0, 10_000),
    )
    def test_multi_round_matches_iterated_ref(self, rounds, k, seed):
        x, c = _blobs(64, 3, k, seed=seed)
        got_c, got_idx = model.lloyd_rounds(x, c, rounds=rounds, block=32)
        want_c = c
        want_idx = None
        for _ in range(rounds):
            want_c, want_idx = ref.lloyd_round_ref(x, want_c)
        np.testing.assert_array_equal(np.asarray(got_idx), np.asarray(want_idx))
        np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c), rtol=1e-10)

    def test_objective_decreases(self):
        x, c = _blobs(256, 5, 8, seed=3)
        prev = float("inf")
        cur = c
        for _ in range(6):
            cur, idx = model.lloyd_rounds(x, cur, rounds=1, block=64)
            obj = float(model.mse(x, cur, idx))
            assert obj <= prev + 1e-9
            prev = obj

    def test_empty_cluster_keeps_centroid(self):
        # one far-away centroid that owns no samples must not move
        x = jnp.asarray(np.random.default_rng(1).normal(size=(64, 2)))
        far = jnp.asarray([[1e6, 1e6]])
        c = jnp.concatenate([x[:3], far], axis=0)
        new_c, _ = model.lloyd_rounds(x, c, rounds=1, block=64)
        np.testing.assert_allclose(np.asarray(new_c)[3], [1e6, 1e6])
