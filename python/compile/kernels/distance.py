"""L1: the pairwise-distance + top-2 Pallas kernel.

This is the dense hot spot every k-means algorithm in the paper shares:
a block of samples against all centroids, reduced to (nearest index,
nearest distance, second-nearest distance) per sample — exactly what
`sta`'s full scan and the ham-family's bound-repair scans consume.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks sample
blocks; each program instance holds one `(bm, d)` x-tile plus the full
`(k, d)` centroid tile in VMEM and drives the MXU with a single
`x @ c.T` contraction; the top-2 reduction fuses into the tile epilogue
so the `(m, k)` distance matrix never reaches HBM. `interpret=True` is
mandatory here — the CPU PJRT plugin cannot execute Mosaic custom calls,
and interpret mode traces the kernel into plain HLO with identical
numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default sample-block height. 128 rows × d ≤ 784 × 8 B ≈ 0.8 MB of VMEM
# for the x-tile at mnist784 scale; centroids dominate (k·d·8 B).
DEFAULT_BLOCK = 128


def _assign_kernel(x_ref, c_ref, idx_ref, d1_ref, d2_ref):
    """One grid step: distances for a (bm, d) x-tile vs all k centroids."""
    x = x_ref[...]  # (bm, d)
    c = c_ref[...]  # (k, d)
    k = c.shape[0]
    xn = jnp.sum(x * x, axis=1, keepdims=True)  # (bm, 1)
    cn = jnp.sum(c * c, axis=1)  # (k,)
    # ‖x−c‖² = ‖x‖² − 2x·c + ‖c‖², clamped (cancellation can go negative)
    d2 = jnp.maximum(xn + cn[None, :] - 2.0 * jnp.dot(x, c.T), 0.0)
    i1 = jnp.argmin(d2, axis=1)
    v1 = jnp.min(d2, axis=1)
    mask = jnp.arange(k)[None, :] == i1[:, None]
    v2 = jnp.min(jnp.where(mask, jnp.inf, d2), axis=1)
    idx_ref[...] = i1.astype(jnp.int32)
    d1_ref[...] = jnp.sqrt(v1)
    d2_ref[...] = jnp.sqrt(v2)


@functools.partial(jax.jit, static_argnames=("block",))
def assign(x, c, *, block=DEFAULT_BLOCK):
    """Pallas-tiled assignment: nearest + second-nearest centroids.

    Args:
      x: (m, d) samples; m must be a multiple of `block` (the AOT path
         compiles fixed shapes; the Rust backend pads the tail block).
      c: (k, d) centroids.
      block: sample-block height (static).

    Returns:
      (idx int32 (m,), d1 (m,), d2 (m,)) — plain distances, not squared.
    """
    m, d = x.shape
    k = c.shape[0]
    if m % block != 0:
        raise ValueError(f"m={m} not a multiple of block={block}")
    grid = (m // block,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # centroids resident
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), x.dtype),
            jax.ShapeDtypeStruct((m,), x.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, c)


def vmem_bytes(block, d, k, itemsize=8):
    """Estimated VMEM footprint of one program instance (DESIGN.md §Perf):
    x-tile + centroid tile + distance tile + three output tiles."""
    return itemsize * (block * d + k * d + block * k + 3 * block)
