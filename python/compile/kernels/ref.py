"""Pure-jnp correctness oracles for the L1 kernels.

These are the ground truth the Pallas kernels (and, transitively, the
Rust-side PJRT execution) are validated against in pytest.
"""

import jax.numpy as jnp


def sqdist_ref(x, c):
    """All pairwise squared Euclidean distances.

    Args:
      x: (m, d) samples.
      c: (k, d) centroids.
    Returns:
      (m, k) squared distances.
    """
    return ((x[:, None, :] - c[None, :, :]) ** 2).sum(-1)


def assign_ref(x, c):
    """Nearest + second-nearest centroid per sample.

    Returns:
      idx: (m,) int32 arg-min centroid index.
      d1:  (m,) distance (plain, not squared) to the nearest centroid.
      d2:  (m,) distance to the second nearest (inf when k == 1).
    """
    d2m = sqdist_ref(x, c)
    idx = jnp.argmin(d2m, axis=1).astype(jnp.int32)
    if c.shape[0] == 1:
        d1 = jnp.sqrt(d2m[:, 0])
        d2_ = jnp.full((x.shape[0],), jnp.inf, dtype=x.dtype)
    else:
        top2 = jnp.sort(d2m, axis=1)[:, :2]
        d1 = jnp.sqrt(top2[:, 0])
        d2_ = jnp.sqrt(top2[:, 1])
    return idx, d1, d2_


def lloyd_round_ref(x, c):
    """One exact Lloyd round: assign, then recompute centroids.

    Empty clusters keep their previous centroid (matching the Rust
    coordinator's update step).

    Returns:
      new_c: (k, d) updated centroids.
      idx:   (m,) int32 assignments used for the update.
    """
    idx, _, _ = assign_ref(x, c)
    k = c.shape[0]
    onehot = (idx[:, None] == jnp.arange(k)[None, :]).astype(x.dtype)  # (m, k)
    counts = onehot.sum(axis=0)  # (k,)
    sums = onehot.T @ x  # (k, d)
    safe = jnp.maximum(counts, 1.0)[:, None]
    new_c = jnp.where(counts[:, None] > 0, sums / safe, c)
    return new_c, idx
