"""L1: the centroid-update Pallas kernel.

The update step (paper eq. 2) is a segment-sum: `sums[j] = Σ_{a(i)=j} x(i)`
plus member counts. As a Pallas kernel it is a one-hot contraction per
sample block, accumulated across the sequential grid — on TPU this is an
MXU matmul per tile with the accumulator resident in VMEM, so the
(m, k) one-hot never materialises in HBM either.

Together with `distance.assign` this gives a complete Lloyd round with
both compute stages as L1 kernels (see `model.lloyd_rounds_kernels`).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _update_kernel(x_ref, onehot_ref, sums_ref, counts_ref):
    """One grid step: accumulate one sample-block's cluster sums."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...]  # (bm, d)
    oh = onehot_ref[...]  # (bm, k)
    # (k, d) contraction on the MXU; accumulator stays in VMEM
    sums_ref[...] += jnp.dot(oh.T, x)
    counts_ref[...] += oh.sum(axis=0)


@functools.partial(jax.jit, static_argnames=("k", "block"))
def cluster_sums(x, idx, *, k, block=DEFAULT_BLOCK):
    """Cluster sums + counts from assignments.

    Args:
      x: (m, d) samples, m a multiple of `block`.
      idx: (m,) int32 assignments in [0, k).
      k: number of clusters (static).
      block: sample-block height (static).

    Returns:
      (sums (k, d), counts (k,)) with `counts.dtype == x.dtype`.
    """
    m, d = x.shape
    if m % block != 0:
        raise ValueError(f"m={m} not a multiple of block={block}")
    onehot = (idx[:, None] == jnp.arange(k, dtype=idx.dtype)[None, :]).astype(x.dtype)
    grid = (m // block,)
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # accumulator resident
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), x.dtype),
            jax.ShapeDtypeStruct((k,), x.dtype),
        ],
        interpret=True,
    )(x, onehot)


def centroids_from_sums(sums, counts, old_c):
    """New centroids; empty clusters keep their previous position."""
    safe = jnp.maximum(counts, 1.0)[:, None]
    return jnp.where(counts[:, None] > 0, sums / safe, old_c)
