"""Build-time compile package: L1 Pallas kernels, L2 JAX model, AOT lowering.

Python in this repo runs ONLY at build time (`make artifacts`); the Rust
coordinator executes the lowered HLO through PJRT at run time.

Everything is double precision to match the Rust side (the paper's
experiments use f64 throughout).
"""

import jax

jax.config.update("jax_enable_x64", True)
