"""AOT lowering: JAX/Pallas graphs → HLO **text** artifacts for Rust/PJRT.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage::

    python -m compile.aot --out-dir ../artifacts [--spec 256x8x50 ...]

Artifacts written (default specs):

    assign_{block}x{d}x{k}.hlo.txt   — batched assignment kernel
    lloyd_{rounds}r_{m}x{d}x{k}.hlo.txt — fused multi-round Lloyd graph
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# (block, d, k) shapes compiled by default: one production-ish shape used
# by examples/xla_backend.rs and the integration tests, plus a tiny shape
# for fast smoke tests.
DEFAULT_ASSIGN_SPECS = [(256, 8, 50), (64, 4, 16), (16, 3, 4)]
# (rounds, m, d, k) for the fused Lloyd graph.
DEFAULT_LLOYD_SPECS = [(5, 512, 8, 50)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_assign(block: int, d: int, k: int) -> str:
    """Lower the assignment kernel for a fixed (block, d, k)."""
    x = jax.ShapeDtypeStruct((block, d), jnp.float64)
    c = jax.ShapeDtypeStruct((k, d), jnp.float64)
    fn = lambda x, c: model.assign(x, c, block=block)  # noqa: E731
    return to_hlo_text(jax.jit(fn).lower(x, c))


def lower_lloyd(rounds: int, m: int, d: int, k: int) -> str:
    """Lower the fused multi-round Lloyd graph."""
    x = jax.ShapeDtypeStruct((m, d), jnp.float64)
    c = jax.ShapeDtypeStruct((k, d), jnp.float64)
    block = min(m, 128)
    fn = lambda x, c: model.lloyd_rounds(x, c, rounds=rounds, block=block)  # noqa: E731
    return to_hlo_text(jax.jit(fn).lower(x, c))


def parse_spec(text: str):
    parts = tuple(int(p) for p in text.split("x"))
    if len(parts) != 3:
        raise argparse.ArgumentTypeError(f"spec must be BLOCKxDxK, got {text!r}")
    return parts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--spec",
        action="append",
        type=parse_spec,
        help="extra assign spec BLOCKxDxK (repeatable)",
    )
    ap.add_argument("--skip-lloyd", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    specs = list(DEFAULT_ASSIGN_SPECS) + (args.spec or [])
    for block, d, k in specs:
        text = lower_assign(block, d, k)
        path = os.path.join(args.out_dir, f"assign_{block}x{d}x{k}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    if not args.skip_lloyd:
        for rounds, m, d, k in DEFAULT_LLOYD_SPECS:
            text = lower_lloyd(rounds, m, d, k)
            path = os.path.join(args.out_dir, f"lloyd_{rounds}r_{m}x{d}x{k}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")

    # stamp so `make artifacts` can skip when inputs are unchanged
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
