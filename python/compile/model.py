"""L2: the JAX compute graph around the L1 Pallas kernel.

Two graphs are AOT-lowered for the Rust coordinator:

* ``assign`` — the batched assignment step (nearest + second-nearest
  centroid per sample), the shared hot spot of every algorithm in the
  paper. This is the artifact `XlaAssignBackend` executes.
* ``lloyd_rounds`` — a fixed number of full Lloyd rounds (assignment +
  centroid update) under ``lax.fori_loop``, proving the whole L2 graph
  (kernel + update + control flow) lowers and runs through PJRT.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import distance


def assign(x, c, *, block=distance.DEFAULT_BLOCK):
    """Batched assignment via the Pallas kernel (see kernels/distance.py)."""
    return distance.assign(x, c, block=block)


def _update(x, c, idx):
    """Centroid update from assignments; empty clusters keep position."""
    k = c.shape[0]
    onehot = (idx[:, None] == jnp.arange(k)[None, :]).astype(x.dtype)
    counts = onehot.sum(axis=0)
    sums = onehot.T @ x
    safe = jnp.maximum(counts, 1.0)[:, None]
    return jnp.where(counts[:, None] > 0, sums / safe, c)


@functools.partial(jax.jit, static_argnames=("rounds", "block"))
def lloyd_rounds(x, c, *, rounds=10, block=distance.DEFAULT_BLOCK):
    """Run `rounds` exact Lloyd rounds.

    Returns:
      (final centroids (k, d), final assignments (m,) int32).
    """

    def body(_, carry):
        c, _idx = carry
        idx, _d1, _d2 = assign(x, c, block=block)
        return _update(x, c, idx), idx

    m = x.shape[0]
    init_idx = jnp.zeros((m,), dtype=jnp.int32)
    final_c, final_idx = jax.lax.fori_loop(0, rounds, body, (c, init_idx))
    return final_c, final_idx


def mse(x, c, idx):
    """Mean squared distance to the assigned centroid (objective / m)."""
    diffs = x - c[idx]
    return (diffs * diffs).sum() / x.shape[0]


@functools.partial(jax.jit, static_argnames=("rounds", "block"))
def lloyd_rounds_kernels(x, c, *, rounds=10, block=distance.DEFAULT_BLOCK):
    """As `lloyd_rounds`, but with BOTH stages as Pallas kernels:
    `kernels.distance.assign` for the assignment step and
    `kernels.update.cluster_sums` for the centroid update."""
    from compile.kernels import update as upd

    k = c.shape[0]

    def body(_, carry):
        c, _idx = carry
        idx, _d1, _d2 = assign(x, c, block=block)
        sums, counts = upd.cluster_sums(x, idx, k=k, block=block)
        return upd.centroids_from_sums(sums, counts, c), idx

    m = x.shape[0]
    init_idx = jnp.zeros((m,), dtype=jnp.int32)
    return jax.lax.fori_loop(0, rounds, body, (c, init_idx))
