//! Quickstart: the fit/predict service API on a shared runtime.
//!
//! One [`Runtime`] owns the worker pool for the whole process; `Kmeans`
//! fits an owned `FittedModel`; the model answers `predict` for new
//! points on the same pool.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use eakm::prelude::*;

fn main() {
    // one pool for every fit and predict in this process
    let rt = Runtime::new(4);

    // 20k samples, 8-D, 40 latent clusters
    let data = eakm::data::synth::blobs(20_000, 8, 40, 0.08, 42);

    let model = Kmeans::new(40)
        .algorithm(Algorithm::ExpNs)
        .seed(7)
        .fit(&rt, &data)
        .expect("clustering failed");

    let report = model.report();
    println!("{}", report.summary());
    println!(
        "distance calculations avoided vs sta: {:.1}% ({} vs {})",
        100.0 * (1.0 - report.counters.total() as f64 / (report.iterations as f64 * 20_000.0 * 40.0)),
        report.counters.total(),
        report.iterations * 20_000 * 40,
    );

    // apply the fitted model to points it has never seen — same pool,
    // nothing new spawned
    let fresh = eakm::data::synth::blobs(5_000, 8, 40, 0.08, 43);
    let labels = model.predict(&rt, &fresh).expect("predict failed");
    println!(
        "predicted {} new points; first five labels: {:?}",
        labels.len(),
        &labels[..5]
    );

    // when a full scan per round is too slow, fit on sampled batches:
    // .batch_size(b) samples b rows per round and .batch_growth(2.0)
    // doubles the (nested) batch until it covers the dataset — same
    // seeded determinism, bounded per-round latency
    let quick = Kmeans::new(40)
        .algorithm(Algorithm::ExpNs)
        .seed(7)
        .batch_size(2_000)
        .fit(&rt, &data)
        .expect("mini-batch fit failed");
    let schedule = quick.report().batch.as_ref().expect("mini-batch telemetry");
    println!(
        "mini-batch fit: {} rounds over batches {:?}, mse={:.5}",
        quick.report().iterations,
        schedule.schedule,
        quick.report().mse
    );

    // exactness: the accelerated fit equals plain Lloyd's from the same
    // seed — only faster
    let sta = Kmeans::new(40)
        .algorithm(Algorithm::Sta)
        .seed(7)
        .fit_predict(&rt, &data)
        .expect("sta failed");
    let exp = Kmeans::new(40)
        .algorithm(Algorithm::ExpNs)
        .seed(7)
        .fit_predict(&rt, &data)
        .expect("exp-ns failed");
    assert_eq!(sta.1, exp.1);
    println!(
        "exactness check OK: sta and exp-ns agree after {} rounds (sta: {:?}, exp-ns: {:?})",
        exp.0.report().iterations,
        sta.0.report().wall,
        exp.0.report().wall
    );
}
