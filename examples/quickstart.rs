//! Quickstart: cluster a synthetic dataset with the paper's best
//! low-dimensional algorithm (Exponion + ns-bounds) and print the report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use eakm::prelude::*;

fn main() {
    // 20k samples, 8-D, 40 latent clusters
    let data = eakm::data::synth::blobs(20_000, 8, 40, 0.08, 42);

    let cfg = RunConfig::new(Algorithm::ExpNs, 40).seed(7).threads(1);
    let out = Runner::new(&cfg).run(&data).expect("clustering failed");

    println!("{}", out.report.summary());
    println!(
        "distance calculations avoided vs sta: {:.1}% ({} vs {})",
        100.0 * (1.0 - out.counters.total() as f64 / (out.iterations as f64 * 20_000.0 * 40.0)),
        out.counters.total(),
        out.iterations * 20_000 * 40,
    );

    // the exact same call with the plain standard algorithm gives the
    // identical clustering — only slower:
    let sta = Runner::new(&RunConfig::new(Algorithm::Sta, 40).seed(7))
        .run(&data)
        .expect("sta failed");
    assert_eq!(sta.assignments, out.assignments);
    println!(
        "exactness check OK: sta and exp-ns agree after {} rounds (sta: {:?}, exp-ns: {:?})",
        out.iterations, sta.wall, out.wall
    );
}
