//! Run the assignment step through the AOT-compiled JAX/Pallas artifact
//! (PJRT) and drive a full Lloyd loop from Rust — Python is nowhere on
//! this path. Compares numerics and per-round latency against the native
//! Rust scan.
//!
//! ```sh
//! make artifacts && cargo run --release --example xla_backend
//! ```

use std::path::PathBuf;
use std::time::Instant;

use eakm::data::synth::blobs;
use eakm::linalg::{argmin, sqdist_batch_block, sqnorms_rows};
use eakm::runtime::{ArtifactSpec, XlaAssignBackend};

fn main() {
    let spec = ArtifactSpec {
        block: 256,
        d: 8,
        k: 50,
    };
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut backend = match XlaAssignBackend::load(&dir, spec) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}\nhint: run `make artifacts` first");
            std::process::exit(1);
        }
    };

    let n = 8_192;
    let ds = blobs(n, spec.d, spec.k, 0.1, 3);
    let mut centroids: Vec<f64> = ds.raw()[..spec.k * spec.d].to_vec();

    println!("running 10 Lloyd rounds with XLA (PJRT) assignment…");
    let mut assignments = vec![0u32; n];
    let t0 = Instant::now();
    for round in 0..10 {
        let out = backend.assign(ds.raw(), &centroids).expect("xla assign");
        let moved = out
            .idx
            .iter()
            .zip(&assignments)
            .filter(|(new, old)| new != old)
            .count();
        assignments.copy_from_slice(&out.idx);
        // centroid update in rust
        let mut sums = vec![0.0; spec.k * spec.d];
        let mut counts = vec![0u64; spec.k];
        for (i, &j) in assignments.iter().enumerate() {
            counts[j as usize] += 1;
            for t in 0..spec.d {
                sums[j as usize * spec.d + t] += ds.row(i)[t];
            }
        }
        for j in 0..spec.k {
            if counts[j] > 0 {
                for t in 0..spec.d {
                    centroids[j * spec.d + t] = sums[j * spec.d + t] / counts[j] as f64;
                }
            }
        }
        println!("  round {round}: {moved} samples moved");
        if moved == 0 && round > 0 {
            break;
        }
    }
    let xla_wall = t0.elapsed();

    // final XLA assignment on the *current* centroids (the loop's last
    // update moved them after the stored assignment), then compare
    let final_out = backend.assign(ds.raw(), &centroids).expect("xla assign");
    assignments.copy_from_slice(&final_out.idx);

    // native comparison on the same centroids
    let t1 = Instant::now();
    let cnorms = sqnorms_rows(&centroids, spec.d);
    let mut buf = vec![0.0; n * spec.k];
    sqdist_batch_block(
        ds.raw(),
        ds.sqnorms(),
        &centroids,
        &cnorms,
        spec.d,
        &mut buf,
    );
    let native: Vec<u32> = (0..n)
        .map(|i| argmin(&buf[i * spec.k..(i + 1) * spec.k]).unwrap() as u32)
        .collect();
    let native_wall = t1.elapsed();

    let agree = native
        .iter()
        .zip(&assignments)
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "agreement with native scan: {agree}/{n} ({:.2}%)",
        100.0 * agree as f64 / n as f64
    );
    assert_eq!(agree, n, "XLA and native assignments diverged");
    println!(
        "xla loop: {:?} total; native single scan: {:?} (n={n}, k={}, d={})",
        xla_wall, native_wall, spec.k, spec.d
    );
    println!("xla_backend OK — three layers composed, no Python at run time.");
}
