//! Monitoring: the unified observability layer, end-to-end.
//!
//! 1. a fit runs with a [`FitObserver`](eakm::obs::FitObserver): every
//!    round lands in a bounded event ring, tagged with the trace ID
//!    minted at the front door — the same stream `eakm run --progress`
//!    prints to stderr;
//! 2. the fitted model goes behind the serve tier, which exposes the
//!    whole telemetry surface with no extra wiring: `GET /metrics`
//!    (Prometheus text exposition) and `GET /v1/events?since=` (the
//!    structured event drain), both answering even when admission
//!    control is rejecting traffic;
//! 3. the `stats` op reports histogram-derived p50/p99 op latencies,
//!    computed server-side from log-bucketed histograms.
//!
//! Observation is strictly read-only: the results are bit-identical
//! with or without it (asserted below against an unobserved fit).
//!
//! ```sh
//! cargo run --release --example monitoring
//! ```

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use eakm::json::Json;
use eakm::obs::{FitObserver, TraceId, Value};
use eakm::prelude::*;
use eakm::serve::client::{self, Client};

/// One-shot `GET` against the serve HTTP shim; returns the body.
fn http_get(addr: SocketAddr, target: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .expect("set timeout");
    let req = format!("GET {target} HTTP/1.1\r\nHost: demo\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes()).expect("send");
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("recv");
    let text = String::from_utf8(raw).expect("utf8");
    text.split_once("\r\n\r\n").expect("body").1.to_string()
}

fn main() {
    let (d, k) = (8, 40);
    let train = eakm::data::synth::blobs(20_000, d, k, 0.05, 1);
    let rt = Runtime::auto();

    // ── an observed fit: every round lands in the event ring ────────
    let observer = FitObserver::new(TraceId::mint(), false);
    let events = observer.events().clone();
    let trace = observer.trace();
    let km = Kmeans::new(k).algorithm(Algorithm::Auto).seed(7);
    let observed = km
        .fit_observed(&rt, &train, Some(std::sync::Arc::new(observer)))
        .expect("observed fit");
    let rounds = events.since(0);
    let total: u64 = rounds
        .iter()
        .filter_map(|e| match e.field("dist_total") {
            Some(Value::U64(v)) => Some(*v),
            _ => None,
        })
        .sum();
    println!(
        "observed fit: {} rounds, {} distance calcs, trace {}",
        rounds.len(),
        total,
        trace,
    );

    // observation is read-only — an unobserved fit agrees to the bit
    let plain = km.fit(&rt, &train).expect("plain fit");
    assert_eq!(plain.report().mse.to_bits(), observed.report().mse.to_bits());
    println!("bit-identity: observed fit matches the unobserved fit exactly");

    // ── the server: /metrics and /v1/events come for free ───────────
    let (addr_tx, addr_rx) = mpsc::channel();
    let cfg = ServeConfig::default();
    let server = thread::spawn(move || {
        let rt = Runtime::auto();
        eakm::serve::serve(&rt, observed, &cfg, |addr| {
            addr_tx.send(addr).expect("announce address");
        })
        .expect("serve failed")
    });
    let addr = addr_rx.recv().expect("server address");
    println!("server is up on {addr}");

    // traffic, so the counters and latency histograms are non-trivial
    let queries = eakm::data::synth::blobs(256, d, k, 0.08, 99);
    let mut cl = Client::connect(addr).expect("connect");
    for chunk in queries.raw().chunks(32 * d) {
        let reply = cl.call(&client::predict_request(chunk, d)).expect("predict");
        assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));
    }

    // ── GET /metrics: the Prometheus text exposition ────────────────
    let metrics = http_get(addr, "/metrics");
    assert!(metrics.contains("eakm_serve_ops_total{op=\"predict\"} 8\n"));
    let interesting = [
        "eakm_serve_ops_total{op=\"predict\"}",
        "eakm_serve_op_latency_p99_micros{op=\"predict\"}",
        "eakm_fit_distance_calcs_per_point_round{site=\"total\"",
        "eakm_fit_sched_imbalance",
    ];
    for line in metrics.lines() {
        if interesting.iter().any(|p| line.starts_with(p)) {
            println!("/metrics → {line}");
        }
    }

    // ── GET /v1/events: the structured event drain ──────────────────
    let drained = Json::parse(http_get(addr, "/v1/events").trim_end()).expect("events json");
    let list = drained.get("events").and_then(Json::as_arr).expect("events");
    let last = drained.get("last").and_then(Json::as_usize).expect("last");
    println!("/v1/events → {} events (cursor {last})", list.len());
    // the batcher tags every executed batch with the trace minted when
    // its first request entered the server
    let batch = list
        .iter()
        .find(|e| e.get("kind").and_then(Json::as_str) == Some("batch"))
        .expect("batch event");
    println!("first batch event: {batch}");
    // incremental drain from the cursor: empty until new events arrive
    let body = http_get(addr, &format!("/v1/events?since={last}"));
    let again = Json::parse(body.trim_end()).expect("events json");
    assert_eq!(again.get("events").and_then(Json::as_arr).map(Vec::len), Some(0));

    // ── the stats op: server-computed per-op latency quantiles ──────
    let stats = cl.call(&client::stats_request()).expect("stats");
    let s = stats.get("stats").expect("stats payload");
    let p50 = s.get("predict_p50_micros").and_then(Json::as_usize);
    let p99 = s.get("predict_p99_micros").and_then(Json::as_usize);
    println!(
        "stats → predict p50 {}µs, p99 {}µs (histogram-derived, server-side)",
        p50.expect("p50"),
        p99.expect("p99"),
    );

    // ── clean shutdown ──────────────────────────────────────────────
    let bye = cl.call(&client::shutdown_request()).expect("shutdown");
    assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    let final_stats = server.join().expect("server thread failed");
    assert_eq!(final_stats.predicts, 8);
    assert!(final_stats.predict_latency.p99_micros >= 1);
    println!("clean shutdown after {} predicts", final_stats.predicts);
}
