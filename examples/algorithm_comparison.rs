//! End-to-end driver: the full system on real (scaled) paper workloads.
//!
//! Runs every algorithm family on three datasets spanning the paper's
//! dimensional regimes (birch d=2, colormoments d=9, gassensor d=128),
//! verifies the exactness invariant system-wide, and prints the
//! speedup-vs-sta table with `q_a`/`q_au` distance-calculation counts —
//! the quantities Tables 9/10 are made of.
//!
//! ```sh
//! cargo run --release --example algorithm_comparison [scale] [seeds]
//! ```

use std::time::Duration;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{measure, TextTable};
use eakm::config::RunConfig;
use eakm::coordinator::Runner;
use eakm::data::synth::{find, generate};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let seeds: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);

    let workloads = [("birch", 50), ("colormoments", 50), ("gassensor", 20)];
    let algs = [
        Algorithm::Sta,
        Algorithm::Ham,
        Algorithm::Ann,
        Algorithm::Exp,
        Algorithm::ExpNs,
        Algorithm::Selk,
        Algorithm::SelkNs,
        Algorithm::Elk,
        Algorithm::Syin,
        Algorithm::SyinNs,
        Algorithm::Yin,
    ];

    for (name, k) in workloads {
        let spec = find(name).expect("known dataset");
        let ds = generate(&spec, scale, 0xE2E);
        println!(
            "\n=== {name} (d={}, n={}, k={k}, scale={scale}, seeds={seeds}) ===",
            ds.d(),
            ds.n()
        );

        // exactness gate: every algorithm must match sta exactly
        let reference = Runner::new(&RunConfig::new(Algorithm::Sta, k).seed(0))
            .run(&ds)
            .expect("sta run");
        for alg in algs {
            let out = Runner::new(&RunConfig::new(alg, k).seed(0)).run(&ds).unwrap();
            assert_eq!(
                out.assignments, reference.assignments,
                "EXACTNESS VIOLATION: {alg} differs from sta on {name}"
            );
        }
        println!(
            "exactness: all {} algorithms agree with sta ({} iterations, mse {:.6})",
            algs.len(),
            reference.iterations,
            reference.mse
        );

        let mut table = TextTable::new("algorithm comparison (mean over seeds)").headers(&[
            "algorithm",
            "wall[ms]",
            "speedup",
            "q_a",
            "q_au",
            "iters",
        ]);
        let mut sta_wall = Duration::ZERO;
        for alg in algs {
            let st = measure(&ds, alg, k, seeds, 1);
            if alg == Algorithm::Sta {
                sta_wall = st.mean_wall;
            }
            table.row(vec![
                alg.name().to_string(),
                format!("{:.1}", st.mean_wall.as_secs_f64() * 1e3),
                format!(
                    "{:.2}x",
                    sta_wall.as_secs_f64() / st.mean_wall.as_secs_f64().max(1e-12)
                ),
                format!("{:.2e}", st.mean_qa),
                format!("{:.2e}", st.mean_qau),
                format!("{:.1}", st.mean_iters),
            ]);
        }
        print!("{}", table.render());
    }
    println!("\nE2E driver complete: all layers composed, exactness held everywhere.");
}
