//! Figure 1 reproduction: sn-bounds vs ns-bounds.
//!
//! Tracks one centroid over a real clustering run and prints, per round,
//! the accumulated sum-of-norms drift (sn, what selk/ham/yin use) against
//! the norm-of-sum displacement (ns, §3.2) — ns is provably never larger
//! (SM-B.5), and the gap is exactly the slack the ns-algorithms reclaim
//! as avoided distance calculations.
//!
//! ```sh
//! cargo run --release --example bounds_demo
//! ```

use eakm::algorithms::Algorithm;
use eakm::bench_support::TextTable;
use eakm::config::RunConfig;
use eakm::coordinator::Engine;
use eakm::data::synth::{find, generate};
use eakm::linalg::sqdist;

fn main() {
    let ds = generate(&find("birch").unwrap(), 0.05, 7);
    let k = 50;
    let cfg = RunConfig::new(Algorithm::Sta, k).seed(0).max_iters(40);
    let mut engine = Engine::new(&ds, &cfg).expect("engine");

    let d = ds.d();
    // follow the centroid that moves the most in round 1
    engine.step();
    let tracked = engine
        .ctx()
        .p
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(j, _)| j)
        .unwrap();
    let origin: Vec<f64> = engine.centroids()[tracked * d..(tracked + 1) * d].to_vec();

    let mut sn = 0.0;
    let mut table = TextTable::new(format!(
        "Figure 1 — bound drift of centroid {tracked} on birch (k={k})"
    ))
    .headers(&["round", "sn = Σ‖p_t‖", "ns = ‖Σ p_t‖", "slack (sn−ns)", "ratio"]);
    let mut rounds = 0;
    while !engine.converged() && rounds < 25 {
        engine.step();
        rounds += 1;
        sn += engine.ctx().p[tracked];
        let cur = &engine.centroids()[tracked * d..(tracked + 1) * d];
        let ns = sqdist(&origin, cur).sqrt();
        assert!(
            ns <= sn + 1e-9,
            "SM-B.5 violated: ns {ns} > sn {sn}"
        );
        table.row(vec![
            format!("{rounds}"),
            format!("{sn:.6}"),
            format!("{ns:.6}"),
            format!("{:.6}", sn - ns),
            TextTable::fmt_ratio(if sn > 0.0 { ns / sn } else { 1.0 }),
        ]);
    }
    print!("{}", table.render());
    println!("\nns ≤ sn held every round (triangle inequality, SM-B.5).");
    println!("The sn−ns slack is what selk-ns/elk-ns/syin-ns/exp-ns convert into skipped distance calculations (Table 5).");
}
