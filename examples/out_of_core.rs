//! Out-of-core clustering: fit a model on an `.ekb` file **without
//! loading it into memory**, and verify the result is bit-identical to
//! the in-memory fit.
//!
//! ```text
//! cargo run --release --example out_of_core
//! ```
//!
//! The flow mirrors a real deployment: some producer writes a (large)
//! binary dataset once; consumers cluster it through `--ooc`-style
//! sources whose resident footprint is one window per worker (chunked)
//! or whatever the page cache keeps warm (mmap). The `.norms` sidecar
//! is computed on first contact and reused afterwards.

use eakm::data::ooc::{mmap_supported, open_ooc, OocMode};
use eakm::data::io;
use eakm::prelude::*;

fn main() {
    // 1. produce a dataset file (stand-in for an ingest pipeline)
    let dir = std::env::temp_dir().join(format!("eakm-ooc-example-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("big.ekb");
    let ds = eakm::data::synth::blobs(50_000, 8, 40, 0.2, 42);
    io::save_bin(&ds, &path).unwrap();
    println!(
        "wrote {} ({} rows × {} dims, {:.1} MiB)",
        path.display(),
        ds.n(),
        ds.d(),
        std::fs::metadata(&path).unwrap().len() as f64 / (1024.0 * 1024.0)
    );

    // 2. the in-memory reference fit
    let rt = Runtime::new(4);
    let kmeans = Kmeans::new(40).algorithm(Algorithm::ExpNs).seed(7);
    let reference = kmeans.fit(&rt, &ds).unwrap();
    println!("in-memory : {}", reference.report().summary());

    // 3. the same fit straight off the file, never loading it
    let mut modes = vec![OocMode::Chunked];
    if mmap_supported() {
        modes.push(OocMode::Mmap);
    }
    for mode in modes {
        // window of 2048 rows ≈ 128 KiB resident per worker at d=8
        let src = open_ooc(&path, mode, 2048).unwrap();
        let model = kmeans.fit(&rt, &*src).unwrap();
        println!("{mode:<10}: {}", model.report().summary());

        let same = model
            .centroids()
            .iter()
            .zip(reference.centroids())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "{mode}: out-of-core fit diverged from in-memory");

        // serving works off the file too
        let labels = model.predict(&rt, &*src).unwrap();
        println!(
            "{mode:<10}: predicted {} rows off the file (io: {:?})",
            labels.len(),
            src.io_stats().unwrap()
        );
    }
    println!("all out-of-core fits bit-identical to the in-memory fit ✓");
}
