//! Serving: a long-lived model server on one `Runtime`, driven
//! end-to-end over a real loopback socket.
//!
//! The shape of a clustering service under traffic:
//!
//! 1. a startup phase fits (or loads) a `FittedModel`;
//! 2. `eakm::serve::serve` answers line-delimited JSON requests —
//!    concurrent `predict`s are coalesced by the micro-batcher into
//!    single pool-sharded scans, so answers stay **bit-identical** to
//!    local `predict` while the per-request dispatch cost is shared;
//! 3. a `reload` op hot-swaps an improved model (here: a mini-batch
//!    refinement) with zero downtime — in-flight requests finish on the
//!    snapshot they started with, none are dropped;
//! 4. `stats` exposes live telemetry and `shutdown` drains cleanly,
//!    returning the final counters for the summary line.
//!
//! The server runs on a spawned thread; the driving happens on the
//! main thread so any failed assertion exits the process (a CI smoke
//! run fails fast instead of hanging on a server that never gets its
//! shutdown op).
//!
//! ```sh
//! cargo run --release --example serving
//! ```

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use eakm::json::Json;
use eakm::prelude::*;
use eakm::serve::client::{self, Client};

fn main() {
    let started = Instant::now();
    let (d, k) = (16, 100);
    let model_path = std::env::temp_dir().join("eakm-serving-refined.json");

    // ── startup: fit the model the server will open with ────────────
    let train = eakm::data::synth::blobs(50_000, d, k, 0.05, 1);
    let (fitted, refined) = {
        let rt = Runtime::auto();
        let fitted = Kmeans::new(k)
            .algorithm(Algorithm::Auto)
            .seed(7)
            .fit(&rt, &train)
            .expect("fit failed");
        println!(
            "fitted: {} (k={}, d={}, iters={}, mse={:.5})",
            fitted.algorithm(),
            fitted.k(),
            fitted.d(),
            fitted.report().iterations,
            fitted.report().mse,
        );
        // a mini-batch refinement under a latency budget — the model a
        // production loop would hot-swap in later
        let refined = Kmeans::new(k)
            .algorithm(Algorithm::Auto)
            .seed(7)
            .batch_size(train.n() / 16)
            .batch_growth(2.0)
            .time_limit(Duration::from_millis(250))
            .fit(&rt, &train)
            .expect("refinement failed");
        (fitted, refined)
    };
    refined.save(&model_path).expect("save refined");
    println!("refined model persisted → {}", model_path.display());

    // reference answers for the bit-identity check below
    let queries = eakm::data::synth::blobs(512, d, k, 0.08, 99);
    let reference = {
        let rt = Runtime::serial();
        fitted.predict(&rt, &queries).expect("local predict")
    };

    // ── the server: its own thread, its own Runtime ─────────────────
    let (addr_tx, addr_rx) = mpsc::channel();
    let cfg = ServeConfig {
        linger: Duration::from_millis(2), // coalesce concurrent clients
        ..ServeConfig::default()
    };
    let server = thread::spawn(move || {
        let rt = Runtime::auto();
        eakm::serve::serve(&rt, fitted, &cfg, |addr| {
            addr_tx.send(addr).expect("announce address");
        })
        .expect("serve failed")
    });
    let addr = addr_rx.recv().expect("server address");
    println!("server is up on {addr}");

    // ── concurrent clients: requests coalesce into shared scans ─────
    let raw = queries.raw().to_vec();
    let mut workers = Vec::new();
    for c in 0..4usize {
        let raw = raw.clone();
        let reference = reference.clone();
        workers.push(thread::spawn(move || {
            let mut cl = Client::connect(addr).expect("connect");
            // each client labels a quarter of the query set, 8 rows per
            // request
            let per = raw.len() / d / 4;
            for chunk in 0..per / 8 {
                let lo = c * per + chunk * 8;
                let reply = cl
                    .call(&client::predict_request(&raw[lo * d..(lo + 8) * d], d))
                    .expect("predict");
                let labels: Vec<u32> = reply
                    .get("labels")
                    .and_then(Json::as_arr)
                    .expect("labels")
                    .iter()
                    .map(|l| l.as_usize().unwrap() as u32)
                    .collect();
                // served answers are bit-identical to local predict
                assert_eq!(labels.as_slice(), &reference[lo..lo + 8], "client {c}");
            }
        }));
    }
    for w in workers {
        w.join().expect("client worker failed");
    }
    println!("512 rows served batch-identical to local predict");

    let mut admin = Client::connect(addr).expect("connect admin");

    // single-point path
    let nearest = admin
        .call(&client::nearest_request(&raw[..d]))
        .expect("nearest");
    println!(
        "nearest → cluster {} at distance {:.4}",
        nearest.get("label").and_then(Json::as_usize).unwrap(),
        nearest.get("distance").and_then(Json::as_f64).unwrap(),
    );

    // live telemetry
    let stats = admin.call(&client::stats_request()).expect("stats");
    let s = stats.get("stats").expect("stats payload");
    println!(
        "stats → {} requests, {} batches ({} coalesced), generation {}",
        s.get("requests").and_then(Json::as_usize).unwrap(),
        s.get("batches").and_then(Json::as_usize).unwrap(),
        s.get("coalesced_batches").and_then(Json::as_usize).unwrap(),
        s.get("generation").and_then(Json::as_usize).unwrap(),
    );

    // hot reload: swap in the refined model with zero downtime
    let reload = admin
        .call(&client::reload_request(model_path.to_str().unwrap()))
        .expect("reload");
    assert_eq!(reload.get("ok").and_then(Json::as_bool), Some(true));
    println!(
        "reloaded refined model (generation {})",
        reload.get("generation").and_then(Json::as_usize).unwrap(),
    );
    let after = admin
        .call(&client::predict_request(&raw[..8 * d], d))
        .expect("post-reload predict");
    assert_eq!(after.get("ok").and_then(Json::as_bool), Some(true));

    // ── clean shutdown: drain and print the summary line ────────────
    let bye = admin.call(&client::shutdown_request()).expect("shutdown");
    assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    let stats = server.join().expect("server thread failed");
    println!("{}", stats.summary_line(started.elapsed()));
    assert_eq!(stats.queue_full_rejects, 0);
    assert_eq!(stats.reloads, 1);
}
