//! Serving: fit once, predict many — and survive a restart.
//!
//! The shape of a clustering service under traffic:
//!
//! 1. a startup phase fits (or loads) a `FittedModel`;
//! 2. a long steady state answers nearest-centroid queries on one
//!    shared [`Runtime`] — batch `predict` for bulk requests,
//!    `nearest` for single points;
//! 3. a background *refinement* loop re-fits on mini-batches under a
//!    wall-clock budget, so the model tracks the data without ever
//!    stealing a full-scan's worth of latency from serving;
//! 4. the model is persisted as JSON, so a restarted process serves
//!    bit-identical answers without refitting.
//!
//! ```sh
//! cargo run --release --example serving
//! ```

use std::time::Duration;

use eakm::prelude::*;

fn main() {
    let rt = Runtime::auto();
    let model_path = std::env::temp_dir().join("eakm-serving-model.json");

    // ── startup: fit once ───────────────────────────────────────────
    let train = eakm::data::synth::blobs(50_000, 16, 100, 0.05, 1);
    let model = Kmeans::new(100)
        .algorithm(Algorithm::Auto) // resolved by dimension
        .seed(7)
        .fit(&rt, &train)
        .expect("fit failed");
    println!(
        "fitted: {} (k={}, d={}, iters={}, mse={:.5}, threads={})",
        model.algorithm(),
        model.k(),
        model.d(),
        model.report().iterations,
        model.report().mse,
        rt.threads(),
    );
    model.save(&model_path).expect("save failed");
    println!("persisted → {}", model_path.display());

    // ── steady state: many predict batches on the same runtime ──────
    let mut served = 0usize;
    for batch in 0..8 {
        let queries = eakm::data::synth::blobs(2_000, 16, 100, 0.08, 100 + batch);
        let labels = model.predict(&rt, &queries).expect("predict failed");
        served += labels.len();
    }
    println!("served {served} batched queries (one pool, zero respawns)");

    // single-point path: no dispatch, no allocation
    let probe = train.row(0);
    let (label, dist) = model.nearest(probe);
    println!("single query → cluster {label} at distance {dist:.4}");

    // ── refine under a latency budget: mini-batch rounds ────────────
    // Between traffic bursts, improve the model on sampled batches: a
    // nested batch (doubling, Newling & Fleuret 2016b) costs a fraction
    // of a full scan per round, and the time limit caps the refinement
    // rounds (the final labelling pass adds one full scan on top). The
    // refit is seeded, so it is bit-identical at any pool width.
    let refined = Kmeans::new(100)
        .algorithm(Algorithm::Auto)
        .seed(7)
        .batch_size(train.n() / 16) // ~3k rows per round to start
        .batch_growth(2.0) // nested: doubles toward the full dataset
        .time_limit(Duration::from_millis(250)) // the latency budget
        .fit(&rt, &train)
        .expect("refinement failed");
    let schedule = refined.report().batch.as_ref().expect("mini-batch telemetry");
    println!(
        "refined on {} mini-batch rounds (schedule {:?}…, mse {:.5} vs full-fit {:.5})",
        refined.report().iterations,
        &schedule.schedule[..schedule.schedule.len().min(6)],
        refined.report().mse,
        model.report().mse,
    );

    // ── restart: load and verify bit-identical serving ──────────────
    let reloaded = FittedModel::load(&model_path).expect("load failed");
    let queries = eakm::data::synth::blobs(2_000, 16, 100, 0.08, 999);
    let before = model.predict(&rt, &queries).expect("predict failed");
    let after = reloaded.predict(&rt, &queries).expect("predict failed");
    assert_eq!(before, after);
    println!("restart check OK: loaded model serves identical labels");
}
