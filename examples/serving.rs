//! Serving: fit once, predict many — and survive a restart.
//!
//! The shape of a clustering service under traffic:
//!
//! 1. a startup phase fits (or loads) a `FittedModel`;
//! 2. a long steady state answers nearest-centroid queries on one
//!    shared [`Runtime`] — batch `predict` for bulk requests,
//!    `nearest` for single points;
//! 3. the model is persisted as JSON, so a restarted process serves
//!    bit-identical answers without refitting.
//!
//! ```sh
//! cargo run --release --example serving
//! ```

use eakm::prelude::*;

fn main() {
    let rt = Runtime::auto();
    let model_path = std::env::temp_dir().join("eakm-serving-model.json");

    // ── startup: fit once ───────────────────────────────────────────
    let train = eakm::data::synth::blobs(50_000, 16, 100, 0.05, 1);
    let model = Kmeans::new(100)
        .algorithm(Algorithm::Auto) // resolved by dimension
        .seed(7)
        .fit(&rt, &train)
        .expect("fit failed");
    println!(
        "fitted: {} (k={}, d={}, iters={}, mse={:.5}, threads={})",
        model.algorithm(),
        model.k(),
        model.d(),
        model.report().iterations,
        model.report().mse,
        rt.threads(),
    );
    model.save(&model_path).expect("save failed");
    println!("persisted → {}", model_path.display());

    // ── steady state: many predict batches on the same runtime ──────
    let mut served = 0usize;
    for batch in 0..8 {
        let queries = eakm::data::synth::blobs(2_000, 16, 100, 0.08, 100 + batch);
        let labels = model.predict(&rt, &queries).expect("predict failed");
        served += labels.len();
    }
    println!("served {served} batched queries (one pool, zero respawns)");

    // single-point path: no dispatch, no allocation
    let probe = train.row(0);
    let (label, dist) = model.nearest(probe);
    println!("single query → cluster {label} at distance {dist:.4}");

    // ── restart: load and verify bit-identical serving ──────────────
    let reloaded = FittedModel::load(&model_path).expect("load failed");
    let queries = eakm::data::synth::blobs(2_000, 16, 100, 0.08, 999);
    let before = model.predict(&rt, &queries).expect("predict failed");
    let after = reloaded.predict(&rt, &queries).expect("predict failed");
    assert_eq!(before, after);
    println!("restart check OK: loaded model serves identical labels");
}
