//! Colour quantisation — the classic k-means application (the paper's
//! intro: data compression). Builds a synthetic photograph-like RGB
//! image, quantises it to a 16/64/256-colour palette with exp-ns, and
//! reports PSNR and the speedup vs the standard algorithm.
//!
//! ```sh
//! cargo run --release --example color_quantization
//! ```

use eakm::algorithms::Algorithm;
use eakm::config::RunConfig;
use eakm::coordinator::Runner;
use eakm::data::Dataset;
use eakm::rng::Rng;

/// Synthetic "photo": smooth colour gradients + texture noise + a few
/// flat regions, 256×256 RGB.
fn synth_image(side: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    // random low-frequency colour field via a few cosine plane waves
    let waves: Vec<(f64, f64, f64, [f64; 3])> = (0..6)
        .map(|_| {
            (
                rng.f64() * 0.05,
                rng.f64() * 0.05,
                rng.f64() * std::f64::consts::TAU,
                [rng.f64(), rng.f64(), rng.f64()],
            )
        })
        .collect();
    let mut out = Vec::with_capacity(side * side * 3);
    for y in 0..side {
        for x in 0..side {
            let mut px = [0.35, 0.35, 0.35];
            for &(fx, fy, ph, ref col) in &waves {
                let v = (fx * x as f64 + fy * y as f64 + ph).cos() * 0.12;
                for c in 0..3 {
                    px[c] += v * col[c];
                }
            }
            for c in px {
                out.push((c + 0.02 * rng.normal()).clamp(0.0, 1.0));
            }
        }
    }
    out
}

fn main() {
    let side = 192;
    let pixels = synth_image(side, 99);
    let n = side * side;
    let ds = Dataset::new("image", pixels.clone(), n, 3).expect("image dataset");

    println!("quantising a {side}x{side} synthetic photo (n={n} pixels, d=3)");
    for palette in [16usize, 64, 256] {
        let cfg = RunConfig::new(Algorithm::ExpNs, palette).seed(1);
        let out = Runner::new(&cfg).run(&ds).expect("quantisation run");
        // PSNR of the palettised image (pixel values in [0,1])
        let mse = out.mse; // mean squared distance over 3 channels
        let psnr = 10.0 * (3.0 / mse).log10(); // peak=1 per channel, mse is per-pixel over 3 dims
        let sta = Runner::new(&RunConfig::new(Algorithm::Sta, palette).seed(1))
            .run(&ds)
            .expect("sta run");
        assert_eq!(sta.assignments, out.assignments, "exactness violated");
        println!(
            "  {palette:>3} colours: PSNR {psnr:.1} dB, {} rounds, exp-ns {:?} vs sta {:?} ({:.2}x)",
            out.iterations,
            out.wall,
            sta.wall,
            sta.wall.as_secs_f64() / out.wall.as_secs_f64().max(1e-12)
        );
    }
    println!("color_quantization OK");
}
