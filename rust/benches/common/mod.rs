//! Shared helpers for the paper-table bench harnesses.
//!
//! Each bench target compiles this module independently and uses only a
//! subset of it, so unused-helper warnings are expected per-target —
//! silenced file-wide to keep `clippy --all-targets -- -D warnings`
//! green.
#![allow(dead_code)]

use std::path::PathBuf;

/// Round cap for bench runs — ratios stay exact (all algorithms execute
/// the identical round sequence), wall time stays bounded.
pub fn max_iters() -> usize {
    std::env::var("EAKM_MAX_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150)
}

/// Where rendered tables land.
pub fn tables_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tables");
    std::fs::create_dir_all(&dir).ok();
    dir
}

/// Print and persist one rendered table.
pub fn emit(name: &str, rendered: &str) {
    print!("{rendered}");
    let path = tables_dir().join(name);
    if let Err(e) = std::fs::write(&path, rendered) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        eprintln!("[written to {}]", path.display());
    }
}

/// Persist a machine-readable companion (`BENCH_*.json`) next to the
/// text tables so CI can diff results structurally.
pub fn emit_json(name: &str, json: &eakm::json::Json) {
    let path = tables_dir().join(name);
    if let Err(e) = std::fs::write(&path, json.to_string()) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        eprintln!("[written to {}]", path.display());
    }
}
