//! Paper Table 2 — benefits of simplification: `yin → syin` on all 22
//! datasets and `elk → selk` on the high-dimensional half, as ratios of
//! mean runtimes (simplified / original; < 1 ⇒ simplification wins).

mod common;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{
    env_scale, env_seeds, grid_datasets, grid_ks, high_d_indices, measure::measure_capped,
    TextTable,
};
use eakm::json::Json;

fn main() {
    let scale = env_scale();
    let seeds = env_seeds();
    let ks = grid_ks(scale);
    let cap = common::max_iters();

    let mut t = TextTable::new(format!(
        "Table 2 — simplification speedup (scale={scale}, seeds={seeds}, ks={ks:?}; <1 ⇒ simplified faster)"
    ))
    .headers(&[
        "ds",
        &format!("syin/yin k={}", ks[0]),
        &format!("syin/yin k={}", ks[1]),
        &format!("selk/elk k={}", ks[0]),
        &format!("selk/elk k={}", ks[1]),
    ]);

    let high_d = high_d_indices();
    let mut yin_wins = 0;
    let mut yin_total = 0;
    let mut elk_wins = 0;
    let mut elk_total = 0;
    for (spec, ds) in grid_datasets(scale, None) {
        let mut row = vec![spec.roman().to_string()];
        for &k in &ks {
            if k >= ds.n() {
                row.push("-".into());
                continue;
            }
            let syin = measure_capped(&ds, Algorithm::Syin, k, seeds, 1, cap);
            let yin = measure_capped(&ds, Algorithm::Yin, k, seeds, 1, cap);
            let r = syin.mean_wall.as_secs_f64() / yin.mean_wall.as_secs_f64().max(1e-12);
            yin_total += 1;
            if r < 1.0 {
                yin_wins += 1;
            }
            row.push(TextTable::fmt_ratio(r));
        }
        for &k in &ks {
            if !high_d.contains(&spec.index) || k >= ds.n() {
                row.push("-".into());
                continue;
            }
            let selk = measure_capped(&ds, Algorithm::Selk, k, seeds, 1, cap);
            let elk = measure_capped(&ds, Algorithm::Elk, k, seeds, 1, cap);
            let r = selk.mean_wall.as_secs_f64() / elk.mean_wall.as_secs_f64().max(1e-12);
            elk_total += 1;
            if r < 1.0 {
                elk_wins += 1;
            }
            row.push(TextTable::fmt_ratio(r));
        }
        t.row(row);
        eprint!(".");
    }
    eprintln!();
    let mut rendered = t.render();
    rendered.push_str(&format!(
        "\nsyin faster than yin in {yin_wins}/{yin_total} experiments (paper: 43/44)\n\
         selk faster than elk in {elk_wins}/{elk_total} experiments (paper: 16/18)\n"
    ));
    common::emit("table2_simplification.txt", &rendered);

    // machine-readable companion: same cells, structurally diffable
    let bench_json = Json::obj()
        .field("bench", "table2_simplification")
        .field("scale", scale)
        .field("seeds", seeds)
        .field("ks", Json::Arr(ks.iter().map(|&k| Json::from(k)).collect()))
        .field("syin_wins", yin_wins as u64)
        .field("syin_total", yin_total as u64)
        .field("selk_wins", elk_wins as u64)
        .field("selk_total", elk_total as u64)
        .field("ratios", t.to_json());
    common::emit_json("BENCH_table2.json", &bench_json);
}
