//! Scan-scheduler bench — over-decomposed LPT dispatch vs
//! one-shard-per-thread on a *clustered-skew* workload.
//!
//! The dataset is built so per-row scan cost is heavily
//! position-correlated (the worst case for static sharding): the front
//! of the row range is tight, well-separated clusters whose rows settle
//! after a round or two (bounds prune almost all distance work), while
//! the tail is one wide overlapping region whose rows keep running full
//! inner loops. With one shard per thread, the thread owning the tail
//! gates every round; over-decomposition splits the tail across many
//! claimable shards and the cost-guided LPT order dispatches them
//! first.
//!
//! Per (threads, shards-per-thread) cell the table reports round-loop
//! scan throughput (`rows/s`, gated as a floor by `bench_check --diff`)
//! and the run's straggler telemetry: the imbalance ratio
//! (slowest-shard wall / mean shard wall, summed over round dispatches)
//! and LPT reorders per dispatch. Bits are asserted identical across
//! the whole sweep — the scheduler may only move wall time.

mod common;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{env_scale, TextTable};
use eakm::config::RunConfig;
use eakm::coordinator::{RunOutput, Runner};
use eakm::data::Dataset;
use eakm::json::Json;
use eakm::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 8];
/// Shards per thread: 1 reproduces the old one-shard-per-thread static
/// split (the baseline the ≥1.2× acceptance compares against).
const FACTORS: [usize; 3] = [1, 4, 16];
const K: usize = 16;

/// Clustered-skew dataset: `frac_hot` of the rows (the tail of the row
/// range) sit in one wide blob overlapping all centroids; the rest are
/// tight separated clusters. Cost per row is therefore a step function
/// of row position.
fn skewed(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let hot = n / 4;
    let cold = n - hot;
    let mut data = Vec::with_capacity(n * d);
    for i in 0..cold {
        // tight cluster c on a line: bounds separate these immediately
        let c = (i * K / cold) as f64;
        data.push(c * 10.0 + 0.05 * rng.normal());
        for _ in 1..d {
            data.push(0.05 * rng.normal());
        }
    }
    for _ in 0..hot {
        // one wide region spanning every cluster centre: these rows
        // stay ambiguous, so their inner loops never collapse
        data.push(5.0 * K as f64 * rng.f64());
        for _ in 1..d {
            data.push(3.0 * rng.normal());
        }
    }
    Dataset::new("skewed", data, n, d).unwrap()
}

fn main() {
    let scale = env_scale();
    let cap = common::max_iters();
    // floor keeps the largest sweep cell (8 threads × 16 shards/thread
    // = 128 shards) above the 256-row min-shard floor even at smoke
    // scale
    let n = ((262_144.0 * scale) as usize).clamp(32_768, 262_144);
    let d = 8;
    let ds = skewed(n, d, 0x5CED);

    let mut t = TextTable::new(format!(
        "Scan scheduling — over-decomposed LPT vs static split, clustered skew (n={n}, scale={scale})"
    ))
    .headers(&["T", "S/T", "shards", "rows/s", "imbalance", "reord/disp", "identical"]);

    let mut base: Option<RunOutput> = None;
    let mut static8 = 0.0f64; // rows/s at T=8, one shard per thread
    let mut over8 = 0.0f64; // best rows/s at T=8, over-decomposed
    for &threads in &THREADS {
        for &factor in &FACTORS {
            let cfg = RunConfig::new(Algorithm::ExpNs, K)
                .seed(0)
                .threads(threads)
                .scan_shards(threads * factor)
                .max_iters(cap);
            let out = Runner::new(&cfg).run(&ds).unwrap();
            let sched = out.report.sched;
            // the scan phase covers the initial full assignment plus
            // every round — one full-dataset pass per dispatch
            let scan_secs = out.report.phases.scan.as_secs_f64().max(1e-12);
            let rows_per_s = (n as u64 * sched.dispatches) as f64 / scan_secs;
            let identical = match &base {
                None => true,
                Some(b) => {
                    b.assignments == out.assignments
                        && b.counters == out.counters
                        && b.mse.to_bits() == out.mse.to_bits()
                }
            };
            if threads == 8 {
                if factor == 1 {
                    static8 = rows_per_s;
                } else {
                    over8 = over8.max(rows_per_s);
                }
            }
            t.row(vec![
                threads.to_string(),
                factor.to_string(),
                sched.shards.to_string(),
                format!("{rows_per_s:.0}"),
                format!("{:.2}", sched.imbalance()),
                format!("{:.2}", sched.reorders as f64 / sched.dispatches.max(1) as f64),
                identical.to_string(),
            ]);
            if base.is_none() {
                base = Some(out);
            }
            eprint!(".");
        }
    }
    eprintln!();

    let speedup8 = over8 / static8.max(1e-12);
    let mut rendered = t.render();
    rendered.push_str(&format!(
        "\nAt T=8, over-decomposition reaches {speedup8:.2}x the static one-shard-per-thread\n\
         round-loop rows/s (acceptance target: ≥1.2x on a real 8-core machine; a\n\
         time-sliced smoke runner understates it). `identical` spans the whole sweep.\n",
    ));
    common::emit("sched.txt", &rendered);

    let bench_json = Json::obj()
        .field("bench", "sched")
        .field("scale", scale)
        .field("n", n)
        .field("max_iters", cap)
        .field("speedup_t8", speedup8)
        .field("skew", t.to_json());
    common::emit_json("BENCH_sched.json", &bench_json);
}
