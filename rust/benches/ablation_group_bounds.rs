//! Ablation benches for two §3 design choices:
//!
//! 1. **Exponion's partial sort** (concentric annuli) vs an exact sort:
//!    candidate-set over-coverage `|J*|/|J|` (paper bound: ≤ 2) and build
//!    cost, on real centroid configurations from converging runs.
//! 2. **Group-bound schemes** (SM-C.2): SMN (syin's rolling sums) vs MNS
//!    (syin-ns's norm-of-sum) — runtime and distance-calculation ratios,
//!    isolating what the ns machinery buys for group bounds.

mod common;

use std::time::Instant;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{env_scale, env_seeds, measure::measure_capped, TextTable};
use eakm::config::RunConfig;
use eakm::coordinator::annuli::Annuli;
use eakm::coordinator::ccdist::CcData;
use eakm::coordinator::Engine;
use eakm::data::synth::{find, generate};
use eakm::metrics::Counters;

fn main() {
    let scale = env_scale();
    let seeds = env_seeds();
    let cap = common::max_iters();

    // --- ablation 1: annuli over-coverage on real centroid layouts ---
    let mut t1 = TextTable::new("Ablation — Exponion partial sort vs exact candidate set")
        .headers(&["dataset", "k", "round", "mean |J*|/|J|", "max |J*|/|J|", "build ms"]);
    for name in ["birch", "europe"] {
        let ds = generate(&find(name).unwrap(), scale, 1);
        let k = 50.min(ds.n() / 4);
        let cfg = RunConfig::new(Algorithm::Exp, k).seed(0).max_iters(cap);
        let mut engine = Engine::new(&ds, &cfg).unwrap();
        for round in [1usize, 5, 15] {
            while engine.rounds() < round && !engine.converged() {
                engine.step();
            }
            let centroids = engine.centroids().to_vec();
            let mut ctr = Counters::default();
            let cc = CcData::build(&centroids, k, ds.d(), &mut ctr);
            let t0 = Instant::now();
            let ann = Annuli::build(&cc);
            let build = t0.elapsed().as_secs_f64() * 1e3;
            // sample radii representative of exponion queries: 2u+s with
            // u ~ typical cluster radius → use s(j) multiples
            let mut ratios = Vec::new();
            for j in 0..k {
                for mult in [1.5, 3.0, 6.0] {
                    let r = cc.s[j] * mult;
                    let exact = ann.exact_count(j, r).max(1);
                    let approx = ann.candidates(j, r).len();
                    ratios.push(approx as f64 / exact as f64);
                }
            }
            let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
            let max = ratios.iter().cloned().fold(0.0, f64::max);
            t1.row(vec![
                name.to_string(),
                k.to_string(),
                engine.rounds().to_string(),
                format!("{mean:.2}"),
                format!("{max:.2}"),
                format!("{build:.3}"),
            ]);
        }
    }
    let mut rendered = t1.render();
    rendered.push_str("\npaper guarantee: |J*| ≤ 2|J| (+1 for the base annulus) — max ratio must stay ≤ ~2–3\n\n");

    // --- ablation 2: SMN (syin) vs MNS (syin-ns) group bounds ---
    let mut t2 = TextTable::new("Ablation — group-bound scheme SMN (syin) vs MNS (syin-ns)")
        .headers(&["dataset", "k", "q_t (mns/smn)", "q_a", "q_au"]);
    for name in ["wcomp", "keggnet", "miniboone"] {
        let ds = generate(&find(name).unwrap(), scale, 2);
        let k = 50.min(ds.n() / 4);
        let smn = measure_capped(&ds, Algorithm::Syin, k, seeds, 1, cap);
        let mns = measure_capped(&ds, Algorithm::SyinNs, k, seeds, 1, cap);
        t2.row(vec![
            name.to_string(),
            k.to_string(),
            TextTable::fmt_ratio(mns.mean_wall.as_secs_f64() / smn.mean_wall.as_secs_f64()),
            TextTable::fmt_ratio(mns.mean_qa / smn.mean_qa),
            TextTable::fmt_ratio(mns.mean_qau / smn.mean_qau),
        ]);
    }
    rendered.push_str(&t2.render());
    rendered.push_str("\nSM-C.2: MNS gives the tightest group bounds; q_a < 1 everywhere is the expected signature.\n");
    common::emit("ablation_group_bounds.txt", &rendered);
}
