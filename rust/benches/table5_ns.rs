//! Paper Table 5 — sn → ns bounding: for each {dataset, k}, take the
//! fastest sn-algorithm that has an ns-variant and report ns/sn ratios of
//! runtime (`q_t`), assignment distance calculations (`q_a`) and total
//! distance calculations (`q_au`).

mod common;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{
    env_scale, env_seeds, grid_datasets, grid_ks, measure::measure_capped, TextTable,
};
use eakm::json::Json;

fn main() {
    let scale = env_scale();
    let seeds = env_seeds();
    let ks = grid_ks(scale);
    let cap = common::max_iters();
    // candidates: the sn-algorithms with ns variants (paper's Table 5 'x'
    // column only ever contains these four)
    let candidates = [
        Algorithm::Selk,
        Algorithm::Elk,
        Algorithm::Syin,
        Algorithm::Exp,
    ];

    let mut headers = vec!["ds".to_string()];
    for &k in &ks {
        headers.push(format!("x k={k}"));
        headers.push(format!("q_t k={k}"));
        headers.push(format!("q_a k={k}"));
        headers.push(format!("q_au k={k}"));
    }
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = TextTable::new(format!(
        "Table 5 — ns-bounds vs sn-bounds on the fastest sn-algorithm (scale={scale}, seeds={seeds}; <1 ⇒ ns wins)"
    ))
    .headers(&headers_ref);

    let mut speedups = 0;
    let mut total = 0;
    let mut qa_never_worse = true;
    for (spec, ds) in grid_datasets(scale, None) {
        let mut row = vec![spec.roman().to_string()];
        for &k in &ks {
            if k >= ds.n() {
                row.extend(["-".into(), "-".into(), "-".into(), "-".into()]);
                continue;
            }
            let mut best: Option<(Algorithm, eakm::bench_support::MeasureStats)> = None;
            for &alg in &candidates {
                let st = measure_capped(&ds, alg, k, seeds, 1, cap);
                if best
                    .as_ref()
                    .map(|(_, b)| st.mean_wall < b.mean_wall)
                    .unwrap_or(true)
                {
                    best = Some((alg, st));
                }
            }
            let (sn_alg, sn) = best.unwrap();
            let ns_alg = sn_alg.ns_variant().unwrap();
            let ns = measure_capped(&ds, ns_alg, k, seeds, 1, cap);
            let qt = ns.mean_wall.as_secs_f64() / sn.mean_wall.as_secs_f64().max(1e-12);
            let qa = ns.mean_qa / sn.mean_qa.max(1e-12);
            let qau = ns.mean_qau / sn.mean_qau.max(1e-12);
            total += 1;
            if qt < 1.0 {
                speedups += 1;
            }
            if qa > 1.0 + 1e-9 {
                qa_never_worse = false;
            }
            row.push(sn_alg.name().to_string());
            row.push(TextTable::fmt_ratio(qt));
            row.push(TextTable::fmt_ratio(qa));
            row.push(TextTable::fmt_ratio(qau));
        }
        t.row(row);
        eprint!(".");
    }
    eprintln!();
    let mut rendered = t.render();
    rendered.push_str(&format!(
        "\nns faster in {speedups}/{total} experiments (paper: 36/44, up to 45%)\n\
         q_a never worse with ns: {qa_never_worse} (paper: guaranteed by construction)\n"
    ));
    common::emit("table5_ns.txt", &rendered);

    // machine-readable companion: same cells, structurally diffable
    let bench_json = Json::obj()
        .field("bench", "table5_ns")
        .field("scale", scale)
        .field("seeds", seeds)
        .field("ks", Json::Arr(ks.iter().map(|&k| Json::from(k)).collect()))
        .field("speedups", speedups as u64)
        .field("total", total as u64)
        .field("qa_never_worse", qa_never_worse)
        .field("ratios", t.to_json());
    common::emit_json("BENCH_table5.json", &bench_json);
}
