//! Paper Table 3 — Annular → Exponion on the low-dimensional datasets
//! (d < 20): ratios of mean runtimes (`q_t`) and of mean total distance
//! calculations (`q_au`), exp / ann (< 1 ⇒ Exponion wins).

mod common;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{
    env_scale, env_seeds, grid_datasets, grid_ks, low_d_indices, measure::measure_capped,
    TextTable,
};
use eakm::json::Json;

fn main() {
    let scale = env_scale();
    let seeds = env_seeds();
    let ks = grid_ks(scale);
    let cap = common::max_iters();

    let mut t = TextTable::new(format!(
        "Table 3 — own-ann → own-exp on d<20 datasets (scale={scale}, seeds={seeds}; <1 ⇒ exp wins)"
    ))
    .headers(&[
        "ds",
        &format!("q_t k={}", ks[0]),
        &format!("q_t k={}", ks[1]),
        &format!("q_au k={}", ks[0]),
        &format!("q_au k={}", ks[1]),
    ]);

    let low = low_d_indices();
    let mut faster = 0;
    let mut total = 0;
    for (spec, ds) in grid_datasets(scale, Some(&low)) {
        let mut qt = Vec::new();
        let mut qau = Vec::new();
        for &k in &ks {
            if k >= ds.n() {
                qt.push(f64::NAN);
                qau.push(f64::NAN);
                continue;
            }
            let exp = measure_capped(&ds, Algorithm::Exp, k, seeds, 1, cap);
            let ann = measure_capped(&ds, Algorithm::Ann, k, seeds, 1, cap);
            let rt = exp.mean_wall.as_secs_f64() / ann.mean_wall.as_secs_f64().max(1e-12);
            total += 1;
            if rt < 1.0 {
                faster += 1;
            }
            qt.push(rt);
            qau.push(exp.mean_qau / ann.mean_qau.max(1e-12));
        }
        t.row(vec![
            spec.roman().to_string(),
            TextTable::fmt_ratio(qt[0]),
            TextTable::fmt_ratio(qt[1]),
            TextTable::fmt_ratio(qau[0]),
            TextTable::fmt_ratio(qau[1]),
        ]);
        eprint!(".");
    }
    eprintln!();
    let mut rendered = t.render();
    rendered.push_str(&format!(
        "\nexp faster than ann in {faster}/{total} experiments (paper: 18/22, >30% faster in 17/22)\n"
    ));
    common::emit("table3_exponion.txt", &rendered);

    // machine-readable companion for the bench_check schema gate + diffs
    let bench_json = Json::obj()
        .field("bench", "table3_exponion")
        .field("scale", scale)
        .field("seeds", seeds)
        .field("max_iters", cap)
        .field("exp_faster", faster)
        .field("total", total)
        .field("ratios", t.to_json());
    common::emit_json("BENCH_table3.json", &bench_json);
}
