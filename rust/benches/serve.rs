//! Serve throughput bench: requests/s over a loopback socket as a
//! function of the micro-batcher's coalescing cap (`--max-batch`).
//!
//! Eight concurrent clients issue synchronous predict requests against
//! one server. With `max_batch = 1` every request costs its own pool
//! dispatch + scan; with a real coalescing cap the batcher folds the
//! backlog that accumulates during each scan into one shard pass —
//! the serving-time analogue of the paper's amortise-work-per-query
//! theme. The table reports the throughput ratio against the
//! unbatched row, plus the server's own telemetry (batches, coalesced
//! batches, overloaded rejects).

mod common;

use std::net::SocketAddr;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use eakm::bench_support::{env_scale, TextTable};
use eakm::data::synth::blobs;
use eakm::json::Json;
use eakm::model::{FittedModel, Kmeans};
use eakm::runtime::Runtime;
use eakm::serve::client::{self, Client};
use eakm::serve::{serve, ServeConfig, ServeStats};

const CLIENTS: usize = 8;
const ROWS_PER_REQ: usize = 4;
const SERVER_THREADS: usize = 4;
const MAX_BATCH_SWEEP: [usize; 3] = [1, 64, 512];

/// One benchmark round: spin up a server with the given coalescing cap,
/// hammer it from `CLIENTS` synchronous clients, return the client-side
/// wall time and the server's final telemetry.
fn run_round(
    model: FittedModel,
    queries: &[f64],
    d: usize,
    per_client: usize,
    max_batch_rows: usize,
) -> (Duration, ServeStats) {
    let cfg = ServeConfig {
        acceptors: CLIENTS,
        queue_depth: 1024,
        max_batch_rows,
        ..ServeConfig::default()
    };
    let (addr_tx, addr_rx) = mpsc::channel();
    let server = thread::spawn(move || {
        let rt = Runtime::new(SERVER_THREADS);
        serve(&rt, model, &cfg, |addr| addr_tx.send(addr).unwrap()).unwrap()
    });
    let addr: SocketAddr = addr_rx.recv().unwrap();
    let n_rows = queries.len() / d;
    let started = Instant::now();
    let mut workers = Vec::new();
    for c in 0..CLIENTS {
        let queries = queries.to_vec();
        workers.push(thread::spawn(move || {
            let mut cl = Client::connect(addr).unwrap();
            for i in 0..per_client {
                let lo = ((c * per_client + i) * ROWS_PER_REQ) % (n_rows - ROWS_PER_REQ);
                let line = client::predict_request(&queries[lo * d..(lo + ROWS_PER_REQ) * d], d);
                let reply = cl.call(&line).unwrap();
                assert_eq!(
                    reply.get("ok").and_then(Json::as_bool),
                    Some(true),
                    "request failed: {reply}"
                );
            }
        }));
    }
    for w in workers {
        w.join().unwrap();
    }
    let wall = started.elapsed();
    // clean shutdown: the joined server returns its final telemetry
    let _ = Client::connect(addr)
        .unwrap()
        .call(&client::shutdown_request());
    (wall, server.join().unwrap())
}

fn main() {
    let scale = env_scale();
    let per_client = ((20_000.0 * scale) as usize).max(40);
    let (d, k) = (8, 64);
    let rt = Runtime::new(SERVER_THREADS);
    let train = blobs(6_000, d, k, 0.08, 0x5E12);
    let model = Kmeans::new(k).seed(7).fit(&rt, &train).unwrap();
    let queries = blobs(2_048, d, k, 0.12, 0xC11E);
    drop(rt);

    let mut t = TextTable::new(format!(
        "Serve throughput vs micro-batch cap ({CLIENTS} clients × {per_client} reqs, \
         {ROWS_PER_REQ} rows/req, k={k}, d={d}, {SERVER_THREADS} server threads)"
    ))
    .headers(&[
        "max_batch",
        "clients",
        "reqs",
        "rows/req",
        "wall[s]",
        "req/s",
        "vs_mb1",
        "batches",
        "coalesced",
        "overloaded",
    ]);

    let total_reqs = CLIENTS * per_client;
    let mut base_rps = None;
    for &max_batch in &MAX_BATCH_SWEEP {
        let (wall, stats) = run_round(
            model.clone(),
            queries.raw(),
            d,
            per_client,
            max_batch,
        );
        let rps = total_reqs as f64 / wall.as_secs_f64();
        let base = *base_rps.get_or_insert(rps);
        assert_eq!(
            stats.predicts, total_reqs as u64,
            "every request must be served"
        );
        // measured cells are float-formatted (the '.' keeps them out of
        // the cross-commit diff row key; only the stable knob cells —
        // max_batch, clients, reqs, rows/req — identify a row)
        t.row(vec![
            max_batch.to_string(),
            CLIENTS.to_string(),
            total_reqs.to_string(),
            ROWS_PER_REQ.to_string(),
            format!("{:.4}", wall.as_secs_f64()),
            format!("{rps:.1}"),
            TextTable::fmt_ratio(rps / base),
            format!("{:.1}", stats.batches as f64),
            format!("{:.1}", stats.coalesced_batches as f64),
            format!("{:.1}", stats.queue_full_rejects as f64),
        ]);
        eprint!(".");
    }
    eprintln!();

    let mut rendered = t.render();
    rendered.push_str(
        "\nmax_batch=1 scans every request alone; larger caps let the batcher fold\n\
         the backlog accumulated during each scan into one pool-sharded pass, so\n\
         req/s should rise (vs_mb1 ≥ 1.00) while batches shrink below reqs.\n",
    );
    common::emit("serve_throughput.txt", &rendered);

    let bench_json = Json::obj()
        .field("bench", "serve")
        .field("scale", scale)
        .field("clients", CLIENTS as u64)
        .field("rows_per_request", ROWS_PER_REQ as u64)
        .field("server_threads", SERVER_THREADS as u64)
        .field("throughput", t.to_json());
    common::emit_json("BENCH_serve.json", &bench_json);
}
