//! Serve benches: closed-loop throughput and open-loop latency over a
//! loopback socket.
//!
//! **Throughput** — eight concurrent clients issue synchronous predict
//! requests against one server as a function of the micro-batcher's
//! coalescing cap (`--max-batch`). With `max_batch = 1` every request
//! costs its own pool dispatch + scan; with a real coalescing cap the
//! batcher folds the backlog that accumulates during each scan into one
//! shard pass — the serving-time analogue of the paper's
//! amortise-work-per-query theme. The table reports the throughput
//! ratio against the unbatched row, plus the server's own telemetry
//! (batches, coalesced batches, overloaded rejects).
//!
//! **Latency** — clients send single-row predicts on a fixed schedule
//! (an offered QPS, not as-fast-as-possible) and latency is measured
//! from the *scheduled* send time, so a server that falls behind
//! accrues visible queueing delay instead of silently slowing the
//! arrival process (no coordinated omission). Rows sweep
//! {line-JSON, HTTP/1.1 shim} × offered load, reporting p50/p99.

mod common;

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use eakm::bench_support::{env_scale, TextTable};
use eakm::data::synth::blobs;
use eakm::json::Json;
use eakm::model::{FittedModel, Kmeans};
use eakm::runtime::Runtime;
use eakm::serve::client::{self, Client};
use eakm::serve::state::Op;
use eakm::serve::{serve, ServeConfig, ServeStats, ServeTelemetry};

const CLIENTS: usize = 8;
const ROWS_PER_REQ: usize = 4;
const SERVER_THREADS: usize = 4;
const MAX_BATCH_SWEEP: [usize; 3] = [1, 64, 512];
const LATENCY_CLIENTS: usize = 4;
const LATENCY_QPS: [f64; 2] = [250.0, 1000.0];
const OVERHEAD_ROUNDS: usize = 7;
/// Gate: per-op histogram recording may cost at most +2% on the
/// predict hot path.
const OVERHEAD_GATE: f64 = 1.02;

/// One benchmark round: spin up a server with the given coalescing cap,
/// hammer it from `CLIENTS` synchronous clients, return the client-side
/// wall time and the server's final telemetry.
fn run_round(
    model: FittedModel,
    queries: &[f64],
    d: usize,
    per_client: usize,
    max_batch_rows: usize,
) -> (Duration, ServeStats) {
    let cfg = ServeConfig {
        acceptors: CLIENTS,
        queue_depth: 1024,
        max_batch_rows,
        ..ServeConfig::default()
    };
    let (addr_tx, addr_rx) = mpsc::channel();
    let server = thread::spawn(move || {
        let rt = Runtime::new(SERVER_THREADS);
        serve(&rt, model, &cfg, |addr| addr_tx.send(addr).unwrap()).unwrap()
    });
    let addr: SocketAddr = addr_rx.recv().unwrap();
    let n_rows = queries.len() / d;
    let started = Instant::now();
    let mut workers = Vec::new();
    for c in 0..CLIENTS {
        let queries = queries.to_vec();
        workers.push(thread::spawn(move || {
            let mut cl = Client::connect(addr).unwrap();
            for i in 0..per_client {
                let lo = ((c * per_client + i) * ROWS_PER_REQ) % (n_rows - ROWS_PER_REQ);
                let line = client::predict_request(&queries[lo * d..(lo + ROWS_PER_REQ) * d], d);
                let reply = cl.call(&line).unwrap();
                assert_eq!(
                    reply.get("ok").and_then(Json::as_bool),
                    Some(true),
                    "request failed: {reply}"
                );
            }
        }));
    }
    for w in workers {
        w.join().unwrap();
    }
    let wall = started.elapsed();
    // clean shutdown: the joined server returns its final telemetry
    let _ = Client::connect(addr)
        .unwrap()
        .call(&client::shutdown_request());
    (wall, server.join().unwrap())
}

/// Wire protocol a latency client speaks (the `proto` axis).
#[derive(Clone, Copy, PartialEq)]
enum Proto {
    Json,
    Http,
}

impl Proto {
    fn name(self) -> &'static str {
        match self {
            Proto::Json => "json",
            Proto::Http => "http",
        }
    }
}

/// Minimal keep-alive HTTP/1.1 client for the latency sweep. The bench
/// only needs `POST /v1/predict` with a Content-Length body and a 200
/// reply on a reused connection — the full-featured test client lives
/// in tests/serve.rs.
struct HttpConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl HttpConn {
    fn connect(addr: SocketAddr) -> HttpConn {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(60)))
            .unwrap();
        HttpConn {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn call(&mut self, body: &str) -> Json {
        write!(
            self.writer,
            "POST /v1/predict HTTP/1.1\r\nhost: bench\r\ncontent-type: application/json\r\n\
             content-length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut status = String::new();
        self.reader.read_line(&mut status).unwrap();
        assert!(status.starts_with("HTTP/1.1 200"), "bad status: {status}");
        let mut clen = 0usize;
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).unwrap();
            let line = h.trim().to_ascii_lowercase();
            if line.is_empty() {
                break;
            }
            if let Some(v) = line.strip_prefix("content-length:") {
                clen = v.trim().parse().unwrap();
            }
        }
        let mut buf = vec![0u8; clen];
        self.reader.read_exact(&mut buf).unwrap();
        Json::parse(String::from_utf8(buf).unwrap().trim()).unwrap()
    }
}

/// One latency-client connection, line-JSON or HTTP — both carry the
/// same request body, so the sweep isolates pure protocol overhead.
enum BenchConn {
    Json(Client),
    Http(HttpConn),
}

impl BenchConn {
    fn connect(proto: Proto, addr: SocketAddr) -> BenchConn {
        match proto {
            Proto::Json => BenchConn::Json(Client::connect(addr).unwrap()),
            Proto::Http => BenchConn::Http(HttpConn::connect(addr)),
        }
    }

    fn predict(&mut self, line: &str) {
        let reply = match self {
            BenchConn::Json(c) => c.call(line).unwrap(),
            BenchConn::Http(c) => c.call(line),
        };
        assert_eq!(
            reply.get("ok").and_then(Json::as_bool),
            Some(true),
            "request failed: {reply}"
        );
    }
}

/// One open-loop round: `LATENCY_CLIENTS` clients each send single-row
/// predicts on a fixed schedule (offered load `qps` across all clients,
/// arrivals staggered uniformly) and report per-request latency from
/// the scheduled send time.
fn run_latency_round(
    model: FittedModel,
    queries: &[f64],
    d: usize,
    proto: Proto,
    qps: f64,
    per_client: usize,
) -> (Duration, Vec<f64>, ServeStats) {
    let cfg = ServeConfig {
        acceptors: LATENCY_CLIENTS,
        queue_depth: 1024,
        ..ServeConfig::default()
    };
    let (addr_tx, addr_rx) = mpsc::channel();
    let server = thread::spawn(move || {
        let rt = Runtime::new(SERVER_THREADS);
        serve(&rt, model, &cfg, |addr| addr_tx.send(addr).unwrap()).unwrap()
    });
    let addr: SocketAddr = addr_rx.recv().unwrap();
    let n_rows = queries.len() / d;
    let epoch = Instant::now();
    let mut workers = Vec::new();
    for c in 0..LATENCY_CLIENTS {
        let queries = queries.to_vec();
        workers.push(thread::spawn(move || {
            let mut conn = BenchConn::connect(proto, addr);
            let interval = LATENCY_CLIENTS as f64 / qps;
            let mut lat_ms = Vec::with_capacity(per_client);
            for i in 0..per_client {
                let sched =
                    epoch + Duration::from_secs_f64(c as f64 / qps + i as f64 * interval);
                if let Some(wait) = sched.checked_duration_since(Instant::now()) {
                    thread::sleep(wait);
                }
                let lo = (c * per_client + i) % n_rows;
                conn.predict(&client::predict_request(&queries[lo * d..(lo + 1) * d], d));
                // from the *scheduled* send: a late previous reply shows
                // up as queueing delay here, not a slower arrival rate
                lat_ms.push(
                    Instant::now().saturating_duration_since(sched).as_secs_f64() * 1e3,
                );
            }
            lat_ms
        }));
    }
    let mut lat_ms = Vec::new();
    for w in workers {
        lat_ms.extend(w.join().unwrap());
    }
    let wall = epoch.elapsed();
    let _ = Client::connect(addr)
        .unwrap()
        .call(&client::shutdown_request());
    (wall, lat_ms, server.join().unwrap())
}

/// Nearest-rank percentile over an already-sorted sample.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

/// Time `reps` instrumented predict scans — the per-batch hot-path
/// sequence (one pool-sharded scan + one telemetry record) with per-op
/// histogram recording on or off.
fn overhead_pass(
    rt: &Runtime,
    model: &FittedModel,
    queries: &[f64],
    reps: usize,
    record_hist: bool,
) -> Duration {
    let tel = ServeTelemetry::new(record_hist);
    let started = Instant::now();
    for _ in 0..reps {
        let t0 = Instant::now();
        let labels = model.predict_rows(rt, queries).unwrap();
        assert!(!labels.is_empty());
        tel.request();
        tel.op_done(Op::Predict, t0.elapsed());
    }
    started.elapsed()
}

fn main() {
    let scale = env_scale();
    let per_client = ((20_000.0 * scale) as usize).max(40);
    let (d, k) = (8, 64);
    let rt = Runtime::new(SERVER_THREADS);
    let train = blobs(6_000, d, k, 0.08, 0x5E12);
    let model = Kmeans::new(k).seed(7).fit(&rt, &train).unwrap();
    let queries = blobs(2_048, d, k, 0.12, 0xC11E);
    drop(rt);

    let mut t = TextTable::new(format!(
        "Serve throughput vs micro-batch cap ({CLIENTS} clients × {per_client} reqs, \
         {ROWS_PER_REQ} rows/req, k={k}, d={d}, {SERVER_THREADS} server threads)"
    ))
    .headers(&[
        "max_batch",
        "clients",
        "reqs",
        "rows/req",
        "wall[s]",
        "req/s",
        "vs_mb1",
        "batches",
        "coalesced",
        "overloaded",
    ]);

    let total_reqs = CLIENTS * per_client;
    let mut base_rps = None;
    for &max_batch in &MAX_BATCH_SWEEP {
        let (wall, stats) = run_round(
            model.clone(),
            queries.raw(),
            d,
            per_client,
            max_batch,
        );
        let rps = total_reqs as f64 / wall.as_secs_f64();
        let base = *base_rps.get_or_insert(rps);
        assert_eq!(
            stats.predicts, total_reqs as u64,
            "every request must be served"
        );
        // measured cells are float-formatted (the '.' keeps them out of
        // the cross-commit diff row key; only the stable knob cells —
        // max_batch, clients, reqs, rows/req — identify a row)
        t.row(vec![
            max_batch.to_string(),
            CLIENTS.to_string(),
            total_reqs.to_string(),
            ROWS_PER_REQ.to_string(),
            format!("{:.4}", wall.as_secs_f64()),
            format!("{rps:.1}"),
            TextTable::fmt_ratio(rps / base),
            format!("{:.1}", stats.batches as f64),
            format!("{:.1}", stats.coalesced_batches as f64),
            format!("{:.1}", stats.queue_full_rejects as f64),
        ]);
        eprint!(".");
    }
    eprintln!();

    let mut rendered = t.render();
    rendered.push_str(
        "\nmax_batch=1 scans every request alone; larger caps let the batcher fold\n\
         the backlog accumulated during each scan into one pool-sharded pass, so\n\
         req/s should rise (vs_mb1 ≥ 1.00) while batches shrink below reqs.\n",
    );
    common::emit("serve_throughput.txt", &rendered);

    // ---- open-loop latency under load ---------------------------------
    let secs = (4.0 * scale).clamp(0.25, 4.0);
    let mut lt = TextTable::new(format!(
        "Serve latency under open-loop load ({LATENCY_CLIENTS} clients, 1 row/req, \
         k={k}, d={d}, {SERVER_THREADS} server threads; latency from scheduled send)"
    ))
    .headers(&[
        "proto",
        "qps",
        "clients",
        "reqs",
        "wall[s]",
        "p50_ms",
        "p99_ms",
        "achieved_qps",
    ]);
    for &qps in &LATENCY_QPS {
        let per_client = ((qps * secs / LATENCY_CLIENTS as f64).round() as usize).max(10);
        let total = LATENCY_CLIENTS * per_client;
        for proto in [Proto::Json, Proto::Http] {
            let (wall, mut lat, stats) =
                run_latency_round(model.clone(), queries.raw(), d, proto, qps, per_client);
            assert_eq!(stats.predicts, total as u64, "every request must be served");
            if proto == Proto::Http {
                assert_eq!(
                    stats.http_requests, total as u64,
                    "http rounds must ride the shim"
                );
            }
            lat.sort_by(f64::total_cmp);
            // p50_ms/p99_ms deliberately avoid the differ's timing-header
            // patterns: loopback tail latencies are too jittery to gate
            lt.row(vec![
                proto.name().to_string(),
                format!("{qps:.0}"),
                LATENCY_CLIENTS.to_string(),
                total.to_string(),
                format!("{:.4}", wall.as_secs_f64()),
                format!("{:.3}", percentile(&lat, 0.50)),
                format!("{:.3}", percentile(&lat, 0.99)),
                format!("{:.1}", total as f64 / wall.as_secs_f64()),
            ]);
            eprint!(".");
        }
    }
    eprintln!();

    let mut rendered = lt.render();
    rendered.push_str(
        "\nOpen loop: each client sends on a fixed schedule and latency counts from\n\
         the scheduled send time, so queueing delay under load stays visible (p99\n\
         rises above p50 as the server saturates). json is the line-delimited fast\n\
         path; http drives the same ops through the HTTP/1.1 shim.\n",
    );
    common::emit("serve_latency.txt", &rendered);

    // ---- observability overhead on the predict hot path ---------------
    // the same scan the batcher runs per batch, with the instrument
    // sequence (Instant::now + atomic counters + optionally one
    // log-bucketed histogram record) on both sides. Rounds alternate
    // modes and each side keeps its min, so machine noise hits both
    // alike; the gate fails the bench before an expensive /metrics
    // pipeline could sneak onto the hot path.
    let rt = Runtime::new(SERVER_THREADS);
    let reps = ((400.0 * scale) as usize).max(20);
    let rows_per_scan = queries.raw().len() / d;
    let _ = overhead_pass(&rt, &model, queries.raw(), reps, true); // warm the pool
    let mut best_off = Duration::MAX;
    let mut best_on = Duration::MAX;
    for _ in 0..OVERHEAD_ROUNDS {
        let off = overhead_pass(&rt, &model, queries.raw(), reps, false);
        let on = overhead_pass(&rt, &model, queries.raw(), reps, true);
        best_off = best_off.min(off);
        best_on = best_on.min(on);
        eprint!(".");
    }
    eprintln!();
    let ratio = best_on.as_secs_f64() / best_off.as_secs_f64();
    assert!(
        ratio < OVERHEAD_GATE,
        "histogram recording costs {:+.2}% on the predict hot path (gate +{:.0}%)",
        (ratio - 1.0) * 100.0,
        (OVERHEAD_GATE - 1.0) * 100.0
    );
    let overhead_headers = ["histograms", "scans", "rows/scan", "wall[s]", "vs_off"];
    let mut ot = TextTable::new(format!(
        "Observability overhead on the predict hot path ({reps} scans × {rows_per_scan} \
         rows, min over {OVERHEAD_ROUNDS} alternating rounds, gate < +2%)"
    ))
    .headers(&overhead_headers);
    for (mode, wall) in [("off", best_off), ("on", best_on)] {
        ot.row(vec![
            mode.to_string(),
            reps.to_string(),
            rows_per_scan.to_string(),
            format!("{:.4}", wall.as_secs_f64()),
            TextTable::fmt_ratio(wall.as_secs_f64() / best_off.as_secs_f64()),
        ]);
    }
    let mut rendered = ot.render();
    rendered.push_str(
        "\nEach scan is the batcher's hot path: one pool-sharded predict plus one\n\
         telemetry record. 'on' additionally records into the log-bucketed latency\n\
         histograms behind /metrics and the stats-op p50/p99; the bench fails if\n\
         that costs 2% or more.\n",
    );
    common::emit("serve_obs_overhead.txt", &rendered);

    let bench_json = Json::obj()
        .field("bench", "serve")
        .field("scale", scale)
        .field("clients", CLIENTS as u64)
        .field("rows_per_request", ROWS_PER_REQ as u64)
        .field("server_threads", SERVER_THREADS as u64)
        .field("throughput", t.to_json())
        .field("latency", lt.to_json())
        .field("overhead", ot.to_json());
    common::emit_json("BENCH_serve.json", &bench_json);
}
