//! Paper Tables 9 & 10 — the full grid: every dataset × every algorithm
//! at the two k values, reported as mean runtime relative to the fastest
//! algorithm (1.00 = fastest, underlined in the paper).
//!
//! Scaled by EAKM_SCALE (default 0.02) — scale 1.0 reproduces the exact
//! Table 8 sizes given the paper's 40-minute-per-run budget.

mod common;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{
    env_scale, env_seeds, grid_datasets, grid_ks, measure::measure_capped, TextTable,
};
use eakm::json::Json;

fn main() {
    let scale = env_scale();
    let seeds = env_seeds();
    let ks = grid_ks(scale);
    let cap = common::max_iters();
    let algs: Vec<Algorithm> = Algorithm::SN
        .iter()
        .chain(Algorithm::NS.iter())
        .copied()
        .collect();

    // one JSON artifact carries both grids under scale-stable keys
    let mut bench_json = Json::obj()
        .field("bench", "table9_grid")
        .field("scale", scale)
        .field("seeds", seeds)
        .field("max_iters", cap)
        .field("ks", Json::Arr(ks.iter().map(|&k| Json::from(k)).collect()));

    for (tbl, &k) in ["table9", "table10"].iter().zip(ks.iter()) {
        let mut headers: Vec<String> = vec![
            "ds".into(),
            "iters".into(),
            "sd_it".into(),
            "fastest[s]".into(),
        ];
        headers.extend(algs.iter().map(|a| a.name().to_string()));
        let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = TextTable::new(format!(
            "{} — full grid at k={k} (scale={scale}, seeds={seeds}): runtime relative to fastest",
            if k == ks[0] { "Table 9" } else { "Table 10" },
        ))
        .headers(&headers_ref);

        for (spec, ds) in grid_datasets(scale, None) {
            if k >= ds.n() {
                continue;
            }
            let stats: Vec<_> = algs
                .iter()
                .map(|&alg| measure_capped(&ds, alg, k, seeds, 1, cap))
                .collect();
            let fastest = stats
                .iter()
                .map(|s| s.mean_wall.as_secs_f64())
                .fold(f64::INFINITY, f64::min);
            let mut row = vec![
                spec.roman().to_string(),
                format!("{:.0}", stats[0].mean_iters),
                format!("{:.0}", stats[0].sd_iters),
                format!("{fastest:.3}"),
            ];
            for s in &stats {
                row.push(TextTable::fmt_ratio(s.mean_wall.as_secs_f64() / fastest));
            }
            t.row(row);
            eprint!(".");
        }
        eprintln!();
        common::emit(&format!("{tbl}_grid_k{k}.txt"), &t.render());
        bench_json = bench_json.field(*tbl, t.to_json());
    }
    common::emit_json("BENCH_table9.json", &bench_json);
}
