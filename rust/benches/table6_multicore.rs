//! Paper Table 6 — multicore scaling (SUBSTITUTED, see DESIGN.md §3).
//!
//! The paper measures wall-time on a real 4-core machine; this testbed
//! has a single core, so a true 4× speedup is unobservable. What this
//! harness verifies instead, for the paper's Table 6 algorithms:
//!
//! 1. thread-sharded runs produce *identical* results at any thread count
//!    (graceful parallelism: no synchronisation on the sample loop);
//! 2. the work partition is balanced (per-shard assignment distance
//!    counts within a few % of each other);
//! 3. coordination overhead is small (1-thread sharded wall ≈ unsharded
//!    wall), so an Amdahl projection of the 4-core speedup stays near
//!    the paper's ~0.27–0.33 ratios.

mod common;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{env_scale, measure::measure_capped, TextTable};
use eakm::config::RunConfig;
use eakm::coordinator::Runner;
use eakm::data::synth::{find, generate};

fn main() {
    let scale = env_scale();
    let cap = common::max_iters();
    let workloads = [("birch", "exp-ns"), ("europe", "syin-ns"), ("keggnet", "selk-ns"), ("mnist50", "elk-ns")];

    let mut t = TextTable::new(format!(
        "Table 6 (substituted) — parallel decomposition checks (scale={scale}; paper: 4-core median speedup 0.27–0.33)"
    ))
    .headers(&[
        "dataset",
        "algorithm",
        "identical@2T",
        "identical@4T",
        "overhead(4T/1T)",
        "par_fraction",
        "amdahl4",
    ]);

    for (ds_name, alg_name) in workloads {
        let spec = find(ds_name).unwrap();
        let ds = generate(&spec, scale, 0x7AB6);
        let alg = Algorithm::parse(alg_name).unwrap();
        let k = 50.min(ds.n() / 4);

        let run = |threads: usize| {
            Runner::new(
                &RunConfig::new(alg, k)
                    .seed(0)
                    .threads(threads)
                    .max_iters(cap),
            )
            .run(&ds)
            .unwrap()
        };
        let r1 = run(1);
        let r2 = run(2);
        let r4 = run(4);
        let same2 = r1.assignments == r2.assignments && r1.iterations == r2.iterations;
        let same4 = r1.assignments == r4.assignments && r1.iterations == r4.iterations;
        // overhead of sharding machinery on one core: 4 shards time-sliced
        // on 1 core ≈ serial work + coordination
        let overhead = r4.wall.as_secs_f64() / r1.wall.as_secs_f64().max(1e-12);
        // parallelisable fraction: assignment step dominates; estimate via
        // distance-counter split (assignment vs coordinator-side work)
        let par = r1.counters.assignment as f64 / r1.counters.total() as f64;
        // Amdahl projection for 4 cores (paper reports time ratios ≈ 1/speedup)
        let amdahl4 = 1.0 / ((1.0 - par) + par / 4.0) / 4.0; // ratio vs ideal... report projected time ratio
        let projected_ratio = (1.0 - par) + par / 4.0;
        let _ = amdahl4;
        t.row(vec![
            ds_name.to_string(),
            alg_name.to_string(),
            same2.to_string(),
            same4.to_string(),
            format!("{overhead:.2}"),
            format!("{par:.3}"),
            format!("{projected_ratio:.2}"),
        ]);
        eprint!(".");
    }
    eprintln!();
    let mut rendered = t.render();
    rendered.push_str(
        "\nSubstitution note: single-core testbed — `identical@NT` proves the sample loop\n\
         parallelises without synchronisation (the paper's §4.2 design); `amdahl4` is the\n\
         projected 4-core time ratio from the measured parallel fraction, to compare against\n\
         the paper's measured 0.27–0.33 medians.\n",
    );
    common::emit("table6_multicore.txt", &rendered);

    // also verify shard balance on one representative run
    let spec = find("birch").unwrap();
    let ds = generate(&spec, scale, 0x7AB6);
    let st = measure_capped(&ds, Algorithm::ExpNs, 50.min(ds.n() / 4), 1, 4, cap);
    eprintln!(
        "balance check: 4-thread run completed with q_a={:.2e} (deterministic merge)",
        st.mean_qa
    );
}
