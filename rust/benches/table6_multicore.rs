//! Paper Table 6 — multicore scaling on the persistent worker pool.
//!
//! The paper measures wall time on a real 4-core machine. This harness
//! sweeps `threads ∈ {1, 2, 4, 8}` per workload and reports, from the
//! engine's phase telemetry, where the time goes — scan (sample-sharded
//! assignment), update (delta centroid sums), build (centroid-side
//! per-round structures) — plus the speedup vs 1 thread and a
//! cross-thread determinism check (assignments, counters, and MSE must
//! be identical at every width).
//!
//! A second table isolates what the runtime refactor bought: per-round
//! dispatch cost of the persistent pool (one condvar broadcast) vs the
//! seed's per-round `thread::scope` spawning.

mod common;

use std::time::Instant;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{env_scale, TextTable};
use eakm::config::RunConfig;
use eakm::coordinator::{RunOutput, Runner};
use eakm::data::synth::{find, generate};
use eakm::json::Json;
use eakm::runtime::pool::WorkerPool;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let scale = env_scale();
    let cap = common::max_iters();
    let workloads = [
        ("birch", "exp-ns"),
        ("europe", "syin-ns"),
        ("keggnet", "selk-ns"),
        ("mnist50", "elk-ns"),
    ];

    let mut t = TextTable::new(format!(
        "Table 6 (substituted) — persistent-pool scaling, k≥100 where possible (scale={scale})"
    ))
    .headers(&[
        "dataset",
        "algorithm",
        "k",
        "T",
        "S/T",
        "wall[s]",
        "scan[s]",
        "update[s]",
        "build[s]",
        "speedup",
        "identical",
    ]);

    for (ds_name, alg_name) in workloads {
        let spec = find(ds_name).unwrap();
        let ds = generate(&spec, scale, 0x7AB6);
        let alg = Algorithm::parse(alg_name).unwrap();
        // the coordinator-side (build) cost the refactor targets scales
        // with k — prefer the paper's k ≥ 100 regime when n allows it
        let k = 100.min(ds.n() / 4).max(2);

        let mut base: Option<RunOutput> = None;
        for &threads in &THREADS {
            let out = Runner::new(
                &RunConfig::new(alg, k)
                    .seed(0)
                    .threads(threads)
                    .max_iters(cap),
            )
            .run(&ds)
            .unwrap();
            let (speedup, identical) = match &base {
                None => (1.0, true),
                Some(b) => (
                    b.wall.as_secs_f64() / out.wall.as_secs_f64().max(1e-12),
                    b.assignments == out.assignments
                        && b.counters == out.counters
                        && b.mse.to_bits() == out.mse.to_bits(),
                ),
            };
            t.row(vec![
                ds_name.to_string(),
                alg_name.to_string(),
                k.to_string(),
                threads.to_string(),
                // over-decomposition factor: auto shard count (a
                // function of n alone) per pool thread
                format!("{:.1}", out.report.sched.shards as f64 / threads as f64),
                format!("{:.4}", out.wall.as_secs_f64()),
                format!("{:.4}", out.report.phases.scan.as_secs_f64()),
                format!("{:.4}", out.report.phases.update.as_secs_f64()),
                format!("{:.4}", out.report.phases.build.as_secs_f64()),
                format!("{speedup:.2}"),
                identical.to_string(),
            ]);
            if base.is_none() {
                base = Some(out);
            }
            eprint!(".");
        }
    }
    eprintln!();
    let mut rendered = t.render();
    rendered.push_str(
        "\nSubstitution note: on a single-core testbed the speedup column reads ≤1 (shards\n\
         time-slice one core); `identical` proves the determinism guarantee regardless.\n\
         The per-phase columns attribute wall time to scan vs coordinator-side work.\n",
    );

    // What the persistent pool replaces: spawning + joining scoped
    // threads every round. Measure pure dispatch cost per round.
    let rounds: u32 = 500;
    let mut d = TextTable::new(format!(
        "Round-dispatch overhead — persistent pool vs per-round thread::scope ({rounds} rounds)"
    ))
    .headers(&["T", "pool[µs/round]", "spawn[µs/round]", "spawn/pool"]);
    for &threads in &[2usize, 4, 8] {
        let pool = WorkerPool::new(threads);
        let t0 = Instant::now();
        for _ in 0..rounds {
            pool.broadcast(|w| {
                std::hint::black_box(w);
            });
        }
        let pool_per = t0.elapsed() / rounds;
        let t1 = Instant::now();
        for _ in 0..rounds {
            std::thread::scope(|scope| {
                for w in 1..threads {
                    scope.spawn(move || {
                        std::hint::black_box(w);
                    });
                }
                std::hint::black_box(0usize);
            });
        }
        let spawn_per = t1.elapsed() / rounds;
        d.row(vec![
            threads.to_string(),
            format!("{:.1}", pool_per.as_secs_f64() * 1e6),
            format!("{:.1}", spawn_per.as_secs_f64() * 1e6),
            format!(
                "{:.1}x",
                spawn_per.as_secs_f64() / pool_per.as_secs_f64().max(1e-12)
            ),
        ]);
    }
    rendered.push('\n');
    rendered.push_str(&d.render());
    common::emit("table6_multicore.txt", &rendered);

    // machine-readable companion: same cells, structurally diffable
    let bench_json = Json::obj()
        .field("bench", "table6_multicore")
        .field("scale", scale)
        .field("max_iters", cap)
        .field(
            "threads",
            Json::Arr(THREADS.iter().map(|&t| Json::from(t)).collect()),
        )
        .field("scaling", t.to_json())
        .field("dispatch", d.to_json());
    common::emit_json("BENCH_table6.json", &bench_json);
}
