//! Paper Table 4 — which sn-algorithm is fastest on each {dataset, k}
//! experiment (ns-variants excluded), and the dimensional regime map.

mod common;

use std::collections::HashMap;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{
    env_scale, env_seeds, grid_datasets, grid_ks, measure::measure_capped, TextTable,
};
use eakm::json::Json;

fn main() {
    let scale = env_scale();
    let seeds = env_seeds();
    let ks = grid_ks(scale);
    let cap = common::max_iters();
    let algs = Algorithm::SN; // sta selk elk ham ann exp syin yin

    let mut counts: HashMap<&'static str, usize> = HashMap::new();
    let mut detail = TextTable::new(format!(
        "Table 4 detail — fastest sn-algorithm per experiment (scale={scale}, seeds={seeds})"
    ))
    .headers(&["ds", "d", &format!("k={}", ks[0]), &format!("k={}", ks[1])]);

    for (spec, ds) in grid_datasets(scale, None) {
        let mut row = vec![spec.roman().to_string(), spec.d.to_string()];
        for &k in &ks {
            if k >= ds.n() {
                row.push("-".into());
                continue;
            }
            let mut best = ("?", f64::INFINITY);
            for alg in algs {
                let st = measure_capped(&ds, alg, k, seeds, 1, cap);
                let w = st.mean_wall.as_secs_f64();
                if w < best.1 {
                    best = (alg.name(), w);
                }
            }
            *counts.entry(best.0).or_insert(0) += 1;
            row.push(best.0.to_string());
        }
        detail.row(row);
        eprint!(".");
    }
    eprintln!();

    let mut summary = TextTable::new("Table 4 — number of experiments each sn-algorithm is fastest")
        .headers(&["ham", "ann", "exp", "syin", "yin", "selk", "elk", "sta"]);
    summary.row(
        ["ham", "ann", "exp", "syin", "yin", "selk", "elk", "sta"]
            .iter()
            .map(|n| counts.get(*n).copied().unwrap_or(0).to_string())
            .collect(),
    );

    let mut rendered = summary.render();
    rendered.push('\n');
    rendered.push_str(&detail.render());
    rendered.push_str("\npaper: exp 13 (all d<5), syin 24 (8<d<69), selk 6 + elk 1 (d>73), ham/ann/yin 0\n");
    common::emit("table4_fastest.txt", &rendered);

    // machine-readable companion: same cells, structurally diffable
    let bench_json = Json::obj()
        .field("bench", "table4_fastest")
        .field("scale", scale)
        .field("seeds", seeds)
        .field("ks", Json::Arr(ks.iter().map(|&k| Json::from(k)).collect()))
        .field("summary", summary.to_json())
        .field("detail", detail.to_json());
    common::emit_json("BENCH_table4.json", &bench_json);
}
