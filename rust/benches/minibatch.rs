//! Mini-batch engine bench — full-batch vs nested (Newling & Fleuret
//! 2016b doubling) vs redraw (Sculley-style) on one workload, at
//! threads ∈ {1, 4}.
//!
//! Reports wall time, rounds, the realised batch schedule, and the
//! final full-data MSE, plus a cross-thread determinism check per mode
//! (MSE and centroid bits must be identical at every width — the same
//! guarantee the exact engine makes). Emits `BENCH_minibatch.json` next
//! to the text table for the CI `bench-smoke` schema gate.

mod common;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{env_scale, TextTable};
use eakm::config::RunConfig;
use eakm::coordinator::{RunOutput, Runner};
use eakm::data::synth::{find, generate};
use eakm::json::Json;

const THREADS: [usize; 2] = [1, 4];

fn main() {
    let scale = env_scale();
    let cap = common::max_iters();
    let spec = find("birch").unwrap();
    let ds = generate(&spec, scale, 0x7AB6);
    let k = 50.min(ds.n() / 4).max(2);
    let b0 = (ds.n() / 8).max(k);

    // (label, batch_size, growth): None = the exact full-batch engine
    let modes: [(&str, Option<usize>, f64); 3] = [
        ("full", None, 1.0),
        ("nested", Some(b0), 2.0),
        ("redraw", Some(b0), 1.0),
    ];

    let mut t = TextTable::new(format!(
        "Mini-batch engine — full vs nested vs redraw on birch (scale={scale}, k={k}, b0={b0})"
    ))
    .headers(&[
        "mode",
        "T",
        "rounds",
        "wall[s]",
        "final batch",
        "mse",
        "identical",
    ]);

    for (label, batch, growth) in modes {
        let mut base: Option<RunOutput> = None;
        for &threads in &THREADS {
            let mut cfg = RunConfig::new(Algorithm::ExpNs, k)
                .seed(0)
                .threads(threads)
                .max_iters(cap)
                .batch_growth(growth);
            if let Some(b) = batch {
                cfg = cfg.batch_size(b);
            }
            let out = Runner::new(&cfg).run(&ds).unwrap();
            let bits = |c: &[f64]| c.iter().map(|v| v.to_bits()).collect::<Vec<u64>>();
            let identical = match &base {
                None => true,
                Some(b) => {
                    b.mse.to_bits() == out.mse.to_bits()
                        && bits(&b.centroids) == bits(&out.centroids)
                        && b.assignments == out.assignments
                }
            };
            let final_batch = out
                .report
                .batch
                .as_ref()
                .and_then(|b| b.schedule.last().copied())
                .unwrap_or(ds.n());
            t.row(vec![
                label.to_string(),
                threads.to_string(),
                out.iterations.to_string(),
                format!("{:.4}", out.wall.as_secs_f64()),
                final_batch.to_string(),
                format!("{:.6}", out.mse),
                identical.to_string(),
            ]);
            if base.is_none() {
                base = Some(out);
            }
            eprint!(".");
        }
    }
    eprintln!();

    let mut rendered = t.render();
    rendered.push_str(
        "\n`identical` must read true in every row: a seeded mini-batch run is\n\
         bit-identical at any thread width, exactly like the full-batch engine.\n\
         nested grows the batch toward n (converges to Lloyd); redraw refines\n\
         under a fixed per-round budget and stops at the round cap.\n",
    );
    common::emit("minibatch.txt", &rendered);

    let bench_json = Json::obj()
        .field("bench", "minibatch")
        .field("scale", scale)
        .field("k", k)
        .field("b0", b0)
        .field("max_iters", cap)
        .field(
            "threads",
            Json::Arr(THREADS.iter().map(|&w| Json::from(w)).collect()),
        )
        .field("modes", t.to_json());
    common::emit_json("BENCH_minibatch.json", &bench_json);
}
