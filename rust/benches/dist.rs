//! Distributed-fit scaling bench: assignment-scan throughput (rows/s)
//! as a function of shard count, with a single-node run as the
//! reference row.
//!
//! The shard servers run in-process over loopback, so the numbers
//! measure protocol + merge overhead rather than real network latency:
//! at shard count 1 the gap to the local row is the round-trip cost of
//! the wire protocol, and growth from 1 → 2 shards shows the scan
//! parallelising across servers. Every distributed run is asserted
//! bit-identical to the local reference before its row is recorded.

mod common;

use std::io::Read;
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::sync::mpsc;
use std::thread;

use eakm::bench_support::{env_scale, TextTable};
use eakm::data::io;
use eakm::dist::wire::tag;
use eakm::dist::{run_dist, ShardConfig};
use eakm::json::Json;
use eakm::net::frame::send_frame;
use eakm::prelude::*;

const SHARD_THREADS: usize = 2;
const COORD_THREADS: usize = 2;
const SHARD_SWEEP: [usize; 2] = [1, 2];

struct Shard {
    addr: SocketAddr,
    handle: thread::JoinHandle<()>,
}

/// Start `parts` in-process shard servers over equal splits of `[0, n)`.
fn start_shards(path: &Path, n: usize, parts: usize) -> Vec<Shard> {
    (0..parts)
        .map(|i| {
            let (lo, hi) = (i * n / parts, (i + 1) * n / parts);
            let mut cfg = ShardConfig::new(path.to_path_buf(), lo, hi);
            cfg.threads = SHARD_THREADS;
            let (tx, rx) = mpsc::channel();
            let handle = thread::spawn(move || {
                eakm::dist::shardd(&cfg, |addr| tx.send(addr).unwrap()).unwrap();
            });
            Shard {
                addr: rx.recv().unwrap(),
                handle,
            }
        })
        .collect()
}

fn stop(shards: Vec<Shard>) {
    for s in &shards {
        if let Ok(mut stream) = TcpStream::connect(s.addr) {
            let _ = send_frame(&mut stream, tag::SHUTDOWN, &[]);
            // drain the ack until the shard closes the connection
            let mut ack = [0u8; 64];
            while matches!(stream.read(&mut ack), Ok(n) if n > 0) {}
        }
    }
    for s in shards {
        s.handle.join().unwrap();
    }
}

fn main() {
    let scale = env_scale();
    let n = ((2_000_000.0 * scale) as usize).max(10_000);
    let (d, k) = (8, 50);
    let ds = eakm::data::synth::blobs(n, d, k, 0.15, 0xD157);
    let dir = std::env::temp_dir().join(format!("eakm-dist-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("dist.ekb");
    io::save_bin(&ds, &path).unwrap();
    drop(ds);

    let mut cfg = RunConfig::new(Algorithm::ExpNs, k).seed(7).threads(COORD_THREADS);
    cfg.max_iters = common::max_iters().min(12);

    let mut t = TextTable::new(format!(
        "Distributed fit — rows/s over shard counts (n={n}, d={d}, k={k}, \
         {SHARD_THREADS} threads/shard, {COORD_THREADS} coordinator threads, scale={scale})"
    ))
    .headers(&["mode", "shards", "n", "k", "iters", "wall[s]", "rows/s"]);
    let rows_per_s = |iters: usize, wall_s: f64| n as f64 * iters as f64 / wall_s.max(1e-9);

    // single-node reference over the same file bytes
    let mem = io::load_bin(&path).unwrap();
    let local = Runner::new(&cfg).run(&mem).unwrap();
    drop(mem);
    let local_wall = local.wall.as_secs_f64();
    t.row(vec![
        "local".into(),
        "0".into(),
        n.to_string(),
        k.to_string(),
        local.iterations.to_string(),
        format!("{local_wall:.4}"),
        format!("{:.1}", rows_per_s(local.iterations, local_wall)),
    ]);

    for &parts in &SHARD_SWEEP {
        let shards = start_shards(&path, n, parts);
        let addrs: Vec<String> = shards.iter().map(|s| s.addr.to_string()).collect();
        let rt = Runtime::new(COORD_THREADS);
        let out = run_dist(&rt, &cfg, &addrs).unwrap();
        stop(shards);
        assert_eq!(
            out.assignments, local.assignments,
            "distributed fit must be bit-identical to single-node"
        );
        assert_eq!(out.mse.to_bits(), local.mse.to_bits());
        assert_eq!(out.counters, local.counters);
        let wall = out.wall.as_secs_f64();
        t.row(vec![
            "dist".into(),
            parts.to_string(),
            n.to_string(),
            k.to_string(),
            out.iterations.to_string(),
            format!("{wall:.4}"),
            format!("{:.1}", rows_per_s(out.iterations, wall)),
        ]);
        eprint!(".");
    }
    eprintln!();

    let mut rendered = t.render();
    rendered.push_str(
        "\nloopback shards: the local→dist(1) gap is pure protocol overhead, and\n\
         dist(1)→dist(2) shows the assignment scan parallelising across shard\n\
         servers. Every dist row was asserted bit-identical to the local row.\n",
    );
    common::emit("dist_scaling.txt", &rendered);

    let bench_json = Json::obj()
        .field("bench", "dist")
        .field("scale", scale)
        .field("shard_threads", SHARD_THREADS as u64)
        .field("coordinator_threads", COORD_THREADS as u64)
        .field("scaling", t.to_json());
    common::emit_json("BENCH_dist.json", &bench_json);
}
