//! Out-of-core bench: the same workload clustered from memory, from an
//! mmap'd `.ekb`, and from chunked file reads with a window far smaller
//! than the file — proving the exact and mini-batch engines stay
//! **bit-identical** to the in-memory run at every thread width, and
//! reporting what the I/O path costs (wall time, blocks leased, bytes
//! read, window refills).

mod common;

use std::path::PathBuf;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{env_scale, TextTable};
use eakm::config::RunConfig;
use eakm::coordinator::{RunOutput, Runner};
use eakm::data::ooc::{mmap_supported, open_ooc, OocMode};
use eakm::data::{io, DataSource};
use eakm::json::Json;

const THREADS: [usize; 3] = [1, 2, 8];

fn tmp_ekb(n: usize, d: usize) -> PathBuf {
    let ds = eakm::data::synth::blobs(n, d, 40, 0.2, 0xB10C);
    let dir = std::env::temp_dir().join(format!("eakm-ooc-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("workload.ekb");
    io::save_bin(&ds, &path).unwrap();
    path
}

fn run(cfg: &RunConfig, src: &dyn DataSource) -> RunOutput {
    Runner::new(cfg).run(src).unwrap()
}

fn main() {
    let scale = env_scale();
    let cap = common::max_iters();
    // paper-scale 200k rows at scale 1.0; floor keeps the windowed path
    // meaningfully larger than the 512-row bench window
    let n = ((200_000.0 * scale) as usize).max(4_000);
    let (d, k) = (8, 40);
    let window = 512;
    let path = tmp_ekb(n, d);
    let mem = io::load_bin(&path).unwrap();

    let engines: [(&str, RunConfig); 2] = [
        (
            "exact",
            RunConfig::new(Algorithm::ExpNs, k).seed(0).max_iters(cap),
        ),
        (
            "minibatch",
            RunConfig::new(Algorithm::ExpNs, k)
                .seed(0)
                .max_iters(cap)
                .batch_size(n / 8)
                .batch_growth(2.0),
        ),
    ];

    let mut t = TextTable::new(format!(
        "Out-of-core sources vs in-memory (n={n}, d={d}, k={k}, window={window} rows)"
    ))
    .headers(&[
        "engine",
        "source",
        "T",
        "wall[s]",
        "blocks",
        "bytes",
        "refills",
        "identical",
    ]);

    let mut all_identical = true;
    for (engine, base_cfg) in &engines {
        for &threads in &THREADS {
            let cfg = base_cfg.clone().threads(threads);
            let want = run(&cfg, &mem);
            t.row(vec![
                engine.to_string(),
                "memory".to_string(),
                threads.to_string(),
                format!("{:.4}", want.wall.as_secs_f64()),
                "-".into(),
                "-".into(),
                "-".into(),
                "true".into(),
            ]);
            let mut modes = vec![OocMode::Chunked];
            if mmap_supported() {
                modes.push(OocMode::Mmap);
            }
            for mode in modes {
                let src = open_ooc(&path, mode, window).unwrap();
                let got = run(&cfg, &*src);
                let identical = got.assignments == want.assignments
                    && got.mse.to_bits() == want.mse.to_bits()
                    && got.counters == want.counters;
                all_identical &= identical;
                let io = got.report.io.expect("ooc runs report I/O");
                t.row(vec![
                    engine.to_string(),
                    mode.to_string(),
                    threads.to_string(),
                    format!("{:.4}", got.wall.as_secs_f64()),
                    io.blocks_leased.to_string(),
                    io.bytes_read.to_string(),
                    io.window_refills.to_string(),
                    identical.to_string(),
                ]);
                eprint!(".");
            }
        }
    }
    eprintln!();
    assert!(
        all_identical,
        "out-of-core run diverged from the in-memory run — bit-identity broken"
    );

    let mut rendered = t.render();
    rendered.push_str(
        "\nEvery out-of-core row must read identical=true: same assignments, MSE bits,\n\
         and distance counters as the in-memory run at that thread count.\n",
    );
    common::emit("ooc_sources.txt", &rendered);

    let bench_json = Json::obj()
        .field("bench", "ooc")
        .field("scale", scale)
        .field("n", n)
        .field("window_rows", window)
        .field("mmap_supported", mmap_supported())
        .field("sources", t.to_json());
    common::emit_json("BENCH_ooc.json", &bench_json);
}
