//! Paper Table 7 — implementation comparison (SUBSTITUTED, DESIGN.md §3).
//!
//! The paper compares its implementations against bay/mlp/pow/vlf; those
//! codebases aren't available offline, so the comparator here is the
//! `naive-*` family: the *same algorithms* minus the paper's §4.1.1
//! engineering (blocked norm-decomposition scans, delta centroid update,
//! O(1) displacement maxima). Values are naive/own mean-runtime ratios —
//! >1 ⇒ our engineered implementation is faster, reproducing Table 7's
//! message that implementation quality is worth 1–4×.

mod common;

use eakm::algorithms::Algorithm;
use eakm::bench_support::{
    env_scale, env_seeds, grid_datasets, grid_ks, measure::measure_capped, TextTable,
};
use eakm::json::Json;

fn main() {
    let scale = env_scale();
    let seeds = env_seeds();
    let ks = grid_ks(scale);
    let cap = common::max_iters();
    let pairs = [
        (Algorithm::NaiveSta, Algorithm::Sta),
        (Algorithm::NaiveHam, Algorithm::Ham),
        (Algorithm::NaiveElk, Algorithm::Elk),
        (Algorithm::NaiveYin, Algorithm::Yin),
    ];

    // representative subset across regimes (full grid is table9's job)
    let subset = [1usize, 3, 6, 9, 12, 14, 20, 22];

    let mut headers = vec!["ds".to_string(), "k".to_string()];
    headers.extend(pairs.iter().map(|(n, o)| format!("{}/{}", n.name(), o.name())));
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = TextTable::new(format!(
        "Table 7 (substituted) — engineering worth: naive/own runtime ratios (scale={scale}, seeds={seeds}; >1 ⇒ own faster)"
    ))
    .headers(&headers_ref);

    let mut own_wins = 0;
    let mut total = 0;
    for (spec, ds) in grid_datasets(scale, Some(&subset)) {
        for &k in &ks {
            if k >= ds.n() {
                continue;
            }
            let mut row = vec![spec.roman().to_string(), k.to_string()];
            for (naive, own) in pairs {
                let n = measure_capped(&ds, naive, k, seeds, 1, cap);
                let o = measure_capped(&ds, own, k, seeds, 1, cap);
                let r = n.mean_wall.as_secs_f64() / o.mean_wall.as_secs_f64().max(1e-12);
                total += 1;
                if r > 1.0 {
                    own_wins += 1;
                }
                row.push(TextTable::fmt_ratio(r));
            }
            t.row(row);
            eprint!(".");
        }
    }
    eprintln!();
    let mut rendered = t.render();
    rendered.push_str(&format!(
        "\nengineered implementation faster in {own_wins}/{total} comparisons\n\
         (paper Table 7: own faster than bay/mlp/pow/vlf in all but 4 of ~170 comparisons, by 1–4x)\n"
    ));
    common::emit("table7_implementations.txt", &rendered);

    // machine-readable companion for the bench_check schema gate + diffs
    let bench_json = Json::obj()
        .field("bench", "table7_implementations")
        .field("scale", scale)
        .field("seeds", seeds)
        .field("max_iters", cap)
        .field("own_wins", own_wins)
        .field("total", total)
        .field("ratios", t.to_json());
    common::emit_json("BENCH_table7.json", &bench_json);
}
