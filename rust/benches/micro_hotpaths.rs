//! Micro-benchmarks of the hot paths (the §Perf profiling surface):
//! single distances, the blocked batch scan, cc-matrix build, annuli
//! build, and a full exp-ns round. Medians over repeated runs.

mod common;

use std::time::Instant;

use eakm::algorithms::Algorithm;
use eakm::bench_support::TextTable;
use eakm::config::RunConfig;
use eakm::coordinator::annuli::Annuli;
use eakm::coordinator::ccdist::CcData;
use eakm::coordinator::Engine;
use eakm::data::synth::blobs;
use eakm::linalg::{sqdist, sqdist_batch_block, sqnorms_rows};
use eakm::metrics::Counters;

fn time_median(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[reps / 2]
}

fn main() {
    let mut t = TextTable::new("micro hot paths (medians)").headers(&["bench", "median", "throughput"]);

    // 1) single sqdist at representative dims
    for d in [4usize, 32, 128, 784] {
        let a: Vec<f64> = (0..d).map(|i| (i as f64).sin()).collect();
        let b: Vec<f64> = (0..d).map(|i| (i as f64).cos()).collect();
        let reps = 2_000_000 / d.max(1);
        let med = time_median(9, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += sqdist(std::hint::black_box(&a), std::hint::black_box(&b));
            }
            std::hint::black_box(acc);
        });
        let flops = (reps * 3 * d) as f64 / med;
        t.row(vec![
            format!("sqdist d={d} x{reps}"),
            format!("{:.3} ms", med * 1e3),
            format!("{:.2} GFLOP/s", flops / 1e9),
        ]);
    }

    // 2) blocked batch scan (the sta/init hot path)
    for (m, d, k) in [(4096usize, 8usize, 100usize), (1024, 64, 200), (256, 784, 100)] {
        let ds = blobs(m, d, 8, 0.2, 1);
        let cs = blobs(k, d, 8, 0.2, 2);
        let xn = ds.sqnorms().to_vec();
        let cn = sqnorms_rows(cs.raw(), d);
        let mut out = vec![0.0; m * k];
        let med = time_median(7, || {
            sqdist_batch_block(ds.raw(), &xn, cs.raw(), &cn, d, &mut out);
            std::hint::black_box(&out);
        });
        let flops = (2.0 * m as f64 * k as f64 * d as f64) / med;
        t.row(vec![
            format!("batch {m}x{d}x{k}"),
            format!("{:.3} ms", med * 1e3),
            format!("{:.2} GFLOP/s", flops / 1e9),
        ]);
    }

    // 3) cc matrix + annuli build (exp's per-round overhead)
    for k in [100usize, 1000] {
        let cs = blobs(k, 8, 16, 0.3, 3);
        let med_cc = time_median(7, || {
            let mut ctr = Counters::default();
            std::hint::black_box(CcData::build(cs.raw(), k, 8, &mut ctr));
        });
        let mut ctr = Counters::default();
        let cc = CcData::build(cs.raw(), k, 8, &mut ctr);
        let mut reuse = Annuli::empty();
        let med_ann = time_median(7, || {
            reuse.build_into_fast(&cc);
            std::hint::black_box(&reuse);
        });
        t.row(vec![
            format!("cc build k={k}"),
            format!("{:.3} ms", med_cc * 1e3),
            String::new(),
        ]);
        t.row(vec![
            format!("annuli build k={k}"),
            format!("{:.3} ms", med_ann * 1e3),
            String::new(),
        ]);
    }

    // 4) one full exp-ns round on a mid-size workload
    let ds = blobs(50_000, 4, 64, 0.1, 4);
    let cfg = RunConfig::new(Algorithm::ExpNs, 64).seed(0);
    let mut engine = Engine::new(&ds, &cfg).unwrap();
    engine.step(); // warm
    let med = time_median(5, || {
        engine.step();
    });
    t.row(vec![
        "exp-ns round n=50k k=64 d=4".into(),
        format!("{:.3} ms", med * 1e3),
        format!("{:.1} Msamples/s", 50.0 / (med * 1e3)),
    ]);

    common::emit("micro_hotpaths.txt", &t.render());
}
