//! Micro-benchmarks of the hot paths (the §Perf profiling surface):
//! single distances, the register-blocked gemm, the blocked batch scan,
//! the fused distance+argmin scan, f64-vs-f32 label streaming,
//! cc-matrix/annuli builds, and a full exp-ns round. Medians over
//! repeated runs, reported with per-kernel GB/s and GFLOP/s so the CI
//! diff gate can hold a throughput *floor* per kernel (see
//! `.github/bench-baselines/`).
//!
//! The `median[ms]` header deliberately avoids the `[s]`/`secs`/`[µs`
//! timing markers: medians at smoke scale are noise, so only the
//! throughput columns are diffed. Row labels carry the workload shape
//! but never the rep count or scaled n, keeping row keys stable across
//! `EAKM_SCALE` values.

mod common;

use std::time::Instant;

use eakm::algorithms::common::blocked_argmin_scan;
use eakm::algorithms::Algorithm;
use eakm::bench_support::{env_scale, TextTable, DEFAULT_SCALE};
use eakm::config::RunConfig;
use eakm::coordinator::annuli::Annuli;
use eakm::coordinator::ccdist::CcData;
use eakm::coordinator::Engine;
use eakm::data::synth::blobs;
use eakm::data::{DataSource, DatasetF32};
use eakm::json::Json;
use eakm::linalg::{dot, gemm, sqdist, sqdist_argmin_block, sqdist_batch_block, sqnorms_rows};
use eakm::metrics::Counters;

fn time_median(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[reps / 2].max(1e-9)
}

/// Scale an iteration count with `EAKM_SCALE` (floor 1).
fn scaled(base: usize) -> usize {
    ((base as f64 * env_scale() / DEFAULT_SCALE) as usize).max(1)
}

/// One table row: label, median ms, and derived GB/s / GFLOP/s.
fn throughput_row(t: &mut TextTable, label: String, med: f64, bytes: f64, flops: f64) {
    t.row(vec![
        label,
        format!("{:.3}", med * 1e3),
        format!("{:.3}", bytes / med / 1e9),
        format!("{:.3}", flops / med / 1e9),
    ]);
}

/// A row whose throughput is not meaningful (composite builds/rounds).
fn timing_only_row(t: &mut TextTable, label: String, med: f64) {
    t.row(vec![label, format!("{:.3}", med * 1e3), "-".into(), "-".into()]);
}

fn main() {
    let mut t =
        TextTable::new("micro hot paths (medians)").headers(&["kernel", "median[ms]", "GB/s", "GFLOP/s"]);

    // 1) single sqdist at representative dims (lane loop + tail)
    for d in [4usize, 32, 128, 784] {
        let a: Vec<f64> = (0..d).map(|i| (i as f64).sin()).collect();
        let b: Vec<f64> = (0..d).map(|i| (i as f64).cos()).collect();
        let reps = (scaled(2_000_000) / d.max(1)).max(1);
        let med = time_median(9, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += sqdist(std::hint::black_box(&a), std::hint::black_box(&b));
            }
            std::hint::black_box(acc);
        });
        let bytes = (reps * 2 * d * 8) as f64;
        let flops = (reps * 3 * d) as f64;
        throughput_row(&mut t, format!("sqdist d={d}"), med, bytes, flops);
    }

    // 2) dot at the widest paper dim
    {
        let d = 784usize;
        let a: Vec<f64> = (0..d).map(|i| (i as f64).sin()).collect();
        let b: Vec<f64> = (0..d).map(|i| (i as f64).cos()).collect();
        let reps = (scaled(2_000_000) / d).max(1);
        let med = time_median(9, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += dot(std::hint::black_box(&a), std::hint::black_box(&b));
            }
            std::hint::black_box(acc);
        });
        throughput_row(
            &mut t,
            format!("dot d={d}"),
            med,
            (reps * 2 * d * 8) as f64,
            (reps * 2 * d) as f64,
        );
    }

    // 3) row norms (the sidecar / ingest kernel)
    {
        let (n, d) = (4096usize, 64usize);
        let ds = blobs(n, d, 8, 0.2, 9);
        let med = time_median(9, || {
            std::hint::black_box(sqnorms_rows(ds.raw(), d));
        });
        throughput_row(
            &mut t,
            format!("sqnorms-rows {n}x{d}"),
            med,
            ((n * d + n) * 8) as f64,
            (2 * n * d) as f64,
        );
    }

    // 4) register-blocked gemm, batch scan, and fused scan on the same
    //    shapes — the three layers of the assignment hot path
    for (m, d, k) in [(4096usize, 8usize, 100usize), (1024, 64, 200), (256, 784, 100)] {
        let ds = blobs(m, d, 8, 0.2, 1);
        let cs = blobs(k, d, 8, 0.2, 2);
        let xn = ds.sqnorms().to_vec();
        let cn = sqnorms_rows(cs.raw(), d);

        let mut out = vec![0.0; m * k];
        let med = time_median(7, || {
            gemm::matmul_nt(ds.raw(), cs.raw(), &mut out, m, d, k);
            std::hint::black_box(&out);
        });
        let gemm_bytes = ((m * d + k * d + m * k) * 8) as f64;
        throughput_row(
            &mut t,
            format!("matmul {m}x{d}x{k}"),
            med,
            gemm_bytes,
            (2 * m * d * k) as f64,
        );

        let med = time_median(7, || {
            sqdist_batch_block(ds.raw(), &xn, cs.raw(), &cn, d, &mut out);
            std::hint::black_box(&out);
        });
        throughput_row(
            &mut t,
            format!("batch {m}x{d}x{k}"),
            med,
            gemm_bytes,
            (2 * m * d * k + 3 * m * k) as f64,
        );

        let mut labels = vec![0u32; m];
        let mut dists = vec![0.0f64; m];
        let med = time_median(7, || {
            sqdist_argmin_block(ds.raw(), &xn, cs.raw(), &cn, d, &mut labels, &mut dists);
            std::hint::black_box(&labels);
        });
        // the fused scan never materialises the m×k matrix: traffic is
        // the operands plus one label + one distance per row
        let fused_bytes = ((m * d + k * d) * 8 + m * 12) as f64;
        throughput_row(
            &mut t,
            format!("fused-argmin {m}x{d}x{k}"),
            med,
            fused_bytes,
            (2 * m * d * k + 3 * m * k) as f64,
        );
    }

    // 5) label streaming through the block-lease seam at both storage
    //    widths — GB/s is computed from *stored* bytes, so the f32 row
    //    directly shows the bandwidth halving
    {
        let (d, k) = (32usize, 64usize);
        let n = scaled(200_000);
        let ds = blobs(n, d, k, 0.2, 11);
        let fs = DatasetF32::from_dataset(&ds).unwrap();
        let cs = blobs(k, d, k, 0.2, 12);
        let cn = sqnorms_rows(cs.raw(), d);
        let mut labels = vec![0u32; n];
        let mut dists = vec![0.0f64; n];
        let flops = (2 * n * d * k + 3 * n * k) as f64;

        let med = time_median(5, || {
            let mut cur = DataSource::open(&ds, 0, n);
            blocked_argmin_scan(cur.as_mut(), cs.raw(), &cn, 0, n, &mut labels, &mut dists);
            std::hint::black_box(&labels);
        });
        throughput_row(
            &mut t,
            format!("stream-labels f64 d={d} k={k}"),
            med,
            (n * (d * 8 + 8)) as f64,
            flops,
        );

        let med = time_median(5, || {
            let mut cur = DataSource::open(&fs, 0, n);
            blocked_argmin_scan(cur.as_mut(), cs.raw(), &cn, 0, n, &mut labels, &mut dists);
            std::hint::black_box(&labels);
        });
        throughput_row(
            &mut t,
            format!("stream-labels f32 d={d} k={k}"),
            med,
            (n * (d * 4 + 8)) as f64,
            flops,
        );
    }

    // 6) cc matrix + annuli build (exp's per-round overhead)
    for k in [100usize, 1000] {
        let cs = blobs(k, 8, 16, 0.3, 3);
        let med_cc = time_median(7, || {
            let mut ctr = Counters::default();
            std::hint::black_box(CcData::build(cs.raw(), k, 8, &mut ctr));
        });
        let mut ctr = Counters::default();
        let cc = CcData::build(cs.raw(), k, 8, &mut ctr);
        let mut reuse = Annuli::empty();
        let med_ann = time_median(7, || {
            reuse.build_into_fast(&cc);
            std::hint::black_box(&reuse);
        });
        timing_only_row(&mut t, format!("cc build k={k}"), med_cc);
        timing_only_row(&mut t, format!("annuli build k={k}"), med_ann);
    }

    // 7) one full exp-ns round on a mid-size workload
    {
        let n = scaled(50_000);
        let ds = blobs(n, 4, 64, 0.1, 4);
        let cfg = RunConfig::new(Algorithm::ExpNs, 64).seed(0);
        let mut engine = Engine::new(&ds, &cfg).unwrap();
        engine.step(); // warm
        let med = time_median(5, || {
            engine.step();
        });
        timing_only_row(&mut t, "exp-ns round k=64 d=4".into(), med);
    }

    common::emit("micro_hotpaths.txt", &t.render());
    common::emit_json(
        "BENCH_micro.json",
        &Json::obj()
            .field("bench", "micro_hotpaths")
            .field("kernels", t.to_json()),
    );
}
