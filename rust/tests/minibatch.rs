//! Integration tests for the mini-batch engine: seeded sampling is
//! reproducible, fits are bit-identical across runtime widths, a batch
//! covering the dataset leaves the exact path untouched, the mini-batch
//! config survives model persistence, and degenerate sources fail with
//! typed errors instead of panics.

use eakm::data::BatchView;
use eakm::error::EakmError;
use eakm::prelude::*;

fn blobs(n: usize, d: usize, k: usize, seed: u64) -> Dataset {
    eakm::data::synth::blobs(n, d, k, 0.12, seed)
}

#[test]
fn seeded_batch_sampling_is_reproducible() {
    let ds = blobs(2_000, 4, 6, 1);
    let a = BatchView::seeded(&ds, 300, 42);
    let b = BatchView::seeded(&ds, 300, 42);
    assert_eq!(a.indices(), b.indices());
    // gathered rows carry the exact base bits
    for (i, &idx) in a.indices().iter().enumerate() {
        assert_eq!(a.row(i), ds.row(idx));
        assert_eq!(a.sqnorm(i).to_bits(), ds.sqnorm(idx).to_bits());
    }
    assert_ne!(
        a.indices(),
        BatchView::seeded(&ds, 300, 43).indices(),
        "a different seed must draw a different batch"
    );
}

#[test]
fn minibatch_fit_is_bit_identical_across_widths() {
    let ds = blobs(4_000, 5, 8, 7);
    for (growth, label) in [(2.0, "nested"), (1.0, "redraw")] {
        let fit_at = |threads: usize| {
            let rt = Runtime::new(threads);
            Kmeans::new(8)
                .algorithm(Algorithm::ExpNs)
                .seed(3)
                .batch_size(333)
                .batch_growth(growth)
                .max_iters(25)
                .fit_predict(&rt, &ds)
                .unwrap()
        };
        let (m1, l1) = fit_at(1);
        let (m4, l4) = fit_at(4);
        let bits = |c: &[f64]| c.iter().map(|v| v.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(m1.centroids()), bits(m4.centroids()), "{label}");
        assert_eq!(
            m1.report().mse.to_bits(),
            m4.report().mse.to_bits(),
            "{label}: mse not bit-identical"
        );
        assert_eq!(l1, l4, "{label}: labels differ across widths");
        assert_eq!(
            m1.report().batch,
            m4.report().batch,
            "{label}: schedules differ across widths"
        );
    }
}

#[test]
fn batch_size_n_leaves_the_full_batch_path_unchanged() {
    let ds = blobs(1_200, 4, 6, 9);
    let rt = Runtime::new(2);
    let base = Kmeans::new(6).algorithm(Algorithm::ExpNs).seed(5);
    let (plain, plain_labels) = base.fit_predict(&rt, &ds).unwrap();
    let (batched, batched_labels) = base
        .clone()
        .batch_size(ds.n()) // covers the dataset → exact engine
        .fit_predict(&rt, &ds)
        .unwrap();
    let bits = |c: &[f64]| c.iter().map(|v| v.to_bits()).collect::<Vec<u64>>();
    assert_eq!(bits(plain.centroids()), bits(batched.centroids()));
    assert_eq!(plain_labels, batched_labels);
    assert_eq!(plain.report().mse.to_bits(), batched.report().mse.to_bits());
    assert_eq!(plain.report().iterations, batched.report().iterations);
    assert!(batched.report().batch.is_none(), "exact path records no schedule");
}

#[test]
fn model_roundtrips_the_minibatch_config() {
    let dir = std::env::temp_dir().join(format!("eakm-minibatch-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.json");

    let ds = blobs(2_500, 4, 5, 11);
    let rt = Runtime::new(2);
    let model = Kmeans::new(5)
        .algorithm(Algorithm::ExpNs)
        .seed(13)
        .batch_size(250)
        .batch_growth(2.0)
        .max_iters(30)
        .fit(&rt, &ds)
        .unwrap();
    let batch = model.report().batch.clone().expect("mini-batch fit records telemetry");
    assert_eq!(batch.batch_size, 250);
    assert_eq!(batch.growth, 2.0);
    assert!(!batch.schedule.is_empty());

    model.save(&path).unwrap();
    let loaded = FittedModel::load(&path).unwrap();
    assert_eq!(loaded.report().batch.as_ref(), Some(&batch));
    // and the centroids still round-trip to the exact bits
    let bits = |c: &[f64]| c.iter().map(|v| v.to_bits()).collect::<Vec<u64>>();
    assert_eq!(bits(loaded.centroids()), bits(model.centroids()));
}

/// A deliberately degenerate source: shape says `n` rows, holds none.
struct Hollow {
    n: usize,
    d: usize,
}

impl DataSource for Hollow {
    fn n(&self) -> usize {
        self.n
    }
    fn d(&self) -> usize {
        self.d
    }
    fn open(&self, lo: usize, len: usize) -> Box<dyn eakm::data::BlockCursor + '_> {
        // shape lies are caught before any lease; an empty cursor is
        // enough for the degenerate-source guards under test
        Box::new(eakm::data::SliceCursor::new(&[], &[], self.d, lo, len))
    }
}

#[test]
fn degenerate_sources_error_instead_of_panicking() {
    let rt = Runtime::serial();
    // empty source (n = 0): typed Data error, not a panic inside init
    let empty = Hollow { n: 0, d: 3 };
    assert!(matches!(
        Kmeans::new(2).fit(&rt, &empty),
        Err(EakmError::Data(_))
    ));
    // zero-dimensional source
    let flat = Hollow { n: 10, d: 0 };
    assert!(matches!(
        Kmeans::new(2).fit(&rt, &flat),
        Err(EakmError::Data(_))
    ));
    // ...including through the mini-batch dispatch, which must apply
    // the same guard before any batch is gathered
    assert!(matches!(
        Kmeans::new(2).batch_size(4).fit(&rt, &flat),
        Err(EakmError::Data(_))
    ));
    // k > n: typed Config error, on both the exact and mini-batch paths
    let ds = blobs(20, 3, 2, 1);
    assert!(matches!(
        Kmeans::new(21).fit(&rt, &ds),
        Err(EakmError::Config(_))
    ));
    assert!(matches!(
        Kmeans::new(21).batch_size(8).fit(&rt, &ds),
        Err(EakmError::Config(_))
    ));
}
