//! Observability acceptance over real loopback sockets: the serve
//! tier's `GET /metrics` exposition and `GET /v1/events` drain, trace
//! propagation from the front door through the dist wire to shard-side
//! events, the shard `STATS` frame, and bit-identity of observed runs
//! (instrumentation must never change a result).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use eakm::data::io;
use eakm::data::synth::blobs;
use eakm::dist::{run_dist, run_dist_observed, shard_stats, ShardConfig};
use eakm::json::Json;
use eakm::net::frame::send_frame;
use eakm::obs::{FitObserver, TraceId, Value};
use eakm::prelude::*;
use eakm::serve::client::{self, Client};

fn tmpdir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("eakm-obs-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn fit_model(n: usize, d: usize, k: usize, seed: u64) -> FittedModel {
    let rt = Runtime::serial();
    let ds = blobs(n, d, k, 0.1, seed);
    Kmeans::new(k).seed(seed).max_iters(20).fit(&rt, &ds).unwrap()
}

/// Run a server on its own thread + runtime; returns the bound address
/// and the handle that yields the final `ServeStats` after shutdown.
fn start_serve(
    model: FittedModel,
    threads: usize,
    cfg: ServeConfig,
) -> (SocketAddr, thread::JoinHandle<ServeStats>) {
    let (tx, rx) = mpsc::channel();
    let handle = thread::spawn(move || {
        let rt = Runtime::new(threads);
        eakm::serve::serve(&rt, model, &cfg, |addr| tx.send(addr).unwrap()).unwrap()
    });
    (rx.recv().unwrap(), handle)
}

fn shutdown_serve(addr: SocketAddr) {
    let reply = Client::connect(addr).unwrap().call(&client::shutdown_request()).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));
}

/// One-shot `GET` over a fresh connection (`Connection: close`);
/// returns (status, body).
fn http_get(addr: SocketAddr, target: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let req = format!("GET {target} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes()).unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8(raw).unwrap();
    let code = text.split_whitespace().nth(1).expect("status code");
    let body = text.split_once("\r\n\r\n").expect("header/body split").1;
    (code.parse().unwrap(), body.to_string())
}

#[test]
fn serve_metrics_exposition_covers_every_telemetry_family() {
    let model = fit_model(300, 4, 5, 21);
    let queries = blobs(8, 4, 5, 0.2, 22);
    let (addr, handle) = start_serve(model, 2, ServeConfig::default());
    // one predict so the op counters, histograms, and batch events are
    // non-trivially populated
    let mut c = Client::connect(addr).unwrap();
    let req = client::predict_request(queries.raw(), queries.d());
    let reply = c.call(&req).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
    drop(c);

    let (status, body) = http_get(addr, "/metrics");
    assert_eq!(status, 200, "{body}");
    // serve counters, one per ServeStats field (spot-check the set)
    assert!(body.contains("# TYPE eakm_serve_requests_total counter"), "{body}");
    assert!(body.contains("eakm_serve_ops_total{op=\"predict\"} 1\n"), "{body}");
    assert!(body.contains("eakm_serve_rejects_total{reason=\"overloaded\"} 0\n"), "{body}");
    assert!(body.contains("eakm_serve_rejects_total{reason=\"rate_limited\"} 0\n"), "{body}");
    assert!(body.contains("eakm_serve_rejects_total{reason=\"breaker_open\"} 0\n"), "{body}");
    assert!(body.contains("eakm_serve_batched_rows_total 8\n"), "{body}");
    assert!(body.contains("eakm_serve_bulk_rows_total"), "{body}");
    assert!(body.contains("eakm_serve_http_requests_total"), "{body}");
    // per-op latency: histogram buckets plus derived mean/p50/p99
    assert!(body.contains("# TYPE eakm_serve_op_latency_micros histogram"), "{body}");
    assert!(
        body.contains("eakm_serve_op_latency_micros_bucket{op=\"predict\",le=\"+Inf\"} 1"),
        "{body}"
    );
    assert!(body.contains("eakm_serve_op_latency_micros_count{op=\"predict\"} 1\n"), "{body}");
    assert!(body.contains("eakm_serve_op_latency_p99_micros{op=\"predict\"}"), "{body}");
    assert!(body.contains("eakm_serve_op_seconds_total{op=\"reload\"}"), "{body}");
    // server shape
    assert!(body.contains("eakm_serve_uptime_seconds"), "{body}");
    assert!(body.contains("eakm_serve_model_generation 1\n"), "{body}");
    assert!(body.contains("eakm_serve_queue_depth"), "{body}");
    assert!(body.contains("eakm_serve_events_seq"), "{body}");
    // the served model's fit report: k/d, rounds/mse, every Counters
    // site as a total and as the paper's per-point-per-round rate,
    // SchedTelemetry, and IoTelemetry
    assert!(body.contains("eakm_model_k 5\n"), "{body}");
    assert!(body.contains("eakm_model_d 4\n"), "{body}");
    assert!(body.contains("eakm_fit_rounds{algorithm="), "{body}");
    assert!(body.contains("eakm_fit_mse{algorithm="), "{body}");
    assert!(
        body.contains("eakm_fit_distance_calcs_total{site=\"assignment\",algorithm="),
        "{body}"
    );
    assert!(body.contains("eakm_fit_distance_calcs_total{site=\"total\",algorithm="), "{body}");
    assert!(body.contains("eakm_fit_distance_calcs_per_point_round{site=\"assignment\""), "{body}");
    assert!(body.contains("eakm_fit_sched_dispatches_total"), "{body}");
    assert!(body.contains("eakm_fit_sched_max_seconds{phase=\"scan\"}"), "{body}");
    assert!(body.contains("eakm_fit_sched_imbalance"), "{body}");
    assert!(body.contains("eakm_fit_io_blocks_leased_total"), "{body}");
    assert!(body.contains("eakm_fit_io_bytes_read_total"), "{body}");
    assert!(body.contains("eakm_fit_io_window_refills_total"), "{body}");

    // the event drain: the predict's batch execution is there, tagged
    // with the trace minted when the request entered the server
    let (status, body) = http_get(addr, "/v1/events");
    assert_eq!(status, 200, "{body}");
    let payload = Json::parse(body.trim_end()).unwrap();
    assert_eq!(payload.get("ok").and_then(Json::as_bool), Some(true), "{payload}");
    let last = payload.get("last").and_then(Json::as_usize).unwrap();
    assert!(last >= 1, "{payload}");
    let events = payload.get("events").and_then(Json::as_arr).unwrap();
    let batch = events
        .iter()
        .find(|e| e.get("kind").and_then(Json::as_str) == Some("batch"))
        .expect("batch event");
    assert_eq!(batch.get("rows").and_then(Json::as_usize), Some(8), "{batch}");
    let trace = batch.get("trace").and_then(Json::as_str).expect("trace");
    assert_eq!(trace.len(), 16, "{trace}");
    assert_ne!(trace, "0000000000000000", "trace must be minted, not unset");
    // incremental drain: nothing new after the cursor
    let (_, body) = http_get(addr, &format!("/v1/events?since={last}"));
    let payload = Json::parse(body.trim_end()).unwrap();
    assert_eq!(payload.get("events").and_then(Json::as_arr).map(Vec::len), Some(0), "{payload}");

    shutdown_serve(addr);
    let stats = handle.join().unwrap();
    assert_eq!(stats.predicts, 1);
    // the stats snapshot carries the histogram-derived latencies the
    // wire protocol reports (mean/p50/p99 are computed server-side)
    assert!(stats.predict_latency.p99_micros >= 1);
    assert!(stats.predict_latency.p99_micros >= stats.predict_latency.p50_micros);
}

/// One in-process shard server and the thread running it.
struct Shard {
    addr: SocketAddr,
    handle: thread::JoinHandle<()>,
}

fn start_shards(path: &Path, bounds: &[usize], threads: usize) -> Vec<Shard> {
    bounds
        .windows(2)
        .map(|w| {
            let mut cfg = ShardConfig::new(path.to_path_buf(), w[0], w[1]);
            cfg.threads = threads;
            let (tx, rx) = mpsc::channel();
            let handle = thread::spawn(move || {
                eakm::dist::shardd(&cfg, |addr| tx.send(addr).unwrap()).unwrap();
            });
            Shard {
                addr: rx.recv().unwrap(),
                handle,
            }
        })
        .collect()
}

fn stop_shards(shards: Vec<Shard>) {
    for s in &shards {
        if let Ok(mut stream) = TcpStream::connect(s.addr) {
            let _ = send_frame(&mut stream, eakm::dist::wire::tag::SHUTDOWN, &[]);
            let mut ack = [0u8; 64];
            while matches!(stream.read(&mut ack), Ok(n) if n > 0) {}
        }
    }
    for s in shards {
        s.handle.join().unwrap();
    }
}

#[test]
fn trace_minted_at_the_front_door_reaches_shard_side_events() {
    let ds = blobs(400, 4, 5, 0.25, 3);
    let path = tmpdir().join("obs-dist.ekb");
    io::save_bin(&ds, &path).unwrap();
    let shards = start_shards(&path, &[0, 200, 400], 2);
    let addrs: Vec<String> = shards.iter().map(|s| s.addr.to_string()).collect();
    let rt = Runtime::new(2);
    let cfg = RunConfig::new(Algorithm::ExpNs, 5).seed(7).threads(2);

    let trace = TraceId::from_u64(0xC0FFEE);
    let obs = FitObserver::new(trace, false);
    let observed = run_dist_observed(&rt, &cfg, &addrs, Some(&obs)).unwrap();

    // coordinator-side: per-round events carry the front-door trace and
    // a real objective (the observer pays for the read; results don't)
    let all = obs.events().since(0);
    let rounds: Vec<_> = all.iter().filter(|e| e.kind == "round").collect();
    assert!(!rounds.is_empty());
    for e in &rounds {
        assert_eq!(e.trace, trace);
        assert_eq!(e.field("site"), Some(&Value::Str("dist".to_string())));
    }

    // shard-side: the STATS frame answers mid-lifetime with Prometheus
    // metrics and events tagged with the same trace — the round is
    // attributable to a specific shard from either end
    for s in &shards {
        let reply = shard_stats(&s.addr.to_string(), 0, Duration::from_secs(10)).unwrap();
        assert!(
            reply.metrics.contains("# TYPE eakm_shard_rounds_total counter"),
            "{}",
            reply.metrics
        );
        assert!(
            reply.metrics.contains("eakm_shard_distance_calcs_total{site=\"assignment\"}"),
            "{}",
            reply.metrics
        );
        assert!(reply.metrics.contains("eakm_shard_round_micros_bucket"), "{}", reply.metrics);
        assert!(reply.events.contains("\"kind\":\"shard_round\""), "{}", reply.events);
        assert!(reply.events.contains("\"trace\":\"0000000000c0ffee\""), "{}", reply.events);
        // incremental drain: replaying the cursor returns nothing new
        let doc = Json::parse(&reply.events).unwrap();
        let last = doc.get("last").and_then(Json::as_usize).unwrap() as u64;
        let newer = shard_stats(&s.addr.to_string(), last, Duration::from_secs(10)).unwrap();
        let doc = Json::parse(&newer.events).unwrap();
        assert_eq!(doc.get("events").and_then(Json::as_arr).map(Vec::len), Some(0));
    }

    // instrumentation must not change a single bit: the same fit
    // without an observer agrees exactly
    let plain = run_dist(&rt, &cfg, &addrs).unwrap();
    assert_eq!(observed.assignments, plain.assignments);
    assert_eq!(observed.mse.to_bits(), plain.mse.to_bits());
    assert_eq!(observed.iterations, plain.iterations);
    assert_eq!(observed.counters, plain.counters);
    stop_shards(shards);
}

#[test]
fn observed_single_node_fit_is_bit_identical() {
    let rt = Runtime::new(2);
    let ds = blobs(500, 6, 8, 0.2, 11);
    let km = Kmeans::new(8).seed(5).max_iters(30);
    let plain = km.fit(&rt, &ds).unwrap();
    let obs = FitObserver::new(TraceId::mint(), false);
    let events = obs.events().clone();
    let observer = Some(std::sync::Arc::new(obs));
    let observed = km.fit_observed(&rt, &ds, observer).unwrap();
    let bits = |m: &FittedModel| m.centroids().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&plain), bits(&observed));
    assert_eq!(plain.report().mse.to_bits(), observed.report().mse.to_bits());
    assert_eq!(plain.report().iterations, observed.report().iterations);
    assert_eq!(plain.report().counters, observed.report().counters);
    // one "round" event per iteration, with the paper's by-site
    // distance-calc deltas attached
    let all = events.since(0);
    let rounds: Vec<_> = all.iter().filter(|e| e.kind == "round").collect();
    assert_eq!(rounds.len(), observed.report().iterations);
    let total: u64 = rounds
        .iter()
        .map(|e| match e.field("dist_total") {
            Some(Value::U64(v)) => *v,
            other => panic!("dist_total missing: {other:?}"),
        })
        .sum();
    assert!(total > 0);
}
