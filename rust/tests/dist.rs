//! Distributed-fit acceptance over real loopback sockets: in-process
//! shard servers + the coordinator must produce **bit-identical**
//! assignments, MSE, counters, and iteration counts to the single-node
//! run — for the exact and mini-batch engines, at several thread widths
//! and shard counts, through both the chunk-partials fast path and the
//! rebuild-through-the-source fallback — and a shard that dies mid-fit
//! must surface as a typed error naming it, never a hang. This is the
//! acceptance gate for the dist layer; CI runs it on every commit.

use std::io::Read;
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use eakm::data::{io, Dataset, DatasetF32};
use eakm::dist::wire::tag;
use eakm::dist::{run_dist, DistEngine, NetSource, ShardConfig};
use eakm::net::frame::send_frame;
use eakm::prelude::*;

fn tmpdir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("eakm-dist-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A blobs dataset written to disk plus the same data resident in
/// memory (reloaded, so the reference went through the same file).
fn fixture(name: &str, n: usize, d: usize, clusters: usize, seed: u64) -> (PathBuf, Dataset) {
    let ds = eakm::data::synth::blobs(n, d, clusters, 0.25, seed);
    let path = tmpdir().join(name);
    io::save_bin(&ds, &path).unwrap();
    let mem = io::load_bin(&path).unwrap();
    (path, mem)
}

/// One in-process shard server and the thread running it.
struct Shard {
    addr: SocketAddr,
    handle: thread::JoinHandle<()>,
}

/// Start one shard per consecutive `[lo, hi)` window of `bounds`.
fn start_shards(path: &Path, bounds: &[usize], threads: usize) -> Vec<Shard> {
    bounds
        .windows(2)
        .map(|w| {
            let mut cfg = ShardConfig::new(path.to_path_buf(), w[0], w[1]);
            cfg.threads = threads;
            let (tx, rx) = mpsc::channel();
            let handle = thread::spawn(move || {
                eakm::dist::shardd(&cfg, |addr| tx.send(addr).unwrap()).unwrap();
            });
            Shard {
                addr: rx.recv().unwrap(),
                handle,
            }
        })
        .collect()
}

fn addr_list(shards: &[Shard]) -> Vec<String> {
    shards.iter().map(|s| s.addr.to_string()).collect()
}

/// Ask a shard to shut down (best-effort: it may already be gone).
fn kill(addr: SocketAddr) {
    if let Ok(mut s) = TcpStream::connect(addr) {
        let _ = send_frame(&mut s, tag::SHUTDOWN, &[]);
        // drain the ack until the shard closes the connection
        let mut ack = [0u8; 64];
        while matches!(s.read(&mut ack), Ok(n) if n > 0) {}
    }
}

fn stop(shards: Vec<Shard>) {
    for s in &shards {
        kill(s.addr);
    }
    for s in shards {
        s.handle.join().unwrap();
    }
}

/// Equal `n / parts` splits. For small `n` the boundaries land inside
/// the global update chunks, so the coordinator takes the
/// rebuild-through-the-source fallback path.
fn even_bounds(n: usize, parts: usize) -> Vec<usize> {
    (0..=parts).map(|i| i * n / parts).collect()
}

fn bits(c: &[f64]) -> Vec<u64> {
    c.iter().map(|v| v.to_bits()).collect()
}

fn assert_same(got: &RunOutput, want: &RunOutput, ctx: &str) {
    assert_eq!(got.assignments, want.assignments, "{ctx}");
    assert_eq!(got.mse.to_bits(), want.mse.to_bits(), "{ctx}");
    assert_eq!(got.counters, want.counters, "{ctx}");
    assert_eq!(got.iterations, want.iterations, "{ctx}");
    assert_eq!(got.converged, want.converged, "{ctx}");
    assert_eq!(bits(&got.centroids), bits(&want.centroids), "{ctx}");
}

#[test]
fn exact_fit_is_bit_identical_across_shard_counts() {
    let (path, mem) = fixture("exact.ekb", 600, 5, 6, 3);
    for threads in [1usize, 4] {
        for parts in [1usize, 2, 3] {
            let shards = start_shards(&path, &even_bounds(600, parts), threads);
            let rt = Runtime::new(threads);
            for alg in [Algorithm::Sta, Algorithm::ExpNs] {
                let cfg = RunConfig::new(alg, 6).seed(7).threads(threads);
                let want = Runner::new(&cfg).run(&mem).unwrap();
                let got = run_dist(&rt, &cfg, &addr_list(&shards)).unwrap();
                assert_same(&got, &want, &format!("{alg} t={threads} shards={parts}"));
                // the distributed run reports network I/O and names the
                // dataset by its file stem, like a local file run
                let io = got.report.io.expect("net run reports I/O telemetry");
                assert!(io.blocks_leased > 0, "{alg} t={threads} shards={parts}");
                assert_eq!(got.report.dataset, "exact");
                assert!(want.report.io.is_none());
            }
            stop(shards);
        }
    }
}

#[test]
fn aligned_shard_boundaries_take_the_partials_path_bit_identically() {
    // chunk_len(12288) = 4096: boundaries at multiples of 4096 mean
    // chunks never straddle shards, so full-update algorithms rebuild
    // centroid sums from shard-computed per-chunk partials instead of
    // re-reading rows through the network source
    let (path, mem) = fixture("aligned.ekb", 12_288, 3, 5, 17);
    let splits = [
        vec![0, 12_288],
        vec![0, 4096, 12_288],
        vec![0, 4096, 8192, 12_288],
    ];
    for alg in [Algorithm::Sta, Algorithm::ExpNs] {
        let mut cfg = RunConfig::new(alg, 5).seed(9).threads(4);
        cfg.max_iters = 25;
        let want = Runner::new(&cfg).run(&mem).unwrap();
        for bounds in &splits {
            let shards = start_shards(&path, bounds, 4);
            let got = run_dist(&Runtime::new(4), &cfg, &addr_list(&shards)).unwrap();
            assert_same(&got, &want, &format!("{alg} bounds={bounds:?}"));
            stop(shards);
        }
    }
}

#[test]
fn minibatch_fit_over_the_network_is_bit_identical() {
    // with a batch size below n, `run --shards` dispatches to the
    // mini-batch engine over the NetSource: a pure data-plane fit
    let (path, mem) = fixture("minibatch.ekb", 2_000, 4, 6, 5);
    for growth in [2.0, 1.0] {
        let mut cfg = RunConfig::new(Algorithm::ExpNs, 6)
            .seed(11)
            .batch_size(150)
            .batch_growth(growth);
        cfg.max_iters = if growth > 1.0 { 200 } else { 12 };
        for threads in [1usize, 4] {
            cfg.threads = threads;
            let want = Runner::new(&cfg).run(&mem).unwrap();
            let shards = start_shards(&path, &even_bounds(2_000, 3), threads);
            let got = run_dist(&Runtime::new(threads), &cfg, &addr_list(&shards)).unwrap();
            let ctx = format!("growth={growth} t={threads}");
            assert_eq!(got.assignments, want.assignments, "{ctx}");
            assert_eq!(got.mse.to_bits(), want.mse.to_bits(), "{ctx}");
            assert_eq!(got.counters, want.counters, "{ctx}");
            assert_eq!(got.report.batch, want.report.batch, "same batch schedule");
            assert!(got.report.io.unwrap().blocks_leased > 0, "{ctx}");
            stop(shards);
        }
    }
}

#[test]
fn f32_files_stream_at_storage_width_bit_identically() {
    // every value exactly f32-representable, so the resident DatasetF32
    // reference and the narrow→widen wire round trip are both lossless
    let ds = eakm::data::synth::blobs(900, 4, 5, 0.25, 41);
    let rounded: Vec<f64> = ds.raw().iter().map(|&v| v as f32 as f64).collect();
    let mem = Dataset::new(ds.name.clone(), rounded, 900, 4).unwrap();
    let f32set = DatasetF32::from_dataset(&mem).unwrap();
    let path = tmpdir().join("f32.ekb");
    io::save_bin_f32(&mem, &path).unwrap();
    let cfg = RunConfig::new(Algorithm::ExpNs, 5).seed(5).threads(2);
    let want = Runner::new(&cfg).run(&f32set).unwrap();
    let shards = start_shards(&path, &even_bounds(900, 2), 2);
    let got = run_dist(&Runtime::new(2), &cfg, &addr_list(&shards)).unwrap();
    assert_eq!(got.assignments, want.assignments);
    assert_eq!(got.mse.to_bits(), want.mse.to_bits());
    assert_eq!(got.counters, want.counters);
    assert_eq!(bits(&got.centroids), bits(&want.centroids));
    stop(shards);
}

#[test]
fn dead_shard_is_a_typed_error_not_a_hang() {
    let (path, _mem) = fixture("failure.ekb", 600, 4, 8, 23);
    let shards = start_shards(&path, &even_bounds(600, 2), 1);
    let addrs = addr_list(&shards);
    let cfg = RunConfig::new(Algorithm::Sta, 8).seed(3).threads(2);
    let rt = Runtime::new(2);
    let net = NetSource::connect(&addrs, 0, Duration::from_secs(30)).unwrap();
    let mut engine = DistEngine::connect(&rt, &cfg, &net).unwrap();
    assert!(engine.step().is_ok(), "healthy round must succeed");
    kill(shards[1].addr);
    thread::sleep(Duration::from_millis(300));
    let err = loop {
        match engine.step() {
            Err(e) => break e,
            Ok(_) => assert!(
                !engine.converged(),
                "fit converged before the dead shard was noticed"
            ),
        }
    };
    let msg = err.to_string();
    assert!(msg.contains("shard"), "{msg}");
    assert!(msg.contains(&addrs[1]), "must name the dead shard: {msg}");
    stop(shards);
}

#[test]
fn connect_validates_coverage_and_unreachable_shards() {
    let (path, _mem) = fixture("cover.ekb", 300, 3, 4, 29);
    let shards = start_shards(&path, &[0, 300], 1);
    let addr = shards[0].addr.to_string();
    // the same shard twice: its ranges overlap instead of tiling [0, n)
    let err = NetSource::connect(&[addr.clone(), addr], 0, Duration::from_secs(5)).unwrap_err();
    assert!(err.to_string().contains("tile"), "{err}");
    // a shard that is not listening is a typed connect error naming it
    let err = NetSource::connect(&["127.0.0.1:1".into()], 0, Duration::from_secs(5)).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("127.0.0.1:1"), "{msg}");
    stop(shards);
}
