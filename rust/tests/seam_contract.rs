//! The block-lease seam contract, checked across every `DataSource`
//! implementation through the one shared property harness
//! (`eakm::algorithms::testutil::assert_block_lease_contract`):
//! coverage of `[0, n)` in shard order, bit-stability of re-reads, and
//! norms matching rows — for `Dataset`, `BatchView`, `MmapSource`, and
//! `ChunkedFileSource`.

use std::path::PathBuf;

use eakm::algorithms::testutil::assert_block_lease_contract;
use eakm::data::ooc::ChunkedFileSource;
use eakm::data::{io, BatchView, Dataset};

fn blobs(n: usize, d: usize, seed: u64) -> Dataset {
    eakm::data::synth::blobs(n, d, 5, 0.2, seed)
}

fn tmp_ekb(name: &str, ds: &Dataset) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("eakm-seam-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    io::save_bin(ds, &path).unwrap();
    path
}

#[test]
fn dataset_upholds_the_block_lease_contract() {
    assert_block_lease_contract(&blobs(937, 6, 1), 101);
    // degenerate-ish shapes: single row, single column
    assert_block_lease_contract(&blobs(7, 1, 2), 102);
}

#[test]
fn batch_view_upholds_the_block_lease_contract() {
    let base = blobs(1_200, 5, 3);
    let view = BatchView::seeded(&base, 311, 9);
    assert_block_lease_contract(&view, 103);
    let full = BatchView::seeded(&base, 1_200, 10);
    assert_block_lease_contract(&full, 104);
}

#[test]
fn chunked_source_upholds_the_block_lease_contract() {
    let ds = blobs(701, 4, 4);
    let path = tmp_ekb("contract-chunked.ekb", &ds);
    // window far smaller than the file: leases constantly refill
    let src = ChunkedFileSource::open(&path, 37).unwrap();
    assert_block_lease_contract(&src, 105);
}

#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
#[test]
fn mmap_source_upholds_the_block_lease_contract() {
    use eakm::data::ooc::MmapSource;
    let ds = blobs(701, 4, 5);
    let path = tmp_ekb("contract-mmap.ekb", &ds);
    let src = MmapSource::open(&path).unwrap();
    assert_block_lease_contract(&src, 106);
}
