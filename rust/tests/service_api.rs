//! Integration tests for the fit/predict service API: predict agrees
//! with a fresh nearest-centroid scan, is bit-identical across runtime
//! widths, and survives a JSON save → load round-trip unchanged.

use eakm::linalg::{argmin, sqdist, sqdist_batch_block, sqnorms_rows};
use eakm::prelude::*;

/// Reference labels: an independent nearest-centroid scan over the same
/// public batch kernel `predict` uses (bit-identical arithmetic), with
/// first-lowest-index tie-breaking.
fn fresh_scan(model: &FittedModel, data: &Dataset) -> Vec<u32> {
    let (n, d, k) = (data.n(), data.d(), model.k());
    let cnorms = sqnorms_rows(model.centroids(), d);
    let mut row = vec![0.0; k];
    (0..n)
        .map(|i| {
            sqdist_batch_block(
                data.row(i),
                &data.sqnorms()[i..i + 1],
                model.centroids(),
                &cnorms,
                d,
                &mut row,
            );
            argmin(&row).unwrap() as u32
        })
        .collect()
}

#[test]
fn predict_agrees_with_fresh_scan_across_widths() {
    let train = eakm::data::synth::blobs(3_000, 6, 12, 0.15, 3);
    let queries = eakm::data::synth::blobs(1_100, 6, 12, 0.25, 17);
    let rt1 = Runtime::new(1);
    let model = Kmeans::new(12)
        .algorithm(Algorithm::ExpNs)
        .seed(7)
        .fit(&rt1, &train)
        .unwrap();

    let reference = fresh_scan(&model, &queries);
    for threads in [1usize, 4] {
        let rt = Runtime::new(threads);
        let labels = model.predict(&rt, &queries).unwrap();
        assert_eq!(labels, reference, "threads={threads}");
    }

    // and the labels are genuinely nearest (independent direct-distance
    // check, tolerance for the two kernels' rounding)
    for (i, &a) in reference.iter().enumerate() {
        let x = queries.row(i);
        let d_pred = sqdist(
            x,
            &model.centroids()[a as usize * 6..(a as usize + 1) * 6],
        );
        let d_min = (0..model.k())
            .map(|j| sqdist(x, &model.centroids()[j * 6..(j + 1) * 6]))
            .fold(f64::INFINITY, f64::min);
        assert!(d_pred <= d_min + 1e-9 * (1.0 + d_min), "query {i}");
    }
}

#[test]
fn save_load_predict_roundtrips_bit_identically() {
    let dir = std::env::temp_dir().join(format!("eakm-service-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.json");

    let train = eakm::data::synth::blobs(2_000, 9, 15, 0.1, 5);
    let queries = eakm::data::synth::blobs(700, 9, 15, 0.2, 23);
    let rt = Runtime::new(2);
    let model = Kmeans::new(15)
        .algorithm(Algorithm::SelkNs)
        .seed(11)
        .fit(&rt, &train)
        .unwrap();
    model.save(&path).unwrap();
    let loaded = FittedModel::load(&path).unwrap();

    // centroids round-trip to the exact bits...
    let bits = |c: &[f64]| c.iter().map(|v| v.to_bits()).collect::<Vec<u64>>();
    assert_eq!(bits(loaded.centroids()), bits(model.centroids()));
    // ...so predictions are identical, at either width
    for threads in [1usize, 4] {
        let rtw = Runtime::new(threads);
        assert_eq!(
            loaded.predict(&rtw, &queries).unwrap(),
            model.predict(&rtw, &queries).unwrap(),
            "threads={threads}"
        );
    }
    // metadata survives too
    assert_eq!(loaded.algorithm(), "selk-ns");
    assert_eq!(loaded.report().seed, 11);
    assert_eq!(loaded.report().k, 15);
}

#[test]
fn fit_is_width_independent_through_the_service_api() {
    let train = eakm::data::synth::blobs(1_500, 5, 9, 0.2, 2);
    let fit_at = |threads: usize| {
        let rt = Runtime::new(threads);
        Kmeans::new(9)
            .algorithm(Algorithm::ExpNs)
            .seed(4)
            .fit(&rt, &train)
            .unwrap()
    };
    let m1 = fit_at(1);
    let m4 = fit_at(4);
    let bits = |c: &[f64]| c.iter().map(|v| v.to_bits()).collect::<Vec<u64>>();
    assert_eq!(bits(m1.centroids()), bits(m4.centroids()));
    assert_eq!(m1.report().iterations, m4.report().iterations);
    assert_eq!(m1.report().mse.to_bits(), m4.report().mse.to_bits());
    assert_eq!(m1.report().counters, m4.report().counters);
}
