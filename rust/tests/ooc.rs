//! Out-of-core bit-identity: clustering an `.ekb` file through
//! `MmapSource` / `ChunkedFileSource` (window far smaller than the
//! file) must produce **bit-identical** assignments, MSE, and bound
//! counters to the in-memory run — for the exact and mini-batch
//! engines, at several thread widths. This is the acceptance gate for
//! the out-of-core layer; CI runs it on every commit.

use std::path::PathBuf;

use eakm::data::ooc::{open_ooc, OocMode};
use eakm::data::{io, Dataset};
use eakm::prelude::*;

const THREADS: [usize; 3] = [1, 2, 8];

fn tmpdir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("eakm-ooc-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A dataset written to disk plus the same data resident in memory.
fn fixture(name: &str, n: usize, d: usize, seed: u64) -> (PathBuf, Dataset) {
    let ds = eakm::data::synth::blobs(n, d, 6, 0.25, seed);
    let path = tmpdir().join(name);
    io::save_bin(&ds, &path).unwrap();
    // reload so the in-memory reference went through the same file
    let mem = io::load_bin(&path).unwrap();
    (path, mem)
}

fn modes() -> Vec<OocMode> {
    let mut modes = vec![OocMode::Chunked];
    if eakm::data::ooc::mmap_supported() {
        modes.push(OocMode::Mmap);
    }
    modes
}

#[test]
fn exact_engine_is_bit_identical_out_of_core() {
    let (path, mem) = fixture("exact.ekb", 1_500, 5, 3);
    for alg in [Algorithm::Sta, Algorithm::ExpNs] {
        for &threads in &THREADS {
            let cfg = RunConfig::new(alg, 6).seed(7).threads(threads);
            let want = Runner::new(&cfg).run(&mem).unwrap();
            for mode in modes() {
                // window of 128 rows over a 1500-row file: the scan
                // refills many times per round
                let src = open_ooc(&path, mode, 128).unwrap();
                let got = Runner::new(&cfg).run(&*src).unwrap();
                assert_eq!(got.assignments, want.assignments, "{alg} {mode} t={threads}");
                assert_eq!(
                    got.mse.to_bits(),
                    want.mse.to_bits(),
                    "{alg} {mode} t={threads}"
                );
                assert_eq!(got.counters, want.counters, "{alg} {mode} t={threads}");
                assert_eq!(got.iterations, want.iterations);
                let bits = |c: &[f64]| c.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&got.centroids), bits(&want.centroids));
                // the out-of-core run reports I/O, the in-memory one not
                let io = got.report.io.expect("ooc run reports I/O telemetry");
                assert!(io.blocks_leased > 0);
                assert!(want.report.io.is_none());
                if mode == OocMode::Chunked {
                    assert!(io.window_refills > 0, "small window must refill");
                    assert!(io.bytes_read > 0);
                }
            }
        }
    }
}

#[test]
fn minibatch_engine_is_bit_identical_out_of_core() {
    let (path, mem) = fixture("minibatch.ekb", 2_000, 4, 5);
    for growth in [2.0, 1.0] {
        let mut cfg = RunConfig::new(Algorithm::ExpNs, 6)
            .seed(11)
            .batch_size(150)
            .batch_growth(growth);
        cfg.max_iters = if growth > 1.0 { 200 } else { 12 };
        for &threads in &THREADS {
            cfg.threads = threads;
            let want = Runner::new(&cfg).run(&mem).unwrap();
            for mode in modes() {
                let src = open_ooc(&path, mode, 128).unwrap();
                let got = Runner::new(&cfg).run(&*src).unwrap();
                assert_eq!(got.assignments, want.assignments, "{mode} t={threads}");
                assert_eq!(got.mse.to_bits(), want.mse.to_bits());
                assert_eq!(got.counters, want.counters);
                assert_eq!(got.report.batch, want.report.batch, "same batch schedule");
                assert!(got.report.io.unwrap().blocks_leased > 0);
            }
        }
    }
}

#[test]
fn predict_and_kmeanspp_run_out_of_core() {
    let (path, mem) = fixture("predict.ekb", 900, 3, 9);
    let rt = Runtime::new(2);
    // k-means++ seeding makes many random-access row reads
    let cfg = Kmeans::new(5)
        .algorithm(Algorithm::Elk)
        .seed(3)
        .init(InitMethod::KmeansPlusPlus);
    let want = cfg.fit(&rt, &mem).unwrap();
    for mode in modes() {
        let src = open_ooc(&path, mode, 64).unwrap();
        let model = cfg.fit(&rt, &*src).unwrap();
        let bits = |c: &[f64]| c.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(model.centroids()), bits(want.centroids()));
        // serving path: predict straight off the file
        let labels = model.predict(&rt, &*src).unwrap();
        let labels_mem = want.predict(&rt, &mem).unwrap();
        assert_eq!(labels, labels_mem, "{mode}");
    }
}

#[test]
fn io_telemetry_reports_per_run_deltas() {
    let (path, _mem) = fixture("telemetry.ekb", 800, 4, 13);
    let src = open_ooc(&path, OocMode::Chunked, 100).unwrap();
    let cfg = RunConfig::new(Algorithm::Sta, 4).seed(1);
    let first = Runner::new(&cfg).run(&*src).unwrap();
    let second = Runner::new(&cfg).run(&*src).unwrap();
    let (a, b) = (first.report.io.unwrap(), second.report.io.unwrap());
    // deltas, not cumulative totals: two identical runs read the same
    assert_eq!(a.blocks_leased, b.blocks_leased);
    assert_eq!(a.bytes_read, b.bytes_read);
    // and the source's cumulative counters kept growing underneath
    let total = src.io_stats().unwrap();
    assert!(total.blocks_leased >= a.blocks_leased * 2);
}
