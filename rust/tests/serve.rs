//! End-to-end serving tests over a real loopback socket: bit-identity
//! with direct `predict`, arrival-order responses under concurrent
//! clients, typed `overloaded` backpressure, zero-downtime reload,
//! hostile-input handling, and idle-connection reaping.

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use eakm::data::synth::blobs;
use eakm::json::Json;
use eakm::prelude::*;
use eakm::serve::client::{self, Client};
use eakm::serve::proto::code;

fn fit_model(n: usize, d: usize, k: usize, seed: u64) -> FittedModel {
    let rt = Runtime::serial();
    let ds = blobs(n, d, k, 0.1, seed);
    Kmeans::new(k).seed(seed).max_iters(20).fit(&rt, &ds).unwrap()
}

/// Run a server on its own thread + runtime; returns the bound address
/// and the handle that yields the final `ServeStats` after shutdown.
fn start(
    model: FittedModel,
    threads: usize,
    cfg: ServeConfig,
) -> (SocketAddr, thread::JoinHandle<ServeStats>) {
    let (tx, rx) = mpsc::channel();
    let handle = thread::spawn(move || {
        let rt = Runtime::new(threads);
        eakm::serve::serve(&rt, model, &cfg, |addr| tx.send(addr).unwrap()).unwrap()
    });
    (rx.recv().unwrap(), handle)
}

fn connect(addr: SocketAddr) -> Client {
    Client::connect(addr).unwrap()
}

fn labels_of(reply: &Json) -> Vec<u32> {
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
    reply
        .get("labels")
        .and_then(Json::as_arr)
        .expect("labels")
        .iter()
        .map(|l| l.as_usize().unwrap() as u32)
        .collect()
}

fn error_code(reply: &Json) -> Option<String> {
    if reply.get("ok").and_then(Json::as_bool) == Some(false) {
        reply
            .get("error")
            .and_then(Json::as_str)
            .map(|s| s.to_string())
    } else {
        None
    }
}

fn shutdown(addr: SocketAddr) {
    let reply = connect(addr).call(&client::shutdown_request()).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));
}

fn tmpfile(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("eakm-serve-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn socket_predictions_are_bit_identical_to_direct_predict() {
    let model = fit_model(400, 6, 8, 11);
    let queries = blobs(60, 6, 8, 0.2, 12);
    for threads in [1usize, 4] {
        let rt = Runtime::new(threads);
        let want = model.predict(&rt, &queries).unwrap();
        let (addr, handle) = start(model.clone(), threads, ServeConfig::default());
        let mut c = connect(addr);
        let mut got = Vec::new();
        // uneven request sizes: batching boundaries must not matter
        let d = queries.d();
        let mut lo = 0;
        for len in [7usize, 1, 20, 32] {
            let rows = &queries.raw()[lo * d..(lo + len) * d];
            got.extend(labels_of(&c.call(&client::predict_request(rows, d)).unwrap()));
            lo += len;
        }
        assert_eq!(got, want, "threads={threads}");
        drop(c);
        shutdown(addr);
        let stats = handle.join().unwrap();
        assert_eq!(stats.predicts, 4, "threads={threads}");
        assert_eq!(stats.batched_rows, 60, "threads={threads}");
    }
}

#[test]
fn concurrent_clients_get_their_own_answers_in_order() {
    let model = fit_model(300, 4, 5, 21);
    let queries = blobs(100, 4, 5, 0.25, 22);
    let rt = Runtime::new(2);
    let want = model.predict(&rt, &queries).unwrap();
    // a small linger forces concurrent single-row requests to coalesce
    // into shared scans — the scatter must still route every client its
    // own labels, in its own send order
    let cfg = ServeConfig {
        linger: Duration::from_millis(3),
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model, 2, cfg);
    let d = queries.d();
    let clients = 4;
    let per_client = 25;
    let mut workers = Vec::new();
    for c in 0..clients {
        let raw = queries.raw().to_vec();
        let expect: Vec<u32> = (0..per_client)
            .map(|i| want[c * per_client + i])
            .collect();
        workers.push(thread::spawn(move || {
            let mut cl = connect(addr);
            for (i, &want_label) in expect.iter().enumerate() {
                let gi = c * per_client + i;
                let rows = &raw[gi * d..(gi + 1) * d];
                let labels = labels_of(&cl.call(&client::predict_request(rows, d)).unwrap());
                assert_eq!(labels, vec![want_label], "client {c}, request {i}");
            }
        }));
    }
    for w in workers {
        w.join().unwrap();
    }
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert_eq!(stats.predicts, (clients * per_client) as u64);
    assert_eq!(stats.batched_rows, (clients * per_client) as u64);
    // with 4 clients inside a 3ms window, at least one scan must have
    // coalesced several requests
    assert!(
        stats.coalesced_batches > 0,
        "expected some coalescing: {stats:?}"
    );
    assert!(stats.batches < stats.predicts, "{stats:?}");
}

#[test]
fn queue_overflow_returns_typed_overloaded_reply() {
    // a deliberately slow scan (k=400, d=32, 200-row requests) with a
    // depth-1 queue and no coalescing: while the batcher scans one
    // request, concurrent arrivals overflow and must get the typed
    // `overloaded` reply immediately
    let model = {
        let rt = Runtime::serial();
        let ds = blobs(800, 32, 400, 0.1, 31);
        // two rounds are plenty — this model only needs to be *big*
        Kmeans::new(400).seed(31).max_iters(2).fit(&rt, &ds).unwrap()
    };
    let queries = blobs(200, 32, 400, 0.3, 32);
    let cfg = ServeConfig {
        queue_depth: 1,
        max_batch_rows: 1,
        acceptors: 4,
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model, 1, cfg);
    let d = queries.d();
    let line = client::predict_request(queries.raw(), d);
    let mut saw_overloaded = false;
    let mut saw_ok = false;
    for _round in 0..20 {
        let mut workers = Vec::new();
        for _ in 0..4 {
            let line = line.clone();
            workers.push(thread::spawn(move || {
                let reply = connect(addr).call(&line).unwrap();
                match error_code(&reply) {
                    Some(code) => {
                        assert_eq!(code, code::OVERLOADED, "{reply}");
                        true
                    }
                    None => {
                        assert_eq!(labels_of(&reply).len(), 200);
                        false
                    }
                }
            }));
        }
        for w in workers {
            if w.join().unwrap() {
                saw_overloaded = true;
            } else {
                saw_ok = true;
            }
        }
        if saw_overloaded && saw_ok {
            break;
        }
    }
    assert!(saw_overloaded, "queue never overflowed");
    assert!(saw_ok, "no request was ever served");
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert!(stats.queue_full_rejects > 0, "{stats:?}");
}

#[test]
fn reload_swaps_models_without_dropping_in_flight_requests() {
    let model_a = fit_model(200, 4, 3, 41);
    let model_b = fit_model(260, 4, 6, 42);
    let path_b = tmpfile("model-b.json");
    model_b.save(&path_b).unwrap();
    let cfg = ServeConfig {
        linger: Duration::from_millis(5),
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model_a, 2, cfg);
    // three clients hammer predicts while the reload lands mid-stream;
    // every single request must get an ok reply — none dropped
    let queries = blobs(30, 4, 6, 0.2, 43);
    let d = queries.d();
    let mut workers = Vec::new();
    for c in 0..3usize {
        let raw = queries.raw().to_vec();
        workers.push(thread::spawn(move || {
            let mut cl = connect(addr);
            for i in 0..30 {
                let gi = (c * 7 + i) % 30;
                let rows = &raw[gi * d..(gi + 1) * d];
                let labels = labels_of(&cl.call(&client::predict_request(rows, d)).unwrap());
                assert_eq!(labels.len(), 1, "client {c}, request {i}");
            }
        }));
    }
    thread::sleep(Duration::from_millis(20));
    let mut admin = connect(addr);
    // a bad path is a typed error and must not disturb serving
    let bad = admin.call(&client::reload_request("/nonexistent.json")).unwrap();
    assert_eq!(error_code(&bad).as_deref(), Some(code::MODEL_ERROR));
    // the real reload swaps generations with zero downtime
    let reply = admin
        .call(&client::reload_request(path_b.to_str().unwrap()))
        .unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
    assert_eq!(reply.get("generation").and_then(Json::as_usize), Some(2));
    assert_eq!(reply.get("k").and_then(Json::as_usize), Some(6));
    for w in workers {
        w.join().unwrap();
    }
    // post-reload requests are served by model B
    let stats_reply = admin.call(&client::stats_request()).unwrap();
    let stats_json = stats_reply.get("stats").expect("stats payload");
    assert_eq!(stats_json.get("generation").and_then(Json::as_usize), Some(2));
    assert_eq!(stats_json.get("model_k").and_then(Json::as_usize), Some(6));
    assert_eq!(stats_json.get("op_errors").and_then(Json::as_usize), Some(1));
    let post = labels_of(
        &admin
            .call(&client::predict_request(&queries.raw()[..d], d))
            .unwrap(),
    );
    let rt = Runtime::serial();
    let direct = model_b.predict_rows(&rt, &queries.raw()[..d]).unwrap();
    assert_eq!(post, direct, "post-reload serving must match model B");
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert_eq!(stats.predicts, 3 * 30 + 1);
    assert_eq!(stats.reloads, 1);
    assert_eq!(stats.op_errors, 1); // the bad reload path
}

#[test]
fn hostile_input_gets_typed_replies_and_the_server_survives() {
    let model = fit_model(150, 3, 4, 51);
    let cfg = ServeConfig {
        max_line_bytes: 4096,
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model, 1, cfg);
    let mut c = connect(addr);
    let cases: &[(String, &str)] = &[
        ("this is not json".to_string(), code::BAD_REQUEST),
        (r#"{"op":"frobnicate"}"#.to_string(), code::UNKNOWN_OP),
        (
            r#"{"op":"predict","rows":[[1,2],[3]]}"#.to_string(),
            code::BAD_REQUEST,
        ),
        (
            // nesting bomb: typed reject, not a stack overflow
            format!("{}1{}", "[".repeat(200), "]".repeat(200)),
            code::PAYLOAD_TOO_LARGE,
        ),
        (
            r#"{"op":"nearest","point":[1.0]}"#.to_string(),
            code::DIM_MISMATCH,
        ),
    ];
    for (line, want) in cases {
        let reply = c.call(line).unwrap();
        assert_eq!(error_code(&reply).as_deref(), Some(*want), "{line:?}");
    }
    // an over-long line gets a typed reply and then the connection is
    // closed (framing is gone), but the server itself keeps serving
    let huge = format!(r#"{{"op":"predict","rows":[[{}]]}}"#, "1,".repeat(4000) + "1");
    let reply = c.call(&huge).unwrap();
    assert_eq!(
        error_code(&reply).as_deref(),
        Some(code::PAYLOAD_TOO_LARGE),
        "{reply}"
    );
    assert!(
        c.recv().unwrap().is_none(),
        "connection must close after overlong line"
    );
    let stats_reply = connect(addr).call(&client::stats_request()).unwrap();
    assert_eq!(stats_reply.get("ok").and_then(Json::as_bool), Some(true));
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert!(stats.bad_requests >= 5, "{stats:?}");
}

#[test]
fn idle_connections_are_reaped_so_acceptors_stay_available() {
    let model = fit_model(150, 3, 4, 71);
    // two acceptors, short idle timeout: two parked connections must
    // not deny service to a third client for long
    let cfg = ServeConfig {
        acceptors: 2,
        idle_timeout: Duration::from_millis(300),
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model, 1, cfg);
    let mut idle_a = connect(addr);
    let mut idle_b = connect(addr);
    // the server reaps both idlers (read returns closed-stream)…
    assert!(idle_a.recv().unwrap().is_none(), "idle connection must be closed");
    assert!(idle_b.recv().unwrap().is_none(), "idle connection must be closed");
    // …a byte-trickling peer (bytes but never a complete request) is
    // reaped just the same — activity without a newline must not reset
    // the idle clock…
    let mut trickler = TcpStream::connect(addr).unwrap();
    let mut reaped = false;
    for _ in 0..60 {
        if trickler.write_all(b"x").is_err() {
            reaped = true;
            break;
        }
        thread::sleep(Duration::from_millis(50));
    }
    assert!(reaped, "byte-trickling connection must be reaped");
    // …and a fresh client is served normally
    let reply = connect(addr).call(&client::stats_request()).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn pipelined_requests_are_answered_in_order() {
    let model = fit_model(200, 3, 4, 61);
    let queries = blobs(6, 3, 4, 0.2, 62);
    let rt = Runtime::serial();
    let want = model.predict(&rt, &queries).unwrap();
    let (addr, handle) = start(model, 1, ServeConfig::default());
    let d = queries.d();
    let mut c = connect(addr);
    // two requests in one send: the line framer must keep the second
    // request buffered and answer both, in order
    let two = format!(
        "{}\n{}",
        client::predict_request(&queries.raw()[..3 * d], d),
        client::predict_request(&queries.raw()[3 * d..], d),
    );
    c.send(&two).unwrap();
    let first = labels_of(&c.recv().unwrap().unwrap());
    let second = labels_of(&c.recv().unwrap().unwrap());
    assert_eq!(first, want[..3].to_vec());
    assert_eq!(second, want[3..].to_vec());
    // nearest agrees with the model's own nearest()
    let (want_label, want_dist) = {
        let m = fit_model(200, 3, 4, 61);
        m.nearest(&queries.raw()[..d])
    };
    let reply = c.call(&client::nearest_request(&queries.raw()[..d])).unwrap();
    assert_eq!(
        reply.get("label").and_then(Json::as_usize),
        Some(want_label as usize)
    );
    let dist = reply.get("distance").and_then(Json::as_f64).unwrap();
    assert_eq!(dist.to_bits(), want_dist.to_bits(), "wire must be lossless");
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert_eq!(stats.predicts, 2);
    assert_eq!(stats.nearests, 1);
    assert_eq!(stats.requests, 4); // 2 predict + nearest + shutdown
}
