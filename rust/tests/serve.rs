//! End-to-end serving tests over a real loopback socket: bit-identity
//! with direct `predict` (line-JSON and the HTTP/1.1 shim), streaming
//! `bulk_predict` over an on-disk `.ekb`, arrival-order responses under
//! concurrent clients, typed `overloaded` backpressure, admission
//! control (rate limit + circuit breaker), zero-downtime reload,
//! hostile-input handling, and idle-connection reaping.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use eakm::data::synth::blobs;
use eakm::json::Json;
use eakm::prelude::*;
use eakm::serve::client::{self, Client};
use eakm::serve::proto::code;
use eakm::serve::{AdmissionConfig, KeyBy};

fn fit_model(n: usize, d: usize, k: usize, seed: u64) -> FittedModel {
    let rt = Runtime::serial();
    let ds = blobs(n, d, k, 0.1, seed);
    Kmeans::new(k).seed(seed).max_iters(20).fit(&rt, &ds).unwrap()
}

/// Run a server on its own thread + runtime; returns the bound address
/// and the handle that yields the final `ServeStats` after shutdown.
fn start(
    model: FittedModel,
    threads: usize,
    cfg: ServeConfig,
) -> (SocketAddr, thread::JoinHandle<ServeStats>) {
    let (tx, rx) = mpsc::channel();
    let handle = thread::spawn(move || {
        let rt = Runtime::new(threads);
        eakm::serve::serve(&rt, model, &cfg, |addr| tx.send(addr).unwrap()).unwrap()
    });
    (rx.recv().unwrap(), handle)
}

fn connect(addr: SocketAddr) -> Client {
    Client::connect(addr).unwrap()
}

fn labels_of(reply: &Json) -> Vec<u32> {
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
    reply
        .get("labels")
        .and_then(Json::as_arr)
        .expect("labels")
        .iter()
        .map(|l| l.as_usize().unwrap() as u32)
        .collect()
}

fn error_code(reply: &Json) -> Option<String> {
    if reply.get("ok").and_then(Json::as_bool) == Some(false) {
        reply
            .get("error")
            .and_then(Json::as_str)
            .map(|s| s.to_string())
    } else {
        None
    }
}

fn shutdown(addr: SocketAddr) {
    let reply = connect(addr).call(&client::shutdown_request()).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));
}

fn tmpfile(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("eakm-serve-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn socket_predictions_are_bit_identical_to_direct_predict() {
    let model = fit_model(400, 6, 8, 11);
    let queries = blobs(60, 6, 8, 0.2, 12);
    for threads in [1usize, 4] {
        let rt = Runtime::new(threads);
        let want = model.predict(&rt, &queries).unwrap();
        let (addr, handle) = start(model.clone(), threads, ServeConfig::default());
        let mut c = connect(addr);
        let mut got = Vec::new();
        // uneven request sizes: batching boundaries must not matter
        let d = queries.d();
        let mut lo = 0;
        for len in [7usize, 1, 20, 32] {
            let rows = &queries.raw()[lo * d..(lo + len) * d];
            got.extend(labels_of(&c.call(&client::predict_request(rows, d)).unwrap()));
            lo += len;
        }
        assert_eq!(got, want, "threads={threads}");
        drop(c);
        shutdown(addr);
        let stats = handle.join().unwrap();
        assert_eq!(stats.predicts, 4, "threads={threads}");
        assert_eq!(stats.batched_rows, 60, "threads={threads}");
    }
}

#[test]
fn concurrent_clients_get_their_own_answers_in_order() {
    let model = fit_model(300, 4, 5, 21);
    let queries = blobs(100, 4, 5, 0.25, 22);
    let rt = Runtime::new(2);
    let want = model.predict(&rt, &queries).unwrap();
    // a small linger forces concurrent single-row requests to coalesce
    // into shared scans — the scatter must still route every client its
    // own labels, in its own send order
    let cfg = ServeConfig {
        linger: Duration::from_millis(3),
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model, 2, cfg);
    let d = queries.d();
    let clients = 4;
    let per_client = 25;
    let mut workers = Vec::new();
    for c in 0..clients {
        let raw = queries.raw().to_vec();
        let expect: Vec<u32> = (0..per_client)
            .map(|i| want[c * per_client + i])
            .collect();
        workers.push(thread::spawn(move || {
            let mut cl = connect(addr);
            for (i, &want_label) in expect.iter().enumerate() {
                let gi = c * per_client + i;
                let rows = &raw[gi * d..(gi + 1) * d];
                let labels = labels_of(&cl.call(&client::predict_request(rows, d)).unwrap());
                assert_eq!(labels, vec![want_label], "client {c}, request {i}");
            }
        }));
    }
    for w in workers {
        w.join().unwrap();
    }
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert_eq!(stats.predicts, (clients * per_client) as u64);
    assert_eq!(stats.batched_rows, (clients * per_client) as u64);
    // with 4 clients inside a 3ms window, at least one scan must have
    // coalesced several requests
    assert!(
        stats.coalesced_batches > 0,
        "expected some coalescing: {stats:?}"
    );
    assert!(stats.batches < stats.predicts, "{stats:?}");
}

#[test]
fn queue_overflow_returns_typed_overloaded_reply() {
    // a deliberately slow scan (k=400, d=32, 200-row requests) with a
    // depth-1 queue and no coalescing: while the batcher scans one
    // request, concurrent arrivals overflow and must get the typed
    // `overloaded` reply immediately
    let model = {
        let rt = Runtime::serial();
        let ds = blobs(800, 32, 400, 0.1, 31);
        // two rounds are plenty — this model only needs to be *big*
        Kmeans::new(400).seed(31).max_iters(2).fit(&rt, &ds).unwrap()
    };
    let queries = blobs(200, 32, 400, 0.3, 32);
    let cfg = ServeConfig {
        queue_depth: 1,
        max_batch_rows: 1,
        acceptors: 4,
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model, 1, cfg);
    let d = queries.d();
    let line = client::predict_request(queries.raw(), d);
    let mut saw_overloaded = false;
    let mut saw_ok = false;
    for _round in 0..20 {
        let mut workers = Vec::new();
        for _ in 0..4 {
            let line = line.clone();
            workers.push(thread::spawn(move || {
                let reply = connect(addr).call(&line).unwrap();
                match error_code(&reply) {
                    Some(code) => {
                        assert_eq!(code, code::OVERLOADED, "{reply}");
                        true
                    }
                    None => {
                        assert_eq!(labels_of(&reply).len(), 200);
                        false
                    }
                }
            }));
        }
        for w in workers {
            if w.join().unwrap() {
                saw_overloaded = true;
            } else {
                saw_ok = true;
            }
        }
        if saw_overloaded && saw_ok {
            break;
        }
    }
    assert!(saw_overloaded, "queue never overflowed");
    assert!(saw_ok, "no request was ever served");
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert!(stats.queue_full_rejects > 0, "{stats:?}");
}

#[test]
fn reload_swaps_models_without_dropping_in_flight_requests() {
    let model_a = fit_model(200, 4, 3, 41);
    let model_b = fit_model(260, 4, 6, 42);
    let path_b = tmpfile("model-b.json");
    model_b.save(&path_b).unwrap();
    let cfg = ServeConfig {
        linger: Duration::from_millis(5),
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model_a, 2, cfg);
    // three clients hammer predicts while the reload lands mid-stream;
    // every single request must get an ok reply — none dropped
    let queries = blobs(30, 4, 6, 0.2, 43);
    let d = queries.d();
    let mut workers = Vec::new();
    for c in 0..3usize {
        let raw = queries.raw().to_vec();
        workers.push(thread::spawn(move || {
            let mut cl = connect(addr);
            for i in 0..30 {
                let gi = (c * 7 + i) % 30;
                let rows = &raw[gi * d..(gi + 1) * d];
                let labels = labels_of(&cl.call(&client::predict_request(rows, d)).unwrap());
                assert_eq!(labels.len(), 1, "client {c}, request {i}");
            }
        }));
    }
    thread::sleep(Duration::from_millis(20));
    let mut admin = connect(addr);
    // a bad path is a typed error and must not disturb serving
    let bad = admin.call(&client::reload_request("/nonexistent.json")).unwrap();
    assert_eq!(error_code(&bad).as_deref(), Some(code::MODEL_ERROR));
    // the real reload swaps generations with zero downtime
    let reply = admin
        .call(&client::reload_request(path_b.to_str().unwrap()))
        .unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
    assert_eq!(reply.get("generation").and_then(Json::as_usize), Some(2));
    assert_eq!(reply.get("k").and_then(Json::as_usize), Some(6));
    for w in workers {
        w.join().unwrap();
    }
    // post-reload requests are served by model B
    let stats_reply = admin.call(&client::stats_request()).unwrap();
    let stats_json = stats_reply.get("stats").expect("stats payload");
    assert_eq!(stats_json.get("generation").and_then(Json::as_usize), Some(2));
    assert_eq!(stats_json.get("model_k").and_then(Json::as_usize), Some(6));
    assert_eq!(stats_json.get("op_errors").and_then(Json::as_usize), Some(1));
    let post = labels_of(
        &admin
            .call(&client::predict_request(&queries.raw()[..d], d))
            .unwrap(),
    );
    let rt = Runtime::serial();
    let direct = model_b.predict_rows(&rt, &queries.raw()[..d]).unwrap();
    assert_eq!(post, direct, "post-reload serving must match model B");
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert_eq!(stats.predicts, 3 * 30 + 1);
    assert_eq!(stats.reloads, 1);
    assert_eq!(stats.op_errors, 1); // the bad reload path
}

#[test]
fn hostile_input_gets_typed_replies_and_the_server_survives() {
    let model = fit_model(150, 3, 4, 51);
    let cfg = ServeConfig {
        max_line_bytes: 4096,
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model, 1, cfg);
    let mut c = connect(addr);
    let cases: &[(String, &str)] = &[
        ("this is not json".to_string(), code::BAD_REQUEST),
        (r#"{"op":"frobnicate"}"#.to_string(), code::UNKNOWN_OP),
        (
            r#"{"op":"predict","rows":[[1,2],[3]]}"#.to_string(),
            code::BAD_REQUEST,
        ),
        (
            // nesting bomb: typed reject, not a stack overflow
            format!("{}1{}", "[".repeat(200), "]".repeat(200)),
            code::PAYLOAD_TOO_LARGE,
        ),
        (
            r#"{"op":"nearest","point":[1.0]}"#.to_string(),
            code::DIM_MISMATCH,
        ),
    ];
    for (line, want) in cases {
        let reply = c.call(line).unwrap();
        assert_eq!(error_code(&reply).as_deref(), Some(*want), "{line:?}");
    }
    // an over-long line gets a typed reply and then the connection is
    // closed (framing is gone), but the server itself keeps serving
    let huge = format!(r#"{{"op":"predict","rows":[[{}]]}}"#, "1,".repeat(4000) + "1");
    let reply = c.call(&huge).unwrap();
    assert_eq!(
        error_code(&reply).as_deref(),
        Some(code::PAYLOAD_TOO_LARGE),
        "{reply}"
    );
    assert!(
        c.recv().unwrap().is_none(),
        "connection must close after overlong line"
    );
    let stats_reply = connect(addr).call(&client::stats_request()).unwrap();
    assert_eq!(stats_reply.get("ok").and_then(Json::as_bool), Some(true));
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert!(stats.bad_requests >= 5, "{stats:?}");
}

#[test]
fn idle_connections_are_reaped_so_acceptors_stay_available() {
    let model = fit_model(150, 3, 4, 71);
    // two acceptors, short idle timeout: two parked connections must
    // not deny service to a third client for long
    let cfg = ServeConfig {
        acceptors: 2,
        idle_timeout: Duration::from_millis(300),
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model, 1, cfg);
    let mut idle_a = connect(addr);
    let mut idle_b = connect(addr);
    // the server reaps both idlers (read returns closed-stream)…
    assert!(idle_a.recv().unwrap().is_none(), "idle connection must be closed");
    assert!(idle_b.recv().unwrap().is_none(), "idle connection must be closed");
    // …a byte-trickling peer (bytes but never a complete request) is
    // reaped just the same — activity without a newline must not reset
    // the idle clock…
    let mut trickler = TcpStream::connect(addr).unwrap();
    let mut reaped = false;
    for _ in 0..60 {
        if trickler.write_all(b"x").is_err() {
            reaped = true;
            break;
        }
        thread::sleep(Duration::from_millis(50));
    }
    assert!(reaped, "byte-trickling connection must be reaped");
    // …and a fresh client is served normally
    let reply = connect(addr).call(&client::stats_request()).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn pipelined_requests_are_answered_in_order() {
    let model = fit_model(200, 3, 4, 61);
    let queries = blobs(6, 3, 4, 0.2, 62);
    let rt = Runtime::serial();
    let want = model.predict(&rt, &queries).unwrap();
    let (addr, handle) = start(model, 1, ServeConfig::default());
    let d = queries.d();
    let mut c = connect(addr);
    // two requests in one send: the line framer must keep the second
    // request buffered and answer both, in order
    let two = format!(
        "{}\n{}",
        client::predict_request(&queries.raw()[..3 * d], d),
        client::predict_request(&queries.raw()[3 * d..], d),
    );
    c.send(&two).unwrap();
    let first = labels_of(&c.recv().unwrap().unwrap());
    let second = labels_of(&c.recv().unwrap().unwrap());
    assert_eq!(first, want[..3].to_vec());
    assert_eq!(second, want[3..].to_vec());
    // nearest agrees with the model's own nearest()
    let (want_label, want_dist) = {
        let m = fit_model(200, 3, 4, 61);
        m.nearest(&queries.raw()[..d])
    };
    let reply = c.call(&client::nearest_request(&queries.raw()[..d])).unwrap();
    assert_eq!(
        reply.get("label").and_then(Json::as_usize),
        Some(want_label as usize)
    );
    let dist = reply.get("distance").and_then(Json::as_f64).unwrap();
    assert_eq!(dist.to_bits(), want_dist.to_bits(), "wire must be lossless");
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert_eq!(stats.predicts, 2);
    assert_eq!(stats.nearests, 1);
    assert_eq!(stats.requests, 4); // 2 predict + nearest + shutdown
}

// ---- the HTTP shim ----------------------------------------------------

/// One parsed HTTP response.
struct HttpResp {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl HttpResp {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Json {
        Json::parse(self.body.trim_end()).unwrap()
    }
}

/// A tiny HTTP/1.1 test client — enough to drive the shim the way curl
/// would: keep-alive, `Content-Length` bodies, chunked responses.
struct Http {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Http {
    fn connect(addr: SocketAddr) -> Http {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(60)))
            .unwrap();
        Http {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn send_raw(&mut self, raw: &str) {
        self.writer.write_all(raw.as_bytes()).unwrap();
        self.writer.flush().unwrap();
    }

    fn send(&mut self, method: &str, target: &str, body: Option<&str>) {
        let mut req = format!("{method} {target} HTTP/1.1\r\nHost: test\r\n");
        if let Some(b) = body {
            req.push_str(&format!(
                "Content-Type: application/json\r\nContent-Length: {}\r\n",
                b.len()
            ));
        }
        req.push_str("\r\n");
        if let Some(b) = body {
            req.push_str(b);
        }
        self.send_raw(&req);
    }

    fn read_response(&mut self) -> HttpResp {
        let mut status_line = String::new();
        assert!(self.reader.read_line(&mut status_line).unwrap() > 0, "no response");
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .expect("status code")
            .parse()
            .unwrap();
        let mut headers = Vec::new();
        loop {
            let mut line = String::new();
            self.reader.read_line(&mut line).unwrap();
            let line = line.trim_end().to_string();
            if line.is_empty() {
                break;
            }
            let (k, v) = line.split_once(':').expect("header line");
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
        let chunked = headers
            .iter()
            .any(|(k, v)| k == "transfer-encoding" && v.contains("chunked"));
        let body = if chunked {
            let mut out = String::new();
            loop {
                let mut size = String::new();
                self.reader.read_line(&mut size).unwrap();
                let n = usize::from_str_radix(size.trim(), 16).expect("chunk size");
                if n == 0 {
                    let mut terminator = String::new();
                    self.reader.read_line(&mut terminator).unwrap();
                    break;
                }
                let mut chunk = vec![0u8; n + 2]; // payload + CRLF
                self.reader.read_exact(&mut chunk).unwrap();
                out.push_str(std::str::from_utf8(&chunk[..n]).unwrap());
            }
            out
        } else {
            let len: usize = self
                .header_of(&headers, "content-length")
                .map(|v| v.parse().unwrap())
                .unwrap_or(0);
            let mut buf = vec![0u8; len];
            self.reader.read_exact(&mut buf).unwrap();
            String::from_utf8(buf).unwrap()
        };
        HttpResp {
            status,
            headers,
            body,
        }
    }

    fn header_of<'a>(&self, headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
        headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    fn call(&mut self, method: &str, target: &str, body: Option<&str>) -> HttpResp {
        self.send(method, target, body);
        self.read_response()
    }
}

#[test]
fn http_predictions_are_bit_identical_to_direct_predict() {
    let model = fit_model(400, 6, 8, 13);
    let queries = blobs(60, 6, 8, 0.2, 14);
    for threads in [1usize, 4] {
        let rt = Runtime::new(threads);
        let want = model.predict(&rt, &queries).unwrap();
        let (addr, handle) = start(model.clone(), threads, ServeConfig::default());
        let mut h = Http::connect(addr);
        let d = queries.d();
        let mut got = Vec::new();
        // uneven request sizes over one keep-alive connection: batching
        // boundaries and the protocol shim must not change a single bit
        let mut lo = 0;
        for len in [9usize, 1, 25, 25] {
            let rows = &queries.raw()[lo * d..(lo + len) * d];
            let resp = h.call("POST", "/v1/predict", Some(&client::predict_request(rows, d)));
            assert_eq!(resp.status, 200, "{}", resp.body);
            got.extend(labels_of(&resp.json()));
            lo += len;
        }
        assert_eq!(got, want, "threads={threads}");
        shutdown(addr);
        let stats = handle.join().unwrap();
        assert_eq!(stats.predicts, 4, "threads={threads}");
        assert_eq!(stats.http_requests, 4, "threads={threads}");
        assert_eq!(stats.batched_rows, 60, "threads={threads}");
    }
}

#[test]
fn http_routes_map_statuses_and_keep_alive_like_a_real_server() {
    let model = fit_model(150, 3, 4, 81);
    let cfg = ServeConfig {
        max_line_bytes: 4096,
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model, 1, cfg);
    let mut h = Http::connect(addr);
    // liveness + stats on one keep-alive connection
    let resp = h.call("GET", "/v1/healthz", None);
    assert_eq!(resp.status, 200);
    assert_eq!(resp.json().get("ok").and_then(Json::as_bool), Some(true));
    let resp = h.call("GET", "/v1/stats", None);
    assert_eq!(resp.status, 200);
    let payload = resp.json();
    let stats_json = payload.get("stats").expect("stats payload");
    assert!(
        stats_json.get("http_requests").and_then(Json::as_usize).unwrap() >= 1,
        "{stats_json}"
    );
    // routing and body failures: typed codes, mapped statuses, and the
    // connection survives every one of them
    let resp = h.call("POST", "/v1/frobnicate", Some("{}"));
    assert_eq!(resp.status, 404);
    assert_eq!(error_code(&resp.json()).as_deref(), Some(code::NOT_FOUND));
    let resp = h.call("GET", "/v1/predict", None);
    assert_eq!(resp.status, 405);
    assert_eq!(error_code(&resp.json()).as_deref(), Some(code::BAD_METHOD));
    let resp = h.call("POST", "/v1/predict", Some("this is not json"));
    assert_eq!(resp.status, 400);
    assert_eq!(error_code(&resp.json()).as_deref(), Some(code::BAD_REQUEST));
    let resp = h.call("POST", "/v1/nearest", Some(r#"{"point":[1.0]}"#));
    assert_eq!(resp.status, 400);
    assert_eq!(error_code(&resp.json()).as_deref(), Some(code::DIM_MISMATCH));
    let resp = h.call("GET", "/v1/healthz", None);
    assert_eq!(resp.status, 200, "connection must still be alive");
    // a body over the byte cap is refused from its declared length
    // alone — 413, Connection: close, and the socket really closes
    h.send_raw("POST /v1/predict HTTP/1.1\r\nContent-Length: 999999999\r\n\r\n");
    let resp = h.read_response();
    assert_eq!(resp.status, 413);
    assert_eq!(error_code(&resp.json()).as_deref(), Some(code::PAYLOAD_TOO_LARGE));
    assert_eq!(resp.header("connection"), Some("close"));
    let mut probe = String::new();
    assert_eq!(
        h.reader.read_line(&mut probe).unwrap_or(0),
        0,
        "connection must close after 413"
    );
    // a malformed request line gets 400 and a close
    let mut h = Http::connect(addr);
    h.send_raw("FROB one two three\r\n\r\n");
    let resp = h.read_response();
    assert_eq!(resp.status, 400);
    assert_eq!(resp.header("connection"), Some("close"));
    shutdown(addr);
    let stats = handle.join().unwrap();
    // seven complete requests on the first connection (the 413 and the
    // malformed request line are rejected before they count as one)
    assert_eq!(stats.http_requests, 7, "{stats:?}");
    // 404 + 405 + bad body + oversized + malformed line
    assert_eq!(stats.bad_requests, 5, "{stats:?}");
}

#[test]
fn bulk_predict_streams_blocks_bit_identical_to_direct_predict() {
    let data = blobs(1234, 5, 6, 0.15, 91);
    let path = tmpfile("bulk.ekb");
    eakm::data::io::save_bin(&data, &path).unwrap();
    let model = fit_model(300, 5, 6, 92);
    for threads in [1usize, 4] {
        let rt = Runtime::new(threads);
        let want = model.predict(&rt, &data).unwrap();
        let cfg = ServeConfig {
            bulk_block_rows: 100, // 1234 rows → 13 blocks
            ..ServeConfig::default()
        };
        let (addr, handle) = start(model.clone(), threads, cfg);

        // line-JSON, server-default block size
        let mut c = connect(addr);
        let got = c.bulk_predict(path.to_str().unwrap(), None).unwrap();
        assert_eq!(got.labels, want, "threads={threads}");
        assert_eq!(got.blocks, 13, "threads={threads}");

        // an explicit block size overrides the default; labels are
        // identical at any block boundary
        let got = c.bulk_predict(path.to_str().unwrap(), Some(500)).unwrap();
        assert_eq!(got.labels, want, "threads={threads}");
        assert_eq!(got.blocks, 3);

        // HTTP chunked response, forced onto the windowed chunked
        // reader (curl-shaped: everything in the query string)
        let mut h = Http::connect(addr);
        let target = format!(
            "/v1/bulk_predict?path={}&block_rows=100&mode=chunked",
            path.display()
        );
        let resp = h.call("POST", &target, None);
        assert_eq!(resp.status, 200, "{}", resp.body);
        assert_eq!(resp.header("transfer-encoding"), Some("chunked"));
        let mut lines = resp.body.lines();
        let header = Json::parse(lines.next().unwrap()).unwrap();
        assert_eq!(header.get("ok").and_then(Json::as_bool), Some(true), "{header}");
        assert_eq!(header.get("n").and_then(Json::as_usize), Some(1234));
        let mut labels = vec![0u32; 1234];
        let mut blocks = 0u64;
        let mut trailer = None;
        for line in lines {
            let doc = Json::parse(line).unwrap();
            if doc.get("done").and_then(Json::as_bool) == Some(true) {
                trailer = Some(doc);
                break;
            }
            let lo = doc.get("lo").and_then(Json::as_usize).unwrap();
            let block = doc.get("labels").and_then(Json::as_arr).unwrap();
            for (i, label) in block.iter().enumerate() {
                labels[lo + i] = label.as_usize().unwrap() as u32;
            }
            blocks += 1;
        }
        assert_eq!(labels, want, "threads={threads} (http)");
        assert_eq!(blocks, 13);
        let trailer = trailer.expect("stream trailer");
        assert_eq!(trailer.get("blocks").and_then(Json::as_usize), Some(13));
        assert_eq!(trailer.get("rows").and_then(Json::as_usize), Some(1234));
        let io = trailer.get("io").expect("io telemetry");
        assert!(
            io.get("bytes_read").and_then(Json::as_f64).unwrap() > 0.0,
            "{trailer}"
        );

        // a missing file is a typed error, not a broken stream
        let err = c.bulk_predict("/nonexistent.ekb", None).unwrap_err();
        assert!(err.to_string().contains("source_error"), "{err}");

        shutdown(addr);
        let stats = handle.join().unwrap();
        assert_eq!(stats.bulk_predicts, 3, "threads={threads}");
        assert_eq!(stats.bulk_blocks, 13 + 3 + 13, "threads={threads}");
        assert_eq!(stats.bulk_rows, 3 * 1234, "threads={threads}");
    }
}

// ---- admission control ------------------------------------------------

#[test]
fn flooding_client_is_rate_limited_while_polite_client_succeeds() {
    let model = fit_model(150, 3, 4, 101);
    // per-connection keying: both clients come from 127.0.0.1, and the
    // test needs them budgeted separately (production keeps `Ip`)
    let cfg = ServeConfig {
        admission: AdmissionConfig {
            rate_limit: 5.0,
            burst: 2.0,
            key_by: KeyBy::Conn,
            ..AdmissionConfig::default()
        },
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model, 1, cfg);
    // the polite client paces itself under the sustained rate and must
    // never be refused, whatever the flood next door is doing
    let polite = thread::spawn(move || {
        let mut c = connect(addr);
        for i in 0..6 {
            let reply = c.call(&client::stats_request()).unwrap();
            assert_eq!(
                reply.get("ok").and_then(Json::as_bool),
                Some(true),
                "polite request {i}: {reply}"
            );
            thread::sleep(Duration::from_millis(250));
        }
    });
    let mut c = connect(addr);
    let mut limited = 0;
    let mut served = 0;
    for _ in 0..40 {
        let reply = c.call(&client::stats_request()).unwrap();
        match error_code(&reply) {
            Some(code) => {
                assert_eq!(code, code::RATE_LIMITED, "{reply}");
                let message = reply.get("message").and_then(Json::as_str).unwrap();
                assert!(message.contains("retry in"), "{reply}");
                limited += 1;
            }
            None => served += 1,
        }
    }
    assert!(limited > 0, "flood was never rate-limited");
    assert!(served >= 2, "burst tokens must admit the first requests");
    // the rejection is advisory, not a ban: after backing off, the same
    // connection is served again
    thread::sleep(Duration::from_millis(250));
    let reply = c.call(&client::stats_request()).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
    polite.join().unwrap();

    // over HTTP the same rejection is a 429 with a Retry-After hint —
    // while healthz bypasses admission (liveness is never load-shed)
    let mut h = Http::connect(addr);
    let mut saw_429 = false;
    for _ in 0..20 {
        let resp = h.call("GET", "/v1/stats", None);
        if resp.status == 429 {
            assert!(resp.header("retry-after").is_some(), "429 needs Retry-After");
            assert_eq!(error_code(&resp.json()).as_deref(), Some(code::RATE_LIMITED));
            saw_429 = true;
        } else {
            assert_eq!(resp.status, 200, "{}", resp.body);
        }
    }
    assert!(saw_429, "HTTP flood was never rate-limited");
    for _ in 0..5 {
        assert_eq!(h.call("GET", "/v1/healthz", None).status, 200);
    }
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert!(stats.rate_limited_rejects > 0, "{stats:?}");
    assert!(stats.http_requests >= 25, "{stats:?}");
}

#[test]
fn breaker_trips_after_consecutive_failures_and_recovers_half_open() {
    let model = fit_model(150, 3, 4, 111);
    let cfg = ServeConfig {
        admission: AdmissionConfig {
            breaker_fails: 3,
            breaker_cooldown: Duration::from_millis(200),
            key_by: KeyBy::Conn,
            ..AdmissionConfig::default()
        },
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model, 1, cfg);
    let mut c = connect(addr);
    for i in 0..3 {
        let reply = c.call("this is not json").unwrap();
        assert_eq!(
            error_code(&reply).as_deref(),
            Some(code::BAD_REQUEST),
            "bad request {i}"
        );
    }
    // tripped: even a well-formed request is refused now
    let reply = c.call(&client::stats_request()).unwrap();
    assert_eq!(error_code(&reply).as_deref(), Some(code::BREAKER_OPEN), "{reply}");
    // an innocent concurrent connection has its own breaker
    let reply = connect(addr).call(&client::stats_request()).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
    // after the cooldown exactly one half-open probe is admitted; its
    // success closes the breaker and traffic flows again
    thread::sleep(Duration::from_millis(250));
    let reply = c.call(&client::stats_request()).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true), "probe: {reply}");
    let reply = c.call(&client::stats_request()).unwrap();
    assert_eq!(
        reply.get("ok").and_then(Json::as_bool),
        Some(true),
        "after probe: {reply}"
    );
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert!(stats.breaker_rejects >= 1, "{stats:?}");
    assert_eq!(stats.bad_requests, 3, "{stats:?}");
}

#[test]
fn stats_report_admission_counters() {
    let model = fit_model(150, 3, 4, 121);
    let cfg = ServeConfig {
        admission: AdmissionConfig {
            rate_limit: 10.0,
            burst: 1.0,
            key_by: KeyBy::Conn,
            ..AdmissionConfig::default()
        },
        ..ServeConfig::default()
    };
    let (addr, handle) = start(model, 1, cfg);
    let mut c = connect(addr);
    let first = c.call(&client::stats_request()).unwrap();
    assert_eq!(first.get("ok").and_then(Json::as_bool), Some(true), "{first}");
    let limited = c.call(&client::stats_request()).unwrap();
    assert_eq!(error_code(&limited).as_deref(), Some(code::RATE_LIMITED));
    thread::sleep(Duration::from_millis(150)); // ≥ one token refills
    let reply = c.call(&client::stats_request()).unwrap();
    let stats_json = reply.get("stats").expect("stats payload");
    assert_eq!(
        stats_json.get("rate_limited_rejects").and_then(Json::as_usize),
        Some(1),
        "{stats_json}"
    );
    assert_eq!(
        stats_json.get("breaker_rejects").and_then(Json::as_usize),
        Some(0)
    );
    assert!(stats_json.get("http_requests").is_some(), "{stats_json}");
    assert!(stats_json.get("bulk_predicts").is_some(), "{stats_json}");
    assert!(stats_json.get("bulk_rows").is_some(), "{stats_json}");
    shutdown(addr);
    let stats = handle.join().unwrap();
    assert_eq!(stats.rate_limited_rejects, 1);
    assert_eq!(stats.breaker_rejects, 0);
}
