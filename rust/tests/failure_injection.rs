//! Failure injection & adversarial inputs: the system must stay exact or
//! fail cleanly, never silently mis-cluster.

use eakm::algorithms::Algorithm;
use eakm::config::RunConfig;
use eakm::coordinator::Runner;
use eakm::data::synth::blobs;
use eakm::data::Dataset;
use eakm::error::EakmError;
use eakm::proptest::forall;

/// Seeds that force empty clusters: k close to n with concentrated data.
#[test]
fn empty_clusters_stay_exact() {
    let mut data = Vec::new();
    // 3 tight far-apart groups; k=12 guarantees several empty clusters
    // after round 1
    for g in 0..3 {
        for i in 0..20 {
            // irrational jitter kills exact distance ties (ties are
            // numeric-route-dependent and not part of the exactness claim)
            data.push(g as f64 * 100.0 + (i as f64) * 1e-3 + (i as f64).sin() * 1e-4);
            data.push(g as f64 * -50.0 + (i as f64 * 0.7).cos() * 1e-4);
        }
    }
    let ds = Dataset::new("tight", data, 60, 2).unwrap();
    for seed in 0..5 {
        let r = Runner::new(&RunConfig::new(Algorithm::Sta, 12).seed(seed))
            .run(&ds)
            .unwrap();
        for alg in [
            Algorithm::Ham,
            Algorithm::Exp,
            Algorithm::ExpNs,
            Algorithm::Selk,
            Algorithm::SelkNs,
            Algorithm::Syin,
            Algorithm::SyinNs,
            Algorithm::Elk,
            Algorithm::ElkNs,
            Algorithm::Ann,
            Algorithm::Yin,
        ] {
            let out = Runner::new(&RunConfig::new(alg, 12).seed(seed)).run(&ds).unwrap();
            assert_eq!(out.assignments, r.assignments, "{alg} seed={seed}");
            assert_eq!(out.iterations, r.iterations, "{alg} seed={seed}");
        }
    }
}

#[test]
fn invalid_configs_are_rejected_not_panicked() {
    let ds = blobs(20, 2, 2, 0.1, 1);
    // k = 0
    let e = Runner::new(&RunConfig::new(Algorithm::Sta, 0)).run(&ds);
    assert!(matches!(e, Err(EakmError::Config(_))));
    // k > n
    let e = Runner::new(&RunConfig::new(Algorithm::Exp, 21)).run(&ds);
    assert!(matches!(e, Err(EakmError::Config(_))));
    // max_iters = 0
    let mut cfg = RunConfig::new(Algorithm::Sta, 2);
    cfg.max_iters = 0;
    assert!(matches!(Runner::new(&cfg).run(&ds), Err(EakmError::Config(_))));
}

#[test]
fn dataset_construction_rejects_poison() {
    assert!(Dataset::new("x", vec![1.0, f64::INFINITY], 1, 2).is_err());
    assert!(Dataset::new("x", vec![1.0, f64::NAN], 1, 2).is_err());
    assert!(Dataset::new("x", vec![], 0, 0).is_err());
    assert!(Dataset::new("x", vec![1.0; 5], 2, 2).is_err());
}

#[test]
fn adversarial_collinear_data() {
    // all points on one line — stresses annuli construction and s(j)
    // degeneracy (many near-equal inter-centroid distances). Non-uniform
    // spacing avoids exact midpoint ties, which are numeric-route
    // dependent and excluded from the exactness claim.
    let data: Vec<f64> = (0..300)
        .flat_map(|i| [(i as f64).powf(1.01), 0.0, 0.0])
        .collect();
    let ds = Dataset::new("line3d", data, 300, 3).unwrap();
    let r = Runner::new(&RunConfig::new(Algorithm::Sta, 16).seed(3))
        .run(&ds)
        .unwrap();
    for alg in [Algorithm::Exp, Algorithm::ExpNs, Algorithm::Ann, Algorithm::Ham] {
        let out = Runner::new(&RunConfig::new(alg, 16).seed(3)).run(&ds).unwrap();
        assert_eq!(out.iterations, r.iterations, "{alg}");
        let rel = (out.mse - r.mse).abs() / r.mse.max(1e-12);
        assert!(rel < 1e-9, "{alg}: objective differs on collinear data");
    }
}

#[test]
fn prop_random_small_workloads_all_exact() {
    // randomized mini-workloads across every algorithm — the paper's
    // exactness claim under fuzz
    forall(42, 8, |g| {
        let n = g.usize_in(30, 120);
        let d = g.usize_in(1, 12);
        let k = g.usize_in(2, 10.min(n / 3));
        let seed = g.usize_in(0, 1000) as u64;
        let spread = g.f64_in(0.05, 0.8);
        let ds = blobs(n, d, k, spread, seed);
        let r = Runner::new(&RunConfig::new(Algorithm::Sta, k).seed(seed))
            .run(&ds)
            .unwrap();
        for alg in Algorithm::ALL {
            let out = Runner::new(&RunConfig::new(alg, k).seed(seed)).run(&ds).unwrap();
            assert_eq!(
                out.assignments, r.assignments,
                "{alg} diverged (n={n} d={d} k={k} seed={seed} spread={spread})"
            );
        }
    });
}

#[test]
fn history_reset_boundary_cases() {
    // reset period 2 (minimum) forces a fold nearly every round
    let ds = blobs(200, 4, 6, 0.3, 9);
    let mut cfg = RunConfig::new(Algorithm::Sta, 6).seed(2);
    cfg.history_cap = Some(2);
    let r = Runner::new(&cfg).run(&ds).unwrap();
    for alg in [Algorithm::SelkNs, Algorithm::ElkNs, Algorithm::SyinNs, Algorithm::ExpNs] {
        let mut c = RunConfig::new(alg, 6).seed(2);
        c.history_cap = Some(2);
        let out = Runner::new(&c).run(&ds).unwrap();
        assert_eq!(out.assignments, r.assignments, "{alg} with cap=2");
        assert_eq!(out.iterations, r.iterations, "{alg} with cap=2");
    }
}

#[test]
fn time_limit_cuts_off_cleanly() {
    use std::time::Duration;
    let ds = blobs(5_000, 3, 50, 0.8, 1);
    let cfg = RunConfig::new(Algorithm::Sta, 50)
        .seed(1)
        .time_limit(Duration::from_millis(1));
    let out = Runner::new(&cfg).run(&ds).unwrap();
    // must return a consistent (if unconverged) state
    assert_eq!(out.assignments.len(), 5_000);
    assert!(out.mse.is_finite());
}
