//! One shared `Runtime` must amortise its pool across the whole
//! process: multiple fits and predicts, zero re-spawns.
//!
//! This file intentionally holds a single test: it asserts on the
//! process-global spawn counter, so it must be the only pool creator in
//! its test binary.

use eakm::prelude::*;
use eakm::runtime::pool::threads_spawned_total;

#[test]
fn one_runtime_drives_many_fits_and_predicts_without_respawning() {
    let data = eakm::data::synth::blobs(2_000, 6, 10, 0.15, 1);
    let queries = eakm::data::synth::blobs(600, 6, 10, 0.2, 42);

    // creating the runtime spawns its workers (width 4 → 3 OS threads)...
    let before_runtime = threads_spawned_total();
    let rt = Runtime::new(4);
    assert_eq!(threads_spawned_total(), before_runtime + 3);

    // ...and everything after rides the same pool: two fits with
    // different algorithms, predicts from both models
    let spawned = threads_spawned_total();
    let model_a = Kmeans::new(10)
        .algorithm(Algorithm::ExpNs)
        .seed(1)
        .fit(&rt, &data)
        .unwrap();
    let model_b = Kmeans::new(10)
        .algorithm(Algorithm::SelkNs)
        .seed(2)
        .fit(&rt, &data)
        .unwrap();
    let labels_a = model_a.predict(&rt, &queries).unwrap();
    let labels_b = model_b.predict(&rt, &queries).unwrap();
    assert_eq!(
        threads_spawned_total(),
        spawned,
        "fit/predict on a shared Runtime must not spawn threads"
    );

    assert!(model_a.report().converged);
    assert!(model_b.report().converged);
    assert_eq!(model_a.report().threads, 4);
    assert_eq!(labels_a.len(), queries.n());
    assert_eq!(labels_b.len(), queries.n());

    // exactness: both algorithms fit the same seed → same clustering
    let bits = |c: &[f64]| c.iter().map(|v| v.to_bits()).collect::<Vec<u64>>();
    let model_c = Kmeans::new(10)
        .algorithm(Algorithm::Sta)
        .seed(1)
        .fit(&rt, &data)
        .unwrap();
    assert_eq!(bits(model_a.centroids()), bits(model_c.centroids()));
}
