//! Property-based tests of the crate's core invariants, using the
//! built-in `eakm::proptest` harness (no external crates offline).

use eakm::coordinator::annuli::Annuli;
use eakm::coordinator::ccdist::CcData;
use eakm::coordinator::sorted_norms::SortedNorms;
use eakm::coordinator::update::UpdateState;
use eakm::data::Dataset;
use eakm::linalg::{
    argmin, dot, gemm, sqdist, sqdist_argmin_block, sqdist_batch_block, sqnorm, sqnorms_rows, top2,
};
use eakm::metrics::Counters;
use eakm::proptest::forall;

#[test]
fn prop_gemm_matches_naive() {
    forall(101, 40, |g| {
        let m = g.usize_in(1, 40);
        let d = g.usize_in(1, 30);
        let k = g.usize_in(1, 70);
        let a = g.normal_vec(m * d);
        let b = g.normal_vec(k * d);
        let mut out = vec![0.0; m * k];
        gemm::matmul_nt(&a, &b, &mut out, m, d, k);
        for i in 0..m {
            for j in 0..k {
                let want = dot(&a[i * d..(i + 1) * d], &b[j * d..(j + 1) * d]);
                let got = out[i * k + j];
                assert!(
                    (got - want).abs() <= 1e-9 * (1.0 + want.abs()),
                    "({m},{d},{k}) at ({i},{j}): {got} vs {want}"
                );
            }
        }
    });
}

#[test]
fn prop_batch_distances_match_direct() {
    forall(102, 40, |g| {
        let m = g.usize_in(1, 50);
        let d = g.usize_in(1, 20);
        let k = g.usize_in(1, 30);
        let xs = g.normal_vec(m * d);
        let cs = g.normal_vec(k * d);
        let xn = sqnorms_rows(&xs, d);
        let cn = sqnorms_rows(&cs, d);
        let mut out = vec![0.0; m * k];
        sqdist_batch_block(&xs, &xn, &cs, &cn, d, &mut out);
        for i in 0..m {
            for j in 0..k {
                let want = sqdist(&xs[i * d..(i + 1) * d], &cs[j * d..(j + 1) * d]);
                assert!((out[i * k + j] - want).abs() < 1e-8 * (1.0 + want));
            }
        }
    });
}

#[test]
fn prop_top2_matches_sort() {
    forall(103, 200, |g| {
        let n = g.usize_in(1, 64);
        let xs = g.uniform_vec(n, -10.0, 10.0);
        let t = top2(&xs);
        let mut sorted: Vec<(f64, usize)> = xs.iter().cloned().zip(0..).collect();
        sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        assert_eq!(t.idx1, sorted[0].1);
        assert_eq!(t.val1, sorted[0].0);
        if n > 1 {
            assert_eq!(t.val2, sorted[1].0);
        } else {
            assert!(t.val2.is_infinite());
        }
    });
}

#[test]
fn prop_cc_s_is_min_distance() {
    forall(104, 30, |g| {
        let k = g.usize_in(2, 40);
        let d = g.usize_in(1, 8);
        let cs = g.normal_vec(k * d);
        let cc = CcData::build(&cs, k, d, &mut Counters::default());
        for j in 0..k {
            let want = (0..k)
                .filter(|&j2| j2 != j)
                .map(|j2| sqdist(&cs[j * d..(j + 1) * d], &cs[j2 * d..(j2 + 1) * d]).sqrt())
                .fold(f64::INFINITY, f64::min);
            assert!((cc.s[j] - want).abs() < 1e-12, "s({j})");
            // symmetry
            for j2 in 0..k {
                assert_eq!(cc.get(j, j2), cc.get(j2, j));
            }
        }
    });
}

#[test]
fn prop_annuli_superset_and_2x_bound() {
    forall(105, 25, |g| {
        let k = g.usize_in(2, 64);
        let d = g.usize_in(1, 6);
        let cs = g.normal_vec(k * d);
        let cc = CcData::build(&cs, k, d, &mut Counters::default());
        let ann = Annuli::build(&cc);
        for _ in 0..10 {
            let j = g.usize_in(0, k - 1);
            let r = g.f64_in(0.0, 6.0);
            let cand: std::collections::HashSet<u32> =
                ann.candidates(j, r).iter().cloned().collect();
            let mut exact = 0;
            for j2 in 0..k {
                if j2 != j && cc.get(j, j2) <= r {
                    exact += 1;
                    assert!(
                        cand.contains(&(j2 as u32)),
                        "k={k} j={j} r={r}: missing {j2} at dist {}",
                        cc.get(j, j2)
                    );
                }
            }
            assert!(
                cand.len() <= 2 * exact + 1,
                "over-coverage: |J*|={} |J|={exact}",
                cand.len()
            );
        }
    });
}

#[test]
fn prop_sorted_norms_window_is_exact_filter() {
    forall(106, 50, |g| {
        let k = g.usize_in(1, 60);
        let cnorms_sq: Vec<f64> = g.uniform_vec(k, 0.0, 25.0);
        let sn = SortedNorms::build(&cnorms_sq);
        let x = g.f64_in(0.0, 5.0);
        let r = g.f64_in(0.0, 2.0);
        let got: std::collections::HashSet<u32> = sn.window(x, r).collect();
        for (j, &sq) in cnorms_sq.iter().enumerate() {
            let inside = (sq.sqrt() - x).abs() <= r;
            assert_eq!(
                got.contains(&(j as u32)),
                inside,
                "j={j} norm={} x={x} r={r}",
                sq.sqrt()
            );
        }
    });
}

#[test]
fn prop_delta_update_equals_recompute() {
    forall(107, 25, |g| {
        let n = g.usize_in(4, 60);
        let d = g.usize_in(1, 5);
        let k = g.usize_in(2, 6);
        let data = g.normal_vec(n * d);
        let ds = Dataset::new("p", data, n, d).unwrap();
        let mut a: Vec<u32> = (0..n).map(|_| g.usize_in(0, k - 1) as u32).collect();
        let mut st = UpdateState::from_assignments(&ds, &a, k);
        // random sequence of moves applied both ways
        for _ in 0..g.usize_in(1, 20) {
            let i = g.usize_in(0, n - 1);
            let to = g.usize_in(0, k - 1) as u32;
            if a[i] == to {
                continue;
            }
            let mv = eakm::algorithms::Moved {
                i: i as u32,
                from: a[i],
                to,
            };
            a[i] = to;
            st.apply_moves(&ds, &[mv]);
        }
        let fresh = UpdateState::from_assignments(&ds, &a, k);
        let old = vec![0.0; k * d];
        let got = st.centroids(&old, d);
        let want = fresh.centroids(&old, d);
        for (gv, wv) in got.iter().zip(&want) {
            assert!((gv - wv).abs() < 1e-9, "delta drifted from recompute");
        }
    });
}

#[test]
fn prop_sqnorm_triangle_inequality_consistency() {
    // ns-vs-sn core fact: ‖a−c‖ ≤ ‖a−b‖ + ‖b−c‖ for our sqdist
    forall(108, 100, |g| {
        let d = g.usize_in(1, 16);
        let a = g.normal_vec(d);
        let b = g.normal_vec(d);
        let c = g.normal_vec(d);
        let ab = sqdist(&a, &b).sqrt();
        let bc = sqdist(&b, &c).sqrt();
        let ac = sqdist(&a, &c).sqrt();
        assert!(ac <= ab + bc + 1e-9);
        assert!(sqnorm(&a) >= 0.0);
    });
}

#[test]
fn prop_fused_argmin_matches_materialising() {
    // the fused scan must agree with materialise-then-argmin on labels
    // AND on distance bits — both paths run the same panel micro-kernel
    forall(111, 40, |g| {
        let m = g.usize_in(1, 50);
        let d = g.usize_in(1, 20);
        let k = g.usize_in(1, 150); // spans the NB=64 panel boundary
        let xs = g.normal_vec(m * d);
        let cs = g.normal_vec(k * d);
        let xn = sqnorms_rows(&xs, d);
        let cn = sqnorms_rows(&cs, d);
        let mut mat = vec![0.0; m * k];
        sqdist_batch_block(&xs, &xn, &cs, &cn, d, &mut mat);
        let mut labels = vec![0u32; m];
        let mut dists = vec![0.0; m];
        sqdist_argmin_block(&xs, &xn, &cs, &cn, d, &mut labels, &mut dists);
        for i in 0..m {
            let row = &mat[i * k..(i + 1) * k];
            let want = argmin(row).unwrap();
            assert_eq!(labels[i] as usize, want, "row {i} of ({m},{d},{k})");
            assert_eq!(
                dists[i].to_bits(),
                row[want].to_bits(),
                "row {i} of ({m},{d},{k}): distance bits diverge"
            );
        }
    });
}

// Scalar references for the lane-blocked kernels — deliberately local
// copies (the lib's #[cfg(test)] reference module is invisible to
// integration tests), summing in plain left-to-right order.
const AWKWARD_DIMS: &[usize] = &[1, 2, 3, 5, 7, 9, 31, 33, 127, 784];

fn naive_dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn naive_sqdist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[test]
fn prop_kernels_match_naive_on_awkward_dims_both_widths() {
    // every awkward dim (lane remainders 0..7, d < LANES, huge d) at
    // both storage widths: blocked summation may reorder, so compare
    // with a relative tolerance, not bits
    forall(112, 20, |g| {
        for &d in AWKWARD_DIMS {
            let mut a = g.normal_vec(d);
            let mut b = g.normal_vec(d);
            if g.usize_in(0, 1) == 1 {
                // f32-width data: round every value like DatasetF32 does
                for v in a.iter_mut() {
                    *v = *v as f32 as f64;
                }
                for v in b.iter_mut() {
                    *v = *v as f32 as f64;
                }
            }
            let want = naive_dot(&a, &b);
            let got = dot(&a, &b);
            assert!(
                (got - want).abs() <= 1e-9 * (1.0 + want.abs()),
                "dot d={d}: {got} vs {want}"
            );
            let want = naive_sqdist(&a, &b);
            let got = sqdist(&a, &b);
            assert!(
                (got - want).abs() <= 1e-9 * (1.0 + want.abs()),
                "sqdist d={d}: {got} vs {want}"
            );
            let want = naive_dot(&a, &a);
            let got = sqnorm(&a);
            assert!(
                (got - want).abs() <= 1e-9 * (1.0 + want),
                "sqnorm d={d}: {got} vs {want}"
            );
        }
    });
}

#[test]
fn prop_config_parser_never_panics() {
    use eakm::config::RunConfig;
    forall(109, 200, |g| {
        // random garbage lines: parser must return Ok or Err, never panic
        let tokens = ["k", "algorithm", "=", "exp", "banana", "seed", "#x", "[s]", "1e9", "-3"];
        let mut text = String::new();
        for _ in 0..g.usize_in(0, 6) {
            for _ in 0..g.usize_in(0, 4) {
                text.push_str(tokens[g.usize_in(0, tokens.len() - 1)]);
                text.push(' ');
            }
            text.push('\n');
        }
        let _ = RunConfig::from_str_cfg(&text);
    });
}

#[test]
fn prop_standardize_is_idempotent() {
    forall(110, 30, |g| {
        let n = g.usize_in(2, 50);
        let d = g.usize_in(1, 6);
        let data = g.normal_vec(n * d);
        let mut ds = Dataset::new("s", data, n, d).unwrap();
        ds.standardize();
        let once = ds.raw().to_vec();
        ds.standardize();
        for (a, b) in ds.raw().iter().zip(&once) {
            assert!((a - b).abs() < 1e-9);
        }
    });
}
