//! Scan-scheduling bit-identity: the over-decomposed, cost-guided scan
//! plan is a *scheduling* change only — assignments, MSE bits, and
//! bound counters must be identical across every thread width × shard
//! count × data source, for the exact and mini-batch engines. This is
//! the acceptance gate for the scheduler; CI runs it on every commit.

use std::path::PathBuf;

use eakm::algorithms::testutil::assert_scan_plan_invariants;
use eakm::coordinator::sched::{AUTO_SCAN_SHARDS, MIN_SHARD_ROWS};
use eakm::data::ooc::{open_ooc, OocMode};
use eakm::data::{io, Dataset};
use eakm::prelude::*;

const THREADS: [usize; 3] = [1, 2, 8];
// explicit shard counts; n is chosen ≥ 16 × MIN_SHARD_ROWS so the
// largest spec survives the floor un-clamped
const SHARDS: [usize; 3] = [1, 4, 16];

fn tmpdir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("eakm-sched-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A dataset written to disk plus the same data resident in memory.
fn fixture(name: &str, n: usize, d: usize, seed: u64) -> (PathBuf, Dataset) {
    let ds = eakm::data::synth::blobs(n, d, 6, 0.25, seed);
    let path = tmpdir().join(name);
    io::save_bin(&ds, &path).unwrap();
    let mem = io::load_bin(&path).unwrap();
    (path, mem)
}

fn modes() -> Vec<OocMode> {
    let mut modes = vec![OocMode::Chunked];
    if eakm::data::ooc::mmap_supported() {
        modes.push(OocMode::Mmap);
    }
    modes
}

#[test]
fn exact_engine_bits_survive_the_scheduling_matrix() {
    let n = 16 * MIN_SHARD_ROWS; // 4096 rows: 16 explicit shards allowed
    let (path, mem) = fixture("exact.ekb", n, 4, 3);
    // reference: serial, single shard, in memory
    let base = RunConfig::new(Algorithm::ExpNs, 6).seed(7).max_iters(12);
    let want = Runner::new(&base.clone().threads(1).scan_shards(1)).run(&mem).unwrap();
    for &threads in &THREADS {
        for &shards in &SHARDS {
            let cfg = base.clone().threads(threads).scan_shards(shards);
            let got = Runner::new(&cfg).run(&mem).unwrap();
            assert_eq!(got.assignments, want.assignments, "t={threads} s={shards}");
            assert_eq!(got.mse.to_bits(), want.mse.to_bits(), "t={threads} s={shards}");
            assert_eq!(got.counters, want.counters, "t={threads} s={shards}");
            assert_eq!(got.iterations, want.iterations);
            // the plan honoured the explicit spec and reported it
            assert_eq!(got.report.sched.shards, shards);
            assert!(got.report.sched.dispatches > 0);
            assert!(got.report.sched.imbalance() >= 1.0);
            for mode in modes() {
                // 128-row window: ooc cursors refill many times per round
                let src = open_ooc(&path, mode, 128).unwrap();
                let ooc = Runner::new(&cfg).run(&*src).unwrap();
                assert_eq!(ooc.assignments, want.assignments, "{mode} t={threads} s={shards}");
                assert_eq!(ooc.mse.to_bits(), want.mse.to_bits(), "{mode} t={threads} s={shards}");
                assert_eq!(ooc.counters, want.counters, "{mode} t={threads} s={shards}");
            }
        }
    }
}

#[test]
fn minibatch_engine_bits_survive_the_scheduling_matrix() {
    let n = 16 * MIN_SHARD_ROWS;
    let (path, mem) = fixture("minibatch.ekb", n, 4, 5);
    let mut base = RunConfig::new(Algorithm::ExpNs, 6).seed(11).batch_size(1024);
    base.batch_growth = 2.0;
    base.max_iters = 40;
    let want = Runner::new(&base.clone().threads(1).scan_shards(1)).run(&mem).unwrap();
    for &threads in &THREADS {
        for &shards in &SHARDS {
            let cfg = base.clone().threads(threads).scan_shards(shards);
            let got = Runner::new(&cfg).run(&mem).unwrap();
            assert_eq!(got.assignments, want.assignments, "t={threads} s={shards}");
            assert_eq!(got.mse.to_bits(), want.mse.to_bits());
            assert_eq!(got.counters, want.counters);
            assert_eq!(got.report.batch, want.report.batch, "same batch schedule");
            assert!(got.report.sched.dispatches > 0);
            for mode in modes() {
                let src = open_ooc(&path, mode, 128).unwrap();
                let ooc = Runner::new(&cfg).run(&*src).unwrap();
                assert_eq!(ooc.assignments, want.assignments, "{mode} t={threads} s={shards}");
                assert_eq!(ooc.mse.to_bits(), want.mse.to_bits());
                assert_eq!(ooc.counters, want.counters);
            }
        }
    }
}

#[test]
fn auto_geometry_is_width_independent() {
    // auto shards must give the same plan — and the same bits — at any
    // thread width, because geometry is a function of n alone
    let ds = eakm::data::synth::blobs(3 * MIN_SHARD_ROWS, 5, 6, 0.25, 17);
    let cfg = RunConfig::new(Algorithm::Sta, 6).seed(9).max_iters(20);
    let want = Runner::new(&cfg.clone().threads(1)).run(&ds).unwrap();
    for &threads in &THREADS {
        let got = Runner::new(&cfg.clone().threads(threads)).run(&ds).unwrap();
        assert_eq!(got.assignments, want.assignments, "t={threads}");
        assert_eq!(got.mse.to_bits(), want.mse.to_bits());
        assert_eq!(got.counters, want.counters);
        assert_eq!(got.report.sched.shards, want.report.sched.shards);
    }
}

#[test]
fn lpt_order_telemetry_is_deterministic_across_runs() {
    // the claim order is ranked by deterministic cost counters, so
    // repeated runs must reorder identically — reorders is part of the
    // reproducible telemetry, not a wall-clock artefact
    let ds = eakm::data::synth::blobs(16 * MIN_SHARD_ROWS, 4, 6, 0.25, 23);
    let mut cfg = RunConfig::new(Algorithm::ExpNs, 6).seed(13).threads(8).scan_shards(16);
    cfg.max_iters = 15;
    let first = Runner::new(&cfg).run(&ds).unwrap();
    for _ in 0..2 {
        let again = Runner::new(&cfg).run(&ds).unwrap();
        assert_eq!(again.assignments, first.assignments);
        assert_eq!(again.report.sched.shards, first.report.sched.shards);
        assert_eq!(again.report.sched.dispatches, first.report.sched.dispatches);
        assert_eq!(again.report.sched.reorders, first.report.sched.reorders);
    }
}

#[test]
fn report_json_carries_scheduling_telemetry() {
    let ds = eakm::data::synth::blobs(2 * MIN_SHARD_ROWS, 3, 4, 0.25, 29);
    let cfg = RunConfig::new(Algorithm::Sta, 4).seed(1).scan_shards(2);
    let out = Runner::new(&cfg).run(&ds).unwrap();
    let json = eakm::json::Json::from(&out.report).to_string();
    for key in [
        "\"sched_shards\":2",
        "\"sched_dispatches\"",
        "\"sched_reorders\"",
        "\"sched_imbalance\"",
        "\"sched_scan_max_secs\"",
    ] {
        assert!(json.contains(key), "report JSON misses {key}: {json}");
    }
}

#[test]
fn scan_plan_geometry_invariants_hold() {
    for n in [0, 1, 255, 256, 300, 4096, 10_000, 100_000, 1_000_000] {
        for spec in [AUTO_SCAN_SHARDS, 1, 4, 16, 1000] {
            assert_scan_plan_invariants(n, spec);
        }
    }
}

#[test]
fn kmeans_builder_accepts_scan_shards() {
    let ds = eakm::data::synth::blobs(1024, 3, 4, 0.3, 31);
    let rt = Runtime::new(2);
    let want = Kmeans::new(4).seed(5).fit(&rt, &ds).unwrap();
    let got = Kmeans::new(4).seed(5).scan_shards(4).fit(&rt, &ds).unwrap();
    let bits = |c: &[f64]| c.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(got.centroids()), bits(want.centroids()));
    assert_eq!(got.report().sched.shards, 4);
    // predict path is width-independent too
    let labels = got.predict(&rt, &ds).unwrap();
    assert_eq!(labels, want.predict(&rt, &ds).unwrap());
}
