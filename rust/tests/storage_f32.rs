//! End-to-end acceptance for opt-in f32 storage: on f32-representable
//! data, clustering through [`DatasetF32`] (and through f32 `.ekb`
//! files, chunked or mapped) must be **bit-identical** to clustering
//! the same widened values through [`Dataset`] — assignments, MSE bits,
//! bound counters, centroid bits — at several thread widths. On
//! general f64 data, narrowing rounds once at ingest and the results
//! agree to documented tolerances.

use std::path::PathBuf;

use eakm::data::ooc::{open_ooc, OocMode};
use eakm::data::{io, Dataset, DatasetF32};
use eakm::prelude::*;

fn tmpdir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("eakm-f32-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Blobs whose every value is exactly f32-representable, so the
/// narrow→widen round trip is the identity and bit-level comparisons
/// are meaningful.
fn f32_exact_blobs(n: usize, d: usize, clusters: usize, seed: u64) -> Dataset {
    let ds = eakm::data::synth::blobs(n, d, clusters, 0.25, seed);
    let rounded: Vec<f64> = ds.raw().iter().map(|&v| v as f32 as f64).collect();
    Dataset::new(ds.name.clone(), rounded, n, d).unwrap()
}

fn modes() -> Vec<OocMode> {
    let mut modes = vec![OocMode::Chunked];
    if eakm::data::ooc::mmap_supported() {
        modes.push(OocMode::Mmap);
    }
    modes
}

fn bits(c: &[f64]) -> Vec<u64> {
    c.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn resident_f32_fit_is_bit_identical_to_f64() {
    let mem = f32_exact_blobs(1_400, 5, 6, 21);
    let f32set = DatasetF32::from_dataset(&mem).unwrap();
    for alg in [Algorithm::Sta, Algorithm::ExpNs] {
        for threads in [1usize, 2, 4, 8] {
            let cfg = RunConfig::new(alg, 6).seed(7).threads(threads);
            let want = Runner::new(&cfg).run(&mem).unwrap();
            let got = Runner::new(&cfg).run(&f32set).unwrap();
            assert_eq!(got.assignments, want.assignments, "{alg} t={threads}");
            assert_eq!(got.mse.to_bits(), want.mse.to_bits(), "{alg} t={threads}");
            assert_eq!(got.counters, want.counters, "{alg} t={threads}");
            assert_eq!(got.iterations, want.iterations);
            assert_eq!(bits(&got.centroids), bits(&want.centroids));
        }
    }
}

#[test]
fn f32_file_runs_are_bit_identical_to_resident_f32() {
    let mem = f32_exact_blobs(1_200, 4, 6, 33);
    let f32set = DatasetF32::from_dataset(&mem).unwrap();
    let path = tmpdir().join("store.ekb");
    io::save_bin_f32(&mem, &path).unwrap();
    for threads in [1usize, 2, 8] {
        let cfg = RunConfig::new(Algorithm::ExpNs, 6).seed(5).threads(threads);
        let want = Runner::new(&cfg).run(&f32set).unwrap();
        for mode in modes() {
            let src = open_ooc(&path, mode, 128).unwrap();
            let got = Runner::new(&cfg).run(&*src).unwrap();
            assert_eq!(got.assignments, want.assignments, "{mode} t={threads}");
            assert_eq!(got.mse.to_bits(), want.mse.to_bits(), "{mode} t={threads}");
            assert_eq!(got.counters, want.counters, "{mode} t={threads}");
            assert_eq!(bits(&got.centroids), bits(&want.centroids));
            // the file run reports I/O at storage width
            assert!(got.report.io.expect("file run reports I/O").bytes_read > 0);
        }
    }
}

#[test]
fn predict_labels_are_identical_across_widths() {
    let train = f32_exact_blobs(1_000, 6, 5, 41);
    let queries = f32_exact_blobs(600, 6, 5, 42);
    let q32 = DatasetF32::from_dataset(&queries).unwrap();
    for threads in [1usize, 4] {
        let rt = Runtime::new(threads);
        let model = Kmeans::new(5)
            .algorithm(Algorithm::ExpNs)
            .seed(3)
            .fit(&rt, &train)
            .unwrap();
        let want = model.predict(&rt, &queries).unwrap();
        let got = model.predict(&rt, &q32).unwrap();
        assert_eq!(got, want, "t={threads}");
    }
}

#[test]
fn general_data_agrees_to_documented_tolerances() {
    // not pre-rounded: narrowing perturbs every value by ≤ half an f32
    // ulp, so labels can legitimately flip on near-ties. The lib.rs
    // contract pins ≥ 99% agreement and relative MSE within 1e-3.
    let mem = eakm::data::synth::blobs(2_000, 6, 8, 0.25, 55);
    let f32set = DatasetF32::from_dataset(&mem).unwrap();
    let cfg = RunConfig::new(Algorithm::Sta, 8).seed(9).threads(2);
    let want = Runner::new(&cfg).run(&mem).unwrap();
    let got = Runner::new(&cfg).run(&f32set).unwrap();
    let agree = got
        .assignments
        .iter()
        .zip(&want.assignments)
        .filter(|(a, b)| a == b)
        .count();
    assert!(
        agree as f64 >= 0.99 * want.assignments.len() as f64,
        "label agreement {agree}/{}",
        want.assignments.len()
    );
    let rel = (got.mse - want.mse).abs() / want.mse.max(f64::MIN_POSITIVE);
    assert!(rel < 1e-3, "relative MSE diff {rel}");
}

#[test]
fn f32_sources_honour_the_block_lease_contract() {
    let mem = f32_exact_blobs(700, 5, 4, 61);
    let f32set = DatasetF32::from_dataset(&mem).unwrap();
    eakm::algorithms::testutil::assert_block_lease_contract(&f32set, 17);
    let path = tmpdir().join("contract.ekb");
    io::save_bin_f32(&mem, &path).unwrap();
    for mode in modes() {
        let src = open_ooc(&path, mode, 96).unwrap();
        eakm::algorithms::testutil::assert_block_lease_contract(&*src, 18);
    }
}
