//! Integration: the AOT-compiled JAX/Pallas artifact, executed from Rust
//! through PJRT, must agree with the native Rust distance path — this is
//! the three-layer composition check (L1 Pallas → L2 JAX → HLO → L3 Rust).
//!
//! Requires `make artifacts` and the `xla` feature (the external `xla`
//! crate is not available in the offline build); tests fail with a clear
//! message otherwise.
#![cfg(feature = "xla")]

use std::path::PathBuf;

use eakm::data::synth::blobs;
use eakm::linalg::{sqdist, top2};
use eakm::runtime::{ArtifactSpec, XlaAssignBackend};

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn native_assign(xs: &[f64], cs: &[f64], d: usize, k: usize) -> (Vec<u32>, Vec<f64>, Vec<f64>) {
    let m = xs.len() / d;
    let mut idx = Vec::with_capacity(m);
    let mut d1 = Vec::with_capacity(m);
    let mut d2 = Vec::with_capacity(m);
    for i in 0..m {
        let row: Vec<f64> = (0..k)
            .map(|j| sqdist(&xs[i * d..(i + 1) * d], &cs[j * d..(j + 1) * d]).sqrt())
            .collect();
        let t = top2(&row);
        idx.push(t.idx1 as u32);
        d1.push(t.val1);
        d2.push(t.val2);
    }
    (idx, d1, d2)
}

fn check_spec(spec: ArtifactSpec, m: usize, seed: u64) {
    let dir = artifact_dir();
    let mut backend = XlaAssignBackend::load(&dir, spec)
        .expect("artifact missing — run `make artifacts` first");
    let ds = blobs(m, spec.d, spec.k.min(8), 0.3, seed);
    let cs = blobs(spec.k, spec.d, spec.k.min(8), 0.3, seed + 1);
    let out = backend.assign(ds.raw(), cs.raw()).expect("assign failed");
    let (ni, nd1, nd2) = native_assign(ds.raw(), cs.raw(), spec.d, spec.k);
    assert_eq!(out.idx.len(), m);
    let mut mismatched_idx = 0;
    for i in 0..m {
        // indices may differ only under exact distance ties (none in
        // continuous random data)
        if out.idx[i] != ni[i] {
            mismatched_idx += 1;
        }
        assert!(
            (out.d1[i] - nd1[i]).abs() < 1e-8 * (1.0 + nd1[i]),
            "sample {i}: xla d1={} native={}",
            out.d1[i],
            nd1[i]
        );
        assert!(
            (out.d2[i] - nd2[i]).abs() < 1e-8 * (1.0 + nd2[i]),
            "sample {i}: xla d2={} native={}",
            out.d2[i],
            nd2[i]
        );
    }
    assert_eq!(mismatched_idx, 0, "arg-min disagreement");
}

#[test]
fn small_artifact_matches_native() {
    check_spec(
        ArtifactSpec {
            block: 16,
            d: 3,
            k: 4,
        },
        64,
        7,
    );
}

#[test]
fn medium_artifact_matches_native() {
    check_spec(
        ArtifactSpec {
            block: 64,
            d: 4,
            k: 16,
        },
        256,
        11,
    );
}

#[test]
fn production_artifact_matches_native_with_padding() {
    // 300 is not a multiple of 256 → exercises the tail-block padding
    check_spec(
        ArtifactSpec {
            block: 256,
            d: 8,
            k: 50,
        },
        300,
        13,
    );
}

#[test]
fn lloyd_artifact_runs_and_descends() {
    use eakm::runtime::PjrtRuntime;
    let path = artifact_dir().join("lloyd_5r_512x8x50.hlo.txt");
    assert!(path.exists(), "run `make artifacts` first");
    let mut rt = PjrtRuntime::cpu().unwrap();
    let ds = blobs(512, 8, 10, 0.2, 5);
    let cs: Vec<f64> = ds.raw()[..50 * 8].to_vec();
    let exe = rt.load(&path).unwrap();
    let outputs =
        PjrtRuntime::execute_f64(exe, &[(ds.raw(), &[512, 8]), (&cs, &[50, 8])]).unwrap();
    assert_eq!(outputs.len(), 2); // (centroids, assignments)
    let new_c = &outputs[0];
    let idx = &outputs[1];
    assert_eq!(new_c.len(), 50 * 8);
    assert_eq!(idx.len(), 512);
    // 5 Lloyd rounds must not increase the objective vs the init state
    let mse_init = ds.mse(&cs, &(0..512).map(|i| {
        let row: Vec<f64> = (0..50)
            .map(|j| sqdist(ds.row(i), &cs[j * 8..(j + 1) * 8]))
            .collect();
        eakm::linalg::argmin(&row).unwrap() as u32
    }).collect::<Vec<_>>());
    let assigns: Vec<u32> = idx.iter().map(|&v| v as u32).collect();
    let mse_after = ds.mse(new_c, &assigns);
    assert!(
        mse_after <= mse_init + 1e-9,
        "lloyd artifact increased objective: {mse_init} → {mse_after}"
    );
}
