//! Integration: §4's exactness claim at system level — every algorithm,
//! on several paper-like datasets, converges in the same number of
//! rounds to the same assignments and objective as `sta`.

use eakm::algorithms::Algorithm;
use eakm::config::RunConfig;
use eakm::coordinator::Runner;
use eakm::data::synth::{find, generate};
use eakm::data::Dataset;

fn check_all(ds: &Dataset, k: usize, seed: u64) {
    let reference = Runner::new(&RunConfig::new(Algorithm::Sta, k).seed(seed))
        .run(ds)
        .unwrap();
    assert!(reference.converged, "sta failed to converge");
    for alg in Algorithm::ALL {
        if alg == Algorithm::Sta {
            continue;
        }
        let out = Runner::new(&RunConfig::new(alg, k).seed(seed)).run(ds).unwrap();
        assert_eq!(
            out.iterations, reference.iterations,
            "{alg} iterations differ on {} (k={k}, seed={seed})",
            ds.name
        );
        assert_eq!(
            out.assignments, reference.assignments,
            "{alg} assignments differ on {} (k={k}, seed={seed})",
            ds.name
        );
        let rel = (out.mse - reference.mse).abs() / reference.mse.max(1e-300);
        assert!(rel < 1e-9, "{alg} mse differs: {} vs {}", out.mse, reference.mse);
    }
}

#[test]
fn all_algorithms_agree_on_low_d() {
    // birch-like: d=2 grid gaussians — Exponion's home turf
    let ds = generate(&find("birch").unwrap(), 0.02, 1);
    check_all(&ds, 20, 0);
}

#[test]
fn all_algorithms_agree_on_mid_d() {
    let ds = generate(&find("colormoments").unwrap(), 0.03, 2);
    check_all(&ds, 30, 1);
}

#[test]
fn all_algorithms_agree_on_high_d() {
    let ds = generate(&find("gassensor").unwrap(), 0.1, 3);
    check_all(&ds, 15, 2);
}

#[test]
fn all_algorithms_agree_on_uniform_data() {
    // uniform random: worst case for bounds — most bound repairs
    let ds = generate(&find("urand2").unwrap(), 0.002, 4);
    check_all(&ds, 25, 3);
}

#[test]
fn all_algorithms_agree_with_kmeanspp_seeding() {
    use eakm::init::InitMethod;
    let ds = generate(&find("mv").unwrap(), 0.05, 5);
    let k = 12;
    let cfg = |alg| {
        RunConfig::new(alg, k)
            .seed(9)
            .init(InitMethod::KmeansPlusPlus)
    };
    let reference = Runner::new(&cfg(Algorithm::Sta)).run(&ds).unwrap();
    for alg in [Algorithm::ExpNs, Algorithm::SyinNs, Algorithm::SelkNs] {
        let out = Runner::new(&cfg(alg)).run(&ds).unwrap();
        assert_eq!(out.assignments, reference.assignments, "{alg}");
        assert_eq!(out.iterations, reference.iterations, "{alg}");
    }
}

#[test]
fn degenerate_duplicate_points() {
    // many duplicate points: tie-heavy, empty clusters likely
    let mut data = vec![0.0; 100 * 2];
    for i in 0..100 {
        data[i * 2] = (i % 5) as f64;
        data[i * 2 + 1] = ((i / 5) % 2) as f64;
    }
    let ds = Dataset::new("dups", data, 100, 2).unwrap();
    // exactness across the ham family still holds because ties resolve
    // to the lowest index in every implementation
    let k = 10;
    let r = Runner::new(&RunConfig::new(Algorithm::Sta, k).seed(1))
        .run(&ds)
        .unwrap();
    for alg in [Algorithm::Ham, Algorithm::Exp, Algorithm::Selk, Algorithm::Syin] {
        let out = Runner::new(&RunConfig::new(alg, k).seed(1)).run(&ds).unwrap();
        assert!(out.converged);
        let rel = (out.mse - r.mse).abs() / r.mse.max(1e-12);
        assert!(rel < 1e-9, "{alg} objective differs on duplicate data");
    }
}

#[test]
fn k_equals_n_is_perfect_clustering() {
    let ds = generate(&find("mv").unwrap(), 0.03, 6);
    let n = ds.n().min(64);
    let small = Dataset::new("head", ds.raw()[..n * ds.d()].to_vec(), n, ds.d()).unwrap();
    let out = Runner::new(&RunConfig::new(Algorithm::Exp, n).seed(2))
        .run(&small)
        .unwrap();
    assert!(out.converged);
    assert!(out.mse < 1e-18, "k=n must give zero objective, got {}", out.mse);
}

#[test]
fn d_equals_one() {
    let mut data: Vec<f64> = (0..200).map(|i| (i as f64 * 0.37).sin() * 5.0).collect();
    data.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ds = Dataset::new("line", data, 200, 1).unwrap();
    check_all(&ds, 8, 4);
}

/// The pool runtime's determinism guarantee: assignments, MSE, and the
/// distance counters must be *identical* — MSE to the bit — at every
/// thread count, for every algorithm.
#[test]
fn cross_thread_determinism_all_algorithms() {
    use eakm::data::synth::blobs;
    let ds = blobs(800, 5, 10, 0.25, 11);
    let k = 10;
    for alg in Algorithm::ALL {
        let base = Runner::new(&RunConfig::new(alg, k).seed(6).threads(1))
            .run(&ds)
            .unwrap();
        for threads in [2, 8] {
            let out = Runner::new(&RunConfig::new(alg, k).seed(6).threads(threads))
                .run(&ds)
                .unwrap();
            assert_eq!(out.assignments, base.assignments, "{alg} @ {threads}T");
            assert_eq!(out.iterations, base.iterations, "{alg} @ {threads}T");
            assert_eq!(out.counters, base.counters, "{alg} @ {threads}T");
            assert_eq!(
                out.mse.to_bits(),
                base.mse.to_bits(),
                "{alg} @ {threads}T: mse not bit-identical"
            );
        }
    }
}

/// Same guarantee on a dataset large enough to force the *chunked*
/// partial-sum reduction paths in the update step (n and the early-round
/// move counts both exceed one reduction chunk).
#[test]
fn cross_thread_determinism_chunked_update_paths() {
    use eakm::data::synth::blobs;
    let ds = blobs(6_000, 4, 16, 0.6, 13);
    let k = 16;
    for alg in [Algorithm::Sta, Algorithm::ExpNs, Algorithm::SyinNs] {
        let cfg = |t: usize| RunConfig::new(alg, k).seed(2).threads(t).max_iters(40);
        let base = Runner::new(&cfg(1)).run(&ds).unwrap();
        for threads in [2, 8] {
            let out = Runner::new(&cfg(threads)).run(&ds).unwrap();
            assert_eq!(out.assignments, base.assignments, "{alg} @ {threads}T");
            assert_eq!(out.counters, base.counters, "{alg} @ {threads}T");
            assert_eq!(out.mse.to_bits(), base.mse.to_bits(), "{alg} @ {threads}T");
        }
    }
}
