//! Spawned-binary smoke for the distributed fit: real `eakm shardd`
//! processes (not in-process servers) plus `eakm run --shards` must
//! reproduce `eakm run --ooc` on the same `.ekb` file exactly — the
//! CLI plumbing (flag parsing, shard startup banner, report JSON) is
//! exercised end-to-end the way an operator would drive it.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStderr, Command, Stdio};

use eakm::data::io;
use eakm::json::Json;

fn eakm() -> Command {
    Command::new(env!("CARGO_BIN_EXE_eakm"))
}

/// A running `eakm shardd` child, killed on drop. The stderr pipe is
/// held open for the shard's lifetime so later diagnostics never hit a
/// closed descriptor.
struct ShardProc {
    child: Child,
    _stderr: BufReader<ChildStderr>,
}

impl Drop for ShardProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn `eakm shardd` on an ephemeral port and parse the bound
/// address out of its startup banner:
/// `[shard serving rows LO..HI of FILE on ADDR]`.
fn spawn_shard(path: &Path, lo: usize, hi: usize) -> (ShardProc, String) {
    let mut child = eakm()
        .args([
            "shardd",
            "--data",
            path.to_str().unwrap(),
            "--rows",
            &format!("{lo}..{hi}"),
            "--addr",
            "127.0.0.1:0",
            "--threads",
            "2",
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let mut stderr = BufReader::new(child.stderr.take().unwrap());
    let mut banner = String::new();
    stderr.read_line(&mut banner).unwrap();
    let addr = banner
        .rsplit(" on ")
        .next()
        .unwrap()
        .trim()
        .trim_end_matches(']')
        .to_string();
    assert!(
        addr.starts_with("127.0.0.1:"),
        "unexpected shardd banner: {banner:?}"
    );
    (
        ShardProc {
            child,
            _stderr: stderr,
        },
        addr,
    )
}

/// Run the binary, require success, and parse its stdout as JSON.
fn run_json(args: &[&str]) -> Json {
    let out = eakm().args(args).output().unwrap();
    assert!(
        out.status.success(),
        "eakm {args:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    Json::parse(std::str::from_utf8(&out.stdout).unwrap().trim()).unwrap()
}

#[test]
fn real_shardd_processes_match_single_node_run() {
    let dir = std::env::temp_dir().join(format!("eakm-dist-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path: PathBuf = dir.join("cli.ekb");
    let ds = eakm::data::synth::blobs(1_200, 4, 6, 0.25, 19);
    io::save_bin(&ds, &path).unwrap();

    let (_s0, a0) = spawn_shard(&path, 0, 600);
    let (_s1, a1) = spawn_shard(&path, 600, 1_200);

    // `--ooc` reads the file as-is, exactly like the shard data plane
    let single = run_json(&[
        "run",
        "--data-file",
        path.to_str().unwrap(),
        "--ooc",
        "chunked",
        "--k",
        "6",
        "--algorithm",
        "exp-ns",
        "--seed",
        "7",
        "--threads",
        "2",
        "--json",
    ]);
    let dist = run_json(&[
        "run",
        "--shards",
        &format!("{a0},{a1}"),
        "--k",
        "6",
        "--algorithm",
        "exp-ns",
        "--seed",
        "7",
        "--threads",
        "2",
        "--json",
    ]);

    for key in [
        "mse",
        "iterations",
        "converged",
        "q_a",
        "q_centroid",
        "q_displacement",
        "q_init",
    ] {
        let s = single.get(key).unwrap_or(&Json::Null).to_string();
        let d = dist.get(key).unwrap_or(&Json::Null).to_string();
        assert_eq!(s, d, "report field {key:?} diverged");
    }
    let leased = dist.get("io_blocks_leased").and_then(Json::as_f64);
    assert!(leased.unwrap_or(0.0) > 0.0, "dist run must report I/O");
}
