//! # eakm — Fast exact k-means with accurate bounds
//!
//! A Rust + JAX + Pallas reproduction of *"Fast K-Means with Accurate
//! Bounds"* (Newling & Fleuret, ICML 2016).
//!
//! The crate implements every algorithm the paper evaluates, behind a
//! single [`coordinator::Runner`]:
//!
//! | name      | description                                            |
//! |-----------|--------------------------------------------------------|
//! | `sta`     | standard Lloyd's algorithm                             |
//! | `selk`    | simplified Elkan (k lower bounds, no centroid tests)   |
//! | `elk`     | Elkan 2003 (adds inter-centroid tests)                 |
//! | `ham`     | Hamerly 2010 (single lower bound, outer test)          |
//! | `ann`     | Drake 2013 Annular (origin-centred norm annulus)       |
//! | `exp`     | **Exponion** (this paper §3.1): centroid-centred ball  |
//! | `syin`    | simplified Yinyang (group bounds, no local filter)     |
//! | `yin`     | Yinyang (Ding et al. 2015, with local filter)          |
//! | `*-ns`    | ns-bound variants (this paper §3.2) of selk/elk/syin/exp |
//!
//! All algorithms are *exact*: from the same seed they produce the same
//! per-round assignments as Lloyd's algorithm; they differ only in how
//! many point-to-centroid distances they evaluate. The distance-evaluation
//! counters ([`metrics::Counters`]) are first-class outputs and drive the
//! reproduction of the paper's tables.
//!
//! ## Service API
//!
//! The public surface is fit-once / predict-many, built from three
//! pieces:
//!
//! * [`runtime::Runtime`] — owns the persistent worker pool; create one
//!   per process and share it across every fit and predict;
//! * [`model::Kmeans`] — fluent fit configuration;
//! * [`model::FittedModel`] — the owned result: centroids + telemetry,
//!   with [`predict`](model::FittedModel::predict) for new points and
//!   JSON [`save`](model::FittedModel::save) /
//!   [`load`](model::FittedModel::load) so models survive restarts.
//!
//! ```
//! use eakm::prelude::*;
//!
//! let rt = Runtime::new(2); // or Runtime::auto()
//! let data = eakm::data::synth::blobs(2_000, 4, 10, 0.05, 42);
//! let model = Kmeans::new(10)
//!     .algorithm(Algorithm::ExpNs)
//!     .seed(7)
//!     .fit(&rt, &data)
//!     .unwrap();
//! assert!(model.report().iterations >= 1);
//! let queries = eakm::data::synth::blobs(100, 4, 10, 0.05, 43);
//! let labels = model.predict(&rt, &queries).unwrap(); // same pool, no respawn
//! assert_eq!(labels.len(), 100);
//! ```
//!
//! The lower-level [`coordinator::Runner`] / [`coordinator::Engine`]
//! remain available (benches and tests inspect rounds through them),
//! and `Runner::new(&cfg).run(&data)` still works as a one-shot shim.
//!
//! ## Serving
//!
//! [`serve`](crate::serve) turns a fitted model into a **long-lived
//! network service**: a dependency-free blocking TCP server speaking
//! two protocols on one port, sniffed per connection — line-delimited
//! JSON (`predict` / `nearest` / `bulk_predict` / `stats` / `reload` /
//! `shutdown`) and an HTTP/1.1 shim ([`serve::http`]) mapping
//! `POST /v1/predict` &co. plus `GET /v1/stats` / `GET /v1/healthz`
//! onto the same ops, so `curl` works out of the box. N acceptor
//! threads feed a *bounded* request queue (overflow gets a typed
//! `overloaded` reply — backpressure, not unbounded queueing; see
//! `ServeConfig::queue_depth` for when each layer binds), a
//! **micro-batcher** coalesces concurrent predict requests into one
//! pool-sharded [`predict_rows`](model::FittedModel::predict_rows)
//! scan on the shared [`Runtime`](runtime::Runtime) — answers stay
//! bit-identical to direct `predict` at any thread width and batch
//! boundary — and a `Mutex<Arc<FittedModel>>` state cell gives
//! zero-downtime model reloads. In front of everything,
//! [`serve::admission`] applies per-client token-bucket rate limiting
//! and a consecutive-failure circuit breaker with typed
//! `rate_limited` / `breaker_open` rejections (HTTP 429/503 +
//! `Retry-After`); `bulk_predict` streams labels for a whole on-disk
//! `.ekb` file through [`model::FittedModel::predict_blocks`] with
//! bounded memory. Request bytes are untrusted, so the [`json`]
//! parser runs under [`json::ParseLimits::network`] (payload and
//! nesting caps with typed errors). Serving telemetry (requests per
//! protocol, batched rows, coalesced batches, queue-full / admission
//! rejects, bulk blocks, per-op latency sums) is live through the
//! `stats` op and summarised on clean shutdown. The CLI front-end is
//! `eakm serve --model model.json` (or fit-then-serve straight from
//! `--dataset`/`--data-file`/`--ooc`, with the same data flags as
//! `run`).
//!
//! ```
//! use std::sync::mpsc;
//! use eakm::prelude::*;
//! use eakm::serve::{client, serve, Client, ServeConfig};
//!
//! let (tx, rx) = mpsc::channel();
//! std::thread::spawn(move || {
//!     let rt = Runtime::new(1);
//!     let data = eakm::data::synth::blobs(400, 3, 4, 0.05, 42);
//!     let model = Kmeans::new(4).seed(7).fit(&rt, &data).unwrap();
//!     let cfg = ServeConfig {
//!         addr: "127.0.0.1:0".into(), // ephemeral port
//!         ..ServeConfig::default()
//!     };
//!     serve(&rt, model, &cfg, move |addr| tx.send(addr).unwrap()).unwrap();
//! });
//! let mut c = Client::connect(rx.recv().unwrap()).unwrap();
//! let reply = c.call(&client::predict_request(&[0.1, 0.2, 0.3], 3)).unwrap();
//! assert_eq!(reply.get("ok").and_then(|v| v.as_bool()), Some(true));
//! c.call(&client::shutdown_request()).unwrap();
//! ```
//!
//! ## Distributed fit
//!
//! [`dist`] splits a fit across shard servers without changing a
//! single result bit. Each shard (`eakm shardd --data file.ekb --rows
//! lo..hi --addr host:port`) owns one global row range of an `.ekb`
//! file and serves two planes over a dependency-free length-prefixed
//! binary protocol (framing shared with [`serve`](crate::serve) via
//! [`net::frame`]): a **data plane** streaming row blocks plus
//! sidecar-exact norms, and a **compute plane** running the local
//! assignment scan per round. On top of them sit [`data::NetSource`]
//! — a [`data::DataSource`] over the data plane, so every existing
//! algorithm (mini-batch included) fits over the network unchanged —
//! and the coordinator (`eakm run --shards host:port,host:port`,
//! [`dist::run_dist`]), which seeds locally, broadcasts centroids each
//! round, and merges shard replies in shard order. Assignments, MSE
//! bits, and bound counters are **bit-identical to the single-node run
//! at any shard count and any thread width** — the determinism
//! argument is spelled out in [`dist`]'s module docs — and a dead
//! shard surfaces as a typed [`error::EakmError::Net`] naming the
//! shard, never a hang.
//!
//! ## Observability
//!
//! [`obs`] is the unified observability layer: a dependency-free
//! metrics [`obs::Registry`] (counters, gauges, and log-bucketed
//! latency histograms with exact deterministic merges) rendered in
//! Prometheus text format — `GET /metrics` on the serve HTTP shim and
//! on `eakm shardd`'s metrics listener — plus [`obs::TraceId`]s minted
//! at the front door and propagated over the dist wire, and a bounded
//! [`obs::EventLog`] of structured per-round fit events and serve
//! lifecycle events, drained via `GET /v1/events?since=` or streamed
//! with `eakm run --progress`. Observation never perturbs results:
//! every bit-identity and determinism test passes with instrumentation
//! enabled.
//!
//! ## Parallel runtime
//!
//! Every phase of a round — the sharded assignment scan, the delta
//! centroid update, and all centroid-side per-round builds
//! (inter-centroid matrix, annuli, group maxima, ns history) — runs on
//! one persistent [`runtime::pool::WorkerPool`] (spawned once, parked
//! between dispatches), shared across runs via [`runtime::Runtime`].
//! Reductions merge in shard/chunk order with geometry independent of
//! the thread count, so assignments, MSE, and counters are
//! **bit-identical** for any width (including `Runtime::auto()`).
//! [`metrics::RunReport`] carries a per-phase wall-time decomposition
//! (`scan` / `update` / `build`) so multicore speedup can be attributed.
//!
//! ## Data access: the block-lease seam
//!
//! Sample rows are read through the [`data::DataSource`] trait's
//! **block-lease contract**: every pool worker
//! [`open`](data::DataSource::open)s a [`data::BlockCursor`] for its
//! shard and advances block by block; each leased [`data::RowBlock`]
//! (rows + pre-computed squared norms) is valid until the next lease.
//! The contract exists because a borrow-returning `rows(lo, len)`
//! cannot be served by a source that refills a resident window — and
//! with it, *where the rows live* becomes an implementation detail:
//!
//! * [`data::Dataset`] — in memory; leases are zero-copy slices;
//! * [`data::BatchView`] — a seeded, sampled view (the mini-batch
//!   engine's data layer), gather-backed, same zero-copy leases;
//! * [`data::ooc`] — **out-of-core**: `MmapSource` (page-cache-backed
//!   `.ekb` mapping) and [`data::ChunkedFileSource`] (buffered reads,
//!   one resident window per worker, `--ooc-window` rows each), both
//!   with a `.norms` sidecar so squared norms are computed once per
//!   file. Runs off a file are **bit-identical** to in-memory runs at
//!   any thread count — for the exact and mini-batch engines — and
//!   report I/O telemetry (blocks leased, bytes read, window refills)
//!   in [`metrics::RunReport::io`]. The CLI reaches them with
//!   `run`/`predict` `--ooc auto|mmap|chunked` on an `.ekb` path,
//!   clustering datasets larger than RAM without loading them.
//!
//! The seam's invariants (lease stability, norms matching rows, shard
//! coverage) are enforced for every implementation by one property
//! harness, [`algorithms::testutil::assert_block_lease_contract`].
//!
//! ## Mixed precision
//!
//! Storage width and accumulation width are separate decisions. Every
//! kernel in [`linalg`] accumulates in `f64`, always; what's opt-in is
//! storing the *samples* at `f32` — half the memory footprint and half
//! the streamed bandwidth on bandwidth-bound scans:
//!
//! * [`data::DatasetF32`] — resident rows stored `f32`, widened to
//!   `f64` into a per-cursor scratch buffer at lease time, so the
//!   block-lease contract (and every algorithm above it) is unchanged;
//! * [`data::io::save_bin_f32`] writes the `.ekb` **v2** container
//!   (header gains an element-width field; v1 files remain readable),
//!   and both out-of-core sources stream/map either width, widening at
//!   the same boundary — I/O telemetry reports the halved storage
//!   bytes;
//! * the CLI opts in with `run --storage f32` (in-memory sources only;
//!   a file's width comes from its header).
//!
//! The `.norms` sidecar and all in-memory squared norms stay `f64`,
//! computed from the widened values by the same
//! [`sqnorm`](linalg::sqnorm) kernel every source shares. Consequence:
//! on data whose values are exactly f32-representable (anything loaded
//! from an f32 file), an f32-storage fit is **bit-identical** to the
//! f64 fit — same assignments, same MSE bits, same counters, at any
//! thread count. On general f64 data, narrowing rounds each value to
//! nearest-even once at ingest; labels and MSE then agree to rounding
//! (the test suite pins ≥ 99% label agreement and relative MSE within
//! `1e-3` on clustered synthetic data), and determinism still holds
//! bit-for-bit *within* the f32 pipeline.
//!
//! ## Mini-batch engine
//!
//! For latency-bounded refinement (the serving story), a fit can run on
//! sampled batches instead of full scans:
//! [`Kmeans::batch_size`](model::Kmeans::batch_size) sets the rows per
//! round and [`Kmeans::batch_growth`](model::Kmeans::batch_growth) the
//! schedule — a factor > 1 grows one *nested* batch (old batch ⊂ new
//! batch, doubling by default, Newling & Fleuret 2016b) until it covers
//! the dataset and the run converges to the exact Lloyd fixed point; a
//! factor of exactly 1 redraws a fresh batch every round
//! (Sculley-style) and refines until `max_iters` or the `time_limit`.
//! A batch size covering the whole dataset runs the exact engine
//! unchanged. Each round drives the standard assignment/update phases
//! through the [`coordinator::Engine`] over a
//! [`data::BatchView`], so a seeded mini-batch fit keeps the pool's
//! guarantee: **bit-identical at any thread count**.
//! [`metrics::RunReport`] records the realised batch schedule, and
//! [`model::FittedModel`] persistence round-trips the mini-batch
//! configuration. The CLI exposes the same knobs as
//! `run --batch-size B [--batch-growth F]`.
//!
//! The dense-compute hot spot (blocked pairwise distances + top-2
//! reduction) is additionally available as an AOT-compiled XLA artifact
//! authored in JAX/Pallas (see `python/compile/`) and executed through the
//! PJRT C API from [`runtime`] — Python never runs at clustering time
//! (off by default behind the `xla` feature; the external `xla` crate is
//! unavailable offline).

#![warn(missing_docs)]

pub mod error;
pub mod rng;
pub mod linalg;
pub mod data;
pub mod init;
pub mod metrics;
pub mod algorithms;
pub mod coordinator;
pub mod runtime;
pub mod config;
pub mod model;
pub mod net;
pub mod obs;
pub mod serve;
pub mod dist;
pub mod bench_support;
pub mod json;
pub mod cli;
pub mod proptest;

/// Convenient re-exports for downstream users.
pub mod prelude {
    pub use crate::algorithms::Algorithm;
    pub use crate::config::RunConfig;
    pub use crate::coordinator::{Runner, RunOutput};
    pub use crate::data::dataset::Dataset;
    pub use crate::data::DataSource;
    pub use crate::init::InitMethod;
    pub use crate::metrics::{Counters, RunReport};
    pub use crate::model::{FittedModel, Kmeans};
    pub use crate::runtime::Runtime;
    pub use crate::serve::{serve, ServeConfig, ServeStats};
}
