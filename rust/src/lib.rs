//! # eakm — Fast exact k-means with accurate bounds
//!
//! A Rust + JAX + Pallas reproduction of *"Fast K-Means with Accurate
//! Bounds"* (Newling & Fleuret, ICML 2016).
//!
//! The crate implements every algorithm the paper evaluates, behind a
//! single [`coordinator::Runner`]:
//!
//! | name      | description                                            |
//! |-----------|--------------------------------------------------------|
//! | `sta`     | standard Lloyd's algorithm                             |
//! | `selk`    | simplified Elkan (k lower bounds, no centroid tests)   |
//! | `elk`     | Elkan 2003 (adds inter-centroid tests)                 |
//! | `ham`     | Hamerly 2010 (single lower bound, outer test)          |
//! | `ann`     | Drake 2013 Annular (origin-centred norm annulus)       |
//! | `exp`     | **Exponion** (this paper §3.1): centroid-centred ball  |
//! | `syin`    | simplified Yinyang (group bounds, no local filter)     |
//! | `yin`     | Yinyang (Ding et al. 2015, with local filter)          |
//! | `*-ns`    | ns-bound variants (this paper §3.2) of selk/elk/syin/exp |
//!
//! All algorithms are *exact*: from the same seed they produce the same
//! per-round assignments as Lloyd's algorithm; they differ only in how
//! many point-to-centroid distances they evaluate. The distance-evaluation
//! counters ([`metrics::Counters`]) are first-class outputs and drive the
//! reproduction of the paper's tables.
//!
//! ## Parallel runtime
//!
//! Each [`coordinator::Engine`] owns a persistent
//! [`runtime::pool::WorkerPool`] (spawned once, parked between rounds)
//! and dispatches *every* phase of a round onto it: the sharded
//! assignment scan, the delta centroid update, and all centroid-side
//! per-round builds (inter-centroid matrix, annuli, group maxima, ns
//! history). Reductions merge in shard/chunk order with geometry
//! independent of the thread count, so assignments, MSE, and counters
//! are **bit-identical** for any `threads` setting (including
//! `threads = auto`, which resolves to the machine's available
//! parallelism). [`metrics::RunReport`] carries a per-phase wall-time
//! decomposition (`scan` / `update` / `build`) so multicore speedup can
//! be attributed.
//!
//! The dense-compute hot spot (blocked pairwise distances + top-2
//! reduction) is additionally available as an AOT-compiled XLA artifact
//! authored in JAX/Pallas (see `python/compile/`) and executed through the
//! PJRT C API from [`runtime`] — Python never runs at clustering time
//! (off by default behind the `xla` feature; the external `xla` crate is
//! unavailable offline).
//!
//! ## Quickstart
//!
//! ```no_run
//! use eakm::prelude::*;
//!
//! let data = eakm::data::synth::blobs(10_000, 8, 50, 0.05, 42);
//! let cfg = RunConfig::new(Algorithm::ExpNs, 50).seed(7);
//! let out = Runner::new(&cfg).run(&data).unwrap();
//! println!("iters={} mse={:.5}", out.iterations, out.mse);
//! ```

pub mod error;
pub mod rng;
pub mod linalg;
pub mod data;
pub mod init;
pub mod metrics;
pub mod algorithms;
pub mod coordinator;
pub mod runtime;
pub mod config;
pub mod bench_support;
pub mod json;
pub mod cli;
pub mod proptest;

/// Convenient re-exports for downstream users.
pub mod prelude {
    pub use crate::algorithms::Algorithm;
    pub use crate::config::RunConfig;
    pub use crate::coordinator::{Runner, RunOutput};
    pub use crate::data::dataset::Dataset;
    pub use crate::init::InitMethod;
    pub use crate::metrics::Counters;
}
