//! Coordinator-side connection to one shard server.
//!
//! [`ShardConn`] wraps one TCP connection with the dist framing and the
//! failure policy the coordinator needs: every transport outcome —
//! connect refused, read timed out, peer closed, bogus frame, or an
//! `ERR` reply — becomes a typed [`EakmError::Net`] *naming the shard
//! address*, so a multi-node failure is attributable from the error
//! alone. Connects retry with a short backoff (shards may still be
//! binding when the coordinator starts); established-connection
//! failures do not retry here — the compute plane surfaces them (a dead
//! shard fails the fit) and the data plane's cursor reconnects at its
//! own layer.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::error::{EakmError, Result};
use crate::net::frame::{send_frame, Frame, FrameReader};

use super::wire::{self, tag};

/// Socket-level read timeout: how often a blocked read wakes so the
/// reply deadline is re-checked.
const READ_POLL: Duration = Duration::from_millis(100);

/// Connect attempts before giving up, with doubling backoff in between.
const CONNECT_TRIES: u32 = 4;
/// First inter-attempt backoff (doubles each retry: 50, 100, 200 ms).
const CONNECT_BACKOFF: Duration = Duration::from_millis(50);

/// One framed connection to a shard server.
pub(crate) struct ShardConn {
    /// The shard's address, verbatim from `--shards` (used in errors).
    pub(crate) addr: String,
    stream: TcpStream,
    reader: FrameReader<TcpStream>,
    /// Reply deadline for [`recv`](ShardConn::recv).
    timeout: Duration,
}

impl ShardConn {
    /// Connect with retry/backoff. `timeout` bounds every subsequent
    /// reply wait.
    pub(crate) fn connect(addr: &str, timeout: Duration) -> Result<ShardConn> {
        let mut backoff = CONNECT_BACKOFF;
        let mut last_err = None;
        for attempt in 0..CONNECT_TRIES {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff *= 2;
            }
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    stream
                        .set_read_timeout(Some(READ_POLL))
                        .map_err(|e| net(addr, format_args!("set read timeout: {e}")))?;
                    let read_half = stream
                        .try_clone()
                        .map_err(|e| net(addr, format_args!("clone stream: {e}")))?;
                    return Ok(ShardConn {
                        addr: addr.to_string(),
                        stream,
                        reader: FrameReader::new(read_half, wire::MAX_FRAME),
                        timeout,
                    });
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(net(
            addr,
            format_args!(
                "connect failed after {CONNECT_TRIES} attempts: {}",
                last_err.expect("at least one attempt")
            ),
        ))
    }

    /// Send one frame.
    pub(crate) fn send(&mut self, tag: u8, body: &[u8]) -> Result<()> {
        if !send_frame(&mut self.stream, tag, body) {
            return Err(net(&self.addr, format_args!("connection closed while sending")));
        }
        Ok(())
    }

    /// Receive one frame, honouring the reply timeout. An `ERR` frame
    /// becomes a typed error carrying the shard's message.
    pub(crate) fn recv(&mut self) -> Result<(u8, Vec<u8>)> {
        let deadline = Instant::now() + self.timeout;
        loop {
            match self.reader.next_frame(deadline.min(Instant::now() + READ_POLL)) {
                Frame::Msg(t, body) => {
                    if t == tag::ERR {
                        return Err(net(
                            &self.addr,
                            format_args!("{}", wire::decode_err(&body)),
                        ));
                    }
                    return Ok((t, body));
                }
                Frame::Idle => {
                    if Instant::now() >= deadline {
                        return Err(net(
                            &self.addr,
                            format_args!("timed out after {:?} waiting for a reply", self.timeout),
                        ));
                    }
                }
                Frame::Eof => {
                    return Err(net(&self.addr, format_args!("connection closed")));
                }
                Frame::TooLong => {
                    return Err(net(&self.addr, format_args!("oversized or malformed frame")));
                }
            }
        }
    }

    /// Send a request and receive its reply, asserting the reply tag.
    pub(crate) fn request(&mut self, req_tag: u8, body: &[u8], want: u8) -> Result<Vec<u8>> {
        self.send(req_tag, body)?;
        let (t, reply) = self.recv()?;
        if t != want {
            return Err(net(
                &self.addr,
                format_args!("unexpected reply tag {t} (wanted {want})"),
            ));
        }
        Ok(reply)
    }
}

/// A typed net error naming the shard.
pub(crate) fn net(addr: &str, msg: std::fmt::Arguments<'_>) -> EakmError {
    EakmError::Net(format!("shard {addr}: {msg}"))
}
