//! Coordinator-side connection to one shard server.
//!
//! [`ShardConn`] wraps one TCP connection with the dist framing and the
//! failure policy the coordinator needs: every transport outcome —
//! connect refused, read timed out, peer closed, bogus frame, or an
//! `ERR` reply — becomes a typed [`EakmError::Net`] *naming the shard
//! address*, so a multi-node failure is attributable from the error
//! alone. Connects retry with a short backoff (shards may still be
//! binding when the coordinator starts); established-connection
//! failures do not retry here — the compute plane surfaces them (a dead
//! shard fails the fit) and the data plane's cursor reconnects at its
//! own layer.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::error::{EakmError, Result};
use crate::net::frame::{send_frame, Frame, FrameReader};

use super::wire::{self, tag, Stats, StatsOk};

/// Socket-level read timeout: how often a blocked read wakes so the
/// reply deadline is re-checked.
const READ_POLL: Duration = Duration::from_millis(100);

/// Connect attempts before giving up, with doubling backoff in between.
const CONNECT_TRIES: u32 = 4;
/// First inter-attempt backoff (doubles each retry: 50, 100, 200 ms).
const CONNECT_BACKOFF: Duration = Duration::from_millis(50);

/// One framed connection to a shard server.
pub(crate) struct ShardConn {
    /// The shard's address, verbatim from `--shards` (used in errors).
    pub(crate) addr: String,
    /// The active fit's trace ID (0 = unset); when set, every typed
    /// error this connection produces carries `[trace <hex>]` so a
    /// shard failure correlates with the fit's events from either end.
    pub(crate) trace: u64,
    stream: TcpStream,
    reader: FrameReader<TcpStream>,
    /// Reply deadline for [`recv`](ShardConn::recv).
    timeout: Duration,
}

impl ShardConn {
    /// Connect with retry/backoff. `timeout` bounds every subsequent
    /// reply wait.
    pub(crate) fn connect(addr: &str, timeout: Duration) -> Result<ShardConn> {
        let mut backoff = CONNECT_BACKOFF;
        let mut last_err = None;
        for attempt in 0..CONNECT_TRIES {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff *= 2;
            }
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    stream
                        .set_read_timeout(Some(READ_POLL))
                        .map_err(|e| net(addr, format_args!("set read timeout: {e}")))?;
                    let read_half = stream
                        .try_clone()
                        .map_err(|e| net(addr, format_args!("clone stream: {e}")))?;
                    return Ok(ShardConn {
                        addr: addr.to_string(),
                        trace: 0,
                        stream,
                        reader: FrameReader::new(read_half, wire::MAX_FRAME),
                        timeout,
                    });
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(net(
            addr,
            format_args!(
                "connect failed after {CONNECT_TRIES} attempts: {}",
                last_err.expect("at least one attempt")
            ),
        ))
    }

    /// A typed net error naming this shard (and the active trace).
    fn err(&self, msg: std::fmt::Arguments<'_>) -> EakmError {
        net_traced(&self.addr, self.trace, msg)
    }

    /// Send one frame.
    pub(crate) fn send(&mut self, tag: u8, body: &[u8]) -> Result<()> {
        if !send_frame(&mut self.stream, tag, body) {
            return Err(self.err(format_args!("connection closed while sending")));
        }
        Ok(())
    }

    /// Receive one frame, honouring the reply timeout. An `ERR` frame
    /// becomes a typed error carrying the shard's message.
    pub(crate) fn recv(&mut self) -> Result<(u8, Vec<u8>)> {
        let deadline = Instant::now() + self.timeout;
        loop {
            match self.reader.next_frame(deadline.min(Instant::now() + READ_POLL)) {
                Frame::Msg(t, body) => {
                    if t == tag::ERR {
                        return Err(self.err(format_args!("{}", wire::decode_err(&body))));
                    }
                    return Ok((t, body));
                }
                Frame::Idle => {
                    if Instant::now() >= deadline {
                        return Err(self.err(format_args!(
                            "timed out after {:?} waiting for a reply",
                            self.timeout
                        )));
                    }
                }
                Frame::Eof => {
                    return Err(self.err(format_args!("connection closed")));
                }
                Frame::TooLong => {
                    return Err(self.err(format_args!("oversized or malformed frame")));
                }
            }
        }
    }

    /// Send a request and receive its reply, asserting the reply tag.
    pub(crate) fn request(&mut self, req_tag: u8, body: &[u8], want: u8) -> Result<Vec<u8>> {
        self.send(req_tag, body)?;
        let (t, reply) = self.recv()?;
        if t != want {
            return Err(self.err(format_args!("unexpected reply tag {t} (wanted {want})")));
        }
        Ok(reply)
    }
}

/// One shard server's observability snapshot ([`shard_stats`]).
#[derive(Clone, Debug)]
pub struct ShardStats {
    /// The shard's metric families in the Prometheus text format (the
    /// same body its optional metrics listener serves as `GET /metrics`).
    pub metrics: String,
    /// Structured events after the requested cursor, as the JSON body
    /// `{"ok":true,"last":N,"events":[…]}` — the same shape the serve
    /// tier's `GET /v1/events` answers.
    pub events: String,
}

/// Query one shard server's `STATS` frame: metric families plus the
/// events newer than `since` (0 = everything resident). Works mid-fit —
/// the shard answers off its compute lock, so a scrape never blocks or
/// perturbs a round.
pub fn shard_stats(addr: &str, since: u64, timeout: Duration) -> Result<ShardStats> {
    let mut conn = ShardConn::connect(addr, timeout)?;
    let body = conn.request(tag::STATS, &Stats { since }.encode(), tag::STATS_OK)?;
    let reply = StatsOk::decode(&body)?;
    Ok(ShardStats {
        metrics: reply.metrics,
        events: reply.events,
    })
}

/// A typed net error naming the shard.
pub(crate) fn net(addr: &str, msg: std::fmt::Arguments<'_>) -> EakmError {
    EakmError::Net(format!("shard {addr}: {msg}"))
}

/// [`net`] with the fit's trace ID appended (when set) so wire failures
/// correlate with round events on both ends.
pub(crate) fn net_traced(addr: &str, trace: u64, msg: std::fmt::Arguments<'_>) -> EakmError {
    if trace == 0 {
        net(addr, msg)
    } else {
        EakmError::Net(format!("shard {addr} [trace {trace:016x}]: {msg}"))
    }
}
