//! [`NetSource`] — a [`DataSource`] whose rows live on shard servers.
//!
//! The source speaks only the **data plane** of the dist protocol
//! ([`wire`](super::wire)): at connect time it `OPEN`s every shard once
//! to learn the global shape and each shard's row range, validates that
//! the ranges tile `[0, n)` contiguously in the order given, and then
//! serves the block-lease contract by `LEASE`-ing row blocks on demand.
//! Because the shards stream the same little-endian `.ekb` payload
//! bytes and sidecar-exact norms the local out-of-core sources decode,
//! every consumer — exact fits, mini-batch, seeding, prediction — sees
//! **bit-identical rows and norms** to a local run over the same file.
//!
//! ## Cursor model
//!
//! [`open`](DataSource::open) hands each pool worker a private cursor
//! with one lazily-dialed connection per shard and a resident window of
//! [`window_rows`](NetSource::window_rows) decoded rows, refilled with
//! the same streaming/random heuristic as
//! [`ChunkedFileSource`](crate::data::ChunkedFileSource): monotone
//! scans fetch full windows (few round trips), isolated single-row
//! gathers fetch small blocks (little read amplification). A refill
//! that crosses a shard boundary issues one `LEASE` per shard touched
//! and splices the blocks.
//!
//! ## Failure semantics
//!
//! Shards are validated at connect; the lease path is infallible by
//! contract (`lease` returns a block, not a `Result`), so a shard that
//! dies **mid-fit** is handled like a file that vanishes mid-run in the
//! out-of-core sources: the cursor retries with reconnect + backoff
//! ([`LEASE_TRIES`]) and then panics naming the shard. Fits driven by
//! the compute plane (`eakm run --shards` with an exact algorithm) do
//! not take this path for the scan itself — there a dead shard is a
//! typed [`EakmError::Net`](crate::error::EakmError::Net) from the
//! coordinator — but mini-batch and seeding read through cursors and
//! accept the panic contract.

use std::time::Duration;

use crate::data::io::{decode_widen_le, ElemWidth};
use crate::data::ooc::DEFAULT_WINDOW_ROWS;
use crate::data::source::{BlockCursor, RowBlock};
use crate::data::DataSource;
use crate::error::Result;
use crate::metrics::IoTelemetry;

use super::client::{net, ShardConn};
use super::wire::{tag, Block, Lease, OpenOk};

// The shared IoCounters lives with the other sources.
use crate::data::ooc::IoCounters;

/// Rows fetched for an isolated single-row lease (random access), as in
/// the chunked source: gathers cost `O(picks)` small round trips, not
/// `O(picks × window)`.
const RANDOM_WINDOW_ROWS: usize = 64;

/// Lease attempts per block before the cursor gives up (reconnect +
/// doubling backoff between attempts).
const LEASE_TRIES: u32 = 3;
/// First inter-attempt backoff (doubles: 50, 100 ms).
const LEASE_BACKOFF: Duration = Duration::from_millis(50);

/// One shard's identity as learned from its `OPEN_OK`.
#[derive(Clone, Debug)]
pub(crate) struct ShardMeta {
    /// Address verbatim from `--shards` (used in errors).
    pub(crate) addr: String,
    /// First global row this shard owns.
    pub(crate) lo: usize,
    /// One past the last global row this shard owns.
    pub(crate) hi: usize,
    /// Storage width of the shard's `.ekb` payload.
    pub(crate) width: ElemWidth,
}

/// A network-backed [`DataSource`]: rows are `LEASE`d from shard
/// servers over the dist data plane.
pub struct NetSource {
    metas: Vec<ShardMeta>,
    n: usize,
    d: usize,
    name: String,
    window_rows: usize,
    timeout: Duration,
    io: IoCounters,
}

impl NetSource {
    /// Dial every shard, learn the global shape, and validate coverage:
    /// the shards' `[lo, hi)` ranges must tile `[0, n)` contiguously
    /// **in the order given** (shard order is merge order — see the
    /// determinism argument in [`dist`](crate::dist)). A `window_rows`
    /// of 0 selects [`DEFAULT_WINDOW_ROWS`].
    pub fn connect(addrs: &[String], window_rows: usize, timeout: Duration) -> Result<NetSource> {
        if addrs.is_empty() {
            return Err(crate::error::EakmError::Config(
                "--shards needs at least one shard address".into(),
            ));
        }
        let mut metas = Vec::with_capacity(addrs.len());
        let mut shape: Option<(usize, usize)> = None;
        let mut name = String::new();
        for addr in addrs {
            let mut conn = ShardConn::connect(addr, timeout)?;
            let reply = conn.request(tag::OPEN, &[], tag::OPEN_OK)?;
            let ok = OpenOk::decode(&reply)?;
            match shape {
                None => {
                    shape = Some((ok.n, ok.d));
                    name = ok.name.clone();
                }
                Some((n, d)) => {
                    if (ok.n, ok.d) != (n, d) {
                        return Err(net(
                            addr,
                            format_args!(
                                "serves a {}×{} dataset, other shards serve {n}×{d}",
                                ok.n, ok.d
                            ),
                        ));
                    }
                }
            }
            metas.push(ShardMeta {
                addr: addr.clone(),
                lo: ok.lo,
                hi: ok.hi,
                width: ok.width,
            });
        }
        let (n, d) = shape.expect("addrs is nonempty");
        let mut expect_lo = 0usize;
        for m in &metas {
            if m.lo != expect_lo {
                return Err(net(
                    &m.addr,
                    format_args!(
                        "owns rows [{}, {}) but [{expect_lo}, …) is next — shard ranges must \
                         tile [0, {n}) contiguously in --shards order",
                        m.lo, m.hi
                    ),
                ));
            }
            if m.hi <= m.lo || m.hi > n {
                return Err(net(
                    &m.addr,
                    format_args!("owns an invalid row range [{}, {}) of n={n}", m.lo, m.hi),
                ));
            }
            expect_lo = m.hi;
        }
        if expect_lo != n {
            return Err(crate::error::EakmError::Net(format!(
                "shards cover rows [0, {expect_lo}) but the dataset has {n} rows — \
                 every row must be owned by exactly one shard"
            )));
        }
        let window_rows = if window_rows == 0 {
            DEFAULT_WINDOW_ROWS
        } else {
            window_rows
        };
        Ok(NetSource {
            metas,
            n,
            d,
            name,
            window_rows,
            timeout,
            io: IoCounters::default(),
        })
    }

    /// Resident-window size in rows.
    pub fn window_rows(&self) -> usize {
        self.window_rows
    }

    /// Shard identities in `--shards` (= merge) order.
    pub(crate) fn metas(&self) -> &[ShardMeta] {
        &self.metas
    }

    /// Reply timeout the source dials shards with.
    pub(crate) fn timeout(&self) -> Duration {
        self.timeout
    }

    /// Index of the shard owning global row `row`.
    fn shard_for(&self, row: usize) -> usize {
        self.metas.partition_point(|m| m.hi <= row)
    }
}

impl DataSource for NetSource {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn open(&self, lo: usize, len: usize) -> Box<dyn BlockCursor + '_> {
        assert!(lo + len <= self.n, "open range out of bounds");
        Box::new(NetCursor {
            src: self,
            conns: self.metas.iter().map(|_| None).collect(),
            range_lo: lo,
            range_len: len,
            win_lo: 0,
            win_len: 0,
            buf: Vec::new(),
            norms: Vec::new(),
        })
    }

    fn io_stats(&self) -> Option<IoTelemetry> {
        Some(self.io.snapshot())
    }
}

/// One worker's window over a [`NetSource`], with a lazily-dialed
/// connection per shard (cursors run concurrently across pool workers,
/// so they cannot share sockets).
struct NetCursor<'a> {
    src: &'a NetSource,
    conns: Vec<Option<ShardConn>>,
    range_lo: usize,
    range_len: usize,
    /// Resident window: rows `[win_lo, win_lo + win_len)` decoded in
    /// `buf`, their norms in `norms`.
    win_lo: usize,
    win_len: usize,
    buf: Vec<f64>,
    norms: Vec<f64>,
}

impl NetCursor<'_> {
    /// Refill the window to start at `lo`, covering at least `len` rows
    /// (same heuristic as the chunked cursor; see module docs).
    fn refill(&mut self, lo: usize, len: usize) {
        let src = self.src;
        let end = self.range_lo + self.range_len;
        let streaming = self.win_len > 0 && lo == self.win_lo + self.win_len;
        let target = if len > 1 || streaming {
            src.window_rows
        } else {
            RANDOM_WINDOW_ROWS.min(src.window_rows)
        };
        let take = target.max(len).min(end - lo);
        self.buf.clear();
        self.norms.clear();
        let mut bytes = 0u64;
        let mut cur = lo;
        let stop = lo + take;
        while cur < stop {
            let s = src.shard_for(cur);
            let chunk = stop.min(src.metas[s].hi) - cur;
            let block = self.fetch(s, cur, chunk);
            // count wire payload bytes: rows at storage width + norms
            bytes += (block.rows.len() + block.norms.len() * 8) as u64;
            decode_widen_le(block.width, &block.rows, &mut self.buf);
            self.norms.extend_from_slice(&block.norms);
            cur += chunk;
        }
        self.win_lo = lo;
        self.win_len = take;
        src.io.add_refill();
        src.io.add_bytes(bytes);
    }

    /// Lease rows `[lo, lo + len)` from shard `s`, retrying with
    /// reconnect + backoff; the shards were validated at connect, so
    /// one staying dead is not a recoverable lease outcome (the same
    /// contract as an `.ekb` file vanishing mid-run).
    fn fetch(&mut self, s: usize, lo: usize, len: usize) -> Block {
        let mut backoff = LEASE_BACKOFF;
        let mut last = None;
        for attempt in 0..LEASE_TRIES {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff *= 2;
            }
            match self.try_fetch(s, lo, len) {
                Ok(block) => return block,
                Err(e) => {
                    // drop the connection: the stream may hold a
                    // half-read reply, so it cannot be reused
                    self.conns[s] = None;
                    last = Some(e);
                }
            }
        }
        panic!(
            "net source: leasing rows [{lo}, {}) failed after {LEASE_TRIES} attempts: {}",
            lo + len,
            last.expect("at least one attempt")
        );
    }

    fn try_fetch(&mut self, s: usize, lo: usize, len: usize) -> Result<Block> {
        let src = self.src;
        let meta = &src.metas[s];
        if self.conns[s].is_none() {
            let mut conn = ShardConn::connect(&meta.addr, src.timeout)?;
            let reply = conn.request(tag::OPEN, &[], tag::OPEN_OK)?;
            let ok = OpenOk::decode(&reply)?;
            if (ok.n, ok.d, ok.lo, ok.hi) != (src.n, src.d, meta.lo, meta.hi) {
                return Err(net(
                    &meta.addr,
                    format_args!(
                        "shard shape changed between connects \
                         (now {}×{} rows [{}, {}))",
                        ok.n, ok.d, ok.lo, ok.hi
                    ),
                ));
            }
            self.conns[s] = Some(conn);
        }
        let conn = self.conns[s].as_mut().expect("dialed above");
        let req = Lease { lo, len };
        let reply = conn.request(tag::LEASE, &req.encode(), tag::BLOCK)?;
        let block = Block::decode(&reply, src.d)?;
        if block.width != meta.width {
            return Err(net(
                &meta.addr,
                format_args!(
                    "block storage width changed mid-stream ({} → {} bytes/elem)",
                    meta.width.bytes(),
                    block.width.bytes()
                ),
            ));
        }
        if block.lo != lo || block.len != len {
            return Err(net(
                &meta.addr,
                format_args!(
                    "lease returned rows [{}, {}), wanted [{lo}, {})",
                    block.lo,
                    block.lo + block.len,
                    lo + len
                ),
            ));
        }
        Ok(block)
    }
}

impl BlockCursor for NetCursor<'_> {
    fn d(&self) -> usize {
        self.src.d
    }

    fn lease(&mut self, lo: usize, len: usize) -> RowBlock<'_> {
        assert!(
            lo >= self.range_lo && lo + len <= self.range_lo + self.range_len,
            "lease [{lo}, {}) outside cursor range [{}, {})",
            lo + len,
            self.range_lo,
            self.range_lo + self.range_len
        );
        if lo < self.win_lo || lo + len > self.win_lo + self.win_len {
            self.refill(lo, len);
        }
        self.src.io.add_block();
        let d = self.src.d;
        let off = lo - self.win_lo;
        RowBlock::new(
            lo,
            d,
            &self.buf[off * d..(off + len) * d],
            &self.norms[off..off + len],
        )
    }
}
