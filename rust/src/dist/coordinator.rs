//! The distributed round loop: [`DistEngine`] (stepwise) and
//! [`run_dist`] (run-to-convergence, the `eakm run --shards` path).
//!
//! The coordinator holds the *global* model state — centroids, the
//! running [`UpdateState`], and the assignment vector — and drives one
//! compute-plane connection per shard. Each round it:
//!
//! 1. computes new centroids from the running sums (its own pool —
//!    exactly [`UpdateState::centroids_pooled`], as single-node);
//! 2. broadcasts them (`ROUND`) to every shard — *sends first, then
//!    reads replies in shard order*, so shards scan concurrently;
//! 3. merges replies **in shard order**: scan counters add up, moved
//!    lists concatenate (each shard's list is ascending in global
//!    sample index and shard ranges are ordered, so the concatenation
//!    is exactly the single-node moved list), and the centroid-side
//!    build counters — identical on every shard by construction — are
//!    merged once and cross-checked;
//! 4. applies the moves to the global state the same way the
//!    single-node engine does: the delta update replays the merged
//!    moved list ([`UpdateState::apply_moves_pooled`] over the
//!    [`NetSource`]); full-update algorithms rebuild from per-chunk
//!    partial sums the shards computed with the shared
//!    [`scan_chunk`](crate::coordinator::update::scan_chunk) loop
//!    (bit-identical to the single-node pooled rebuild because the
//!    chunk grid is global), falling back to a rebuild through the
//!    network source when shard boundaries don't land on chunk
//!    boundaries.
//!
//! See [`dist`](crate::dist) for the full determinism argument. The
//! upshot: every quantity the run reports — assignments, MSE bits,
//! bound counters, iteration count — is **bit-identical to the
//! single-node run at any shard count and any thread width**, which
//! `tests/dist.rs` asserts.
//!
//! ## Failure semantics
//!
//! [`DistEngine::step`] returns a [`Result`]: a shard that dies
//! mid-fit (connection drops, times out, or replies `ERR`) surfaces as
//! a typed [`EakmError::Net`](crate::error::EakmError::Net) *naming
//! the shard address* — never a hang. The engine is not usable after
//! an error (the surviving shards' sessions are out of sync); callers
//! abandon the fit.

use std::time::{Duration, Instant};

use crate::algorithms::Algorithm;
use crate::config::RunConfig;
use crate::coordinator::groups::GroupData;
use crate::coordinator::history::HistoryStore;
use crate::coordinator::runner::RunOutput;
use crate::coordinator::update::{chunk_len, merge_partial_sums, UpdateState};
use crate::data::DataSource;
use crate::error::{EakmError, Result};
use crate::metrics::{Counters, PhaseTimes, RunReport, SchedTelemetry};
use crate::obs::{FitObserver, RoundObservation, TraceId};
use crate::rng::Rng;
use crate::runtime::pool::WorkerPool;
use crate::runtime::Runtime;

use super::client::{net, ShardConn};
use super::netsource::NetSource;
use super::wire::{tag, ChunkPartial, FitInit, FitOk, Round, RoundOk};

/// Reply timeout for compute-plane requests (a shard scan of a large
/// range can legitimately take a while; a dead shard fails much faster
/// via connection reset).
pub const DEFAULT_NET_TIMEOUT: Duration = Duration::from_secs(120);

/// A stepwise distributed k-means engine: one `step()` = one update +
/// one broadcast round across the shards.
pub struct DistEngine<'a> {
    net: &'a NetSource,
    /// Compute-plane connections, in shard (= merge) order.
    conns: Vec<ShardConn>,
    pool: &'a WorkerPool,
    n: usize,
    d: usize,
    k: usize,
    a: Vec<u32>,
    centroids: Vec<f64>,
    update: UpdateState,
    full_update: bool,
    want_partials: bool,
    counters: Counters,
    phases: PhaseTimes,
    converged: bool,
    rounds: usize,
    name: String,
    last_moved: usize,
    trace: TraceId,
}

impl<'a> DistEngine<'a> {
    /// Seed and start a fit session on every shard of `net`, mirroring
    /// the single-node `Engine` build: the empty-source guard, config
    /// validation, `Auto` resolution, seeding from `cfg.init` with the
    /// config's RNG stream, and the round-0 full assignment — except
    /// the scan runs on the shards.
    ///
    /// Mints a fresh [`TraceId`] for the fit; use
    /// [`connect_traced`](DistEngine::connect_traced) to propagate one
    /// minted further up (e.g. by an observer at the front door).
    pub fn connect(rt: &'a Runtime, cfg: &RunConfig, net: &'a NetSource) -> Result<Self> {
        DistEngine::connect_traced(rt, cfg, net, TraceId::mint())
    }

    /// [`connect`](DistEngine::connect) with a caller-supplied trace ID,
    /// shipped in `FIT_INIT`/`ROUND` and echoed by every shard reply —
    /// shard-side round events for this fit carry the same ID.
    pub fn connect_traced(
        rt: &'a Runtime,
        cfg: &RunConfig,
        net: &'a NetSource,
        trace: TraceId,
    ) -> Result<Self> {
        if net.n() == 0 || net.d() == 0 {
            return Err(EakmError::Data(format!(
                "cannot cluster an empty data source (n={}, d={})",
                net.n(),
                net.d()
            )));
        }
        cfg.validate(net.n())?;
        let (n, d, k) = (net.n(), net.d(), cfg.k);
        let alg = match cfg.algorithm {
            Algorithm::Auto => crate::coordinator::auto::resolve(d),
            other => other,
        };
        let g = GroupData::group_count(k);
        let probe = alg.make_shard(0, 0, k, g);
        let req = probe.requirements();
        let name = probe.name().to_string();
        drop(probe);
        let pool = rt.pool();

        // seeding runs on the coordinator (it consumes the RNG stream),
        // reading sample rows through the network source
        let mut counters = Counters::default();
        let mut phases = PhaseTimes::default();
        let mut rng = Rng::new(cfg.seed);
        let centroids = cfg.init.centroids(net, k, &mut rng, &mut counters);

        // the ns-history cap is a function of the *global* row count —
        // computed here once and shipped, never derived shard-locally
        let hist_cap = cfg
            .history_cap
            .unwrap_or_else(|| HistoryStore::paper_cap(n, k, d, cfg.history_budget));

        // the full-sums fast path needs the global chunk grid to land
        // on shard boundaries (chunks must not straddle shards) and the
        // single-node reference to take the pooled (chunked) path
        let clen = chunk_len(n);
        let want_partials = n > clen && net.metas().iter().all(|m| m.lo % clen == 0);

        let init = FitInit {
            alg: alg.to_string(),
            k,
            d,
            seed: cfg.seed,
            hist_cap,
            want_partials,
            centroids: centroids.clone(),
            trace: trace.as_u64(),
        };
        let mut conns = Vec::with_capacity(net.metas().len());
        for m in net.metas() {
            let mut conn = ShardConn::connect(&m.addr, net.timeout())?;
            conn.trace = trace.as_u64();
            conns.push(conn);
        }

        // round 0: broadcast the seed, collect every shard's full
        // assignment of its range
        let t_scan = Instant::now();
        let body = init.encode();
        for conn in &mut conns {
            conn.send(tag::FIT_INIT, &body)?;
        }
        let mut a = vec![0u32; n];
        let mut build_ctr: Option<Counters> = None;
        let mut partials: Vec<Vec<ChunkPartial>> = Vec::with_capacity(conns.len());
        for (conn, m) in conns.iter_mut().zip(net.metas()) {
            let reply = conn.request_reply(tag::FIT_OK)?;
            let fit = FitOk::decode(&reply).map_err(|e| reply_err(&conn.addr, e))?;
            if fit.assignments.len() != m.hi - m.lo {
                return Err(net(
                    &conn.addr,
                    format_args!(
                        "returned {} assignments for {} rows",
                        fit.assignments.len(),
                        m.hi - m.lo
                    ),
                ));
            }
            a[m.lo..m.hi].copy_from_slice(&fit.assignments);
            if fit.trace != trace.as_u64() {
                return Err(net(
                    &conn.addr,
                    format_args!(
                        "echoed trace {:016x}, expected {trace}",
                        fit.trace
                    ),
                ));
            }
            merge_build_ctr(&mut build_ctr, &fit.build_ctr, &mut counters, &conn.addr)?;
            counters.merge(&fit.scan_ctr);
            partials.push(fit.partials);
        }
        phases.scan += t_scan.elapsed();

        let t_update = Instant::now();
        let update = if want_partials {
            assemble_update(&partials, n, k, d)?
        } else {
            UpdateState::from_assignments_pooled(net, &a, k, pool)
        };
        phases.update += t_update.elapsed();

        Ok(DistEngine {
            net,
            conns,
            pool,
            n,
            d,
            k,
            a,
            centroids,
            update,
            full_update: req.full_update,
            want_partials,
            counters,
            phases,
            converged: false,
            rounds: 0,
            name,
            last_moved: usize::MAX,
            trace,
        })
    }

    /// One Lloyd round (update step + broadcast assignment step).
    /// Returns the number of samples that changed cluster, or a typed
    /// error naming the shard that failed.
    pub fn step(&mut self) -> Result<usize> {
        if self.converged {
            return Ok(0);
        }
        let (d, k, n) = (self.d, self.k, self.n);
        // update step — identical arithmetic to single-node
        let t_update = Instant::now();
        self.centroids = self.update.centroids_pooled(&self.centroids, d, self.pool);
        self.phases.update += t_update.elapsed();
        // centroid-side rebuilds + assignment scan happen on the
        // shards; the whole round trip is charged to the scan phase
        let t_scan = Instant::now();
        let body = Round {
            centroids: self.centroids.clone(),
            trace: self.trace.as_u64(),
        }
        .encode();
        for conn in &mut self.conns {
            conn.send(tag::ROUND, &body)?;
        }
        let mut moved = Vec::new();
        let mut build_ctr: Option<Counters> = None;
        let mut partials: Vec<Vec<ChunkPartial>> = Vec::with_capacity(self.conns.len());
        for conn in &mut self.conns {
            let reply = conn.request_reply(tag::ROUND_OK)?;
            let round = RoundOk::decode(&reply).map_err(|e| reply_err(&conn.addr, e))?;
            if round.trace != self.trace.as_u64() {
                return Err(net(
                    &conn.addr,
                    format_args!(
                        "echoed trace {:016x}, expected {}",
                        round.trace, self.trace
                    ),
                ));
            }
            merge_build_ctr(&mut build_ctr, &round.build_ctr, &mut self.counters, &conn.addr)?;
            self.counters.merge(&round.scan_ctr);
            for m in &round.moved {
                if m.i as usize >= n || m.to as usize >= k {
                    return Err(net(
                        &conn.addr,
                        format_args!("move ({}, {} → {}) out of range", m.i, m.from, m.to),
                    ));
                }
            }
            moved.extend_from_slice(&round.moved);
            partials.push(round.partials);
        }
        self.phases.scan += t_scan.elapsed();

        let t_apply = Instant::now();
        for m in &moved {
            self.a[m.i as usize] = m.to;
        }
        if self.full_update {
            self.update = if self.want_partials {
                assemble_update(&partials, n, k, d)?
            } else {
                UpdateState::from_assignments_pooled(self.net, &self.a, k, self.pool)
            };
        } else {
            self.update.apply_moves_pooled(self.net, &moved, self.pool);
        }
        self.phases.update += t_apply.elapsed();
        self.rounds += 1;
        self.last_moved = moved.len();
        self.converged = moved.is_empty();
        Ok(moved.len())
    }

    /// End the fit sessions (best-effort: a shard that already died is
    /// ignored — the fit result is complete without it).
    pub fn finish(&mut self) {
        for conn in &mut self.conns {
            if conn.send(tag::FIT_END, &[]).is_ok() {
                let _ = conn.recv();
            }
        }
    }

    /// Current assignments.
    pub fn assignments(&self) -> &[u32] {
        &self.a
    }

    /// Current centroids (row-major `k×d`).
    pub fn centroids(&self) -> &[f64] {
        &self.centroids
    }

    /// Whether the last round moved nothing.
    pub fn converged(&self) -> bool {
        self.converged
    }

    /// Rounds executed so far (excluding the initial assignment).
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Accumulated distance counters (coordinator seeding + one copy of
    /// the shard-identical build counters + all scan counters).
    pub fn counters(&self) -> Counters {
        self.counters
    }

    /// Accumulated per-phase wall times (`scan` includes the shards'
    /// centroid-side build work — the round trip is not decomposable
    /// from here).
    pub fn phases(&self) -> PhaseTimes {
        self.phases
    }

    /// The coordinator pool's width.
    pub fn threads(&self) -> usize {
        self.pool.width()
    }

    /// Samples moved in the last round.
    pub fn last_moved(&self) -> usize {
        self.last_moved
    }

    /// Resolved algorithm name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The fit's trace ID (shipped to every shard and echoed back).
    pub fn trace(&self) -> TraceId {
        self.trace
    }

    /// Objective (mean squared distance to assigned centroid), computed
    /// through the network source with the shared serial walk.
    pub fn mse(&self) -> f64 {
        self.net.mse(&self.centroids, &self.a)
    }
}

impl ShardConn {
    /// Receive one reply and assert its tag (`ERR` already became a
    /// typed error in [`recv`](ShardConn::recv)).
    fn request_reply(&mut self, want: u8) -> Result<Vec<u8>> {
        let (t, body) = self.recv()?;
        if t != want {
            return Err(net(
                &self.addr,
                format_args!("unexpected reply tag {t} (wanted {want})"),
            ));
        }
        Ok(body)
    }
}

/// Merge one shard's centroid-side build counters: the first shard's
/// are added to the totals (the build happens once per fit, logically);
/// every later shard must report identical numbers — the builds are
/// pure functions of (centroids, k, d, seed) — or the determinism
/// contract is broken.
fn merge_build_ctr(
    first: &mut Option<Counters>,
    ctr: &Counters,
    totals: &mut Counters,
    addr: &str,
) -> Result<()> {
    match *first {
        None => {
            *first = Some(*ctr);
            totals.merge(ctr);
            Ok(())
        }
        Some(expect) if expect == *ctr => Ok(()),
        Some(expect) => Err(EakmError::Invariant(format!(
            "shard {addr} build counters diverge from shard 0 \
             ({ctr:?} vs {expect:?}) — centroid-side builds must be \
             identical on every shard"
        ))),
    }
}

/// Rebuild the [`UpdateState`] from per-shard, per-global-chunk partial
/// sums: validate that the shards together returned exactly the chunks
/// `0..n.div_ceil(chunk_len(n))` in order, then fold them with the same
/// [`merge_partial_sums`] loop the single-node pooled rebuild uses —
/// same grid, same accumulation order, bit-identical sums.
fn assemble_update(
    per_shard: &[Vec<ChunkPartial>],
    n: usize,
    k: usize,
    d: usize,
) -> Result<UpdateState> {
    let nchunks = n.div_ceil(chunk_len(n));
    let mut parts: Vec<&ChunkPartial> = Vec::with_capacity(nchunks);
    for ps in per_shard {
        parts.extend(ps.iter());
    }
    if parts.len() != nchunks {
        return Err(EakmError::Net(format!(
            "shards returned {} chunk partials, expected {nchunks}",
            parts.len()
        )));
    }
    for (c, p) in parts.iter().enumerate() {
        if p.chunk as usize != c || p.sums.len() != k * d || p.counts.len() != k {
            return Err(EakmError::Net(format!(
                "chunk partial {c} is malformed (chunk id {}, {} sums, {} counts)",
                p.chunk,
                p.sums.len(),
                p.counts.len()
            )));
        }
    }
    Ok(merge_partial_sums(
        parts.iter().map(|p| (&p.sums[..], &p.counts[..])),
        k,
        d,
    ))
}

fn reply_err(addr: &str, e: EakmError) -> EakmError {
    net(addr, format_args!("malformed reply: {e}"))
}

/// Cluster the rows served by `addrs` to convergence (or a configured
/// limit) on a shared [`Runtime`] — the distributed mirror of
/// `Runner::run_on`, producing the same [`RunOutput`] / report shape.
///
/// With [`RunConfig::batch_size`] below the global row count the run is
/// dispatched to the mini-batch engine over the [`NetSource`] — a pure
/// data-plane fit: only row blocks cross the network.
pub fn run_dist(rt: &Runtime, cfg: &RunConfig, addrs: &[String]) -> Result<RunOutput> {
    run_dist_observed(rt, cfg, addrs, None)
}

/// [`run_dist`] with an optional [`FitObserver`]: per-round `"round"`
/// events with `site = "dist"`, carrying the observer's trace ID to
/// every shard (shard-side round events record the same ID). Without an
/// observer a fresh trace is minted and the per-round objective read
/// (a full network scan) is skipped.
pub fn run_dist_observed(
    rt: &Runtime,
    cfg: &RunConfig,
    addrs: &[String],
    observer: Option<&FitObserver>,
) -> Result<RunOutput> {
    let net = NetSource::connect(addrs, 0, DEFAULT_NET_TIMEOUT)?;
    if let Some(batch) = cfg.batch_size {
        if batch < net.n() {
            return crate::coordinator::minibatch::run_minibatch(rt, cfg, &net, observer);
        }
    }
    let io_before = net.io_stats();
    let start = Instant::now();
    let trace = match observer {
        Some(obs) => obs.trace(),
        None => TraceId::mint(),
    };
    let mut engine = DistEngine::connect_traced(rt, cfg, &net, trace)?;
    let mut round_times = Vec::new();
    while !engine.converged() && engine.rounds() < cfg.max_iters {
        if let Some(limit) = cfg.time_limit {
            if start.elapsed() > limit {
                break;
            }
        }
        let t0 = Instant::now();
        let ctr_before = engine.counters();
        let moved = engine.step()?;
        if cfg.record_rounds {
            round_times.push(t0.elapsed());
        }
        if let Some(obs) = observer {
            obs.round(&RoundObservation {
                site: "dist",
                round: engine.rounds(),
                moved,
                mse: engine.mse(),
                delta: engine.counters().since(&ctr_before),
                // shard-side scan telemetry stays node-local
                imbalance: 1.0,
                batch_rows: None,
            });
        }
    }
    engine.finish();
    let wall = start.elapsed();
    let mse = engine.mse();
    let io = match (io_before, net.io_stats()) {
        (Some(before), Some(after)) => Some(after.since(&before)),
        _ => None,
    };
    let report = RunReport {
        algorithm: engine.name().to_string(),
        dataset: net.name().to_string(),
        k: cfg.k,
        n: net.n(),
        seed: cfg.seed,
        iterations: engine.rounds(),
        converged: engine.converged(),
        mse,
        wall,
        threads: engine.threads(),
        phases: engine.phases(),
        counters: engine.counters(),
        round_times,
        batch: None,
        io,
        // the scan runs on the remote shard servers; their plans'
        // telemetry stays node-local (surfaced by each shardd), so the
        // coordinator-side report carries an empty block
        sched: SchedTelemetry::default(),
    };
    Ok(RunOutput {
        assignments: engine.assignments().to_vec(),
        centroids: engine.centroids().to_vec(),
        iterations: engine.rounds(),
        converged: engine.converged(),
        mse,
        counters: engine.counters(),
        wall,
        report,
    })
}
