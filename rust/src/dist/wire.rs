//! The dist wire protocol: message tags and binary codecs.
//!
//! Every message is one [`net::frame`](crate::net::frame) frame —
//! `u32-LE length | u8 tag | body` — with the body encoded by the
//! little-endian codecs here. The protocol is dependency-free and
//! versionless by construction: coordinator and shards ship in the same
//! binary, so the only compatibility contract is "same build".
//!
//! ## Frame tags
//!
//! | tag | name       | plane   | body                                             |
//! |----:|------------|---------|--------------------------------------------------|
//! |   1 | `OPEN`     | data    | *(empty)* — open the serving shard's row range    |
//! |   2 | `OPEN_OK`  | data    | `n d lo hi elem_bytes name`                      |
//! |   3 | `LEASE`    | data    | `lo len` (global rows, within `[lo, hi)`)        |
//! |   4 | `BLOCK`    | data    | `lo len elem_bytes rows norms`                   |
//! |  10 | `FIT_INIT` | compute | `alg k d seed hist_cap want_partials centroids trace` |
//! |  11 | `FIT_OK`   | compute | `build_ctr scan_ctr assignments partials trace`  |
//! |  12 | `ROUND`    | compute | `centroids trace`                                |
//! |  13 | `ROUND_OK` | compute | `build_ctr scan_ctr moved partials trace`        |
//! |  14 | `FIT_END`  | compute | *(empty)* — tear down the fit session            |
//! |  15 | `OK`       | both    | *(empty)* — acknowledgement                      |
//! |  20 | `STATS`    | both    | `since` — drain shard metrics + events           |
//! |  21 | `STATS_OK` | both    | `metrics events` (Prometheus text, events JSON)  |
//! |  99 | `SHUTDOWN` | both    | *(empty)* — stop the shard server                |
//! | 255 | `ERR`      | both    | `msg` — typed failure, connection stays usable   |
//!
//! `trace` is the coordinator-minted [`TraceId`](crate::obs::TraceId)
//! (`u64`, `0` = unset): shards record it in their round events and
//! echo it in replies, so a slow round is attributable to a specific
//! shard from either end of the wire.
//!
//! Row payloads travel at the file's storage width (`elem_bytes` 4 or
//! 8) and are widened to f64 by the receiver with the same
//! [`decode_widen_le`](crate::data::io::decode_widen_le) the file
//! sources use; squared norms always travel as f64 so they match the
//! `.norms` sidecar bit for bit.
//!
//! Decoders validate every length against the remaining body *before*
//! allocating, and truncation is a typed [`EakmError::Net`] — hostile
//! or corrupt peers cannot drive allocation or panics.

use crate::algorithms::common::Moved;
use crate::data::io::ElemWidth;
use crate::error::{EakmError, Result};
use crate::metrics::Counters;

/// Frame cap for both sides of the dist protocol: 1 GiB comfortably
/// holds the largest legal message (a `BLOCK` of `window_rows` rows or
/// a partial-sum set) while bounding a hostile length prefix.
pub(crate) const MAX_FRAME: usize = 1 << 30;

/// Frame tags (see the module table). Public so tests and tooling can
/// speak the protocol (e.g. send a `SHUTDOWN` frame to a shard).
pub mod tag {
    /// Data plane, client → shard: open a block cursor over the shard's
    /// row range (body: window-rows hint).
    pub const OPEN: u8 = 1;
    /// Data plane, shard → client: cursor opened (body: n, d, widths).
    pub const OPEN_OK: u8 = 2;
    /// Data plane, client → shard: lease the next row block.
    pub const LEASE: u8 = 3;
    /// Data plane, shard → client: one leased block (rows + exact norms).
    pub const BLOCK: u8 = 4;
    /// Compute plane, coordinator → shard: start a fit generation
    /// (body: k, algorithm, fit parameters).
    pub const FIT_INIT: u8 = 10;
    /// Compute plane, shard → coordinator: fit generation accepted.
    pub const FIT_OK: u8 = 11;
    /// Compute plane, coordinator → shard: one assignment round
    /// (body: current centroids).
    pub const ROUND: u8 = 12;
    /// Compute plane, shard → coordinator: the round's partial sums,
    /// moved counts, and bound counters for the shard's rows.
    pub const ROUND_OK: u8 = 13;
    /// Compute plane, coordinator → shard: the fit generation is over;
    /// drop its state.
    pub const FIT_END: u8 = 14;
    /// Generic success acknowledgement with an empty body.
    pub const OK: u8 = 15;
    /// Either plane, client → shard: drain the shard's observability
    /// state (body: the event sequence already seen).
    pub const STATS: u8 = 20;
    /// Either plane, shard → client: Prometheus metrics text plus the
    /// events-JSON document for everything after `since`.
    pub const STATS_OK: u8 = 21;
    /// Either plane: ask the shard process to exit cleanly.
    pub const SHUTDOWN: u8 = 99;
    /// Either direction: a typed failure (body: UTF-8 message); the
    /// receiver surfaces it as [`EakmError::Net`](crate::error::EakmError).
    pub const ERR: u8 = 255;
}

// ---- encoding helpers -------------------------------------------------

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

pub(crate) fn put_f64s(buf: &mut Vec<u8>, vs: &[f64]) {
    put_u64(buf, vs.len() as u64);
    for &v in vs {
        put_f64(buf, v);
    }
}

pub(crate) fn put_u32s(buf: &mut Vec<u8>, vs: &[u32]) {
    put_u64(buf, vs.len() as u64);
    for &v in vs {
        put_u32(buf, v);
    }
}

pub(crate) fn put_i64s(buf: &mut Vec<u8>, vs: &[i64]) {
    put_u64(buf, vs.len() as u64);
    for &v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

// ---- decoding helpers -------------------------------------------------

/// A bounds-checked little-endian reader over one frame body.
pub(crate) struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Rd { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(EakmError::Net(format!(
                "truncated frame: wanted {n} bytes, {} left",
                self.buf.len() - self.pos
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    pub(crate) fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| EakmError::Net("string field is not utf-8".into()))
    }

    /// A counted f64 vector; the count is validated against the
    /// remaining bytes before any allocation.
    pub(crate) fn f64s(&mut self) -> Result<Vec<f64>> {
        let count = self.u64()? as usize;
        let bytes = self.take(count.checked_mul(8).ok_or_else(len_overflow)?)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().expect("8-byte chunk")))
            .collect())
    }

    pub(crate) fn u32s(&mut self) -> Result<Vec<u32>> {
        let count = self.u64()? as usize;
        let bytes = self.take(count.checked_mul(4).ok_or_else(len_overflow)?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().expect("4-byte chunk")))
            .collect())
    }

    pub(crate) fn i64s(&mut self) -> Result<Vec<i64>> {
        let count = self.u64()? as usize;
        let bytes = self.take(count.checked_mul(8).ok_or_else(len_overflow)?)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| i64::from_le_bytes(c.try_into().expect("8-byte chunk")))
            .collect())
    }

    pub(crate) fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Assert the whole body was consumed (decoders call this last so a
    /// length-desynced peer is caught, not silently tolerated).
    pub(crate) fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(EakmError::Net(format!(
                "frame has {} trailing bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn len_overflow() -> EakmError {
    EakmError::Net("length field overflows".into())
}

// ---- counters / moved codecs -----------------------------------------

pub(crate) fn put_counters(buf: &mut Vec<u8>, c: &Counters) {
    put_u64(buf, c.assignment);
    put_u64(buf, c.centroid);
    put_u64(buf, c.displacement);
    put_u64(buf, c.init);
}

pub(crate) fn read_counters(r: &mut Rd<'_>) -> Result<Counters> {
    Ok(Counters {
        assignment: r.u64()?,
        centroid: r.u64()?,
        displacement: r.u64()?,
        init: r.u64()?,
    })
}

pub(crate) fn put_moved(buf: &mut Vec<u8>, moved: &[Moved]) {
    put_u64(buf, moved.len() as u64);
    for m in moved {
        put_u32(buf, m.i);
        put_u32(buf, m.from);
        put_u32(buf, m.to);
    }
}

pub(crate) fn read_moved(r: &mut Rd<'_>) -> Result<Vec<Moved>> {
    let count = r.u64()? as usize;
    let bytes = r.bytes(count.checked_mul(12).ok_or_else(len_overflow)?)?;
    Ok(bytes
        .chunks_exact(12)
        .map(|c| Moved {
            i: u32::from_le_bytes(c[0..4].try_into().expect("4 bytes")),
            from: u32::from_le_bytes(c[4..8].try_into().expect("4 bytes")),
            to: u32::from_le_bytes(c[8..12].try_into().expect("4 bytes")),
        })
        .collect())
}

// ---- data plane -------------------------------------------------------

/// `OPEN_OK`: the serving shard's shape — global dataset `n`/`d`, the
/// shard's row range `[lo, hi)`, the file's storage width, and the
/// dataset name (file stem, so reports match single-node runs).
#[derive(Debug, PartialEq)]
pub(crate) struct OpenOk {
    pub n: usize,
    pub d: usize,
    pub lo: usize,
    pub hi: usize,
    pub width: ElemWidth,
    pub name: String,
}

impl OpenOk {
    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        put_u64(&mut buf, self.n as u64);
        put_u64(&mut buf, self.d as u64);
        put_u64(&mut buf, self.lo as u64);
        put_u64(&mut buf, self.hi as u64);
        put_u32(&mut buf, self.width.bytes() as u32);
        put_str(&mut buf, &self.name);
        buf
    }

    pub(crate) fn decode(body: &[u8]) -> Result<Self> {
        let mut r = Rd::new(body);
        let (n, d) = (r.u64()? as usize, r.u64()? as usize);
        let (lo, hi) = (r.u64()? as usize, r.u64()? as usize);
        let width = match r.u32()? {
            4 => ElemWidth::F32,
            8 => ElemWidth::F64,
            eb => return Err(EakmError::Net(format!("bad elem_bytes {eb} (want 4 or 8)"))),
        };
        let name = r.str()?;
        r.finish()?;
        Ok(OpenOk {
            n,
            d,
            lo,
            hi,
            width,
            name,
        })
    }
}

/// `LEASE`: request rows `[lo, lo+len)` (global indices).
#[derive(Debug, PartialEq)]
pub(crate) struct Lease {
    pub lo: usize,
    pub len: usize,
}

impl Lease {
    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        put_u64(&mut buf, self.lo as u64);
        put_u64(&mut buf, self.len as u64);
        buf
    }

    pub(crate) fn decode(body: &[u8]) -> Result<Self> {
        let mut r = Rd::new(body);
        let (lo, len) = (r.u64()? as usize, r.u64()? as usize);
        r.finish()?;
        Ok(Lease { lo, len })
    }
}

/// `BLOCK`: `len` rows starting at global row `lo` — raw row payload at
/// the storage width plus the rows' f64 squared norms.
#[derive(Debug, PartialEq)]
pub(crate) struct Block {
    pub lo: usize,
    pub len: usize,
    pub width: ElemWidth,
    /// `len · d · width.bytes()` raw little-endian row bytes.
    pub rows: Vec<u8>,
    /// `len` sidecar-exact squared norms.
    pub norms: Vec<f64>,
}

impl Block {
    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.rows.len() + self.norms.len() * 8 + 32);
        put_u64(&mut buf, self.lo as u64);
        put_u64(&mut buf, self.len as u64);
        put_u32(&mut buf, self.width.bytes() as u32);
        buf.extend_from_slice(&self.rows);
        for &v in &self.norms {
            put_f64(&mut buf, v);
        }
        buf
    }

    /// Decode with the known row dimension `d` (row/norm byte counts
    /// follow from `len` and the width; nothing is length-prefixed).
    pub(crate) fn decode(body: &[u8], d: usize) -> Result<Self> {
        let mut r = Rd::new(body);
        let (lo, len) = (r.u64()? as usize, r.u64()? as usize);
        let width = match r.u32()? {
            4 => ElemWidth::F32,
            8 => ElemWidth::F64,
            eb => return Err(EakmError::Net(format!("bad elem_bytes {eb} (want 4 or 8)"))),
        };
        let row_bytes = len
            .checked_mul(d)
            .and_then(|v| v.checked_mul(width.bytes()))
            .ok_or_else(len_overflow)?;
        let rows = r.bytes(row_bytes)?.to_vec();
        let mut norms = Vec::with_capacity(len);
        for _ in 0..len {
            norms.push(r.f64()?);
        }
        r.finish()?;
        Ok(Block {
            lo,
            len,
            width,
            rows,
            norms,
        })
    }
}

// ---- compute plane ----------------------------------------------------

/// `FIT_INIT`: start a fit session — algorithm, shape, seed, the
/// coordinator-computed ns-history cap (a function of the *global* row
/// count, so it must not be derived shard-locally), whether the shard
/// should ship per-chunk partial sums, and the seeded centroids.
#[derive(Debug, PartialEq)]
pub(crate) struct FitInit {
    pub alg: String,
    pub k: usize,
    pub d: usize,
    pub seed: u64,
    pub hist_cap: usize,
    pub want_partials: bool,
    pub centroids: Vec<f64>,
    /// Coordinator-minted trace ID (0 = unset).
    pub trace: u64,
}

impl FitInit {
    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        put_str(&mut buf, &self.alg);
        put_u64(&mut buf, self.k as u64);
        put_u64(&mut buf, self.d as u64);
        put_u64(&mut buf, self.seed);
        put_u64(&mut buf, self.hist_cap as u64);
        buf.push(u8::from(self.want_partials));
        put_f64s(&mut buf, &self.centroids);
        put_u64(&mut buf, self.trace);
        buf
    }

    pub(crate) fn decode(body: &[u8]) -> Result<Self> {
        let mut r = Rd::new(body);
        let alg = r.str()?;
        let (k, d) = (r.u64()? as usize, r.u64()? as usize);
        let seed = r.u64()?;
        let hist_cap = r.u64()? as usize;
        let want_partials = r.bytes(1)?[0] != 0;
        let centroids = r.f64s()?;
        let trace = r.u64()?;
        r.finish()?;
        Ok(FitInit {
            alg,
            k,
            d,
            seed,
            hist_cap,
            want_partials,
            centroids,
            trace,
        })
    }
}

/// One global chunk's partial sums (full `k×d` sums + `k` counts), as
/// produced by [`scan_chunk`](crate::coordinator::update::scan_chunk)
/// over the chunk's rows. `chunk` indexes the *global* chunk grid.
#[derive(Debug, PartialEq)]
pub(crate) struct ChunkPartial {
    pub chunk: u64,
    pub sums: Vec<f64>,
    pub counts: Vec<i64>,
}

fn put_partials(buf: &mut Vec<u8>, partials: &[ChunkPartial]) {
    put_u32(buf, partials.len() as u32);
    for p in partials {
        put_u64(buf, p.chunk);
        put_f64s(buf, &p.sums);
        put_i64s(buf, &p.counts);
    }
}

fn read_partials(r: &mut Rd<'_>) -> Result<Vec<ChunkPartial>> {
    let count = r.u32()? as usize;
    let mut out = Vec::new();
    for _ in 0..count {
        let chunk = r.u64()?;
        let sums = r.f64s()?;
        let counts = r.i64s()?;
        out.push(ChunkPartial {
            chunk,
            sums,
            counts,
        });
    }
    Ok(out)
}

/// `FIT_OK`: the shard's round-0 result — centroid-side build counters
/// (identical on every shard; merged once), scan counters (merged in
/// shard order), the shard's local assignments, and optional partials.
#[derive(Debug, PartialEq)]
pub(crate) struct FitOk {
    pub build_ctr: Counters,
    pub scan_ctr: Counters,
    pub assignments: Vec<u32>,
    pub partials: Vec<ChunkPartial>,
    /// The session trace ID, echoed back so the coordinator can assert
    /// the shard is answering for the right fit.
    pub trace: u64,
}

impl FitOk {
    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        put_counters(&mut buf, &self.build_ctr);
        put_counters(&mut buf, &self.scan_ctr);
        put_u32s(&mut buf, &self.assignments);
        put_partials(&mut buf, &self.partials);
        put_u64(&mut buf, self.trace);
        buf
    }

    pub(crate) fn decode(body: &[u8]) -> Result<Self> {
        let mut r = Rd::new(body);
        let build_ctr = read_counters(&mut r)?;
        let scan_ctr = read_counters(&mut r)?;
        let assignments = r.u32s()?;
        let partials = read_partials(&mut r)?;
        let trace = r.u64()?;
        r.finish()?;
        Ok(FitOk {
            build_ctr,
            scan_ctr,
            assignments,
            partials,
            trace,
        })
    }
}

/// `ROUND`: the new centroids for one Lloyd round.
#[derive(Debug, PartialEq)]
pub(crate) struct Round {
    pub centroids: Vec<f64>,
    /// The session trace ID (0 = unset), repeated per round so shard
    /// events stay attributable even on long fits.
    pub trace: u64,
}

impl Round {
    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        put_f64s(&mut buf, &self.centroids);
        put_u64(&mut buf, self.trace);
        buf
    }

    pub(crate) fn decode(body: &[u8]) -> Result<Self> {
        let mut r = Rd::new(body);
        let centroids = r.f64s()?;
        let trace = r.u64()?;
        r.finish()?;
        Ok(Round { centroids, trace })
    }
}

/// `ROUND_OK`: one round's shard result — build/scan counters, the
/// moved list (global indices, ascending), and optional partials.
#[derive(Debug, PartialEq)]
pub(crate) struct RoundOk {
    pub build_ctr: Counters,
    pub scan_ctr: Counters,
    pub moved: Vec<Moved>,
    pub partials: Vec<ChunkPartial>,
    /// The session trace ID, echoed back from `ROUND`.
    pub trace: u64,
}

impl RoundOk {
    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        put_counters(&mut buf, &self.build_ctr);
        put_counters(&mut buf, &self.scan_ctr);
        put_moved(&mut buf, &self.moved);
        put_partials(&mut buf, &self.partials);
        put_u64(&mut buf, self.trace);
        buf
    }

    pub(crate) fn decode(body: &[u8]) -> Result<Self> {
        let mut r = Rd::new(body);
        let build_ctr = read_counters(&mut r)?;
        let scan_ctr = read_counters(&mut r)?;
        let moved = read_moved(&mut r)?;
        let partials = read_partials(&mut r)?;
        let trace = r.u64()?;
        r.finish()?;
        Ok(RoundOk {
            build_ctr,
            scan_ctr,
            moved,
            partials,
            trace,
        })
    }
}

/// `STATS`: drain the shard's observability state. `since` is the last
/// event sequence number the caller has already seen (0 = everything
/// still in the ring), mirroring `GET /v1/events?since=` on the serve
/// shim.
#[derive(Debug, PartialEq)]
pub(crate) struct Stats {
    pub since: u64,
}

impl Stats {
    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        put_u64(&mut buf, self.since);
        buf
    }

    pub(crate) fn decode(body: &[u8]) -> Result<Self> {
        let mut r = Rd::new(body);
        let since = r.u64()?;
        r.finish()?;
        Ok(Stats { since })
    }
}

/// `STATS_OK`: the shard's metric families in the Prometheus text
/// format plus its event ring (after `since`) as the standard
/// events-JSON document.
#[derive(Debug, PartialEq)]
pub(crate) struct StatsOk {
    pub metrics: String,
    pub events: String,
}

impl StatsOk {
    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        put_str(&mut buf, &self.metrics);
        put_str(&mut buf, &self.events);
        buf
    }

    pub(crate) fn decode(body: &[u8]) -> Result<Self> {
        let mut r = Rd::new(body);
        let metrics = r.str()?;
        let events = r.str()?;
        r.finish()?;
        Ok(StatsOk { metrics, events })
    }
}

/// `ERR`: a typed failure message.
pub(crate) fn encode_err(msg: &str) -> Vec<u8> {
    let mut buf = Vec::new();
    put_str(&mut buf, msg);
    buf
}

pub(crate) fn decode_err(body: &[u8]) -> String {
    let mut r = Rd::new(body);
    r.str().unwrap_or_else(|_| "malformed error frame".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_ok_roundtrip() {
        let msg = OpenOk {
            n: 1000,
            d: 8,
            lo: 250,
            hi: 500,
            width: ElemWidth::F32,
            name: "blobs".into(),
        };
        assert_eq!(OpenOk::decode(&msg.encode()).unwrap(), msg);
    }

    #[test]
    fn lease_and_block_roundtrip() {
        let lease = Lease { lo: 7, len: 3 };
        assert_eq!(Lease::decode(&lease.encode()).unwrap(), lease);
        let block = Block {
            lo: 7,
            len: 2,
            width: ElemWidth::F64,
            rows: (0..2 * 3 * 8).map(|b| b as u8).collect(),
            norms: vec![1.25, -0.5],
        };
        assert_eq!(Block::decode(&block.encode(), 3).unwrap(), block);
    }

    #[test]
    fn fit_messages_roundtrip() {
        let init = FitInit {
            alg: "exp-ns".into(),
            k: 3,
            d: 2,
            seed: 42,
            hist_cap: 17,
            want_partials: true,
            centroids: vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
            trace: 0xDEAD_BEEF,
        };
        assert_eq!(FitInit::decode(&init.encode()).unwrap(), init);
        let ctr = Counters {
            assignment: 10,
            centroid: 3,
            displacement: 4,
            init: 9,
        };
        let ok = FitOk {
            build_ctr: ctr,
            scan_ctr: Counters::default(),
            assignments: vec![0, 2, 1],
            partials: vec![ChunkPartial {
                chunk: 5,
                sums: vec![1.0; 6],
                counts: vec![2, 0, 1],
            }],
            trace: 0xDEAD_BEEF,
        };
        assert_eq!(FitOk::decode(&ok.encode()).unwrap(), ok);
        let rok = RoundOk {
            build_ctr: ctr,
            scan_ctr: ctr,
            moved: vec![Moved {
                i: 9,
                from: 1,
                to: 0,
            }],
            partials: Vec::new(),
            trace: 0xDEAD_BEEF,
        };
        assert_eq!(RoundOk::decode(&rok.encode()).unwrap(), rok);
        let round = Round {
            centroids: vec![1.5, -2.5],
            trace: 7,
        };
        assert_eq!(Round::decode(&round.encode()).unwrap(), round);
    }

    #[test]
    fn stats_messages_roundtrip() {
        let req = Stats { since: 42 };
        assert_eq!(Stats::decode(&req.encode()).unwrap(), req);
        let ok = StatsOk {
            metrics: "# HELP x y\nx 1\n".into(),
            events: r#"{"ok":true,"last":0,"events":[]}"#.into(),
        };
        assert_eq!(StatsOk::decode(&ok.encode()).unwrap(), ok);
    }

    #[test]
    fn decode_rejects_truncation_and_trailing_bytes() {
        let msg = OpenOk {
            n: 10,
            d: 2,
            lo: 0,
            hi: 10,
            width: ElemWidth::F64,
            name: "x".into(),
        };
        let mut bytes = msg.encode();
        assert!(OpenOk::decode(&bytes[..bytes.len() - 1]).is_err());
        bytes.push(0);
        assert!(OpenOk::decode(&bytes).is_err());
    }

    #[test]
    fn hostile_length_does_not_allocate() {
        // an f64s count of u64::MAX must fail the bounds check (and not
        // attempt a 2^67-byte allocation)
        let mut body = Vec::new();
        put_str(&mut body, "sta");
        put_u64(&mut body, 2);
        put_u64(&mut body, 2);
        put_u64(&mut body, 0);
        put_u64(&mut body, 0);
        body.push(0);
        put_u64(&mut body, u64::MAX); // centroids count
        assert!(FitInit::decode(&body).is_err());
    }

    #[test]
    fn err_frame_roundtrip() {
        assert_eq!(decode_err(&encode_err("shard down")), "shard down");
    }
}
