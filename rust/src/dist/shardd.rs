//! The shard server: owns one row range of an `.ekb` file and serves
//! both planes of the dist protocol.
//!
//! One process (or in-process [`shardd`] call, for tests) per shard.
//! The server opens the *full* file through the out-of-core seam —
//! global row indices stay valid — but answers only for its configured
//! range `[lo, hi)`:
//!
//! * **data plane** (`OPEN`/`LEASE`): stream row blocks at the file's
//!   storage width plus sidecar-exact f64 squared norms, so a remote
//!   [`NetSource`](crate::dist::netsource::NetSource) cursor sees
//!   exactly the bytes a local source would;
//! * **compute plane** (`FIT_INIT`/`ROUND`): run the local assignment
//!   scan — the same [`run_shards`] the single-node engine uses, over
//!   thread-shards offset to global indices — and return counters,
//!   moved lists (global indices), and optionally per-global-chunk
//!   partial sums computed by the shared
//!   [`scan_chunk`](crate::coordinator::update::scan_chunk) loop.
//!
//! Connections are handled by one scoped thread each (shards talk to
//! exactly one coordinator; a fixed acceptor budget could deadlock a
//! coordinator whose workers hold data-plane connections to several
//! shards at once). Compute-plane work is serialised behind a mutex —
//! the worker pool is one resource, and nested/concurrent broadcasts
//! are not a thing it supports. A `SHUTDOWN` frame (tests) or process
//! kill (CI) stops the server; the accept loop polls a nonblocking
//! listener so shutdown can never strand it.

use std::fs::File;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::algorithms::common::{AssignStep, Requirements};
use crate::algorithms::Algorithm;
use crate::coordinator::groups::GroupData;
use crate::coordinator::history::HistoryStore;
use crate::coordinator::parallel::run_shards;
use crate::coordinator::sched::{ScanPlan, AUTO_SCAN_SHARDS};
use crate::coordinator::round_ctx::RoundCtxOwner;
use crate::coordinator::update::{chunk_len, scan_chunk, Partial};
use crate::data::io::{read_bin_header, ElemWidth};
use crate::data::ooc::{open_ooc_described, stem_name, OocMode, DEFAULT_WINDOW_ROWS};
use crate::data::{BlockCursor, DataSource};
use crate::error::{EakmError, Result};
use crate::metrics::Counters;
use crate::net::frame::{send_frame, Frame, FrameReader};
use crate::runtime::pool::WorkerPool;
use crate::runtime::rt::resolve_threads;

use super::wire::{self, tag, Block, ChunkPartial, FitInit, FitOk, Lease, OpenOk, Round, RoundOk};

/// How often a connection read wakes to re-check the shutdown flag.
const READ_POLL: Duration = Duration::from_millis(100);
/// How long an idle accept loop sleeps between polls.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Configuration for one shard server (the `eakm shardd` subcommand).
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// The `.ekb` file (every shard has the full file; the range below
    /// selects which rows this shard owns).
    pub data: PathBuf,
    /// Owned global row range `[lo, hi)`.
    pub rows: (usize, usize),
    /// Bind address (`host:port`; port 0 picks an ephemeral port).
    pub addr: String,
    /// Worker threads for the local scan (0 = auto).
    pub threads: usize,
    /// Out-of-core backend for reading the file.
    pub mode: OocMode,
    /// Resident-window rows for the chunked backend.
    pub window_rows: usize,
}

impl ShardConfig {
    /// A loopback config for `[lo, hi)` of `data` with serial scans.
    pub fn new(data: PathBuf, lo: usize, hi: usize) -> Self {
        ShardConfig {
            data,
            rows: (lo, hi),
            addr: "127.0.0.1:0".to_string(),
            threads: 1,
            mode: OocMode::Auto,
            window_rows: DEFAULT_WINDOW_ROWS,
        }
    }
}

/// Everything the connection handlers share.
struct ShardState<'a> {
    src: &'a dyn DataSource,
    pool: &'a WorkerPool,
    /// Serialises compute-plane pool use across connections.
    compute: &'a Mutex<()>,
    shutdown: &'a AtomicBool,
    /// Global dataset shape.
    n: usize,
    d: usize,
    /// Owned row range.
    lo: usize,
    hi: usize,
    /// Storage width of the backing file (rows travel at this width).
    width: ElemWidth,
    name: String,
}

/// One connection's fit session (compute plane). All of it is a
/// deterministic function of what came over the wire plus the shard's
/// row range — shards never consult local row counts for geometry.
struct FitSession {
    algs: Vec<Box<dyn AssignStep>>,
    plan: ScanPlan,
    /// Local assignments: index 0 is global row `state.lo`.
    a: Vec<u32>,
    ctx: RoundCtxOwner,
    history: Option<HistoryStore>,
    req: Requirements,
    want_partials: bool,
    k: usize,
}

/// Run a shard server until a `SHUTDOWN` frame: open the file, bind
/// `cfg.addr`, call `on_ready` with the bound address, serve. The
/// caller's thread blocks for the server's lifetime (tests spawn it).
pub fn shardd<F: FnOnce(SocketAddr)>(cfg: &ShardConfig, on_ready: F) -> Result<()> {
    let hdr = read_bin_header(&mut BufReader::new(File::open(&cfg.data)?), &cfg.data)?;
    let src = open_ooc_described(&cfg.data, cfg.mode, cfg.window_rows)?;
    let (lo, hi) = cfg.rows;
    if lo >= hi || hi > src.n() {
        return Err(EakmError::Config(format!(
            "shard rows {lo}..{hi} invalid for n={}",
            src.n()
        )));
    }
    let pool = WorkerPool::new(resolve_threads(cfg.threads));
    let listener = TcpListener::bind(&cfg.addr)?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let compute = Mutex::new(());
    let shutdown = AtomicBool::new(false);
    let state = ShardState {
        src: src.as_ref(),
        pool: &pool,
        compute: &compute,
        shutdown: &shutdown,
        n: src.n(),
        d: src.d(),
        lo,
        hi,
        width: hdr.width,
        name: stem_name(&cfg.data),
    };
    on_ready(addr);
    let st = &state;
    std::thread::scope(|scope| loop {
        if st.shutdown.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                scope.spawn(move || handle_conn(stream, st));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    });
    Ok(())
}

/// Reply with a typed `ERR` frame; `false` means the peer is gone.
fn send_err(stream: &mut TcpStream, msg: &str) -> bool {
    send_frame(stream, tag::ERR, &wire::encode_err(msg))
}

fn handle_conn<'a>(stream: TcpStream, st: &ShardState<'a>) {
    if stream.set_read_timeout(Some(READ_POLL)).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = FrameReader::new(read_half, wire::MAX_FRAME);
    let mut write_half = stream;
    // per-connection planes: one lazy data-plane cursor, one fit session
    let mut cursor: Option<Box<dyn BlockCursor + 'a>> = None;
    let mut session: Option<FitSession> = None;
    loop {
        if st.shutdown.load(Ordering::Acquire) {
            return;
        }
        match reader.next_frame(Instant::now() + READ_POLL) {
            Frame::Idle => continue,
            Frame::Eof => return,
            Frame::TooLong => {
                let _ = send_err(&mut write_half, "oversized or malformed frame");
                return;
            }
            Frame::Msg(t, body) => {
                let ok = match t {
                    tag::OPEN => handle_open(&mut write_half, st, &mut cursor),
                    tag::LEASE => handle_lease(&mut write_half, st, &mut cursor, &body),
                    tag::FIT_INIT => handle_fit_init(&mut write_half, st, &mut session, &body),
                    tag::ROUND => handle_round(&mut write_half, st, &mut session, &body),
                    tag::FIT_END => {
                        session = None;
                        send_frame(&mut write_half, tag::OK, &[])
                    }
                    tag::SHUTDOWN => {
                        let _ = send_frame(&mut write_half, tag::OK, &[]);
                        st.shutdown.store(true, Ordering::Release);
                        return;
                    }
                    other => send_err(&mut write_half, &format!("unknown frame tag {other}")),
                };
                if !ok {
                    return;
                }
            }
        }
    }
}

// ---- data plane -------------------------------------------------------

fn handle_open<'a>(
    w: &mut TcpStream,
    st: &ShardState<'a>,
    cursor: &mut Option<Box<dyn BlockCursor + 'a>>,
) -> bool {
    *cursor = Some(st.src.open(st.lo, st.hi - st.lo));
    let reply = OpenOk {
        n: st.n,
        d: st.d,
        lo: st.lo,
        hi: st.hi,
        width: st.width,
        name: st.name.clone(),
    };
    send_frame(w, tag::OPEN_OK, &reply.encode())
}

fn handle_lease<'a>(
    w: &mut TcpStream,
    st: &ShardState<'a>,
    cursor: &mut Option<Box<dyn BlockCursor + 'a>>,
    body: &[u8],
) -> bool {
    let lease = match Lease::decode(body) {
        Ok(l) => l,
        Err(e) => return send_err(w, &e.to_string()),
    };
    let Some(cur) = cursor.as_mut() else {
        return send_err(w, "LEASE before OPEN");
    };
    let end = match lease.lo.checked_add(lease.len) {
        Some(end) => end,
        None => return send_err(w, "lease range overflows"),
    };
    if lease.len == 0 || lease.lo < st.lo || end > st.hi {
        return send_err(
            w,
            &format!(
                "lease {}..{end} outside shard rows {}..{}",
                lease.lo, st.lo, st.hi
            ),
        );
    }
    let block = cur.lease(lease.lo, lease.len);
    // rows travel at the file's storage width: for f32 files the leased
    // f64 values are exact widenings, so narrowing back is lossless and
    // the remote widen reproduces identical f64 bits
    let mut rows = Vec::with_capacity(block.rows().len() * st.width.bytes());
    match st.width {
        ElemWidth::F64 => {
            for &v in block.rows() {
                rows.extend_from_slice(&v.to_le_bytes());
            }
        }
        ElemWidth::F32 => {
            for &v in block.rows() {
                rows.extend_from_slice(&(v as f32).to_le_bytes());
            }
        }
    }
    let reply = Block {
        lo: lease.lo,
        len: lease.len,
        width: st.width,
        rows,
        norms: block.sqnorms().to_vec(),
    };
    send_frame(w, tag::BLOCK, &reply.encode())
}

// ---- compute plane ----------------------------------------------------

/// Per-global-chunk partial sums for the shard's rows, computed with
/// the shared [`scan_chunk`] loop over the *global* chunk grid (the
/// coordinator only asks for partials when every shard boundary lands
/// on a chunk boundary, so chunks never straddle shards).
fn chunk_partials(st: &ShardState<'_>, s: &FitSession, d: usize) -> Vec<ChunkPartial> {
    let clen = chunk_len(st.n);
    let c0 = st.lo / clen;
    let c1 = st.hi.div_ceil(clen);
    struct Task {
        c: usize,
        part: Partial,
    }
    let mut tasks: Vec<Task> = (c0..c1)
        .map(|c| Task {
            c,
            part: Partial::new(s.k, d),
        })
        .collect();
    st.pool.run_tasks(&mut tasks, |_, t| {
        let lo = t.c * clen;
        let hi = ((t.c + 1) * clen).min(st.hi);
        scan_chunk(st.src, &s.a, st.lo, lo, hi - lo, d, &mut t.part);
    });
    tasks
        .into_iter()
        .map(|t| ChunkPartial {
            chunk: t.c as u64,
            sums: t.part.sums,
            counts: t.part.counts,
        })
        .collect()
}

fn handle_fit_init(
    w: &mut TcpStream,
    st: &ShardState<'_>,
    session: &mut Option<FitSession>,
    body: &[u8],
) -> bool {
    let init = match FitInit::decode(body) {
        Ok(m) => m,
        Err(e) => return send_err(w, &e.to_string()),
    };
    let alg = match Algorithm::parse(&init.alg) {
        Some(Algorithm::Auto) | None => {
            // Auto must be resolved by the coordinator (it depends on d
            // only, but resolving it once keeps every shard identical
            // by construction)
            return send_err(w, &format!("unknown or unresolved algorithm {:?}", init.alg));
        }
        Some(alg) => alg,
    };
    if init.d != st.d {
        return send_err(w, &format!("dimension mismatch: fit d={} file d={}", init.d, st.d));
    }
    if init.k == 0 || init.centroids.len() != init.k * init.d {
        return send_err(
            w,
            &format!(
                "centroids have {} values, expected k×d = {}",
                init.centroids.len(),
                init.k * init.d
            ),
        );
    }
    // the pool is one resource: all compute-plane work is serialised
    let _guard = st.compute.lock().unwrap();
    let (k, d) = (init.k, init.d);
    let g = GroupData::group_count(k);
    let probe = alg.make_shard(0, 0, k, g);
    let req = probe.requirements();
    drop(probe);

    let mut build_ctr = Counters::default();
    let mut ctx = RoundCtxOwner::new(init.centroids, k, d);
    if req.groups {
        ctx.groups = Some(GroupData::build(&ctx.centroids, k, d, init.seed, &mut build_ctr));
    }
    let mut history = if req.history {
        // the cap came over the wire: it is a function of the *global*
        // row count, which this shard must not derive locally
        let (group_of, gh) = if req.group_history {
            let gd = ctx.groups.as_ref().expect("group_history requires groups");
            (gd.group_of.clone(), gd.g())
        } else {
            (Vec::new(), 0)
        };
        Some(HistoryStore::new(k, d, init.hist_cap, group_of, gh))
    } else {
        None
    };
    if let Some(h) = history.as_mut() {
        ctx.history = Some(h.begin(&ctx.centroids));
    }

    // over-decomposed plan across the owned range, offset to global
    // indices so the algorithms report global sample indices in their
    // moved lists; geometry is a function of the range length alone —
    // never of this node's pool width
    let mut plan = ScanPlan::for_range(st.lo, st.hi - st.lo, AUTO_SCAN_SHARDS);
    let mut algs: Vec<Box<dyn AssignStep>> = plan
        .shards()
        .iter()
        .map(|&(slo, len)| alg.make_shard(slo, len, k, g))
        .collect();

    let mut a = vec![0u32; st.hi - st.lo];
    let sh = ctx.shared(st.src);
    let (scan_ctr, _) = run_shards(st.pool, &mut algs, &mut plan, &mut a, &sh, true);
    drop(sh);

    let s = FitSession {
        algs,
        plan,
        a,
        ctx,
        history,
        req,
        want_partials: init.want_partials,
        k,
    };
    let partials = if s.want_partials {
        chunk_partials(st, &s, d)
    } else {
        Vec::new()
    };
    let reply = FitOk {
        build_ctr,
        scan_ctr,
        assignments: s.a.clone(),
        partials,
    };
    *session = Some(s);
    send_frame(w, tag::FIT_OK, &reply.encode())
}

fn handle_round(
    w: &mut TcpStream,
    st: &ShardState<'_>,
    session: &mut Option<FitSession>,
    body: &[u8],
) -> bool {
    let round = match Round::decode(body) {
        Ok(m) => m,
        Err(e) => return send_err(w, &e.to_string()),
    };
    let Some(s) = session.as_mut() else {
        return send_err(w, "ROUND before FIT_INIT");
    };
    let d = st.d;
    if round.centroids.len() != s.k * d {
        return send_err(
            w,
            &format!(
                "centroids have {} values, expected k×d = {}",
                round.centroids.len(),
                s.k * d
            ),
        );
    }
    let _guard = st.compute.lock().unwrap();
    // centroid-side rebuilds: pure functions of (centroids, k, d, seed)
    // — every shard computes identical structures and counters; the
    // coordinator merges the counters once and cross-checks equality
    let mut build_ctr = Counters::default();
    s.ctx
        .advance_centroids_pooled(round.centroids, d, &mut build_ctr, st.pool);
    s.ctx.rebuild(&s.req, d, &mut build_ctr, st.pool);
    if let Some(h) = s.history.as_mut() {
        s.ctx.history = Some(h.advance_pooled(&s.ctx.centroids, &mut build_ctr, st.pool));
    }
    let sh = s.ctx.shared(st.src);
    let (scan_ctr, moved) = run_shards(st.pool, &mut s.algs, &mut s.plan, &mut s.a, &sh, false);
    drop(sh);
    let partials = if s.want_partials && s.req.full_update {
        chunk_partials(st, s, d)
    } else {
        Vec::new()
    };
    let reply = RoundOk {
        build_ctr,
        scan_ctr,
        moved,
        partials,
    };
    send_frame(w, tag::ROUND_OK, &reply.encode())
}
