//! The shard server: owns one row range of an `.ekb` file and serves
//! both planes of the dist protocol.
//!
//! One process (or in-process [`shardd`] call, for tests) per shard.
//! The server opens the *full* file through the out-of-core seam —
//! global row indices stay valid — but answers only for its configured
//! range `[lo, hi)`:
//!
//! * **data plane** (`OPEN`/`LEASE`): stream row blocks at the file's
//!   storage width plus sidecar-exact f64 squared norms, so a remote
//!   [`NetSource`](crate::dist::netsource::NetSource) cursor sees
//!   exactly the bytes a local source would;
//! * **compute plane** (`FIT_INIT`/`ROUND`): run the local assignment
//!   scan — the same [`run_shards`] the single-node engine uses, over
//!   thread-shards offset to global indices — and return counters,
//!   moved lists (global indices), and optionally per-global-chunk
//!   partial sums computed by the shared
//!   [`scan_chunk`](crate::coordinator::update::scan_chunk) loop.
//!
//! Connections are handled by one scoped thread each (shards talk to
//! exactly one coordinator; a fixed acceptor budget could deadlock a
//! coordinator whose workers hold data-plane connections to several
//! shards at once). Compute-plane work is serialised behind a mutex —
//! the worker pool is one resource, and nested/concurrent broadcasts
//! are not a thing it supports. A `SHUTDOWN` frame (tests) or process
//! kill (CI) stops the server; the accept loop polls a nonblocking
//! listener so shutdown can never strand it.

use std::fs::File;
use std::io::{BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::algorithms::common::{AssignStep, Requirements};
use crate::algorithms::Algorithm;
use crate::coordinator::groups::GroupData;
use crate::coordinator::history::HistoryStore;
use crate::coordinator::parallel::run_shards;
use crate::coordinator::sched::{ScanPlan, AUTO_SCAN_SHARDS};
use crate::coordinator::round_ctx::RoundCtxOwner;
use crate::coordinator::update::{chunk_len, scan_chunk, Partial};
use crate::data::io::{read_bin_header, ElemWidth};
use crate::data::ooc::{open_ooc_described, stem_name, OocMode, DEFAULT_WINDOW_ROWS};
use crate::data::{BlockCursor, DataSource};
use crate::error::{EakmError, Result};
use crate::metrics::Counters;
use crate::net::frame::{send_frame, Frame, FrameReader};
use crate::obs::{
    events_json, Counter, EventLog, Histogram, Registry, TraceId, Value, DEFAULT_EVENT_CAP,
};
use crate::runtime::pool::WorkerPool;
use crate::runtime::rt::resolve_threads;

use super::wire::{
    self, tag, Block, ChunkPartial, FitInit, FitOk, Lease, OpenOk, Round, RoundOk, Stats, StatsOk,
};

/// How often a connection read wakes to re-check the shutdown flag.
const READ_POLL: Duration = Duration::from_millis(100);
/// How long an idle accept loop sleeps between polls.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Configuration for one shard server (the `eakm shardd` subcommand).
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// The `.ekb` file (every shard has the full file; the range below
    /// selects which rows this shard owns).
    pub data: PathBuf,
    /// Owned global row range `[lo, hi)`.
    pub rows: (usize, usize),
    /// Bind address (`host:port`; port 0 picks an ephemeral port).
    pub addr: String,
    /// Worker threads for the local scan (0 = auto).
    pub threads: usize,
    /// Out-of-core backend for reading the file.
    pub mode: OocMode,
    /// Resident-window rows for the chunked backend.
    pub window_rows: usize,
    /// Optional bind address for a tiny metrics HTTP listener serving
    /// `GET /metrics` (Prometheus text) and `GET /v1/events?since=` —
    /// the same observability the `STATS` wire frame exposes, for
    /// scrapers that speak HTTP rather than the dist protocol.
    pub metrics_addr: Option<String>,
}

impl ShardConfig {
    /// A loopback config for `[lo, hi)` of `data` with serial scans.
    pub fn new(data: PathBuf, lo: usize, hi: usize) -> Self {
        ShardConfig {
            data,
            rows: (lo, hi),
            addr: "127.0.0.1:0".to_string(),
            threads: 1,
            mode: OocMode::Auto,
            window_rows: DEFAULT_WINDOW_ROWS,
            metrics_addr: None,
        }
    }
}

/// The shard server's observability block: a long-lived [`Registry`]
/// (counters registered once, recorded forever), the round-scan latency
/// histogram, and the bounded event ring. Everything here is off the
/// determinism path — recording never feeds back into fit state.
struct ShardObs {
    registry: Registry,
    events: Arc<EventLog>,
    leases: Arc<Counter>,
    lease_rows: Arc<Counter>,
    fits: Arc<Counter>,
    rounds: Arc<Counter>,
    dist_assignment: Arc<Counter>,
    dist_centroid: Arc<Counter>,
    dist_displacement: Arc<Counter>,
    dist_init: Arc<Counter>,
    scan_hist: Arc<Histogram>,
}

impl ShardObs {
    fn new(lo: usize, hi: usize) -> ShardObs {
        let registry = Registry::new();
        registry.sample_gauge(
            "eakm_shard_rows",
            "Rows owned by this shard (hi - lo of its global range).",
            &[],
            (hi - lo) as f64,
        );
        let leases = registry.counter(
            "eakm_shard_leases_total",
            "Data-plane row blocks leased to remote cursors.",
            &[],
        );
        let lease_rows = registry.counter(
            "eakm_shard_lease_rows_total",
            "Data-plane rows streamed to remote cursors.",
            &[],
        );
        let fits = registry.counter(
            "eakm_shard_fits_total",
            "Compute-plane fit sessions started (FIT_INIT frames).",
            &[],
        );
        let rounds = registry.counter(
            "eakm_shard_rounds_total",
            "Compute-plane assignment rounds served (ROUND frames).",
            &[],
        );
        let mk_site = |site: &str| {
            registry.counter(
                "eakm_shard_distance_calcs_total",
                "Distance calculations on this shard, by accounting site.",
                &[("site", site)],
            )
        };
        let dist_assignment = mk_site("assignment");
        let dist_centroid = mk_site("centroid");
        let dist_displacement = mk_site("displacement");
        let dist_init = mk_site("init");
        let scan_hist = registry.histogram(
            "eakm_shard_round_micros",
            "Wall time of one compute-plane round on this shard (scan + \
             centroid-side rebuilds), microseconds.",
            &[],
        );
        ShardObs {
            registry,
            events: Arc::new(EventLog::new(DEFAULT_EVENT_CAP)),
            leases,
            lease_rows,
            fits,
            rounds,
            dist_assignment,
            dist_centroid,
            dist_displacement,
            dist_init,
            scan_hist,
        }
    }

    /// Fold one round's (or round 0's) counters into the live totals.
    fn add_counters(&self, c: &Counters) {
        self.dist_assignment.add(c.assignment);
        self.dist_centroid.add(c.centroid);
        self.dist_displacement.add(c.displacement);
        self.dist_init.add(c.init);
    }
}

/// Everything the connection handlers share.
struct ShardState<'a> {
    src: &'a dyn DataSource,
    pool: &'a WorkerPool,
    /// Serialises compute-plane pool use across connections.
    compute: &'a Mutex<()>,
    shutdown: &'a AtomicBool,
    /// Global dataset shape.
    n: usize,
    d: usize,
    /// Owned row range.
    lo: usize,
    hi: usize,
    /// Storage width of the backing file (rows travel at this width).
    width: ElemWidth,
    name: String,
    obs: &'a ShardObs,
}

/// One connection's fit session (compute plane). All of it is a
/// deterministic function of what came over the wire plus the shard's
/// row range — shards never consult local row counts for geometry.
struct FitSession {
    algs: Vec<Box<dyn AssignStep>>,
    plan: ScanPlan,
    /// Local assignments: index 0 is global row `state.lo`.
    a: Vec<u32>,
    ctx: RoundCtxOwner,
    history: Option<HistoryStore>,
    req: Requirements,
    want_partials: bool,
    k: usize,
    /// Coordinator-minted trace ID for this fit (0 = unset).
    trace: u64,
    /// Rounds served in this session (round 0 is the FIT_INIT scan).
    rounds: u64,
}

/// Run a shard server until a `SHUTDOWN` frame: open the file, bind
/// `cfg.addr`, call `on_ready` with the bound address, serve. The
/// caller's thread blocks for the server's lifetime (tests spawn it).
pub fn shardd<F: FnOnce(SocketAddr)>(cfg: &ShardConfig, on_ready: F) -> Result<()> {
    let hdr = read_bin_header(&mut BufReader::new(File::open(&cfg.data)?), &cfg.data)?;
    let src = open_ooc_described(&cfg.data, cfg.mode, cfg.window_rows)?;
    let (lo, hi) = cfg.rows;
    if lo >= hi || hi > src.n() {
        return Err(EakmError::Config(format!(
            "shard rows {lo}..{hi} invalid for n={}",
            src.n()
        )));
    }
    let pool = WorkerPool::new(resolve_threads(cfg.threads));
    let listener = TcpListener::bind(&cfg.addr)?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let metrics_listener = match &cfg.metrics_addr {
        Some(maddr) => {
            let l = TcpListener::bind(maddr)?;
            l.set_nonblocking(true)?;
            Some(l)
        }
        None => None,
    };
    let compute = Mutex::new(());
    let shutdown = AtomicBool::new(false);
    let obs = ShardObs::new(lo, hi);
    let state = ShardState {
        src: src.as_ref(),
        pool: &pool,
        compute: &compute,
        shutdown: &shutdown,
        n: src.n(),
        d: src.d(),
        lo,
        hi,
        width: hdr.width,
        name: stem_name(&cfg.data),
        obs: &obs,
    };
    on_ready(addr);
    let st = &state;
    std::thread::scope(|scope| {
        if let Some(ml) = metrics_listener {
            scope.spawn(move || serve_metrics_http(ml, st.obs, st.shutdown));
        }
        loop {
            if st.shutdown.load(Ordering::Acquire) {
                return;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    scope.spawn(move || handle_conn(stream, st));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        }
    });
    Ok(())
}

// ---- metrics listener -------------------------------------------------

/// One minimal HTTP/1.0 response (close-delimited via Content-Length).
fn http_response(status: &str, content_type: &str, body: &str) -> Vec<u8> {
    format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Serve `GET /metrics`, `GET /v1/events?since=N`, and `GET /healthz`
/// over plain HTTP until shutdown. One request per connection,
/// close-delimited — the minimum a Prometheus scraper or `curl` needs.
/// Runs entirely off the compute lock, so a mid-fit shard still answers
/// scrapes.
fn serve_metrics_http(listener: TcpListener, obs: &ShardObs, shutdown: &AtomicBool) {
    loop {
        if shutdown.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
                let response = match read_request_path(&mut stream) {
                    Some(target) => route_metrics_request(&target, obs),
                    None => http_response("400 Bad Request", "text/plain", "bad request\n"),
                };
                let _ = stream.write_all(&response);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Read one request head (capped at 8 KiB) and return the GET target.
fn read_request_path(stream: &mut TcpStream) -> Option<String> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    while !buf.windows(4).any(|w| w == b"\r\n\r\n") {
        if buf.len() > 8192 {
            return None;
        }
        match stream.read(&mut chunk) {
            Ok(0) | Err(_) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let mut parts = head.lines().next()?.split_whitespace();
    if parts.next()? != "GET" {
        return None;
    }
    parts.next().map(str::to_string)
}

fn route_metrics_request(target: &str, obs: &ShardObs) -> Vec<u8> {
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    match path {
        "/metrics" => http_response(
            "200 OK",
            "text/plain; version=0.0.4",
            &obs.registry.render(),
        ),
        "/v1/events" => {
            let since = query
                .split('&')
                .find_map(|kv| kv.strip_prefix("since="))
                .and_then(|v| v.parse::<u64>().ok())
                .unwrap_or(0);
            let body = events_json(&obs.events.since(since), obs.events.last_seq()).to_string();
            http_response("200 OK", "application/json", &body)
        }
        "/healthz" => http_response("200 OK", "application/json", "{\"ok\":true}"),
        _ => http_response("404 Not Found", "text/plain", "not found\n"),
    }
}

/// Reply with a typed `ERR` frame; `false` means the peer is gone.
fn send_err(stream: &mut TcpStream, msg: &str) -> bool {
    send_frame(stream, tag::ERR, &wire::encode_err(msg))
}

fn handle_conn<'a>(stream: TcpStream, st: &ShardState<'a>) {
    if stream.set_read_timeout(Some(READ_POLL)).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = FrameReader::new(read_half, wire::MAX_FRAME);
    let mut write_half = stream;
    // per-connection planes: one lazy data-plane cursor, one fit session
    let mut cursor: Option<Box<dyn BlockCursor + 'a>> = None;
    let mut session: Option<FitSession> = None;
    loop {
        if st.shutdown.load(Ordering::Acquire) {
            return;
        }
        match reader.next_frame(Instant::now() + READ_POLL) {
            Frame::Idle => continue,
            Frame::Eof => return,
            Frame::TooLong => {
                let _ = send_err(&mut write_half, "oversized or malformed frame");
                return;
            }
            Frame::Msg(t, body) => {
                let ok = match t {
                    tag::OPEN => handle_open(&mut write_half, st, &mut cursor),
                    tag::LEASE => handle_lease(&mut write_half, st, &mut cursor, &body),
                    tag::FIT_INIT => handle_fit_init(&mut write_half, st, &mut session, &body),
                    tag::ROUND => handle_round(&mut write_half, st, &mut session, &body),
                    tag::FIT_END => {
                        session = None;
                        send_frame(&mut write_half, tag::OK, &[])
                    }
                    tag::STATS => handle_stats(&mut write_half, st, &body),
                    tag::SHUTDOWN => {
                        let _ = send_frame(&mut write_half, tag::OK, &[]);
                        st.shutdown.store(true, Ordering::Release);
                        return;
                    }
                    other => send_err(&mut write_half, &format!("unknown frame tag {other}")),
                };
                if !ok {
                    return;
                }
            }
        }
    }
}

// ---- data plane -------------------------------------------------------

fn handle_open<'a>(
    w: &mut TcpStream,
    st: &ShardState<'a>,
    cursor: &mut Option<Box<dyn BlockCursor + 'a>>,
) -> bool {
    *cursor = Some(st.src.open(st.lo, st.hi - st.lo));
    let reply = OpenOk {
        n: st.n,
        d: st.d,
        lo: st.lo,
        hi: st.hi,
        width: st.width,
        name: st.name.clone(),
    };
    send_frame(w, tag::OPEN_OK, &reply.encode())
}

fn handle_lease<'a>(
    w: &mut TcpStream,
    st: &ShardState<'a>,
    cursor: &mut Option<Box<dyn BlockCursor + 'a>>,
    body: &[u8],
) -> bool {
    let lease = match Lease::decode(body) {
        Ok(l) => l,
        Err(e) => return send_err(w, &e.to_string()),
    };
    let Some(cur) = cursor.as_mut() else {
        return send_err(w, "LEASE before OPEN");
    };
    let end = match lease.lo.checked_add(lease.len) {
        Some(end) => end,
        None => return send_err(w, "lease range overflows"),
    };
    if lease.len == 0 || lease.lo < st.lo || end > st.hi {
        return send_err(
            w,
            &format!(
                "lease {}..{end} outside shard rows {}..{}",
                lease.lo, st.lo, st.hi
            ),
        );
    }
    st.obs.leases.inc();
    st.obs.lease_rows.add(lease.len as u64);
    let block = cur.lease(lease.lo, lease.len);
    // rows travel at the file's storage width: for f32 files the leased
    // f64 values are exact widenings, so narrowing back is lossless and
    // the remote widen reproduces identical f64 bits
    let mut rows = Vec::with_capacity(block.rows().len() * st.width.bytes());
    match st.width {
        ElemWidth::F64 => {
            for &v in block.rows() {
                rows.extend_from_slice(&v.to_le_bytes());
            }
        }
        ElemWidth::F32 => {
            for &v in block.rows() {
                rows.extend_from_slice(&(v as f32).to_le_bytes());
            }
        }
    }
    let reply = Block {
        lo: lease.lo,
        len: lease.len,
        width: st.width,
        rows,
        norms: block.sqnorms().to_vec(),
    };
    send_frame(w, tag::BLOCK, &reply.encode())
}

// ---- compute plane ----------------------------------------------------

/// Per-global-chunk partial sums for the shard's rows, computed with
/// the shared [`scan_chunk`] loop over the *global* chunk grid (the
/// coordinator only asks for partials when every shard boundary lands
/// on a chunk boundary, so chunks never straddle shards).
fn chunk_partials(st: &ShardState<'_>, s: &FitSession, d: usize) -> Vec<ChunkPartial> {
    let clen = chunk_len(st.n);
    let c0 = st.lo / clen;
    let c1 = st.hi.div_ceil(clen);
    struct Task {
        c: usize,
        part: Partial,
    }
    let mut tasks: Vec<Task> = (c0..c1)
        .map(|c| Task {
            c,
            part: Partial::new(s.k, d),
        })
        .collect();
    st.pool.run_tasks(&mut tasks, |_, t| {
        let lo = t.c * clen;
        let hi = ((t.c + 1) * clen).min(st.hi);
        scan_chunk(st.src, &s.a, st.lo, lo, hi - lo, d, &mut t.part);
    });
    tasks
        .into_iter()
        .map(|t| ChunkPartial {
            chunk: t.c as u64,
            sums: t.part.sums,
            counts: t.part.counts,
        })
        .collect()
}

fn handle_fit_init(
    w: &mut TcpStream,
    st: &ShardState<'_>,
    session: &mut Option<FitSession>,
    body: &[u8],
) -> bool {
    let init = match FitInit::decode(body) {
        Ok(m) => m,
        Err(e) => return send_err(w, &e.to_string()),
    };
    let alg = match Algorithm::parse(&init.alg) {
        Some(Algorithm::Auto) | None => {
            // Auto must be resolved by the coordinator (it depends on d
            // only, but resolving it once keeps every shard identical
            // by construction)
            return send_err(w, &format!("unknown or unresolved algorithm {:?}", init.alg));
        }
        Some(alg) => alg,
    };
    if init.d != st.d {
        return send_err(w, &format!("dimension mismatch: fit d={} file d={}", init.d, st.d));
    }
    if init.k == 0 || init.centroids.len() != init.k * init.d {
        return send_err(
            w,
            &format!(
                "centroids have {} values, expected k×d = {}",
                init.centroids.len(),
                init.k * init.d
            ),
        );
    }
    // the pool is one resource: all compute-plane work is serialised
    let _guard = st.compute.lock().unwrap();
    let t_fit = Instant::now();
    let (k, d) = (init.k, init.d);
    let g = GroupData::group_count(k);
    let probe = alg.make_shard(0, 0, k, g);
    let req = probe.requirements();
    drop(probe);

    let mut build_ctr = Counters::default();
    let mut ctx = RoundCtxOwner::new(init.centroids, k, d);
    if req.groups {
        ctx.groups = Some(GroupData::build(&ctx.centroids, k, d, init.seed, &mut build_ctr));
    }
    let mut history = if req.history {
        // the cap came over the wire: it is a function of the *global*
        // row count, which this shard must not derive locally
        let (group_of, gh) = if req.group_history {
            let gd = ctx.groups.as_ref().expect("group_history requires groups");
            (gd.group_of.clone(), gd.g())
        } else {
            (Vec::new(), 0)
        };
        Some(HistoryStore::new(k, d, init.hist_cap, group_of, gh))
    } else {
        None
    };
    if let Some(h) = history.as_mut() {
        ctx.history = Some(h.begin(&ctx.centroids));
    }

    // over-decomposed plan across the owned range, offset to global
    // indices so the algorithms report global sample indices in their
    // moved lists; geometry is a function of the range length alone —
    // never of this node's pool width
    let mut plan = ScanPlan::for_range(st.lo, st.hi - st.lo, AUTO_SCAN_SHARDS);
    let mut algs: Vec<Box<dyn AssignStep>> = plan
        .shards()
        .iter()
        .map(|&(slo, len)| alg.make_shard(slo, len, k, g))
        .collect();

    let mut a = vec![0u32; st.hi - st.lo];
    let sh = ctx.shared(st.src);
    let (scan_ctr, _) = run_shards(st.pool, &mut algs, &mut plan, &mut a, &sh, true);
    drop(sh);

    let s = FitSession {
        algs,
        plan,
        a,
        ctx,
        history,
        req,
        want_partials: init.want_partials,
        k,
        trace: init.trace,
        rounds: 0,
    };
    let partials = if s.want_partials {
        chunk_partials(st, &s, d)
    } else {
        Vec::new()
    };
    st.obs.fits.inc();
    st.obs.add_counters(&build_ctr);
    st.obs.add_counters(&scan_ctr);
    st.obs.scan_hist.record(t_fit.elapsed());
    st.obs.events.push(
        "shard_round",
        TraceId::from_u64(s.trace),
        vec![
            ("round", Value::U64(0)),
            ("alg", Value::Str(init.alg.clone())),
            ("k", Value::U64(k as u64)),
            ("dist_assignment", Value::U64(scan_ctr.assignment)),
            ("dist_init", Value::U64(scan_ctr.init)),
        ],
    );
    let reply = FitOk {
        build_ctr,
        scan_ctr,
        assignments: s.a.clone(),
        partials,
        trace: s.trace,
    };
    *session = Some(s);
    send_frame(w, tag::FIT_OK, &reply.encode())
}

fn handle_round(
    w: &mut TcpStream,
    st: &ShardState<'_>,
    session: &mut Option<FitSession>,
    body: &[u8],
) -> bool {
    let round = match Round::decode(body) {
        Ok(m) => m,
        Err(e) => return send_err(w, &e.to_string()),
    };
    let Some(s) = session.as_mut() else {
        return send_err(w, "ROUND before FIT_INIT");
    };
    let d = st.d;
    if round.centroids.len() != s.k * d {
        return send_err(
            w,
            &format!(
                "centroids have {} values, expected k×d = {}",
                round.centroids.len(),
                s.k * d
            ),
        );
    }
    let _guard = st.compute.lock().unwrap();
    let t_round = Instant::now();
    // centroid-side rebuilds: pure functions of (centroids, k, d, seed)
    // — every shard computes identical structures and counters; the
    // coordinator merges the counters once and cross-checks equality
    let mut build_ctr = Counters::default();
    s.ctx
        .advance_centroids_pooled(round.centroids, d, &mut build_ctr, st.pool);
    s.ctx.rebuild(&s.req, d, &mut build_ctr, st.pool);
    if let Some(h) = s.history.as_mut() {
        s.ctx.history = Some(h.advance_pooled(&s.ctx.centroids, &mut build_ctr, st.pool));
    }
    let sh = s.ctx.shared(st.src);
    let (scan_ctr, moved) = run_shards(st.pool, &mut s.algs, &mut s.plan, &mut s.a, &sh, false);
    drop(sh);
    let partials = if s.want_partials && s.req.full_update {
        chunk_partials(st, s, d)
    } else {
        Vec::new()
    };
    s.rounds += 1;
    st.obs.rounds.inc();
    st.obs.add_counters(&build_ctr);
    st.obs.add_counters(&scan_ctr);
    st.obs.scan_hist.record(t_round.elapsed());
    st.obs.events.push(
        "shard_round",
        TraceId::from_u64(round.trace),
        vec![
            ("round", Value::U64(s.rounds)),
            ("moved", Value::U64(moved.len() as u64)),
            ("dist_assignment", Value::U64(scan_ctr.assignment)),
            ("dist_centroid", Value::U64(build_ctr.centroid)),
            ("dist_displacement", Value::U64(build_ctr.displacement)),
        ],
    );
    let reply = RoundOk {
        build_ctr,
        scan_ctr,
        moved,
        partials,
        trace: round.trace,
    };
    send_frame(w, tag::ROUND_OK, &reply.encode())
}

/// `STATS`: render the shard's registry and drain its event ring after
/// the caller's `since` cursor. Deliberately does **not** take the
/// compute lock — observability must work while a fit round runs.
fn handle_stats(w: &mut TcpStream, st: &ShardState<'_>, body: &[u8]) -> bool {
    let stats = match Stats::decode(body) {
        Ok(m) => m,
        Err(e) => return send_err(w, &e.to_string()),
    };
    let reply = StatsOk {
        metrics: st.obs.registry.render(),
        events: events_json(&st.obs.events.since(stats.since), st.obs.events.last_seq())
            .to_string(),
    };
    send_frame(w, tag::STATS_OK, &reply.encode())
}
