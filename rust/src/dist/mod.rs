//! Distributed fit: shard servers, a network
//! [`DataSource`](crate::data::DataSource), and a bit-identical
//! multi-node round protocol.
//!
//! The subsystem splits a fit across processes (or machines) without
//! changing a single result bit:
//!
//! * [`shardd`] — a shard server (`eakm shardd`) owning one global row
//!   range of an `.ekb` file. It serves a **data plane** (stream row
//!   blocks + sidecar-exact norms to remote cursors) and a **compute
//!   plane** (run the local assignment scan for a fit session and
//!   return counters, moved lists, and partial sums).
//! * [`NetSource`] — a [`DataSource`] over the data plane, so every
//!   existing algorithm (mini-batch included) fits over the network
//!   unchanged.
//! * [`DistEngine`] / [`run_dist`] — the coordinator: seeds locally,
//!   broadcasts centroids each round, merges shard replies in shard
//!   order (`eakm run --shards host:port,host:port`).
//!
//! The dependency-free wire protocol (length-prefixed binary frames)
//! is specified in [`wire`]; both planes share the
//! [`net::frame`](crate::net::frame) codec with the model server.
//!
//! Observability rides the same wire: `FIT_INIT`/`ROUND` carry the
//! coordinator's [`TraceId`](crate::obs::TraceId) (echoed in replies
//! and recorded in shard-side round events), and a `STATS` frame — or
//! the optional `--metrics-addr` HTTP listener — drains any shard's
//! metric families and event ring without touching the compute lock.
//!
//! ## Why the distributed fit is bit-identical
//!
//! Every source of nondeterminism is pinned, one by one:
//!
//! * **Seeding** runs on the coordinator with the config's RNG stream,
//!   reading rows through the network source — same bytes, same draws
//!   as a local run.
//! * **Per-sample algorithm state** (bounds, assignments) depends only
//!   on the sample's own history against the shared centroid stream —
//!   never on which shard or thread scanned it — so any partition of
//!   the rows computes the same per-sample results.
//! * **Centroid-side builds** (inter-centroid structures, groups,
//!   ns-history) are pure functions of `(centroids, k, d, seed)`;
//!   every shard computes them identically, the coordinator counts
//!   them once and cross-checks equality. The ns-history *cap* is a
//!   function of the global row count, computed on the coordinator and
//!   shipped in `FIT_INIT`.
//! * **Merges are order-fixed**: replies are read in shard order, and
//!   shard ranges tile `[0, n)` in that order, so concatenated moved
//!   lists are exactly the single-node ascending moved list; counters
//!   are `u64` sums (order-free).
//! * **Centroid sums**: the delta update replays the identical moved
//!   list through the same pooled loop; full-update algorithms rebuild
//!   from per-chunk partials on the *global* chunk grid, folded with
//!   the same merge loop as the single-node pooled rebuild — used only
//!   when every shard boundary lands on a chunk boundary (else the
//!   coordinator rebuilds through the network source, which is the
//!   single-node code path verbatim).
//!
//! `tests/dist.rs` asserts the consequence: assignments, MSE bits,
//! counters, and iteration counts are identical to single-node at
//! every tested shard count and thread width.
//!
//! ## Failure semantics
//!
//! Shards are validated when a fit or source connects; afterwards the
//! two planes differ. The **compute plane** returns `Result`s — a dead
//! shard becomes a typed [`EakmError::Net`](crate::error::EakmError::Net)
//! naming the shard address, never a hang (every wait is bounded by a
//! reply timeout). The **data plane** sits behind the infallible
//! `lease` contract, so its cursors retry with reconnect + backoff and
//! then panic naming the failure — the same contract as an `.ekb` file
//! vanishing mid-run on a local out-of-core source.

pub mod client;
pub mod coordinator;
pub mod netsource;
pub mod shardd;
pub mod wire;

pub use client::{shard_stats, ShardStats};
pub use coordinator::{run_dist, run_dist_observed, DistEngine, DEFAULT_NET_TIMEOUT};
pub use netsource::NetSource;
pub use shardd::{shardd, ShardConfig};
