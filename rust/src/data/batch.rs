//! [`BatchView`] — a seeded, sampled index view over any [`DataSource`].
//!
//! The mini-batch engine's data layer. A batch is *just another
//! `DataSource`* (the seam PR 2 built for exactly this), so the
//! assignment and update phases — and their cross-thread determinism
//! guarantee — run over it unchanged. Rows are gathered once at draw
//! time into a contiguous row-major buffer, and squared norms are
//! gathered from the base's precomputed norms rather than recomputed,
//! so per-row arithmetic is bit-identical with the full-batch path.
//!
//! Sampling is uniform without replacement from an explicit complement
//! pool, which gives two properties the mini-batch driver relies on:
//!
//! * [`BatchView::grow`] extends the *same* batch — every previously
//!   drawn row keeps its position, so old batch ⊂ new batch (the
//!   nesting of Newling & Fleuret 2016b);
//! * draws consume only the supplied [`Rng`] stream, so a seeded batch
//!   sequence is identical at every thread count.

use crate::data::source::{BlockCursor, SliceCursor};
use crate::data::DataSource;
use crate::rng::Rng;

/// A sampled subset of a base [`DataSource`], materialised by gather.
///
/// Row `i` of the view is row `indices()[i]` of the base. The view owns
/// its gathered rows and norms, so it stays valid (and cheap to scan)
/// while engines run over it; the base is only touched while drawing.
#[derive(Clone, Debug)]
pub struct BatchView {
    /// Base-source row index of each batch row, in batch order.
    indices: Vec<usize>,
    /// Base rows not yet drawn (swap-remove sampling pool).
    remaining: Vec<usize>,
    /// Gathered rows, row-major `indices.len() × d`.
    rows: Vec<f64>,
    /// Gathered `‖x‖²`, aligned with `indices`.
    sqnorms: Vec<f64>,
    d: usize,
    base_n: usize,
    name: String,
}

impl BatchView {
    /// Draw `size` distinct rows from `base` using `rng`. Keep the same
    /// stream to [`grow`](BatchView::grow) this batch (or to draw the
    /// next one) deterministically.
    ///
    /// Panics if `size` is zero or exceeds `base.n()` — the mini-batch
    /// driver clamps to `[k, n]` before sampling.
    pub fn sample(base: &dyn DataSource, size: usize, rng: &mut Rng) -> BatchView {
        assert!(
            size >= 1 && size <= base.n(),
            "batch size {size} out of range for n={}",
            base.n()
        );
        let mut view = BatchView {
            indices: Vec::with_capacity(size),
            remaining: (0..base.n()).collect(),
            rows: Vec::with_capacity(size * base.d()),
            sqnorms: Vec::with_capacity(size),
            d: base.d(),
            base_n: base.n(),
            name: format!("{}[batch]", base.name()),
        };
        view.draw(base, size, rng);
        view
    }

    /// As [`BatchView::sample`], with a one-shot seed.
    pub fn seeded(base: &dyn DataSource, size: usize, seed: u64) -> BatchView {
        Self::sample(base, size, &mut Rng::new(seed))
    }

    /// Grow the batch to `new_size` rows (clamped to the base size),
    /// keeping every existing row in place — the nested-batch property.
    /// A no-op when the batch already has `new_size` rows or more.
    pub fn grow(&mut self, base: &dyn DataSource, new_size: usize, rng: &mut Rng) {
        assert_eq!(base.n(), self.base_n, "grow must use the same base source");
        let new_size = new_size.min(self.base_n);
        if new_size > self.indices.len() {
            let extra = new_size - self.indices.len();
            self.draw(base, extra, rng);
        }
    }

    /// Redraw the batch in place at its current size: every row goes
    /// back into the sampling pool and a fresh batch is drawn
    /// (Sculley-style resampling). Reuses the pool and gather buffers,
    /// so a redraw costs `O(batch)` per round, not `O(n)`.
    pub fn resample(&mut self, base: &dyn DataSource, rng: &mut Rng) {
        assert_eq!(base.n(), self.base_n, "resample must use the same base source");
        let size = self.indices.len();
        self.remaining.append(&mut self.indices);
        self.rows.clear();
        self.sqnorms.clear();
        self.draw(base, size, rng);
    }

    fn draw(&mut self, base: &dyn DataSource, extra: usize, rng: &mut Rng) {
        // one cursor for the whole gather: picks are random-access, so a
        // windowed base refills as needed while a resident base just
        // re-slices
        let mut cur = base.open(0, base.n());
        for _ in 0..extra {
            let pick = rng.below(self.remaining.len());
            let idx = self.remaining.swap_remove(pick);
            self.indices.push(idx);
            let block = cur.lease(idx, 1);
            self.rows.extend_from_slice(block.rows());
            self.sqnorms.push(block.sqnorms()[0]);
        }
    }

    /// Base-source index of each batch row, in batch order.
    pub fn indices(&self) -> &[usize] {
        &self.indices
    }

    /// Rows in the base source this view samples from.
    pub fn base_len(&self) -> usize {
        self.base_n
    }

    /// True once the batch covers every base row.
    pub fn is_full(&self) -> bool {
        self.indices.len() == self.base_n
    }

    /// Gathered rows `[lo, lo+len)` as one row-major slice (inherent
    /// fast path, mirroring [`Dataset`](crate::data::Dataset)'s).
    pub fn rows(&self, lo: usize, len: usize) -> &[f64] {
        &self.rows[lo * self.d..(lo + len) * self.d]
    }

    /// Batch row `i`.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.rows[i * self.d..(i + 1) * self.d]
    }

    /// `‖x(i)‖²` of batch row `i` (gathered from the base's norms).
    pub fn sqnorm(&self, i: usize) -> f64 {
        self.sqnorms[i]
    }
}

impl DataSource for BatchView {
    fn n(&self) -> usize {
        self.indices.len()
    }

    fn d(&self) -> usize {
        self.d
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn open(&self, lo: usize, len: usize) -> Box<dyn BlockCursor + '_> {
        Box::new(SliceCursor::new(&self.rows, &self.sqnorms, self.d, lo, len))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;

    #[test]
    fn sampling_is_reproducible_per_seed() {
        let ds = blobs(500, 3, 4, 0.2, 1);
        let a = BatchView::seeded(&ds, 64, 9);
        let b = BatchView::seeded(&ds, 64, 9);
        assert_eq!(a.indices(), b.indices());
        assert_eq!(a.rows(0, a.n()), b.rows(0, b.n()));
        let c = BatchView::seeded(&ds, 64, 10);
        assert_ne!(a.indices(), c.indices(), "different seeds, same batch");
    }

    #[test]
    fn view_gathers_rows_and_norms_from_the_base() {
        let ds = blobs(200, 4, 3, 0.3, 7);
        let view = BatchView::seeded(&ds, 50, 3);
        assert_eq!(view.n(), 50);
        assert_eq!(view.d(), 4);
        assert_eq!(view.base_len(), 200);
        assert!(view.name().ends_with("[batch]"));
        for (i, &idx) in view.indices().iter().enumerate() {
            assert_eq!(view.row(i), ds.row(idx), "row {i} ↔ base {idx}");
            assert_eq!(view.sqnorm(i).to_bits(), ds.sqnorm(idx).to_bits());
        }
    }

    #[test]
    fn indices_are_distinct_and_in_range() {
        let ds = blobs(100, 2, 2, 0.2, 5);
        let view = BatchView::seeded(&ds, 100, 8);
        assert!(view.is_full());
        let mut sorted = view.indices().to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 100, "duplicates drawn");
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn grow_nests_the_old_batch() {
        let ds = blobs(300, 3, 3, 0.2, 2);
        let mut rng = Rng::new(4);
        let mut view = BatchView::sample(&ds, 40, &mut rng);
        let first = view.indices().to_vec();
        view.grow(&ds, 80, &mut rng);
        assert_eq!(view.n(), 80);
        // nesting: the old draw is a prefix of the grown batch
        assert_eq!(&view.indices()[..40], first.as_slice());
        // and growth past the base clamps without panicking
        view.grow(&ds, 10_000, &mut rng);
        assert!(view.is_full());
        let mut sorted = view.indices().to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 300);
    }

    #[test]
    fn resample_redraws_in_place() {
        let ds = blobs(400, 3, 4, 0.2, 6);
        let mut rng = Rng::new(12);
        let mut view = BatchView::sample(&ds, 60, &mut rng);
        let first = view.indices().to_vec();
        view.resample(&ds, &mut rng);
        assert_eq!(view.n(), 60);
        assert_ne!(view.indices(), first.as_slice(), "fresh draw expected");
        // still distinct, in range, and gathered from the base
        let mut sorted = view.indices().to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 60);
        assert!(sorted.iter().all(|&i| i < 400));
        for (i, &idx) in view.indices().iter().enumerate() {
            assert_eq!(view.row(i), ds.row(idx));
        }
        // deterministic given the stream
        let mut rng2 = Rng::new(12);
        let mut view2 = BatchView::sample(&ds, 60, &mut rng2);
        view2.resample(&ds, &mut rng2);
        assert_eq!(view.indices(), view2.indices());
    }

    #[test]
    fn engines_run_unchanged_over_a_batch_view() {
        // the seam is real: a batch is clusterable like any source
        use crate::algorithms::Algorithm;
        use crate::config::RunConfig;
        use crate::coordinator::Runner;
        let ds = blobs(400, 3, 5, 0.15, 11);
        let view = BatchView::seeded(&ds, 200, 6);
        let cfg = RunConfig::new(Algorithm::ExpNs, 5).seed(3);
        let out = Runner::new(&cfg).run(&view).unwrap();
        assert!(out.converged);
        assert_eq!(out.assignments.len(), 200);
    }
}
