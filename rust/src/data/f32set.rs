//! [`DatasetF32`] — fully-resident samples stored at f32 width.
//!
//! The opt-in mixed-precision container: rows live in memory as `f32`
//! (half the bandwidth and footprint of [`Dataset`]) and are widened to
//! `f64` at lease time into a per-cursor scratch buffer, so every
//! consumer — and every kernel — still sees the `&[f64]` block-lease
//! contract and accumulates in double precision. Squared norms are
//! computed once, in f64 from the *widened* values, with the same
//! [`sqnorm`](crate::linalg::sqnorm) kernel every other source uses:
//! both widths share one definition, which is what keeps the
//! norms-match-rows invariant and the bit-identity tests honest.
//!
//! On data whose values are exactly f32-representable (e.g. anything
//! loaded from an f32 `.ekb` file), clustering through `DatasetF32` is
//! bit-identical to clustering the widened values through `Dataset`.

use crate::data::source::{BlockCursor, DataSource, RowBlock};
use crate::data::Dataset;
use crate::error::{EakmError, Result};
use crate::linalg::sqnorm;

/// A row-major `n×d` matrix stored as `f32`, leased as widened `f64`.
pub struct DatasetF32 {
    /// Row-major samples, `n*d` values at storage width.
    data: Vec<f32>,
    n: usize,
    d: usize,
    /// `‖x(i)‖²` per sample — f64, from the widened rows.
    sqnorms: Vec<f64>,
    /// Human-readable name (used in reports).
    pub name: String,
}

impl DatasetF32 {
    /// Wrap a row-major f32 buffer. Fails on shape mismatch, empty
    /// data, or non-finite values — the same contract as
    /// [`Dataset::new`].
    pub fn new(name: impl Into<String>, data: Vec<f32>, n: usize, d: usize) -> Result<Self> {
        if n == 0 || d == 0 {
            return Err(EakmError::Data(format!("empty dataset: n={n}, d={d}")));
        }
        if data.len() != n * d {
            return Err(EakmError::Data(format!(
                "shape mismatch: {} values for n={n} × d={d}",
                data.len()
            )));
        }
        if data.iter().any(|v| !v.is_finite()) {
            return Err(EakmError::Data("non-finite value in dataset".into()));
        }
        let mut sqnorms = Vec::with_capacity(n);
        let mut row = vec![0.0f64; d];
        for chunk in data.chunks_exact(d) {
            for (w, &v) in row.iter_mut().zip(chunk) {
                *w = v as f64;
            }
            sqnorms.push(sqnorm(&row));
        }
        Ok(DatasetF32 {
            data,
            n,
            d,
            sqnorms,
            name: name.into(),
        })
    }

    /// Narrow a [`Dataset`] to f32 storage. Values round to
    /// nearest-even; magnitudes beyond f32 range would become ±inf, so
    /// those error out instead of poisoning the kernels downstream.
    pub fn from_dataset(ds: &Dataset) -> Result<DatasetF32> {
        let data: Vec<f32> = ds.raw().iter().map(|&v| v as f32).collect();
        DatasetF32::new(ds.name.clone(), data, ds.n(), ds.d())
    }

    /// Number of samples.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Dimension.
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// The full row-major f32 buffer.
    #[inline]
    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    /// All pre-computed squared norms (f64, from widened rows).
    #[inline]
    pub fn sqnorms(&self) -> &[f64] {
        &self.sqnorms
    }
}

impl DataSource for DatasetF32 {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn open(&self, lo: usize, len: usize) -> Box<dyn BlockCursor + '_> {
        assert!(lo + len <= self.n, "open range out of bounds");
        Box::new(WideningCursor {
            rows: &self.data,
            sqnorms: &self.sqnorms,
            d: self.d,
            range_lo: lo,
            range_len: len,
            scratch: Vec::new(),
        })
    }
}

/// Cursor that widens f32 rows into a per-cursor f64 scratch buffer at
/// lease time (one active lease per cursor, per the block-lease
/// contract, so one buffer suffices).
struct WideningCursor<'a> {
    rows: &'a [f32],
    sqnorms: &'a [f64],
    d: usize,
    range_lo: usize,
    range_len: usize,
    scratch: Vec<f64>,
}

impl BlockCursor for WideningCursor<'_> {
    fn d(&self) -> usize {
        self.d
    }

    fn lease(&mut self, lo: usize, len: usize) -> RowBlock<'_> {
        assert!(
            lo >= self.range_lo && lo + len <= self.range_lo + self.range_len,
            "lease [{lo}, {}) outside cursor range [{}, {})",
            lo + len,
            self.range_lo,
            self.range_lo + self.range_len
        );
        let d = self.d;
        self.scratch.clear();
        self.scratch
            .extend(self.rows[lo * d..(lo + len) * d].iter().map(|&v| v as f64));
        RowBlock::new(lo, d, &self.scratch, &self.sqnorms[lo..lo + len])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;

    fn rounded_pair(n: usize, d: usize) -> (Dataset, DatasetF32) {
        // pre-round to f32 so narrow→widen is exact and the two
        // containers hold bitwise-equal values after widening
        let ds = blobs(n, d, 4, 0.2, 19);
        let rounded: Vec<f64> = ds.raw().iter().map(|&v| v as f32 as f64).collect();
        let ds = Dataset::new("r", rounded, n, d).unwrap();
        let f32set = DatasetF32::from_dataset(&ds).unwrap();
        (ds, f32set)
    }

    #[test]
    fn leases_match_the_widened_dataset_bit_for_bit() {
        let (ds, fs) = rounded_pair(500, 7);
        assert_eq!((fs.n(), fs.d()), (500, 7));
        assert_eq!(fs.name(), "r");
        let mut cur = DataSource::open(&fs, 0, 500);
        for (start, len) in [(0usize, 128usize), (128, 128), (490, 10), (3, 77)] {
            let block = cur.lease(start, len);
            assert_eq!(block.rows(), &ds.raw()[start * 7..(start + len) * 7]);
            for i in start..start + len {
                assert_eq!(block.sqnorm(i).to_bits(), ds.sqnorm(i).to_bits());
            }
        }
    }

    #[test]
    fn sqnorms_use_the_shared_kernel_on_widened_rows() {
        let (ds, fs) = rounded_pair(64, 9);
        for i in 0..64 {
            assert_eq!(fs.sqnorms()[i].to_bits(), ds.sqnorm(i).to_bits());
        }
    }

    #[test]
    fn rejects_bad_shapes_and_non_finite() {
        assert!(DatasetF32::new("x", vec![1.0], 1, 2).is_err());
        assert!(DatasetF32::new("x", vec![], 0, 2).is_err());
        assert!(DatasetF32::new("x", vec![1.0, f32::NAN], 1, 2).is_err());
        // f64 values beyond f32 range must error on narrowing
        let big = Dataset::new("big", vec![1e308, 0.0], 1, 2).unwrap();
        assert!(DatasetF32::from_dataset(&big).is_err());
    }

    #[test]
    fn rounding_on_general_data_stays_within_f32_ulp() {
        let ds = blobs(100, 3, 2, 0.3, 5);
        let fs = DatasetF32::from_dataset(&ds).unwrap();
        let mut cur = DataSource::open(&fs, 0, 100);
        let block = cur.lease(0, 100);
        for (w, &orig) in block.rows().iter().zip(ds.raw()) {
            assert_eq!(*w, orig as f32 as f64);
        }
    }
}
