//! [`ChunkedFileSource`] — portable out-of-core reads with one resident
//! window per cursor.
//!
//! Each [`open`](crate::data::DataSource::open)ed cursor owns a private
//! file handle and a window of `window_rows` decoded rows. A lease that
//! falls inside the window is a slice of it (no I/O); a lease outside
//! it seeks and refills the window starting at the requested row.
//! Since every consumer in the coordinator advances monotonically
//! within a shard (scans, the delta update, seeding passes), a round
//! costs `shard_rows / window_rows` refills per worker — sequential
//! reads the OS readahead handles well.
//!
//! Squared norms come from the `.norms` sidecar and stay fully
//! resident (`8n` bytes vs the data's `8nd`): windowing them too would
//! save d× less memory than the rows while doubling the refill logic.

use std::fs::File;
use std::io::{BufReader, Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use super::norms;
use super::{stem_name, IoCounters};
use crate::data::io::{decode_widen_le, read_bin_header, EkbHeader};
use crate::data::source::{BlockCursor, RowBlock};
use crate::data::DataSource;
use crate::error::{EakmError, Result};
use crate::metrics::IoTelemetry;

/// An `.ekb` file served through per-cursor resident windows.
pub struct ChunkedFileSource {
    path: PathBuf,
    /// Validated `.ekb` header: shape, storage width, payload offset.
    hdr: EkbHeader,
    n: usize,
    d: usize,
    name: String,
    window_rows: usize,
    /// Sidecar norms, fully resident (see module docs).
    norms: Vec<f64>,
    io: IoCounters,
}

impl ChunkedFileSource {
    /// Open `path` without loading it: validate the header and size,
    /// ensure the `.norms` sidecar (one streaming pass on first
    /// contact with the file), and record the window size. A
    /// `window_rows` of 0 selects [`DEFAULT_WINDOW_ROWS`](super::DEFAULT_WINDOW_ROWS).
    pub fn open(path: &Path, window_rows: usize) -> Result<ChunkedFileSource> {
        let mut r = BufReader::new(File::open(path)?);
        let hdr = read_bin_header(&mut r, path)?;
        let (n, d) = (hdr.n, hdr.d);
        let expect = hdr.file_len();
        let actual = r.get_ref().metadata()?.len();
        if actual != expect {
            return Err(EakmError::Data(format!(
                "{}: file is {actual} bytes, header implies {expect}",
                path.display()
            )));
        }
        drop(r);
        let sidecar = norms::ensure_sidecar(path, n, d)?;
        let norms = norms::load_sidecar(&sidecar, n, d)?;
        let window_rows = if window_rows == 0 {
            super::DEFAULT_WINDOW_ROWS
        } else {
            window_rows
        };
        Ok(ChunkedFileSource {
            path: path.to_path_buf(),
            hdr,
            n,
            d,
            name: stem_name(path),
            window_rows,
            norms,
            io: IoCounters::default(),
        })
    }

    /// Resident-window size in rows.
    pub fn window_rows(&self) -> usize {
        self.window_rows
    }
}

impl DataSource for ChunkedFileSource {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn open(&self, lo: usize, len: usize) -> Box<dyn BlockCursor + '_> {
        assert!(lo + len <= self.n, "open range out of bounds");
        // a private handle per cursor: seek positions must not be
        // shared between workers. Sources are validated at open, so a
        // file that vanishes mid-run is a panic, not a silent zero.
        let file = File::open(&self.path).unwrap_or_else(|e| {
            panic!("{}: reopening for cursor: {e}", self.path.display())
        });
        Box::new(ChunkedCursor {
            src: self,
            file,
            range_lo: lo,
            range_len: len,
            win_lo: 0,
            win_len: 0,
            buf: Vec::new(),
            byte_buf: Vec::new(),
        })
    }

    fn io_stats(&self) -> Option<IoTelemetry> {
        Some(self.io.snapshot())
    }
}

/// One worker's window over a [`ChunkedFileSource`] shard.
struct ChunkedCursor<'a> {
    src: &'a ChunkedFileSource,
    file: File,
    range_lo: usize,
    range_len: usize,
    /// Resident window: rows `[win_lo, win_lo + win_len)` decoded in `buf`.
    win_lo: usize,
    win_len: usize,
    buf: Vec<f64>,
    byte_buf: Vec<u8>,
}

/// Rows fetched for a random-access (non-streaming) single-row lease:
/// a small readahead that keeps sorted-ish walks cheap without the
/// full-window read amplification a gather pattern (mini-batch draws,
/// k-means++ picks) would otherwise pay per pick.
const RANDOM_WINDOW_ROWS: usize = 64;

impl ChunkedCursor<'_> {
    /// Refill the window to start at `lo`, covering at least `len`
    /// rows. Streaming leases (block scans, or a single row continuing
    /// the window forward) fetch a full `window_rows` window; isolated
    /// single-row leases fetch only [`RANDOM_WINDOW_ROWS`] — gathering
    /// `b` random rows then costs `O(b)` small reads, not
    /// `O(b × window)`.
    fn refill(&mut self, lo: usize, len: usize) {
        let d = self.src.d;
        let end = self.range_lo + self.range_len;
        let streaming = self.win_len > 0 && lo == self.win_lo + self.win_len;
        let target = if len > 1 || streaming {
            self.src.window_rows
        } else {
            RANDOM_WINDOW_ROWS.min(self.src.window_rows)
        };
        let take = target.max(len).min(end - lo);
        // f32 files move half the bytes per row; the io counters
        // report the storage bytes actually read, not the widened size
        let eb = self.src.hdr.width.bytes();
        let bytes = take * d * eb;
        self.byte_buf.resize(bytes, 0);
        let read = (|| -> std::io::Result<()> {
            self.file
                .seek(SeekFrom::Start(self.src.hdr.row_offset(lo)))?;
            self.file.read_exact(&mut self.byte_buf[..bytes])
        })();
        if let Err(e) = read {
            // the file was validated at open: losing it mid-run is not
            // a recoverable lease outcome
            panic!(
                "{}: reading rows [{lo}, {}): {e}",
                self.src.path.display(),
                lo + take
            );
        }
        self.buf.clear();
        decode_widen_le(self.src.hdr.width, &self.byte_buf[..bytes], &mut self.buf);
        self.win_lo = lo;
        self.win_len = take;
        self.src.io.add_refill();
        self.src.io.add_bytes(bytes as u64);
    }
}

impl BlockCursor for ChunkedCursor<'_> {
    fn d(&self) -> usize {
        self.src.d
    }

    fn lease(&mut self, lo: usize, len: usize) -> RowBlock<'_> {
        assert!(
            lo >= self.range_lo && lo + len <= self.range_lo + self.range_len,
            "lease [{lo}, {}) outside cursor range [{}, {})",
            lo + len,
            self.range_lo,
            self.range_lo + self.range_len
        );
        if lo < self.win_lo || lo + len > self.win_lo + self.win_len {
            self.refill(lo, len);
        }
        self.src.io.add_block();
        let d = self.src.d;
        let off = (lo - self.win_lo) * d;
        RowBlock::new(
            lo,
            d,
            &self.buf[off..off + len * d],
            &self.src.norms[lo..lo + len],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::io::{save_bin, save_bin_f32};
    use crate::data::synth::blobs;
    use crate::data::Dataset;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("eakm-chunked-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn leases_match_the_in_memory_dataset() {
        let ds = blobs(1_000, 5, 4, 0.2, 21);
        let path = tmpfile("leases.ekb");
        save_bin(&ds, &path).unwrap();
        // window far smaller than the file → many refills
        let src = ChunkedFileSource::open(&path, 64).unwrap();
        assert_eq!(src.n(), 1_000);
        assert_eq!(src.d(), 5);
        assert_eq!(src.name(), "leases");
        assert_eq!(src.window_rows(), 64);
        let mut cur = DataSource::open(&src, 0, 1_000);
        for start in [0usize, 10, 500, 990, 3, 700] {
            let len = 10.min(1_000 - start);
            let block = cur.lease(start, len);
            assert_eq!(block.rows(), &ds.raw()[start * 5..(start + len) * 5]);
            for i in start..start + len {
                assert_eq!(block.sqnorm(i).to_bits(), ds.sqnorm(i).to_bits());
            }
        }
        let io = src.io_stats().unwrap();
        assert!(io.window_refills >= 3, "small window must refill");
        assert!(io.bytes_read > 0);
        assert_eq!(io.blocks_leased, 6);
    }

    #[test]
    fn lease_larger_than_window_grows_the_buffer() {
        let ds = blobs(300, 3, 3, 0.2, 8);
        let path = tmpfile("grow.ekb");
        save_bin(&ds, &path).unwrap();
        let src = ChunkedFileSource::open(&path, 4).unwrap();
        let mut cur = DataSource::open(&src, 0, 300);
        let block = cur.lease(100, 50); // 50 > window of 4
        assert_eq!(block.len(), 50);
        assert_eq!(block.rows(), &ds.raw()[100 * 3..150 * 3]);
    }

    #[test]
    fn random_single_row_leases_read_small_windows() {
        let ds = blobs(2_000, 4, 3, 0.2, 17);
        let path = tmpfile("gather.ekb");
        save_bin(&ds, &path).unwrap();
        let src = ChunkedFileSource::open(&path, 1_000).unwrap();
        let mut cur = DataSource::open(&src, 0, 2_000);
        // a scatter of single-row picks (the BatchView::draw pattern)
        for &i in &[1_500usize, 3, 900, 1_999, 250, 1_200] {
            let block = cur.lease(i, 1);
            assert_eq!(block.rows(), &ds.raw()[i * 4..(i + 1) * 4]);
        }
        let io = src.io_stats().unwrap();
        // each refill reads ≤ RANDOM_WINDOW_ROWS rows, not the full
        // 1000-row window — a gather must not amplify reads per pick
        assert!(
            io.bytes_read <= (6 * RANDOM_WINDOW_ROWS * 4 * 8) as u64,
            "gather read-amplified: {} bytes",
            io.bytes_read
        );
        // and a streaming continuation afterwards goes back to full
        // windows: one refill covers many block leases
        let refills_before = src.io_stats().unwrap().window_refills;
        let mut scan = DataSource::open(&src, 0, 2_000);
        let mut at = 0;
        while at < 2_000 {
            let take = 128.min(2_000 - at);
            scan.lease(at, take);
            at += take;
        }
        // 2000 rows / 1000-row window ≈ 2 refills (+1 for the block
        // straddling a window boundary)
        let scan_refills = src.io_stats().unwrap().window_refills - refills_before;
        assert!(scan_refills <= 3, "scan refilled {scan_refills}× with a 1000-row window");
    }

    #[test]
    fn f32_file_leases_match_widened_dataset_and_halve_bytes() {
        let ds = blobs(1_000, 6, 4, 0.2, 23);
        let rounded: Vec<f64> = ds.raw().iter().map(|&v| v as f32 as f64).collect();
        let ds = Dataset::new("r32", rounded, 1_000, 6).unwrap();
        let p64 = tmpfile("width64.ekb");
        let p32 = tmpfile("width32.ekb");
        save_bin(&ds, &p64).unwrap();
        save_bin_f32(&ds, &p32).unwrap();
        let s64 = ChunkedFileSource::open(&p64, 64).unwrap();
        let s32 = ChunkedFileSource::open(&p32, 64).unwrap();
        let mut c64 = DataSource::open(&s64, 0, 1_000);
        let mut c32 = DataSource::open(&s32, 0, 1_000);
        let mut at = 0;
        while at < 1_000 {
            let take = 128.min(1_000 - at);
            let b64 = c64.lease(at, take);
            let b32 = c32.lease(at, take);
            assert_eq!(b64.rows(), b32.rows(), "rows differ at {at}");
            assert_eq!(b32.rows(), &ds.raw()[at * 6..(at + take) * 6]);
            for i in at..at + take {
                assert_eq!(b64.sqnorm(i).to_bits(), b32.sqnorm(i).to_bits());
            }
            at += take;
        }
        drop(c64);
        drop(c32);
        // storage bytes actually read: f32 moves half of f64
        let r64 = s64.io_stats().unwrap().bytes_read;
        let r32 = s32.io_stats().unwrap().bytes_read;
        assert_eq!(r32 * 2, r64, "f32 should read half the bytes");
    }

    #[test]
    fn zero_window_selects_the_default() {
        let ds = blobs(50, 2, 2, 0.2, 4);
        let path = tmpfile("defwin.ekb");
        save_bin(&ds, &path).unwrap();
        let src = ChunkedFileSource::open(&path, 0).unwrap();
        assert_eq!(src.window_rows(), super::super::DEFAULT_WINDOW_ROWS);
    }

    #[test]
    fn rejects_size_mismatch() {
        let ds = blobs(40, 2, 2, 0.2, 6);
        let path = tmpfile("short.ekb");
        save_bin(&ds, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        assert!(ChunkedFileSource::open(&path, 16).is_err());
    }
}
