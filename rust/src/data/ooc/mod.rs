//! Out-of-core data sources: cluster `.ekb` files larger than RAM.
//!
//! Two implementations sit behind the block-lease
//! [`DataSource`](crate::data::DataSource) seam:
//!
//! * [`MmapSource`] — maps the file (and its `.norms` sidecar) into the
//!   address space; leases are zero-copy slices of the mapping and the
//!   kernel's page cache decides what is resident. The fast choice on
//!   64-bit little-endian unix (the `.ekb` payload is little-endian
//!   f64, 8-byte aligned after the 24-byte header). All `unsafe` for
//!   the out-of-core layer lives in its module.
//! * [`ChunkedFileSource`] — portable buffered reads with **one
//!   resident window per cursor** (= per pool worker), sized in rows by
//!   the `--ooc-window` knob. A lease inside the window is a slice; a
//!   lease outside it refills the window from the file.
//!
//! Both share the `.norms` **sidecar cache** (`<file>.ekb.norms`):
//! squared norms are computed once per file — streaming the data in
//! row chunks through the same [`sqnorm`](crate::linalg::sqnorm) kernel
//! the in-memory [`Dataset`](crate::data::Dataset) uses — and reused by
//! every subsequent run, so the paper's §4.1.1 norm precomputation
//! survives out-of-core. Because the values, the norms, and every
//! consumer's arithmetic are bit-identical to the in-memory path,
//! **out-of-core runs produce bit-identical assignments, MSE, and bound
//! counters to in-memory runs at any thread count** (proved by
//! `tests/ooc.rs` and the `ooc` bench).
//!
//! Cursors report I/O telemetry (blocks leased, bytes read, window
//! refills) through [`DataSource::io_stats`](crate::data::DataSource::io_stats)
//! into [`RunReport::io`](crate::metrics::RunReport::io).

pub mod chunked;
#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
pub mod mmap;
pub mod norms;

pub use chunked::ChunkedFileSource;
#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
pub use mmap::MmapSource;

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::data::DataSource;
use crate::error::Result;
use crate::metrics::IoTelemetry;

/// Default resident-window size (rows) for [`ChunkedFileSource`] — at
/// d = 64 this is ~4 MiB per worker.
pub const DEFAULT_WINDOW_ROWS: usize = 8192;

/// Cumulative I/O counters shared by a source's cursors. Relaxed
/// atomics: the counts are telemetry, not synchronisation.
#[derive(Debug, Default)]
pub(crate) struct IoCounters {
    blocks: AtomicU64,
    bytes: AtomicU64,
    refills: AtomicU64,
}

impl IoCounters {
    pub(crate) fn add_block(&self) {
        self.blocks.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn add_bytes(&self, bytes: u64) {
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub(crate) fn add_refill(&self) {
        self.refills.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn snapshot(&self) -> IoTelemetry {
        IoTelemetry {
            blocks_leased: self.blocks.load(Ordering::Relaxed),
            bytes_read: self.bytes.load(Ordering::Relaxed),
            window_refills: self.refills.load(Ordering::Relaxed),
        }
    }
}

/// Which out-of-core backend to use (the CLI's `--ooc` flag).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OocMode {
    /// [`MmapSource`] where the platform supports it, else chunked.
    Auto,
    /// Page-cache-backed mapping (64-bit little-endian unix only).
    Mmap,
    /// Buffered reads with a resident window per worker (portable).
    Chunked,
}

impl OocMode {
    /// Parse a CLI value.
    pub fn parse(s: &str) -> Option<OocMode> {
        match s {
            "auto" => Some(OocMode::Auto),
            "mmap" => Some(OocMode::Mmap),
            "chunked" => Some(OocMode::Chunked),
            _ => None,
        }
    }
}

impl std::fmt::Display for OocMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            OocMode::Auto => "auto",
            OocMode::Mmap => "mmap",
            OocMode::Chunked => "chunked",
        })
    }
}

/// True when [`MmapSource`] is available on this platform.
pub fn mmap_supported() -> bool {
    cfg!(all(unix, target_endian = "little", target_pointer_width = "64"))
}

/// Open an out-of-core source over an `.ekb` file without loading it.
/// `window_rows` sizes the chunked backend's resident window (ignored
/// by mmap). `Auto` resolves to mmap where supported, else chunked;
/// an explicit `Mmap` on an unsupported platform is a config error.
pub fn open_ooc(path: &Path, mode: OocMode, window_rows: usize) -> Result<Box<dyn DataSource>> {
    match mode {
        OocMode::Chunked => Ok(Box::new(ChunkedFileSource::open(path, window_rows)?)),
        #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
        OocMode::Mmap | OocMode::Auto => Ok(Box::new(MmapSource::open(path)?)),
        #[cfg(not(all(unix, target_endian = "little", target_pointer_width = "64")))]
        OocMode::Mmap => Err(crate::error::EakmError::Config(
            "--ooc mmap is unsupported on this platform (needs 64-bit little-endian unix) — \
             use --ooc chunked"
                .into(),
        )),
        #[cfg(not(all(unix, target_endian = "little", target_pointer_width = "64")))]
        OocMode::Auto => Ok(Box::new(ChunkedFileSource::open(path, window_rows)?)),
    }
}

/// As [`open_ooc`], but I/O failures are wrapped with the path and the
/// source mode — a missing `.ekb` used to surface the raw OS error
/// ("No such file or directory") with no hint of *which* file or
/// *which* backend was asked for it.
pub fn open_ooc_described(
    path: &Path,
    mode: OocMode,
    window_rows: usize,
) -> Result<Box<dyn DataSource>> {
    open_ooc(path, mode, window_rows).map_err(|e| match e {
        crate::error::EakmError::Io(io) => crate::error::EakmError::Io(std::io::Error::new(
            io.kind(),
            format!("{} ({mode} source): {io}", path.display()),
        )),
        other => other,
    })
}

/// Source name for reports: the file stem, exactly like
/// [`load_bin`](crate::data::io::load_bin) names the in-memory dataset
/// — so an out-of-core report is comparable to the in-memory one.
pub(crate) fn stem_name(path: &Path) -> String {
    path.file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "bin".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parsing() {
        assert_eq!(OocMode::parse("auto"), Some(OocMode::Auto));
        assert_eq!(OocMode::parse("mmap"), Some(OocMode::Mmap));
        assert_eq!(OocMode::parse("chunked"), Some(OocMode::Chunked));
        assert_eq!(OocMode::parse("ram"), None);
        assert_eq!(OocMode::Chunked.to_string(), "chunked");
    }

    #[test]
    fn counters_accumulate() {
        let c = IoCounters::default();
        c.add_block();
        c.add_block();
        c.add_bytes(512);
        c.add_refill();
        let snap = c.snapshot();
        assert_eq!(snap.blocks_leased, 2);
        assert_eq!(snap.bytes_read, 512);
        assert_eq!(snap.window_refills, 1);
    }

    #[test]
    fn open_ooc_rejects_missing_file() {
        let missing = Path::new("/nonexistent/never.ekb");
        assert!(open_ooc(missing, OocMode::Chunked, 64).is_err());
        assert!(open_ooc(missing, OocMode::Auto, 64).is_err());
    }
}
