//! The `.norms` sidecar cache: squared norms, computed once per file.
//!
//! Format (little-endian, 32-byte header so the payload stays
//! f64-aligned under mmap): `magic "EAKN" | u32 version | u64 n |
//! u64 d | u64 fingerprint | n × f64`. The fingerprint is an FNV-1a
//! hash over the data file's length and its first/last 64 KiB, so a
//! rewritten file — even one with the same shape — invalidates the
//! sidecar instead of silently serving stale norms (which would break
//! the norms-match-rows invariant the bounds machinery relies on).
//!
//! The norms are computed by streaming the `.ekb` payload in row
//! chunks through [`sqnorm`](crate::linalg::sqnorm) — the same kernel
//! [`Dataset`](crate::data::Dataset) uses at load time — so the cached
//! values are bit-identical to the in-memory ones. The streaming pass
//! also validates finiteness, mirroring `Dataset::new`'s check, which
//! is why sources can skip revalidating rows at lease time.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use crate::data::io::{decode_f64_le, decode_widen_le, read_bin_header};
use crate::error::{EakmError, Result};
use crate::linalg::sqnorm;

pub(crate) const NMAGIC: &[u8; 4] = b"EAKN";
/// Bumped 2 → 3 when the 8-wide lane `sqnorm` landed: the new fixed
/// tree summation order changes norm *bits*, and a sidecar cached by an
/// older build would silently break the norms-match-rows invariant
/// (the fingerprint only tracks the data file, not the kernel).
pub(crate) const NVERSION: u32 = 3;
/// Bytes before the f64 norms payload (multiple of 8).
pub(crate) const NHEADER_LEN: usize = 4 + 4 + 8 + 8 + 8;

/// Bytes per streaming chunk while computing the sidecar.
const STREAM_BYTES: usize = 1 << 16;

/// Cheap content fingerprint of the data file: FNV-1a over its length
/// and its first/last 64 KiB. Not cryptographic — it exists to catch
/// "same shape, different data" rewrites, not adversaries.
fn data_fingerprint(path: &Path) -> Result<u64> {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut f = File::open(path)?;
    let len = f.metadata()?.len();
    let mut hash = FNV_OFFSET;
    let mut mix = |bytes: &[u8]| {
        for &b in bytes {
            hash ^= b as u64;
            hash = hash.wrapping_mul(FNV_PRIME);
        }
    };
    mix(&len.to_le_bytes());
    let take = (STREAM_BYTES as u64).min(len) as usize;
    let mut buf = vec![0u8; take];
    f.read_exact(&mut buf)?;
    mix(&buf);
    if len > STREAM_BYTES as u64 {
        use std::io::{Seek, SeekFrom};
        f.seek(SeekFrom::End(-(take as i64)))?;
        f.read_exact(&mut buf)?;
        mix(&buf);
    }
    Ok(hash)
}

/// Sidecar path for a data file: `<path>.norms` (extension appended,
/// not replaced, so `a.ekb` and `a.csv` never collide).
pub fn sidecar_path(data_path: &Path) -> PathBuf {
    let mut os = data_path.as_os_str().to_os_string();
    os.push(".norms");
    PathBuf::from(os)
}

fn read_sidecar_header(r: &mut impl Read, path: &Path) -> Result<(usize, usize, u64)> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != NMAGIC {
        return Err(EakmError::Data(format!(
            "{}: not an EAKM norms sidecar",
            path.display()
        )));
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    if version != NVERSION {
        return Err(EakmError::Data(format!(
            "{}: unsupported sidecar version {version}",
            path.display()
        )));
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let d = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let fp = u64::from_le_bytes(b8);
    Ok((n, d, fp))
}

/// True when `path` is a sidecar matching shape `(n, d)` and data
/// fingerprint `fp`, with a complete payload.
fn sidecar_matches(path: &Path, n: usize, d: usize, fp: u64) -> bool {
    let Ok(mut f) = File::open(path) else {
        return false;
    };
    let header_ok = read_sidecar_header(&mut f, path)
        .map(|hd| hd == (n, d, fp))
        .unwrap_or(false);
    if !header_ok {
        return false;
    }
    f.metadata()
        .map(|m| m.len() == (NHEADER_LEN + n * 8) as u64)
        .unwrap_or(false)
}

/// Ensure the sidecar for `data_path` (shape `(n, d)`) exists and is
/// valid, computing it with one streaming pass when missing or stale —
/// stale includes a rewritten data file of the *same* shape, caught by
/// the content fingerprint. Returns the sidecar path. The pass rejects
/// non-finite values, so a valid sidecar certifies the data file the
/// way `Dataset::new` does.
pub fn ensure_sidecar(data_path: &Path, n: usize, d: usize) -> Result<PathBuf> {
    let path = sidecar_path(data_path);
    let fp = data_fingerprint(data_path)?;
    if sidecar_matches(&path, n, d, fp) {
        return Ok(path);
    }

    let mut r = BufReader::new(File::open(data_path)?);
    let hdr = read_bin_header(&mut r, data_path)?;
    if (hdr.n, hdr.d) != (n, d) {
        return Err(EakmError::Data(format!(
            "{}: header says {}×{}, expected {n}×{d}",
            data_path.display(),
            hdr.n,
            hdr.d
        )));
    }

    // write to a temp file, then rename: a crashed pass never leaves a
    // truncated sidecar behind for the next run to trust
    let tmp = path.with_extension(format!("norms.tmp{}", std::process::id()));
    let write_err = |e: std::io::Error| {
        EakmError::Data(format!("{}: writing norms sidecar: {e}", tmp.display()))
    };
    {
        let mut w = BufWriter::new(File::create(&tmp).map_err(write_err)?);
        w.write_all(NMAGIC).map_err(write_err)?;
        w.write_all(&NVERSION.to_le_bytes()).map_err(write_err)?;
        w.write_all(&(n as u64).to_le_bytes()).map_err(write_err)?;
        w.write_all(&(d as u64).to_le_bytes()).map_err(write_err)?;
        w.write_all(&fp.to_le_bytes()).map_err(write_err)?;

        // sidecar norms are always f64, computed from the *widened*
        // rows — both storage widths share one definition of sqnorm
        let eb = hdr.width.bytes();
        let rows_per_chunk = (STREAM_BYTES / (d * eb)).max(1);
        let mut byte_buf = vec![0u8; rows_per_chunk * d * eb];
        let mut rows = Vec::with_capacity(rows_per_chunk * d);
        let mut out = Vec::with_capacity(rows_per_chunk * 8);
        let mut remaining = n;
        while remaining > 0 {
            let take = rows_per_chunk.min(remaining);
            r.read_exact(&mut byte_buf[..take * d * eb])?;
            rows.clear();
            decode_widen_le(hdr.width, &byte_buf[..take * d * eb], &mut rows);
            if rows.iter().any(|v| !v.is_finite()) {
                let _ = std::fs::remove_file(&tmp);
                return Err(EakmError::Data(format!(
                    "{}: non-finite value in dataset",
                    data_path.display()
                )));
            }
            out.clear();
            for row in rows.chunks_exact(d) {
                out.extend_from_slice(&sqnorm(row).to_le_bytes());
            }
            w.write_all(&out).map_err(write_err)?;
            remaining -= take;
        }
        w.flush().map_err(write_err)?;
    }
    std::fs::rename(&tmp, &path)?;
    Ok(path)
}

/// Load a sidecar's norms fully into memory (the chunked source keeps
/// them resident: they are `8n` bytes against the data's `8nd`).
pub fn load_sidecar(path: &Path, n: usize, d: usize) -> Result<Vec<f64>> {
    let mut r = BufReader::new(File::open(path)?);
    let (sn, sd, _fp) = read_sidecar_header(&mut r, path)?;
    if (sn, sd) != (n, d) {
        return Err(EakmError::Data(format!(
            "{}: sidecar says {sn}×{sd}, expected {n}×{d}",
            path.display()
        )));
    }
    let mut norms = Vec::with_capacity(n);
    let mut buf = vec![0u8; STREAM_BYTES];
    let mut remaining = n;
    while remaining > 0 {
        let take = (STREAM_BYTES / 8).min(remaining);
        r.read_exact(&mut buf[..take * 8])?;
        decode_f64_le(&buf[..take * 8], &mut norms);
        remaining -= take;
    }
    Ok(norms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::io::{save_bin, save_bin_f32, HEADER_LEN};
    use crate::data::synth::blobs;
    use crate::linalg::sqnorms_rows;

    fn tmpdir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("eakm-norms-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn sidecar_roundtrips_bit_identical_norms() {
        let ds = blobs(500, 7, 4, 0.2, 11);
        let path = tmpdir().join("norms-rt.ekb");
        save_bin(&ds, &path).unwrap();
        let side = ensure_sidecar(&path, ds.n(), ds.d()).unwrap();
        assert_eq!(side, sidecar_path(&path));
        let norms = load_sidecar(&side, ds.n(), ds.d()).unwrap();
        let want = sqnorms_rows(ds.raw(), ds.d());
        assert_eq!(norms.len(), want.len());
        for (a, b) in norms.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // second call is a cache hit: same shape and same content
        let again = ensure_sidecar(&path, ds.n(), ds.d()).unwrap();
        assert_eq!(again, side);
    }

    #[test]
    fn sidecar_for_f32_file_matches_widened_in_memory_norms() {
        // pre-round so narrow→widen is exact, then the sidecar must be
        // bit-identical to sqnorms_rows over the widened values
        let ds = blobs(300, 5, 3, 0.2, 13);
        let rounded: Vec<f64> = ds.raw().iter().map(|&v| v as f32 as f64).collect();
        let ds = crate::data::Dataset::new("r32", rounded, ds.n(), ds.d()).unwrap();
        let path = tmpdir().join("norms-f32.ekb");
        save_bin_f32(&ds, &path).unwrap();
        let side = ensure_sidecar(&path, ds.n(), ds.d()).unwrap();
        let norms = load_sidecar(&side, ds.n(), ds.d()).unwrap();
        let want = sqnorms_rows(ds.raw(), ds.d());
        for (a, b) in norms.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn same_shape_rewrite_invalidates_the_sidecar() {
        let a = blobs(120, 3, 2, 0.2, 1);
        let path = tmpdir().join("norms-rewrite.ekb");
        save_bin(&a, &path).unwrap();
        ensure_sidecar(&path, 120, 3).unwrap();
        // rewrite with *different data of the same shape* — the stale
        // sidecar must not be trusted (it would silently break the
        // norms-match-rows invariant)
        let b = blobs(120, 3, 2, 0.2, 2);
        save_bin(&b, &path).unwrap();
        let side = ensure_sidecar(&path, 120, 3).unwrap();
        let norms = load_sidecar(&side, 120, 3).unwrap();
        let want = sqnorms_rows(b.raw(), 3);
        for (got, want) in norms.iter().zip(&want) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn stale_sidecar_is_recomputed() {
        let ds = blobs(60, 3, 2, 0.2, 5);
        let path = tmpdir().join("norms-stale.ekb");
        save_bin(&ds, &path).unwrap();
        // plant garbage where the sidecar goes
        std::fs::write(sidecar_path(&path), b"junk").unwrap();
        let side = ensure_sidecar(&path, ds.n(), ds.d()).unwrap();
        let norms = load_sidecar(&side, ds.n(), ds.d()).unwrap();
        assert_eq!(norms.len(), 60);
        // and a shape-mismatched request errors instead of trusting it
        assert!(ensure_sidecar(&path, 61, ds.d()).is_err());
    }

    #[test]
    fn sidecar_rejects_non_finite_payload() {
        let ds = blobs(10, 2, 2, 0.2, 3);
        let path = tmpdir().join("norms-nan.ekb");
        save_bin(&ds, &path).unwrap();
        // corrupt one payload value into a NaN
        let mut bytes = std::fs::read(&path).unwrap();
        let off = HEADER_LEN + 3 * 8;
        bytes[off..off + 8].copy_from_slice(&f64::NAN.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        assert!(ensure_sidecar(&path, 10, 2).is_err());
    }
}
