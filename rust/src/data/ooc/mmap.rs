//! [`MmapSource`] — page-cache-backed `.ekb` mapping.
//!
//! The data file and its `.norms` sidecar are mapped read-only; an f64
//! lease is a zero-copy `&[f64]` straight into the mapping, and
//! residency is the kernel's problem (the page cache keeps hot shards
//! in RAM and evicts cold ones under pressure). f32 payloads are
//! widened into a per-cursor scratch buffer at lease time — still half
//! the *paged* bytes of an f64 file. This is the out-of-core fast path
//! on platforms where the on-disk format *is* the in-memory format:
//! 64-bit little-endian unix, with payloads aligned after the header
//! (mappings are page-aligned; offset 24 keeps v1 f64 payloads
//! 8-aligned, offset 32 keeps v2 f32 payloads 4-aligned and v2 f64
//! payloads 8-aligned).
//!
//! This module owns **all** `unsafe` of the out-of-core layer: the raw
//! `mmap`/`munmap` FFI (declared here — the build is dependency-free,
//! so no `libc` crate) and the byte→f64 reinterpretation, both confined
//! behind the safe [`Map`] wrapper. Compiled only under
//! `cfg(all(unix, target_endian = "little", target_pointer_width = "64"))`.

use std::ffi::c_void;
use std::fs::File;
use std::io::BufReader;
use std::os::unix::io::AsRawFd;
use std::path::Path;

use super::norms;
use super::{stem_name, IoCounters};
use crate::data::io::{read_bin_header, EkbHeader, ElemWidth};
use crate::data::source::{BlockCursor, RowBlock};
use crate::data::DataSource;
use crate::error::{EakmError, Result};
use crate::metrics::IoTelemetry;

// Raw mmap FFI. std links libc on unix, so declaring the two symbols
// we need keeps the build dependency-free. Constants are identical on
// Linux and the BSDs (incl. macOS) for these flags.
extern "C" {
    fn mmap(
        addr: *mut c_void,
        length: usize,
        prot: i32,
        flags: i32,
        fd: i32,
        offset: i64,
    ) -> *mut c_void;
    fn munmap(addr: *mut c_void, length: usize) -> i32;
}

const PROT_READ: i32 = 0x1;
const MAP_SHARED: i32 = 0x01;

/// RAII read-only mapping of one whole file.
struct Map {
    ptr: *mut c_void,
    len: usize,
}

// The mapping is read-only and never remapped, so concurrent reads
// from any thread are safe.
unsafe impl Send for Map {}
unsafe impl Sync for Map {}

impl Map {
    fn of_file(file: &File, len: usize, path: &Path) -> Result<Map> {
        assert!(len > 0, "cannot map an empty file");
        let ptr = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ,
                MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(EakmError::Data(format!(
                "{}: mmap failed: {}",
                path.display(),
                std::io::Error::last_os_error()
            )));
        }
        // page-aligned by the kernel; the f64 views below rely on it
        assert_eq!(ptr as usize % 8, 0, "mmap returned unaligned pointer");
        Ok(Map { ptr, len })
    }

    /// `count` f64 values starting `byte_off` bytes into the mapping.
    /// Safe because the mapping is immutable for the `Map`'s lifetime,
    /// the offset keeps 8-byte alignment (asserted), and the range is
    /// bounds-checked against the mapped length.
    fn f64s(&self, byte_off: usize, count: usize) -> &[f64] {
        debug_assert_eq!(byte_off % 8, 0);
        assert!(byte_off + count * 8 <= self.len, "mapped read out of range");
        unsafe {
            std::slice::from_raw_parts((self.ptr as *const u8).add(byte_off) as *const f64, count)
        }
    }

    /// `count` f32 values starting `byte_off` bytes into the mapping
    /// (v2 f32 payloads start at offset 32, keeping 4-byte alignment).
    fn f32s(&self, byte_off: usize, count: usize) -> &[f32] {
        debug_assert_eq!(byte_off % 4, 0);
        assert!(byte_off + count * 4 <= self.len, "mapped read out of range");
        unsafe {
            std::slice::from_raw_parts((self.ptr as *const u8).add(byte_off) as *const f32, count)
        }
    }
}

impl Drop for Map {
    fn drop(&mut self) {
        unsafe {
            munmap(self.ptr, self.len);
        }
    }
}

/// An `.ekb` file (plus `.norms` sidecar) served from read-only
/// mappings; leases are zero-copy.
pub struct MmapSource {
    data: Map,
    norms: Map,
    /// Validated `.ekb` header: shape, storage width, payload offset.
    hdr: EkbHeader,
    n: usize,
    d: usize,
    name: String,
    io: IoCounters,
}

impl MmapSource {
    /// Map `path` without loading it: validate header and size, ensure
    /// the `.norms` sidecar (one streaming pass on first contact), then
    /// map both files.
    pub fn open(path: &Path) -> Result<MmapSource> {
        let file = File::open(path)?;
        let hdr = read_bin_header(&mut BufReader::new(&file), path)?;
        let (n, d) = (hdr.n, hdr.d);
        let expect = hdr.file_len() as usize;
        let actual = file.metadata()?.len();
        if actual != expect as u64 {
            return Err(EakmError::Data(format!(
                "{}: file is {actual} bytes, header implies {expect}",
                path.display()
            )));
        }
        let sidecar = norms::ensure_sidecar(path, n, d)?;
        let nfile = File::open(&sidecar)?;
        let nexpect = norms::NHEADER_LEN + n * 8;
        let nactual = nfile.metadata()?.len();
        if nactual != nexpect as u64 {
            return Err(EakmError::Data(format!(
                "{}: sidecar is {nactual} bytes, expected {nexpect}",
                sidecar.display()
            )));
        }
        Ok(MmapSource {
            data: Map::of_file(&file, expect, path)?,
            norms: Map::of_file(&nfile, nexpect, &sidecar)?,
            hdr,
            n,
            d,
            name: stem_name(path),
            io: IoCounters::default(),
        })
    }
}

impl DataSource for MmapSource {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn open(&self, lo: usize, len: usize) -> Box<dyn BlockCursor + '_> {
        assert!(lo + len <= self.n, "open range out of bounds");
        Box::new(MmapCursor {
            src: self,
            range_lo: lo,
            range_len: len,
            scratch: Vec::new(),
        })
    }

    fn io_stats(&self) -> Option<IoTelemetry> {
        Some(self.io.snapshot())
    }
}

/// Cursor over an [`MmapSource`]: f64 leases are zero-copy views into
/// the mapping (no window, no refills); f32 leases widen into a
/// per-cursor scratch buffer (one active lease at a time, per the
/// block-lease contract, so one buffer suffices).
struct MmapCursor<'a> {
    src: &'a MmapSource,
    range_lo: usize,
    range_len: usize,
    /// Widened rows for f32 payloads; untouched for f64.
    scratch: Vec<f64>,
}

impl BlockCursor for MmapCursor<'_> {
    fn d(&self) -> usize {
        self.src.d
    }

    fn lease(&mut self, lo: usize, len: usize) -> RowBlock<'_> {
        assert!(
            lo >= self.range_lo && lo + len <= self.range_lo + self.range_len,
            "lease [{lo}, {}) outside cursor range [{}, {})",
            lo + len,
            self.range_lo,
            self.range_lo + self.range_len
        );
        // detach the shared source ref before touching self.scratch,
        // so the mapped view and the scratch borrow don't conflict
        let src = self.src;
        let hdr = &src.hdr;
        let d = src.d;
        src.io.add_block();
        // "bytes read" for a mapping = storage bytes leased (f32 pages
        // half of f64) + norms; actual paging is invisible from here
        src.io
            .add_bytes((len * d * hdr.width.bytes() + len * 8) as u64);
        let rows: &[f64] = match hdr.width {
            ElemWidth::F64 => src.data.f64s(hdr.row_offset(lo) as usize, len * d),
            ElemWidth::F32 => {
                let raw = src.data.f32s(hdr.row_offset(lo) as usize, len * d);
                self.scratch.clear();
                self.scratch.extend(raw.iter().map(|&v| v as f64));
                &self.scratch
            }
        };
        RowBlock::new(
            lo,
            d,
            rows,
            src.norms.f64s(norms::NHEADER_LEN + lo * 8, len),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::io::{save_bin, save_bin_f32};
    use crate::data::synth::blobs;
    use crate::data::Dataset;
    use std::path::PathBuf;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("eakm-mmap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn mapped_leases_match_the_in_memory_dataset() {
        let ds = blobs(800, 6, 4, 0.2, 31);
        let path = tmpfile("map.ekb");
        save_bin(&ds, &path).unwrap();
        let src = MmapSource::open(&path).unwrap();
        assert_eq!((src.n(), src.d()), (800, 6));
        assert_eq!(src.name(), "map");
        let mut cur = DataSource::open(&src, 0, 800);
        for start in [0usize, 17, 400, 790] {
            let len = 10.min(800 - start);
            let block = cur.lease(start, len);
            assert_eq!(block.rows(), &ds.raw()[start * 6..(start + len) * 6]);
            for i in start..start + len {
                assert_eq!(block.sqnorm(i).to_bits(), ds.sqnorm(i).to_bits());
            }
        }
        let io = src.io_stats().unwrap();
        assert_eq!(io.blocks_leased, 4);
        assert_eq!(io.window_refills, 0, "mmap never refills");
    }

    #[test]
    fn f32_mapped_leases_match_widened_dataset() {
        let ds = blobs(600, 5, 3, 0.2, 41);
        let rounded: Vec<f64> = ds.raw().iter().map(|&v| v as f32 as f64).collect();
        let ds = Dataset::new("r32", rounded, 600, 5).unwrap();
        let path = tmpfile("map32.ekb");
        save_bin_f32(&ds, &path).unwrap();
        let src = MmapSource::open(&path).unwrap();
        let mut cur = DataSource::open(&src, 0, 600);
        for start in [0usize, 17, 300, 590] {
            let len = 10.min(600 - start);
            let block = cur.lease(start, len);
            assert_eq!(block.rows(), &ds.raw()[start * 5..(start + len) * 5]);
            for i in start..start + len {
                assert_eq!(block.sqnorm(i).to_bits(), ds.sqnorm(i).to_bits());
            }
        }
    }

    #[test]
    fn rejects_truncated_file() {
        let ds = blobs(50, 3, 2, 0.2, 7);
        let path = tmpfile("trunc.ekb");
        save_bin(&ds, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        assert!(MmapSource::open(&path).is_err());
    }
}
