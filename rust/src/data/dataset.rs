//! The dataset container used throughout the crate.

use crate::data::source::{BlockCursor, DataSource, SliceCursor};
use crate::error::{EakmError, Result};
use crate::linalg::sqnorms_rows;

/// A row-major `n×d` matrix of samples with pre-computed squared norms.
///
/// Norm pre-computation is one of the paper's §4.1.1 engineering points:
/// `‖x(i)‖²` is computed once at load time and reused by every algorithm
/// and round.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major samples, `n*d` values.
    data: Vec<f64>,
    /// Number of samples.
    n: usize,
    /// Dimension.
    d: usize,
    /// `‖x(i)‖²` for every sample.
    sqnorms: Vec<f64>,
    /// Human-readable name (dataset id for the paper grid, or "custom").
    pub name: String,
}

impl Dataset {
    /// Wrap a row-major buffer. Fails on shape mismatch or empty data.
    pub fn new(name: impl Into<String>, data: Vec<f64>, n: usize, d: usize) -> Result<Self> {
        if n == 0 || d == 0 {
            return Err(EakmError::Data(format!("empty dataset: n={n}, d={d}")));
        }
        if data.len() != n * d {
            return Err(EakmError::Data(format!(
                "shape mismatch: {} values for n={n} × d={d}",
                data.len()
            )));
        }
        if data.iter().any(|v| !v.is_finite()) {
            return Err(EakmError::Data("non-finite value in dataset".into()));
        }
        let sqnorms = sqnorms_rows(&data, d);
        Ok(Dataset {
            data,
            n,
            d,
            sqnorms,
            name: name.into(),
        })
    }

    /// Number of samples.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Dimension.
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// The full row-major buffer.
    #[inline]
    pub fn raw(&self) -> &[f64] {
        &self.data
    }

    /// Pre-computed `‖x(i)‖²`.
    #[inline]
    pub fn sqnorm(&self, i: usize) -> f64 {
        self.sqnorms[i]
    }

    /// All pre-computed squared norms.
    #[inline]
    pub fn sqnorms(&self) -> &[f64] {
        &self.sqnorms
    }

    /// Standardise features to mean 0 / variance 1 in place (Table 8:
    /// "All datasets are preprocessed such that features have mean zero
    /// and variance 1"). Constant features are left centred at zero.
    pub fn standardize(&mut self) {
        let (n, d) = (self.n, self.d);
        let mut mean = vec![0.0; d];
        for i in 0..n {
            for (t, m) in mean.iter_mut().enumerate() {
                *m += self.data[i * d + t];
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        let mut var = vec![0.0; d];
        for i in 0..n {
            for t in 0..d {
                let c = self.data[i * d + t] - mean[t];
                var[t] += c * c;
            }
        }
        let inv_std: Vec<f64> = var
            .iter()
            .map(|&v| {
                let s = (v / n as f64).sqrt();
                if s > 1e-300 {
                    1.0 / s
                } else {
                    0.0 // constant feature: centre only
                }
            })
            .collect();
        for i in 0..n {
            for t in 0..d {
                let v = &mut self.data[i * d + t];
                *v = (*v - mean[t]) * inv_std[t];
            }
        }
        self.sqnorms = sqnorms_rows(&self.data, d);
    }

    /// Mean squared distance to the nearest of the given centroids — the
    /// k-means objective divided by `n`, used for convergence reporting.
    pub fn mse(&self, centroids: &[f64], assignments: &[u32]) -> f64 {
        assert_eq!(assignments.len(), self.n);
        let d = self.d;
        let total: f64 = assignments
            .iter()
            .enumerate()
            .map(|(i, &a)| {
                crate::linalg::sqdist(self.row(i), &centroids[a as usize * d..(a as usize + 1) * d])
            })
            .sum();
        total / self.n as f64
    }
}

/// The in-memory reference implementation of the data-access seam:
/// cursors are zero-copy [`SliceCursor`]s over the resident buffers
/// (the inherent accessors stay the fast path for concrete `Dataset`
/// callers — no cursor indirection).
impl DataSource for Dataset {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn open(&self, lo: usize, len: usize) -> Box<dyn BlockCursor + '_> {
        Box::new(SliceCursor::new(&self.data, &self.sqnorms, self.d, lo, len))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new("toy", vec![0.0, 0.0, 1.0, 1.0, 2.0, 0.0], 3, 2).unwrap()
    }

    #[test]
    fn shape_accessors() {
        let ds = toy();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 2);
        assert_eq!(ds.row(1), &[1.0, 1.0]);
        assert_eq!(ds.sqnorm(2), 4.0);
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(Dataset::new("x", vec![1.0], 1, 2).is_err());
        assert!(Dataset::new("x", vec![], 0, 2).is_err());
        assert!(Dataset::new("x", vec![1.0, f64::NAN], 1, 2).is_err());
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut ds = Dataset::new(
            "s",
            vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0],
            4,
            2,
        )
        .unwrap();
        ds.standardize();
        for t in 0..2 {
            let mean: f64 = (0..4).map(|i| ds.row(i)[t]).sum::<f64>() / 4.0;
            let var: f64 = (0..4).map(|i| ds.row(i)[t].powi(2)).sum::<f64>() / 4.0;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn standardize_constant_feature() {
        let mut ds = Dataset::new("c", vec![5.0, 1.0, 5.0, 2.0, 5.0, 3.0], 3, 2).unwrap();
        ds.standardize();
        for i in 0..3 {
            assert_eq!(ds.row(i)[0], 0.0);
        }
    }

    #[test]
    fn mse_of_perfect_assignment_is_zero() {
        let ds = toy();
        let centroids = ds.raw().to_vec();
        let mse = ds.mse(&centroids, &[0, 1, 2]);
        assert_eq!(mse, 0.0);
    }

    #[test]
    fn sqnorms_refresh_after_standardize() {
        let mut ds = toy();
        ds.standardize();
        for i in 0..ds.n() {
            let direct: f64 = ds.row(i).iter().map(|v| v * v).sum();
            assert!((ds.sqnorm(i) - direct).abs() < 1e-12);
        }
    }
}
