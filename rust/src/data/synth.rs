//! Synthetic stand-ins for the paper's 22 datasets (Table 8).
//!
//! We do not have the UCI/KDD/KEEL/MNIST/STL-10 files in this
//! environment, so each dataset id is replaced by a deterministic
//! generator that matches the original's **dimension and size exactly**
//! and its broad structure class (documented per entry below). Bound-based
//! k-means accelerations are sensitive to (d, N, k) and to how clustered
//! the data is — not to the raw feature values — so this preserves the
//! *shape* of the paper's results (see DESIGN.md §3 for the argument).
//!
//! Every generator standardises features to mean 0 / variance 1, as the
//! paper does (Table 8 caption).

use super::dataset::Dataset;
use crate::rng::Rng;

/// Structure class of a synthetic dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StructureClass {
    /// Gaussians on a regular grid (birch-style).
    GridGaussians {
        /// grid side; clusters = side²
        side: usize,
    },
    /// Points along piecewise-linear curves (geographic outlines).
    Curves {
        /// number of closed curves
        curves: usize,
    },
    /// Uniform random in the unit hypercube — worst case for bounds.
    Uniform,
    /// Correlated random-walk trajectories (sensor/telemetry data).
    RandomWalk {
        /// number of independent walks
        walks: usize,
    },
    /// Isotropic Gaussian mixture with cluster-count `c` and spread `s`
    /// (×1000 fixed-point to stay `Eq`).
    Mixture {
        /// number of mixture components
        c: usize,
        /// component std-dev ×1000 relative to unit placement box
        s_milli: usize,
    },
    /// Gaussian mixture living on an `r`-dimensional subspace plus
    /// full-dimensional noise (image/PCA-style data).
    LowRank {
        /// number of mixture components
        c: usize,
        /// intrinsic rank
        r: usize,
    },
    /// Heavy-tailed, sparse-ish mixture (KDD-cup-style behavioural data).
    HeavyTail {
        /// number of mixture components
        c: usize,
    },
}

/// Specification of one of the 22 paper datasets.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Roman-numeral index used in the paper's tables (1-based: 1 ⇒ "i").
    pub index: usize,
    /// Dataset name from Table 8.
    pub name: &'static str,
    /// Dimension (matches Table 8).
    pub d: usize,
    /// Full sample count (matches Table 8).
    pub n: usize,
    /// Generator class.
    pub class: StructureClass,
}

impl DatasetSpec {
    /// Roman numeral id as the paper prints it.
    pub fn roman(&self) -> &'static str {
        ROMAN[self.index - 1]
    }
}

const ROMAN: [&str; 22] = [
    "i", "ii", "iii", "iv", "v", "vi", "vii", "viii", "ix", "x", "xi", "xii", "xiii", "xiv",
    "xv", "xvi", "xvii", "xviii", "xix", "xx", "xxi", "xxii",
];

/// The 22 dataset specs of Table 8, in paper order.
pub fn paper_datasets() -> Vec<DatasetSpec> {
    use StructureClass::*;
    vec![
        DatasetSpec { index: 1, name: "birch", d: 2, n: 100_000, class: GridGaussians { side: 10 } },
        DatasetSpec { index: 2, name: "europe", d: 2, n: 169_300, class: Curves { curves: 12 } },
        DatasetSpec { index: 3, name: "urand2", d: 2, n: 1_000_000, class: Uniform },
        DatasetSpec { index: 4, name: "ldfpads", d: 3, n: 164_850, class: RandomWalk { walks: 30 } },
        DatasetSpec { index: 5, name: "conflongdemo", d: 3, n: 164_860, class: RandomWalk { walks: 40 } },
        DatasetSpec { index: 6, name: "skinseg", d: 4, n: 200_000, class: Mixture { c: 60, s_milli: 40 } },
        DatasetSpec { index: 7, name: "tsn", d: 4, n: 200_000, class: Mixture { c: 120, s_milli: 60 } },
        DatasetSpec { index: 8, name: "colormoments", d: 9, n: 68_040, class: Mixture { c: 80, s_milli: 90 } },
        DatasetSpec { index: 9, name: "mv", d: 11, n: 40_760, class: Mixture { c: 50, s_milli: 80 } },
        DatasetSpec { index: 10, name: "wcomp", d: 15, n: 165_630, class: Mixture { c: 100, s_milli: 110 } },
        DatasetSpec { index: 11, name: "house16h", d: 17, n: 22_780, class: HeavyTail { c: 40 } },
        DatasetSpec { index: 12, name: "keggnet", d: 28, n: 65_550, class: HeavyTail { c: 60 } },
        DatasetSpec { index: 13, name: "urand30", d: 30, n: 1_000_000, class: Uniform },
        DatasetSpec { index: 14, name: "mnist50", d: 50, n: 60_000, class: LowRank { c: 10, r: 12 } },
        DatasetSpec { index: 15, name: "miniboone", d: 50, n: 130_060, class: Mixture { c: 30, s_milli: 150 } },
        DatasetSpec { index: 16, name: "covtype", d: 55, n: 581_012, class: Mixture { c: 7, s_milli: 200 } },
        DatasetSpec { index: 17, name: "uscensus", d: 68, n: 2_458_285, class: HeavyTail { c: 120 } },
        DatasetSpec { index: 18, name: "kddcup04", d: 74, n: 145_750, class: Mixture { c: 50, s_milli: 180 } },
        DatasetSpec { index: 19, name: "stl10", d: 108, n: 1_000_000, class: LowRank { c: 10, r: 20 } },
        DatasetSpec { index: 20, name: "gassensor", d: 128, n: 13_910, class: LowRank { c: 6, r: 16 } },
        DatasetSpec { index: 21, name: "kddcup98", d: 310, n: 95_000, class: HeavyTail { c: 80 } },
        DatasetSpec { index: 22, name: "mnist784", d: 784, n: 60_000, class: LowRank { c: 10, r: 30 } },
    ]
}

/// Look a spec up by paper name or roman numeral.
pub fn find(name: &str) -> Option<DatasetSpec> {
    paper_datasets()
        .into_iter()
        .find(|s| s.name == name || s.roman() == name)
}

/// Generate dataset `spec` at `scale` ∈ (0, 1] of its full size.
///
/// Scaling shrinks N (never below 2k samples or 1000) — the grid benches
/// use this to fit the session's compute budget; `scale=1.0` is the
/// paper-faithful size.
pub fn generate(spec: &DatasetSpec, scale: f64, seed: u64) -> Dataset {
    assert!(scale > 0.0 && scale <= 1.0, "scale must be in (0,1]");
    let n = ((spec.n as f64 * scale) as usize).clamp(1_000.min(spec.n), spec.n);
    let mut rng = Rng::new(seed ^ 0xEA4B_0000).split(spec.index as u64);
    let data = match spec.class {
        StructureClass::GridGaussians { side } => grid_gaussians(&mut rng, n, spec.d, side),
        StructureClass::Curves { curves } => curves_mixture(&mut rng, n, spec.d, curves),
        StructureClass::Uniform => uniform(&mut rng, n, spec.d),
        StructureClass::RandomWalk { walks } => random_walk(&mut rng, n, spec.d, walks),
        StructureClass::Mixture { c, s_milli } => {
            mixture(&mut rng, n, spec.d, c, s_milli as f64 / 1000.0)
        }
        StructureClass::LowRank { c, r } => low_rank(&mut rng, n, spec.d, c, r),
        StructureClass::HeavyTail { c } => heavy_tail(&mut rng, n, spec.d, c),
    };
    let mut ds = Dataset::new(spec.name, data, n, spec.d).expect("generator produced bad shape");
    ds.standardize();
    ds
}

/// Convenience: isotropic Gaussian blobs for examples and tests.
pub fn blobs(n: usize, d: usize, c: usize, spread: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let data = mixture(&mut rng, n, d, c, spread);
    let mut ds = Dataset::new("blobs", data, n, d).unwrap();
    ds.standardize();
    ds
}

fn grid_gaussians(rng: &mut Rng, n: usize, d: usize, side: usize) -> Vec<f64> {
    // birch1-style: gaussians centred on a side×side grid in the first two
    // dims (extra dims, if any, get small noise).
    let clusters = side * side;
    let sigma = 0.35 / side as f64;
    let mut out = Vec::with_capacity(n * d);
    for _ in 0..n {
        let c = rng.below(clusters);
        let (gx, gy) = (c % side, c / side);
        let cx = (gx as f64 + 0.5) / side as f64;
        let cy = (gy as f64 + 0.5) / side as f64;
        out.push(cx + sigma * rng.normal());
        if d >= 2 {
            out.push(cy + sigma * rng.normal());
        }
        for _ in 2..d {
            out.push(0.05 * rng.normal());
        }
    }
    out
}

fn curves_mixture(rng: &mut Rng, n: usize, d: usize, curves: usize) -> Vec<f64> {
    // europe-style: dense points along closed piecewise-linear loops of
    // varying scale (country borders). Vertices are a loop around a random
    // centre with radius modulated by a few harmonics.
    struct Loop {
        cx: f64,
        cy: f64,
        scale: f64,
        harm: [(f64, f64); 3],
        weight: f64,
    }
    let loops: Vec<Loop> = (0..curves)
        .map(|_| Loop {
            cx: rng.f64(),
            cy: rng.f64(),
            scale: 0.03 + 0.2 * rng.f64(),
            harm: [
                (1.0 + rng.f64(), rng.f64() * std::f64::consts::TAU),
                (0.5 * rng.f64(), rng.f64() * std::f64::consts::TAU),
                (0.25 * rng.f64(), rng.f64() * std::f64::consts::TAU),
            ],
            weight: 0.2 + rng.f64(),
        })
        .collect();
    let weights: Vec<f64> = loops.iter().map(|l| l.weight).collect();
    let mut out = Vec::with_capacity(n * d);
    let jitter = 0.002;
    for _ in 0..n {
        let l = &loops[rng.weighted(&weights).unwrap()];
        let t = rng.f64() * std::f64::consts::TAU;
        let mut r = 1.0;
        for (m, &(amp, ph)) in l.harm.iter().enumerate() {
            r += amp * ((m as f64 + 2.0) * t + ph).sin() * 0.2;
        }
        let x = l.cx + l.scale * r * t.cos() + jitter * rng.normal();
        let y = l.cy + l.scale * r * t.sin() + jitter * rng.normal();
        out.push(x);
        if d >= 2 {
            out.push(y);
        }
        for _ in 2..d {
            out.push(0.05 * rng.normal());
        }
    }
    out
}

fn uniform(rng: &mut Rng, n: usize, d: usize) -> Vec<f64> {
    (0..n * d).map(|_| rng.f64()).collect()
}

fn random_walk(rng: &mut Rng, n: usize, d: usize, walks: usize) -> Vec<f64> {
    // Telemetry-style trajectories: `walks` independent mean-reverting
    // random walks, samples taken in time order.
    let per = n.div_ceil(walks);
    let mut out = Vec::with_capacity(n * d);
    let mut produced = 0;
    for _ in 0..walks {
        let mut pos: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let step = 0.05 + 0.1 * rng.f64();
        let pull = 0.01;
        for _ in 0..per {
            if produced == n {
                break;
            }
            for p in pos.iter_mut() {
                *p += step * rng.normal() - pull * *p;
            }
            out.extend_from_slice(&pos);
            produced += 1;
        }
    }
    out
}

fn mixture(rng: &mut Rng, n: usize, d: usize, c: usize, spread: f64) -> Vec<f64> {
    // Isotropic gaussian mixture; centres uniform in the unit cube, mildly
    // unbalanced component weights (realistic cluster-size skew).
    let centres: Vec<f64> = (0..c * d).map(|_| rng.f64()).collect();
    let weights: Vec<f64> = (0..c).map(|_| 0.3 + rng.f64()).collect();
    let mut out = Vec::with_capacity(n * d);
    for _ in 0..n {
        let j = rng.weighted(&weights).unwrap();
        for t in 0..d {
            out.push(centres[j * d + t] + spread * rng.normal());
        }
    }
    out
}

fn low_rank(rng: &mut Rng, n: usize, d: usize, c: usize, r: usize) -> Vec<f64> {
    // Image/PCA-style data: mixture in an r-dim latent space pushed
    // through a random linear map to d dims, plus small ambient noise.
    let map: Vec<f64> = (0..r * d).map(|_| rng.normal() / (r as f64).sqrt()).collect();
    let centres: Vec<f64> = (0..c * r).map(|_| 2.0 * rng.f64() - 1.0).collect();
    let weights: Vec<f64> = (0..c).map(|_| 0.5 + rng.f64()).collect();
    let spread = 0.25;
    let noise = 0.05;
    let mut latent = vec![0.0; r];
    let mut out = Vec::with_capacity(n * d);
    for _ in 0..n {
        let j = rng.weighted(&weights).unwrap();
        for (t, l) in latent.iter_mut().enumerate() {
            *l = centres[j * r + t] + spread * rng.normal();
        }
        for t in 0..d {
            let mut v = 0.0;
            for (s, &l) in latent.iter().enumerate() {
                v += l * map[s * d + t];
            }
            out.push(v + noise * rng.normal());
        }
    }
    out
}

fn heavy_tail(rng: &mut Rng, n: usize, d: usize, c: usize) -> Vec<f64> {
    // Behavioural/count-style data: log-normal-ish magnitudes, many values
    // near zero, cluster structure in which features are "on".
    let centres: Vec<f64> = (0..c * d)
        .map(|_| if rng.f64() < 0.3 { rng.f64() * 2.0 } else { 0.0 })
        .collect();
    let weights: Vec<f64> = (0..c).map(|_| (rng.f64() * 3.0).exp()).collect();
    let mut out = Vec::with_capacity(n * d);
    for _ in 0..n {
        let j = rng.weighted(&weights).unwrap();
        for t in 0..d {
            let base = centres[j * d + t];
            let v = if base > 0.0 {
                base * (0.5 * rng.normal()).exp()
            } else if rng.f64() < 0.05 {
                (rng.normal()).abs() * 0.5
            } else {
                0.0
            };
            out.push(v + 0.01 * rng.normal());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twenty_two_specs_match_table8() {
        let specs = paper_datasets();
        assert_eq!(specs.len(), 22);
        // spot-check paper values
        assert_eq!(specs[0].name, "birch");
        assert_eq!((specs[0].d, specs[0].n), (2, 100_000));
        assert_eq!(specs[12].name, "urand30");
        assert_eq!((specs[12].d, specs[12].n), (30, 1_000_000));
        assert_eq!(specs[21].name, "mnist784");
        assert_eq!((specs[21].d, specs[21].n), (784, 60_000));
        assert_eq!(specs[16].n, 2_458_285); // uscensus
        // indices are 1..22 in order
        for (i, s) in specs.iter().enumerate() {
            assert_eq!(s.index, i + 1);
        }
    }

    #[test]
    fn roman_ids_roundtrip() {
        assert_eq!(find("i").unwrap().name, "birch");
        assert_eq!(find("mnist784").unwrap().roman(), "xxii");
        assert!(find("nosuch").is_none());
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = find("birch").unwrap();
        let a = generate(&spec, 0.02, 7);
        let b = generate(&spec, 0.02, 7);
        assert_eq!(a.raw(), b.raw());
        let c = generate(&spec, 0.02, 8);
        assert_ne!(a.raw(), c.raw());
    }

    #[test]
    fn scaled_sizes_and_dims() {
        for spec in paper_datasets() {
            let ds = generate(&spec, 0.01, 3);
            assert_eq!(ds.d(), spec.d);
            assert!(ds.n() <= spec.n);
            assert!(ds.n() >= 1_000.min(spec.n));
        }
    }

    #[test]
    fn generated_data_is_standardized() {
        for name in ["birch", "europe", "urand2", "mv", "mnist50", "kddcup98"] {
            let spec = find(name).unwrap();
            let ds = generate(&spec, 0.02, 11);
            let (n, d) = (ds.n(), ds.d());
            for t in 0..d.min(5) {
                let mean: f64 = (0..n).map(|i| ds.row(i)[t]).sum::<f64>() / n as f64;
                let var: f64 = (0..n).map(|i| ds.row(i)[t].powi(2)).sum::<f64>() / n as f64;
                assert!(mean.abs() < 1e-9, "{name} feature {t} mean={mean}");
                // constant features standardise to 0 variance
                assert!(var < 1.5 && (var > 0.5 || var == 0.0), "{name} var={var}");
            }
        }
    }

    #[test]
    fn blobs_shape() {
        let ds = blobs(500, 6, 5, 0.1, 1);
        assert_eq!((ds.n(), ds.d()), (500, 6));
    }

    #[test]
    #[should_panic]
    fn generate_rejects_zero_scale() {
        let spec = find("birch").unwrap();
        generate(&spec, 0.0, 1);
    }
}
