//! The data-access seam: [`DataSource`].
//!
//! Every consumer of sample data in the coordination layer — the
//! sharded assignment scan (via
//! [`SharedRound`](crate::algorithms::common::SharedRound)), the
//! centroid update ([`UpdateState`](crate::coordinator::update::UpdateState)),
//! seeding ([`InitMethod`](crate::init::InitMethod)), and
//! [`FittedModel::predict`](crate::model::FittedModel::predict) — reads
//! samples through this trait instead of the concrete [`Dataset`].
//!
//! The contract is deliberately *range-oriented* (`rows(lo, len)`)
//! rather than whole-buffer (`raw()`): an implementation only has to
//! produce a contiguous window of rows at a time, which is exactly the
//! access pattern of the blocked batch scan. That makes the ROADMAP's
//! out-of-core shard layer and the mini-batch engine implementations of
//! a trait, not rewrites of the coordinator: a shard file, an mmap, or
//! a sampled batch can all sit behind `DataSource` unchanged — the
//! mini-batch engine's [`BatchView`](crate::data::BatchView) already
//! does exactly this.
//!
//! Implementations must uphold two invariants the algorithms rely on:
//!
//! * `rows`/`sqnorms_range` return *stable* values — two reads of the
//!   same range during one run observe identical bits (the bounds are
//!   only correct against immutable data);
//! * `sqnorms_range(i, len)[j] == ‖rows(i, len)[j·d .. (j+1)·d]‖²` —
//!   pre-computed squared norms (the paper's §4.1.1 engineering point).

use crate::linalg::sqdist;

/// Read-only access to `n` samples of dimension `d` (row-major `f64`).
///
/// `Sync` is a supertrait: sources are shared by every pool worker
/// during a round.
pub trait DataSource: Sync {
    /// Number of samples.
    fn n(&self) -> usize;

    /// Dimension of each sample.
    fn d(&self) -> usize;

    /// Human-readable name (used in reports).
    fn name(&self) -> &str {
        "custom"
    }

    /// A contiguous block of `len` rows starting at row `lo`, as one
    /// row-major slice of `len * d` values.
    fn rows(&self, lo: usize, len: usize) -> &[f64];

    /// Pre-computed `‖x(i)‖²` for rows `[lo, lo + len)`.
    fn sqnorms_range(&self, lo: usize, len: usize) -> &[f64];

    /// Row `i`.
    #[inline]
    fn row(&self, i: usize) -> &[f64] {
        self.rows(i, 1)
    }

    /// `‖x(i)‖²`.
    #[inline]
    fn sqnorm(&self, i: usize) -> f64 {
        self.sqnorms_range(i, 1)[0]
    }

    /// Mean squared distance to the assigned centroid — the k-means
    /// objective divided by `n`.
    fn mse(&self, centroids: &[f64], assignments: &[u32]) -> f64 {
        assert_eq!(assignments.len(), self.n());
        let d = self.d();
        let total: f64 = assignments
            .iter()
            .enumerate()
            .map(|(i, &a)| {
                sqdist(
                    self.row(i),
                    &centroids[a as usize * d..(a as usize + 1) * d],
                )
            })
            .sum();
        total / self.n() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::linalg::sqnorms_rows;

    /// A minimal non-`Dataset` source: borrowed rows, owned norms.
    struct Borrowed<'a> {
        rows: &'a [f64],
        sqnorms: Vec<f64>,
        d: usize,
    }

    impl<'a> Borrowed<'a> {
        fn new(rows: &'a [f64], d: usize) -> Self {
            Borrowed {
                sqnorms: sqnorms_rows(rows, d),
                rows,
                d,
            }
        }
    }

    impl DataSource for Borrowed<'_> {
        fn n(&self) -> usize {
            self.rows.len() / self.d
        }
        fn d(&self) -> usize {
            self.d
        }
        fn rows(&self, lo: usize, len: usize) -> &[f64] {
            &self.rows[lo * self.d..(lo + len) * self.d]
        }
        fn sqnorms_range(&self, lo: usize, len: usize) -> &[f64] {
            &self.sqnorms[lo..lo + len]
        }
    }

    #[test]
    fn dataset_implements_the_seam() {
        let ds = Dataset::new("t", vec![0.0, 0.0, 1.0, 1.0, 2.0, 0.0], 3, 2).unwrap();
        let src: &dyn DataSource = &ds;
        assert_eq!(src.n(), 3);
        assert_eq!(src.d(), 2);
        assert_eq!(src.name(), "t");
        assert_eq!(src.rows(1, 2), &[1.0, 1.0, 2.0, 0.0]);
        assert_eq!(src.row(2), &[2.0, 0.0]);
        assert_eq!(src.sqnorm(1), 2.0);
        assert_eq!(src.sqnorms_range(0, 3), &[0.0, 2.0, 4.0]);
    }

    #[test]
    fn default_row_and_sqnorm_delegate_to_ranges() {
        let raw = [0.0, 3.0, 4.0, 0.0];
        let src = Borrowed::new(&raw, 2);
        assert_eq!(src.n(), 2);
        assert_eq!(src.row(1), &[4.0, 0.0]);
        assert_eq!(src.sqnorm(0), 9.0);
        assert_eq!(src.sqnorm(1), 16.0);
    }

    #[test]
    fn trait_mse_matches_dataset_mse() {
        let ds = Dataset::new("t", vec![0.0, 0.0, 1.0, 1.0, 2.0, 0.0], 3, 2).unwrap();
        let centroids = vec![0.0, 0.0, 2.0, 0.0];
        let a = [0u32, 0, 1];
        let via_trait = {
            let src: &dyn DataSource = &ds;
            src.mse(&centroids, &a)
        };
        assert_eq!(via_trait.to_bits(), ds.mse(&centroids, &a).to_bits());
    }

    #[test]
    fn a_full_run_works_through_a_non_dataset_source() {
        // the seam is real: cluster through `Borrowed`, not `Dataset`
        use crate::algorithms::Algorithm;
        use crate::config::RunConfig;
        use crate::coordinator::Runner;
        let ds = crate::data::synth::blobs(300, 4, 5, 0.1, 7);
        let view = Borrowed::new(ds.raw(), ds.d());
        let cfg = RunConfig::new(Algorithm::ExpNs, 5).seed(3);
        let via_view = Runner::new(&cfg).run(&view).unwrap();
        let via_ds = Runner::new(&cfg).run(&ds).unwrap();
        assert_eq!(via_view.assignments, via_ds.assignments);
        assert_eq!(via_view.mse.to_bits(), via_ds.mse.to_bits());
        assert_eq!(via_view.counters, via_ds.counters);
    }
}
