//! The data-access seam: [`DataSource`] and the block-lease contract
//! ([`BlockCursor`] / [`RowBlock`]).
//!
//! Every consumer of sample data in the coordination layer — the
//! sharded assignment scan (via
//! [`SharedRound`](crate::algorithms::common::SharedRound)), the
//! centroid update ([`UpdateState`](crate::coordinator::update::UpdateState)),
//! seeding ([`InitMethod`](crate::init::InitMethod)), and
//! [`FittedModel::predict`](crate::model::FittedModel::predict) — reads
//! samples through this seam instead of a concrete container.
//!
//! ## Why a lease, not a borrow
//!
//! The seam used to be borrow-returning (`rows(lo, len) -> &[f64]` on
//! `&self`), which structurally cannot be implemented by a source that
//! *refills a window*: an out-of-core reader has one resident buffer per
//! worker and must overwrite it as the scan advances, so it can never
//! hand out a borrow tied to `&self`. The contract is therefore a
//! **block lease**: each pool worker [`open`](DataSource::open)s a
//! [`BlockCursor`] for its shard range and advances block by block; a
//! [`RowBlock`] is valid until the next lease from the same cursor.
//! Fully-resident sources ([`Dataset`](crate::data::Dataset),
//! [`BatchView`](crate::data::BatchView)) lease zero-copy borrows of
//! their buffers; out-of-core sources
//! ([`MmapSource`](crate::data::ooc::MmapSource) leases pages of the
//! mapping, [`ChunkedFileSource`](crate::data::ChunkedFileSource) leases
//! its per-cursor resident window, refilled on demand).
//!
//! Implementations must uphold the invariants the algorithms rely on:
//!
//! * **stability** — two leases of the same range during one run
//!   observe identical bits (the bounds are only correct against
//!   immutable data);
//! * **norms match rows** — `block.sqnorms()[j]` equals
//!   `sqnorm(block.row(lo + j))` bit-for-bit, computed once with
//!   [`sqnorm`](crate::linalg::sqnorm) (the paper's §4.1.1 engineering
//!   point);
//! * **coverage** — a cursor opened for `[lo, lo+len)` can lease any
//!   sub-range of it, in any order (scans are monotone; the delta
//!   update and seeding make monotone or random accesses).
//!
//! These invariants are checked for every implementation by the shared
//! property harness in
//! [`algorithms::testutil::assert_block_lease_contract`](crate::algorithms::testutil::assert_block_lease_contract).

use crate::linalg::sqdist;
use crate::metrics::IoTelemetry;

/// One leased, contiguous block of rows with their precomputed squared
/// norms. Indices are **global** (`lo()` is the block's first global
/// row), so consumers address samples the same way regardless of which
/// cursor leased the block.
#[derive(Clone, Copy, Debug)]
pub struct RowBlock<'c> {
    lo: usize,
    d: usize,
    rows: &'c [f64],
    sqnorms: &'c [f64],
}

impl<'c> RowBlock<'c> {
    /// Assemble a block (used by `BlockCursor` implementations).
    /// Panics when rows and norms disagree on the row count.
    pub fn new(lo: usize, d: usize, rows: &'c [f64], sqnorms: &'c [f64]) -> Self {
        assert_eq!(rows.len(), sqnorms.len() * d, "rows/norms shape mismatch");
        RowBlock {
            lo,
            d,
            rows,
            sqnorms,
        }
    }

    /// Global index of the first row in the block.
    #[inline]
    pub fn lo(&self) -> usize {
        self.lo
    }

    /// Number of rows in the block.
    #[inline]
    pub fn len(&self) -> usize {
        self.sqnorms.len()
    }

    /// True when the block holds no rows.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.sqnorms.is_empty()
    }

    /// Row dimension.
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// All rows, row-major `len × d`.
    #[inline]
    pub fn rows(&self) -> &'c [f64] {
        self.rows
    }

    /// Precomputed `‖x‖²` per row, aligned with [`rows`](RowBlock::rows).
    #[inline]
    pub fn sqnorms(&self) -> &'c [f64] {
        self.sqnorms
    }

    /// Row at **global** index `i` (must lie inside the block).
    #[inline]
    pub fn row(&self, i: usize) -> &'c [f64] {
        let off = i - self.lo;
        &self.rows[off * self.d..(off + 1) * self.d]
    }

    /// `‖x(i)‖²` at **global** index `i`.
    #[inline]
    pub fn sqnorm(&self, i: usize) -> f64 {
        self.sqnorms[i - self.lo]
    }
}

/// A per-worker guard for reading one shard's rows block by block.
///
/// A cursor is opened for a row range by [`DataSource::open`] and is the
/// *only* way to reach sample values. The single primitive is
/// [`lease`](BlockCursor::lease): borrow a block of rows until the next
/// lease from the same cursor. In-memory cursors slice their backing
/// buffers (zero copy); windowed cursors reuse one resident buffer and
/// refill it when a lease falls outside the window — which is exactly
/// why the lease expires at the next call.
///
/// Cursors are not `Sync` and never shared: every pool worker opens its
/// own for the shard it scans.
pub trait BlockCursor {
    /// Row dimension of the underlying source.
    fn d(&self) -> usize;

    /// Lease rows `[lo, lo+len)` (global indices; must lie inside the
    /// range the cursor was opened for). The returned block is valid
    /// until the next `lease` call on this cursor.
    fn lease(&mut self, lo: usize, len: usize) -> RowBlock<'_>;

    /// Lease a single row (convenience over [`lease`](BlockCursor::lease)).
    #[inline]
    fn row(&mut self, i: usize) -> &[f64] {
        self.lease(i, 1).rows
    }

    /// `‖x(i)‖²` for a single row.
    #[inline]
    fn sqnorm(&mut self, i: usize) -> f64 {
        self.lease(i, 1).sqnorms[0]
    }
}

/// Block size used by the default [`DataSource::mse`] walk.
const MSE_BLOCK: usize = 128;

/// Read-only access to `n` samples of dimension `d` (row-major `f64`).
///
/// `Sync` is a supertrait: sources are shared by every pool worker
/// during a round — but all row access goes through per-worker
/// [`BlockCursor`]s, so the source itself only needs to hand out
/// cursors and answer shape queries.
pub trait DataSource: Sync {
    /// Number of samples.
    fn n(&self) -> usize;

    /// Dimension of each sample.
    fn d(&self) -> usize;

    /// Human-readable name (used in reports).
    fn name(&self) -> &str {
        "custom"
    }

    /// Open a block cursor over rows `[lo, lo+len)` — one per pool
    /// worker and shard. Opening is cheap (a slice borrow for resident
    /// sources, a file handle + empty window for out-of-core ones);
    /// the data is read lease by lease.
    fn open(&self, lo: usize, len: usize) -> Box<dyn BlockCursor + '_>;

    /// I/O telemetry snapshot (bytes read, blocks leased, window
    /// refills) for out-of-core sources; `None` for resident sources.
    /// Runners report the per-run delta of two snapshots.
    fn io_stats(&self) -> Option<IoTelemetry> {
        None
    }

    /// Mean squared distance to the assigned centroid — the k-means
    /// objective divided by `n`. Walks the source block by block with
    /// one serial accumulator, so the summation order (and the result's
    /// bits) is identical for every implementation.
    fn mse(&self, centroids: &[f64], assignments: &[u32]) -> f64 {
        assert_eq!(assignments.len(), self.n());
        let (n, d) = (self.n(), self.d());
        let mut cur = self.open(0, n);
        let mut total = 0.0;
        let mut start = 0;
        while start < n {
            let len = MSE_BLOCK.min(n - start);
            let block = cur.lease(start, len);
            for (off, a) in assignments[start..start + len].iter().enumerate() {
                let j = *a as usize;
                total += sqdist(block.row(start + off), &centroids[j * d..(j + 1) * d]);
            }
            start += len;
        }
        total / n as f64
    }
}

/// A ready-made cursor over fully-resident buffers: leases are plain
/// zero-copy slices. Used by [`Dataset`](crate::data::Dataset),
/// [`BatchView`](crate::data::BatchView), and any custom source whose
/// rows already live in memory.
pub struct SliceCursor<'a> {
    rows: &'a [f64],
    sqnorms: &'a [f64],
    d: usize,
    /// Opened range (global), for lease validation.
    lo: usize,
    len: usize,
}

impl<'a> SliceCursor<'a> {
    /// Cursor over rows `[lo, lo+len)` of a resident `rows`/`sqnorms`
    /// pair covering the *whole* source (global indexing).
    pub fn new(rows: &'a [f64], sqnorms: &'a [f64], d: usize, lo: usize, len: usize) -> Self {
        SliceCursor {
            rows,
            sqnorms,
            d,
            lo,
            len,
        }
    }
}

impl BlockCursor for SliceCursor<'_> {
    fn d(&self) -> usize {
        self.d
    }

    fn lease(&mut self, lo: usize, len: usize) -> RowBlock<'_> {
        debug_assert!(
            lo >= self.lo && lo + len <= self.lo + self.len,
            "lease [{lo}, {}) outside cursor range [{}, {})",
            lo + len,
            self.lo,
            self.lo + self.len
        );
        RowBlock::new(
            lo,
            self.d,
            &self.rows[lo * self.d..(lo + len) * self.d],
            &self.sqnorms[lo..lo + len],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::linalg::sqnorms_rows;

    /// A minimal non-`Dataset` source: borrowed rows, owned norms.
    struct Borrowed<'a> {
        rows: &'a [f64],
        sqnorms: Vec<f64>,
        d: usize,
    }

    impl<'a> Borrowed<'a> {
        fn new(rows: &'a [f64], d: usize) -> Self {
            Borrowed {
                sqnorms: sqnorms_rows(rows, d),
                rows,
                d,
            }
        }
    }

    impl DataSource for Borrowed<'_> {
        fn n(&self) -> usize {
            self.rows.len() / self.d
        }
        fn d(&self) -> usize {
            self.d
        }
        fn open(&self, lo: usize, len: usize) -> Box<dyn BlockCursor + '_> {
            Box::new(SliceCursor::new(self.rows, &self.sqnorms, self.d, lo, len))
        }
    }

    #[test]
    fn dataset_implements_the_seam() {
        let ds = Dataset::new("t", vec![0.0, 0.0, 1.0, 1.0, 2.0, 0.0], 3, 2).unwrap();
        let src: &dyn DataSource = &ds;
        assert_eq!(src.n(), 3);
        assert_eq!(src.d(), 2);
        assert_eq!(src.name(), "t");
        let mut cur = src.open(0, 3);
        let block = cur.lease(1, 2);
        assert_eq!(block.lo(), 1);
        assert_eq!(block.len(), 2);
        assert_eq!(block.rows(), &[1.0, 1.0, 2.0, 0.0]);
        assert_eq!(block.row(2), &[2.0, 0.0]);
        assert_eq!(block.sqnorm(1), 2.0);
        let all = cur.lease(0, 3);
        assert_eq!(all.sqnorms(), &[0.0, 2.0, 4.0]);
    }

    #[test]
    fn cursor_row_and_sqnorm_delegate_to_lease() {
        let raw = [0.0, 3.0, 4.0, 0.0];
        let src = Borrowed::new(&raw, 2);
        assert_eq!(src.n(), 2);
        let mut cur = src.open(0, 2);
        assert_eq!(cur.row(1), &[4.0, 0.0]);
        assert_eq!(cur.sqnorm(0), 9.0);
        assert_eq!(cur.sqnorm(1), 16.0);
    }

    #[test]
    fn leases_can_revisit_ranges() {
        let raw: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let src = Borrowed::new(&raw, 2);
        let mut cur = src.open(0, 10);
        let first = cur.lease(3, 4).rows().to_vec();
        let _ = cur.lease(7, 3);
        // stability: re-leasing observes identical bits
        assert_eq!(cur.lease(3, 4).rows(), first.as_slice());
    }

    #[test]
    fn trait_mse_matches_dataset_mse() {
        let ds = Dataset::new("t", vec![0.0, 0.0, 1.0, 1.0, 2.0, 0.0], 3, 2).unwrap();
        let centroids = vec![0.0, 0.0, 2.0, 0.0];
        let a = [0u32, 0, 1];
        let via_trait = {
            let src: &dyn DataSource = &ds;
            src.mse(&centroids, &a)
        };
        assert_eq!(via_trait.to_bits(), ds.mse(&centroids, &a).to_bits());
    }

    #[test]
    fn a_full_run_works_through_a_non_dataset_source() {
        // the seam is real: cluster through `Borrowed`, not `Dataset`
        use crate::algorithms::Algorithm;
        use crate::config::RunConfig;
        use crate::coordinator::Runner;
        let ds = crate::data::synth::blobs(300, 4, 5, 0.1, 7);
        let view = Borrowed::new(ds.raw(), ds.d());
        let cfg = RunConfig::new(Algorithm::ExpNs, 5).seed(3);
        let via_view = Runner::new(&cfg).run(&view).unwrap();
        let via_ds = Runner::new(&cfg).run(&ds).unwrap();
        assert_eq!(via_view.assignments, via_ds.assignments);
        assert_eq!(via_view.mse.to_bits(), via_ds.mse.to_bits());
        assert_eq!(via_view.counters, via_ds.counters);
    }
}
