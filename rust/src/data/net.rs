//! Network data source — a re-export shim.
//!
//! [`NetSource`] lives with the rest of the distributed subsystem in
//! [`dist::netsource`](crate::dist::netsource) (it shares the wire
//! codecs and the shard-connection client), but it *is* a
//! [`DataSource`](crate::data::DataSource) like the others, so it is
//! also reachable from here alongside `Dataset`, `MmapSource`, and
//! `ChunkedFileSource`.

pub use crate::dist::netsource::NetSource;
