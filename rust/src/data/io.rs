//! Dataset I/O: a simple little-endian binary format and CSV.
//!
//! The binary format (`.ekb`) is `magic "EAKM" | u32 version | u64 n |
//! u64 d | n*d f64 LE`. CSV is headerless numeric rows.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::dataset::Dataset;
use crate::error::{EakmError, Result};

pub(crate) const MAGIC: &[u8; 4] = b"EAKM";
pub(crate) const VERSION: u32 = 1;
/// Bytes before the row-major f64 payload: magic + version + n + d.
/// A multiple of 8, so the payload is f64-aligned in an mmap.
pub(crate) const HEADER_LEN: usize = 4 + 4 + 8 + 8;

/// Values per chunk for the bulk payload transfers (64 KiB of bytes) —
/// large enough that syscall/copy overhead amortises, small enough to
/// stay cache-friendly.
const IO_CHUNK_VALS: usize = 8192;

/// Read and validate an `.ekb` header, returning `(n, d)`. Shared by
/// [`load_bin`] and the out-of-core sources in [`crate::data::ooc`].
pub(crate) fn read_bin_header(r: &mut impl Read, path: &Path) -> Result<(usize, usize)> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(EakmError::Data(format!("{}: not an EAKM file", path.display())));
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    if version != VERSION {
        return Err(EakmError::Data(format!("unsupported version {version}")));
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let d = u64::from_le_bytes(b8) as usize;
    if n == 0 || d == 0 || n.checked_mul(d).is_none() {
        return Err(EakmError::Data(format!("bad header n={n} d={d}")));
    }
    Ok((n, d))
}

/// Decode little-endian f64 payload bytes into `out`.
pub(crate) fn decode_f64_le(bytes: &[u8], out: &mut Vec<f64>) {
    debug_assert_eq!(bytes.len() % 8, 0);
    out.extend(
        bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().expect("8-byte chunk"))),
    );
}

/// Save a dataset in the binary format. The payload is written in
/// ~64 KiB chunks (one `write_all` per chunk, not per value).
pub fn save_bin(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(ds.n() as u64).to_le_bytes())?;
    w.write_all(&(ds.d() as u64).to_le_bytes())?;
    let mut buf = Vec::with_capacity(IO_CHUNK_VALS * 8);
    for chunk in ds.raw().chunks(IO_CHUNK_VALS) {
        buf.clear();
        for &v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a dataset from the binary format. The payload is read in
/// ~64 KiB chunks — one `read_exact` per chunk, not the one-value-read
/// loop this function used to be (which cost a `read_exact` dispatch
/// per f64 and dominated load time on datasets of any size).
pub fn load_bin(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(File::open(path)?);
    let (n, d) = read_bin_header(&mut r, path)?;
    let total = n * d;
    let mut data = Vec::with_capacity(total);
    let mut buf = vec![0u8; IO_CHUNK_VALS * 8];
    let mut remaining = total;
    while remaining > 0 {
        let take = IO_CHUNK_VALS.min(remaining);
        r.read_exact(&mut buf[..take * 8])?;
        decode_f64_le(&buf[..take * 8], &mut data);
        remaining -= take;
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "bin".into());
    Dataset::new(name, data, n, d)
}

/// Load a headerless numeric CSV (comma- or whitespace-separated).
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let r = BufReader::new(File::open(path)?);
    let mut data = Vec::new();
    let mut d = 0usize;
    let mut n = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<f64> = line
            .split(|c: char| c == ',' || c.is_whitespace())
            .filter(|f| !f.is_empty())
            .map(|f| {
                f.parse::<f64>().map_err(|_| {
                    EakmError::Data(format!("{}:{}: bad number {f:?}", path.display(), lineno + 1))
                })
            })
            .collect::<Result<_>>()?;
        if fields.is_empty() {
            continue;
        }
        if d == 0 {
            d = fields.len();
        } else if fields.len() != d {
            return Err(EakmError::Data(format!(
                "{}:{}: expected {d} fields, got {}",
                path.display(),
                lineno + 1,
                fields.len()
            )));
        }
        data.extend_from_slice(&fields);
        n += 1;
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "csv".into());
    Dataset::new(name, data, n, d)
}

/// Save as CSV (for interop/debugging; lossy via `{:.17e}` is avoided by
/// using Rust's shortest-roundtrip float formatting).
pub fn save_csv(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for i in 0..ds.n() {
        let row = ds.row(i);
        for (t, v) in row.iter().enumerate() {
            if t > 0 {
                write!(w, ",")?;
            }
            write!(w, "{v}")?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("eakm-io-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn bin_roundtrip() {
        let ds = blobs(200, 7, 4, 0.1, 5);
        let path = tmpdir().join("rt.ekb");
        save_bin(&ds, &path).unwrap();
        let back = load_bin(&path).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.d(), ds.d());
        assert_eq!(back.raw(), ds.raw());
    }

    #[test]
    fn csv_roundtrip() {
        let ds = blobs(50, 3, 2, 0.2, 6);
        let path = tmpdir().join("rt.csv");
        save_csv(&ds, &path).unwrap();
        let back = load_csv(&path).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.d(), ds.d());
        for (a, b) in back.raw().iter().zip(ds.raw()) {
            assert_eq!(a, b); // shortest-roundtrip formatting is exact
        }
    }

    #[test]
    fn csv_rejects_ragged_rows() {
        let path = tmpdir().join("ragged.csv");
        std::fs::write(&path, "1,2,3\n4,5\n").unwrap();
        assert!(load_csv(&path).is_err());
    }

    #[test]
    fn csv_skips_comments_and_blanks() {
        let path = tmpdir().join("comments.csv");
        std::fs::write(&path, "# header\n\n1 2\n3,4\n").unwrap();
        let ds = load_csv(&path).unwrap();
        assert_eq!((ds.n(), ds.d()), (2, 2));
        assert_eq!(ds.raw(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn bin_rejects_garbage() {
        let path = tmpdir().join("garbage.ekb");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(load_bin(&path).is_err());
    }

    #[test]
    fn bin_rejects_truncated_payload() {
        let ds = blobs(100, 4, 2, 0.1, 9);
        let path = tmpdir().join("trunc.ekb");
        save_bin(&ds, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
        assert!(load_bin(&path).is_err());
    }

    #[test]
    fn bin_roundtrip_a_million_values() {
        // ~1M values (n·d = 125_000 × 8): exercises many full chunks of
        // the bulk read/write paths plus a partial tail chunk
        let (n, d) = (125_000usize, 8usize);
        let data: Vec<f64> = (0..n * d)
            .map(|i| {
                let x = (i as f64).mul_add(0.618_033_988_749_895, 0.25);
                (x - x.floor()) * 2.0 - 1.0
            })
            .collect();
        let ds = Dataset::new("million", data, n, d).unwrap();
        let path = tmpdir().join("million.ekb");
        save_bin(&ds, &path).unwrap();
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            (super::HEADER_LEN + n * d * 8) as u64
        );
        let back = load_bin(&path).unwrap();
        assert_eq!(back.n(), n);
        assert_eq!(back.d(), d);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(back.raw()), bits(ds.raw()));
    }
}
