//! Dataset I/O: a simple little-endian binary format and CSV.
//!
//! The binary format (`.ekb`) has two versions:
//!
//! - v1: `magic "EAKM" | u32 1 | u64 n | u64 d | n·d f64 LE` — 24-byte
//!   header, always f64 payload. [`save_bin`] still writes this, so
//!   every file produced before the mixed-precision work loads
//!   unchanged.
//! - v2: `magic "EAKM" | u32 2 | u64 n | u64 d | u64 elem_bytes |
//!   n·d elems LE` — 32-byte header whose `elem_bytes` field (4 or 8)
//!   carries the storage width. [`save_bin_f32`] writes v2 with
//!   `elem_bytes = 4`. The 32-byte payload offset keeps f64 payloads
//!   8-aligned and f32 payloads 4-aligned for the mmap source.
//!
//! Readers widen f32 payloads to f64 at decode time
//! ([`decode_widen_le`]); every consumer downstream of a header sees
//! `f64` rows regardless of storage width. CSV is headerless numeric
//! rows.

use std::fmt;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::dataset::Dataset;
use crate::error::{EakmError, Result};

pub(crate) const MAGIC: &[u8; 4] = b"EAKM";
/// v1: f64 payload, no width field.
pub(crate) const VERSION_F64: u32 = 1;
/// v2: explicit `elem_bytes` width field.
pub(crate) const VERSION_WIDE: u32 = 2;
/// v1 header bytes before the payload: magic + version + n + d.
/// A multiple of 8, so the f64 payload is aligned in an mmap.
pub(crate) const HEADER_LEN: usize = 4 + 4 + 8 + 8;
/// v2 header bytes: v1 fields + u64 elem_bytes. Still a multiple of 8.
pub(crate) const HEADER_LEN_V2: usize = HEADER_LEN + 8;

/// Values per chunk for the bulk payload transfers (64 KiB of bytes) —
/// large enough that syscall/copy overhead amortises, small enough to
/// stay cache-friendly.
const IO_CHUNK_VALS: usize = 8192;

/// Storage width of an `.ekb` payload (and of in-memory sources).
/// Kernels always *accumulate* in f64; this is about what the rows are
/// stored/streamed as.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElemWidth {
    /// 4-byte little-endian IEEE-754 single precision, widened on read.
    F32,
    /// 8-byte little-endian IEEE-754 double precision.
    F64,
}

impl ElemWidth {
    /// Payload bytes per element.
    #[inline]
    pub fn bytes(self) -> usize {
        match self {
            ElemWidth::F32 => 4,
            ElemWidth::F64 => 8,
        }
    }

    /// Parse a CLI spelling (`"f32"` / `"f64"`).
    pub fn parse(s: &str) -> Option<ElemWidth> {
        match s {
            "f32" => Some(ElemWidth::F32),
            "f64" => Some(ElemWidth::F64),
            _ => None,
        }
    }
}

impl fmt::Display for ElemWidth {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ElemWidth::F32 => "f32",
            ElemWidth::F64 => "f64",
        })
    }
}

/// A validated `.ekb` header: shape, storage width, and payload offset.
#[derive(Clone, Copy, Debug)]
pub(crate) struct EkbHeader {
    pub n: usize,
    pub d: usize,
    pub width: ElemWidth,
    /// Byte offset of the first payload element (24 for v1, 32 for v2).
    pub payload: usize,
}

impl EkbHeader {
    /// Total payload bytes.
    pub fn payload_bytes(&self) -> usize {
        self.n * self.d * self.width.bytes()
    }

    /// Expected total file length.
    pub fn file_len(&self) -> u64 {
        (self.payload + self.payload_bytes()) as u64
    }

    /// Byte offset of row `lo`'s first element.
    pub fn row_offset(&self, lo: usize) -> u64 {
        (self.payload + lo * self.d * self.width.bytes()) as u64
    }
}

/// Read and validate an `.ekb` header (v1 or v2). Shared by
/// [`load_bin`] and the out-of-core sources in [`crate::data::ooc`].
pub(crate) fn read_bin_header(r: &mut impl Read, path: &Path) -> Result<EkbHeader> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(EakmError::Data(format!("{}: not an EAKM file", path.display())));
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let d = u64::from_le_bytes(b8) as usize;
    if n == 0 || d == 0 || n.checked_mul(d).is_none() {
        return Err(EakmError::Data(format!("bad header n={n} d={d}")));
    }
    let (width, payload) = match version {
        VERSION_F64 => (ElemWidth::F64, HEADER_LEN),
        VERSION_WIDE => {
            r.read_exact(&mut b8)?;
            let width = match u64::from_le_bytes(b8) {
                4 => ElemWidth::F32,
                8 => ElemWidth::F64,
                eb => {
                    return Err(EakmError::Data(format!(
                        "{}: bad elem_bytes {eb} (want 4 or 8)",
                        path.display()
                    )))
                }
            };
            (width, HEADER_LEN_V2)
        }
        _ => return Err(EakmError::Data(format!("unsupported version {version}"))),
    };
    Ok(EkbHeader { n, d, width, payload })
}

/// Decode little-endian f64 payload bytes into `out`.
pub(crate) fn decode_f64_le(bytes: &[u8], out: &mut Vec<f64>) {
    debug_assert_eq!(bytes.len() % 8, 0);
    out.extend(
        bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().expect("8-byte chunk"))),
    );
}

/// Decode little-endian f32 payload bytes into `out`, widening to f64.
pub(crate) fn decode_f32_le(bytes: &[u8], out: &mut Vec<f64>) {
    debug_assert_eq!(bytes.len() % 4, 0);
    out.extend(
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("4-byte chunk")) as f64),
    );
}

/// Decode a payload chunk of the given storage width into f64s.
pub(crate) fn decode_widen_le(width: ElemWidth, bytes: &[u8], out: &mut Vec<f64>) {
    match width {
        ElemWidth::F32 => decode_f32_le(bytes, out),
        ElemWidth::F64 => decode_f64_le(bytes, out),
    }
}

/// Save a dataset in the v1 binary format (f64 payload). The payload is
/// written in ~64 KiB chunks (one `write_all` per chunk, not per value).
pub fn save_bin(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION_F64.to_le_bytes())?;
    w.write_all(&(ds.n() as u64).to_le_bytes())?;
    w.write_all(&(ds.d() as u64).to_le_bytes())?;
    let mut buf = Vec::with_capacity(IO_CHUNK_VALS * 8);
    for chunk in ds.raw().chunks(IO_CHUNK_VALS) {
        buf.clear();
        for &v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    w.flush()?;
    Ok(())
}

/// Save a dataset in the v2 binary format with an f32 payload — half
/// the bytes of [`save_bin`]. Narrowing rounds to nearest-even;
/// magnitudes beyond f32 range become ±inf in the file and are rejected
/// by `Dataset::new`'s finiteness check on load.
pub fn save_bin_f32(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION_WIDE.to_le_bytes())?;
    w.write_all(&(ds.n() as u64).to_le_bytes())?;
    w.write_all(&(ds.d() as u64).to_le_bytes())?;
    w.write_all(&4u64.to_le_bytes())?;
    let mut buf = Vec::with_capacity(IO_CHUNK_VALS * 4);
    for chunk in ds.raw().chunks(IO_CHUNK_VALS) {
        buf.clear();
        for &v in chunk {
            buf.extend_from_slice(&(v as f32).to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a dataset from the binary format (either version, either
/// width). The payload is read in ~64 KiB chunks — one `read_exact` per
/// chunk, not the one-value-read loop this function used to be (which
/// cost a `read_exact` dispatch per f64 and dominated load time on
/// datasets of any size). f32 payloads are widened to f64 here; the
/// resulting `Dataset` is indistinguishable from one built in memory
/// from the widened values.
pub fn load_bin(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(File::open(path)?);
    let hdr = read_bin_header(&mut r, path)?;
    let total = hdr.n * hdr.d;
    let eb = hdr.width.bytes();
    let mut data = Vec::with_capacity(total);
    let mut buf = vec![0u8; IO_CHUNK_VALS * eb];
    let mut remaining = total;
    while remaining > 0 {
        let take = IO_CHUNK_VALS.min(remaining);
        r.read_exact(&mut buf[..take * eb])?;
        decode_widen_le(hdr.width, &buf[..take * eb], &mut data);
        remaining -= take;
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "bin".into());
    Dataset::new(name, data, hdr.n, hdr.d)
}

/// Load a headerless numeric CSV (comma- or whitespace-separated).
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let r = BufReader::new(File::open(path)?);
    let mut data = Vec::new();
    let mut d = 0usize;
    let mut n = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<f64> = line
            .split(|c: char| c == ',' || c.is_whitespace())
            .filter(|f| !f.is_empty())
            .map(|f| {
                f.parse::<f64>().map_err(|_| {
                    EakmError::Data(format!("{}:{}: bad number {f:?}", path.display(), lineno + 1))
                })
            })
            .collect::<Result<_>>()?;
        if fields.is_empty() {
            continue;
        }
        if d == 0 {
            d = fields.len();
        } else if fields.len() != d {
            return Err(EakmError::Data(format!(
                "{}:{}: expected {d} fields, got {}",
                path.display(),
                lineno + 1,
                fields.len()
            )));
        }
        data.extend_from_slice(&fields);
        n += 1;
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "csv".into());
    Dataset::new(name, data, n, d)
}

/// Save as CSV (for interop/debugging; lossy via `{:.17e}` is avoided by
/// using Rust's shortest-roundtrip float formatting).
pub fn save_csv(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for i in 0..ds.n() {
        let row = ds.row(i);
        for (t, v) in row.iter().enumerate() {
            if t > 0 {
                write!(w, ",")?;
            }
            write!(w, "{v}")?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("eakm-io-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn bin_roundtrip() {
        let ds = blobs(200, 7, 4, 0.1, 5);
        let path = tmpdir().join("rt.ekb");
        save_bin(&ds, &path).unwrap();
        let back = load_bin(&path).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.d(), ds.d());
        assert_eq!(back.raw(), ds.raw());
    }

    #[test]
    fn bin_f32_roundtrip_is_lossless_on_f32_values() {
        // pre-round the data to f32: narrow→widen is then exact and the
        // loaded dataset is bit-identical to the rounded original
        let mut ds = blobs(200, 7, 4, 0.1, 5);
        let rounded: Vec<f64> = ds.raw().iter().map(|&v| v as f32 as f64).collect();
        ds = Dataset::new("rounded", rounded, ds.n(), ds.d()).unwrap();
        let path = tmpdir().join("rt32.ekb");
        save_bin_f32(&ds, &path).unwrap();
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            (HEADER_LEN_V2 + ds.n() * ds.d() * 4) as u64
        );
        let back = load_bin(&path).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.d(), ds.d());
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(back.raw()), bits(ds.raw()));
    }

    #[test]
    fn bin_f32_widening_rounds_general_values() {
        let ds = blobs(64, 3, 2, 0.3, 11);
        let path = tmpdir().join("round32.ekb");
        save_bin_f32(&ds, &path).unwrap();
        let back = load_bin(&path).unwrap();
        for (a, b) in back.raw().iter().zip(ds.raw()) {
            assert_eq!(*a, *b as f32 as f64);
        }
    }

    #[test]
    fn bin_f32_rejects_truncated_payload() {
        let ds = blobs(100, 4, 2, 0.1, 9);
        let path = tmpdir().join("trunc32.ekb");
        save_bin_f32(&ds, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load_bin(&path).is_err());
    }

    #[test]
    fn bin_rejects_bad_elem_bytes() {
        // v2 header claiming 2-byte elements
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION_WIDE.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 8]);
        let path = tmpdir().join("badwidth.ekb");
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_bin(&path).is_err());
    }

    #[test]
    fn elem_width_parse_and_display() {
        assert_eq!(ElemWidth::parse("f32"), Some(ElemWidth::F32));
        assert_eq!(ElemWidth::parse("f64"), Some(ElemWidth::F64));
        assert_eq!(ElemWidth::parse("f16"), None);
        assert_eq!(ElemWidth::F32.to_string(), "f32");
        assert_eq!(ElemWidth::F64.bytes(), 8);
    }

    #[test]
    fn csv_roundtrip() {
        let ds = blobs(50, 3, 2, 0.2, 6);
        let path = tmpdir().join("rt.csv");
        save_csv(&ds, &path).unwrap();
        let back = load_csv(&path).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.d(), ds.d());
        for (a, b) in back.raw().iter().zip(ds.raw()) {
            assert_eq!(a, b); // shortest-roundtrip formatting is exact
        }
    }

    #[test]
    fn csv_rejects_ragged_rows() {
        let path = tmpdir().join("ragged.csv");
        std::fs::write(&path, "1,2,3\n4,5\n").unwrap();
        assert!(load_csv(&path).is_err());
    }

    #[test]
    fn csv_skips_comments_and_blanks() {
        let path = tmpdir().join("comments.csv");
        std::fs::write(&path, "# header\n\n1 2\n3,4\n").unwrap();
        let ds = load_csv(&path).unwrap();
        assert_eq!((ds.n(), ds.d()), (2, 2));
        assert_eq!(ds.raw(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn bin_rejects_garbage() {
        let path = tmpdir().join("garbage.ekb");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(load_bin(&path).is_err());
    }

    #[test]
    fn bin_rejects_truncated_payload() {
        let ds = blobs(100, 4, 2, 0.1, 9);
        let path = tmpdir().join("trunc.ekb");
        save_bin(&ds, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
        assert!(load_bin(&path).is_err());
    }

    #[test]
    fn bin_roundtrip_a_million_values() {
        // ~1M values (n·d = 125_000 × 8): exercises many full chunks of
        // the bulk read/write paths plus a partial tail chunk
        let (n, d) = (125_000usize, 8usize);
        let data: Vec<f64> = (0..n * d)
            .map(|i| {
                let x = (i as f64).mul_add(0.618_033_988_749_895, 0.25);
                (x - x.floor()) * 2.0 - 1.0
            })
            .collect();
        let ds = Dataset::new("million", data, n, d).unwrap();
        let path = tmpdir().join("million.ekb");
        save_bin(&ds, &path).unwrap();
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            (super::HEADER_LEN + n * d * 8) as u64
        );
        let back = load_bin(&path).unwrap();
        assert_eq!(back.n(), n);
        assert_eq!(back.d(), d);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(back.raw()), bits(ds.raw()));
    }
}
