//! Datasets: container, standardisation, synthetic generators for the 22
//! paper datasets (Table 8 substitution), and simple binary/CSV I/O.

pub mod dataset;
pub mod io;
pub mod synth;

pub use dataset::Dataset;
