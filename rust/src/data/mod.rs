//! Datasets: container, standardisation, synthetic generators for the 22
//! paper datasets (Table 8 substitution), simple binary/CSV I/O, the
//! block-lease [`DataSource`] seam every consumer reads samples through
//! ([`BlockCursor`] / [`RowBlock`]), the [`BatchView`] sampled view the
//! mini-batch engine draws through it, the out-of-core sources
//! ([`ooc`]) that cluster `.ekb` files larger than RAM behind the same
//! seam, and the network source ([`net`]) that leases rows from shard
//! servers behind it too.

pub mod batch;
pub mod dataset;
pub mod f32set;
pub mod io;
pub mod net;
pub mod ooc;
pub mod source;
pub mod synth;

pub use batch::BatchView;
pub use dataset::Dataset;
pub use f32set::DatasetF32;
pub use io::ElemWidth;
pub use net::NetSource;
pub use ooc::{ChunkedFileSource, OocMode};
#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
pub use ooc::MmapSource;
pub use source::{BlockCursor, DataSource, RowBlock, SliceCursor};
