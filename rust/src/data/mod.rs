//! Datasets: container, standardisation, synthetic generators for the 22
//! paper datasets (Table 8 substitution), simple binary/CSV I/O, and the
//! [`DataSource`] seam every consumer reads samples through.

pub mod dataset;
pub mod io;
pub mod source;
pub mod synth;

pub use dataset::Dataset;
pub use source::DataSource;
