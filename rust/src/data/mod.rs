//! Datasets: container, standardisation, synthetic generators for the 22
//! paper datasets (Table 8 substitution), simple binary/CSV I/O, the
//! [`DataSource`] seam every consumer reads samples through, and the
//! [`BatchView`] sampled view the mini-batch engine draws through it.

pub mod batch;
pub mod dataset;
pub mod io;
pub mod source;
pub mod synth;

pub use batch::BatchView;
pub use dataset::Dataset;
pub use source::DataSource;
