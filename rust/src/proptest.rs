//! A tiny property-testing harness (no external crates are available in
//! this environment). Generates random cases from a seeded [`Rng`] and
//! reports the failing case index + seed for reproduction.

use crate::rng::Rng;

/// Generator context handed to property bodies.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    /// Uniform usize in `[lo, hi]`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    /// Vector of uniforms in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Borrow the underlying RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. Panics (with the case index and
/// derived seed) on the first failure, so `EAKM_PROP_SEED` in the message
/// reproduces it.
pub fn forall(seed: u64, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    let root = Rng::new(seed ^ 0x5EED_CAFE);
    for case in 0..cases {
        let mut g = Gen {
            rng: root.split(case as u64),
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(1, 50, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn forall_reports_failing_case() {
        forall(2, 50, |g| {
            let x = g.usize_in(0, 10);
            assert!(x < 10, "x was {x}");
        });
    }

    #[test]
    fn generators_are_deterministic_per_case() {
        let mut first = Vec::new();
        forall(3, 5, |g| first.push(g.f64_in(0.0, 1.0)));
        let mut second = Vec::new();
        forall(3, 5, |g| second.push(g.f64_in(0.0, 1.0)));
        assert_eq!(first, second);
    }
}
