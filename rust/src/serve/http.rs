//! A minimal, dependency-free HTTP/1.1 shim over the serve op handlers.
//!
//! The line-JSON protocol stays the fast path; this module makes the
//! same operations reachable from `curl` and ordinary HTTP clients. The
//! server sniffs each connection's first byte — `{` (a JSON object)
//! selects line-JSON, an ASCII method letter selects HTTP — so one port
//! serves both.
//!
//! | route                        | op                                  |
//! |------------------------------|-------------------------------------|
//! | `POST /v1/predict`           | `predict` (body: `{"rows":[[…]]}`)  |
//! | `POST /v1/nearest`           | `nearest` (body: `{"point":[…]}`)   |
//! | `POST /v1/bulk_predict?path=…&block_rows=…&mode=…` | streaming bulk predict (chunked response) |
//! | `POST /v1/reload`            | `reload` (body: `{"model":"…"}`)    |
//! | `POST /v1/shutdown`          | `shutdown`                          |
//! | `GET /v1/stats`              | `stats`                             |
//! | `GET /v1/healthz`            | liveness probe                      |
//! | `GET /metrics`               | Prometheus text exposition (served directly, bypasses admission) |
//! | `GET /v1/events?since=N`     | drain the structured event ring (served directly, bypasses admission) |
//!
//! Response bodies are exactly the line-JSON reply payloads (one JSON
//! object, newline-terminated), so the two protocols cannot drift.
//! Status codes are mapped from the typed error codes by
//! [`status_for`]: 400 for parse/validation errors, 404/405 for routing
//! errors, 413 over the payload cap, **429 + `Retry-After`** for
//! `rate_limited`, 500 for model errors, **503 + `Retry-After`** for
//! `overloaded`/`breaker_open`/`shutting_down`.
//!
//! Requests are parsed with the crate's untrusted-input discipline:
//! header bytes are capped ([`HEADER_CAP`]) before allocation, the body
//! is framed by `Content-Length` and capped by the same byte budget as
//! a line-JSON request (the serve config's 4 MiB default), and
//! `Expect: 100-continue` is answered so large `curl` uploads do not
//! stall. Chunked *request* bodies are not accepted (typed 400);
//! chunked *responses* are how [`bulk_predict`] streams
//! (`Transfer-Encoding: chunked`, one chunk per label block).
//!
//! [`bulk_predict`]: crate::serve::proto::Request::BulkPredict

use std::io::{Read, Write};
use std::time::{Duration, Instant};

use crate::data::ooc::OocMode;
use crate::json::{Json, ParseLimits};
use crate::serve::proto::{self, code, ProtoError, Request};

/// Cap on the request line + headers, applied before any parsing.
pub const HEADER_CAP: usize = 16 << 10;

/// One parsed HTTP request.
pub struct HttpRequest {
    /// Request method, upper-case (`GET`, `POST`, …).
    pub method: String,
    /// Path component of the request target (before `?`), undecoded.
    pub path: String,
    /// Raw query string (after `?`), empty when absent.
    pub query: String,
    /// The request body (`Content-Length` framed; empty for `GET`).
    pub body: Vec<u8>,
    /// Whether the connection stays open after the response
    /// (HTTP/1.1 default unless `Connection: close`).
    pub keep_alive: bool,
}

/// One framed HTTP request off the socket (mirrors
/// [`Line`](crate::net::frame::Line)'s discipline).
pub enum HttpRead {
    /// A complete request.
    Msg(HttpRequest),
    /// Read timeout — poll the shutdown flag and retry.
    Idle,
    /// Peer closed (or errored); drop the connection.
    Eof,
    /// Headers exceed [`HEADER_CAP`] or the body exceeds the byte cap;
    /// reply 413 and close.
    TooLarge,
    /// Malformed request line/headers; reply 400 and close.
    Bad,
}

/// Incremental HTTP/1.1 request reader with the same cap/timeout
/// discipline as [`LineReader`](crate::net::frame::LineReader): caps
/// are enforced before allocation, timeouts surface as
/// [`Idle`](HttpRead::Idle), and bytes after a complete request are
/// kept for the next call (keep-alive pipelining).
pub struct HttpReader<S> {
    stream: S,
    buf: Vec<u8>,
    body_cap: usize,
    /// `Expect: 100-continue` has been answered for the in-progress
    /// request (reset per request).
    continued: bool,
}

impl<S: Read> HttpReader<S> {
    /// Wrap `stream`, capping bodies at `body_cap` bytes and seeding
    /// the buffer with bytes the protocol sniffer already consumed.
    pub fn with_buffered(stream: S, body_cap: usize, buffered: Vec<u8>) -> Self {
        HttpReader {
            stream,
            buf: buffered,
            body_cap,
            continued: false,
        }
    }

    /// Read until a complete request, a cap, EOF, or `deadline`.
    /// `w` is the write half, used only to answer
    /// `Expect: 100-continue` once the headers are in.
    pub fn next_request<W: Write>(&mut self, deadline: Instant, w: &mut W) -> HttpRead {
        loop {
            if let Some(head_end) = find_head_end(&self.buf) {
                let head = match parse_head(&self.buf[..head_end]) {
                    Some(h) => h,
                    None => return HttpRead::Bad,
                };
                if head.bad_framing {
                    return HttpRead::Bad;
                }
                if head.content_length > self.body_cap {
                    return HttpRead::TooLarge;
                }
                if self.buf.len() >= head_end + head.content_length {
                    let request = HttpRequest {
                        method: head.method,
                        path: head.path,
                        query: head.query,
                        body: self.buf[head_end..head_end + head.content_length].to_vec(),
                        keep_alive: head.keep_alive,
                    };
                    self.buf.drain(..head_end + head.content_length);
                    self.continued = false;
                    return HttpRead::Msg(request);
                }
                if head.expect_continue && !self.continued {
                    // curl pauses before large uploads until this
                    // interim response arrives
                    self.continued = true;
                    if w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n").is_err()
                        || w.flush().is_err()
                    {
                        return HttpRead::Eof;
                    }
                }
            } else if self.buf.len() > HEADER_CAP {
                return HttpRead::TooLarge;
            }
            if Instant::now() >= deadline {
                return HttpRead::Idle;
            }
            let mut chunk = [0u8; 4096];
            match self.stream.read(&mut chunk) {
                Ok(0) => return HttpRead::Eof,
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return HttpRead::Idle
                }
                Err(_) => return HttpRead::Eof,
            }
        }
    }
}

/// Index one past the blank line ending the header block, if complete.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] == b'\n' {
            if buf.get(i + 1) == Some(&b'\n') {
                return Some(i + 2);
            }
            if buf.get(i + 1) == Some(&b'\r') && buf.get(i + 2) == Some(&b'\n') {
                return Some(i + 3);
            }
        }
        i += 1;
    }
    None
}

/// The parsed header block.
struct Head {
    method: String,
    path: String,
    query: String,
    content_length: usize,
    keep_alive: bool,
    expect_continue: bool,
    /// A framing we refuse (chunked/invalid Content-Length).
    bad_framing: bool,
}

/// Parse the request line + headers; `None` is malformed (400).
fn parse_head(head: &[u8]) -> Option<Head> {
    let text = std::str::from_utf8(head).ok()?;
    let mut lines = text.split('\n').map(|l| l.trim_end_matches('\r'));
    let request_line = lines.next()?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next()?.to_string();
    let target = parts.next()?;
    let version = parts.next()?;
    if parts.next().is_some() || !version.starts_with("HTTP/1.") {
        return None;
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    let http11 = version == "HTTP/1.1";
    let mut keep_alive = http11;
    let mut content_length = 0usize;
    let mut content_length_seen = false;
    let mut expect_continue = false;
    let mut bad_framing = false;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return None;
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => match value.parse::<usize>() {
                Ok(n) => {
                    // conflicting lengths are a request-smuggling vector
                    // (RFC 7230 §3.3.2): refuse, never last-one-wins
                    if content_length_seen && content_length != n {
                        bad_framing = true;
                    }
                    content_length = n;
                    content_length_seen = true;
                }
                Err(_) => bad_framing = true,
            },
            "transfer-encoding" => bad_framing = true,
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    keep_alive = false;
                } else if v.contains("keep-alive") {
                    keep_alive = true;
                }
            }
            "expect" => {
                expect_continue = value.to_ascii_lowercase().contains("100-continue");
            }
            _ => {}
        }
    }
    Some(Head {
        method,
        path,
        query,
        content_length,
        keep_alive,
        expect_continue,
        bad_framing,
    })
}

/// Percent-decode one query component (`%XX` escapes, `+` as space);
/// `None` on an invalid escape.
pub fn percent_decode(s: &str) -> Option<String> {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' => {
                let hex = bytes.get(i + 1..i + 3)?;
                let hex = std::str::from_utf8(hex).ok()?;
                out.push(u8::from_str_radix(hex, 16).ok()?);
                i += 3;
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8(out).ok()
}

/// Look up a decoded query parameter in a raw query string.
fn query_param(query: &str, key: &str) -> Option<Result<String, ()>> {
    for pair in query.split('&') {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        if k == key {
            return Some(percent_decode(v).ok_or(()));
        }
    }
    None
}

/// What a routed HTTP request maps to.
pub enum Routed {
    /// A serve-protocol op (dispatched exactly like line-JSON).
    Op(Request),
    /// `GET /v1/healthz` — answered by the server without touching the
    /// op handlers.
    Healthz,
}

/// Map method + path (+ query/body) onto a serve op. Failures are the
/// same typed [`ProtoError`]s as line-JSON parsing, plus `not_found` /
/// `bad_method` for routing.
pub fn route(req: &HttpRequest, limits: &ParseLimits) -> Result<Routed, ProtoError> {
    let op = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/v1/healthz") => return Ok(Routed::Healthz),
        ("GET", "/v1/stats") => return Ok(Routed::Op(Request::Stats)),
        ("POST", "/v1/predict") => "predict",
        ("POST", "/v1/nearest") => "nearest",
        ("POST", "/v1/bulk_predict") => return Ok(Routed::Op(route_bulk(req, limits)?)),
        ("POST", "/v1/reload") => "reload",
        ("POST", "/v1/shutdown") => return Ok(Routed::Op(Request::Shutdown)),
        (_, "/v1/healthz" | "/v1/stats" | "/v1/predict" | "/v1/nearest" | "/v1/bulk_predict"
        | "/v1/reload" | "/v1/shutdown") => {
            return Err(ProtoError::new(
                code::BAD_METHOD,
                format!("method {} not allowed for {}", req.method, req.path),
            ));
        }
        (_, path) => {
            return Err(ProtoError::new(
                code::NOT_FOUND,
                format!("no route for {path:?}"),
            ));
        }
    };
    let doc = parse_body(&req.body, limits)?;
    proto::request_from_op(op, &doc).map(Routed::Op)
}

/// `POST /v1/bulk_predict`: `path`/`block_rows`/`mode` come from the
/// query string (the `curl`-friendly spelling) or from a JSON body.
fn route_bulk(req: &HttpRequest, limits: &ParseLimits) -> Result<Request, ProtoError> {
    let bad_query =
        |k: &str| ProtoError::new(code::BAD_REQUEST, format!("query parameter {k:?} is invalid"));
    match query_param(&req.query, "path") {
        Some(path) => {
            let path = path.map_err(|()| bad_query("path"))?;
            let block_rows = match query_param(&req.query, "block_rows") {
                Some(v) => Some(
                    v.ok()
                        .and_then(|v| v.parse::<usize>().ok())
                        .filter(|&b| b > 0)
                        .ok_or_else(|| bad_query("block_rows"))?,
                ),
                None => None,
            };
            let mode = match query_param(&req.query, "mode") {
                Some(v) => v
                    .ok()
                    .and_then(|v| OocMode::parse(&v))
                    .ok_or_else(|| bad_query("mode"))?,
                None => OocMode::Auto,
            };
            Ok(Request::BulkPredict {
                path,
                block_rows,
                mode,
            })
        }
        None => {
            let doc = parse_body(&req.body, limits)?;
            proto::request_from_op("bulk_predict", &doc)
        }
    }
}

/// Parse a request body as one JSON document under the serve limits.
fn parse_body(body: &[u8], limits: &ParseLimits) -> Result<Json, ProtoError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| ProtoError::new(code::BAD_REQUEST, "request body is not utf-8"))?;
    if text.trim().is_empty() {
        return Err(ProtoError::new(code::BAD_REQUEST, "request body is empty"));
    }
    Json::parse_with_limits(text, limits).map_err(|e| match e {
        crate::error::EakmError::Limit(m) => ProtoError::new(code::PAYLOAD_TOO_LARGE, m),
        e => ProtoError::new(code::BAD_REQUEST, e.to_string()),
    })
}

/// HTTP status for a typed serve error code.
pub fn status_for(error_code: &str) -> u16 {
    match error_code {
        code::BAD_REQUEST | code::UNKNOWN_OP | code::DIM_MISMATCH => 400,
        code::NOT_FOUND => 404,
        code::BAD_METHOD => 405,
        code::PAYLOAD_TOO_LARGE => 413,
        code::RATE_LIMITED => 429,
        code::OVERLOADED | code::SHUTTING_DOWN | code::BREAKER_OPEN => 503,
        _ => 500,
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Error",
    }
}

/// Write one complete JSON response; `false` means the peer is gone.
/// `retry_after` adds a `Retry-After` header (whole seconds, rounded
/// up) — sent with 429/503 so clients know when to come back.
pub fn send_response(
    w: &mut impl Write,
    status: u16,
    retry_after: Option<Duration>,
    body_line: &str,
    keep_alive: bool,
) -> bool {
    let mut response = String::with_capacity(body_line.len() + 160);
    response.push_str(&format!("HTTP/1.1 {} {}\r\n", status, status_text(status)));
    response.push_str("Content-Type: application/json\r\n");
    response.push_str(&format!("Content-Length: {}\r\n", body_line.len() + 1));
    if let Some(after) = retry_after {
        // ceil, not floor: an early retry would just eat another 429
        let secs = (after.as_secs_f64().ceil() as u64).max(1);
        response.push_str(&format!("Retry-After: {secs}\r\n"));
    }
    response.push_str(if keep_alive {
        "Connection: keep-alive\r\n"
    } else {
        "Connection: close\r\n"
    });
    response.push_str("\r\n");
    response.push_str(body_line);
    response.push('\n');
    w.write_all(response.as_bytes()).is_ok() && w.flush().is_ok()
}

/// Write one complete response with an explicit content type, sending
/// `body` verbatim (no trailing newline added) — the shape of the
/// `/metrics` text exposition (`text/plain; version=0.0.4`) and the
/// `/v1/events` drain. `false` means the peer is gone.
pub fn send_typed_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &str,
    keep_alive: bool,
) -> bool {
    let mut response = String::with_capacity(body.len() + 160);
    response.push_str(&format!("HTTP/1.1 {} {}\r\n", status, status_text(status)));
    response.push_str(&format!("Content-Type: {content_type}\r\n"));
    response.push_str(&format!("Content-Length: {}\r\n", body.len()));
    response.push_str(if keep_alive {
        "Connection: keep-alive\r\n"
    } else {
        "Connection: close\r\n"
    });
    response.push_str("\r\n");
    response.push_str(body);
    w.write_all(response.as_bytes()).is_ok() && w.flush().is_ok()
}

/// Start a chunked streaming response (the bulk-predict path).
pub fn send_chunked_head(w: &mut impl Write, keep_alive: bool) -> bool {
    let head = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n\
         Transfer-Encoding: chunked\r\nConnection: {}\r\n\r\n",
        if keep_alive { "keep-alive" } else { "close" }
    );
    w.write_all(head.as_bytes()).is_ok() && w.flush().is_ok()
}

/// Write one JSON line as one HTTP chunk.
pub fn send_chunk(w: &mut impl Write, body_line: &str) -> bool {
    let mut chunk = String::with_capacity(body_line.len() + 16);
    chunk.push_str(&format!("{:x}\r\n", body_line.len() + 1));
    chunk.push_str(body_line);
    chunk.push('\n');
    chunk.push_str("\r\n");
    w.write_all(chunk.as_bytes()).is_ok() && w.flush().is_ok()
}

/// Terminate a chunked response.
pub fn send_chunk_end(w: &mut impl Write) -> bool {
    w.write_all(b"0\r\n\r\n").is_ok() && w.flush().is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// An in-memory stream yielding scripted pieces, then EOF.
    struct Script {
        pieces: Vec<Vec<u8>>,
        next: usize,
    }

    impl Read for Script {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.next >= self.pieces.len() {
                return Ok(0);
            }
            // respect the caller's buffer: a piece larger than `out`
            // is delivered across successive reads
            let piece = &mut self.pieces[self.next];
            let n = piece.len().min(out.len());
            out[..n].copy_from_slice(&piece[..n]);
            piece.drain(..n);
            if piece.is_empty() {
                self.next += 1;
            }
            Ok(n)
        }
    }

    fn reader(pieces: Vec<Vec<u8>>) -> HttpReader<Script> {
        HttpReader::with_buffered(Script { pieces, next: 0 }, 4 << 20, Vec::new())
    }

    fn soon() -> Instant {
        Instant::now() + Duration::from_secs(5)
    }

    fn read_one(r: &mut HttpReader<Script>) -> HttpRequest {
        let mut sink = Vec::new();
        match r.next_request(soon(), &mut sink) {
            HttpRead::Msg(req) => req,
            _ => panic!("expected a complete request"),
        }
    }

    #[test]
    fn parses_a_curl_shaped_post_across_partial_reads() {
        let raw = b"POST /v1/predict HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\n\
                    Content-Length: 20\r\n\r\n{\"rows\":[[1.0,2.0]]}";
        let pieces = raw.chunks(7).map(|c| c.to_vec()).collect();
        let mut r = reader(pieces);
        let req = read_one(&mut r);
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/predict");
        assert!(req.keep_alive);
        assert_eq!(req.body, b"{\"rows\":[[1.0,2.0]]}");
    }

    #[test]
    fn keep_alive_pipelines_and_connection_close_is_honoured() {
        let raw = b"GET /v1/stats HTTP/1.1\r\n\r\nGET /v1/healthz HTTP/1.1\r\n\
                    Connection: close\r\n\r\n"
            .to_vec();
        let mut r = reader(vec![raw]);
        let first = read_one(&mut r);
        assert_eq!(first.path, "/v1/stats");
        assert!(first.keep_alive);
        let second = read_one(&mut r);
        assert_eq!(second.path, "/v1/healthz");
        assert!(!second.keep_alive);
    }

    #[test]
    fn expect_100_continue_is_answered_before_the_body() {
        let head = b"POST /v1/predict HTTP/1.1\r\nExpect: 100-continue\r\n\
                     Content-Length: 2\r\n\r\n"
            .to_vec();
        let mut r = reader(vec![head, b"{}".to_vec()]);
        let mut interim = Vec::new();
        match r.next_request(soon(), &mut interim) {
            HttpRead::Msg(req) => assert_eq!(req.body, b"{}"),
            _ => panic!("expected a complete request"),
        }
        let interim = String::from_utf8(interim).unwrap();
        assert!(interim.starts_with("HTTP/1.1 100 Continue"), "{interim}");
    }

    #[test]
    fn caps_and_malformed_heads_are_typed() {
        // oversized headers: rejected once the cap is passed
        let mut r = reader(vec![vec![b'A'; HEADER_CAP + 10]]);
        let mut sink = Vec::new();
        assert!(matches!(r.next_request(soon(), &mut sink), HttpRead::TooLarge));
        // declared body over the cap: rejected from the header alone
        let raw = format!("POST /v1/predict HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 1 << 30);
        let mut r = reader(vec![raw.into_bytes()]);
        assert!(matches!(r.next_request(soon(), &mut sink), HttpRead::TooLarge));
        // chunked request bodies are refused
        let raw = b"POST /v1/predict HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec();
        let mut r = reader(vec![raw]);
        assert!(matches!(r.next_request(soon(), &mut sink), HttpRead::Bad));
        // conflicting Content-Length values are refused (smuggling
        // vector), not resolved last-one-wins
        let raw = b"POST /v1/predict HTTP/1.1\r\nContent-Length: 2\r\n\
                    Content-Length: 3\r\n\r\n{}x"
            .to_vec();
        let mut r = reader(vec![raw]);
        assert!(matches!(r.next_request(soon(), &mut sink), HttpRead::Bad));
        // a repeated identical Content-Length is tolerated
        let raw = b"POST /v1/predict HTTP/1.1\r\nContent-Length: 2\r\n\
                    Content-Length: 2\r\n\r\n{}"
            .to_vec();
        let mut r = reader(vec![raw]);
        assert!(matches!(r.next_request(soon(), &mut sink), HttpRead::Msg(_)));
        // not HTTP at all
        let mut r = reader(vec![b"FROB one two three\r\n\r\n".to_vec()]);
        assert!(matches!(r.next_request(soon(), &mut sink), HttpRead::Bad));
    }

    fn http(method: &str, path_query: &str, body: &[u8]) -> HttpRequest {
        let (path, query) = match path_query.split_once('?') {
            Some((p, q)) => (p.to_string(), q.to_string()),
            None => (path_query.to_string(), String::new()),
        };
        HttpRequest {
            method: method.to_string(),
            path,
            query,
            body: body.to_vec(),
            keep_alive: true,
        }
    }

    #[test]
    fn routes_map_to_ops_with_typed_failures() {
        let net = ParseLimits::network();
        assert!(matches!(
            route(&http("GET", "/v1/healthz", b""), &net),
            Ok(Routed::Healthz)
        ));
        assert!(matches!(
            route(&http("GET", "/v1/stats", b""), &net),
            Ok(Routed::Op(Request::Stats))
        ));
        match route(&http("POST", "/v1/predict", br#"{"rows":[[1,2],[3,4]]}"#), &net) {
            Ok(Routed::Op(Request::Predict { n_rows, d, .. })) => {
                assert_eq!((n_rows, d), (2, 2));
            }
            _ => panic!("predict route"),
        }
        match route(&http("POST", "/v1/nearest", br#"{"point":[0.5]}"#), &net) {
            Ok(Routed::Op(Request::Nearest { point })) => assert_eq!(point, vec![0.5]),
            _ => panic!("nearest route"),
        }
        assert!(matches!(
            route(&http("POST", "/v1/shutdown", b""), &net),
            Ok(Routed::Op(Request::Shutdown))
        ));
        // routing failures carry routing codes
        assert_eq!(route(&http("GET", "/nope", b""), &net).unwrap_err().code, code::NOT_FOUND);
        assert_eq!(
            route(&http("DELETE", "/v1/predict", b""), &net).unwrap_err().code,
            code::BAD_METHOD
        );
        // body failures carry the same codes as line-JSON parsing
        assert_eq!(
            route(&http("POST", "/v1/predict", b"not json"), &net).unwrap_err().code,
            code::BAD_REQUEST
        );
        assert_eq!(
            route(&http("POST", "/v1/predict", b""), &net).unwrap_err().code,
            code::BAD_REQUEST
        );
    }

    #[test]
    fn bulk_route_reads_query_params_with_percent_decoding() {
        let net = ParseLimits::network();
        let req = http(
            "POST",
            "/v1/bulk_predict?path=%2Fdata%2Fbig%20set.ekb&block_rows=512&mode=chunked",
            b"",
        );
        match route(&req, &net) {
            Ok(Routed::Op(Request::BulkPredict {
                path,
                block_rows,
                mode,
            })) => {
                assert_eq!(path, "/data/big set.ekb");
                assert_eq!(block_rows, Some(512));
                assert_eq!(mode, OocMode::Chunked);
            }
            _ => panic!("bulk route"),
        }
        // body spelling works too
        let req = http("POST", "/v1/bulk_predict", br#"{"path":"/d/x.ekb"}"#);
        match route(&req, &net) {
            Ok(Routed::Op(Request::BulkPredict { path, block_rows, .. })) => {
                assert_eq!(path, "/d/x.ekb");
                assert_eq!(block_rows, None);
            }
            _ => panic!("bulk body route"),
        }
        // invalid knobs are typed, not ignored
        let req = http("POST", "/v1/bulk_predict?path=%2Fx.ekb&block_rows=0", b"");
        assert_eq!(route(&req, &net).unwrap_err().code, code::BAD_REQUEST);
        let req = http("POST", "/v1/bulk_predict?path=%GG", b"");
        assert_eq!(route(&req, &net).unwrap_err().code, code::BAD_REQUEST);
    }

    #[test]
    fn responses_are_well_formed() {
        let mut out = Vec::new();
        assert!(send_response(
            &mut out,
            429,
            Some(Duration::from_millis(2500)),
            r#"{"ok":false}"#,
            true,
        ));
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        // 2500 ms rounds UP: retrying at 2 s would be refused again
        assert!(text.contains("Retry-After: 3\r\n"), "{text}");
        assert!(text.contains("Content-Length: 13\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{\"ok\":false}\n"), "{text}");

        let mut out = Vec::new();
        assert!(send_response(&mut out, 503, Some(Duration::from_millis(80)), "{}", true));
        let text = String::from_utf8(out).unwrap();
        // sub-second hints still advertise at least one whole second
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");

        let mut out = Vec::new();
        assert!(send_typed_response(
            &mut out,
            200,
            "text/plain; version=0.0.4",
            "m_total 1\n",
            true,
        ));
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Content-Type: text/plain; version=0.0.4\r\n"), "{text}");
        // body is sent verbatim: Content-Length counts no extra newline
        assert!(text.contains("Content-Length: 10\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\nm_total 1\n"), "{text}");

        let mut out = Vec::new();
        assert!(send_chunked_head(&mut out, false));
        assert!(send_chunk(&mut out, r#"{"lo":0}"#));
        assert!(send_chunk_end(&mut out));
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Transfer-Encoding: chunked"), "{text}");
        assert!(text.contains("9\r\n{\"lo\":0}\n\r\n"), "{text}");
        assert!(text.ends_with("0\r\n\r\n"), "{text}");
    }

    #[test]
    fn status_mapping_is_total() {
        assert_eq!(status_for(code::BAD_REQUEST), 400);
        assert_eq!(status_for(code::UNKNOWN_OP), 400);
        assert_eq!(status_for(code::DIM_MISMATCH), 400);
        assert_eq!(status_for(code::NOT_FOUND), 404);
        assert_eq!(status_for(code::BAD_METHOD), 405);
        assert_eq!(status_for(code::PAYLOAD_TOO_LARGE), 413);
        assert_eq!(status_for(code::RATE_LIMITED), 429);
        assert_eq!(status_for(code::MODEL_ERROR), 500);
        assert_eq!(status_for(code::OVERLOADED), 503);
        assert_eq!(status_for(code::BREAKER_OPEN), 503);
        assert_eq!(status_for(code::SHUTTING_DOWN), 503);
        assert_eq!(status_for("anything_else"), 500);
    }
}
