//! `serve` — the long-lived model server: request batching,
//! backpressure, admission control, streaming bulk predict, and
//! hot-reload on one [`Runtime`].
//!
//! The fit/predict service API (PR 2) answers queries *inside* a
//! process; this subsystem answers them *over a socket*, for as long as
//! the process lives. It is dependency-free: a blocking TCP server on
//! `std::net` speaking two protocols on one port — the line-delimited
//! JSON fast path of [`proto`] and the [`http`] HTTP/1.1 shim (for
//! `curl` and ordinary HTTP clients), sniffed per-connection from the
//! first byte — both parsed by the crate's own hardened
//! [`json`](crate::json) parser under network limits.
//!
//! ## Architecture
//!
//! ```text
//!  clients ─► N acceptors ─► admission ─► bounded RequestQueue ─► micro-batcher ─► one Runtime
//!   (json │       │    ▲     (rate limit      │ (overflow ⇒            │  one pool-sharded
//!    or   │       │    │      + breaker       │  typed "overloaded")   │  predict_rows scan
//!    http)│       │    └───── replies ◄───────┘                        ▼
//!         │       ├── nearest/stats/reload served inline ◄── Mutex<Arc<FittedModel>>
//!         │       └── bulk_predict streamed inline ◄── ooc DataSource block leases
//! ```
//!
//! * **Batching** — the micro-batcher drains the queue, concatenates
//!   pending predict rows, and labels them with a *single*
//!   [`FittedModel::predict_rows`](crate::model::FittedModel::predict_rows)
//!   scan before scattering per-request replies in arrival order. The
//!   paper's theme — amortise work across many queries — applied at
//!   serving time: one dispatch, one blocked kernel pass, many
//!   requests. Because every row's scan is independent, coalescing is
//!   invisible: answers are **bit-identical** to direct `predict` at
//!   any thread width and any batch boundary.
//! * **Backpressure** — the queue is bounded
//!   ([`queue_depth`](ServeConfig::queue_depth)); when it is full the
//!   client gets the typed `overloaded` reply immediately instead of
//!   the server queueing unboundedly. Connection concurrency is bounded
//!   separately by the acceptor count, and since each connection has at
//!   most one request in flight, the typed reject actively fires only
//!   in strict-reject mode (`queue_depth < acceptors`); at the defaults
//!   the acceptor budget + OS backlog bind first. Idle (and
//!   byte-trickling) connections are reaped after
//!   [`idle_timeout`](ServeConfig::idle_timeout).
//! * **Admission control** — in front of everything, [`admission`]
//!   keys each connection by peer IP (or per-connection) and applies a
//!   token-bucket rate limit plus a trip-after-consecutive-failures
//!   circuit breaker with a half-open probe. Rejections are typed
//!   (`rate_limited` / `breaker_open`, HTTP 429/503 + `Retry-After`)
//!   and cost no parsing, so one abusive client degrades gracefully
//!   instead of eating the acceptor budget.
//! * **Streaming bulk predict** — the `bulk_predict` op (and
//!   `POST /v1/bulk_predict`) labels an entire on-disk dataset over
//!   one connection with bounded memory: `RowBlock` leases from an
//!   out-of-core source flow through
//!   [`FittedModel::predict_blocks`](crate::model::FittedModel::predict_blocks)
//!   and stream back one label block per lease, bit-identical to
//!   in-memory `predict` at any thread width and block boundary, with
//!   the source's I/O telemetry in the trailer.
//! * **Hot reload** — the served model lives in a
//!   [`ModelCell`](state::ModelCell) (`Mutex<Arc<FittedModel>>`); the
//!   `reload` op swaps in a model JSON file with zero downtime —
//!   batches in flight finish on the snapshot they took, later batches
//!   see the new generation, and no request is ever dropped.
//! * **Telemetry** — [`ServeStats`] counts requests (per protocol),
//!   batched rows, coalesced batches, queue-full / rate-limited /
//!   breaker rejects, bulk blocks and rows, and per-op latency sums;
//!   the `stats` op returns it live and [`serve`] returns the final
//!   snapshot for the clean-shutdown summary line. Per-op latency is
//!   additionally recorded into log-bucketed
//!   [`Histogram`](crate::obs::Histogram)s, so the `stats` reply
//!   carries server-computed mean/p50/p99 microseconds per op
//!   ([`OpLatency`](state::OpLatency)).
//! * **Observability** — `GET /metrics` renders every telemetry field
//!   (serve counters, op latency histograms, and the served model's
//!   fit report: distance-calc counters, per-point-per-round rates,
//!   scheduler and I/O telemetry) in the Prometheus text format, and
//!   `GET /v1/events?since=N` drains a bounded ring of structured
//!   lifecycle events (batch executions, reloads, overloads, admission
//!   rejects, shutdown) tagged with the trace ID minted when the
//!   request entered the server. Both bypass admission control the
//!   same way `healthz` does: a tripped breaker must never blind the
//!   operator. See [`crate::obs`] and docs/OPERATIONS.md.
//!
//! ## Example
//!
//! ```no_run
//! use eakm::prelude::*;
//! use eakm::serve::{serve, ServeConfig};
//!
//! let rt = Runtime::auto();
//! let data = eakm::data::synth::blobs(10_000, 8, 50, 0.05, 42);
//! let model = Kmeans::new(50).seed(7).fit(&rt, &data).unwrap();
//! let cfg = ServeConfig {
//!     addr: "127.0.0.1:4999".into(),
//!     ..ServeConfig::default()
//! };
//! // blocks until a {"op":"shutdown"} request arrives
//! let stats = serve(&rt, model, &cfg, |addr| println!("serving on {addr}")).unwrap();
//! println!("{}", stats.summary_line(std::time::Duration::ZERO));
//! ```
//!
//! The CLI front-end is `eakm serve --model model.json --addr …`, and
//! [`client`] is a matching minimal Rust client (used by the tests,
//! the throughput bench, and `examples/serving.rs`).
//!
//! [`Runtime`]: crate::runtime::Runtime

pub mod admission;
mod batcher;
pub mod client;
pub mod http;
pub mod proto;
mod server;
pub mod state;

pub use admission::{AdmissionConfig, KeyBy};
pub use client::Client;
pub use server::{serve, ServeConfig};
pub use state::{OpLatency, ServeStats, ServeTelemetry};
