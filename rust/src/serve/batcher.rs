//! The micro-batcher: a bounded request queue with typed backpressure
//! and the drain loop that coalesces pending predict requests into one
//! pool-sharded scan.
//!
//! Acceptor threads [`push`](RequestQueue::push) parsed predict jobs;
//! when the queue is at capacity the push fails *immediately* and the
//! client receives the typed `overloaded` reply — the server never
//! queues unboundedly. One batcher thread drains the queue: it takes
//! the oldest job, keeps pulling until [`max_batch_rows`] rows are
//! assembled (optionally lingering to let concurrent arrivals
//! coalesce), concatenates every job's rows into one slice, runs a
//! single [`FittedModel::predict_rows`] scan on the shared [`Runtime`],
//! and scatters per-job label slices back **in arrival order**.
//!
//! Correctness rests on the `predict_rows` contract: every row's scan
//! is independent, so the coalesced answer is bit-identical to serving
//! each request alone — at any pool width and any batch boundary.
//!
//! [`max_batch_rows`]: crate::serve::ServeConfig::max_batch_rows
//! [`FittedModel::predict_rows`]: crate::model::FittedModel::predict_rows
//! [`Runtime`]: crate::runtime::Runtime

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::obs::{EventLog, TraceId, Value};
use crate::runtime::Runtime;
use crate::serve::proto::{code, ProtoError};
use crate::serve::state::{ModelCell, ServeTelemetry};

/// One enqueued predict request: parsed rows plus the reply channel the
/// owning connection thread blocks on.
pub(crate) struct PredictJob {
    /// Row-major `n_rows × d` query values.
    pub rows: Vec<f64>,
    /// Rows in this job.
    pub n_rows: usize,
    /// Per-row dimension (validated at parse time).
    pub d: usize,
    /// Trace ID minted at the front door (0 = unset), carried through
    /// the batcher so each executed batch's event names the request
    /// whose arrival opened it.
    pub trace: u64,
    /// Where the labels (or a typed error) go.
    pub reply: mpsc::Sender<Result<Vec<u32>, ProtoError>>,
}

struct Inner {
    jobs: VecDeque<PredictJob>,
    closed: bool,
}

/// Why a [`RequestQueue::push`] was refused.
pub(crate) enum PushRefused {
    /// At capacity — the caller answers `overloaded`.
    Full,
    /// Shutting down — the caller answers `shutting_down`.
    Closed,
}

/// The bounded, condvar-backed predict queue between acceptors and the
/// batcher.
pub(crate) struct RequestQueue {
    depth: usize,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl RequestQueue {
    pub(crate) fn new(depth: usize) -> RequestQueue {
        RequestQueue {
            depth,
            inner: Mutex::new(Inner {
                jobs: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue, or refuse *immediately* when full/closed (backpressure:
    /// the queue never grows past its depth).
    pub(crate) fn push(&self, job: PredictJob) -> Result<(), PushRefused> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        if inner.closed {
            return Err(PushRefused::Closed);
        }
        if inner.jobs.len() >= self.depth {
            return Err(PushRefused::Full);
        }
        inner.jobs.push_back(job);
        drop(inner);
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pop: the next job in arrival order, or `None` once the
    /// queue is closed *and* drained (queued work survives shutdown).
    fn pop_wait(&self) -> Option<PredictJob> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(job) = inner.jobs.pop_front() {
                return Some(job);
            }
            if inner.closed {
                return None;
            }
            inner = self.cv.wait(inner).expect("queue poisoned");
        }
    }

    /// Non-blocking pop.
    fn try_pop(&self) -> Option<PredictJob> {
        self.inner.lock().expect("queue poisoned").jobs.pop_front()
    }

    /// Pop, waiting until `deadline` at most. `None` on timeout or
    /// close-and-drained.
    fn pop_until(&self, deadline: Instant) -> Option<PredictJob> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(job) = inner.jobs.pop_front() {
                return Some(job);
            }
            if inner.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(inner, deadline - now)
                .expect("queue poisoned");
            inner = guard;
        }
    }

    /// Close for shutdown: new pushes are refused, the batcher drains
    /// what is already queued and then stops.
    pub(crate) fn close(&self) {
        self.inner.lock().expect("queue poisoned").closed = true;
        self.cv.notify_all();
    }
}

/// The batcher thread body: drain → coalesce → one scan → scatter,
/// until the queue closes and drains. Runs on a scoped thread inside
/// [`serve`](crate::serve::serve).
pub(crate) fn run_batcher(
    queue: &RequestQueue,
    cell: &ModelCell,
    rt: &Runtime,
    telemetry: &ServeTelemetry,
    events: &EventLog,
    max_batch_rows: usize,
    linger: Duration,
) {
    let max_batch_rows = max_batch_rows.max(1);
    while let Some(first) = queue.pop_wait() {
        let mut batch = Vec::with_capacity(8);
        let mut rows_total = first.n_rows;
        batch.push(first);
        if linger > Duration::ZERO {
            // micro-batching window: give concurrent arrivals a chance
            // to coalesce into this scan
            let deadline = Instant::now() + linger;
            while rows_total < max_batch_rows {
                match queue.pop_until(deadline) {
                    Some(job) => {
                        rows_total += job.n_rows;
                        batch.push(job);
                    }
                    None => break,
                }
            }
        } else {
            // pure drain: take whatever is already waiting
            while rows_total < max_batch_rows {
                match queue.try_pop() {
                    Some(job) => {
                        rows_total += job.n_rows;
                        batch.push(job);
                    }
                    None => break,
                }
            }
        }
        execute_batch(batch, cell, rt, telemetry, events);
    }
}

/// Run one coalesced batch: snapshot the model, peel off jobs whose
/// dimension does not match it (typed `dim_mismatch` replies), scan the
/// rest as one concatenated slice, scatter labels in arrival order.
fn execute_batch(
    batch: Vec<PredictJob>,
    cell: &ModelCell,
    rt: &Runtime,
    telemetry: &ServeTelemetry,
    events: &EventLog,
) {
    // one snapshot per batch: a reload landing mid-batch affects the
    // *next* batch; this one finishes on the generation it started with
    let model = cell.current();
    let d = model.d();
    let mut jobs = Vec::with_capacity(batch.len());
    for job in batch {
        if job.d == d {
            jobs.push(job);
        } else {
            let _ = job.reply.send(Err(ProtoError::new(
                code::DIM_MISMATCH,
                format!("model expects d={d}, rows have d={}", job.d),
            )));
        }
    }
    if jobs.is_empty() {
        return;
    }
    let rows_total: usize = jobs.iter().map(|j| j.n_rows).sum();
    let labels = if jobs.len() == 1 {
        model.predict_rows(rt, &jobs[0].rows)
    } else {
        let mut all = Vec::with_capacity(rows_total * d);
        for job in &jobs {
            all.extend_from_slice(&job.rows);
        }
        model.predict_rows(rt, &all)
    };
    match labels {
        Ok(labels) => {
            telemetry.batch_done(jobs.len() as u64, rows_total as u64);
            // one event per executed scan (not per row): the trace is
            // the batch-opening request's, tying the scan back to the
            // front-door arrival that triggered it
            events.push(
                "batch",
                TraceId::from_u64(jobs[0].trace),
                vec![
                    ("requests", Value::U64(jobs.len() as u64)),
                    ("rows", Value::U64(rows_total as u64)),
                ],
            );
            let mut lo = 0;
            for job in &jobs {
                // send failures mean the client hung up — nothing to do
                let _ = job.reply.send(Ok(labels[lo..lo + job.n_rows].to_vec()));
                lo += job.n_rows;
            }
        }
        Err(e) => {
            // dims were validated above, so this is exceptional; every
            // waiter still gets a typed reply rather than a hang
            for job in &jobs {
                let _ = job.reply.send(Err(ProtoError::new(
                    code::MODEL_ERROR,
                    format!("batched scan failed: {e}"),
                )));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::model::Kmeans;

    fn job(rows: Vec<f64>, d: usize) -> (PredictJob, mpsc::Receiver<Result<Vec<u32>, ProtoError>>) {
        let (tx, rx) = mpsc::channel();
        let n_rows = rows.len() / d;
        (
            PredictJob {
                rows,
                n_rows,
                d,
                trace: 0,
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn queue_enforces_depth_and_close() {
        let q = RequestQueue::new(2);
        let (j1, _r1) = job(vec![0.0], 1);
        let (j2, _r2) = job(vec![1.0], 1);
        let (j3, _r3) = job(vec![2.0], 1);
        assert!(q.push(j1).is_ok());
        assert!(q.push(j2).is_ok());
        assert!(matches!(q.push(j3), Err(PushRefused::Full)));
        // closing refuses new work but keeps what is queued
        q.close();
        let (j4, _r4) = job(vec![3.0], 1);
        assert!(matches!(q.push(j4), Err(PushRefused::Closed)));
        assert!(q.pop_wait().is_some());
        assert!(q.pop_wait().is_some());
        assert!(q.pop_wait().is_none());
    }

    #[test]
    fn batcher_coalesces_and_scatters_in_arrival_order() {
        let rt = Runtime::new(2);
        let ds = blobs(200, 2, 4, 0.05, 3);
        let model = Kmeans::new(4).seed(1).fit(&rt, &ds).unwrap();
        let queries = blobs(24, 2, 4, 0.1, 9);
        let want = model.predict(&rt, &queries).unwrap();
        let cell = ModelCell::new(model);
        let tel = ServeTelemetry::default();
        let q = RequestQueue::new(64);
        // enqueue 3 uneven jobs covering the query set, then close so
        // run_batcher drains and exits
        let d = queries.d();
        let mut receivers = Vec::new();
        for (lo, len) in [(0usize, 5usize), (5, 1), (6, 18)] {
            let (j, rx) = job(queries.raw()[lo * d..(lo + len) * d].to_vec(), d);
            q.push(j).map_err(|_| "push").unwrap();
            receivers.push((lo, len, rx));
        }
        q.close();
        let events = EventLog::new(16);
        run_batcher(&q, &cell, &rt, &tel, &events, 1024, Duration::ZERO);
        for (lo, len, rx) in receivers {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(got.as_slice(), &want[lo..lo + len], "job at {lo}");
        }
        let s = tel.snapshot();
        assert_eq!(s.batches, 1, "all three jobs coalesced into one scan");
        assert_eq!(s.coalesced_batches, 1);
        assert_eq!(s.batched_rows, 24);
        let batch_events = events.since(0);
        assert_eq!(batch_events.len(), 1);
        assert_eq!(batch_events[0].kind, "batch");
        assert_eq!(batch_events[0].field("rows"), Some(&Value::U64(24)));
    }

    #[test]
    fn max_batch_rows_splits_scans_without_changing_answers() {
        let rt = Runtime::serial();
        let ds = blobs(150, 3, 3, 0.1, 5);
        let model = Kmeans::new(3).seed(2).fit(&rt, &ds).unwrap();
        let queries = blobs(12, 3, 3, 0.2, 6);
        let want = model.predict(&rt, &queries).unwrap();
        let cell = ModelCell::new(model);
        let tel = ServeTelemetry::default();
        let q = RequestQueue::new(64);
        let d = queries.d();
        let mut receivers = Vec::new();
        for i in 0..12 {
            let (j, rx) = job(queries.raw()[i * d..(i + 1) * d].to_vec(), d);
            q.push(j).map_err(|_| "push").unwrap();
            receivers.push(rx);
        }
        q.close();
        // cap of 4 rows → 12 single-row jobs make exactly 3 scans
        let events = EventLog::new(16);
        run_batcher(&q, &cell, &rt, &tel, &events, 4, Duration::ZERO);
        for (i, rx) in receivers.iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![want[i]], "row {i}");
        }
        assert_eq!(tel.snapshot().batches, 3);
    }

    #[test]
    fn dimension_mismatch_gets_typed_reply_and_spares_the_batch() {
        let rt = Runtime::serial();
        let ds = blobs(100, 2, 3, 0.1, 7);
        let model = Kmeans::new(3).seed(1).fit(&rt, &ds).unwrap();
        let want = model.predict_rows(&rt, &[0.5, 0.5]).unwrap();
        let cell = ModelCell::new(model);
        let tel = ServeTelemetry::default();
        let q = RequestQueue::new(8);
        let (good, rx_good) = job(vec![0.5, 0.5], 2);
        let (bad, rx_bad) = job(vec![1.0, 2.0, 3.0], 3);
        q.push(good).map_err(|_| "push").unwrap();
        q.push(bad).map_err(|_| "push").unwrap();
        q.close();
        let events = EventLog::new(16);
        run_batcher(&q, &cell, &rt, &tel, &events, 1024, Duration::ZERO);
        assert_eq!(rx_good.recv().unwrap().unwrap(), want);
        let err = rx_bad.recv().unwrap().unwrap_err();
        assert_eq!(err.code, code::DIM_MISMATCH);
    }
}
