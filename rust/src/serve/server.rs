//! The blocking TCP server: N acceptor threads, one micro-batcher, one
//! shared [`Runtime`], one hot-reloadable [`ModelCell`].
//!
//! [`serve`] binds, spawns everything on scoped threads, and blocks the
//! caller until a `shutdown` op arrives; it then drains queued work and
//! returns the final [`ServeStats`]. Each acceptor owns one connection
//! at a time and handles its requests strictly in order (reply before
//! the next read), so per-connection responses always map to requests
//! in arrival order; across connections the batcher's arrival-order
//! scatter gives the same guarantee. Two backpressure layers keep the
//! server's memory bounded under any traffic: connection concurrency
//! beyond the acceptor count waits in the OS listen backlog, and work
//! beyond the queue depth is refused with the typed `overloaded`
//! reply. Because every connection carries at most one in-flight
//! request, the second layer actively fires only when
//! `queue_depth < acceptors` — see
//! [`ServeConfig::queue_depth`]. Idle connections are reaped after
//! [`ServeConfig::idle_timeout`], byte-trickling included, so parked
//! peers cannot pin the acceptor budget.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::json::ParseLimits;
use crate::model::FittedModel;
use crate::net::frame::{send_line, Line, LineReader};
use crate::runtime::Runtime;
use crate::serve::batcher::{run_batcher, PredictJob, PushRefused, RequestQueue};
use crate::serve::proto::{self, code, ProtoError, Request};
use crate::serve::state::{ModelCell, Op, ServeStats, ServeTelemetry};

/// How often a connection read wakes up to re-check the shutdown flag.
const READ_POLL: Duration = Duration::from_millis(100);

/// Knobs for [`serve`]. `Default` binds an ephemeral loopback port with
/// serving-friendly queue/batch sizes.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address (`host:port`; port 0 picks an ephemeral port).
    pub addr: String,
    /// Acceptor threads — the concurrent-connection budget.
    pub acceptors: usize,
    /// Bounded predict-queue depth; pushes beyond it get the typed
    /// `overloaded` reply instead of queueing unboundedly.
    ///
    /// Each connection has at most one request in flight, so queue
    /// occupancy never exceeds the acceptor count: the typed reject
    /// only actually fires when `queue_depth < acceptors`
    /// (strict-reject mode). At the defaults the first backpressure
    /// layer — the acceptor budget plus the OS listen backlog — binds
    /// instead, and this depth is a hard safety bound, not an active
    /// limiter.
    pub queue_depth: usize,
    /// Coalescing cap: a batch stops pulling jobs once it holds this
    /// many rows (a single larger request still runs alone).
    pub max_batch_rows: usize,
    /// Micro-batching window: after taking a batch's first job, keep
    /// pulling arrivals until this much time passes or the row cap is
    /// hit. Zero (the default) drains only what is already queued.
    pub linger: Duration,
    /// Per-line byte cap on the socket (requests longer than this get
    /// the typed `payload_too_large` reply and the connection closes).
    pub max_line_bytes: usize,
    /// Close a connection after this long without a complete request.
    /// Acceptors are the concurrency budget, so idle peers must not be
    /// allowed to pin them forever (`Duration::ZERO` disables the
    /// timeout — only for trusted peers).
    pub idle_timeout: Duration,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            acceptors: 4,
            queue_depth: 256,
            max_batch_rows: 4096,
            linger: Duration::ZERO,
            max_line_bytes: 4 << 20,
            idle_timeout: Duration::from_secs(60),
        }
    }
}

/// Everything a connection handler needs, borrowed for the scope of one
/// [`serve`] call.
struct Ctx<'a> {
    cfg: &'a ServeConfig,
    limits: ParseLimits,
    threads: usize,
    started: Instant,
    shutdown: &'a AtomicBool,
    queue: &'a RequestQueue,
    cell: &'a ModelCell,
    telemetry: &'a ServeTelemetry,
}

/// Run the server until a `shutdown` op: bind `cfg.addr`, call
/// `on_ready` with the bound address (ephemeral ports become known
/// here), serve, drain, and return the final telemetry snapshot.
///
/// The caller's thread blocks for the server's lifetime; tests and
/// embedders run `serve` on a thread of its own and talk to it over the
/// socket.
pub fn serve<F: FnOnce(SocketAddr)>(
    rt: &Runtime,
    model: FittedModel,
    cfg: &ServeConfig,
    on_ready: F,
) -> Result<ServeStats> {
    let listener = TcpListener::bind(&cfg.addr)?;
    // the acceptors poll a nonblocking listener so shutdown can never
    // strand a thread inside a blocking accept()
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let acceptors = cfg.acceptors.max(1);
    let shutdown = AtomicBool::new(false);
    let queue = RequestQueue::new(cfg.queue_depth.max(1));
    let cell = ModelCell::new(model);
    let telemetry = ServeTelemetry::default();
    let ctx = Ctx {
        cfg,
        limits: ParseLimits {
            max_bytes: cfg.max_line_bytes,
            ..ParseLimits::network()
        },
        threads: rt.threads(),
        started: Instant::now(),
        shutdown: &shutdown,
        queue: &queue,
        cell: &cell,
        telemetry: &telemetry,
    };
    on_ready(addr);
    std::thread::scope(|scope| {
        scope.spawn(|| {
            run_batcher(
                &queue,
                &cell,
                rt,
                &telemetry,
                cfg.max_batch_rows,
                cfg.linger,
            );
        });
        for _ in 0..acceptors {
            scope.spawn(|| accept_loop(&listener, &ctx));
        }
    });
    Ok(telemetry.snapshot())
}

/// How long an idle acceptor sleeps between polls of the nonblocking
/// listener.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

fn accept_loop(listener: &TcpListener, ctx: &Ctx<'_>) {
    loop {
        if ctx.shutdown.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                // accepted sockets may inherit the listener's
                // nonblocking mode on some platforms — undo it so the
                // per-connection read timeout governs instead
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                handle_conn(stream, ctx);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Flip the shutdown flag once and close the queue: new work is
/// refused, queued work drains, acceptors notice on their next poll.
fn initiate_shutdown(ctx: &Ctx<'_>) {
    if ctx.shutdown.swap(true, Ordering::AcqRel) {
        return;
    }
    ctx.queue.close();
}

fn handle_conn(stream: TcpStream, ctx: &Ctx<'_>) {
    if stream.set_read_timeout(Some(READ_POLL)).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    // shared framing (net::frame): the deadline passed to next_line is
    // capped at READ_POLL below, so the connection loop re-checks the
    // shutdown flag on that cadence no matter what the peer sends
    let mut reader = LineReader::new(read_half, ctx.cfg.max_line_bytes);
    let mut write_half = stream;
    let mut last_activity = Instant::now();
    loop {
        if ctx.shutdown.load(Ordering::Acquire) {
            return;
        }
        // every pass is capped at READ_POLL so the shutdown flag above
        // is re-checked on that cadence even while bytes keep arriving;
        // the idle deadline (when enabled) can only tighten it
        let poll_cap = Instant::now() + READ_POLL;
        let deadline = if ctx.cfg.idle_timeout > Duration::ZERO {
            poll_cap.min(last_activity + ctx.cfg.idle_timeout)
        } else {
            poll_cap
        };
        match reader.next_line(deadline) {
            Line::Idle => {
                // idle peers must not pin an acceptor (the concurrency
                // budget) forever
                if ctx.cfg.idle_timeout > Duration::ZERO
                    && last_activity.elapsed() >= ctx.cfg.idle_timeout
                {
                    return;
                }
                continue;
            }
            Line::Eof => return,
            Line::TooLong => {
                ctx.telemetry.bad_request();
                let err = ProtoError::new(
                    code::PAYLOAD_TOO_LARGE,
                    format!("request line exceeds {} bytes", ctx.cfg.max_line_bytes),
                );
                let _ = send_line(&mut write_half, &proto::reply_error(&err));
                return;
            }
            Line::BadUtf8 => {
                last_activity = Instant::now();
                ctx.telemetry.bad_request();
                let err = ProtoError::new(code::BAD_REQUEST, "request line is not utf-8");
                if !send_line(&mut write_half, &proto::reply_error(&err)) {
                    return;
                }
            }
            Line::Msg(line) => {
                last_activity = Instant::now();
                if line.trim().is_empty() {
                    continue;
                }
                match proto::parse_request(&line, &ctx.limits) {
                    Err(e) => {
                        ctx.telemetry.bad_request();
                        if !send_line(&mut write_half, &proto::reply_error(&e)) {
                            return;
                        }
                    }
                    Ok(req) => {
                        ctx.telemetry.request();
                        if !dispatch(req, &mut write_half, ctx) {
                            return;
                        }
                    }
                }
            }
        }
    }
}

/// Serve one parsed request; `false` ends the connection.
fn dispatch(req: Request, w: &mut TcpStream, ctx: &Ctx<'_>) -> bool {
    let t0 = Instant::now();
    match req {
        Request::Predict { rows, n_rows, d } => {
            let (tx, rx) = mpsc::channel();
            let job = PredictJob {
                rows,
                n_rows,
                d,
                reply: tx,
            };
            match ctx.queue.push(job) {
                Err(PushRefused::Full) => {
                    ctx.telemetry.queue_full_reject();
                    let err = ProtoError::new(
                        code::OVERLOADED,
                        format!(
                            "request queue is full ({} pending) — retry later",
                            ctx.cfg.queue_depth
                        ),
                    );
                    send_line(w, &proto::reply_error(&err))
                }
                Err(PushRefused::Closed) => {
                    let err = ProtoError::new(code::SHUTTING_DOWN, "server is shutting down");
                    send_line(w, &proto::reply_error(&err))
                }
                Ok(()) => match rx.recv() {
                    Ok(Ok(labels)) => {
                        ctx.telemetry.op_done(Op::Predict, t0.elapsed());
                        send_line(w, &proto::reply_labels(&labels))
                    }
                    Ok(Err(e)) => {
                        ctx.telemetry.op_error();
                        send_line(w, &proto::reply_error(&e))
                    }
                    Err(_) => {
                        let err =
                            ProtoError::new(code::SHUTTING_DOWN, "batcher stopped before reply");
                        send_line(w, &proto::reply_error(&err))
                    }
                },
            }
        }
        Request::Nearest { point } => {
            let model = ctx.cell.current();
            if point.len() != model.d() {
                ctx.telemetry.op_error();
                let err = ProtoError::new(
                    code::DIM_MISMATCH,
                    format!("model expects d={}, point has d={}", model.d(), point.len()),
                );
                return send_line(w, &proto::reply_error(&err));
            }
            let (label, distance) = model.nearest(&point);
            ctx.telemetry.op_done(Op::Nearest, t0.elapsed());
            send_line(w, &proto::reply_nearest(label, distance))
        }
        Request::Stats => {
            let model = ctx.cell.current();
            let stats = ctx
                .telemetry
                .snapshot()
                .to_json()
                .field("generation", ctx.cell.generation())
                .field("model_k", model.k())
                .field("model_d", model.d())
                .field("algorithm", model.algorithm())
                .field("threads", ctx.threads)
                .field("queue_depth", ctx.cfg.queue_depth)
                .field("max_batch_rows", ctx.cfg.max_batch_rows)
                .field("uptime_secs", ctx.started.elapsed().as_secs_f64());
            ctx.telemetry.op_done(Op::Stats, t0.elapsed());
            send_line(w, &proto::reply_stats(stats))
        }
        Request::Reload { path } => match FittedModel::load(Path::new(&path)) {
            Ok(model) => {
                let (k, d) = (model.k(), model.d());
                let generation = ctx.cell.swap(model);
                ctx.telemetry.op_done(Op::Reload, t0.elapsed());
                send_line(w, &proto::reply_reloaded(generation, k, d))
            }
            Err(e) => {
                ctx.telemetry.op_error();
                let err = ProtoError::new(code::MODEL_ERROR, format!("reload {path:?}: {e}"));
                send_line(w, &proto::reply_error(&err))
            }
        },
        Request::Shutdown => {
            let _ = send_line(w, &proto::reply_ok());
            initiate_shutdown(ctx);
            false
        }
    }
}
