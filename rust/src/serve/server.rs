//! The blocking TCP server: N acceptor threads, one micro-batcher, one
//! shared [`Runtime`], one hot-reloadable [`ModelCell`].
//!
//! [`serve`] binds, spawns everything on scoped threads, and blocks the
//! caller until a `shutdown` op arrives; it then drains queued work and
//! returns the final [`ServeStats`]. Each acceptor owns one connection
//! at a time and handles its requests strictly in order (reply before
//! the next read), so per-connection responses always map to requests
//! in arrival order; across connections the batcher's arrival-order
//! scatter gives the same guarantee.
//!
//! Every connection speaks one of two protocols, sniffed from its first
//! byte: `{` opens the line-JSON fast path
//! ([`proto`](crate::serve::proto)), an upper-case ASCII letter (an
//! HTTP method) opens the HTTP/1.1 shim
//! ([`http`](crate::serve::http)) — same ops, same typed errors, same
//! op handlers underneath, via the [`ReplySink`] seam.
//!
//! Three protection layers keep the server healthy under any traffic,
//! outermost first: **admission control**
//! ([`Admission`](crate::serve::admission)) bounces over-budget or
//! breaker-tripped clients per client key with typed
//! `rate_limited`/`breaker_open` replies before any parsing happens;
//! connection concurrency beyond the acceptor count waits in the OS
//! listen backlog; and work beyond the bounded queue depth is refused
//! with the typed `overloaded` reply. Because every connection carries
//! at most one in-flight request, the queue layer actively fires only
//! when `queue_depth < acceptors` — see [`ServeConfig::queue_depth`].
//! Idle connections are reaped after [`ServeConfig::idle_timeout`],
//! byte-trickling included, so parked peers cannot pin the acceptor
//! budget.

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::data::ooc::{open_ooc_described, DEFAULT_WINDOW_ROWS};
use crate::error::{EakmError, Result};
use crate::json::ParseLimits;
use crate::model::FittedModel;
use crate::net::frame::{send_line, Line, LineReader};
use crate::obs::{events_json, EventLog, Registry, TraceId, Value, DEFAULT_EVENT_CAP};
use crate::runtime::Runtime;
use crate::serve::admission::{Admission, AdmissionConfig, ClientKey, Decision};
use crate::serve::batcher::{run_batcher, PredictJob, PushRefused, RequestQueue};
use crate::serve::http::{self, HttpRead, HttpReader, Routed};
use crate::serve::proto::{self, code, ProtoError, Request};
use crate::serve::state::{ModelCell, Op, ServeStats, ServeTelemetry};

/// How often a connection read wakes up to re-check the shutdown flag.
const READ_POLL: Duration = Duration::from_millis(100);

/// Server-side ceiling on a bulk-predict block, whatever the request
/// asks for — bounds the per-stream label buffer and chunked-source
/// window.
const MAX_BULK_BLOCK_ROWS: usize = 1 << 22;

/// Knobs for [`serve`]. `Default` binds an ephemeral loopback port with
/// serving-friendly queue/batch sizes and admission control disabled.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address (`host:port`; port 0 picks an ephemeral port).
    pub addr: String,
    /// Acceptor threads — the concurrent-connection budget.
    pub acceptors: usize,
    /// Bounded predict-queue depth; pushes beyond it get the typed
    /// `overloaded` reply instead of queueing unboundedly.
    ///
    /// Each connection has at most one request in flight, so queue
    /// occupancy never exceeds the acceptor count: the typed reject
    /// only actually fires when `queue_depth < acceptors`
    /// (strict-reject mode). At the defaults the first backpressure
    /// layer — the acceptor budget plus the OS listen backlog — binds
    /// instead, and this depth is a hard safety bound, not an active
    /// limiter.
    pub queue_depth: usize,
    /// Coalescing cap: a batch stops pulling jobs once it holds this
    /// many rows (a single larger request still runs alone).
    pub max_batch_rows: usize,
    /// Micro-batching window: after taking a batch's first job, keep
    /// pulling arrivals until this much time passes or the row cap is
    /// hit. Zero (the default) drains only what is already queued.
    pub linger: Duration,
    /// Per-line byte cap on the socket (requests longer than this get
    /// the typed `payload_too_large` reply and the connection closes).
    /// The HTTP shim applies the same cap to request bodies.
    pub max_line_bytes: usize,
    /// Close a connection after this long without a complete request.
    /// Acceptors are the concurrency budget, so idle peers must not be
    /// allowed to pin them forever (`Duration::ZERO` disables the
    /// timeout — only for trusted peers).
    pub idle_timeout: Duration,
    /// Per-client rate limiting and circuit breaking, checked before
    /// any request parsing. Disabled by default.
    pub admission: AdmissionConfig,
    /// Default rows per streamed `bulk_predict` block when the request
    /// does not pick its own (clamped server-side either way).
    pub bulk_block_rows: usize,
    /// Record per-op latency histograms (the `GET /metrics` bucket
    /// series and the histogram-derived `stats` fields). On by
    /// default; the serve bench flips it off to price the
    /// observability overhead on the predict hot path. Counters,
    /// latency sums, and lifecycle events are recorded either way.
    pub metrics: bool,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            acceptors: 4,
            queue_depth: 256,
            max_batch_rows: 4096,
            linger: Duration::ZERO,
            max_line_bytes: 4 << 20,
            idle_timeout: Duration::from_secs(60),
            admission: AdmissionConfig::default(),
            bulk_block_rows: DEFAULT_WINDOW_ROWS,
            metrics: true,
        }
    }
}

/// Everything a connection handler needs, borrowed for the scope of one
/// [`serve`] call.
struct Ctx<'a> {
    cfg: &'a ServeConfig,
    limits: ParseLimits,
    rt: &'a Runtime,
    threads: usize,
    started: Instant,
    shutdown: &'a AtomicBool,
    queue: &'a RequestQueue,
    cell: &'a ModelCell,
    telemetry: &'a ServeTelemetry,
    admission: &'a Admission,
    events: &'a EventLog,
}

/// Run the server until a `shutdown` op: bind `cfg.addr`, call
/// `on_ready` with the bound address (ephemeral ports become known
/// here), serve, drain, and return the final telemetry snapshot.
///
/// The caller's thread blocks for the server's lifetime; tests and
/// embedders run `serve` on a thread of its own and talk to it over the
/// socket.
pub fn serve<F: FnOnce(SocketAddr)>(
    rt: &Runtime,
    model: FittedModel,
    cfg: &ServeConfig,
    on_ready: F,
) -> Result<ServeStats> {
    let listener = TcpListener::bind(&cfg.addr)?;
    // the acceptors poll a nonblocking listener so shutdown can never
    // strand a thread inside a blocking accept()
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let acceptors = cfg.acceptors.max(1);
    let shutdown = AtomicBool::new(false);
    let queue = RequestQueue::new(cfg.queue_depth.max(1));
    let cell = ModelCell::new(model);
    let telemetry = ServeTelemetry::new(cfg.metrics);
    let admission = Admission::new(cfg.admission.clone());
    let events = EventLog::new(DEFAULT_EVENT_CAP);
    let ctx = Ctx {
        cfg,
        limits: ParseLimits {
            max_bytes: cfg.max_line_bytes,
            ..ParseLimits::network()
        },
        rt,
        threads: rt.threads(),
        started: Instant::now(),
        shutdown: &shutdown,
        queue: &queue,
        cell: &cell,
        telemetry: &telemetry,
        admission: &admission,
        events: &events,
    };
    on_ready(addr);
    std::thread::scope(|scope| {
        scope.spawn(|| {
            run_batcher(
                &queue,
                &cell,
                rt,
                &telemetry,
                &events,
                cfg.max_batch_rows,
                cfg.linger,
            );
        });
        for _ in 0..acceptors {
            scope.spawn(|| accept_loop(&listener, &ctx));
        }
    });
    Ok(telemetry.snapshot())
}

/// How long an idle acceptor sleeps between polls of the nonblocking
/// listener.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

fn accept_loop(listener: &TcpListener, ctx: &Ctx<'_>) {
    loop {
        if ctx.shutdown.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                // accepted sockets may inherit the listener's
                // nonblocking mode on some platforms — undo it so the
                // per-connection read timeout governs instead
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                handle_conn(stream, ctx);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Flip the shutdown flag once and close the queue: new work is
/// refused, queued work drains, acceptors notice on their next poll.
fn initiate_shutdown(ctx: &Ctx<'_>) {
    if ctx.shutdown.swap(true, Ordering::AcqRel) {
        return;
    }
    ctx.events.push(
        "shutdown",
        TraceId::from_u64(0),
        vec![(
            "uptime_secs",
            Value::F64(ctx.started.elapsed().as_secs_f64()),
        )],
    );
    ctx.queue.close();
}

/// Which wire protocol a connection's first byte selected, carrying
/// the sniffed bytes so the chosen reader replays them.
enum Proto {
    Json(Vec<u8>),
    Http(Vec<u8>),
}

/// Peek at a connection's first non-whitespace byte: `{` is a
/// line-JSON request, an upper-case ASCII letter is an HTTP method.
/// Anything else falls through to the line-JSON path, whose typed
/// `bad_request` replies already cover garbage. `None` means the
/// connection went away (or shutdown/idle-timeout fired) before any
/// request arrived.
fn sniff_protocol(stream: &mut TcpStream, ctx: &Ctx<'_>) -> Option<Proto> {
    let opened = Instant::now();
    loop {
        if ctx.shutdown.load(Ordering::Acquire) {
            return None;
        }
        if ctx.cfg.idle_timeout > Duration::ZERO && opened.elapsed() >= ctx.cfg.idle_timeout {
            return None;
        }
        let mut byte = [0u8; 1];
        match stream.read(&mut byte) {
            Ok(0) => return None,
            Ok(_) => {
                if byte[0].is_ascii_whitespace() {
                    continue; // blank lines before the first request
                }
                let sniffed = vec![byte[0]];
                return Some(if byte[0].is_ascii_uppercase() {
                    Proto::Http(sniffed)
                } else {
                    Proto::Json(sniffed)
                });
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => return None,
        }
    }
}

fn handle_conn(stream: TcpStream, ctx: &Ctx<'_>) {
    if stream.set_read_timeout(Some(READ_POLL)).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    let key = ctx.admission.key_for(stream.peer_addr().ok());
    let Ok(mut read_half) = stream.try_clone() else {
        return;
    };
    let write_half = stream;
    match sniff_protocol(&mut read_half, ctx) {
        None => {}
        Some(Proto::Json(buffered)) => {
            // shared framing (net::frame), seeded with the sniffed byte
            let reader = LineReader::with_buffered(read_half, ctx.cfg.max_line_bytes, buffered);
            serve_lines(reader, write_half, ctx, key);
        }
        Some(Proto::Http(buffered)) => {
            let reader = HttpReader::with_buffered(read_half, ctx.cfg.max_line_bytes, buffered);
            serve_http(reader, write_half, ctx, key);
        }
    }
}

/// The line-JSON connection loop: the deadline passed to each read is
/// capped at [`READ_POLL`] so the shutdown flag is re-checked on that
/// cadence no matter what the peer sends; the idle deadline (when
/// enabled) can only tighten it.
fn serve_lines(
    mut reader: LineReader<TcpStream>,
    mut write_half: TcpStream,
    ctx: &Ctx<'_>,
    key: ClientKey,
) {
    let mut last_activity = Instant::now();
    loop {
        if ctx.shutdown.load(Ordering::Acquire) {
            return;
        }
        let poll_cap = Instant::now() + READ_POLL;
        let deadline = if ctx.cfg.idle_timeout > Duration::ZERO {
            poll_cap.min(last_activity + ctx.cfg.idle_timeout)
        } else {
            poll_cap
        };
        match reader.next_line(deadline) {
            Line::Idle => {
                // idle peers must not pin an acceptor (the concurrency
                // budget) forever
                if ctx.cfg.idle_timeout > Duration::ZERO
                    && last_activity.elapsed() >= ctx.cfg.idle_timeout
                {
                    return;
                }
                continue;
            }
            Line::Eof => return,
            Line::TooLong => {
                ctx.telemetry.bad_request();
                ctx.admission.outcome(key, false);
                let err = ProtoError::new(
                    code::PAYLOAD_TOO_LARGE,
                    format!("request line exceeds {} bytes", ctx.cfg.max_line_bytes),
                );
                let _ = send_line(&mut write_half, &proto::reply_error(&err));
                return;
            }
            Line::BadUtf8 => {
                last_activity = Instant::now();
                ctx.telemetry.bad_request();
                ctx.admission.outcome(key, false);
                let err = ProtoError::new(code::BAD_REQUEST, "request line is not utf-8");
                if !send_line(&mut write_half, &proto::reply_error(&err)) {
                    return;
                }
            }
            Line::Msg(line) => {
                last_activity = Instant::now();
                if line.trim().is_empty() {
                    continue;
                }
                // admission runs before parsing: refused work must cost
                // (almost) nothing
                if let Some(err) = admission_reject(ctx, key) {
                    if !send_line(&mut write_half, &proto::reply_error(&err)) {
                        return;
                    }
                    continue;
                }
                match proto::parse_request(&line, &ctx.limits) {
                    Err(e) => {
                        ctx.telemetry.bad_request();
                        ctx.admission.outcome(key, false);
                        if !send_line(&mut write_half, &proto::reply_error(&e)) {
                            return;
                        }
                    }
                    Ok(req) => {
                        ctx.telemetry.request();
                        let mut sink = LineSink { w: &mut write_half };
                        let done = dispatch(req, &mut sink, ctx);
                        match done.verdict {
                            Some(ok) => ctx.admission.outcome(key, ok),
                            // no verdict (overload/shutdown/peer gone):
                            // still release a half-open probe slot
                            None => ctx.admission.probe_aborted(key),
                        }
                        if !done.keep {
                            return;
                        }
                    }
                }
            }
        }
    }
}

/// The HTTP connection loop — same shutdown/idle discipline as
/// [`serve_lines`], with keep-alive and per-route status codes.
fn serve_http(
    mut reader: HttpReader<TcpStream>,
    mut write_half: TcpStream,
    ctx: &Ctx<'_>,
    key: ClientKey,
) {
    let mut last_activity = Instant::now();
    loop {
        if ctx.shutdown.load(Ordering::Acquire) {
            return;
        }
        let poll_cap = Instant::now() + READ_POLL;
        let deadline = if ctx.cfg.idle_timeout > Duration::ZERO {
            poll_cap.min(last_activity + ctx.cfg.idle_timeout)
        } else {
            poll_cap
        };
        match reader.next_request(deadline, &mut write_half) {
            HttpRead::Idle => {
                if ctx.cfg.idle_timeout > Duration::ZERO
                    && last_activity.elapsed() >= ctx.cfg.idle_timeout
                {
                    return;
                }
                continue;
            }
            HttpRead::Eof => return,
            HttpRead::TooLarge => {
                ctx.telemetry.bad_request();
                ctx.admission.outcome(key, false);
                let err = ProtoError::new(
                    code::PAYLOAD_TOO_LARGE,
                    format!("request exceeds {} bytes", ctx.cfg.max_line_bytes),
                );
                let _ = http::send_response(
                    &mut write_half,
                    413,
                    None,
                    &proto::reply_error(&err),
                    false,
                );
                return;
            }
            HttpRead::Bad => {
                ctx.telemetry.bad_request();
                ctx.admission.outcome(key, false);
                let err = ProtoError::new(code::BAD_REQUEST, "malformed HTTP request");
                let _ = http::send_response(
                    &mut write_half,
                    400,
                    None,
                    &proto::reply_error(&err),
                    false,
                );
                return;
            }
            HttpRead::Msg(req) => {
                last_activity = Instant::now();
                ctx.telemetry.http_request();
                let keep = req.keep_alive;
                // the liveness probe bypasses admission control: load
                // shedding must never make the server look dead
                if req.method == "GET" && req.path == "/v1/healthz" {
                    if !http::send_response(&mut write_half, 200, None, &proto::reply_ok(), keep)
                        || !keep
                    {
                        return;
                    }
                    continue;
                }
                // the observability endpoints bypass admission for the
                // same reason healthz does: load shedding must never
                // blind the operator who is diagnosing the shedding
                if req.method == "GET" && req.path == "/metrics" {
                    let body = render_metrics(ctx);
                    if !http::send_typed_response(
                        &mut write_half,
                        200,
                        "text/plain; version=0.0.4",
                        &body,
                        keep,
                    ) || !keep
                    {
                        return;
                    }
                    continue;
                }
                if req.method == "GET" && req.path == "/v1/events" {
                    let since = events_since(&req.query);
                    let body =
                        events_json(&ctx.events.since(since), ctx.events.last_seq()).to_string();
                    if !http::send_typed_response(
                        &mut write_half,
                        200,
                        "application/json",
                        &body,
                        keep,
                    ) || !keep
                    {
                        return;
                    }
                    continue;
                }
                if let Some(err) = admission_reject(ctx, key) {
                    let retry = retry_after(&err);
                    let status = http::status_for(err.code);
                    if !http::send_response(
                        &mut write_half,
                        status,
                        retry,
                        &proto::reply_error(&err),
                        keep,
                    ) || !keep
                    {
                        return;
                    }
                    continue;
                }
                match http::route(&req, &ctx.limits) {
                    Err(e) => {
                        ctx.telemetry.bad_request();
                        ctx.admission.outcome(key, false);
                        let status = http::status_for(e.code);
                        if !http::send_response(
                            &mut write_half,
                            status,
                            None,
                            &proto::reply_error(&e),
                            keep,
                        ) || !keep
                        {
                            return;
                        }
                    }
                    Ok(Routed::Healthz) => {
                        // unreachable via the early check above; answer
                        // anyway so the route table stays total
                        if !http::send_response(
                            &mut write_half,
                            200,
                            None,
                            &proto::reply_ok(),
                            keep,
                        ) || !keep
                        {
                            return;
                        }
                    }
                    Ok(Routed::Op(op)) => {
                        ctx.telemetry.request();
                        let mut sink = HttpSink {
                            w: &mut write_half,
                            keep_alive: keep,
                        };
                        let done = dispatch(op, &mut sink, ctx);
                        match done.verdict {
                            Some(ok) => ctx.admission.outcome(key, ok),
                            None => ctx.admission.probe_aborted(key),
                        }
                        if !done.keep || !keep {
                            return;
                        }
                    }
                }
            }
        }
    }
}

/// Run the admission decision for one request; `Some` is the typed
/// rejection to send (connection stays open — a throttled client that
/// backs off correctly should not pay a reconnect).
fn admission_reject(ctx: &Ctx<'_>, key: ClientKey) -> Option<ProtoError> {
    match ctx.admission.check(key) {
        Decision::Admit => None,
        Decision::RateLimited(after) => {
            ctx.telemetry.rate_limited_reject();
            ctx.events.push(
                "rate_limited",
                TraceId::from_u64(0),
                vec![("retry_secs", Value::F64(after.as_secs_f64()))],
            );
            Some(ProtoError::new(
                code::RATE_LIMITED,
                format!("rate limit exceeded — retry in {:.2}s", after.as_secs_f64()),
            ))
        }
        Decision::BreakerOpen(after) => {
            ctx.telemetry.breaker_reject();
            ctx.events.push(
                "breaker_open",
                TraceId::from_u64(0),
                vec![("retry_secs", Value::F64(after.as_secs_f64()))],
            );
            Some(ProtoError::new(
                code::BREAKER_OPEN,
                format!(
                    "circuit breaker open after repeated failures — retry in {:.2}s",
                    after.as_secs_f64()
                ),
            ))
        }
    }
}

/// Recover the Retry-After hint baked into an admission rejection's
/// message (kept out of [`ProtoError`] so the wire shape is unchanged).
fn retry_after(err: &ProtoError) -> Option<Duration> {
    err.message
        .rsplit_once("retry in ")
        .and_then(|(_, tail)| tail.strip_suffix('s'))
        .and_then(|secs| secs.parse::<f64>().ok())
        .map(Duration::from_secs_f64)
}

/// Parse the `since=` cursor of a `GET /v1/events` drain (0 — the
/// whole resident ring — when absent or malformed).
fn events_since(query: &str) -> u64 {
    query
        .split('&')
        .find_map(|pair| pair.strip_prefix("since="))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

/// Render the `GET /metrics` body: a scrape-time [`Registry`] built
/// from the current telemetry snapshot and the served model's fit
/// report, so the request hot path never pays any exposition cost.
/// Every [`ServeStats`],
/// [`SchedTelemetry`](crate::metrics::SchedTelemetry),
/// [`IoTelemetry`](crate::metrics::IoTelemetry), and
/// [`Counters`](crate::metrics::Counters) field appears as a metric
/// family here, plus the paper-grounded bounds-effectiveness rates
/// (distance calculations per point per round, by site).
fn render_metrics(ctx: &Ctx<'_>) -> String {
    let reg = Registry::new();
    let s = ctx.telemetry.snapshot();
    reg.sample_counter(
        "eakm_serve_requests_total",
        "Request lines received (including invalid ones).",
        &[],
        s.requests,
    );
    reg.sample_counter(
        "eakm_serve_bad_requests_total",
        "Request lines rejected as malformed or over-limit.",
        &[],
        s.bad_requests,
    );
    reg.sample_counter(
        "eakm_serve_op_errors_total",
        "Well-formed requests that failed during execution.",
        &[],
        s.op_errors,
    );
    reg.sample_counter(
        "eakm_serve_http_requests_total",
        "Requests that arrived via the HTTP shim (protocol mix).",
        &[],
        s.http_requests,
    );
    for (reason, count) in [
        ("overloaded", s.queue_full_rejects),
        ("rate_limited", s.rate_limited_rejects),
        ("breaker_open", s.breaker_rejects),
    ] {
        reg.sample_counter(
            "eakm_serve_rejects_total",
            "Requests bounced with a typed backpressure reply, by reason.",
            &[("reason", reason)],
            count,
        );
    }
    reg.sample_counter(
        "eakm_serve_batches_total",
        "Pool scans the micro-batcher executed.",
        &[],
        s.batches,
    );
    reg.sample_counter(
        "eakm_serve_coalesced_batches_total",
        "Batches that coalesced more than one request into one scan.",
        &[],
        s.coalesced_batches,
    );
    reg.sample_counter(
        "eakm_serve_batched_rows_total",
        "Query rows that went through the micro-batcher.",
        &[],
        s.batched_rows,
    );
    reg.sample_counter(
        "eakm_serve_bulk_blocks_total",
        "Label blocks streamed by bulk predicts.",
        &[],
        s.bulk_blocks,
    );
    reg.sample_counter(
        "eakm_serve_bulk_rows_total",
        "Rows labelled by bulk predicts.",
        &[],
        s.bulk_rows,
    );
    for (name, op, ops, secs, lat) in [
        ("predict", Op::Predict, s.predicts, s.predict_secs, s.predict_latency),
        ("nearest", Op::Nearest, s.nearests, s.nearest_secs, s.nearest_latency),
        ("stats", Op::Stats, s.stats_ops, s.stats_secs, s.stats_latency),
        ("reload", Op::Reload, s.reloads, s.reload_secs, s.reload_latency),
        ("bulk", Op::Bulk, s.bulk_predicts, s.bulk_secs, s.bulk_latency),
    ] {
        let labels = [("op", name)];
        reg.sample_counter("eakm_serve_ops_total", "Completed ops, by op.", &labels, ops);
        reg.sample_gauge(
            "eakm_serve_op_seconds_total",
            "Summed op latency in seconds — the stats reply's *_secs sums.",
            &labels,
            secs,
        );
        reg.sample_histogram(
            "eakm_serve_op_latency_micros",
            "Op latency histogram (log-bucketed microseconds).",
            &labels,
            &ctx.telemetry.op_histogram(op),
        );
        reg.sample_gauge(
            "eakm_serve_op_latency_mean_micros",
            "Histogram-derived mean op latency, microseconds.",
            &labels,
            lat.mean_micros,
        );
        reg.sample_gauge(
            "eakm_serve_op_latency_p50_micros",
            "Histogram-derived median op latency, microseconds (bucket upper bound).",
            &labels,
            lat.p50_micros as f64,
        );
        reg.sample_gauge(
            "eakm_serve_op_latency_p99_micros",
            "Histogram-derived p99 op latency, microseconds (bucket upper bound).",
            &labels,
            lat.p99_micros as f64,
        );
    }
    reg.sample_gauge(
        "eakm_serve_uptime_seconds",
        "Seconds since the server started.",
        &[],
        ctx.started.elapsed().as_secs_f64(),
    );
    reg.sample_gauge(
        "eakm_serve_model_generation",
        "Served model generation: 1 at startup, +1 per reload.",
        &[],
        ctx.cell.generation() as f64,
    );
    reg.sample_gauge(
        "eakm_serve_threads",
        "Worker threads in the shared runtime.",
        &[],
        ctx.threads as f64,
    );
    reg.sample_gauge(
        "eakm_serve_queue_depth",
        "Bounded predict-queue depth.",
        &[],
        ctx.cfg.queue_depth as f64,
    );
    reg.sample_gauge(
        "eakm_serve_max_batch_rows",
        "Coalescing row cap per batch.",
        &[],
        ctx.cfg.max_batch_rows as f64,
    );
    reg.sample_gauge(
        "eakm_serve_events_seq",
        "Sequence number of the newest structured event.",
        &[],
        ctx.events.last_seq() as f64,
    );
    // the served model's fit report: the paper's distance-calculation
    // decompositions and the rates they normalise to
    let model = ctx.cell.current();
    let report = model.report();
    let alg: &str = &report.algorithm;
    reg.sample_gauge("eakm_model_k", "Clusters in the served model.", &[], model.k() as f64);
    reg.sample_gauge("eakm_model_d", "Dimensions in the served model.", &[], model.d() as f64);
    reg.sample_gauge(
        "eakm_fit_rounds",
        "Rounds the served model's fit ran.",
        &[("algorithm", alg)],
        report.iterations as f64,
    );
    reg.sample_gauge(
        "eakm_fit_mse",
        "Final mean squared error of the served model's fit.",
        &[("algorithm", alg)],
        report.mse,
    );
    reg.sample_gauge(
        "eakm_fit_n",
        "Training rows the served model's fit scanned (0 = unknown).",
        &[("algorithm", alg)],
        report.n as f64,
    );
    for (site, count) in [
        ("assignment", report.counters.assignment),
        ("centroid", report.counters.centroid),
        ("displacement", report.counters.displacement),
        ("init", report.counters.init),
        ("total", report.counters.total()),
    ] {
        let labels = [("site", site), ("algorithm", alg)];
        reg.sample_counter(
            "eakm_fit_distance_calcs_total",
            "Distance calculations of the served model's fit, by site.",
            &labels,
            count,
        );
        reg.sample_gauge(
            "eakm_fit_distance_calcs_per_point_round",
            "Bounds effectiveness: distance calculations per point per round (Lloyd pays k).",
            &labels,
            report.per_point_round(count),
        );
    }
    let sched = report.sched;
    reg.sample_gauge(
        "eakm_fit_sched_shards",
        "Shards in the fit's scan plan.",
        &[],
        sched.shards as f64,
    );
    reg.sample_counter(
        "eakm_fit_sched_dispatches_total",
        "Pooled scan dispatches (initial assignment + one per round).",
        &[],
        sched.dispatches,
    );
    reg.sample_counter(
        "eakm_fit_sched_reorders_total",
        "Dispatches whose LPT claim order re-ranked shards.",
        &[],
        sched.reorders,
    );
    for (phase, max, mean) in [
        ("init", sched.init_max, sched.init_mean),
        ("scan", sched.scan_max, sched.scan_mean),
    ] {
        let labels = [("phase", phase)];
        reg.sample_gauge(
            "eakm_fit_sched_max_seconds",
            "Slowest-shard wall time summed over dispatches, by phase.",
            &labels,
            max.as_secs_f64(),
        );
        reg.sample_gauge(
            "eakm_fit_sched_mean_seconds",
            "Mean shard wall time summed over dispatches, by phase.",
            &labels,
            mean.as_secs_f64(),
        );
    }
    reg.sample_gauge(
        "eakm_fit_sched_imbalance",
        "Straggler ratio of the fit's scans (1.0 = balanced).",
        &[],
        sched.imbalance(),
    );
    let io = report.io.unwrap_or_default();
    reg.sample_counter(
        "eakm_fit_io_blocks_leased_total",
        "Row blocks leased from out-of-core cursors during the fit.",
        &[],
        io.blocks_leased,
    );
    reg.sample_counter(
        "eakm_fit_io_bytes_read_total",
        "Bytes read from the backing file during the fit.",
        &[],
        io.bytes_read,
    );
    reg.sample_counter(
        "eakm_fit_io_window_refills_total",
        "Resident-window refills during the fit (0 for mmap sources).",
        &[],
        io.window_refills,
    );
    reg.render()
}

/// How a dispatched request ended.
struct Done {
    /// Keep the connection (replies were delivered)?
    keep: bool,
    /// The circuit-breaker verdict: `Some(true)` success,
    /// `Some(false)` client-caused failure, `None` for server-side
    /// conditions (overload, shutdown, peer gone) that must not trip a
    /// client's breaker — `None` is still reported to admission as
    /// [`probe_aborted`](Admission::probe_aborted) so a half-open probe
    /// that lands on one of these paths cannot wedge the breaker open.
    verdict: Option<bool>,
}

/// Where replies go — the seam that lets one [`dispatch`] serve both
/// protocols. Single-reply ops call [`ok`](ReplySink::ok) or
/// [`err`](ReplySink::err); the streaming bulk-predict op brackets its
/// block items with `stream_begin`/`stream_end`. Every method returns
/// `false` when the peer is gone.
trait ReplySink {
    /// Deliver a successful single-line reply.
    fn ok(&mut self, line: &str) -> bool;
    /// Deliver a typed failure reply.
    fn err(&mut self, e: &ProtoError) -> bool;
    /// Open a streaming reply with its header line.
    fn stream_begin(&mut self, header: &str) -> bool;
    /// Deliver one streamed item line.
    fn stream_item(&mut self, line: &str) -> bool;
    /// Close the stream with its trailer line.
    fn stream_end(&mut self, trailer: &str) -> bool;
}

/// Line-JSON replies: every reply is one newline-terminated JSON line,
/// streams included.
struct LineSink<'a> {
    w: &'a mut TcpStream,
}

impl ReplySink for LineSink<'_> {
    fn ok(&mut self, line: &str) -> bool {
        send_line(self.w, line)
    }
    fn err(&mut self, e: &ProtoError) -> bool {
        send_line(self.w, &proto::reply_error(e))
    }
    fn stream_begin(&mut self, header: &str) -> bool {
        send_line(self.w, header)
    }
    fn stream_item(&mut self, line: &str) -> bool {
        send_line(self.w, line)
    }
    fn stream_end(&mut self, trailer: &str) -> bool {
        send_line(self.w, trailer)
    }
}

/// HTTP replies: status codes mapped from the typed error codes,
/// streams delivered as one chunked response (one chunk per line).
struct HttpSink<'a> {
    w: &'a mut TcpStream,
    keep_alive: bool,
}

impl ReplySink for HttpSink<'_> {
    fn ok(&mut self, line: &str) -> bool {
        http::send_response(self.w, 200, None, line, self.keep_alive)
    }
    fn err(&mut self, e: &ProtoError) -> bool {
        let status = http::status_for(e.code);
        // backpressure statuses always advertise a retry hint
        let retry = if status == 429 || status == 503 {
            Some(retry_after(e).unwrap_or_else(|| Duration::from_secs(1)))
        } else {
            None
        };
        http::send_response(self.w, status, retry, &proto::reply_error(e), self.keep_alive)
    }
    fn stream_begin(&mut self, header: &str) -> bool {
        http::send_chunked_head(self.w, self.keep_alive) && http::send_chunk(self.w, header)
    }
    fn stream_item(&mut self, line: &str) -> bool {
        http::send_chunk(self.w, line)
    }
    fn stream_end(&mut self, trailer: &str) -> bool {
        http::send_chunk(self.w, trailer) && http::send_chunk_end(self.w)
    }
}

/// Serve one parsed request through `sink`.
fn dispatch(req: Request, sink: &mut dyn ReplySink, ctx: &Ctx<'_>) -> Done {
    let t0 = Instant::now();
    // the front door: every accepted request gets a trace ID here, and
    // predict jobs carry it through the batcher to the pool dispatch
    let trace = TraceId::mint();
    match req {
        Request::Predict { rows, n_rows, d } => {
            let (tx, rx) = mpsc::channel();
            let job = PredictJob {
                rows,
                n_rows,
                d,
                trace: trace.as_u64(),
                reply: tx,
            };
            match ctx.queue.push(job) {
                Err(PushRefused::Full) => {
                    ctx.telemetry.queue_full_reject();
                    ctx.events.push(
                        "overload",
                        trace,
                        vec![("queue_depth", Value::U64(ctx.cfg.queue_depth as u64))],
                    );
                    let err = ProtoError::new(
                        code::OVERLOADED,
                        format!(
                            "request queue is full ({} pending) — retry later",
                            ctx.cfg.queue_depth
                        ),
                    );
                    Done {
                        keep: sink.err(&err),
                        verdict: None,
                    }
                }
                Err(PushRefused::Closed) => {
                    let err = ProtoError::new(code::SHUTTING_DOWN, "server is shutting down");
                    Done {
                        keep: sink.err(&err),
                        verdict: None,
                    }
                }
                Ok(()) => match rx.recv() {
                    Ok(Ok(labels)) => {
                        ctx.telemetry.op_done(Op::Predict, t0.elapsed());
                        Done {
                            keep: sink.ok(&proto::reply_labels(&labels)),
                            verdict: Some(true),
                        }
                    }
                    Ok(Err(e)) => {
                        ctx.telemetry.op_error();
                        Done {
                            keep: sink.err(&e),
                            verdict: Some(false),
                        }
                    }
                    Err(_) => {
                        let err =
                            ProtoError::new(code::SHUTTING_DOWN, "batcher stopped before reply");
                        Done {
                            keep: sink.err(&err),
                            verdict: None,
                        }
                    }
                },
            }
        }
        Request::Nearest { point } => {
            let model = ctx.cell.current();
            if point.len() != model.d() {
                ctx.telemetry.op_error();
                let err = ProtoError::new(
                    code::DIM_MISMATCH,
                    format!("model expects d={}, point has d={}", model.d(), point.len()),
                );
                return Done {
                    keep: sink.err(&err),
                    verdict: Some(false),
                };
            }
            let (label, distance) = model.nearest(&point);
            ctx.telemetry.op_done(Op::Nearest, t0.elapsed());
            Done {
                keep: sink.ok(&proto::reply_nearest(label, distance)),
                verdict: Some(true),
            }
        }
        Request::Stats => {
            let model = ctx.cell.current();
            let sched = model.report().sched;
            let stats = ctx
                .telemetry
                .snapshot()
                .to_json()
                .field("generation", ctx.cell.generation())
                .field("model_k", model.k())
                .field("model_d", model.d())
                .field("algorithm", model.algorithm())
                .field("threads", ctx.threads)
                .field("queue_depth", ctx.cfg.queue_depth)
                .field("max_batch_rows", ctx.cfg.max_batch_rows)
                // scheduling telemetry of the fit that produced the
                // served model (zeros for loaded models persisted
                // before the sched block existed)
                .field("fit_sched_shards", sched.shards)
                .field("fit_sched_reorders", sched.reorders as usize)
                .field("fit_sched_imbalance", sched.imbalance())
                .field("uptime_secs", ctx.started.elapsed().as_secs_f64());
            ctx.telemetry.op_done(Op::Stats, t0.elapsed());
            Done {
                keep: sink.ok(&proto::reply_stats(stats)),
                verdict: Some(true),
            }
        }
        Request::Reload { path } => match FittedModel::load(Path::new(&path)) {
            Ok(model) => {
                let (k, d) = (model.k(), model.d());
                let generation = ctx.cell.swap(model);
                ctx.events.push(
                    "reload",
                    trace,
                    vec![
                        ("generation", Value::U64(generation)),
                        ("k", Value::U64(k as u64)),
                        ("d", Value::U64(d as u64)),
                        ("path", Value::Str(path.clone())),
                    ],
                );
                ctx.telemetry.op_done(Op::Reload, t0.elapsed());
                Done {
                    keep: sink.ok(&proto::reply_reloaded(generation, k, d)),
                    verdict: Some(true),
                }
            }
            Err(e) => {
                ctx.telemetry.op_error();
                ctx.events.push(
                    "reload_failed",
                    trace,
                    vec![
                        ("path", Value::Str(path.clone())),
                        ("error", Value::Str(e.to_string())),
                    ],
                );
                let err = ProtoError::new(code::MODEL_ERROR, format!("reload {path:?}: {e}"));
                Done {
                    keep: sink.err(&err),
                    verdict: Some(false),
                }
            }
        },
        Request::BulkPredict {
            path,
            block_rows,
            mode,
        } => bulk_predict(&path, block_rows, mode, sink, ctx, t0),
        Request::Shutdown => {
            let _ = sink.ok(&proto::reply_ok());
            initiate_shutdown(ctx);
            Done {
                keep: false,
                verdict: Some(true),
            }
        }
    }
}

/// The streaming bulk-predict op: open the on-disk source, stream one
/// label block per [`predict_blocks`](FittedModel::predict_blocks)
/// window, close with an [`IoTelemetry`](crate::metrics::IoTelemetry)
/// trailer. Runs inline on the connection thread — the scan holds the
/// worker pool for full blocks at a time, and the pool's dispatch gate
/// already serialises it against the micro-batcher.
fn bulk_predict(
    path: &str,
    block_rows: Option<usize>,
    mode: crate::data::ooc::OocMode,
    sink: &mut dyn ReplySink,
    ctx: &Ctx<'_>,
    t0: Instant,
) -> Done {
    let model = ctx.cell.current();
    let block_rows = block_rows
        .unwrap_or(ctx.cfg.bulk_block_rows)
        .clamp(1, MAX_BULK_BLOCK_ROWS);
    let source = match open_ooc_described(Path::new(path), mode, block_rows) {
        Ok(s) => s,
        Err(e) => {
            ctx.telemetry.op_error();
            let err = ProtoError::new(code::SOURCE_ERROR, format!("bulk_predict: {e}"));
            return Done {
                keep: sink.err(&err),
                verdict: Some(false),
            };
        }
    };
    if source.d() != model.d() {
        ctx.telemetry.op_error();
        let err = ProtoError::new(
            code::DIM_MISMATCH,
            format!(
                "model expects d={}, source {:?} has d={}",
                model.d(),
                source.name(),
                source.d()
            ),
        );
        return Done {
            keep: sink.err(&err),
            verdict: Some(false),
        };
    }
    let n = source.n();
    let io0 = source.io_stats();
    if !sink.stream_begin(&proto::reply_bulk_header(n, source.d(), block_rows)) {
        return Done {
            keep: false,
            verdict: None,
        };
    }
    let mut blocks = 0usize;
    let scan = {
        let sink = &mut *sink;
        let blocks = &mut blocks;
        model.predict_blocks(ctx.rt, source.as_ref(), block_rows, move |lo, labels| {
            ctx.telemetry.bulk_block(labels.len() as u64);
            *blocks += 1;
            if sink.stream_item(&proto::reply_bulk_block(lo, labels)) {
                Ok(())
            } else {
                Err(EakmError::Net(
                    "bulk_predict peer went away mid-stream".to_string(),
                ))
            }
        })
    };
    if scan.is_err() {
        // the stream is already open — a truncated chunked/line stream
        // (no trailer) is the error signal; nothing typed can follow
        return Done {
            keep: false,
            verdict: None,
        };
    }
    let io_delta = match (&io0, source.io_stats()) {
        (Some(before), Some(after)) => Some(after.since(before)),
        _ => None,
    };
    ctx.telemetry.op_done(Op::Bulk, t0.elapsed());
    Done {
        keep: sink.stream_end(&proto::reply_bulk_trailer(blocks, n, io_delta.as_ref())),
        verdict: Some(true),
    }
}
