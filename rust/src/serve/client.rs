//! A minimal client for the serve wire protocol: one TCP connection,
//! synchronous line-at-a-time request/reply, plus builders that format
//! each op with the crate's shortest-roundtrip float writer (so query
//! rows reach the server **bit-identical** — the wire is lossless).
//!
//! The tests, the throughput bench, and `examples/serving.rs` all
//! drive the server through this one client, and embedders can too:
//!
//! ```no_run
//! use eakm::serve::client::{self, Client};
//!
//! let mut c = Client::connect("127.0.0.1:4999").unwrap();
//! let reply = c.call(&client::predict_request(&[0.1, 0.2, 0.3, 0.4], 2)).unwrap();
//! let labels = reply.get("labels").unwrap();
//! # let _ = labels;
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::error::{EakmError, Result};
use crate::json::Json;

/// A blocking connection to a serve endpoint.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect with `TCP_NODELAY` and a 60-second read timeout (a hung
    /// server surfaces as an error, never an indefinite block; tune
    /// with [`set_read_timeout`](Client::set_read_timeout)).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    /// Adjust the reply-read timeout (`None` blocks indefinitely).
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(timeout)?;
        Ok(())
    }

    /// Send one request line (the newline terminator is appended).
    /// Multiple embedded `\n`-separated requests pipeline: the server
    /// answers each, in order, via successive [`recv`](Client::recv)s.
    pub fn send(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    /// Read the next reply line; `Ok(None)` once the server closed the
    /// connection.
    pub fn recv(&mut self) -> Result<Option<Json>> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Ok(None);
        }
        Ok(Some(Json::parse(line.trim_end())?))
    }

    /// One synchronous round-trip: [`send`](Client::send) then
    /// [`recv`](Client::recv), erroring if the server closed instead of
    /// replying.
    pub fn call(&mut self, line: &str) -> Result<Json> {
        self.send(line)?;
        self.recv()?.ok_or_else(|| {
            EakmError::Data("server closed the connection before replying".into())
        })
    }
}

/// `{"op":"predict","rows":[[…],…]}` for row-major `rows` of dimension
/// `d`. Panics when `rows` is not a whole number of rows (caller bug).
pub fn predict_request(rows: &[f64], d: usize) -> String {
    assert!(d > 0 && rows.len() % d == 0, "rows must be n×d row-major");
    Json::obj()
        .field("op", "predict")
        .field(
            "rows",
            Json::Arr(
                rows.chunks_exact(d)
                    .map(|row| Json::Arr(row.iter().map(|&v| Json::Num(v)).collect()))
                    .collect(),
            ),
        )
        .to_string()
}

/// `{"op":"nearest","point":[…]}`
pub fn nearest_request(point: &[f64]) -> String {
    Json::obj()
        .field("op", "nearest")
        .field(
            "point",
            Json::Arr(point.iter().map(|&v| Json::Num(v)).collect()),
        )
        .to_string()
}

/// `{"op":"stats"}`
pub fn stats_request() -> String {
    r#"{"op":"stats"}"#.to_string()
}

/// `{"op":"reload","model":PATH}`
pub fn reload_request(path: &str) -> String {
    Json::obj()
        .field("op", "reload")
        .field("model", path)
        .to_string()
}

/// `{"op":"shutdown"}`
pub fn shutdown_request() -> String {
    r#"{"op":"shutdown"}"#.to_string()
}

/// `{"op":"bulk_predict","path":PATH,…}` — `block_rows: None` leaves
/// the block size to the server.
pub fn bulk_predict_request(path: &str, block_rows: Option<usize>) -> String {
    let mut req = Json::obj().field("op", "bulk_predict").field("path", path);
    if let Some(b) = block_rows {
        req = req.field("block_rows", b as u64);
    }
    req.to_string()
}

/// The collected result of one streaming bulk predict.
#[derive(Clone, Debug)]
pub struct BulkResult {
    /// Labels for every source row, in row order.
    pub labels: Vec<u32>,
    /// Blocks the server streamed.
    pub blocks: u64,
    /// The trailer's `io` object (`None` for in-memory sources).
    pub io: Option<Json>,
}

impl Client {
    /// Run one `bulk_predict` stream to completion: send the request,
    /// read header + blocks + trailer, and reassemble the labels in
    /// row order. A typed server error surfaces as `EakmError::Data`
    /// with the error code in the message; a connection drop
    /// mid-stream (the server's truncation signal) as a read error.
    pub fn bulk_predict(&mut self, path: &str, block_rows: Option<usize>) -> Result<BulkResult> {
        self.send(&bulk_predict_request(path, block_rows))?;
        let header = self.recv()?.ok_or_else(|| {
            EakmError::Data("server closed the connection before replying".into())
        })?;
        if header.get("ok").and_then(Json::as_bool) != Some(true) {
            let code = header
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("unknown");
            let message = header.get("message").and_then(Json::as_str).unwrap_or("");
            return Err(EakmError::Data(format!("bulk_predict: {code}: {message}")));
        }
        let n = header.get("n").and_then(Json::as_f64).unwrap_or(0.0) as usize;
        let mut labels = vec![0u32; n];
        let mut blocks = 0u64;
        loop {
            let line = self.recv()?.ok_or_else(|| {
                EakmError::Net("bulk_predict stream truncated (no trailer)".into())
            })?;
            if line.get("done").and_then(Json::as_bool) == Some(true) {
                return Ok(BulkResult {
                    labels,
                    blocks,
                    io: line.get("io").cloned(),
                });
            }
            let lo = line
                .get("lo")
                .and_then(Json::as_f64)
                .ok_or_else(|| EakmError::Data("bulk_predict block is missing \"lo\"".into()))?
                as usize;
            let block = line
                .get("labels")
                .and_then(Json::as_arr)
                .ok_or_else(|| EakmError::Data("bulk_predict block is missing \"labels\"".into()))?;
            if lo + block.len() > n {
                return Err(EakmError::Data(format!(
                    "bulk_predict block [{lo}, {}) overruns n={n}",
                    lo + block.len()
                )));
            }
            for (i, cell) in block.iter().enumerate() {
                labels[lo + i] = cell
                    .as_f64()
                    .ok_or_else(|| EakmError::Data("bulk_predict label is not a number".into()))?
                    as u32;
            }
            blocks += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::ParseLimits;
    use crate::serve::proto::{parse_request, Request};

    #[test]
    fn builders_roundtrip_through_the_server_parser() {
        let net = ParseLimits::network();
        let vals = [0.1, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE];
        match parse_request(&predict_request(&vals, 2), &net).unwrap() {
            Request::Predict { rows, n_rows, d } => {
                assert_eq!((n_rows, d), (2, 2));
                let bits = |xs: &[f64]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&rows), bits(&vals));
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            parse_request(&nearest_request(&[1.5, 2.5]), &net).unwrap(),
            Request::Nearest { .. }
        ));
        assert!(matches!(
            parse_request(&stats_request(), &net).unwrap(),
            Request::Stats
        ));
        match parse_request(&reload_request("/tmp/m \"x\".json"), &net).unwrap() {
            Request::Reload { path } => assert_eq!(path, "/tmp/m \"x\".json"),
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            parse_request(&shutdown_request(), &net).unwrap(),
            Request::Shutdown
        ));
        match parse_request(&bulk_predict_request("/d/x.ekb", Some(64)), &net).unwrap() {
            Request::BulkPredict {
                path, block_rows, ..
            } => {
                assert_eq!(path, "/d/x.ekb");
                assert_eq!(block_rows, Some(64));
            }
            other => panic!("{other:?}"),
        }
        match parse_request(&bulk_predict_request("/d/x.ekb", None), &net).unwrap() {
            Request::BulkPredict { block_rows, .. } => assert_eq!(block_rows, None),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    #[should_panic(expected = "row-major")]
    fn predict_request_rejects_ragged_slices() {
        let _ = predict_request(&[1.0, 2.0, 3.0], 2);
    }
}
