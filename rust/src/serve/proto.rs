//! The serve wire protocol: line-delimited JSON requests and replies.
//!
//! One request per line, one reply line per request, in order:
//!
//! ```text
//! → {"op":"predict","rows":[[0.1,0.2],[0.3,0.4]]}
//! ← {"ok":true,"labels":[3,7]}
//! → {"op":"nearest","point":[0.1,0.2]}
//! ← {"ok":true,"label":3,"distance":0.173}
//! → {"op":"stats"}
//! ← {"ok":true,"stats":{...}}
//! → {"op":"reload","model":"/path/to/model.json"}
//! ← {"ok":true,"generation":2,"k":100,"d":8}
//! → {"op":"shutdown"}
//! ← {"ok":true}
//! ```
//!
//! `bulk_predict` is the one *streaming* op: a single request line is
//! answered by a header line, one line per label block, and a trailer —
//! so a multi-GB on-disk dataset is labelled over one connection with
//! bounded memory:
//!
//! ```text
//! → {"op":"bulk_predict","path":"/data/big.ekb","block_rows":8192}
//! ← {"ok":true,"streaming":true,"n":1000000,"d":16,"block_rows":8192}
//! ← {"lo":0,"labels":[…]}
//! ← {"lo":8192,"labels":[…]}
//! ← …
//! ← {"done":true,"blocks":123,"rows":1000000,"io":{…}}
//! ```
//!
//! Errors are typed: `{"ok":false,"error":CODE,"message":TEXT}` where
//! `CODE` is one of the [`code`] constants — notably
//! [`code::OVERLOADED`], the backpressure reply a client receives the
//! moment the bounded request queue is full (instead of queueing
//! unboundedly and timing out later).
//!
//! Request bytes are attacker-controlled, so parsing runs under
//! [`ParseLimits::network`] (byte + nesting caps) on top of the
//! server's own line-length cap; every reject is a typed reply, never a
//! panic or an unbounded allocation.

use crate::data::ooc::OocMode;
use crate::error::EakmError;
use crate::json::{Json, ParseLimits};

/// Stable error codes carried in the `"error"` field of failure
/// replies.
pub mod code {
    /// The bounded request queue is full — retry later (backpressure).
    pub const OVERLOADED: &str = "overloaded";
    /// Malformed JSON, missing/ill-typed fields, or non-finite numbers.
    pub const BAD_REQUEST: &str = "bad_request";
    /// Unknown `"op"` value.
    pub const UNKNOWN_OP: &str = "unknown_op";
    /// Request line or document breaches a size/depth limit.
    pub const PAYLOAD_TOO_LARGE: &str = "payload_too_large";
    /// Query dimension does not match the served model.
    pub const DIM_MISMATCH: &str = "dim_mismatch";
    /// A `reload` could not load/validate the model file.
    pub const MODEL_ERROR: &str = "model_error";
    /// The server is shutting down and no longer accepts work.
    pub const SHUTTING_DOWN: &str = "shutting_down";
    /// The client exceeded its admission token bucket — back off for
    /// the advertised interval (HTTP 429 + `Retry-After`).
    pub const RATE_LIMITED: &str = "rate_limited";
    /// The client's circuit breaker is open after consecutive failures
    /// — back off for the cooldown (HTTP 503 + `Retry-After`).
    pub const BREAKER_OPEN: &str = "breaker_open";
    /// HTTP only: no route for the request path (404).
    pub const NOT_FOUND: &str = "not_found";
    /// HTTP only: the route exists but not for this method (405).
    pub const BAD_METHOD: &str = "bad_method";
    /// A `bulk_predict` could not open or read its data source.
    pub const SOURCE_ERROR: &str = "source_error";
}

/// A typed protocol-level failure: stable `code` plus a human message.
#[derive(Clone, Debug)]
pub struct ProtoError {
    /// One of the [`code`] constants.
    pub code: &'static str,
    /// Human-readable detail for the `"message"` field.
    pub message: String,
}

impl ProtoError {
    /// Build an error reply value.
    pub fn new(code: &'static str, message: impl Into<String>) -> ProtoError {
        ProtoError {
            code,
            message: message.into(),
        }
    }
}

/// A parsed client request.
#[derive(Clone, Debug)]
pub enum Request {
    /// Label `n_rows` query rows (row-major, `d` values each).
    Predict {
        /// Row-major `n_rows × d` query values.
        rows: Vec<f64>,
        /// Number of rows.
        n_rows: usize,
        /// Per-row dimension (validated rectangular at parse time).
        d: usize,
    },
    /// Single-point nearest-centroid lookup.
    Nearest {
        /// The query point.
        point: Vec<f64>,
    },
    /// Telemetry snapshot.
    Stats,
    /// Swap the served model for the one at `path` (server-side path).
    Reload {
        /// Model JSON path, as written by `FittedModel::save`.
        path: String,
    },
    /// Label an entire on-disk dataset, streaming label blocks back.
    BulkPredict {
        /// Server-side `.ekb` (or text) dataset path.
        path: String,
        /// Rows per streamed label block (bounds peak memory);
        /// `None` uses the server's configured default.
        block_rows: Option<usize>,
        /// Out-of-core access mode for the source.
        mode: OocMode,
    },
    /// Stop the server after draining in-flight work.
    Shutdown,
}

/// Parse one request line under the given limits. All failures are
/// typed [`ProtoError`]s ready to serialise as a reply.
pub fn parse_request(line: &str, limits: &ParseLimits) -> Result<Request, ProtoError> {
    let doc = Json::parse_with_limits(line, limits).map_err(|e| match e {
        EakmError::Limit(m) => ProtoError::new(code::PAYLOAD_TOO_LARGE, m),
        e => ProtoError::new(code::BAD_REQUEST, e.to_string()),
    })?;
    let op = doc
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| ProtoError::new(code::BAD_REQUEST, "missing string field \"op\""))?;
    request_from_op(op, &doc)
}

/// Build a request from an already-known op name and a parsed
/// document — shared by line-JSON (`"op"` field) and the HTTP shim
/// (op from the route, fields from the body/query).
pub fn request_from_op(op: &str, doc: &Json) -> Result<Request, ProtoError> {
    match op {
        "predict" => parse_predict(doc),
        "nearest" => {
            let point = doc
                .get("point")
                .and_then(Json::as_arr)
                .ok_or_else(|| ProtoError::new(code::BAD_REQUEST, "nearest needs \"point\""))?;
            Ok(Request::Nearest {
                point: finite_row(point, "point")?,
            })
        }
        "stats" => Ok(Request::Stats),
        "reload" => {
            let path = doc
                .get("model")
                .and_then(Json::as_str)
                .ok_or_else(|| ProtoError::new(code::BAD_REQUEST, "reload needs \"model\""))?;
            Ok(Request::Reload {
                path: path.to_string(),
            })
        }
        "bulk_predict" => parse_bulk(doc),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(ProtoError::new(
            code::UNKNOWN_OP,
            format!("unknown op {other:?}"),
        )),
    }
}

fn parse_bulk(doc: &Json) -> Result<Request, ProtoError> {
    let path = doc
        .get("path")
        .and_then(Json::as_str)
        .ok_or_else(|| ProtoError::new(code::BAD_REQUEST, "bulk_predict needs \"path\""))?;
    let block_rows = match doc.get("block_rows") {
        None => None,
        Some(v) => Some(
            v.as_f64()
                .filter(|&b| b.fract() == 0.0 && b >= 1.0 && b <= (1u64 << 32) as f64)
                .map(|b| b as usize)
                .ok_or_else(|| {
                    ProtoError::new(
                        code::BAD_REQUEST,
                        "\"block_rows\" must be a positive integer",
                    )
                })?,
        ),
    };
    let mode = match doc.get("mode") {
        None => OocMode::Auto,
        Some(v) => v
            .as_str()
            .and_then(OocMode::parse)
            .ok_or_else(|| {
                ProtoError::new(code::BAD_REQUEST, "\"mode\" must be auto|mmap|chunked")
            })?,
    };
    Ok(Request::BulkPredict {
        path: path.to_string(),
        block_rows,
        mode,
    })
}

fn finite_row(cells: &[Json], what: &str) -> Result<Vec<f64>, ProtoError> {
    if cells.is_empty() {
        return Err(ProtoError::new(
            code::BAD_REQUEST,
            format!("{what} must not be empty"),
        ));
    }
    let mut row = Vec::with_capacity(cells.len());
    for cell in cells {
        match cell.as_f64() {
            Some(x) if x.is_finite() => row.push(x),
            _ => {
                return Err(ProtoError::new(
                    code::BAD_REQUEST,
                    format!("{what} must hold finite numbers"),
                ))
            }
        }
    }
    Ok(row)
}

fn parse_predict(doc: &Json) -> Result<Request, ProtoError> {
    let rows_json = doc
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or_else(|| ProtoError::new(code::BAD_REQUEST, "predict needs \"rows\""))?;
    if rows_json.is_empty() {
        return Err(ProtoError::new(
            code::BAD_REQUEST,
            "predict rows must not be empty",
        ));
    }
    let mut rows = Vec::new();
    let mut d = 0usize;
    for (i, row_json) in rows_json.iter().enumerate() {
        let cells = row_json.as_arr().ok_or_else(|| {
            ProtoError::new(code::BAD_REQUEST, format!("row {i} is not an array"))
        })?;
        let row = finite_row(cells, "rows")?;
        if i == 0 {
            d = row.len();
            rows.reserve(rows_json.len() * d);
        } else if row.len() != d {
            return Err(ProtoError::new(
                code::BAD_REQUEST,
                format!("row {i} has {} values, row 0 has {d}", row.len()),
            ));
        }
        rows.extend(row);
    }
    Ok(Request::Predict {
        n_rows: rows_json.len(),
        rows,
        d,
    })
}

/// `{"ok":true,"labels":[…]}`
pub fn reply_labels(labels: &[u32]) -> String {
    Json::obj()
        .field("ok", true)
        .field(
            "labels",
            Json::Arr(labels.iter().map(|&l| Json::from(l as u64)).collect()),
        )
        .to_string()
}

/// `{"ok":true,"label":…,"distance":…}`
pub fn reply_nearest(label: u32, distance: f64) -> String {
    Json::obj()
        .field("ok", true)
        .field("label", label as u64)
        .field("distance", distance)
        .to_string()
}

/// `{"ok":true,"stats":{…}}`
pub fn reply_stats(stats: Json) -> String {
    Json::obj().field("ok", true).field("stats", stats).to_string()
}

/// `{"ok":true,"generation":…,"k":…,"d":…}` — a successful reload.
pub fn reply_reloaded(generation: u64, k: usize, d: usize) -> String {
    Json::obj()
        .field("ok", true)
        .field("generation", generation)
        .field("k", k)
        .field("d", d)
        .to_string()
}

/// `{"ok":true}` — shutdown acknowledged.
pub fn reply_ok() -> String {
    Json::obj().field("ok", true).to_string()
}

/// `{"ok":true,"streaming":true,"n":…,"d":…,"block_rows":…}` — the
/// header line opening a bulk-predict stream.
pub fn reply_bulk_header(n: usize, d: usize, block_rows: usize) -> String {
    Json::obj()
        .field("ok", true)
        .field("streaming", true)
        .field("n", n as u64)
        .field("d", d as u64)
        .field("block_rows", block_rows as u64)
        .to_string()
}

/// `{"lo":…,"labels":[…]}` — one streamed block of labels, starting
/// at global row `lo`.
pub fn reply_bulk_block(lo: usize, labels: &[u32]) -> String {
    Json::obj()
        .field("lo", lo as u64)
        .field(
            "labels",
            Json::Arr(labels.iter().map(|&l| Json::from(l as u64)).collect()),
        )
        .to_string()
}

/// `{"done":true,"blocks":…,"rows":…,"io":{…}}` — the trailer closing
/// a bulk-predict stream; `io` carries the source's
/// [`IoTelemetry`](crate::metrics::IoTelemetry) delta for the scan
/// (`null` for in-memory sources).
pub fn reply_bulk_trailer(
    blocks: usize,
    rows: usize,
    io: Option<&crate::metrics::IoTelemetry>,
) -> String {
    let io_json = match io {
        Some(t) => Json::obj()
            .field("blocks_leased", t.blocks_leased)
            .field("bytes_read", t.bytes_read)
            .field("window_refills", t.window_refills),
        None => Json::Null,
    };
    Json::obj()
        .field("done", true)
        .field("blocks", blocks as u64)
        .field("rows", rows as u64)
        .field("io", io_json)
        .to_string()
}

/// `{"ok":false,"error":…,"message":…}`
pub fn reply_error(err: &ProtoError) -> String {
    Json::obj()
        .field("ok", false)
        .field("error", err.code)
        .field("message", err.message.as_str())
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net() -> ParseLimits {
        ParseLimits::network()
    }

    #[test]
    fn parses_every_op() {
        match parse_request(r#"{"op":"predict","rows":[[1,2],[3,4],[5,6]]}"#, &net()) {
            Ok(Request::Predict { rows, n_rows, d }) => {
                assert_eq!(n_rows, 3);
                assert_eq!(d, 2);
                assert_eq!(rows, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
            }
            other => panic!("{other:?}"),
        }
        match parse_request(r#"{"op":"nearest","point":[0.5,-1.5]}"#, &net()) {
            Ok(Request::Nearest { point }) => assert_eq!(point, vec![0.5, -1.5]),
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            parse_request(r#"{"op":"stats"}"#, &net()),
            Ok(Request::Stats)
        ));
        match parse_request(r#"{"op":"reload","model":"/tmp/m.json"}"#, &net()) {
            Ok(Request::Reload { path }) => assert_eq!(path, "/tmp/m.json"),
            other => panic!("{other:?}"),
        }
        match parse_request(
            r#"{"op":"bulk_predict","path":"/d/x.ekb","block_rows":512,"mode":"mmap"}"#,
            &net(),
        ) {
            Ok(Request::BulkPredict {
                path,
                block_rows,
                mode,
            }) => {
                assert_eq!(path, "/d/x.ekb");
                assert_eq!(block_rows, Some(512));
                assert_eq!(mode, OocMode::Mmap);
            }
            other => panic!("{other:?}"),
        }
        match parse_request(r#"{"op":"bulk_predict","path":"/d/x.ekb"}"#, &net()) {
            Ok(Request::BulkPredict {
                block_rows, mode, ..
            }) => {
                assert_eq!(block_rows, None);
                assert_eq!(mode, OocMode::Auto);
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            parse_request(r#"{"op":"shutdown"}"#, &net()),
            Ok(Request::Shutdown)
        ));
    }

    #[test]
    fn rejects_malformed_requests_with_typed_codes() {
        let cases: &[(&str, &str)] = &[
            ("not json at all", code::BAD_REQUEST),
            (r#"{"rows":[[1]]}"#, code::BAD_REQUEST),
            (r#"{"op":"frobnicate"}"#, code::UNKNOWN_OP),
            (r#"{"op":"predict"}"#, code::BAD_REQUEST),
            (r#"{"op":"predict","rows":[]}"#, code::BAD_REQUEST),
            (r#"{"op":"predict","rows":[[1,2],[3]]}"#, code::BAD_REQUEST),
            (r#"{"op":"predict","rows":[[1,null]]}"#, code::BAD_REQUEST),
            (r#"{"op":"predict","rows":[1,2]}"#, code::BAD_REQUEST),
            (r#"{"op":"nearest","point":[]}"#, code::BAD_REQUEST),
            (r#"{"op":"nearest"}"#, code::BAD_REQUEST),
            (r#"{"op":"reload"}"#, code::BAD_REQUEST),
            (r#"{"op":"bulk_predict"}"#, code::BAD_REQUEST),
            (
                r#"{"op":"bulk_predict","path":"/x","block_rows":0}"#,
                code::BAD_REQUEST,
            ),
            (
                r#"{"op":"bulk_predict","path":"/x","block_rows":1.5}"#,
                code::BAD_REQUEST,
            ),
            (
                r#"{"op":"bulk_predict","path":"/x","mode":"warp"}"#,
                code::BAD_REQUEST,
            ),
        ];
        for (line, want) in cases {
            match parse_request(line, &net()) {
                Err(e) => assert_eq!(e.code, *want, "{line}"),
                Ok(r) => panic!("accepted {line:?} as {r:?}"),
            }
        }
    }

    #[test]
    fn hostile_payloads_get_limit_codes() {
        // nesting bomb → typed payload_too_large, not a stack overflow
        let deep = format!("{}1{}", "[".repeat(1000), "]".repeat(1000));
        let err = parse_request(&deep, &net()).unwrap_err();
        assert_eq!(err.code, code::PAYLOAD_TOO_LARGE);
        // oversized document → same code, rejected before parsing
        let tiny = ParseLimits {
            max_bytes: 32,
            max_depth: 64,
        };
        let err = parse_request(r#"{"op":"predict","rows":[[1,2,3,4]]}"#, &tiny).unwrap_err();
        assert_eq!(err.code, code::PAYLOAD_TOO_LARGE);
    }

    #[test]
    fn replies_are_single_json_lines() {
        assert_eq!(reply_labels(&[1, 2, 3]), r#"{"ok":true,"labels":[1,2,3]}"#);
        assert_eq!(
            reply_nearest(4, 0.5),
            r#"{"ok":true,"label":4,"distance":0.5}"#
        );
        assert_eq!(reply_ok(), r#"{"ok":true}"#);
        assert_eq!(
            reply_reloaded(2, 10, 4),
            r#"{"ok":true,"generation":2,"k":10,"d":4}"#
        );
        let err = reply_error(&ProtoError::new(code::OVERLOADED, "queue full"));
        assert_eq!(
            err,
            r#"{"ok":false,"error":"overloaded","message":"queue full"}"#
        );
        assert_eq!(
            reply_bulk_header(100, 4, 32),
            r#"{"ok":true,"streaming":true,"n":100,"d":4,"block_rows":32}"#
        );
        assert_eq!(
            reply_bulk_block(64, &[7, 8]),
            r#"{"lo":64,"labels":[7,8]}"#
        );
        let io = crate::metrics::IoTelemetry {
            blocks_leased: 3,
            bytes_read: 4096,
            window_refills: 1,
        };
        assert_eq!(
            reply_bulk_trailer(3, 100, Some(&io)),
            r#"{"done":true,"blocks":3,"rows":100,"io":{"blocks_leased":3,"bytes_read":4096,"window_refills":1}}"#
        );
        assert_eq!(
            reply_bulk_trailer(1, 2, None),
            r#"{"done":true,"blocks":1,"rows":2,"io":null}"#
        );
        // every reply round-trips through the parser (clients can rely
        // on it) and never contains a raw newline
        for reply in [
            reply_labels(&[0]),
            reply_nearest(0, 1.0),
            reply_stats(Json::obj().field("requests", 1u64)),
            reply_ok(),
            reply_bulk_header(1, 1, 1),
            reply_bulk_block(0, &[0]),
            reply_bulk_trailer(1, 1, None),
            err,
        ] {
            assert!(!reply.contains('\n'));
            assert!(Json::parse(&reply).is_ok());
        }
    }

    #[test]
    fn predict_row_values_roundtrip_bit_identically() {
        // the client writes rows with the shortest-roundtrip formatter;
        // the server must read back the same bits (serving equals local
        // predict only if the wire is lossless)
        let vals = [0.1, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE, 123456789.125];
        let line = Json::obj()
            .field("op", "predict")
            .field(
                "rows",
                Json::Arr(vec![Json::Arr(vals.iter().map(|&v| Json::Num(v)).collect())]),
            )
            .to_string();
        match parse_request(&line, &net()).unwrap() {
            Request::Predict { rows, .. } => {
                let bits = |xs: &[f64]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&rows), bits(&vals));
            }
            other => panic!("{other:?}"),
        }
    }
}
