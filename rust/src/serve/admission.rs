//! Per-client admission control: token-bucket rate limiting and a
//! trip-after-consecutive-failures circuit breaker, sitting *in front*
//! of the bounded request queue.
//!
//! The queue protects the server's memory; admission control protects
//! its **fairness**. One abusive client — flooding requests, or sending
//! a stream of malformed/failing ones — would otherwise consume the
//! acceptor budget and queue slots that well-behaved clients need.
//! Admission keys clients (by peer IP, or per connection — see
//! [`KeyBy`]) and answers over-limit traffic with typed replies the
//! client can act on:
//!
//! * **rate limiting** — each key owns a token bucket refilled at
//!   [`rate_limit`](AdmissionConfig::rate_limit) requests/second up to
//!   [`burst`](AdmissionConfig::burst) tokens; a request with no token
//!   available is refused with `rate_limited` (HTTP 429) and a
//!   retry-after hint. Tokens refill continuously, so a client that
//!   paces itself to the configured rate is never refused.
//! * **circuit breaking** — [`breaker_fails`](AdmissionConfig::breaker_fails)
//!   *consecutive* failed requests (malformed lines, dimension
//!   mismatches, failed reloads) trip the key's breaker **open**:
//!   requests are refused with `breaker_open` (HTTP 503) for
//!   [`breaker_cooldown`](AdmissionConfig::breaker_cooldown). After the
//!   cooldown the breaker goes **half-open**: exactly one probe request
//!   is admitted; success closes the breaker, failure re-opens it for
//!   another cooldown. Any success resets the consecutive-failure
//!   count.
//!
//! Both layers are off by default (`rate_limit == 0.0`,
//! `breaker_fails == 0`) so embedders opt in per deployment; the CLI
//! knobs are `--rate-limit`, `--rate-burst`, `--breaker-fails`,
//! `--breaker-cooldown-ms`, and `--admission-key`.
//!
//! Decisions are made under one mutex over a small per-key state map.
//! Admission is amortised O(1) per request: idle keys are pruned at
//! most once per few seconds, and when the map hits a hard cap (8192
//! keys) the older half is evicted in one pass before inserting, so an
//! address-rotating flood can neither grow the map unboundedly nor
//! force a full-map scan on every new key.

use std::collections::HashMap;
use std::net::{IpAddr, SocketAddr};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// How admission state is keyed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KeyBy {
    /// Per peer IP address (the production default): every connection
    /// from one host shares one bucket and one breaker, so a client
    /// cannot escape its budget by reconnecting.
    Ip,
    /// Per TCP connection: each accepted connection gets its own
    /// bucket/breaker. For trusted multi-tenant proxies (all peers
    /// share one IP) and for tests, where every client is loopback.
    Conn,
}

impl KeyBy {
    /// Parse a CLI value (`ip` | `conn`).
    pub fn parse(s: &str) -> Option<KeyBy> {
        match s {
            "ip" => Some(KeyBy::Ip),
            "conn" => Some(KeyBy::Conn),
            _ => None,
        }
    }
}

/// Admission knobs, embedded in
/// [`ServeConfig`](crate::serve::ServeConfig). The default disables
/// both layers.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Sustained request budget per client key, requests/second.
    /// `0.0` disables rate limiting.
    pub rate_limit: f64,
    /// Token-bucket capacity: how many requests a key may burst above
    /// the sustained rate. Clamped to at least 1 token when rate
    /// limiting is on.
    pub burst: f64,
    /// Consecutive failures that trip a key's circuit breaker.
    /// `0` disables the breaker.
    pub breaker_fails: u32,
    /// How long a tripped breaker stays open before admitting one
    /// half-open probe request.
    pub breaker_cooldown: Duration,
    /// What identifies a client (IP or connection).
    pub key_by: KeyBy,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            rate_limit: 0.0,
            burst: 8.0,
            breaker_fails: 0,
            breaker_cooldown: Duration::from_secs(1),
            key_by: KeyBy::Ip,
        }
    }
}

impl AdmissionConfig {
    /// True when both layers are disabled (the default) — the server
    /// skips admission entirely.
    pub fn is_disabled(&self) -> bool {
        self.rate_limit <= 0.0 && self.breaker_fails == 0
    }
}

/// What a client key resolves to — opaque to callers; obtained from
/// [`Admission::key_for`] once per connection.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ClientKey(KeyRepr);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum KeyRepr {
    Ip(IpAddr),
    Conn(u64),
}

/// The verdict for one request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Decision {
    /// Serve it.
    Admit,
    /// Token bucket empty — refuse with `rate_limited` and this
    /// retry-after hint.
    RateLimited(Duration),
    /// Breaker open — refuse with `breaker_open` and this retry-after
    /// hint (the remaining cooldown).
    BreakerOpen(Duration),
}

/// Per-key bucket + breaker state.
struct ClientState {
    /// Tokens currently in the bucket.
    tokens: f64,
    /// When the bucket was last refilled.
    refilled: Instant,
    /// Consecutive failed requests (reset by any success).
    fails: u32,
    /// `Some(when)` while the breaker is open; half-open after
    /// `when + cooldown`.
    opened: Option<Instant>,
    /// `Some(started)` while a half-open probe is in flight — further
    /// requests are refused until its outcome arrives, or until it is
    /// one cooldown stale (a probe whose outcome never comes back must
    /// not wedge the breaker open forever).
    probing: Option<Instant>,
    /// For pruning idle keys.
    last_seen: Instant,
}

impl ClientState {
    fn new(cfg: &AdmissionConfig, now: Instant) -> ClientState {
        ClientState {
            // a fresh key starts with a full bucket
            tokens: cfg.burst.max(1.0),
            refilled: now,
            fails: 0,
            opened: None,
            probing: None,
            last_seen: now,
        }
    }
}

/// Prune idle keys once the map holds this many.
const PRUNE_AT: usize = 4096;

/// A key idle this long is forgotten (its bucket would be full and its
/// breaker cooled down anyway).
const IDLE_HORIZON: Duration = Duration::from_secs(300);

/// Idle pruning runs at most this often — a rotating-key flood whose
/// entries are all recently seen must not pay a full-map scan that
/// removes nothing on every new key.
const PRUNE_INTERVAL: Duration = Duration::from_secs(5);

/// Hard cap on tracked keys. Inserting a new key at the cap first
/// evicts the older half of the map (by `last_seen`) in one pass, so
/// the scan cost is amortised O(1) per insert and the map is bounded
/// even when every entry is fresh.
const HARD_CAP: usize = 8192;

/// The per-key state map plus prune bookkeeping, all under one mutex.
struct ClientMap {
    map: HashMap<ClientKey, ClientState>,
    /// When the last idle prune ran (rate-limits the scan).
    last_prune: Option<Instant>,
}

/// The shared admission gate: one per server, consulted by every
/// acceptor before a request touches the queue or an op handler.
pub struct Admission {
    cfg: AdmissionConfig,
    clients: Mutex<ClientMap>,
    next_conn: AtomicU64,
}

impl Admission {
    /// Build a gate from its config.
    pub fn new(cfg: AdmissionConfig) -> Admission {
        Admission {
            cfg,
            clients: Mutex::new(ClientMap {
                map: HashMap::new(),
                last_prune: None,
            }),
            next_conn: AtomicU64::new(0),
        }
    }

    /// The config this gate was built with.
    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Resolve the admission key for a new connection — per peer IP or
    /// per connection, as configured. Call once at accept time.
    pub fn key_for(&self, peer: Option<SocketAddr>) -> ClientKey {
        match (self.cfg.key_by, peer) {
            (KeyBy::Ip, Some(addr)) => ClientKey(KeyRepr::Ip(addr.ip())),
            // no peer address (already disconnected) or per-connection
            // keying: a fresh id, never shared
            _ => ClientKey(KeyRepr::Conn(
                self.next_conn.fetch_add(1, Ordering::Relaxed),
            )),
        }
    }

    /// Decide one request for `key`. Consumes a token when admitted.
    pub fn check(&self, key: ClientKey) -> Decision {
        if self.cfg.is_disabled() {
            return Decision::Admit;
        }
        self.check_at(key, Instant::now())
    }

    /// Report the outcome of an admitted request: failures count toward
    /// the breaker threshold, success resets it (and closes an open
    /// breaker after a successful half-open probe).
    pub fn outcome(&self, key: ClientKey, success: bool) {
        if self.cfg.breaker_fails == 0 {
            return;
        }
        let now = Instant::now();
        let mut clients = self.clients.lock().expect("admission poisoned");
        let state = clients
            .map
            .entry(key)
            .or_insert_with(|| ClientState::new(&self.cfg, now));
        state.last_seen = now;
        if success {
            state.fails = 0;
            state.opened = None;
            state.probing = None;
        } else {
            state.fails = state.fails.saturating_add(1);
            if state.probing.is_some() || state.fails >= self.cfg.breaker_fails {
                // trip (or re-trip after a failed probe): refuse until
                // the cooldown elapses again
                state.opened = Some(now);
                state.probing = None;
                state.fails = 0;
            }
        }
    }

    /// Report that an admitted request ended without a breaker verdict
    /// (overloaded, shutting down, peer gone mid-reply). If that
    /// request was the half-open probe, this releases the probe slot —
    /// counting neither success nor failure — and re-arms the cooldown,
    /// so the next probe waits out the overload instead of the breaker
    /// wedging open with an outcome that never arrives.
    pub fn probe_aborted(&self, key: ClientKey) {
        if self.cfg.breaker_fails == 0 {
            return;
        }
        let now = Instant::now();
        let mut clients = self.clients.lock().expect("admission poisoned");
        if let Some(state) = clients.map.get_mut(&key) {
            state.last_seen = now;
            if state.probing.is_some() {
                state.probing = None;
                state.opened = Some(now);
            }
        }
    }

    /// Testable core of [`check`](Admission::check) with an explicit
    /// clock.
    fn check_at(&self, key: ClientKey, now: Instant) -> Decision {
        let mut guard = self.clients.lock().expect("admission poisoned");
        let clients = &mut *guard;
        if !clients.map.contains_key(&key) {
            if clients.map.len() >= PRUNE_AT
                && clients
                    .last_prune
                    .map_or(true, |at| now.duration_since(at) >= PRUNE_INTERVAL)
            {
                clients.last_prune = Some(now);
                clients
                    .map
                    .retain(|_, s| now.duration_since(s.last_seen) < IDLE_HORIZON);
            }
            if clients.map.len() >= HARD_CAP {
                evict_older_half(&mut clients.map);
            }
        }
        let state = clients
            .map
            .entry(key)
            .or_insert_with(|| ClientState::new(&self.cfg, now));
        state.last_seen = now;
        // breaker first: an open breaker refuses without spending tokens
        if let Some(opened) = state.opened {
            let elapsed = now.duration_since(opened);
            if elapsed < self.cfg.breaker_cooldown {
                return Decision::BreakerOpen(self.cfg.breaker_cooldown - elapsed);
            }
            if let Some(started) = state.probing {
                // one probe at a time; others retry after a cooldown.
                // A probe one full cooldown stale (its outcome lost —
                // e.g. a worker died mid-request) expires and a fresh
                // probe is admitted instead of wedging the key.
                if now.duration_since(started) < self.cfg.breaker_cooldown {
                    return Decision::BreakerOpen(self.cfg.breaker_cooldown);
                }
            }
            state.probing = Some(now);
            // the probe bypasses the bucket: it exists to test recovery
            return Decision::Admit;
        }
        if self.cfg.rate_limit > 0.0 {
            let burst = self.cfg.burst.max(1.0);
            let refill = now.duration_since(state.refilled).as_secs_f64() * self.cfg.rate_limit;
            state.tokens = (state.tokens + refill).min(burst);
            state.refilled = now;
            if state.tokens < 1.0 {
                let wait = (1.0 - state.tokens) / self.cfg.rate_limit;
                return Decision::RateLimited(Duration::from_secs_f64(wait));
            }
            state.tokens -= 1.0;
        }
        Decision::Admit
    }

    /// Number of tracked keys (test observability for the prune/cap).
    #[cfg(test)]
    fn tracked_keys(&self) -> usize {
        self.clients.lock().expect("admission poisoned").map.len()
    }
}

/// Drop the older half of the map by `last_seen` (ties at the median go
/// too). Called only at [`HARD_CAP`]; freeing ~half the slots per scan
/// keeps the per-insert cost amortised O(1) under a key-rotating flood.
fn evict_older_half(map: &mut HashMap<ClientKey, ClientState>) {
    let mut stamps: Vec<Instant> = map.values().map(|s| s.last_seen).collect();
    let mid = stamps.len() / 2;
    let (_, median, _) = stamps.select_nth_unstable(mid);
    let cutoff = *median;
    map.retain(|_, s| s.last_seen > cutoff);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gate(rate: f64, burst: f64, fails: u32, cooldown_ms: u64) -> Admission {
        Admission::new(AdmissionConfig {
            rate_limit: rate,
            burst,
            breaker_fails: fails,
            breaker_cooldown: Duration::from_millis(cooldown_ms),
            key_by: KeyBy::Conn,
        })
    }

    #[test]
    fn disabled_config_admits_everything() {
        let g = Admission::new(AdmissionConfig::default());
        let k = g.key_for(None);
        for _ in 0..10_000 {
            assert_eq!(g.check(k), Decision::Admit);
        }
    }

    #[test]
    fn bucket_allows_burst_then_refuses_then_refills() {
        let g = gate(10.0, 4.0, 0, 0);
        let k = g.key_for(None);
        let t0 = Instant::now();
        for i in 0..4 {
            assert_eq!(g.check_at(k, t0), Decision::Admit, "burst token {i}");
        }
        match g.check_at(k, t0) {
            Decision::RateLimited(wait) => {
                // retry-after ≈ one token at 10/s
                assert!(wait <= Duration::from_millis(101), "{wait:?}");
            }
            other => panic!("{other:?}"),
        }
        // 250 ms later: 2.5 tokens refilled → two more admits
        let t1 = t0 + Duration::from_millis(250);
        assert_eq!(g.check_at(k, t1), Decision::Admit);
        assert_eq!(g.check_at(k, t1), Decision::Admit);
        assert!(matches!(g.check_at(k, t1), Decision::RateLimited(_)));
    }

    #[test]
    fn keys_are_isolated() {
        let g = gate(10.0, 1.0, 0, 0);
        let (a, b) = (g.key_for(None), g.key_for(None));
        assert_ne!(a, b);
        let t0 = Instant::now();
        assert_eq!(g.check_at(a, t0), Decision::Admit);
        assert!(matches!(g.check_at(a, t0), Decision::RateLimited(_)));
        // a's empty bucket must not affect b
        assert_eq!(g.check_at(b, t0), Decision::Admit);
    }

    #[test]
    fn ip_keying_shares_state_across_connections() {
        let g = Admission::new(AdmissionConfig {
            rate_limit: 10.0,
            burst: 1.0,
            key_by: KeyBy::Ip,
            ..AdmissionConfig::default()
        });
        let peer = |port| Some(SocketAddr::from(([192, 0, 2, 7], port)));
        let k1 = g.key_for(peer(1000));
        let k2 = g.key_for(peer(2000));
        // same IP, different source ports → same key (reconnecting does
        // not grant a fresh bucket)
        assert_eq!(k1, k2);
        let t0 = Instant::now();
        assert_eq!(g.check_at(k1, t0), Decision::Admit);
        assert!(matches!(g.check_at(k2, t0), Decision::RateLimited(_)));
    }

    #[test]
    fn breaker_trips_cools_down_and_recovers_through_a_probe() {
        let g = gate(0.0, 1.0, 3, 50);
        let k = g.key_for(None);
        // two failures: still closed
        g.outcome(k, false);
        g.outcome(k, false);
        assert_eq!(g.check(k), Decision::Admit);
        // third consecutive failure trips it
        g.outcome(k, false);
        match g.check(k) {
            Decision::BreakerOpen(wait) => assert!(wait <= Duration::from_millis(50)),
            other => panic!("{other:?}"),
        }
        // cooldown elapses → exactly one half-open probe is admitted
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(g.check(k), Decision::Admit);
        assert!(matches!(g.check(k), Decision::BreakerOpen(_)));
        // the probe succeeds → closed, traffic flows again
        g.outcome(k, true);
        assert_eq!(g.check(k), Decision::Admit);
    }

    #[test]
    fn failed_probe_reopens_the_breaker() {
        let g = gate(0.0, 1.0, 1, 40);
        let k = g.key_for(None);
        g.outcome(k, false); // threshold 1: trips immediately
        assert!(matches!(g.check(k), Decision::BreakerOpen(_)));
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(g.check(k), Decision::Admit); // the probe
        g.outcome(k, false); // probe failed → open again, full cooldown
        assert!(matches!(g.check(k), Decision::BreakerOpen(_)));
    }

    #[test]
    fn aborted_probe_does_not_wedge_the_breaker() {
        let g = gate(0.0, 1.0, 1, 40);
        let k = g.key_for(None);
        g.outcome(k, false); // trips
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(g.check(k), Decision::Admit); // the probe
        // the probe hits overload/shutdown: no verdict, only an abort.
        // The cooldown re-arms, then a FRESH probe must be admitted.
        g.probe_aborted(k);
        assert!(matches!(g.check(k), Decision::BreakerOpen(_)));
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(g.check(k), Decision::Admit);
        // and the new probe's success closes the breaker normally
        g.outcome(k, true);
        assert_eq!(g.check(k), Decision::Admit);
    }

    #[test]
    fn stale_probe_expires_instead_of_wedging() {
        let g = gate(0.0, 1.0, 1, 1000);
        let k = g.key_for(None);
        let t0 = Instant::now();
        g.outcome(k, false); // trips at ~t0 (outcome uses the real clock)
        let t1 = t0 + Duration::from_millis(1100);
        assert_eq!(g.check_at(k, t1), Decision::Admit); // probe starts
        // within one cooldown of the probe start, others are refused
        let t2 = t1 + Duration::from_millis(500);
        assert!(matches!(g.check_at(k, t2), Decision::BreakerOpen(_)));
        // the outcome never arrives; one full cooldown later the stale
        // probe expires and a fresh one is admitted
        let t3 = t1 + Duration::from_millis(1100);
        assert_eq!(g.check_at(k, t3), Decision::Admit);
    }

    #[test]
    fn rotating_key_flood_stays_bounded() {
        let g = gate(100.0, 1.0, 0, 0);
        let t0 = Instant::now();
        // every key is fresh and recently seen: the idle prune removes
        // nothing, so only the hard cap keeps the map bounded
        for i in 0..(3 * HARD_CAP) {
            let k = g.key_for(None);
            let now = t0 + Duration::from_micros(i as u64);
            assert_eq!(g.check_at(k, now), Decision::Admit);
        }
        assert!(
            g.tracked_keys() <= HARD_CAP,
            "map grew past the hard cap: {}",
            g.tracked_keys()
        );
    }

    #[test]
    fn success_resets_the_consecutive_failure_count() {
        let g = gate(0.0, 1.0, 3, 1000);
        let k = g.key_for(None);
        for _ in 0..10 {
            g.outcome(k, false);
            g.outcome(k, false);
            g.outcome(k, true); // never three in a row
        }
        assert_eq!(g.check(k), Decision::Admit);
    }

    #[test]
    fn key_by_parses() {
        assert_eq!(KeyBy::parse("ip"), Some(KeyBy::Ip));
        assert_eq!(KeyBy::parse("conn"), Some(KeyBy::Conn));
        assert_eq!(KeyBy::parse("mac"), None);
    }
}
