//! Shared server state: the hot-reloadable model cell and the serving
//! telemetry counters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::json::Json;
use crate::model::FittedModel;
use crate::obs::{Histogram, HistogramSnapshot};

/// An `ArcSwap`-style cell holding the currently served model.
///
/// Readers take a cheap [`current`](ModelCell::current) snapshot (one
/// mutex-guarded `Arc` clone — the lock is held for the clone only,
/// never across a scan) and keep serving from that snapshot even if a
/// [`swap`](ModelCell::swap) lands mid-batch: reload is zero-downtime
/// and in-flight requests are never dropped, they just finish on
/// whichever model generation their batch picked up.
pub struct ModelCell {
    inner: Mutex<Arc<FittedModel>>,
    generation: AtomicU64,
}

impl ModelCell {
    /// Wrap the initial model (generation 1).
    pub fn new(model: FittedModel) -> ModelCell {
        ModelCell {
            inner: Mutex::new(Arc::new(model)),
            generation: AtomicU64::new(1),
        }
    }

    /// Snapshot the current model.
    pub fn current(&self) -> Arc<FittedModel> {
        self.inner.lock().expect("model cell poisoned").clone()
    }

    /// Swap in a new model, returning the new generation number. Old
    /// snapshots stay valid until their holders drop them.
    pub fn swap(&self, model: FittedModel) -> u64 {
        let mut guard = self.inner.lock().expect("model cell poisoned");
        *guard = Arc::new(model);
        self.generation.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Generation counter: 1 for the startup model, +1 per swap.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }
}

/// Which op a latency observation belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Batched predict.
    Predict,
    /// Single-point nearest.
    Nearest,
    /// Stats snapshot.
    Stats,
    /// Model reload.
    Reload,
    /// Streaming bulk predict over an on-disk source.
    Bulk,
}

/// Lock-free serving counters, shared by acceptors and the batcher.
/// All monotone; [`snapshot`](ServeTelemetry::snapshot) renders a
/// consistent-enough view for the `stats` op and the shutdown summary.
///
/// Per-op latencies are tracked two ways: the original `*_micros` sums
/// (kept for wire compatibility of the `stats` reply's `*_secs`
/// fields) and log-bucketed [`Histogram`]s, from which the snapshot
/// derives per-op mean/p50/p99 and the `/metrics` endpoint renders
/// full bucket series. Histogram recording can be disabled
/// ([`new`](ServeTelemetry::new)) so the serve bench can price the
/// observability overhead; the sums are always recorded.
#[derive(Default)]
pub struct ServeTelemetry {
    /// Histogram recording disabled (`false` — i.e. enabled — by
    /// default and under `Default`).
    hist_off: bool,
    predict_hist: Histogram,
    nearest_hist: Histogram,
    stats_hist: Histogram,
    reload_hist: Histogram,
    bulk_hist: Histogram,
    requests: AtomicU64,
    predicts: AtomicU64,
    nearests: AtomicU64,
    stats_ops: AtomicU64,
    reloads: AtomicU64,
    bad_requests: AtomicU64,
    op_errors: AtomicU64,
    batched_rows: AtomicU64,
    batches: AtomicU64,
    coalesced_batches: AtomicU64,
    queue_full_rejects: AtomicU64,
    rate_limited_rejects: AtomicU64,
    breaker_rejects: AtomicU64,
    http_requests: AtomicU64,
    bulk_predicts: AtomicU64,
    bulk_blocks: AtomicU64,
    bulk_rows: AtomicU64,
    predict_micros: AtomicU64,
    nearest_micros: AtomicU64,
    stats_micros: AtomicU64,
    reload_micros: AtomicU64,
    bulk_micros: AtomicU64,
}

impl ServeTelemetry {
    /// Telemetry with per-op latency histograms on (`record_hist =
    /// true`, also what `Default` gives) or off — the serve bench's
    /// overhead-comparison mode. Counters and latency sums are
    /// recorded either way.
    pub fn new(record_hist: bool) -> ServeTelemetry {
        ServeTelemetry {
            hist_off: !record_hist,
            ..ServeTelemetry::default()
        }
    }

    /// The latency histogram for one op.
    fn op_hist(&self, op: Op) -> &Histogram {
        match op {
            Op::Predict => &self.predict_hist,
            Op::Nearest => &self.nearest_hist,
            Op::Stats => &self.stats_hist,
            Op::Reload => &self.reload_hist,
            Op::Bulk => &self.bulk_hist,
        }
    }

    /// Snapshot one op's latency histogram (empty when histogram
    /// recording is off) — the `/metrics` bucket series.
    pub fn op_histogram(&self, op: Op) -> HistogramSnapshot {
        self.op_hist(op).snapshot()
    }

    /// Count one parsed request of any op.
    pub fn request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one unparseable/invalid request line.
    pub fn bad_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.bad_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one completed op and add its wall latency to that op's sum.
    pub fn op_done(&self, op: Op, latency: Duration) {
        let micros = latency.as_micros().min(u64::MAX as u128) as u64;
        let (count, sum) = match op {
            Op::Predict => (&self.predicts, &self.predict_micros),
            Op::Nearest => (&self.nearests, &self.nearest_micros),
            Op::Stats => (&self.stats_ops, &self.stats_micros),
            Op::Reload => (&self.reloads, &self.reload_micros),
            Op::Bulk => (&self.bulk_predicts, &self.bulk_micros),
        };
        count.fetch_add(1, Ordering::Relaxed);
        sum.fetch_add(micros, Ordering::Relaxed);
        if !self.hist_off {
            self.op_hist(op).record_micros(micros);
        }
    }

    /// Count one well-formed request that failed during execution
    /// (dimension mismatch, reload/model failure) — visible in stats so
    /// a misbehaving client cannot hide in the completed-op counts.
    pub fn op_error(&self) {
        self.op_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one request rejected because the bounded queue was full.
    pub fn queue_full_reject(&self) {
        self.queue_full_rejects.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one request bounced by the per-client token bucket.
    pub fn rate_limited_reject(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.rate_limited_rejects.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one request bounced by an open circuit breaker.
    pub fn breaker_reject(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.breaker_rejects.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one request that arrived via the HTTP shim (also counted
    /// in the per-op counters — this tracks protocol mix).
    pub fn http_request(&self) {
        self.http_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one streamed bulk-predict block of `rows` labels.
    pub fn bulk_block(&self, rows: u64) {
        self.bulk_blocks.fetch_add(1, Ordering::Relaxed);
        self.bulk_rows.fetch_add(rows, Ordering::Relaxed);
    }

    /// Record one executed batch of `rows` total rows covering
    /// `requests` coalesced predict requests.
    pub fn batch_done(&self, requests: u64, rows: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_rows.fetch_add(rows, Ordering::Relaxed);
        if requests > 1 {
            self.coalesced_batches.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Snapshot every counter.
    pub fn snapshot(&self) -> ServeStats {
        let secs = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64 / 1e6;
        ServeStats {
            requests: self.requests.load(Ordering::Relaxed),
            predicts: self.predicts.load(Ordering::Relaxed),
            nearests: self.nearests.load(Ordering::Relaxed),
            stats_ops: self.stats_ops.load(Ordering::Relaxed),
            reloads: self.reloads.load(Ordering::Relaxed),
            bad_requests: self.bad_requests.load(Ordering::Relaxed),
            op_errors: self.op_errors.load(Ordering::Relaxed),
            batched_rows: self.batched_rows.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            coalesced_batches: self.coalesced_batches.load(Ordering::Relaxed),
            queue_full_rejects: self.queue_full_rejects.load(Ordering::Relaxed),
            rate_limited_rejects: self.rate_limited_rejects.load(Ordering::Relaxed),
            breaker_rejects: self.breaker_rejects.load(Ordering::Relaxed),
            http_requests: self.http_requests.load(Ordering::Relaxed),
            bulk_predicts: self.bulk_predicts.load(Ordering::Relaxed),
            bulk_blocks: self.bulk_blocks.load(Ordering::Relaxed),
            bulk_rows: self.bulk_rows.load(Ordering::Relaxed),
            predict_secs: secs(&self.predict_micros),
            nearest_secs: secs(&self.nearest_micros),
            stats_secs: secs(&self.stats_micros),
            reload_secs: secs(&self.reload_micros),
            bulk_secs: secs(&self.bulk_micros),
            predict_latency: OpLatency::from_snapshot(&self.predict_hist.snapshot()),
            nearest_latency: OpLatency::from_snapshot(&self.nearest_hist.snapshot()),
            stats_latency: OpLatency::from_snapshot(&self.stats_hist.snapshot()),
            reload_latency: OpLatency::from_snapshot(&self.reload_hist.snapshot()),
            bulk_latency: OpLatency::from_snapshot(&self.bulk_hist.snapshot()),
        }
    }
}

/// Server-side derived latency view of one op, computed from its
/// log-bucketed histogram at snapshot time: clients get mean/p50/p99
/// without shipping bucket arrays over the `stats` reply. Quantiles
/// are bucket upper bounds (µs) per
/// [`HistogramSnapshot::quantile`]; all zeros when no ops completed or
/// histogram recording is off.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpLatency {
    /// Mean latency, µs.
    pub mean_micros: f64,
    /// Median latency — the upper bound (µs) of the bucket holding it.
    pub p50_micros: u64,
    /// 99th-percentile latency, same bucket-upper-bound convention.
    pub p99_micros: u64,
}

impl OpLatency {
    fn from_snapshot(s: &HistogramSnapshot) -> OpLatency {
        OpLatency {
            mean_micros: s.mean_micros(),
            p50_micros: s.quantile(0.5),
            p99_micros: s.quantile(0.99),
        }
    }
}

/// A point-in-time view of [`ServeTelemetry`] — the payload of the
/// `stats` op and of the clean-shutdown summary.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Request lines received (including invalid ones).
    pub requests: u64,
    /// Completed predict ops.
    pub predicts: u64,
    /// Completed nearest ops.
    pub nearests: u64,
    /// Completed stats ops.
    pub stats_ops: u64,
    /// Completed (successful) reload ops.
    pub reloads: u64,
    /// Request lines rejected as malformed/over-limit.
    pub bad_requests: u64,
    /// Well-formed requests that failed during execution (dimension
    /// mismatch, reload failure).
    pub op_errors: u64,
    /// Query rows that went through the micro-batcher.
    pub batched_rows: u64,
    /// Pool scans the batcher executed.
    pub batches: u64,
    /// Batches that coalesced more than one request into one scan.
    pub coalesced_batches: u64,
    /// Predict requests bounced with the typed `overloaded` reply.
    pub queue_full_rejects: u64,
    /// Requests bounced with the typed `rate_limited` reply (429).
    pub rate_limited_rejects: u64,
    /// Requests bounced with the typed `breaker_open` reply (503).
    pub breaker_rejects: u64,
    /// Requests that arrived via the HTTP shim (protocol mix).
    pub http_requests: u64,
    /// Completed bulk-predict streams.
    pub bulk_predicts: u64,
    /// Label blocks streamed by bulk predicts.
    pub bulk_blocks: u64,
    /// Rows labelled by bulk predicts.
    pub bulk_rows: u64,
    /// Summed predict latency (enqueue → reply handed back), seconds.
    pub predict_secs: f64,
    /// Summed nearest latency, seconds.
    pub nearest_secs: f64,
    /// Summed stats latency, seconds.
    pub stats_secs: f64,
    /// Summed reload latency, seconds.
    pub reload_secs: f64,
    /// Summed bulk-predict stream latency (open → trailer), seconds.
    pub bulk_secs: f64,
    /// Histogram-derived predict latency (mean/p50/p99).
    pub predict_latency: OpLatency,
    /// Histogram-derived nearest latency.
    pub nearest_latency: OpLatency,
    /// Histogram-derived stats latency.
    pub stats_latency: OpLatency,
    /// Histogram-derived reload latency.
    pub reload_latency: OpLatency,
    /// Histogram-derived bulk-predict latency.
    pub bulk_latency: OpLatency,
}

impl ServeStats {
    /// JSON rendering used by the `stats` op.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("requests", self.requests)
            .field("predicts", self.predicts)
            .field("nearests", self.nearests)
            .field("stats_ops", self.stats_ops)
            .field("reloads", self.reloads)
            .field("bad_requests", self.bad_requests)
            .field("op_errors", self.op_errors)
            .field("batched_rows", self.batched_rows)
            .field("batches", self.batches)
            .field("coalesced_batches", self.coalesced_batches)
            .field("queue_full_rejects", self.queue_full_rejects)
            .field("rate_limited_rejects", self.rate_limited_rejects)
            .field("breaker_rejects", self.breaker_rejects)
            .field("http_requests", self.http_requests)
            .field("bulk_predicts", self.bulk_predicts)
            .field("bulk_blocks", self.bulk_blocks)
            .field("bulk_rows", self.bulk_rows)
            .field("predict_secs", self.predict_secs)
            .field("nearest_secs", self.nearest_secs)
            .field("stats_secs", self.stats_secs)
            .field("reload_secs", self.reload_secs)
            .field("bulk_secs", self.bulk_secs)
            .field("predict_mean_micros", self.predict_latency.mean_micros)
            .field("predict_p50_micros", self.predict_latency.p50_micros)
            .field("predict_p99_micros", self.predict_latency.p99_micros)
            .field("nearest_mean_micros", self.nearest_latency.mean_micros)
            .field("nearest_p50_micros", self.nearest_latency.p50_micros)
            .field("nearest_p99_micros", self.nearest_latency.p99_micros)
            .field("stats_mean_micros", self.stats_latency.mean_micros)
            .field("stats_p50_micros", self.stats_latency.p50_micros)
            .field("stats_p99_micros", self.stats_latency.p99_micros)
            .field("reload_mean_micros", self.reload_latency.mean_micros)
            .field("reload_p50_micros", self.reload_latency.p50_micros)
            .field("reload_p99_micros", self.reload_latency.p99_micros)
            .field("bulk_mean_micros", self.bulk_latency.mean_micros)
            .field("bulk_p50_micros", self.bulk_latency.p50_micros)
            .field("bulk_p99_micros", self.bulk_latency.p99_micros)
    }

    /// The one-line clean-shutdown summary.
    pub fn summary_line(&self, uptime: Duration) -> String {
        format!(
            "serve: {} requests ({} predict / {} nearest / {} stats / {} reload / {} bulk, \
             {} bad, {} failed, {} http) — {} batches ({} coalesced, {} rows), \
             {} overloaded, {} rate-limited, {} breaker, bulk {} rows in {} blocks, \
             predict {:.3}s total, up {:.1}s",
            self.requests,
            self.predicts,
            self.nearests,
            self.stats_ops,
            self.reloads,
            self.bulk_predicts,
            self.bad_requests,
            self.op_errors,
            self.http_requests,
            self.batches,
            self.coalesced_batches,
            self.batched_rows,
            self.queue_full_rejects,
            self.rate_limited_rejects,
            self.breaker_rejects,
            self.bulk_rows,
            self.bulk_blocks,
            self.predict_secs,
            uptime.as_secs_f64(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::model::Kmeans;
    use crate::runtime::Runtime;

    fn tiny_model(k: usize, seed: u64) -> FittedModel {
        let rt = Runtime::serial();
        let ds = blobs(120, 3, k, 0.1, seed);
        Kmeans::new(k).seed(seed).fit(&rt, &ds).unwrap()
    }

    #[test]
    fn swap_bumps_generation_and_keeps_old_snapshots_alive() {
        let cell = ModelCell::new(tiny_model(3, 1));
        assert_eq!(cell.generation(), 1);
        let old = cell.current();
        assert_eq!(old.k(), 3);
        let g = cell.swap(tiny_model(5, 2));
        assert_eq!(g, 2);
        assert_eq!(cell.generation(), 2);
        // an in-flight holder still sees the old model, bit for bit
        assert_eq!(old.k(), 3);
        assert_eq!(cell.current().k(), 5);
    }

    #[test]
    fn telemetry_counts_and_snapshots() {
        let tel = ServeTelemetry::default();
        tel.request();
        tel.op_done(Op::Predict, Duration::from_micros(1500));
        tel.request();
        tel.op_done(Op::Nearest, Duration::from_micros(500));
        tel.bad_request();
        tel.queue_full_reject();
        tel.op_error();
        tel.batch_done(3, 12);
        tel.batch_done(1, 4);
        tel.rate_limited_reject();
        tel.breaker_reject();
        tel.http_request();
        tel.bulk_block(8);
        tel.bulk_block(3);
        tel.op_done(Op::Bulk, Duration::from_micros(2000));
        let s = tel.snapshot();
        assert_eq!(s.requests, 5);
        assert_eq!(s.predicts, 1);
        assert_eq!(s.nearests, 1);
        assert_eq!(s.bad_requests, 1);
        assert_eq!(s.op_errors, 1);
        assert_eq!(s.queue_full_rejects, 1);
        assert_eq!(s.rate_limited_rejects, 1);
        assert_eq!(s.breaker_rejects, 1);
        assert_eq!(s.http_requests, 1);
        assert_eq!(s.bulk_predicts, 1);
        assert_eq!(s.bulk_blocks, 2);
        assert_eq!(s.bulk_rows, 11);
        assert_eq!(s.batches, 2);
        assert_eq!(s.coalesced_batches, 1);
        assert_eq!(s.batched_rows, 16);
        assert!((s.predict_secs - 0.0015).abs() < 1e-9);
        assert!((s.bulk_secs - 0.002).abs() < 1e-9);
        // histogram-derived views: 1500 µs lands in the ≤2048 bucket
        assert!((s.predict_latency.mean_micros - 1500.0).abs() < 1e-9);
        assert_eq!(s.predict_latency.p50_micros, 2048);
        assert_eq!(s.predict_latency.p99_micros, 2048);
        assert_eq!(s.nearest_latency.p50_micros, 512);
        assert_eq!(tel.op_histogram(Op::Predict).count, 1);
        let json = s.to_json().to_string();
        assert!(json.contains("\"predict_p50_micros\":2048"), "{json}");
        assert!(json.contains("\"batched_rows\":16"), "{json}");
        assert!(json.contains("\"rate_limited_rejects\":1"), "{json}");
        assert!(json.contains("\"breaker_rejects\":1"), "{json}");
        assert!(json.contains("\"bulk_rows\":11"), "{json}");
        let line = s.summary_line(Duration::from_secs(2));
        assert!(line.contains("5 requests"), "{line}");
        assert!(line.contains("1 overloaded"), "{line}");
        assert!(line.contains("1 rate-limited"), "{line}");
    }

    #[test]
    fn histogram_recording_can_be_disabled_without_losing_sums() {
        let tel = ServeTelemetry::new(false);
        tel.request();
        tel.op_done(Op::Predict, Duration::from_micros(1500));
        let s = tel.snapshot();
        assert_eq!(s.predicts, 1);
        // sums stay (wire compat); histogram-derived views read zero
        assert!((s.predict_secs - 0.0015).abs() < 1e-9);
        assert_eq!(s.predict_latency, OpLatency::default());
        assert_eq!(tel.op_histogram(Op::Predict).count, 0);
    }
}
