//! Shared networking primitives used by every TCP front-end.
//!
//! [`frame`] holds the two framing disciplines the crate speaks on a
//! socket — capped line reads (the [`serve`](crate::serve) line-JSON
//! protocol) and capped length-prefixed binary frames (the
//! [`dist`](crate::dist) wire protocol) — behind one hostile-input
//! implementation: byte caps before allocation, read timeouts surfaced
//! as `Idle` so callers can poll shutdown flags, and EOF/garbage as
//! typed outcomes instead of panics.

pub mod frame;

pub use frame::{send_frame, send_line, Frame, FrameReader, Line, LineReader};
