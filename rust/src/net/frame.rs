//! Socket framing: capped incremental line reads and length-prefixed
//! binary frames.
//!
//! Both readers share the same discipline for untrusted peers:
//!
//! * **caps before allocation** — a line or frame longer than the
//!   configured cap is rejected (`TooLong`) without buffering it;
//! * **timeouts as `Idle`** — a blocking read that times out (the
//!   stream's read timeout) returns `Idle` so the caller can re-check
//!   its shutdown flag and deadline instead of pinning a thread;
//! * **EOF and transport errors as `Eof`** — the connection is simply
//!   over; no error values to thread through hot loops.
//!
//! [`LineReader`] frames `\n`-terminated text (the serve protocol);
//! [`FrameReader`] frames `u32-LE length | u8 tag | body` binary
//! messages (the dist wire protocol). Bytes after a terminator are kept
//! for the next call, so pipelined peers work with either.

use std::io::{Read, Write};
use std::time::Instant;

/// One framed line off the socket.
pub enum Line {
    /// A complete request line (without the terminator).
    Msg(String),
    /// Read timeout — poll the shutdown flag and retry.
    Idle,
    /// Peer closed (or errored); drop the connection.
    Eof,
    /// Line exceeded the byte cap; reply typed and drop the connection
    /// (framing is lost once a line is abandoned mid-way).
    TooLong,
    /// Line bytes were not UTF-8; reply typed, framing stays intact.
    BadUtf8,
}

/// Incremental, capped line framing over a blocking stream with a read
/// timeout. Bytes after a newline are kept for the next call, so
/// pipelined clients work.
pub struct LineReader<S> {
    stream: S,
    buf: Vec<u8>,
    cap: usize,
}

impl<S: Read> LineReader<S> {
    /// Wrap `stream`, rejecting lines longer than `cap` bytes.
    pub fn new(stream: S, cap: usize) -> Self {
        LineReader {
            stream,
            buf: Vec::new(),
            cap,
        }
    }

    /// As [`new`](LineReader::new), but seed the buffer with bytes the
    /// caller already read off the stream — the protocol-sniffing path:
    /// a server peeks at a connection's first bytes to pick a protocol,
    /// then hands them to the reader it chose so no byte is lost.
    pub fn with_buffered(stream: S, cap: usize, buffered: Vec<u8>) -> Self {
        LineReader {
            stream,
            buf: buffered,
            cap,
        }
    }

    /// Read until a complete line, the byte cap, EOF, or `deadline`.
    /// The deadline is checked after every read, so a peer trickling
    /// bytes without ever completing a line still returns `Idle` (and
    /// gets reaped by the caller's idle timeout) instead of pinning the
    /// thread — callers cap the deadline at their shutdown-poll cadence
    /// so the flag is re-checked no matter what the peer sends.
    pub fn next_line(&mut self, deadline: Instant) -> Line {
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                // the cap is on the line, not the buffer: a too-long
                // line is rejected even when its terminator has already
                // arrived
                if pos > self.cap {
                    return Line::TooLong;
                }
                let mut line: Vec<u8> = self.buf.drain(..=pos).collect();
                line.pop(); // the \n
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                return match String::from_utf8(line) {
                    Ok(s) => Line::Msg(s),
                    Err(_) => Line::BadUtf8,
                };
            }
            if self.buf.len() > self.cap {
                return Line::TooLong;
            }
            if Instant::now() >= deadline {
                return Line::Idle;
            }
            let mut chunk = [0u8; 4096];
            match self.stream.read(&mut chunk) {
                Ok(0) => return Line::Eof,
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Line::Idle
                }
                Err(_) => return Line::Eof,
            }
        }
    }
}

/// Write one reply line; `false` means the peer is gone.
pub fn send_line<W: Write>(stream: &mut W, reply: &str) -> bool {
    let mut framed = String::with_capacity(reply.len() + 1);
    framed.push_str(reply);
    framed.push('\n');
    stream.write_all(framed.as_bytes()).is_ok() && stream.flush().is_ok()
}

/// One binary frame off the socket.
pub enum Frame {
    /// A complete frame: tag byte + body.
    Msg(u8, Vec<u8>),
    /// Read timeout — poll the shutdown flag and retry.
    Idle,
    /// Peer closed (or errored); drop the connection.
    Eof,
    /// Declared frame length was zero or exceeded the cap; drop the
    /// connection (framing is unrecoverable once a length is bogus).
    TooLong,
}

/// Incremental, capped binary framing: `u32-LE length | u8 tag | body`,
/// where `length` counts the tag byte plus the body. Same timeout and
/// cap discipline as [`LineReader`].
pub struct FrameReader<S> {
    stream: S,
    buf: Vec<u8>,
    cap: usize,
}

impl<S: Read> FrameReader<S> {
    /// Wrap `stream`, rejecting frames whose declared length (tag +
    /// body) exceeds `cap` bytes.
    pub fn new(stream: S, cap: usize) -> Self {
        FrameReader {
            stream,
            buf: Vec::new(),
            cap,
        }
    }

    /// Read until a complete frame, a bogus length, EOF, or `deadline`.
    pub fn next_frame(&mut self, deadline: Instant) -> Frame {
        loop {
            if self.buf.len() >= 4 {
                let len = u32::from_le_bytes(self.buf[..4].try_into().expect("4 bytes")) as usize;
                // a frame always carries its tag byte; a zero length is
                // as malformed as an oversized one — and the check runs
                // before any body bytes are buffered, so a hostile
                // length never drives an allocation
                if len == 0 || len > self.cap {
                    return Frame::TooLong;
                }
                if self.buf.len() >= 4 + len {
                    let mut frame: Vec<u8> = self.buf.drain(..4 + len).collect();
                    let tag = frame[4];
                    frame.drain(..5);
                    return Frame::Msg(tag, frame);
                }
            }
            if Instant::now() >= deadline {
                return Frame::Idle;
            }
            let mut chunk = [0u8; 4096];
            match self.stream.read(&mut chunk) {
                Ok(0) => return Frame::Eof,
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Frame::Idle
                }
                Err(_) => return Frame::Eof,
            }
        }
    }
}

/// Write one `tag + body` frame; `false` means the peer is gone.
pub fn send_frame<W: Write>(stream: &mut W, tag: u8, body: &[u8]) -> bool {
    let len = (body.len() + 1) as u32;
    stream.write_all(&len.to_le_bytes()).is_ok()
        && stream.write_all(&[tag]).is_ok()
        && stream.write_all(body).is_ok()
        && stream.flush().is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// An in-memory stream that yields its script one piece per read —
    /// exercises partial arrival — then reports EOF.
    struct Script {
        pieces: Vec<Vec<u8>>,
        next: usize,
    }

    impl Script {
        fn new(pieces: Vec<Vec<u8>>) -> Self {
            Script { pieces, next: 0 }
        }
    }

    impl Read for Script {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.next >= self.pieces.len() {
                return Ok(0);
            }
            let piece = &self.pieces[self.next];
            self.next += 1;
            out[..piece.len()].copy_from_slice(piece);
            Ok(piece.len())
        }
    }

    fn soon() -> Instant {
        Instant::now() + Duration::from_secs(5)
    }

    #[test]
    fn line_reader_frames_and_pipelines() {
        let mut r = LineReader::new(Script::new(vec![b"hel".to_vec(), b"lo\nwor".to_vec(), b"ld\n".to_vec()]), 64);
        match r.next_line(soon()) {
            Line::Msg(s) => assert_eq!(s, "hello"),
            _ => panic!("want Msg"),
        }
        match r.next_line(soon()) {
            Line::Msg(s) => assert_eq!(s, "world"),
            _ => panic!("want Msg"),
        }
        assert!(matches!(r.next_line(soon()), Line::Eof));
    }

    #[test]
    fn line_reader_with_buffered_replays_sniffed_bytes() {
        // bytes a sniffer consumed before choosing the protocol must be
        // replayed ahead of anything still on the stream
        let mut r = LineReader::with_buffered(
            Script::new(vec![b"lo\nnext\n".to_vec()]),
            64,
            b"hel".to_vec(),
        );
        match r.next_line(soon()) {
            Line::Msg(s) => assert_eq!(s, "hello"),
            _ => panic!("want Msg"),
        }
        match r.next_line(soon()) {
            Line::Msg(s) => assert_eq!(s, "next"),
            _ => panic!("want Msg"),
        }
    }

    #[test]
    fn line_reader_strips_crlf_and_rejects_bad_utf8() {
        let mut r = LineReader::new(Script::new(vec![b"crlf\r\n".to_vec(), vec![0xff, 0xfe, b'\n']]), 64);
        match r.next_line(soon()) {
            Line::Msg(s) => assert_eq!(s, "crlf"),
            _ => panic!("want Msg"),
        }
        assert!(matches!(r.next_line(soon()), Line::BadUtf8));
    }

    #[test]
    fn line_reader_caps_with_and_without_terminator() {
        // terminator present but the line is over the cap
        let mut r = LineReader::new(Script::new(vec![b"0123456789\n".to_vec()]), 4);
        assert!(matches!(r.next_line(soon()), Line::TooLong));
        // no terminator: rejected as soon as the buffer exceeds the cap
        let mut r = LineReader::new(Script::new(vec![vec![b'x'; 100]]), 4);
        assert!(matches!(r.next_line(soon()), Line::TooLong));
    }

    #[test]
    fn line_reader_timeout_is_idle() {
        struct Block;
        impl Read for Block {
            fn read(&mut self, _out: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::ErrorKind::WouldBlock.into())
            }
        }
        let mut r = LineReader::new(Block, 64);
        assert!(matches!(r.next_line(soon()), Line::Idle));
        // an already-expired deadline is Idle even before a read
        let mut r = LineReader::new(Script::new(vec![b"late\n".to_vec()]), 64);
        assert!(matches!(
            r.next_line(Instant::now() - Duration::from_secs(1)),
            Line::Idle
        ));
    }

    fn framed(tag: u8, body: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        assert!(send_frame(&mut out, tag, body));
        out
    }

    #[test]
    fn frame_roundtrip_and_pipelining() {
        let mut bytes = framed(7, b"abc");
        bytes.extend(framed(9, b""));
        // deliver byte by byte: reassembly must not care about arrival
        let pieces = bytes.iter().map(|&b| vec![b]).collect();
        let mut r = FrameReader::new(Script::new(pieces), 1024);
        match r.next_frame(soon()) {
            Frame::Msg(tag, body) => {
                assert_eq!(tag, 7);
                assert_eq!(body, b"abc");
            }
            _ => panic!("want Msg"),
        }
        match r.next_frame(soon()) {
            Frame::Msg(tag, body) => {
                assert_eq!(tag, 9);
                assert!(body.is_empty());
            }
            _ => panic!("want Msg"),
        }
        assert!(matches!(r.next_frame(soon()), Frame::Eof));
    }

    #[test]
    fn frame_rejects_hostile_lengths() {
        // zero length (no room for the tag byte)
        let mut r = FrameReader::new(Script::new(vec![0u32.to_le_bytes().to_vec()]), 1024);
        assert!(matches!(r.next_frame(soon()), Frame::TooLong));
        // a 4 GiB declared length is rejected from the header alone —
        // no body bytes are ever buffered
        let mut r = FrameReader::new(Script::new(vec![u32::MAX.to_le_bytes().to_vec()]), 1024);
        assert!(matches!(r.next_frame(soon()), Frame::TooLong));
        // just over the cap
        let mut r = FrameReader::new(Script::new(vec![1025u32.to_le_bytes().to_vec()]), 1024);
        assert!(matches!(r.next_frame(soon()), Frame::TooLong));
    }

    #[test]
    fn frame_truncated_body_is_eof() {
        let mut bytes = framed(3, b"full body");
        bytes.truncate(bytes.len() - 2);
        let mut r = FrameReader::new(Script::new(vec![bytes]), 1024);
        assert!(matches!(r.next_frame(soon()), Frame::Eof));
    }

    #[test]
    fn frame_timeout_is_idle() {
        struct Block;
        impl Read for Block {
            fn read(&mut self, _out: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::ErrorKind::TimedOut.into())
            }
        }
        let mut r = FrameReader::new(Block, 64);
        assert!(matches!(r.next_frame(soon()), Frame::Idle));
    }
}
