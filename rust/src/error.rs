//! Crate-wide error type.

use std::fmt;

/// Errors produced by the eakm library.
#[derive(Debug)]
pub enum EakmError {
    /// Invalid run or experiment configuration (message explains).
    Config(String),
    /// Dataset shape/content problem.
    Data(String),
    /// I/O failure wrapped with context.
    Io(std::io::Error),
    /// XLA/PJRT runtime failure (artifact load, compile, execute).
    Runtime(String),
    /// A configured resource limit was exceeded while reading untrusted
    /// input (payload bytes, nesting depth). Distinct from `Data` so
    /// network front-ends can answer with a typed "too large" error
    /// instead of a generic parse failure.
    Limit(String),
    /// An internal invariant was violated — a bug in eakm itself.
    Invariant(String),
    /// A distributed-fit peer failed: connect refused, read timed out,
    /// or a shard reported an error. The message names the shard
    /// address so a multi-node failure is attributable.
    Net(String),
}

impl fmt::Display for EakmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EakmError::Config(m) => write!(f, "config error: {m}"),
            EakmError::Data(m) => write!(f, "data error: {m}"),
            EakmError::Io(e) => write!(f, "io error: {e}"),
            EakmError::Runtime(m) => write!(f, "runtime error: {m}"),
            EakmError::Limit(m) => write!(f, "limit exceeded: {m}"),
            EakmError::Invariant(m) => write!(f, "invariant violated: {m}"),
            EakmError::Net(m) => write!(f, "net error: {m}"),
        }
    }
}

impl std::error::Error for EakmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            EakmError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for EakmError {
    fn from(e: std::io::Error) -> Self {
        EakmError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, EakmError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        assert!(format!("{}", EakmError::Config("bad k".into())).contains("bad k"));
        assert!(format!("{}", EakmError::Data("empty".into())).contains("empty"));
        assert!(format!("{}", EakmError::Runtime("pjrt".into())).contains("pjrt"));
        assert!(format!("{}", EakmError::Limit("too deep".into())).contains("too deep"));
        assert!(format!("{}", EakmError::Invariant("bound".into())).contains("bound"));
        assert!(format!("{}", EakmError::Net("shard gone".into())).contains("shard gone"));
    }

    #[test]
    fn io_conversion_preserves_source() {
        let e: EakmError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(std::error::Error::source(&e).is_some());
    }
}
