//! The `eakm` command-line interface (hand-rolled parsing — the build is
//! offline and dependency-free beyond the `xla` runtime).
//!
//! ```text
//! eakm run       --dataset birch --k 100 --algorithm exp-ns [--seed 0]
//!                [--threads 1] [--scan-shards N|auto] [--scale 0.02]
//!                [--max-iters N] [--json] [--progress]
//!                [--batch-size B] [--batch-growth F]
//!                [--config file] [--data-file path.csv|.ekb]
//!                [--ooc auto|mmap|chunked] [--ooc-window ROWS]
//!                [--storage f32|f64] [--save-model model.json]
//! eakm predict   --model model.json --data-file points.csv
//!                [--ooc auto|mmap|chunked] [--ooc-window ROWS]
//!                [--threads T|auto] [--out labels.txt] [--json]
//! eakm serve     --model model.json [--addr 127.0.0.1:4999]
//!                [--queue-depth N] [--max-batch ROWS] [--acceptors N]
//!                [--linger-ms M] [--threads T|auto] [--rate-limit R]
//!                [--rate-burst B] [--breaker-fails N]
//!                [--breaker-cooldown-ms M] [--admission-key ip|conn]
//!                [--bulk-block-rows N]
//!                (or fit at startup: the same --dataset/--data-file/
//!                --ooc/--k/--algorithm flags as `run`)
//! eakm shardd    --data file.ekb --rows LO..HI [--addr host:port]
//!                [--threads T|auto] [--ooc auto|mmap|chunked]
//!                [--ooc-window ROWS] [--metrics-addr host:port]
//!                                          # one shard of a distributed fit
//! eakm run       --shards host:port,host:port --k 100 [--algorithm exp-ns]
//!                [--seed 0] [--threads T]  # coordinate a distributed fit
//! eakm datasets  [--scale 0.02]           # list the 22 paper datasets
//! eakm validate  --dataset birch --k 50   # all algorithms must agree
//! eakm grid      [--scale f] [--seeds n] [--k 50,200] [--out dir]
//! eakm help
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::algorithms::Algorithm;
use crate::bench_support::{env_scale, measure, TextTable};
use crate::config::RunConfig;
use crate::coordinator::Runner;
use crate::data::ooc::{open_ooc_described, OocMode};
use crate::data::synth::{find, generate, paper_datasets};
use crate::data::{io, DataSource, Dataset, DatasetF32, ElemWidth};
use crate::error::{EakmError, Result};
use crate::init::InitMethod;
use crate::json::Json;
use crate::model::{FittedModel, Kmeans};
use crate::obs::{FitObserver, TraceId};
use crate::runtime::Runtime;

/// Entry point: parse args (excluding argv[0]) and run.
pub fn main(args: &[String]) -> Result<i32> {
    let (cmd, rest) = match args.split_first() {
        Some((c, rest)) => (c.as_str(), rest),
        None => ("help", &[] as &[String]),
    };
    match cmd {
        "run" => cmd_run(&parse_flags(rest)?),
        "predict" => cmd_predict(&parse_flags(rest)?),
        "serve" => cmd_serve(&parse_flags(rest)?),
        "shardd" => cmd_shardd(&parse_flags(rest)?),
        "datasets" => cmd_datasets(&parse_flags(rest)?),
        "validate" => cmd_validate(&parse_flags(rest)?),
        "grid" => cmd_grid(&parse_flags(rest)?),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(0)
        }
        other => Err(EakmError::Config(format!(
            "unknown command {other:?} — try `eakm help`"
        ))),
    }
}

const HELP: &str = "\
eakm — fast exact k-means with accurate bounds (Newling & Fleuret, ICML 2016)

commands:
  run        cluster one dataset with one algorithm (fit)
  predict    assign new points to a saved model's clusters
  serve      long-lived model server: batching, backpressure, hot reload
  shardd     shard server: own one row range of an .ekb file and serve
             it to a distributed fit (data + compute planes)
  datasets   list the 22 paper datasets (synthetic stand-ins)
  validate   run every algorithm and check they agree exactly
  grid       run the full {dataset × k × algorithm} grid (Tables 9/10)
  help       this text

common flags:
  --dataset NAME     paper dataset name or roman numeral (e.g. birch, iii)
  --data-file PATH   load a .csv or .ekb file instead (alias: --data)
  --ooc MODE         run/predict on an .ekb file *without* loading it:
                     auto (mmap where supported, else chunked), mmap
                     (page-cache-backed mapping), chunked (buffered
                     reads, one resident window per worker). The file
                     is read as-is — run's usual standardisation pass
                     is skipped (standardise at write time if needed).
                     Against the same as-is data, results are
                     bit-identical to an in-memory run at any thread
                     count; a plain `run --data-file` standardises
                     first and therefore differs by design
  --ooc-window ROWS  (with --ooc chunked) resident-window rows per
                     worker (default 8192)
  --storage W        in-memory sample storage width: f64 (default) or
                     f32 — halves memory footprint and scan bandwidth;
                     rows are widened to f64 at the kernel boundary, so
                     all accumulation stays double precision. Invalid
                     with --ooc (an .ekb file's width comes from its
                     header; write f32 files with save_bin_f32)
  --scale F          fraction of the full dataset size (default 0.02)
  --k K              number of clusters
  --algorithm ALG    sta selk elk ham ann exp syin yin selk-ns elk-ns
                     syin-ns exp-ns naive-* auto
  --seed S           RNG seed (default 0)
  --threads T|auto   worker threads for the whole round (default 1;
                     auto = available parallelism)
  --scan-shards N|auto  shards in the over-decomposed scan plan
                     (default auto = derived from n; results are
                     bit-identical at any value — a scheduling knob)
  --max-iters N      round cap
  --batch-size B     (run) mini-batch mode: sample B rows per round
                     instead of scanning everything (B ≥ n stays exact)
  --batch-growth F   (run) nested batch growth per round (default 2.0 =
                     doubling, Newling & Fleuret 2016b); 1.0 redraws a
                     fresh batch each round
  --init M           random | kmeans++
  --json             emit the report as JSON
  --progress         (run) stream one stderr line per round (moved
                     points, mse, distance-calc deltas, straggler
                     ratio) tagged with the fit's trace ID; results
                     are bit-identical with or without it
  --save-model PATH  (run) persist the fitted model as JSON
  --model PATH       (predict/serve) model file written by --save-model
  --out PATH         (predict) write labels here, one per line
                     (default: stdout)

serve flags (requests are line-delimited JSON or HTTP/1.1, sniffed per
connection — POST /v1/predict|nearest|bulk_predict|reload|shutdown and
GET /v1/stats|healthz map onto the same ops; GET /metrics serves the
Prometheus exposition and GET /v1/events?since=N drains the structured
event ring, both bypassing admission control; see docs/PROTOCOLS.md):
  --addr HOST:PORT   bind address (default 127.0.0.1:4999; port 0 =
                     ephemeral)
  --queue-depth N    bounded predict queue; overflow answers a typed
                     \"overloaded\" error instead of queueing (default
                     256; the reject only fires when N < --acceptors —
                     otherwise the acceptor budget is the bound)
  --max-batch ROWS   micro-batcher coalescing cap per scan (default 4096)
  --acceptors N      concurrent connection budget (default 4)
  --linger-ms M      micro-batching window: wait up to M ms to coalesce
                     concurrent requests into one scan (default 0)
  --rate-limit R     per-client admission: sustained requests/second as
                     a token bucket; rejects are typed \"rate_limited\"
                     (HTTP 429 + Retry-After). 0 = off (default)
  --rate-burst B     token-bucket burst capacity (default 8)
  --breaker-fails N  trip a per-client circuit breaker after N
                     consecutive failed requests; rejects are typed
                     \"breaker_open\" (HTTP 503). 0 = off (default)
  --breaker-cooldown-ms M
                     how long a tripped breaker stays open before one
                     half-open probe request (default 1000)
  --admission-key ip|conn
                     what \"per-client\" means for the rate limit and
                     breaker: peer IP (default) or one connection
  --bulk-block-rows N
                     rows per streamed bulk_predict label block when
                     the request leaves it unset (default 8192)
serve answers with a model from --model, or fits one at startup using
the same data flags as run (the two are mutually exclusive); the
\"reload\" op hot-swaps a model JSON with zero downtime, and
\"bulk_predict\" streams labels for a whole on-disk .ekb file. Stop it
with the \"shutdown\" op.

distributed fit (results are bit-identical to single-node):
  eakm shardd --data file.ekb --rows LO..HI [--addr host:port]
             one shard server per row range; every shard has the full
             .ekb file (any filesystem or a copy) and answers only for
             its rows. --threads sizes its local scan pool; --ooc /
             --ooc-window pick how it reads the file (default auto).
             Port 0 binds an ephemeral port. Stays up until killed.
             --metrics-addr binds a second listener that answers
             GET /metrics (Prometheus text) and GET /v1/events; the
             same numbers travel in-band as the STATS wire frame.
  eakm run --shards host:port,host:port --k K [--algorithm ALG] ...
             coordinate a fit across the shard servers, in the order
             given (which must match ascending row ranges). Seeding,
             merging, and the update step run here; assignment scans
             run on the shards. Incompatible with local data flags
             (--dataset/--data-file/--ooc/--storage/--save-model).
             --batch-size B runs the mini-batch engine over the
             network data plane instead.

predict applies the model to the points as given — no standardisation
is re-applied, so feed features in the same space the model was fit on.
";

type Flags = HashMap<String, String>;

fn parse_flags(args: &[String]) -> Result<Flags> {
    let mut flags = Flags::new();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        let key = arg
            .strip_prefix("--")
            .ok_or_else(|| EakmError::Config(format!("expected --flag, got {arg:?}")))?;
        if key == "json" || key == "progress" {
            flags.insert(key.to_string(), "true".to_string());
            continue;
        }
        let value = it
            .next()
            .ok_or_else(|| EakmError::Config(format!("--{key} needs a value")))?;
        flags.insert(key.to_string(), value.clone());
    }
    Ok(flags)
}

fn flag_num<T: std::str::FromStr>(flags: &Flags, key: &str) -> Result<Option<T>> {
    match flags.get(key) {
        None => Ok(None),
        Some(v) => v
            .parse::<T>()
            .map(Some)
            .map_err(|_| EakmError::Config(format!("bad --{key}: {v:?}"))),
    }
}

/// `--data-file` (or its `--data` alias), if given.
fn data_file_flag(flags: &Flags) -> Option<&String> {
    flags.get("data-file").or_else(|| flags.get("data"))
}

/// Open an out-of-core source when `--ooc` is given: the file is
/// clustered/predicted *without loading it* (and without the in-memory
/// standardisation pass — the file is read as-is). `Ok(None)` when the
/// run should use the in-memory path.
fn open_ooc_source(flags: &Flags) -> Result<Option<Box<dyn DataSource>>> {
    let Some(mode_s) = flags.get("ooc") else {
        if flags.contains_key("ooc-window") {
            return Err(EakmError::Config("--ooc-window requires --ooc".into()));
        }
        return Ok(None);
    };
    let mode = OocMode::parse(mode_s)
        .ok_or_else(|| EakmError::Config(format!("bad --ooc: {mode_s:?} (auto|mmap|chunked)")))?;
    let path = data_file_flag(flags)
        .ok_or_else(|| EakmError::Config("--ooc requires --data-file PATH.ekb".into()))?;
    let path = PathBuf::from(path);
    if path.extension().and_then(|e| e.to_str()) != Some("ekb") {
        return Err(EakmError::Config(
            "--ooc needs the binary .ekb format (CSV must be loaded in memory)".into(),
        ));
    }
    let window = flag_num::<usize>(flags, "ooc-window")?.unwrap_or(0);
    // _described: a missing/unreadable file names the path and the
    // backend mode instead of surfacing a bare OS error
    Ok(Some(open_ooc_described(&path, mode, window)?))
}

/// Load the dataset named by the flags. `standardize` applies the
/// paper's zero-mean/unit-variance preprocessing to `--data-file` input
/// (fit path); `predict` passes `false` so points stay in the feature
/// space the model was fitted on.
fn load_dataset(flags: &Flags, standardize: bool) -> Result<Dataset> {
    if let Some(path) = data_file_flag(flags) {
        let path = PathBuf::from(path);
        let mut ds = match path.extension().and_then(|e| e.to_str()) {
            Some("ekb") => io::load_bin(&path)?,
            _ => io::load_csv(&path)?,
        };
        if standardize {
            ds.standardize();
        }
        return Ok(ds);
    }
    let name = flags
        .get("dataset")
        .ok_or_else(|| EakmError::Config("--dataset or --data-file required".into()))?;
    let spec = find(name)
        .ok_or_else(|| EakmError::Config(format!("unknown dataset {name:?} — see `eakm datasets`")))?;
    let scale = flag_num::<f64>(flags, "scale")?.unwrap_or_else(env_scale);
    Ok(generate(&spec, scale, 0x00DA_7A5E))
}

/// Resolve the input rows named by the data flags into one boxed
/// source: the out-of-core path when `--ooc` is given, the in-memory
/// dataset otherwise. The single resolver shared by `run`, `predict`,
/// and `serve`, so `--data`/`--data-file`/`--ooc`/`--ooc-window`
/// behave identically across all three. `standardize` applies only to
/// the in-memory path (out-of-core files are read as-is by design).
fn open_source(flags: &Flags, standardize: bool) -> Result<Box<dyn DataSource>> {
    let storage = match flags.get("storage") {
        None => None,
        Some(s) => Some(
            ElemWidth::parse(s)
                .ok_or_else(|| EakmError::Config(format!("bad --storage: {s:?} (f32|f64)")))?,
        ),
    };
    if storage.is_some() && flags.contains_key("ooc") {
        return Err(EakmError::Config(
            "--storage applies to in-memory sources only; an .ekb file's \
             width comes from its header"
                .into(),
        ));
    }
    if let Some(src) = open_ooc_source(flags)? {
        return Ok(src);
    }
    let ds = load_dataset(flags, standardize)?;
    match storage {
        Some(ElemWidth::F32) => Ok(Box::new(DatasetF32::from_dataset(&ds)?)),
        _ => Ok(Box::new(ds)),
    }
}

/// Parse `--threads T|auto` (returns `None` when the flag is absent).
fn parse_threads(flags: &Flags) -> Result<Option<usize>> {
    match flags.get("threads") {
        None => Ok(None),
        Some(t) if t == "auto" => Ok(Some(crate::config::AUTO_THREADS)),
        Some(t) => {
            let n = t
                .parse::<usize>()
                .map_err(|_| EakmError::Config(format!("bad --threads: {t:?}")))?;
            if n == 0 {
                return Err(EakmError::Config(
                    "--threads must be ≥ 1, or \"auto\"".into(),
                ));
            }
            Ok(Some(n))
        }
    }
}

/// Parse `--scan-shards N|auto` (returns `None` when the flag is
/// absent). Mirrors `--threads`: only the literal "auto" selects the
/// derived-from-`n` geometry.
fn parse_scan_shards(flags: &Flags) -> Result<Option<usize>> {
    match flags.get("scan-shards") {
        None => Ok(None),
        Some(s) if s == "auto" => Ok(Some(crate::coordinator::sched::AUTO_SCAN_SHARDS)),
        Some(s) => {
            let n = s
                .parse::<usize>()
                .map_err(|_| EakmError::Config(format!("bad --scan-shards: {s:?}")))?;
            if n == 0 {
                return Err(EakmError::Config(
                    "--scan-shards must be ≥ 1, or \"auto\"".into(),
                ));
            }
            Ok(Some(n))
        }
    }
}

fn build_config(flags: &Flags) -> Result<RunConfig> {
    let mut cfg = if let Some(path) = flags.get("config") {
        let text = std::fs::read_to_string(path)?;
        RunConfig::from_str_cfg(&text)?
    } else {
        RunConfig::new(Algorithm::Auto, 100)
    };
    if let Some(a) = flags.get("algorithm") {
        cfg.algorithm = Algorithm::parse(a)
            .ok_or_else(|| EakmError::Config(format!("unknown algorithm {a:?}")))?;
    }
    if let Some(k) = flag_num::<usize>(flags, "k")? {
        cfg.k = k;
    }
    if let Some(s) = flag_num::<u64>(flags, "seed")? {
        cfg.seed = s;
    }
    if let Some(t) = parse_threads(flags)? {
        cfg.threads = t;
    }
    if let Some(s) = parse_scan_shards(flags)? {
        cfg.scan_shards = s;
    }
    if let Some(m) = flag_num::<usize>(flags, "max-iters")? {
        cfg.max_iters = m;
    }
    if let Some(b) = flag_num::<usize>(flags, "batch-size")? {
        if b == 0 {
            return Err(EakmError::Config("--batch-size must be ≥ 1".into()));
        }
        cfg.batch_size = Some(b);
    }
    if let Some(g) = flag_num::<f64>(flags, "batch-growth")? {
        cfg.batch_growth = g;
    }
    if let Some(i) = flags.get("init") {
        cfg.init = InitMethod::parse(i)
            .ok_or_else(|| EakmError::Config(format!("unknown init {i:?}")))?;
    }
    Ok(cfg)
}

/// Build the `--progress` observer: a fresh trace ID minted here at
/// the front door, one stderr line per round. `None` without the flag
/// (runs without an observer skip even the read-only hooks).
fn progress_observer(flags: &Flags) -> Option<FitObserver> {
    flags
        .contains_key("progress")
        .then(|| FitObserver::new(TraceId::mint(), true))
}

fn cmd_run(flags: &Flags) -> Result<i32> {
    if flags.contains_key("shards") {
        return cmd_run_dist(flags);
    }
    let cfg = build_config(flags)?;
    let rt = Runtime::new(cfg.resolved_threads());
    // out-of-core sources fit straight off the file; RunReport.io
    // carries the blocks/bytes/refills telemetry
    let src = open_source(flags, true)?;
    let observer = progress_observer(flags).map(std::sync::Arc::new);
    let model = Kmeans::from_config(cfg).fit_observed(&rt, &*src, observer)?;
    if flags.contains_key("json") {
        println!("{}", Json::from(model.report()));
    } else {
        println!("{}", model.report().summary());
    }
    if let Some(path) = flags.get("save-model") {
        model.save(Path::new(path))?;
        eprintln!("[model written to {path}]");
    }
    Ok(0)
}

/// `eakm run --shards host:port,…`: coordinate a distributed fit. The
/// rows live on the shard servers, so every local data flag is a
/// contradiction and is rejected loudly.
fn cmd_run_dist(flags: &Flags) -> Result<i32> {
    for data_flag in [
        "dataset",
        "data-file",
        "data",
        "ooc",
        "ooc-window",
        "scale",
        "storage",
        "save-model",
    ] {
        if flags.contains_key(data_flag) {
            return Err(EakmError::Config(format!(
                "run: --shards and --{data_flag} are mutually exclusive \
                 (the shard servers own the rows)"
            )));
        }
    }
    let shards = flags.get("shards").expect("checked by cmd_run");
    let addrs: Vec<String> = shards
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if addrs.is_empty() {
        return Err(EakmError::Config(
            "--shards needs host:port[,host:port…]".into(),
        ));
    }
    let cfg = build_config(flags)?;
    let rt = Runtime::new(cfg.resolved_threads());
    let observer = progress_observer(flags);
    let out = crate::dist::run_dist_observed(&rt, &cfg, &addrs, observer.as_ref())?;
    if flags.contains_key("json") {
        println!("{}", Json::from(&out.report));
    } else {
        println!("{}", out.report.summary());
    }
    Ok(0)
}

/// Parse `--rows LO..HI`.
fn parse_rows(s: &str) -> Result<(usize, usize)> {
    let bad = || EakmError::Config(format!("bad --rows {s:?} (want LO..HI, e.g. 0..50000)"));
    let (lo, hi) = s.split_once("..").ok_or_else(bad)?;
    let lo = lo.parse::<usize>().map_err(|_| bad())?;
    let hi = hi.parse::<usize>().map_err(|_| bad())?;
    if lo >= hi {
        return Err(EakmError::Config(format!(
            "--rows {s}: the range is empty (LO must be < HI)"
        )));
    }
    Ok((lo, hi))
}

/// `eakm shardd`: serve one row range of an `.ekb` file to a
/// distributed fit. Blocks the calling thread until killed (or a
/// SHUTDOWN frame arrives).
fn cmd_shardd(flags: &Flags) -> Result<i32> {
    let data = data_file_flag(flags)
        .ok_or_else(|| EakmError::Config("shardd: --data PATH.ekb required".into()))?;
    let path = PathBuf::from(data);
    if path.extension().and_then(|e| e.to_str()) != Some("ekb") {
        return Err(EakmError::Config(
            "shardd serves the binary .ekb format only".into(),
        ));
    }
    let rows = flags
        .get("rows")
        .ok_or_else(|| EakmError::Config("shardd: --rows LO..HI required".into()))?;
    let (lo, hi) = parse_rows(rows)?;
    let mode = match flags.get("ooc") {
        None => OocMode::Auto,
        Some(s) => OocMode::parse(s)
            .ok_or_else(|| EakmError::Config(format!("bad --ooc: {s:?} (auto|mmap|chunked)")))?,
    };
    let cfg = crate::dist::ShardConfig {
        data: path,
        rows: (lo, hi),
        addr: flags
            .get("addr")
            .cloned()
            .unwrap_or_else(|| "127.0.0.1:5999".to_string()),
        threads: parse_threads(flags)?.unwrap_or(1),
        mode,
        window_rows: flag_num::<usize>(flags, "ooc-window")?.unwrap_or(0),
        metrics_addr: flags.get("metrics-addr").cloned(),
    };
    let file = cfg.data.display().to_string();
    crate::dist::shardd(&cfg, |addr| {
        eprintln!("[shard serving rows {lo}..{hi} of {file} on {addr}]");
    })?;
    Ok(0)
}

fn cmd_predict(flags: &Flags) -> Result<i32> {
    let model_path = flags
        .get("model")
        .ok_or_else(|| EakmError::Config("--model required (see `eakm run --save-model`)".into()))?;
    let model = FittedModel::load(Path::new(model_path))?;
    // points are taken as-is: the model defines the feature space
    let rt = Runtime::new(parse_threads(flags)?.unwrap_or(1));
    let src = open_source(flags, false)?;
    let labels = model.predict(&rt, &*src)?;
    let mse = src.mse(model.centroids(), &labels);
    let n = src.n();
    if flags.contains_key("json") {
        println!(
            "{}",
            Json::obj()
                .field("model", model_path.as_str())
                .field("algorithm", model.algorithm())
                .field("n", n)
                .field("k", model.k())
                .field("d", model.d())
                .field("mse", mse)
                .field(
                    "assignments",
                    Json::Arr(labels.iter().map(|&a| Json::from(a as u64)).collect()),
                )
        );
        return Ok(0);
    }
    let mut text = String::with_capacity(labels.len() * 4);
    for a in &labels {
        text.push_str(&a.to_string());
        text.push('\n');
    }
    match flags.get("out") {
        Some(path) => {
            std::fs::write(path, text)?;
            println!(
                "predicted {n} points into k={} clusters (mse={mse:.6}) → {path}",
                model.k()
            );
        }
        None => {
            eprintln!(
                "predicted {n} points into k={} clusters (mse={mse:.6})",
                model.k()
            );
            print!("{text}");
        }
    }
    Ok(0)
}

/// The startup note explaining when typed "overloaded" rejects fire.
/// Only worth printing when the user *chose* the coupling by passing
/// `--queue-depth` or `--acceptors` — the computed defaults always
/// satisfy `queue_depth ≥ acceptors` and warning about them on every
/// start is noise (and was a bug: the note fired unconditionally).
fn queue_coupling_note(queue_depth: usize, acceptors: usize, user_tuned: bool) -> Option<String> {
    if user_tuned && queue_depth >= acceptors {
        Some(format!(
            "[note: queue depth {queue_depth} ≥ {acceptors} acceptors — overload will surface \
             as connection queueing; use --queue-depth < --acceptors for typed \
             \"overloaded\" rejects]"
        ))
    } else {
        None
    }
}

/// `eakm serve`: load (or fit) a model, then run the long-lived server
/// until a `shutdown` op arrives. Blocks the calling thread.
fn cmd_serve(flags: &Flags) -> Result<i32> {
    use std::time::{Duration, Instant};
    let rt = Runtime::new(parse_threads(flags)?.unwrap_or(crate::config::AUTO_THREADS));
    let model = match flags.get("model") {
        Some(path) => {
            // a saved model and fit flags contradict each other — fail
            // loudly rather than silently serving the stale model
            for fit_flag in [
                "dataset",
                "data-file",
                "data",
                "ooc",
                "ooc-window",
                "scale",
                "config",
                "k",
                "algorithm",
                "seed",
                "init",
                "max-iters",
                "batch-size",
                "batch-growth",
                "storage",
            ] {
                if flags.contains_key(fit_flag) {
                    return Err(EakmError::Config(format!(
                        "serve: --model and --{fit_flag} are mutually exclusive \
                         (drop --model to fit at startup, or drop the fit flags \
                         and use the \"reload\" op to swap models)"
                    )));
                }
            }
            FittedModel::load(Path::new(path))?
        }
        // no saved model: fit one at startup with the same config +
        // data flags as `run` (--dataset/--data-file/--ooc/--k/…)
        None => {
            let cfg = build_config(flags)?;
            let src = open_source(flags, true)?;
            Kmeans::from_config(cfg).fit(&rt, &*src)?
        }
    };
    let defaults = crate::serve::ServeConfig::default();
    let positive = |key: &str, fallback: usize| -> Result<usize> {
        match flag_num::<usize>(flags, key)? {
            Some(0) => Err(EakmError::Config(format!("--{key} must be ≥ 1"))),
            Some(v) => Ok(v),
            None => Ok(fallback),
        }
    };
    let adm = crate::serve::AdmissionConfig::default();
    let rate_limit = flag_num::<f64>(flags, "rate-limit")?.unwrap_or(adm.rate_limit);
    if !(rate_limit >= 0.0 && rate_limit.is_finite()) {
        return Err(EakmError::Config("--rate-limit must be a finite value ≥ 0".into()));
    }
    let burst = flag_num::<f64>(flags, "rate-burst")?.unwrap_or(adm.burst);
    if !(burst > 0.0 && burst.is_finite()) {
        return Err(EakmError::Config("--rate-burst must be a finite value > 0".into()));
    }
    let key_by = match flags.get("admission-key") {
        None => adm.key_by,
        Some(v) => crate::serve::KeyBy::parse(v)
            .ok_or_else(|| EakmError::Config(format!("bad --admission-key: {v:?} (ip|conn)")))?,
    };
    let cfg = crate::serve::ServeConfig {
        addr: flags
            .get("addr")
            .cloned()
            .unwrap_or_else(|| "127.0.0.1:4999".to_string()),
        acceptors: positive("acceptors", defaults.acceptors)?,
        queue_depth: positive("queue-depth", defaults.queue_depth)?,
        max_batch_rows: positive("max-batch", defaults.max_batch_rows)?,
        linger: Duration::from_millis(flag_num::<u64>(flags, "linger-ms")?.unwrap_or(0)),
        max_line_bytes: defaults.max_line_bytes,
        idle_timeout: defaults.idle_timeout,
        bulk_block_rows: positive("bulk-block-rows", defaults.bulk_block_rows)?,
        metrics: defaults.metrics,
        admission: crate::serve::AdmissionConfig {
            rate_limit,
            burst,
            breaker_fails: flag_num::<u32>(flags, "breaker-fails")?.unwrap_or(adm.breaker_fails),
            breaker_cooldown: flag_num::<u64>(flags, "breaker-cooldown-ms")?
                .map(Duration::from_millis)
                .unwrap_or(adm.breaker_cooldown),
            key_by,
        },
    };
    if let Some(note) = queue_coupling_note(
        cfg.queue_depth,
        cfg.acceptors,
        flags.contains_key("queue-depth") || flags.contains_key("acceptors"),
    ) {
        eprintln!("{note}");
    }
    let started = Instant::now();
    let threads = rt.threads();
    let stats = crate::serve::serve(&rt, model, &cfg, |addr| {
        eprintln!(
            "[serving on {addr} — {threads} worker threads, queue {}, batch cap {} rows]",
            cfg.queue_depth, cfg.max_batch_rows
        );
    })?;
    println!("{}", stats.summary_line(started.elapsed()));
    Ok(0)
}

fn cmd_datasets(flags: &Flags) -> Result<i32> {
    let scale = flag_num::<f64>(flags, "scale")?.unwrap_or_else(env_scale);
    let mut t = TextTable::new(format!(
        "The 22 paper datasets (synthetic stand-ins), scale={scale}"
    ))
    .headers(&["id", "name", "d", "N(paper)", "N(scaled)", "class"]);
    for spec in paper_datasets() {
        let scaled = ((spec.n as f64 * scale) as usize).clamp(1_000.min(spec.n), spec.n);
        t.row(vec![
            spec.roman().to_string(),
            spec.name.to_string(),
            spec.d.to_string(),
            spec.n.to_string(),
            scaled.to_string(),
            format!("{:?}", spec.class),
        ]);
    }
    print!("{}", t.render());
    Ok(0)
}

fn cmd_validate(flags: &Flags) -> Result<i32> {
    let data = load_dataset(flags, true)?;
    let k = flag_num::<usize>(flags, "k")?.unwrap_or(50);
    let seed = flag_num::<u64>(flags, "seed")?.unwrap_or(0);
    let mut reference: Option<(usize, f64, Vec<u32>)> = None;
    let mut failures = 0;
    for alg in Algorithm::ALL {
        let cfg = RunConfig::new(alg, k).seed(seed).max_iters(100_000);
        let out = Runner::new(&cfg).run(&data)?;
        match &reference {
            None => {
                println!(
                    "{:<10} iters={:<5} mse={:.9}  [reference]",
                    alg.name(),
                    out.iterations,
                    out.mse
                );
                reference = Some((out.iterations, out.mse, out.assignments));
            }
            Some((iters, mse, assign)) => {
                let ok = out.iterations == *iters
                    && (out.mse - mse).abs() <= 1e-9 * mse.max(1.0)
                    && out.assignments == *assign;
                println!(
                    "{:<10} iters={:<5} mse={:.9}  [{}]",
                    alg.name(),
                    out.iterations,
                    out.mse,
                    if ok { "OK" } else { "MISMATCH" }
                );
                if !ok {
                    failures += 1;
                }
            }
        }
    }
    if failures > 0 {
        eprintln!("{failures} algorithm(s) diverged from sta — exactness violated");
        return Ok(1);
    }
    println!("all {} algorithms agree exactly", Algorithm::ALL.len());
    Ok(0)
}

fn cmd_grid(flags: &Flags) -> Result<i32> {
    use crate::bench_support::{env_seeds, grid_datasets, grid_ks};
    let scale = flag_num::<f64>(flags, "scale")?.unwrap_or_else(env_scale);
    let seeds = flag_num::<usize>(flags, "seeds")?.unwrap_or_else(env_seeds);
    let ks: Vec<usize> = match flags.get("k") {
        Some(s) => s
            .split(',')
            .map(|x| {
                x.parse::<usize>()
                    .map_err(|_| EakmError::Config(format!("bad k list {s:?}")))
            })
            .collect::<Result<_>>()?,
        None => grid_ks(scale).to_vec(),
    };
    let algs: Vec<Algorithm> = match flags.get("algorithms") {
        Some(s) => s
            .split(',')
            .map(|x| {
                Algorithm::parse(x)
                    .ok_or_else(|| EakmError::Config(format!("unknown algorithm {x:?}")))
            })
            .collect::<Result<_>>()?,
        None => Algorithm::SN
            .iter()
            .chain(Algorithm::NS.iter())
            .copied()
            .collect(),
    };
    let out_dir = flags.get("out").map(PathBuf::from);
    if let Some(dir) = &out_dir {
        std::fs::create_dir_all(dir)?;
    }
    for k in ks {
        let mut t = TextTable::new(format!(
            "Grid (scale={scale}, seeds={seeds}, k={k}): mean time relative to fastest"
        ));
        let mut headers: Vec<String> = vec!["ds".into(), "iters".into(), "fastest[s]".into()];
        headers.extend(algs.iter().map(|a| a.name().to_string()));
        let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        t = t.headers(&headers_ref);
        let mut json_rows = Vec::new();
        for (spec, ds) in grid_datasets(scale, None) {
            if k >= ds.n() {
                continue;
            }
            let stats: Vec<_> = algs
                .iter()
                .map(|&alg| measure(&ds, alg, k, seeds, 1))
                .collect();
            let fastest = stats
                .iter()
                .map(|s| s.mean_wall.as_secs_f64())
                .fold(f64::INFINITY, f64::min);
            let mut row = vec![
                spec.roman().to_string(),
                format!("{:.0}", stats[0].mean_iters),
                format!("{fastest:.3}"),
            ];
            for s in &stats {
                row.push(TextTable::fmt_ratio(s.mean_wall.as_secs_f64() / fastest));
            }
            t.row(row);
            for s in &stats {
                json_rows.push(
                    Json::obj()
                        .field("dataset", spec.name)
                        .field("k", k)
                        .field("algorithm", s.algorithm.name())
                        .field("wall_secs", s.mean_wall.as_secs_f64())
                        .field("q_a", s.mean_qa)
                        .field("q_au", s.mean_qau)
                        .field("iters", s.mean_iters),
                );
            }
            eprint!(".");
        }
        eprintln!();
        let rendered = t.render();
        print!("{rendered}");
        if let Some(dir) = &out_dir {
            std::fs::write(dir.join(format!("grid_k{k}.txt")), &rendered)?;
            std::fs::write(
                dir.join(format!("grid_k{k}.json")),
                Json::Arr(json_rows).to_string(),
            )?;
        }
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_flags_roundtrip() {
        let f = parse_flags(&s(&["--k", "100", "--json", "--seed", "3"])).unwrap();
        assert_eq!(f.get("k").unwrap(), "100");
        assert_eq!(f.get("json").unwrap(), "true");
        assert_eq!(f.get("seed").unwrap(), "3");
    }

    #[test]
    fn queue_coupling_note_is_silent_on_defaults() {
        // the stock defaults (256 ≥ 4) are a valid config — no note
        assert_eq!(queue_coupling_note(256, 4, false), None);
        // user tuned the knobs into the coupled regime — explain it
        let note = queue_coupling_note(8, 4, true).unwrap();
        assert!(note.contains("queue depth 8 ≥ 4 acceptors"), "{note}");
        // user tuned into strict-reject mode — nothing to explain
        assert_eq!(queue_coupling_note(2, 4, true), None);
    }

    #[test]
    fn parse_flags_rejects_positional() {
        assert!(parse_flags(&s(&["oops"])).is_err());
        assert!(parse_flags(&s(&["--k"])).is_err());
    }

    #[test]
    fn help_runs() {
        assert_eq!(main(&s(&["help"])).unwrap(), 0);
        assert!(main(&s(&["frobnicate"])).is_err());
    }

    #[test]
    fn run_on_tiny_dataset() {
        let code = main(&s(&[
            "run",
            "--dataset",
            "birch",
            "--scale",
            "0.01",
            "--k",
            "10",
            "--algorithm",
            "exp",
            "--json",
        ]))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn run_with_progress_flag() {
        // --progress is a boolean flag like --json; the run must still
        // exit 0 (round lines go to stderr, the report to stdout)
        let code = main(&s(&[
            "run",
            "--dataset",
            "birch",
            "--scale",
            "0.01",
            "--k",
            "5",
            "--algorithm",
            "exp-ns",
            "--progress",
            "--json",
        ]))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn datasets_lists() {
        assert_eq!(main(&s(&["datasets"])).unwrap(), 0);
    }

    fn tmpdir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("eakm-cli-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn fit_save_then_predict() {
        let dir = tmpdir();
        let model_path = dir.join("model.json");
        let code = main(&s(&[
            "run",
            "--dataset",
            "birch",
            "--scale",
            "0.01",
            "--k",
            "8",
            "--algorithm",
            "exp-ns",
            "--save-model",
            model_path.to_str().unwrap(),
        ]))
        .unwrap();
        assert_eq!(code, 0);
        // predict the model against a CSV of raw points
        let points_path = dir.join("points.csv");
        std::fs::write(&points_path, "0.0,0.5\n1.0,-0.25\n-2.0,3.0\n").unwrap();
        let labels_path = dir.join("labels.txt");
        let code = main(&s(&[
            "predict",
            "--model",
            model_path.to_str().unwrap(),
            "--data-file",
            points_path.to_str().unwrap(),
            "--threads",
            "2",
            "--out",
            labels_path.to_str().unwrap(),
        ]))
        .unwrap();
        assert_eq!(code, 0);
        let labels = std::fs::read_to_string(&labels_path).unwrap();
        assert_eq!(labels.lines().count(), 3);
        for line in labels.lines() {
            assert!(line.parse::<u32>().unwrap() < 8);
        }
    }

    #[test]
    fn predict_requires_model_flag() {
        assert!(main(&s(&["predict", "--data-file", "nope.csv"])).is_err());
    }

    #[test]
    fn run_with_batch_flags() {
        let code = main(&s(&[
            "run",
            "--dataset",
            "birch",
            "--scale",
            "0.01",
            "--k",
            "10",
            "--algorithm",
            "exp-ns",
            "--batch-size",
            "64",
            "--batch-growth",
            "2.0",
            "--max-iters",
            "20",
            "--json",
        ]))
        .unwrap();
        assert_eq!(code, 0);
        // degenerate knobs are rejected up front
        assert!(main(&s(&["run", "--dataset", "birch", "--batch-size", "0"])).is_err());
        assert!(main(&s(&[
            "run",
            "--dataset",
            "birch",
            "--k",
            "5",
            "--batch-size",
            "32",
            "--batch-growth",
            "0.5",
        ]))
        .is_err());
    }

    #[test]
    fn run_and_predict_out_of_core() {
        use crate::data::synth::blobs;
        let dir = tmpdir();
        let ekb = dir.join("ooc-cli.ekb");
        io::save_bin(&blobs(600, 4, 5, 0.2, 13), &ekb).unwrap();
        let model_path = dir.join("ooc-cli-model.json");
        // fit off the file without loading it (chunked, tiny window)
        let code = main(&s(&[
            "run",
            "--data",
            ekb.to_str().unwrap(),
            "--ooc",
            "chunked",
            "--ooc-window",
            "64",
            "--k",
            "5",
            "--algorithm",
            "exp-ns",
            "--threads",
            "2",
            "--json",
            "--save-model",
            model_path.to_str().unwrap(),
        ]))
        .unwrap();
        assert_eq!(code, 0);
        // predict off the same file through auto mode
        let code = main(&s(&[
            "predict",
            "--model",
            model_path.to_str().unwrap(),
            "--data-file",
            ekb.to_str().unwrap(),
            "--ooc",
            "auto",
            "--out",
            dir.join("ooc-cli-labels.txt").to_str().unwrap(),
        ]))
        .unwrap();
        assert_eq!(code, 0);
        let labels = std::fs::read_to_string(dir.join("ooc-cli-labels.txt")).unwrap();
        assert_eq!(labels.lines().count(), 600);
    }

    #[test]
    fn ooc_flag_validation() {
        // --ooc needs a data file, an .ekb one, and a known mode
        assert!(main(&s(&["run", "--dataset", "birch", "--ooc", "chunked"])).is_err());
        assert!(main(&s(&[
            "run",
            "--data-file",
            "points.csv",
            "--ooc",
            "chunked"
        ]))
        .is_err());
        assert!(main(&s(&[
            "run",
            "--data-file",
            "x.ekb",
            "--ooc",
            "ramdisk"
        ]))
        .is_err());
        // --ooc-window without --ooc is a config error, not ignored
        assert!(main(&s(&[
            "run",
            "--dataset",
            "birch",
            "--ooc-window",
            "64"
        ]))
        .is_err());
    }

    #[test]
    fn serve_flag_validation() {
        // a missing model file fails before any socket is bound
        assert!(main(&s(&["serve", "--model", "/nonexistent/model.json"])).is_err());
        // no --model and no data flags: nothing to serve
        assert!(main(&s(&["serve"])).is_err());
        // zero-sized knobs are config errors, not silent clamps
        let dir = tmpdir();
        let model_path = dir.join("serve-flags-model.json");
        assert_eq!(
            main(&s(&[
                "run",
                "--dataset",
                "birch",
                "--scale",
                "0.01",
                "--k",
                "4",
                "--save-model",
                model_path.to_str().unwrap(),
            ]))
            .unwrap(),
            0
        );
        for knob in ["queue-depth", "max-batch", "acceptors"] {
            let flag = format!("--{knob}");
            assert!(
                main(&s(&[
                    "serve",
                    "--model",
                    model_path.to_str().unwrap(),
                    flag.as_str(),
                    "0",
                ]))
                .is_err(),
                "--{knob} 0 must be rejected"
            );
        }
        // an unbindable address surfaces as an error, not a hang
        assert!(main(&s(&[
            "serve",
            "--model",
            model_path.to_str().unwrap(),
            "--addr",
            "256.256.256.256:1",
        ]))
        .is_err());
        // --model plus fit flags is a contradiction, not a silent
        // preference for the saved model
        for fit_flag in ["--dataset", "--data-file", "--k"] {
            assert!(
                main(&s(&[
                    "serve",
                    "--model",
                    model_path.to_str().unwrap(),
                    fit_flag,
                    "birch",
                ]))
                .is_err(),
                "--model with {fit_flag} must be rejected"
            );
        }
    }

    #[test]
    fn shardd_flag_validation() {
        // --data and --rows are both required
        assert!(main(&s(&["shardd", "--rows", "0..10"])).is_err());
        assert!(main(&s(&["shardd", "--data", "x.ekb"])).is_err());
        // .ekb only (the shard serves raw payload bytes)
        assert!(main(&s(&["shardd", "--data", "x.csv", "--rows", "0..10"])).is_err());
        // malformed or empty ranges are config errors
        for rows in ["10", "5..5", "9..3", "a..b", "..", "3.."] {
            assert!(
                main(&s(&["shardd", "--data", "x.ekb", "--rows", rows])).is_err(),
                "--rows {rows} must be rejected"
            );
        }
        // unknown ooc backend
        assert!(main(&s(&[
            "shardd", "--data", "x.ekb", "--rows", "0..10", "--ooc", "ramdisk"
        ]))
        .is_err());
    }

    #[test]
    fn run_shards_flag_validation() {
        // the shard servers own the rows: local data flags contradict
        for extra in [
            ["--dataset", "birch"],
            ["--data-file", "x.ekb"],
            ["--ooc", "chunked"],
            ["--storage", "f32"],
            ["--save-model", "m.json"],
        ] {
            assert!(
                main(&s(&[
                    "run",
                    "--shards",
                    "127.0.0.1:1",
                    "--k",
                    "4",
                    extra[0],
                    extra[1],
                ]))
                .is_err(),
                "--shards with {} must be rejected",
                extra[0]
            );
        }
        // an empty shard list is a config error, not a connect attempt
        assert!(main(&s(&["run", "--shards", ",", "--k", "4"])).is_err());
    }

    #[test]
    fn missing_ekb_error_names_path_and_mode() {
        // regression: a missing .ekb used to surface the raw OS error
        // with no hint of which file or which backend was asked for it
        for mode in ["chunked", "auto"] {
            let err = main(&s(&[
                "run",
                "--data-file",
                "/nonexistent/never.ekb",
                "--ooc",
                mode,
                "--k",
                "4",
            ]))
            .unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("/nonexistent/never.ekb"), "{msg}");
            assert!(msg.contains("source"), "{msg}");
        }
        let err = main(&s(&[
            "run",
            "--data-file",
            "/nonexistent/never.ekb",
            "--ooc",
            "chunked",
            "--k",
            "4",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("chunked"), "{err}");
    }

    #[test]
    fn run_with_f32_storage() {
        let code = main(&s(&[
            "run",
            "--dataset",
            "birch",
            "--scale",
            "0.01",
            "--k",
            "8",
            "--algorithm",
            "exp-ns",
            "--storage",
            "f32",
            "--json",
        ]))
        .unwrap();
        assert_eq!(code, 0);
        // explicit f64 is the default spelled out
        let code = main(&s(&[
            "run", "--dataset", "birch", "--scale", "0.01", "--k", "8", "--storage", "f64",
        ]))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn storage_flag_validation() {
        // unknown width is a config error
        assert!(main(&s(&[
            "run", "--dataset", "birch", "--storage", "f16"
        ]))
        .is_err());
        // --storage with --ooc contradicts the file header's authority
        assert!(main(&s(&[
            "run",
            "--data-file",
            "x.ekb",
            "--ooc",
            "chunked",
            "--storage",
            "f32"
        ]))
        .is_err());
    }

    #[test]
    fn run_with_auto_threads() {
        let code = main(&s(&[
            "run",
            "--dataset",
            "birch",
            "--scale",
            "0.01",
            "--k",
            "5",
            "--algorithm",
            "sta",
            "--threads",
            "auto",
        ]))
        .unwrap();
        assert_eq!(code, 0);
        assert!(main(&s(&["run", "--dataset", "birch", "--threads", "lots"])).is_err());
        // 0 is not a thread count; only the explicit "auto" selects auto
        assert!(main(&s(&["run", "--dataset", "birch", "--threads", "0"])).is_err());
    }

    #[test]
    fn run_with_scan_shards_flag() {
        // explicit counts and "auto" both run; the knob never changes
        // results, so a plain exit-0 smoke is the CLI's contract here
        for shards in ["auto", "3"] {
            let code = main(&s(&[
                "run",
                "--dataset",
                "birch",
                "--scale",
                "0.01",
                "--k",
                "5",
                "--algorithm",
                "sta",
                "--threads",
                "2",
                "--scan-shards",
                shards,
            ]))
            .unwrap();
            assert_eq!(code, 0, "--scan-shards {shards}");
        }
        assert!(main(&s(&["run", "--dataset", "birch", "--scan-shards", "many"])).is_err());
        // 0 is not a shard count; only the explicit "auto" selects auto
        assert!(main(&s(&["run", "--dataset", "birch", "--scan-shards", "0"])).is_err());
    }
}
