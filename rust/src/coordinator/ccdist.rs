//! Inter-centroid distances `cc(j,j′)` and nearest-other-centroid `s(j)`.
//!
//! Rebuilt once per round when any active algorithm requests it (elk's
//! inner test, ham/ann/exp's outer test, exponion's annuli). The build
//! costs `k(k−1)/2` distance evaluations, charged to
//! [`Counters::centroid`](crate::metrics::Counters).

use crate::linalg::sqdist;
use crate::metrics::Counters;

/// Symmetric inter-centroid distance matrix with row access, plus `s`.
#[derive(Clone, Debug)]
pub struct CcData {
    /// Row-major `k×k` plain (non-squared) distances; diagonal is 0.
    cc: Vec<f64>,
    /// `s(j) = min_{j′≠j} cc(j,j′)` (∞ when k == 1).
    pub s: Vec<f64>,
    k: usize,
}

impl CcData {
    /// Build from current centroids (row-major `k×d`).
    pub fn build(centroids: &[f64], k: usize, d: usize, ctr: &mut Counters) -> Self {
        debug_assert_eq!(centroids.len(), k * d);
        let mut cc = vec![0.0; k * k];
        let mut s = vec![f64::INFINITY; k];
        for j in 0..k {
            let cj = &centroids[j * d..(j + 1) * d];
            for j2 in (j + 1)..k {
                let dist = sqdist(cj, &centroids[j2 * d..(j2 + 1) * d]).sqrt();
                cc[j * k + j2] = dist;
                cc[j2 * k + j] = dist;
                if dist < s[j] {
                    s[j] = dist;
                }
                if dist < s[j2] {
                    s[j2] = dist;
                }
            }
        }
        ctr.centroid += (k * (k - 1) / 2) as u64;
        CcData { cc, s, k }
    }

    /// Distance between centroids `a` and `b`.
    #[inline]
    pub fn get(&self, a: usize, b: usize) -> f64 {
        self.cc[a * self.k + b]
    }

    /// Full row for centroid `j` (used by the annuli builder).
    #[inline]
    pub fn row(&self, j: usize) -> &[f64] {
        &self.cc[j * self.k..(j + 1) * self.k]
    }

    /// Number of centroids.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_with_correct_s() {
        // three collinear centroids at 0, 1, 5 in 1-D
        let c = [0.0, 1.0, 5.0];
        let mut ctr = Counters::default();
        let cc = CcData::build(&c, 3, 1, &mut ctr);
        assert_eq!(cc.get(0, 1), 1.0);
        assert_eq!(cc.get(1, 0), 1.0);
        assert_eq!(cc.get(0, 2), 5.0);
        assert_eq!(cc.get(1, 2), 4.0);
        assert_eq!(cc.s, vec![1.0, 1.0, 4.0]);
        assert_eq!(ctr.centroid, 3);
    }

    #[test]
    fn single_centroid_s_infinite() {
        let mut ctr = Counters::default();
        let cc = CcData::build(&[1.0, 2.0], 1, 2, &mut ctr);
        assert!(cc.s[0].is_infinite());
        assert_eq!(ctr.centroid, 0);
    }

    #[test]
    fn diagonal_zero() {
        let mut ctr = Counters::default();
        let cc = CcData::build(&[0.0, 3.0, 1.0, 1.0], 2, 2, &mut ctr);
        assert_eq!(cc.get(0, 0), 0.0);
        assert_eq!(cc.get(1, 1), 0.0);
    }
}
