//! Inter-centroid distances `cc(j,j′)` and nearest-other-centroid `s(j)`.
//!
//! Rebuilt once per round when any active algorithm requests it (elk's
//! inner test, ham/ann/exp's outer test, exponion's annuli). The build
//! costs `k(k−1)/2` distance evaluations, charged to
//! [`Counters::centroid`](crate::metrics::Counters).

use crate::linalg::sqdist;
use crate::metrics::Counters;
use crate::runtime::pool::{SharedSliceMut, WorkerPool};

/// Below this k the parallel build costs more in scheduling than the
/// `k(k−1)/2` distance evaluations it shares out.
const PAR_MIN_K: usize = 64;

/// Symmetric inter-centroid distance matrix with row access, plus `s`.
#[derive(Clone, Debug)]
pub struct CcData {
    /// Row-major `k×k` plain (non-squared) distances; diagonal is 0.
    cc: Vec<f64>,
    /// `s(j) = min_{j′≠j} cc(j,j′)` (∞ when k == 1).
    pub s: Vec<f64>,
    k: usize,
}

impl CcData {
    /// Build from current centroids (row-major `k×d`).
    pub fn build(centroids: &[f64], k: usize, d: usize, ctr: &mut Counters) -> Self {
        debug_assert_eq!(centroids.len(), k * d);
        let mut cc = vec![0.0; k * k];
        let mut s = vec![f64::INFINITY; k];
        for j in 0..k {
            let cj = &centroids[j * d..(j + 1) * d];
            for j2 in (j + 1)..k {
                let dist = sqdist(cj, &centroids[j2 * d..(j2 + 1) * d]).sqrt();
                cc[j * k + j2] = dist;
                cc[j2 * k + j] = dist;
                if dist < s[j] {
                    s[j] = dist;
                }
                if dist < s[j2] {
                    s[j2] = dist;
                }
            }
        }
        ctr.centroid += (k * (k - 1) / 2) as u64;
        CcData { cc, s, k }
    }

    /// As [`CcData::build`], parallel over centroid rows. Each `(j, j′)`
    /// pair is evaluated exactly once by the owner of `min(j, j′)` and
    /// written to both mirror cells; `s(j)` is then a row minimum over
    /// the completed matrix. Both are element-wise, so the result is
    /// bit-identical to the serial build at any pool width.
    pub fn build_pooled(
        centroids: &[f64],
        k: usize,
        d: usize,
        ctr: &mut Counters,
        pool: &WorkerPool,
    ) -> Self {
        if pool.width() == 1 || k < PAR_MIN_K {
            return Self::build(centroids, k, d, ctr);
        }
        debug_assert_eq!(centroids.len(), k * d);
        let mut cc = vec![0.0; k * k];
        {
            let cells = SharedSliceMut::new(&mut cc);
            // row j costs k−1−j evaluations: small chunks keep the
            // triangle balanced under dynamic scheduling
            pool.for_each_chunk(k, 8, |lo, hi| {
                for j in lo..hi {
                    let cj = &centroids[j * d..(j + 1) * d];
                    for j2 in (j + 1)..k {
                        let dist = sqdist(cj, &centroids[j2 * d..(j2 + 1) * d]).sqrt();
                        // sound: cell (a, b) is written only by the chunk
                        // owning row min(a, b), and each row has one owner
                        unsafe {
                            cells.write(j * k + j2, dist);
                            cells.write(j2 * k + j, dist);
                        }
                    }
                }
            });
        }
        let mut s = vec![f64::INFINITY; k];
        {
            let mins = SharedSliceMut::new(&mut s);
            pool.for_each_chunk(k, 32, |lo, hi| {
                let dst = unsafe { mins.range(lo, hi) };
                for (off, out) in dst.iter_mut().enumerate() {
                    let j = lo + off;
                    let mut best = f64::INFINITY;
                    for (j2, &v) in cc[j * k..(j + 1) * k].iter().enumerate() {
                        if j2 != j && v < best {
                            best = v;
                        }
                    }
                    *out = best;
                }
            });
        }
        ctr.centroid += (k * (k - 1) / 2) as u64;
        CcData { cc, s, k }
    }

    /// Distance between centroids `a` and `b`.
    #[inline]
    pub fn get(&self, a: usize, b: usize) -> f64 {
        self.cc[a * self.k + b]
    }

    /// Full row for centroid `j` (used by the annuli builder).
    #[inline]
    pub fn row(&self, j: usize) -> &[f64] {
        &self.cc[j * self.k..(j + 1) * self.k]
    }

    /// Number of centroids.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_with_correct_s() {
        // three collinear centroids at 0, 1, 5 in 1-D
        let c = [0.0, 1.0, 5.0];
        let mut ctr = Counters::default();
        let cc = CcData::build(&c, 3, 1, &mut ctr);
        assert_eq!(cc.get(0, 1), 1.0);
        assert_eq!(cc.get(1, 0), 1.0);
        assert_eq!(cc.get(0, 2), 5.0);
        assert_eq!(cc.get(1, 2), 4.0);
        assert_eq!(cc.s, vec![1.0, 1.0, 4.0]);
        assert_eq!(ctr.centroid, 3);
    }

    #[test]
    fn single_centroid_s_infinite() {
        let mut ctr = Counters::default();
        let cc = CcData::build(&[1.0, 2.0], 1, 2, &mut ctr);
        assert!(cc.s[0].is_infinite());
        assert_eq!(ctr.centroid, 0);
    }

    #[test]
    fn diagonal_zero() {
        let mut ctr = Counters::default();
        let cc = CcData::build(&[0.0, 3.0, 1.0, 1.0], 2, 2, &mut ctr);
        assert_eq!(cc.get(0, 0), 0.0);
        assert_eq!(cc.get(1, 1), 0.0);
    }

    #[test]
    fn pooled_build_is_bit_identical_to_serial() {
        // k ≥ PAR_MIN_K so the parallel path actually runs
        let k = 80;
        let d = 3;
        let centroids: Vec<f64> = (0..k * d)
            .map(|i| ((i * 2654435761usize % 1000) as f64) * 0.01)
            .collect();
        let mut ctr_a = Counters::default();
        let want = CcData::build(&centroids, k, d, &mut ctr_a);
        for threads in [2, 8] {
            let pool = WorkerPool::new(threads);
            let mut ctr_b = Counters::default();
            let got = CcData::build_pooled(&centroids, k, d, &mut ctr_b, &pool);
            assert_eq!(got.cc, want.cc, "threads={threads}");
            assert_eq!(got.s, want.s, "threads={threads}");
            assert_eq!(ctr_b.centroid, ctr_a.centroid);
        }
    }
}
