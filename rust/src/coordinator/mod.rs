//! The coordination layer: per-round centroid-side structures, the
//! update step, thread-sharded execution, and the round loop.

pub mod annuli;
pub mod auto;
pub mod ccdist;
pub mod groups;
pub mod history;
pub mod parallel;
pub mod round_ctx;
pub mod runner;
pub mod sorted_norms;
pub mod update;

pub use runner::{Engine, RunOutput, Runner};
