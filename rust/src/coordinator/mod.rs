//! The coordination layer: per-round centroid-side structures, the
//! update step, thread-sharded execution, the round loop, and the
//! mini-batch engine flavour ([`minibatch`]) that drives the same
//! phases over sampled [`BatchView`](crate::data::BatchView)s.
//!
//! ## Parallel architecture
//!
//! Every phase of a round runs on one persistent
//! [`WorkerPool`](crate::runtime::pool::WorkerPool) — borrowed from a
//! shared [`Runtime`](crate::runtime::Runtime) ([`Engine::on_runtime`],
//! the serving path) or owned by the [`Engine`] (legacy one-shot path);
//! either way it is spawned once and parked between dispatches:
//!
//! * **assignment scan** — [`sched`] plans `S ≫ w` contiguous shards
//!   (geometry a function of `n` alone), one persistent algorithm
//!   instance per shard; [`parallel`] dispatches them in cost-guided
//!   LPT claim order and merges counters and moved lists in ascending
//!   shard order;
//! * **update step** — [`update`] folds per-chunk partial centroid sums
//!   in chunk order, with chunk geometry a function of the item count
//!   only;
//! * **centroid-side builds** — [`round_ctx`] shards `p(j)`/norms,
//!   [`ccdist`] the `k(k−1)/2` matrix, [`annuli`] the per-centroid
//!   partial sorts, [`groups`] the `q(f)` maxima and [`history`] the
//!   `P(j,t)` table over centroids (all element-wise disjoint writes).
//!
//! ## Determinism guarantee
//!
//! Assignments, MSE, and [`Counters`](crate::metrics::Counters) are
//! bit-identical at every thread count *and* every shard count:
//! element-wise parallel work is split arbitrarily (each element's math
//! is independent of the split), claim *order* is free (each shard's
//! math reads only the immutable round context and its own state), and
//! every floating-point *reduction* is performed serially in
//! shard/chunk order with width-independent geometry. The equivalence
//! suite asserts this for `threads ∈ {1, 2, 8}` across all algorithms;
//! `tests/sched.rs` crosses thread widths with shard counts and data
//! sources.

pub mod annuli;
pub mod auto;
pub mod ccdist;
pub mod groups;
pub mod history;
pub mod minibatch;
pub mod parallel;
pub mod round_ctx;
pub mod runner;
pub mod sched;
pub mod sorted_norms;
pub mod update;

pub use runner::{Engine, RunOutput, Runner};
