//! Exponion's concentric-annuli partial sort (paper §3.1).
//!
//! For each centroid j we keep the other k−1 centroids *partially* sorted
//! by distance from c(j): a sequence of annuli whose sizes double
//! (1, 2, 4, …), with `e(j,f)` the outer radius of annulus f. Building
//! this costs O(k) per centroid via repeated quick-select (vs O(k log k)
//! for a full sort), and a search-radius lookup returns a candidate
//! prefix at most twice the size of the exact candidate set
//! (`|J*(i)| ≤ 2|J(i)|`, paper).

use super::ccdist::CcData;
use crate::runtime::pool::{SharedSliceMut, WorkerPool};

/// Below this k the parallel rebuild costs more in scheduling than the
/// O(k) per-row partial sorts it shares out.
const PAR_MIN_K: usize = 64;

/// Per-centroid partially sorted neighbour lists + annulus radii.
#[derive(Clone, Debug)]
pub struct Annuli {
    /// k rows of k−1 neighbour indices, annulus-ordered.
    order: Vec<u32>,
    /// Per row, distance of each neighbour in `order` (same layout) —
    /// kept so tests/debug can verify; lookups only need `radii`.
    dists: Vec<f64>,
    /// Outer radius `e(j,f)` per row: `radii[j*levels + f]`.
    radii: Vec<f64>,
    /// Cumulative annulus sizes: prefix lengths 1, 3, 7, … clipped to k−1.
    prefix: Vec<usize>,
    /// Number of annulus levels.
    levels: usize,
    k: usize,
}

impl Annuli {
    /// Build from this round's inter-centroid matrix.
    pub fn build(cc: &CcData) -> Self {
        let mut out = Annuli::empty();
        out.build_into(cc);
        out
    }

    /// An empty shell whose buffers [`Annuli::build_into`] will size.
    pub fn empty() -> Self {
        Annuli {
            order: Vec::new(),
            dists: Vec::new(),
            radii: Vec::new(),
            prefix: Vec::new(),
            levels: 1,
            k: 0,
        }
    }

    /// Rebuild in place, reusing the previous round's buffers — the
    /// annuli are reconstructed every round (centroids move), so
    /// avoiding the ~`k²`-sized reallocations matters at k=1000.
    pub fn build_into(&mut self, cc: &CcData) {
        self.build_into_opts(cc, true);
    }

    /// Hot-path rebuild: skips the per-element distance copy-out
    /// (`dists` stays empty; only tests/ablation need it).
    pub fn build_into_fast(&mut self, cc: &CcData) {
        self.build_into_opts(cc, false);
    }

    /// Hot-path rebuild sharded over the pool: rows are independent (one
    /// partial sort each, writing disjoint `order`/`radii` slices), so
    /// the result is bit-identical to the serial rebuild at any width.
    /// Like [`Annuli::build_into_fast`], skips the `dists` copy-out.
    pub fn build_into_fast_pooled(&mut self, cc: &CcData, pool: &WorkerPool) {
        if pool.width() == 1 || cc.k() < PAR_MIN_K {
            self.build_into_fast(cc);
            return;
        }
        self.size_for(cc.k(), false);
        let (k, levels) = (self.k, self.levels);
        let km1 = k - 1;
        let prefix = &self.prefix;
        let order = SharedSliceMut::new(&mut self.order);
        let radii = SharedSliceMut::new(&mut self.radii);
        pool.for_each_chunk(k, 4, |lo, hi| {
            // per-chunk scratch, reused across the chunk's rows
            let mut scratch: Vec<u128> = Vec::with_capacity(km1);
            let order_rows = unsafe { order.range(lo * km1, hi * km1) };
            let radii_rows = unsafe { radii.range(lo * levels, hi * levels) };
            for j in lo..hi {
                fill_row(
                    cc,
                    j,
                    prefix,
                    &mut scratch,
                    &mut order_rows[(j - lo) * km1..(j - lo + 1) * km1],
                    &mut radii_rows[(j - lo) * levels..(j - lo + 1) * levels],
                    None,
                );
            }
        });
    }

    fn build_into_opts(&mut self, cc: &CcData, keep_dists: bool) {
        let k = cc.k();
        self.size_for(k, keep_dists);
        let (km1, levels) = (k.saturating_sub(1), self.levels);
        let mut scratch: Vec<u128> = Vec::with_capacity(km1);
        for j in 0..k {
            let dists_row = if keep_dists {
                Some(&mut self.dists[j * km1..(j + 1) * km1])
            } else {
                None
            };
            fill_row(
                cc,
                j,
                &self.prefix,
                &mut scratch,
                &mut self.order[j * km1..(j + 1) * km1],
                &mut self.radii[j * levels..(j + 1) * levels],
                dists_row,
            );
        }
    }

    /// (Re)size all buffers for `k` centroids, leaving the per-row fill
    /// to [`fill_row`].
    fn size_for(&mut self, k: usize, keep_dists: bool) {
        let km1 = k.saturating_sub(1);
        // levels: smallest L with 2^L − 1 ≥ k−1
        let mut levels = 0;
        while (1usize << levels) - 1 < km1 {
            levels += 1;
        }
        let levels = levels.max(1);
        self.levels = levels;
        self.k = k;
        self.prefix.clear();
        self.prefix
            .extend((1..=levels).map(|f| ((1usize << f) - 1).min(km1)));
        self.order.clear();
        self.order.resize(k * km1, 0);
        self.dists.clear();
        if keep_dists {
            self.dists.resize(k * km1, 0.0);
        }
        self.radii.clear();
        self.radii.resize(k * levels, f64::INFINITY);
    }

    /// Candidate neighbours of centroid `j` covering search radius `r`:
    /// the shortest annulus prefix whose outer radius is ≥ `r`
    /// (`J*(i)` in the paper). Never includes `j` itself.
    pub fn candidates(&self, j: usize, r: f64) -> &[u32] {
        let km1 = self.k - 1;
        let radii = &self.radii[j * self.levels..(j + 1) * self.levels];
        // Galloping/binary search over ⌈log2 k⌉ radii — the log log k the
        // paper mentions is available; levels is tiny so linear is fine
        // and branch-predictable. `<= r` (not `< r`): when the prefix
        // maximum ties the search radius exactly, an equal-distance
        // centroid could sit just outside the prefix, so we must take the
        // next level. The partition then guarantees everything outside is
        // strictly further than r.
        let mut f = 0;
        while f < self.levels && radii[f] <= r {
            f += 1;
        }
        let len = if f >= self.levels {
            km1
        } else {
            self.prefix[f]
        };
        &self.order[j * km1..j * km1 + len]
    }

    /// Exact candidate count for radius `r` (linear scan; test/bench aid).
    pub fn exact_count(&self, j: usize, r: f64) -> usize {
        let km1 = self.k - 1;
        self.dists[j * km1..(j + 1) * km1]
            .iter()
            .filter(|&&d| d <= r)
            .count()
    }

    /// Number of annulus levels.
    pub fn levels(&self) -> usize {
        self.levels
    }

    /// Annulus-ordered neighbour distances of centroid `j` (tests).
    pub fn row_dists(&self, j: usize) -> &[f64] {
        let km1 = self.k - 1;
        &self.dists[j * km1..(j + 1) * km1]
    }

    /// Annulus-ordered neighbour indices of centroid `j` (tests).
    pub fn row_order(&self, j: usize) -> &[u32] {
        let km1 = self.k - 1;
        &self.order[j * km1..(j + 1) * km1]
    }
}

/// Build one centroid's annulus row: partial-sort its neighbours and
/// derive the per-level radii. Rows are independent, which is what the
/// pooled rebuild exploits.
///
/// Distances are non-negative, so the IEEE-754 bit pattern is monotone
/// as an integer: pack (dist_bits << 32 | idx) into one u128 and
/// introselect on plain integer order — branchless and ~2× faster than
/// the (f64, u32) comparator at k=1000.
fn fill_row(
    cc: &CcData,
    j: usize,
    prefix: &[usize],
    scratch: &mut Vec<u128>,
    order_row: &mut [u32],
    radii_row: &mut [f64],
    dists_row: Option<&mut [f64]>,
) {
    scratch.clear();
    let row = cc.row(j);
    for (j2, &dist) in row.iter().enumerate() {
        if j2 != j {
            scratch.push(((dist.to_bits() as u128) << 32) | j2 as u128);
        }
    }
    // Partial sort: partition at the annulus boundaries from the
    // OUTERMOST inward, so each select works on a halving range —
    // O(k) total (vs O(k log k) ascending, which rescans the tail
    // at every level).
    let mut hi = scratch.len();
    for &b in prefix.iter().rev() {
        let b = b.min(scratch.len());
        if b > 0 && b < hi {
            scratch[..hi].select_nth_unstable(b);
            hi = b;
        }
    }
    // e(j,f) = max distance within the prefix [0, b) — packed
    // order is distance-major, so the max key is the max dist
    let mut start = 0;
    for (f, &b) in prefix.iter().enumerate() {
        let bc = b.min(scratch.len());
        let seg_max_bits = scratch[start..bc]
            .iter()
            .cloned()
            .max()
            .map(|key| (key >> 32) as u64)
            .unwrap_or(0);
        let seg_max = f64::from_bits(seg_max_bits).max(if f == 0 { 0.0 } else { radii_row[f - 1] });
        radii_row[f] = if b >= scratch.len() {
            f64::INFINITY // outermost annulus covers everything
        } else {
            seg_max
        };
        start = bc;
    }
    for (t, &key) in scratch.iter().enumerate() {
        order_row[t] = key as u32;
    }
    if let Some(dists_row) = dists_row {
        for (t, &key) in scratch.iter().enumerate() {
            dists_row[t] = f64::from_bits((key >> 32) as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Counters;

    fn line_centroids(k: usize) -> CcData {
        // centroids at positions 0,1,2,...,k−1 on a line
        let c: Vec<f64> = (0..k).map(|j| j as f64).collect();
        CcData::build(&c, k, 1, &mut Counters::default())
    }

    #[test]
    fn annuli_partition_is_ordering_consistent() {
        let ann = Annuli::build(&line_centroids(16));
        // within row 0, annulus boundaries respect the ≤ ordering between sets
        let dists = ann.row_dists(0);
        let mut start = 0;
        for &b in &ann.prefix {
            let b = b.min(dists.len());
            if b > start && b < dists.len() {
                let max_inner = dists[..b].iter().cloned().fold(0.0, f64::max);
                let min_outer = dists[b..].iter().cloned().fold(f64::INFINITY, f64::min);
                assert!(max_inner <= min_outer, "annulus ordering violated");
            }
            start = b;
        }
        let _ = start;
    }

    #[test]
    fn rows_are_permutations_of_others() {
        let k = 13;
        let ann = Annuli::build(&line_centroids(k));
        for j in 0..k {
            let mut row: Vec<u32> = ann.row_order(j).to_vec();
            row.sort_unstable();
            let want: Vec<u32> = (0..k as u32).filter(|&x| x != j as u32).collect();
            assert_eq!(row, want, "row {j} is not a permutation");
        }
    }

    #[test]
    fn candidates_superset_of_exact_and_bounded() {
        let k = 64;
        let ann = Annuli::build(&line_centroids(k));
        for j in [0usize, 5, 31, 63] {
            for r in [0.5, 1.5, 3.2, 7.9, 100.0] {
                let cand = ann.candidates(j, r);
                let exact = ann.exact_count(j, r);
                // superset: every centroid within r is in the candidate set
                assert!(cand.len() >= exact, "j={j} r={r}");
                let cand_set: std::collections::HashSet<u32> = cand.iter().cloned().collect();
                for j2 in 0..k {
                    if j2 != j && ((j2 as f64) - (j as f64)).abs() <= r {
                        assert!(cand_set.contains(&(j2 as u32)), "j={j} r={r} missing {j2}");
                    }
                }
                // |J*| ≤ 2|J| + 1 (paper's factor-2, +1 for the size-1 base annulus)
                assert!(
                    cand.len() <= 2 * exact + 1,
                    "j={j} r={r}: {} > 2·{exact}+1",
                    cand.len()
                );
            }
        }
    }

    #[test]
    fn k_equals_two() {
        let ann = Annuli::build(&line_centroids(2));
        assert_eq!(ann.candidates(0, 0.1), &[1u32]);
        assert_eq!(ann.candidates(1, 99.0), &[0u32]);
    }

    #[test]
    fn radius_zero_returns_first_annulus() {
        let ann = Annuli::build(&line_centroids(8));
        let c = ann.candidates(3, 0.0);
        assert!(!c.is_empty() && c.len() <= 1);
    }

    #[test]
    fn pooled_rebuild_is_bit_identical_to_serial() {
        use crate::runtime::pool::WorkerPool;
        // k ≥ PAR_MIN_K so the parallel path actually runs
        let cc = line_centroids(100);
        let mut want = Annuli::empty();
        want.build_into_fast(&cc);
        for threads in [2, 8] {
            let pool = WorkerPool::new(threads);
            let mut got = Annuli::empty();
            got.build_into_fast_pooled(&cc, &pool);
            assert_eq!(got.order, want.order, "threads={threads}");
            assert_eq!(got.radii, want.radii, "threads={threads}");
            assert_eq!(got.prefix, want.prefix);
            assert_eq!(got.levels, want.levels);
        }
    }
}
