//! The mini-batch engine flavour: latency-bounded refinement on the
//! [`DataSource`] seam.
//!
//! Each round draws a [`BatchView`] from the base source and runs the
//! *existing* assignment + update phases over it through [`Engine`] —
//! the batch is just another `DataSource`, so the paper's accelerated
//! scans, the pool sharding, and the width-independence guarantee all
//! carry over unchanged. The driver then advances the centroids itself
//! with a decayed update over per-centroid counts:
//!
//! * **nested mode** (`batch_growth > 1`, Newling & Fleuret 2016b): the
//!   batch *grows* each round, keeping every previously drawn row (old
//!   batch ⊂ new batch), so the batch itself carries the sample history
//!   and the update is the plain per-cluster batch mean — no point is
//!   ever redundantly resampled. Once the batch covers the dataset the
//!   driver hands the tail to one persistent exact [`Engine`] over the
//!   (now full) view, restoring the accelerators' cross-round bound
//!   reuse, and the run converges in the usual fixed-point sense.
//! * **redraw mode** (`batch_growth == 1`): a fresh batch per round
//!   (Sculley 2010), redrawn in place at `O(batch)` cost. History is
//!   carried in the decayed per-centroid counts instead: cluster `j`'s
//!   effective learning rate is `count_r(j) / (carry(j) + count_r(j))`,
//!   which decays as samples accumulate. Redraw runs refine
//!   indefinitely — they stop at `max_iters` or the wall-clock limit,
//!   which is exactly the refine-under-latency-budget serving shape.
//!
//! Determinism: seeding and batch sampling consume serial seeded RNG
//! streams, the per-batch engine is the coordinator's width-independent
//! machinery, the decayed update is a serial fold over centroids, and
//! the final full-data labelling uses the element-wise predict kernel —
//! so a seeded mini-batch fit is **bit-identical at any thread count**,
//! matching the pool's guarantee for full-batch runs.
//!
//! Cost note: growing/redrawn rounds rebuild their engine, which pays
//! the centroid-side setup (`cc` matrix, annuli, history epoch) for a
//! single scan. That is the price of running the real phases — the
//! engine also keeps the paper's distance-calculation counters exact,
//! which a bare labelling scan would not. The exact-engine tail removes
//! this overhead where it dominates (the full-coverage convergence
//! rounds of a nested run).

use std::time::Instant;

use crate::algorithms::common::nearest_labels;
use crate::algorithms::Algorithm;
use crate::config::RunConfig;
use crate::coordinator::runner::{Engine, RunOutput};
use crate::data::{BatchView, DataSource};
use crate::error::Result;
use crate::linalg::sqnorms_rows;
use crate::metrics::{BatchTelemetry, Counters, PhaseTimes, RunReport, SchedTelemetry};
use crate::obs::{FitObserver, RoundObservation};
use crate::rng::Rng;
use crate::runtime::Runtime;

/// Label for the batch-sampling RNG stream, split from `cfg.seed` so
/// batch draws are decorrelated from centroid seeding (which consumes
/// the root stream exactly like the full-batch path).
const SAMPLE_STREAM: u64 = 0xBA7C;

/// Run a mini-batch fit of `cfg` over `data` on the shared runtime.
///
/// Callers route here only when `cfg.batch_size` is set below
/// `data.n()` ([`Runner::run_on`](crate::coordinator::Runner::run_on)
/// keeps batch sizes covering the dataset on the exact engine). The
/// initial batch size is clamped to at least `cfg.k` so every cluster
/// can seat a member.
///
/// `cfg.time_limit` bounds the refinement rounds; the mandatory final
/// full-data labelling pass (one `O(n·k)` scan, needed to report
/// assignments and MSE) runs after the budget, so total wall time is
/// the budget plus one full scan.
///
/// When `observer` is set, each round pushes a `"round"` event with
/// `site = "minibatch"` and the rows scanned that round; the reported
/// MSE is the batch objective (the full-data objective is only computed
/// by the final labelling pass). Without an observer the per-round
/// objective read is skipped entirely.
pub fn run_minibatch(
    rt: &Runtime,
    cfg: &RunConfig,
    data: &dyn DataSource,
    observer: Option<&FitObserver>,
) -> Result<RunOutput> {
    let io_before = data.io_stats();
    let start = Instant::now();
    let (n, d, k) = (data.n(), data.d(), cfg.k);
    if n == 0 || d == 0 {
        // same typed guard as Engine::build — without it a d = 0 source
        // would panic inside the batch gather, not error
        return Err(crate::error::EakmError::Data(format!(
            "cannot cluster an empty data source (n={n}, d={d})"
        )));
    }
    cfg.validate(n)?;
    let b0 = cfg
        .batch_size
        .expect("mini-batch driver requires batch_size")
        .clamp(k, n);
    let growth = cfg.batch_growth;
    let nested = growth > 1.0;

    // seeding consumes the root stream exactly like the full-batch path
    let mut counters = Counters::default();
    let mut centroids = cfg
        .init
        .centroids(data, k, &mut Rng::new(cfg.seed), &mut counters);
    let mut sample_rng = Rng::new(cfg.seed).split(SAMPLE_STREAM);

    // the per-batch engine runs the configured algorithm; resolve Auto
    // once so every round (and the report) agree
    let mut ecfg = cfg.clone();
    ecfg.algorithm = match cfg.algorithm {
        Algorithm::Auto => crate::coordinator::auto::resolve(d),
        other => other,
    };

    let mut view = BatchView::sample(data, b0, &mut sample_rng);
    // decayed per-centroid counts carried across batches (redraw mode;
    // nested batches carry their history in the batch itself)
    let mut carry = vec![0.0f64; k];
    let mut phases = PhaseTimes::default();
    let mut sched = SchedTelemetry::default();
    let mut schedule = Vec::new();
    let mut round_times = Vec::new();
    let mut name = ecfg.algorithm.name().to_string();
    let mut converged = false;
    let mut rounds = 0;

    while rounds < cfg.max_iters {
        if let Some(limit) = cfg.time_limit {
            if start.elapsed() > limit {
                break;
            }
        }
        if nested && view.is_full() {
            // the nested batch now covers the dataset: hand the tail to
            // one persistent exact engine so the accelerators' per-round
            // bound reuse is restored (rebuilding per round would pay a
            // cold full scan every round)
            let mut engine = Engine::on_runtime_with_centroids(&view, &ecfg, rt, centroids)?;
            name = engine.name().to_string();
            while !engine.converged() && rounds < cfg.max_iters {
                if let Some(limit) = cfg.time_limit {
                    if start.elapsed() > limit {
                        break;
                    }
                }
                let t_round = Instant::now();
                let ctr_before = engine.counters();
                let moved = engine.step();
                if cfg.record_rounds {
                    round_times.push(t_round.elapsed());
                }
                rounds += 1;
                schedule.push(view.n());
                if let Some(obs) = observer {
                    obs.round(&RoundObservation {
                        site: "minibatch",
                        round: rounds,
                        moved,
                        mse: engine.mse(),
                        delta: engine.counters().since(&ctr_before),
                        imbalance: engine.sched().imbalance(),
                        batch_rows: Some(view.n()),
                    });
                }
            }
            converged = engine.converged();
            centroids = engine.centroids().to_vec();
            counters.merge(&engine.counters());
            phases.merge(&engine.phases());
            sched.merge(&engine.sched());
            break;
        }
        let t_round = Instant::now();
        // assignment scan + cluster-sum build run unchanged through the
        // engine, seeded from the current centroids
        let (sums, counts, round_ctr, round_imb, batch_mse) = {
            let engine = Engine::on_runtime_with_centroids(&view, &ecfg, rt, centroids.clone())?;
            name = engine.name().to_string();
            counters.merge(&engine.counters());
            phases.merge(&engine.phases());
            sched.merge(&engine.sched());
            // batch objective read only when someone is watching — the
            // fit itself never depends on it
            let mse = match observer {
                Some(_) => engine.mse(),
                None => f64::NAN,
            };
            let update = engine.update_state();
            (
                update.sums().to_vec(),
                update.counts().to_vec(),
                engine.counters(),
                engine.sched().imbalance(),
                mse,
            )
        };

        // decayed centroid update with carried per-centroid counts;
        // empty clusters keep their position (as in the exact engine)
        let t_update = Instant::now();
        let mut moved = 0usize;
        for (j, carried) in carry.iter_mut().enumerate() {
            let count = counts[j] as f64;
            let prior = if nested { 0.0 } else { *carried };
            if count > 0.0 {
                let row = &mut centroids[j * d..(j + 1) * d];
                let sum = &sums[j * d..(j + 1) * d];
                let inv = 1.0 / (prior + count);
                let mut changed = false;
                for (t, c) in row.iter_mut().enumerate() {
                    let next = (prior * *c + sum[t]) * inv;
                    if next != *c {
                        changed = true;
                    }
                    *c = next;
                }
                if changed {
                    moved += 1;
                }
            }
            *carried = if nested { count } else { *carried + count };
        }
        phases.update += t_update.elapsed();

        if cfg.record_rounds {
            round_times.push(t_round.elapsed());
        }
        rounds += 1;
        schedule.push(view.n());
        if let Some(obs) = observer {
            obs.round(&RoundObservation {
                site: "minibatch",
                round: rounds,
                // here `moved` counts centroids displaced by the decayed
                // update (per-sample movement is not defined across
                // redraws)
                moved,
                mse: batch_mse,
                delta: round_ctr,
                imbalance: round_imb,
                batch_rows: Some(view.n()),
            });
        }
        if moved == 0 && view.is_full() {
            // the batch is the whole dataset and nothing moved: this is
            // the exact Lloyd fixed point. Reachable only in redraw
            // mode when the k-clamp raised b0 to n (k = n); nested
            // full views are consumed by the tail branch above.
            converged = true;
            break;
        }
        if nested {
            let next = ((view.n() as f64 * growth).ceil() as usize)
                .max(view.n() + 1)
                .min(n);
            view.grow(data, next, &mut sample_rng);
        } else {
            // fresh Sculley-style batch, reusing the pool + buffers:
            // O(batch) per round, not O(n)
            view.resample(data, &mut sample_rng);
        }
    }

    // final full-data labelling on the fitted centroids — the same
    // element-wise kernel as `FittedModel::predict`, width-independent
    let t_scan = Instant::now();
    let cnorms = sqnorms_rows(&centroids, d);
    let mut assignments = vec![0u32; n];
    nearest_labels(rt.pool(), data, &centroids, &cnorms, &mut assignments);
    phases.scan += t_scan.elapsed();
    let mse = data.mse(&centroids, &assignments);
    let wall = start.elapsed();
    let io = match (io_before, data.io_stats()) {
        (Some(before), Some(after)) => Some(after.since(&before)),
        _ => None,
    };

    let report = RunReport {
        algorithm: name,
        dataset: data.name().to_string(),
        k,
        n,
        seed: cfg.seed,
        iterations: rounds,
        converged,
        mse,
        wall,
        threads: rt.threads(),
        phases,
        counters,
        round_times,
        batch: Some(BatchTelemetry {
            batch_size: b0,
            growth,
            schedule,
        }),
        io,
        sched,
    };
    Ok(RunOutput {
        assignments,
        centroids,
        iterations: rounds,
        converged,
        mse,
        counters,
        wall,
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Runner;
    use crate::data::synth::blobs;

    fn cfg(k: usize) -> RunConfig {
        RunConfig::new(Algorithm::ExpNs, k).seed(5).max_iters(60)
    }

    #[test]
    fn nested_run_doubles_until_coverage_and_converges() {
        let ds = blobs(2_000, 4, 6, 0.1, 3);
        let out = Runner::new(&cfg(6).batch_size(125).batch_growth(2.0))
            .run(&ds)
            .unwrap();
        assert!(out.converged, "nested run should reach the Lloyd fixed point");
        let batch = out.report.batch.as_ref().expect("batch telemetry recorded");
        assert_eq!(batch.batch_size, 125);
        assert_eq!(batch.growth, 2.0);
        // the schedule is the doubling staircase, capped at n
        assert_eq!(batch.schedule[0], 125);
        assert!(batch.schedule.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(*batch.schedule.last().unwrap(), 2_000);
        assert_eq!(out.assignments.len(), 2_000);
        assert!(out.mse.is_finite());
    }

    #[test]
    fn redraw_run_keeps_a_flat_schedule() {
        let ds = blobs(1_500, 3, 5, 0.15, 7);
        let out = Runner::new(&cfg(5).batch_size(200).batch_growth(1.0).max_iters(12))
            .run(&ds)
            .unwrap();
        let batch = out.report.batch.as_ref().unwrap();
        assert_eq!(batch.schedule, vec![200; 12]);
        assert!(!out.converged, "redraw refines until the round budget");
        assert!(out.mse.is_finite());
    }

    #[test]
    fn batch_size_is_clamped_to_seat_every_cluster() {
        let ds = blobs(800, 3, 10, 0.1, 2);
        // requested batch smaller than k: clamped up, not an error
        let out = Runner::new(&cfg(10).batch_size(4)).run(&ds).unwrap();
        assert_eq!(out.report.batch.as_ref().unwrap().batch_size, 10);
    }

    #[test]
    fn same_seed_reproduces_bit_identically_and_seeds_differ() {
        let ds = blobs(1_800, 4, 7, 0.12, 9);
        let config = cfg(7).batch_size(190).batch_growth(2.0);
        let a = Runner::new(&config).run(&ds).unwrap();
        let b = Runner::new(&config).run(&ds).unwrap();
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.mse.to_bits(), b.mse.to_bits());
        assert_eq!(a.report.batch, b.report.batch);
        let c = Runner::new(&config.seed(99)).run(&ds).unwrap();
        assert_ne!(
            a.centroids.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            c.centroids.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "a different seed must draw different batches/seeding"
        );
    }
}
