//! Sorted centroid norms — the Annular algorithm's per-round index.
//!
//! ann filters candidates through the origin-centred annulus
//! `{ j : | ‖c(j)‖ − ‖x(i)‖ | ≤ R(i) }` (paper eq. 9); keeping `‖c(j)‖`
//! sorted lets the window be found with two binary searches (Θ(log k)).

/// Centroid norms sorted ascending, remembering original indices.
#[derive(Clone, Debug)]
pub struct SortedNorms {
    /// (‖c(j)‖, j) sorted by norm.
    entries: Vec<(f64, u32)>,
}

impl SortedNorms {
    /// Build from pre-computed squared centroid norms.
    pub fn build(cnorms_sq: &[f64]) -> Self {
        let mut entries: Vec<(f64, u32)> = cnorms_sq
            .iter()
            .enumerate()
            .map(|(j, &sq)| (sq.sqrt(), j as u32))
            .collect();
        entries.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        SortedNorms { entries }
    }

    /// All centroid indices j with `| ‖c(j)‖ − xnorm | ≤ r`, via two
    /// binary searches.
    pub fn window(&self, xnorm: f64, r: f64) -> impl Iterator<Item = u32> + '_ {
        let lo_val = xnorm - r;
        let hi_val = xnorm + r;
        let lo = self.entries.partition_point(|e| e.0 < lo_val);
        let hi = self.entries.partition_point(|e| e.0 <= hi_val);
        self.entries[lo..hi].iter().map(|e| e.1)
    }

    /// Number of centroids in the window without materialising it.
    pub fn window_len(&self, xnorm: f64, r: f64) -> usize {
        let lo = self.entries.partition_point(|e| e.0 < xnorm - r);
        let hi = self.entries.partition_point(|e| e.0 <= xnorm + r);
        hi - lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_finds_expected_indices() {
        // norms: sqrt of squared norms 1,16,4,25 → 1,4,2,5 (idx 0..3)
        let sn = SortedNorms::build(&[1.0, 16.0, 4.0, 25.0]);
        let mut w: Vec<u32> = sn.window(3.0, 1.5).collect();
        w.sort_unstable();
        assert_eq!(w, vec![1, 2]); // norms 4 and 2 within [1.5, 4.5]
        assert_eq!(sn.window_len(3.0, 1.5), 2);
    }

    #[test]
    fn window_boundaries_inclusive() {
        let sn = SortedNorms::build(&[4.0, 9.0]); // norms 2, 3
        let w: Vec<u32> = sn.window(2.5, 0.5).collect();
        assert_eq!(w.len(), 2); // both 2 and 3 at exactly distance 0.5
    }

    #[test]
    fn empty_window() {
        let sn = SortedNorms::build(&[1.0, 4.0]);
        assert_eq!(sn.window_len(100.0, 1.0), 0);
    }

    #[test]
    fn whole_range_window() {
        let sn = SortedNorms::build(&[1.0, 4.0, 9.0]);
        assert_eq!(sn.window_len(0.0, 100.0), 3);
    }
}
