//! Owner of all centroid-side per-round structures.
//!
//! The [`Engine`](super::runner::Engine) mutates this once per round (only
//! rebuilding what the active algorithm's [`Requirements`] ask for) and
//! every worker borrows it immutably through [`SharedRound`].

use crate::algorithms::common::{Requirements, SharedRound};
use crate::coordinator::annuli::Annuli;
use crate::coordinator::ccdist::CcData;
use crate::coordinator::groups::GroupData;
use crate::coordinator::history::HistoryRound;
use crate::coordinator::sorted_norms::SortedNorms;
use crate::data::{DataSource, Dataset};
use crate::linalg::{sqdist, sqnorm, sqnorms_rows};
use crate::metrics::Counters;
use crate::runtime::pool::{SharedSliceMut, WorkerPool};

/// Centroid-side state for the current round.
pub struct RoundCtxOwner {
    /// Number of clusters.
    pub k: usize,
    /// Current round (0 = initial assignment).
    pub round: usize,
    /// Current centroids `k×d`.
    pub centroids: Vec<f64>,
    /// `‖c(j)‖²`.
    pub cnorms: Vec<f64>,
    /// Last-round displacement `p(j)`.
    pub p: Vec<f64>,
    /// max / second-max / argmax of `p`.
    pub p_max: f64,
    /// Second-largest displacement.
    pub p_max2: f64,
    /// Index attaining `p_max`.
    pub p_argmax: usize,
    /// Inter-centroid data (if required).
    pub cc: Option<CcData>,
    /// Sorted centroid norms (if required).
    pub sorted_norms: Option<SortedNorms>,
    /// Exponion annuli (if required).
    pub annuli: Option<Annuli>,
    /// Yinyang groups (if required; persists across rounds).
    pub groups: Option<GroupData>,
    /// ns history view for this round (if required).
    pub history: Option<HistoryRound>,
}

impl RoundCtxOwner {
    /// Create for round 0 with the initial centroids.
    pub fn new(centroids: Vec<f64>, k: usize, d: usize) -> Self {
        assert_eq!(centroids.len(), k * d);
        let cnorms = sqnorms_rows(&centroids, d);
        RoundCtxOwner {
            k,
            round: 0,
            centroids,
            cnorms,
            p: vec![0.0; k],
            p_max: 0.0,
            p_max2: 0.0,
            p_argmax: 0,
            cc: None,
            sorted_norms: None,
            annuli: None,
            groups: None,
            history: None,
        }
    }

    /// Test-only convenience: a fully-populated context (cc + sorted
    /// norms + annuli) so unit tests can exercise any algorithm's init.
    pub fn new_for_test(data: &Dataset, centroids: Vec<f64>) -> Self {
        let d = data.d();
        let k = centroids.len() / d;
        let mut ctx = RoundCtxOwner::new(centroids, k, d);
        let mut ctr = Counters::default();
        ctx.cc = Some(CcData::build(&ctx.centroids, k, d, &mut ctr));
        ctx.sorted_norms = Some(SortedNorms::build(&ctx.cnorms));
        ctx.annuli = Some(Annuli::build(ctx.cc.as_ref().unwrap()));
        ctx
    }

    /// Install new centroids, computing `p(j)` and its maxima.
    /// Counts k displacement distances.
    pub fn advance_centroids(&mut self, new: Vec<f64>, d: usize, ctr: &mut Counters) {
        self.advance_centroids_pooled(new, d, ctr, &WorkerPool::serial());
    }

    /// As [`RoundCtxOwner::advance_centroids`], computing `p(j)` and the
    /// centroid norms in parallel over centroids. Per-element math, so
    /// bit-identical at any pool width; the `p` maxima scan stays serial
    /// (O(k), and its result feeds every shard).
    pub fn advance_centroids_pooled(
        &mut self,
        new: Vec<f64>,
        d: usize,
        ctr: &mut Counters,
        pool: &WorkerPool,
    ) {
        debug_assert_eq!(new.len(), self.k * d);
        {
            let old = &self.centroids;
            let p = SharedSliceMut::new(&mut self.p);
            pool.for_each_chunk(self.k, 32, |lo, hi| {
                let dst = unsafe { p.range(lo, hi) };
                for (off, pv) in dst.iter_mut().enumerate() {
                    let j = lo + off;
                    *pv = sqdist(&old[j * d..(j + 1) * d], &new[j * d..(j + 1) * d]).sqrt();
                }
            });
        }
        ctr.displacement += self.k as u64;
        self.centroids = new;
        self.cnorms = sqnorms_rows_pooled(&self.centroids, d, pool);
        let (mut m1, mut a1, mut m2) = (f64::NEG_INFINITY, 0usize, f64::NEG_INFINITY);
        for (j, &v) in self.p.iter().enumerate() {
            if v > m1 {
                m2 = m1;
                m1 = v;
                a1 = j;
            } else if v > m2 {
                m2 = v;
            }
        }
        self.p_max = m1.max(0.0);
        self.p_max2 = m2.max(0.0);
        self.p_argmax = a1;
        self.round += 1;
    }

    /// Rebuild the optional per-round structures per `req`, sharding
    /// each build over the pool.
    pub fn rebuild(&mut self, req: &Requirements, d: usize, ctr: &mut Counters, pool: &WorkerPool) {
        if req.cc {
            let cc = CcData::build_pooled(&self.centroids, self.k, d, ctr, pool);
            if req.annuli {
                // reuse last round's buffers
                let mut ann = self.annuli.take().unwrap_or_else(Annuli::empty);
                ann.build_into_fast_pooled(&cc, pool);
                self.annuli = Some(ann);
            }
            self.cc = Some(cc);
        }
        if req.sorted_norms {
            self.sorted_norms = Some(SortedNorms::build(&self.cnorms));
        }
        if req.groups {
            if let Some(g) = self.groups.as_mut() {
                g.refresh_pooled(&self.p, pool);
            }
        }
    }

    /// Borrow as the per-round shared view.
    pub fn shared<'a>(&'a self, data: &'a dyn DataSource) -> SharedRound<'a> {
        SharedRound {
            data,
            k: self.k,
            round: self.round,
            centroids: &self.centroids,
            cnorms: &self.cnorms,
            p: &self.p,
            p_max: self.p_max,
            p_max2: self.p_max2,
            p_argmax: self.p_argmax,
            cc: self.cc.as_ref(),
            sorted_norms: self.sorted_norms.as_ref(),
            annuli: self.annuli.as_ref(),
            groups: self.groups.as_ref(),
            history: self.history.as_ref(),
        }
    }
}

/// `‖row‖²` per row, sharded over the pool (element-wise, so
/// bit-identical to [`sqnorms_rows`] at any width).
fn sqnorms_rows_pooled(rows: &[f64], d: usize, pool: &WorkerPool) -> Vec<f64> {
    if pool.width() == 1 {
        return sqnorms_rows(rows, d);
    }
    let m = rows.len() / d;
    let mut out = vec![0.0; m];
    {
        let cells = SharedSliceMut::new(&mut out);
        pool.for_each_chunk(m, 64, |lo, hi| {
            let dst = unsafe { cells.range(lo, hi) };
            for (off, nv) in dst.iter_mut().enumerate() {
                let i = lo + off;
                *nv = sqnorm(&rows[i * d..(i + 1) * d]);
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;

    #[test]
    fn advance_tracks_displacements() {
        let mut ctx = RoundCtxOwner::new(vec![0.0, 0.0, 1.0, 1.0], 2, 2);
        let mut ctr = Counters::default();
        ctx.advance_centroids(vec![3.0, 4.0, 1.0, 1.0], 2, &mut ctr);
        assert_eq!(ctx.p, vec![5.0, 0.0]);
        assert_eq!(ctx.p_max, 5.0);
        assert_eq!(ctx.p_argmax, 0);
        assert_eq!(ctx.p_max2, 0.0);
        assert_eq!(ctx.round, 1);
        assert_eq!(ctr.displacement, 2);
    }

    #[test]
    fn rebuild_builds_requested_structures() {
        let ds = blobs(50, 3, 2, 0.2, 1);
        let centroids = ds.raw()[..5 * 3].to_vec();
        let mut ctx = RoundCtxOwner::new(centroids, 5, 3);
        let mut ctr = Counters::default();
        let req = Requirements {
            cc: true,
            annuli: true,
            sorted_norms: true,
            ..Default::default()
        };
        ctx.rebuild(&req, 3, &mut ctr, &WorkerPool::serial());
        assert!(ctx.cc.is_some());
        assert!(ctx.annuli.is_some());
        assert!(ctx.sorted_norms.is_some());
        assert!(ctr.centroid > 0);
    }

    #[test]
    fn pooled_advance_matches_serial() {
        let k = 70;
        let d = 4;
        let old: Vec<f64> = (0..k * d).map(|i| (i as f64 * 0.37).sin()).collect();
        let new: Vec<f64> = (0..k * d).map(|i| (i as f64 * 0.91).cos()).collect();
        let mut want = RoundCtxOwner::new(old.clone(), k, d);
        want.advance_centroids(new.clone(), d, &mut Counters::default());
        for threads in [2, 8] {
            let pool = WorkerPool::new(threads);
            let mut got = RoundCtxOwner::new(old.clone(), k, d);
            got.advance_centroids_pooled(new.clone(), d, &mut Counters::default(), &pool);
            assert_eq!(got.p, want.p, "threads={threads}");
            assert_eq!(got.cnorms, want.cnorms, "threads={threads}");
            assert_eq!(got.p_max, want.p_max);
            assert_eq!(got.p_max2, want.p_max2);
            assert_eq!(got.p_argmax, want.p_argmax);
        }
    }
}
