//! Owner of all centroid-side per-round structures.
//!
//! The [`Engine`](super::runner::Engine) mutates this once per round (only
//! rebuilding what the active algorithm's [`Requirements`] ask for) and
//! every worker borrows it immutably through [`SharedRound`].

use crate::algorithms::common::{Requirements, SharedRound};
use crate::coordinator::annuli::Annuli;
use crate::coordinator::ccdist::CcData;
use crate::coordinator::groups::GroupData;
use crate::coordinator::history::HistoryRound;
use crate::coordinator::sorted_norms::SortedNorms;
use crate::data::Dataset;
use crate::linalg::{sqdist, sqnorms_rows};
use crate::metrics::Counters;

/// Centroid-side state for the current round.
pub struct RoundCtxOwner {
    /// Number of clusters.
    pub k: usize,
    /// Current round (0 = initial assignment).
    pub round: usize,
    /// Current centroids `k×d`.
    pub centroids: Vec<f64>,
    /// `‖c(j)‖²`.
    pub cnorms: Vec<f64>,
    /// Last-round displacement `p(j)`.
    pub p: Vec<f64>,
    /// max / second-max / argmax of `p`.
    pub p_max: f64,
    /// Second-largest displacement.
    pub p_max2: f64,
    /// Index attaining `p_max`.
    pub p_argmax: usize,
    /// Inter-centroid data (if required).
    pub cc: Option<CcData>,
    /// Sorted centroid norms (if required).
    pub sorted_norms: Option<SortedNorms>,
    /// Exponion annuli (if required).
    pub annuli: Option<Annuli>,
    /// Yinyang groups (if required; persists across rounds).
    pub groups: Option<GroupData>,
    /// ns history view for this round (if required).
    pub history: Option<HistoryRound>,
}

impl RoundCtxOwner {
    /// Create for round 0 with the initial centroids.
    pub fn new(centroids: Vec<f64>, k: usize, d: usize) -> Self {
        assert_eq!(centroids.len(), k * d);
        let cnorms = sqnorms_rows(&centroids, d);
        RoundCtxOwner {
            k,
            round: 0,
            centroids,
            cnorms,
            p: vec![0.0; k],
            p_max: 0.0,
            p_max2: 0.0,
            p_argmax: 0,
            cc: None,
            sorted_norms: None,
            annuli: None,
            groups: None,
            history: None,
        }
    }

    /// Test-only convenience: a fully-populated context (cc + sorted
    /// norms + annuli) so unit tests can exercise any algorithm's init.
    pub fn new_for_test(data: &Dataset, centroids: Vec<f64>) -> Self {
        let d = data.d();
        let k = centroids.len() / d;
        let mut ctx = RoundCtxOwner::new(centroids, k, d);
        let mut ctr = Counters::default();
        ctx.cc = Some(CcData::build(&ctx.centroids, k, d, &mut ctr));
        ctx.sorted_norms = Some(SortedNorms::build(&ctx.cnorms));
        ctx.annuli = Some(Annuli::build(ctx.cc.as_ref().unwrap()));
        ctx
    }

    /// Install new centroids, computing `p(j)` and its maxima.
    /// Counts k displacement distances.
    pub fn advance_centroids(&mut self, new: Vec<f64>, d: usize, ctr: &mut Counters) {
        debug_assert_eq!(new.len(), self.k * d);
        for j in 0..self.k {
            self.p[j] = sqdist(
                &self.centroids[j * d..(j + 1) * d],
                &new[j * d..(j + 1) * d],
            )
            .sqrt();
        }
        ctr.displacement += self.k as u64;
        self.centroids = new;
        self.cnorms = sqnorms_rows(&self.centroids, d);
        let (mut m1, mut a1, mut m2) = (f64::NEG_INFINITY, 0usize, f64::NEG_INFINITY);
        for (j, &v) in self.p.iter().enumerate() {
            if v > m1 {
                m2 = m1;
                m1 = v;
                a1 = j;
            } else if v > m2 {
                m2 = v;
            }
        }
        self.p_max = m1.max(0.0);
        self.p_max2 = m2.max(0.0);
        self.p_argmax = a1;
        self.round += 1;
    }

    /// Rebuild the optional per-round structures per `req`.
    pub fn rebuild(&mut self, req: &Requirements, d: usize, ctr: &mut Counters) {
        if req.cc {
            let cc = CcData::build(&self.centroids, self.k, d, ctr);
            if req.annuli {
                // reuse last round's buffers
                let mut ann = self.annuli.take().unwrap_or_else(Annuli::empty);
                ann.build_into_fast(&cc);
                self.annuli = Some(ann);
            }
            self.cc = Some(cc);
        }
        if req.sorted_norms {
            self.sorted_norms = Some(SortedNorms::build(&self.cnorms));
        }
        if req.groups {
            if let Some(g) = self.groups.as_mut() {
                g.refresh(&self.p);
            }
        }
    }

    /// Borrow as the per-round shared view.
    pub fn shared<'a>(&'a self, data: &'a Dataset) -> SharedRound<'a> {
        SharedRound {
            data,
            k: self.k,
            round: self.round,
            centroids: &self.centroids,
            cnorms: &self.cnorms,
            p: &self.p,
            p_max: self.p_max,
            p_max2: self.p_max2,
            p_argmax: self.p_argmax,
            cc: self.cc.as_ref(),
            sorted_norms: self.sorted_norms.as_ref(),
            annuli: self.annuli.as_ref(),
            groups: self.groups.as_ref(),
            history: self.history.as_ref(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;

    #[test]
    fn advance_tracks_displacements() {
        let mut ctx = RoundCtxOwner::new(vec![0.0, 0.0, 1.0, 1.0], 2, 2);
        let mut ctr = Counters::default();
        ctx.advance_centroids(vec![3.0, 4.0, 1.0, 1.0], 2, &mut ctr);
        assert_eq!(ctx.p, vec![5.0, 0.0]);
        assert_eq!(ctx.p_max, 5.0);
        assert_eq!(ctx.p_argmax, 0);
        assert_eq!(ctx.p_max2, 0.0);
        assert_eq!(ctx.round, 1);
        assert_eq!(ctr.displacement, 2);
    }

    #[test]
    fn rebuild_builds_requested_structures() {
        let ds = blobs(50, 3, 2, 0.2, 1);
        let centroids = ds.raw()[..5 * 3].to_vec();
        let mut ctx = RoundCtxOwner::new(centroids, 5, 3);
        let mut ctr = Counters::default();
        let req = Requirements {
            cc: true,
            annuli: true,
            sorted_norms: true,
            ..Default::default()
        };
        ctx.rebuild(&req, 3, &mut ctr);
        assert!(ctx.cc.is_some());
        assert!(ctx.annuli.is_some());
        assert!(ctx.sorted_norms.is_some());
        assert!(ctr.centroid > 0);
    }
}
