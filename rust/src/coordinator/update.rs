//! The update step: cluster sums/counts and new centroid computation.
//!
//! Implements the paper's §4.1.1 "delta" optimisation — between rounds,
//! sums change only for the samples whose assignment changed, so the
//! update is `O(|moved|·d)` instead of `O(N·d)`. Empty clusters keep
//! their previous centroid (so `p(j)=0`), preserving exactness.
//!
//! The `*_pooled` variants shard the work over the persistent
//! [`WorkerPool`]. Sum reductions use per-chunk partial sums merged in
//! chunk order, with chunk geometry derived from the item count alone —
//! never from the pool width — so the resulting centroids are
//! bit-identical across thread counts.

use crate::algorithms::common::Moved;
use crate::data::DataSource;
use crate::runtime::pool::{SharedSliceMut, WorkerPool};

/// Minimum items per reduction chunk: below this, sharding costs more
/// (zeroed `k×d` partials) than it saves.
const CHUNK_ITEMS: usize = 4096;
/// Cap on reduction chunks, bounding partial-buffer memory at
/// `MAX_CHUNKS · k · d` floats.
const MAX_CHUNKS: usize = 16;

/// Chunk length for `items` — a function of the item count only, so the
/// serial/sharded decision and the chunk boundaries (and therefore the
/// floating-point merge order) are identical at every pool width.
/// `pub(crate)`: the distributed coordinator derives the same chunk
/// geometry from the *global* row count so shard-computed partials drop
/// into the identical merge.
pub(crate) fn chunk_len(items: usize) -> usize {
    items.div_ceil(MAX_CHUNKS).max(CHUNK_ITEMS)
}

/// One chunk's partial contribution to the cluster sums.
pub(crate) struct Partial {
    pub(crate) sums: Vec<f64>,
    pub(crate) counts: Vec<i64>,
    pub(crate) touched: Vec<bool>,
}

impl Partial {
    pub(crate) fn new(k: usize, d: usize) -> Self {
        Partial {
            sums: vec![0.0; k * d],
            counts: vec![0i64; k],
            touched: vec![false; k],
        }
    }
}

/// Accumulate rows `[lo, lo+len)` of `data` into `part` under the
/// assignment slice `a`, which starts at global row `a_off` (so row `i`
/// is assigned to `a[i - a_off]`). This is the one inner loop behind
/// every full-sum pass — the single-node pooled chunks (`a_off = 0`)
/// and each dist shard's partial-sum scan (`a_off =` the shard's first
/// row) run literally this code, which is what makes their accumulation
/// bit-identical.
pub(crate) fn scan_chunk(
    data: &dyn DataSource,
    a: &[u32],
    a_off: usize,
    lo: usize,
    len: usize,
    d: usize,
    part: &mut Partial,
) {
    let mut cur = data.open(lo, len);
    for (i, &j) in a[lo - a_off..lo - a_off + len].iter().enumerate() {
        let j = j as usize;
        part.counts[j] += 1;
        let row = cur.row(lo + i);
        let s = &mut part.sums[j * d..(j + 1) * d];
        for (t, v) in row.iter().enumerate() {
            s[t] += v;
        }
    }
}

/// Fold per-chunk `(sums, counts)` partials — in iteration order — into
/// an [`UpdateState`]. The single-node pooled path and the distributed
/// coordinator both merge through this loop, so as long as the chunk
/// geometry matches, the resulting sums are bit-identical.
pub(crate) fn merge_partial_sums<'p>(
    parts: impl Iterator<Item = (&'p [f64], &'p [i64])>,
    k: usize,
    d: usize,
) -> UpdateState {
    let mut sums = vec![0.0; k * d];
    let mut counts = vec![0u64; k];
    for (psums, pcounts) in parts {
        for (t, v) in psums.iter().enumerate() {
            sums[t] += v;
        }
        for (j, c) in pcounts.iter().enumerate() {
            counts[j] += *c as u64;
        }
    }
    UpdateState { sums, counts, k }
}

/// Running cluster sums and member counts.
#[derive(Clone, Debug)]
pub struct UpdateState {
    sums: Vec<f64>,
    counts: Vec<u64>,
    k: usize,
}

impl UpdateState {
    /// Build from a full assignment (used at init and by `full_update`).
    pub fn from_assignments(data: &dyn DataSource, a: &[u32], k: usize) -> Self {
        Self::from_assignments_pooled(data, a, k, &WorkerPool::serial())
    }

    /// As [`UpdateState::from_assignments`], sharded over the pool.
    /// Each chunk's worker opens its own block cursor, so out-of-core
    /// sources stream the pass through per-worker windows.
    pub fn from_assignments_pooled(
        data: &dyn DataSource,
        a: &[u32],
        k: usize,
        pool: &WorkerPool,
    ) -> Self {
        let (n, d) = (data.n(), data.d());
        let clen = chunk_len(n);
        if n <= clen {
            return Self::from_assignments_serial(data, a, k);
        }
        let nchunks = n.div_ceil(clen);
        let mut partials: Vec<Partial> = (0..nchunks).map(|_| Partial::new(k, d)).collect();
        pool.run_tasks(&mut partials, |c, part| {
            let lo = c * clen;
            let hi = (lo + clen).min(n);
            scan_chunk(data, a, 0, lo, hi - lo, d, part);
        });
        // merge in chunk order — deterministic at any pool width
        merge_partial_sums(
            partials.iter().map(|p| (&p.sums[..], &p.counts[..])),
            k,
            d,
        )
    }

    fn from_assignments_serial(data: &dyn DataSource, a: &[u32], k: usize) -> Self {
        let d = data.d();
        let mut sums = vec![0.0; k * d];
        let mut counts = vec![0u64; k];
        let mut cur = data.open(0, data.n());
        for (i, &j) in a.iter().enumerate() {
            let j = j as usize;
            counts[j] += 1;
            let row = cur.row(i);
            let s = &mut sums[j * d..(j + 1) * d];
            for (t, v) in row.iter().enumerate() {
                s[t] += v;
            }
        }
        UpdateState { sums, counts, k }
    }

    /// Apply one round's assignment changes (delta update). Moves arrive
    /// in ascending sample order, so the cursor advances monotonically —
    /// an out-of-core source refills forward only.
    pub fn apply_moves(&mut self, data: &dyn DataSource, moved: &[Moved]) {
        let d = data.d();
        let mut cur = data.open(0, data.n());
        for m in moved {
            let row = cur.row(m.i as usize);
            let from = &mut self.sums[m.from as usize * d..(m.from as usize + 1) * d];
            for (t, v) in row.iter().enumerate() {
                from[t] -= v;
            }
            let to = &mut self.sums[m.to as usize * d..(m.to as usize + 1) * d];
            for (t, v) in row.iter().enumerate() {
                to[t] += v;
            }
            self.counts[m.from as usize] -= 1;
            self.counts[m.to as usize] += 1;
        }
    }

    /// As [`UpdateState::apply_moves`], sharded over the pool: each chunk
    /// of the moved list accumulates a private partial delta, and the
    /// partials are folded into the running sums in chunk order.
    pub fn apply_moves_pooled(&mut self, data: &dyn DataSource, moved: &[Moved], pool: &WorkerPool) {
        let d = data.d();
        let clen = chunk_len(moved.len());
        if moved.len() <= clen {
            self.apply_moves(data, moved);
            return;
        }
        let k = self.k;
        let nchunks = moved.len().div_ceil(clen);
        let mut partials: Vec<Partial> = (0..nchunks).map(|_| Partial::new(k, d)).collect();
        pool.run_tasks(&mut partials, |c, part| {
            let lo = c * clen;
            let hi = (lo + clen).min(moved.len());
            // this chunk touches rows [moved[lo].i, moved[hi-1].i] in
            // ascending order — open the cursor for exactly that span
            let row_lo = moved[lo].i as usize;
            let row_hi = moved[hi - 1].i as usize + 1;
            let mut cur = data.open(row_lo, row_hi - row_lo);
            for m in &moved[lo..hi] {
                let (from, to) = (m.from as usize, m.to as usize);
                let row = cur.row(m.i as usize);
                part.touched[from] = true;
                part.touched[to] = true;
                let s = &mut part.sums[from * d..(from + 1) * d];
                for (t, v) in row.iter().enumerate() {
                    s[t] -= v;
                }
                let s = &mut part.sums[to * d..(to + 1) * d];
                for (t, v) in row.iter().enumerate() {
                    s[t] += v;
                }
                part.counts[from] -= 1;
                part.counts[to] += 1;
            }
        });
        // merge touched rows in chunk order — deterministic at any width
        for part in &partials {
            for (j, touched) in part.touched.iter().enumerate() {
                if !touched {
                    continue;
                }
                let dst = &mut self.sums[j * d..(j + 1) * d];
                let src = &part.sums[j * d..(j + 1) * d];
                for (t, dv) in dst.iter_mut().enumerate() {
                    *dv += src[t];
                }
                self.counts[j] = (self.counts[j] as i64 + part.counts[j]) as u64;
            }
        }
    }

    /// Compute new centroids; empty clusters keep `old`'s position.
    pub fn centroids(&self, old: &[f64], d: usize) -> Vec<f64> {
        self.centroids_pooled(old, d, &WorkerPool::serial())
    }

    /// As [`UpdateState::centroids`], parallel over centroids. Each row
    /// is computed independently (no reduction), so the result is
    /// bit-identical at any pool width.
    pub fn centroids_pooled(&self, old: &[f64], d: usize, pool: &WorkerPool) -> Vec<f64> {
        let k = self.k;
        let mut out = vec![0.0; k * d];
        {
            let rows = SharedSliceMut::new(&mut out);
            pool.for_each_chunk(k, 16, |lo, hi| {
                // rows [lo, hi) are disjoint across chunks
                let dst = unsafe { rows.range(lo * d, hi * d) };
                for (off, row) in dst.chunks_mut(d).enumerate() {
                    let j = lo + off;
                    if self.counts[j] == 0 {
                        row.copy_from_slice(&old[j * d..(j + 1) * d]);
                    } else {
                        let inv = 1.0 / self.counts[j] as f64;
                        let src = &self.sums[j * d..(j + 1) * d];
                        for (t, dv) in row.iter_mut().enumerate() {
                            *dv = src[t] * inv;
                        }
                    }
                }
            });
        }
        out
    }

    /// Member count of cluster j.
    pub fn count(&self, j: usize) -> u64 {
        self.counts[j]
    }

    /// Member counts of every cluster.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Per-cluster coordinate sums, row-major `k×d` (the mini-batch
    /// driver folds these into its decayed centroid update).
    pub fn sums(&self) -> &[f64] {
        &self.sums
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    fn toy() -> Dataset {
        // four points on a line
        Dataset::new("t", vec![0.0, 1.0, 10.0, 11.0], 4, 1).unwrap()
    }

    #[test]
    fn from_assignments_sums() {
        let ds = toy();
        let st = UpdateState::from_assignments(&ds, &[0, 0, 1, 1], 2);
        let c = st.centroids(&[0.0, 0.0], 1);
        assert_eq!(c, vec![0.5, 10.5]);
        assert_eq!(st.count(0), 2);
    }

    #[test]
    fn delta_equals_recompute() {
        let ds = toy();
        let mut st = UpdateState::from_assignments(&ds, &[0, 0, 1, 1], 2);
        // sample 1 moves cluster 0 → 1
        st.apply_moves(
            &ds,
            &[Moved {
                i: 1,
                from: 0,
                to: 1,
            }],
        );
        let fresh = UpdateState::from_assignments(&ds, &[0, 1, 1, 1], 2);
        assert_eq!(st.centroids(&[0.0, 0.0], 1), fresh.centroids(&[0.0, 0.0], 1));
    }

    #[test]
    fn empty_cluster_keeps_old_centroid() {
        let ds = toy();
        let st = UpdateState::from_assignments(&ds, &[0, 0, 0, 0], 2);
        let c = st.centroids(&[7.0, 42.0], 1);
        assert_eq!(c[1], 42.0);
    }

    /// A dataset large enough to force the chunked reduction paths
    /// (`n > chunk_len(n)`).
    fn big() -> (Dataset, Vec<u32>, usize) {
        let k = 7;
        let n = 3 * CHUNK_ITEMS;
        let d = 3;
        let data: Vec<f64> = (0..n * d).map(|i| ((i % 97) as f64) * 0.25 - 3.0).collect();
        let a: Vec<u32> = (0..n).map(|i| (i % k) as u32).collect();
        (Dataset::new("big", data, n, d).unwrap(), a, k)
    }

    #[test]
    fn pooled_from_assignments_is_width_independent() {
        let (ds, a, k) = big();
        let base = UpdateState::from_assignments_pooled(&ds, &a, k, &WorkerPool::serial());
        for threads in [2, 5, 8] {
            let pool = WorkerPool::new(threads);
            let st = UpdateState::from_assignments_pooled(&ds, &a, k, &pool);
            assert_eq!(st.sums, base.sums, "threads={threads}");
            assert_eq!(st.counts, base.counts, "threads={threads}");
        }
    }

    #[test]
    fn pooled_apply_moves_is_width_independent_and_exact() {
        let (ds, mut a, k) = big();
        let mut moved = Vec::new();
        // move every 3rd sample to the next cluster: > chunk_len moves
        for i in (0..ds.n()).step_by(3) {
            let from = a[i];
            let to = (from + 1) % k as u32;
            moved.push(Moved {
                i: i as u32,
                from,
                to,
            });
            a[i] = to;
        }
        assert!(moved.len() > CHUNK_ITEMS);
        // recompute the pre-move state, then delta at several widths
        let mut base: Option<UpdateState> = None;
        for threads in [1, 2, 8] {
            let mut pre = a.clone();
            for m in &moved {
                pre[m.i as usize] = m.from;
            }
            let mut st = UpdateState::from_assignments(&ds, &pre, k);
            let pool = WorkerPool::new(threads);
            st.apply_moves_pooled(&ds, &moved, &pool);
            let base = base.get_or_insert_with(|| st.clone());
            assert_eq!(st.sums, base.sums, "threads={threads}");
            assert_eq!(st.counts, base.counts, "threads={threads}");
            // and the delta stays close to a fresh recompute
            let fresh = UpdateState::from_assignments(&ds, &a, k);
            let old = vec![0.0; k * ds.d()];
            for (got, want) in st
                .centroids(&old, ds.d())
                .iter()
                .zip(fresh.centroids(&old, ds.d()))
            {
                assert!((got - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn pooled_centroids_match_serial() {
        let (ds, a, k) = big();
        let st = UpdateState::from_assignments(&ds, &a, k);
        let old = vec![1.0; k * ds.d()];
        let want = st.centroids(&old, ds.d());
        for threads in [2, 8] {
            let pool = WorkerPool::new(threads);
            assert_eq!(st.centroids_pooled(&old, ds.d(), &pool), want);
        }
    }

    #[test]
    fn chunk_geometry_ignores_width() {
        // chunk_len depends only on the item count
        assert_eq!(chunk_len(100), CHUNK_ITEMS);
        assert_eq!(chunk_len(CHUNK_ITEMS * MAX_CHUNKS), CHUNK_ITEMS);
        assert!(chunk_len(CHUNK_ITEMS * MAX_CHUNKS * 3) == CHUNK_ITEMS * 3);
    }
}
