//! The update step: cluster sums/counts and new centroid computation.
//!
//! Implements the paper's §4.1.1 "delta" optimisation — between rounds,
//! sums change only for the samples whose assignment changed, so the
//! update is `O(|moved|·d)` instead of `O(N·d)`. Empty clusters keep
//! their previous centroid (so `p(j)=0`), preserving exactness.

use crate::algorithms::common::Moved;
use crate::data::Dataset;

/// Running cluster sums and member counts.
#[derive(Clone, Debug)]
pub struct UpdateState {
    sums: Vec<f64>,
    counts: Vec<u64>,
    k: usize,
}

impl UpdateState {
    /// Build from a full assignment (used at init and by `full_update`).
    pub fn from_assignments(data: &Dataset, a: &[u32], k: usize) -> Self {
        let d = data.d();
        let mut sums = vec![0.0; k * d];
        let mut counts = vec![0u64; k];
        for (i, &j) in a.iter().enumerate() {
            let j = j as usize;
            counts[j] += 1;
            let row = data.row(i);
            let s = &mut sums[j * d..(j + 1) * d];
            for (t, v) in row.iter().enumerate() {
                s[t] += v;
            }
        }
        UpdateState { sums, counts, k }
    }

    /// Apply one round's assignment changes (delta update).
    pub fn apply_moves(&mut self, data: &Dataset, moved: &[Moved]) {
        let d = data.d();
        for m in moved {
            let row = data.row(m.i as usize);
            let from = &mut self.sums[m.from as usize * d..(m.from as usize + 1) * d];
            for (t, v) in row.iter().enumerate() {
                from[t] -= v;
            }
            let to = &mut self.sums[m.to as usize * d..(m.to as usize + 1) * d];
            for (t, v) in row.iter().enumerate() {
                to[t] += v;
            }
            self.counts[m.from as usize] -= 1;
            self.counts[m.to as usize] += 1;
        }
    }

    /// Compute new centroids; empty clusters keep `old`'s position.
    pub fn centroids(&self, old: &[f64], d: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.k * d];
        for j in 0..self.k {
            let dst = &mut out[j * d..(j + 1) * d];
            if self.counts[j] == 0 {
                dst.copy_from_slice(&old[j * d..(j + 1) * d]);
            } else {
                let inv = 1.0 / self.counts[j] as f64;
                let src = &self.sums[j * d..(j + 1) * d];
                for (t, dv) in dst.iter_mut().enumerate() {
                    *dv = src[t] * inv;
                }
            }
        }
        out
    }

    /// Member count of cluster j.
    pub fn count(&self, j: usize) -> u64 {
        self.counts[j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    fn toy() -> Dataset {
        // four points on a line
        Dataset::new("t", vec![0.0, 1.0, 10.0, 11.0], 4, 1).unwrap()
    }

    #[test]
    fn from_assignments_sums() {
        let ds = toy();
        let st = UpdateState::from_assignments(&ds, &[0, 0, 1, 1], 2);
        let c = st.centroids(&[0.0, 0.0], 1);
        assert_eq!(c, vec![0.5, 10.5]);
        assert_eq!(st.count(0), 2);
    }

    #[test]
    fn delta_equals_recompute() {
        let ds = toy();
        let mut st = UpdateState::from_assignments(&ds, &[0, 0, 1, 1], 2);
        // sample 1 moves cluster 0 → 1
        st.apply_moves(
            &ds,
            &[Moved {
                i: 1,
                from: 0,
                to: 1,
            }],
        );
        let fresh = UpdateState::from_assignments(&ds, &[0, 1, 1, 1], 2);
        assert_eq!(st.centroids(&[0.0, 0.0], 1), fresh.centroids(&[0.0, 0.0], 1));
    }

    #[test]
    fn empty_cluster_keeps_old_centroid() {
        let ds = toy();
        let st = UpdateState::from_assignments(&ds, &[0, 0, 0, 0], 2);
        let c = st.centroids(&[7.0, 42.0], 1);
        assert_eq!(c[1], 42.0);
    }
}
