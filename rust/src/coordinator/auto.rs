//! Adaptive algorithm selection — the paper's §5 future-work item.
//!
//! The paper's Table 4 regime map: Exponion wins for very low d (< 5),
//! syin for intermediate d (8–69), selk/elk for high d (> 73), with the
//! ns-variants on top (§4.1.4). `resolve` encodes those boundaries.

use crate::algorithms::Algorithm;

/// Pick the algorithm the paper's results say is fastest for dimension d.
pub fn resolve(d: usize) -> Algorithm {
    if d < 8 {
        Algorithm::ExpNs
    } else if d < 70 {
        Algorithm::SyinNs
    } else {
        Algorithm::SelkNs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regime_map_matches_table4() {
        assert_eq!(resolve(2), Algorithm::ExpNs);
        assert_eq!(resolve(4), Algorithm::ExpNs);
        assert_eq!(resolve(10), Algorithm::SyinNs);
        assert_eq!(resolve(55), Algorithm::SyinNs);
        assert_eq!(resolve(74), Algorithm::SelkNs);
        assert_eq!(resolve(784), Algorithm::SelkNs);
    }
}
