//! The round loop: [`Engine`] (stepwise, inspectable) and [`Runner`]
//! (run-to-convergence with limits and telemetry).
//!
//! Engines dispatch every phase of a round — assignment scan, delta
//! centroid update, and the centroid-side rebuilds — onto a persistent
//! [`WorkerPool`], and accumulate per-phase wall time into
//! [`PhaseTimes`] for the run report. The pool is either *shared*
//! (borrowed from a [`Runtime`] via [`Engine::on_runtime`] — the
//! serving configuration: one pool for any number of fits and predicts)
//! or *owned* (spawned by [`Engine::new`] from the config's resolved
//! thread count — the legacy one-shot configuration).
//!
//! Sample data is read through the [`DataSource`] seam, so engines run
//! unchanged over any row source — the in-memory
//! [`Dataset`](crate::data::Dataset), the mini-batch
//! [`BatchView`](crate::data::BatchView) (driven per batch by
//! [`minibatch`](crate::coordinator::minibatch) via
//! [`Engine::on_runtime_with_centroids`]), or future shard sources.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::algorithms::common::{AssignStep, Requirements};
use crate::algorithms::Algorithm;
use crate::config::RunConfig;
use crate::coordinator::groups::GroupData;
use crate::coordinator::history::HistoryStore;
use crate::coordinator::parallel::run_shards;
use crate::coordinator::round_ctx::RoundCtxOwner;
use crate::coordinator::sched::ScanPlan;
use crate::coordinator::update::UpdateState;
use crate::data::DataSource;
use crate::error::{EakmError, Result};
use crate::metrics::{Counters, PhaseTimes, RunReport, SchedTelemetry};
use crate::obs::{FitObserver, RoundObservation};
use crate::rng::Rng;
use crate::runtime::pool::WorkerPool;
use crate::runtime::Runtime;

/// Factory signature: `(lo, len, k, g) → shard state`.
pub type ShardFactory<'f> = dyn Fn(usize, usize, usize, usize) -> Box<dyn AssignStep> + 'f;

/// The engine's pool: borrowed from a shared [`Runtime`], or spawned
/// privately (legacy path).
enum PoolHandle<'a> {
    Owned(WorkerPool),
    Shared(&'a WorkerPool),
}

impl PoolHandle<'_> {
    #[inline]
    fn get(&self) -> &WorkerPool {
        match self {
            PoolHandle::Owned(pool) => pool,
            PoolHandle::Shared(pool) => pool,
        }
    }
}

/// A stepwise k-means engine: one `step()` = one update + assignment
/// round. Exposes everything tests and benches need to inspect.
pub struct Engine<'a> {
    data: &'a dyn DataSource,
    k: usize,
    pool: PoolHandle<'a>,
    algs: Vec<Box<dyn AssignStep>>,
    plan: ScanPlan,
    a: Vec<u32>,
    ctx: RoundCtxOwner,
    update: UpdateState,
    history: Option<HistoryStore>,
    req: Requirements,
    counters: Counters,
    phases: PhaseTimes,
    converged: bool,
    rounds: usize,
    name: String,
    last_moved: usize,
}

impl<'a> Engine<'a> {
    /// Build from a config with a *private* pool sized from
    /// `cfg.resolved_threads()` (resolves `Auto` by dimension). Prefer
    /// [`Engine::on_runtime`] when running more than once per process.
    pub fn new(data: &'a dyn DataSource, cfg: &RunConfig) -> Result<Self> {
        let pool = PoolHandle::Owned(WorkerPool::new(cfg.resolved_threads()));
        Self::build_resolved(data, cfg, pool, None)
    }

    /// Build on a shared [`Runtime`]: the pool is borrowed, nothing is
    /// spawned, and `cfg.threads` is ignored in favour of the runtime's
    /// width.
    pub fn on_runtime(data: &'a dyn DataSource, cfg: &RunConfig, rt: &'a Runtime) -> Result<Self> {
        Self::build_resolved(data, cfg, PoolHandle::Shared(rt.pool()), None)
    }

    /// As [`Engine::on_runtime`], but seeded from explicit `centroids`
    /// (row-major `k×d`) instead of `cfg.init` — the mini-batch driver
    /// rebuilds an engine per batch and continues from the current
    /// model state without consuming the seeding RNG stream.
    pub fn on_runtime_with_centroids(
        data: &'a dyn DataSource,
        cfg: &RunConfig,
        rt: &'a Runtime,
        centroids: Vec<f64>,
    ) -> Result<Self> {
        Self::build_resolved(data, cfg, PoolHandle::Shared(rt.pool()), Some(centroids))
    }

    fn build_resolved(
        data: &'a dyn DataSource,
        cfg: &RunConfig,
        pool: PoolHandle<'a>,
        initial: Option<Vec<f64>>,
    ) -> Result<Self> {
        let alg = match cfg.algorithm {
            Algorithm::Auto => crate::coordinator::auto::resolve(data.d()),
            other => other,
        };
        Self::build(
            data,
            cfg,
            &move |lo, len, k, g| alg.make_shard(lo, len, k, g),
            pool,
            initial,
        )
    }

    /// Build with an arbitrary shard factory (test/bench hook) and a
    /// private pool.
    pub fn with_factory(
        data: &'a dyn DataSource,
        cfg: &RunConfig,
        factory: &ShardFactory,
    ) -> Result<Self> {
        let pool = PoolHandle::Owned(WorkerPool::new(cfg.resolved_threads()));
        Self::build(data, cfg, factory, pool, None)
    }

    fn build(
        data: &'a dyn DataSource,
        cfg: &RunConfig,
        factory: &ShardFactory,
        pool: PoolHandle<'a>,
        initial: Option<Vec<f64>>,
    ) -> Result<Self> {
        if data.n() == 0 || data.d() == 0 {
            // typed guard: without it, seeding would panic on a
            // degenerate source before cfg.validate could explain why
            return Err(EakmError::Data(format!(
                "cannot cluster an empty data source (n={}, d={})",
                data.n(),
                data.d()
            )));
        }
        cfg.validate(data.n())?;
        let (n, d, k) = (data.n(), data.d(), cfg.k);
        let g = GroupData::group_count(k);
        let probe = factory(0, 0, k, g);
        let req = probe.requirements();
        let name = probe.name().to_string();
        drop(probe);

        let mut counters = Counters::default();
        let mut phases = PhaseTimes::default();
        let mut rng = Rng::new(cfg.seed);
        let centroids = match initial {
            Some(c) => {
                if c.len() != k * d {
                    return Err(EakmError::Invariant(format!(
                        "initial centroids have {} values, expected k×d = {}",
                        c.len(),
                        k * d
                    )));
                }
                c
            }
            None => cfg.init.centroids(data, k, &mut rng, &mut counters),
        };

        // over-decomposed scan plan: geometry is a function of n and
        // cfg.scan_shards alone — never of the pool width — so results
        // and per-shard state are identical at any thread count
        let mut plan = ScanPlan::for_rows(n, cfg.scan_shards);
        let mut algs: Vec<Box<dyn AssignStep>> = plan
            .shards()
            .iter()
            .map(|&(lo, len)| factory(lo, len, k, g))
            .collect();

        let mut ctx = RoundCtxOwner::new(centroids, k, d);
        if req.groups {
            ctx.groups = Some(GroupData::build(&ctx.centroids, k, d, cfg.seed, &mut counters));
        }
        let mut history = if req.history {
            let cap = cfg
                .history_cap
                .unwrap_or_else(|| HistoryStore::paper_cap(n, k, d, cfg.history_budget));
            let (group_of, gh) = if req.group_history {
                let gd = ctx.groups.as_ref().expect("group_history requires groups");
                (gd.group_of.clone(), gd.g())
            } else {
                (Vec::new(), 0)
            };
            Some(HistoryStore::new(k, d, cap, group_of, gh))
        } else {
            None
        };
        if let Some(h) = history.as_mut() {
            ctx.history = Some(h.begin(&ctx.centroids));
        }

        // round 0: initial full assignment with tight bounds
        let mut a = vec![0u32; n];
        let t_scan = Instant::now();
        let sh = ctx.shared(data);
        let (ctr, _) = run_shards(pool.get(), &mut algs, &mut plan, &mut a, &sh, true);
        drop(sh);
        phases.scan += t_scan.elapsed();
        counters.merge(&ctr);
        let t_update = Instant::now();
        let update = UpdateState::from_assignments_pooled(data, &a, k, pool.get());
        phases.update += t_update.elapsed();

        Ok(Engine {
            data,
            k,
            pool,
            algs,
            plan,
            a,
            ctx,
            update,
            history,
            req,
            counters,
            phases,
            converged: false,
            rounds: 0,
            name,
            last_moved: usize::MAX,
        })
    }

    /// One Lloyd round (update step + assignment step).
    /// Returns the number of samples that changed cluster.
    pub fn step(&mut self) -> usize {
        if self.converged {
            return 0;
        }
        let d = self.data.d();
        let pool = self.pool.get();
        // update step
        let t_update = Instant::now();
        let new_centroids = self.update.centroids_pooled(&self.ctx.centroids, d, pool);
        self.phases.update += t_update.elapsed();
        // centroid-side rebuilds
        let t_build = Instant::now();
        self.ctx
            .advance_centroids_pooled(new_centroids, d, &mut self.counters, pool);
        self.ctx.rebuild(&self.req, d, &mut self.counters, pool);
        if let Some(h) = self.history.as_mut() {
            self.ctx.history =
                Some(h.advance_pooled(&self.ctx.centroids, &mut self.counters, pool));
        }
        self.phases.build += t_build.elapsed();
        // assignment step
        let t_scan = Instant::now();
        let sh = self.ctx.shared(self.data);
        let (ctr, moved) = run_shards(
            pool,
            &mut self.algs,
            &mut self.plan,
            &mut self.a,
            &sh,
            false,
        );
        drop(sh);
        self.phases.scan += t_scan.elapsed();
        self.counters.merge(&ctr);
        let t_apply = Instant::now();
        if self.req.full_update {
            self.update =
                UpdateState::from_assignments_pooled(self.data, &self.a, self.k, pool);
        } else {
            self.update.apply_moves_pooled(self.data, &moved, pool);
        }
        self.phases.update += t_apply.elapsed();
        self.rounds += 1;
        self.last_moved = moved.len();
        self.converged = moved.is_empty();
        moved.len()
    }

    /// Current assignments.
    pub fn assignments(&self) -> &[u32] {
        &self.a
    }

    /// Current centroids (row-major `k×d`).
    pub fn centroids(&self) -> &[f64] {
        &self.ctx.centroids
    }

    /// Whether the last round moved nothing.
    pub fn converged(&self) -> bool {
        self.converged
    }

    /// Rounds executed so far (excluding the initial assignment).
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Accumulated distance counters.
    pub fn counters(&self) -> Counters {
        self.counters
    }

    /// Accumulated per-phase wall times.
    pub fn phases(&self) -> PhaseTimes {
        self.phases
    }

    /// Scan-scheduler telemetry accumulated so far (shard count,
    /// dispatches, LPT reorders, per-phase max/mean shard walls).
    pub fn sched(&self) -> SchedTelemetry {
        self.plan.telemetry()
    }

    /// Resolved worker count (the pool's width).
    pub fn threads(&self) -> usize {
        self.pool.get().width()
    }

    /// Samples moved in the last round.
    pub fn last_moved(&self) -> usize {
        self.last_moved
    }

    /// Resolved algorithm name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Current round context (tests: bound checks need groups/history).
    pub fn ctx(&self) -> &RoundCtxOwner {
        &self.ctx
    }

    /// The running cluster sums/counts behind the update step (the
    /// mini-batch driver reads these to apply its decayed update).
    pub fn update_state(&self) -> &UpdateState {
        &self.update
    }

    /// Shard algorithm instances (tests: downcast to inspect bounds).
    pub fn algs(&self) -> &[Box<dyn AssignStep>] {
        &self.algs
    }

    /// Objective (mean squared distance to assigned centroid).
    pub fn mse(&self) -> f64 {
        self.data.mse(&self.ctx.centroids, &self.a)
    }
}

/// Run-to-convergence driver producing a [`RunReport`].
///
/// `Runner::new(&cfg).run(&data)` is the legacy one-shot entry point
/// and is kept as a thin shim (it builds a throwaway [`Runtime`] per
/// call). New code should use the service API —
/// [`Kmeans`](crate::model::Kmeans) on a shared [`Runtime`] — or
/// [`Runner::run_on`] directly.
pub struct Runner {
    cfg: RunConfig,
    observer: Option<Arc<FitObserver>>,
}

/// Output of [`Runner::run`].
#[derive(Clone, Debug)]
pub struct RunOutput {
    /// Final assignments.
    pub assignments: Vec<u32>,
    /// Final centroids.
    pub centroids: Vec<f64>,
    /// Rounds executed.
    pub iterations: usize,
    /// True if the run reached a fixed point (vs hitting a limit).
    pub converged: bool,
    /// Final mean squared error.
    pub mse: f64,
    /// Distance counters.
    pub counters: Counters,
    /// Wall time of the clustering loop.
    pub wall: Duration,
    /// Full telemetry record.
    pub report: RunReport,
}

impl Runner {
    /// Create from a config.
    pub fn new(cfg: &RunConfig) -> Self {
        Runner {
            cfg: cfg.clone(),
            observer: None,
        }
    }

    /// Attach a [`FitObserver`]: each round pushes a structured event
    /// (and, in progress mode, a stderr line). Observation is read-only
    /// over engine state — assignments, centroids, and counters are
    /// bit-identical with or without an observer. Runs without one skip
    /// even the per-round reads (notably the extra [`Engine::mse`]
    /// scan).
    pub fn with_observer(mut self, observer: Arc<FitObserver>) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Legacy shim: cluster `data` on a throwaway [`Runtime`] sized
    /// from `cfg.resolved_threads()`. Prefer [`Runner::run_on`] (or the
    /// [`Kmeans`](crate::model::Kmeans) service API) so the pool is
    /// spawned once per process, not once per run.
    pub fn run(&self, data: &dyn DataSource) -> Result<RunOutput> {
        let rt = Runtime::new(self.cfg.resolved_threads());
        self.run_on(&rt, data)
    }

    /// Cluster `data` to convergence (or a configured limit) on a
    /// shared [`Runtime`].
    ///
    /// With [`RunConfig::batch_size`] set below `data.n()`, the run is
    /// dispatched to the [mini-batch engine](crate::coordinator::minibatch)
    /// instead of the exact full-batch round loop; a batch size
    /// covering the whole dataset runs the exact engine unchanged.
    pub fn run_on(&self, rt: &Runtime, data: &dyn DataSource) -> Result<RunOutput> {
        if let Some(batch) = self.cfg.batch_size {
            if batch < data.n() {
                return crate::coordinator::minibatch::run_minibatch(
                    rt,
                    &self.cfg,
                    data,
                    self.observer.as_deref(),
                );
            }
        }
        // out-of-core sources expose cumulative I/O counters; report the
        // per-run delta so one source can serve many runs
        let io_before = data.io_stats();
        let start = Instant::now();
        let mut engine = Engine::on_runtime(data, &self.cfg, rt)?;
        let mut round_times = Vec::new();
        while !engine.converged() && engine.rounds() < self.cfg.max_iters {
            if let Some(limit) = self.cfg.time_limit {
                if start.elapsed() > limit {
                    break;
                }
            }
            let t0 = Instant::now();
            let ctr_before = engine.counters();
            let moved = engine.step();
            if self.cfg.record_rounds {
                round_times.push(t0.elapsed());
            }
            if let Some(obs) = self.observer.as_deref() {
                obs.round(&RoundObservation {
                    site: "fit",
                    round: engine.rounds(),
                    moved,
                    mse: engine.mse(),
                    delta: engine.counters().since(&ctr_before),
                    imbalance: engine.sched().imbalance(),
                    batch_rows: None,
                });
            }
        }
        let wall = start.elapsed();
        let mse = engine.mse();
        let io = match (io_before, data.io_stats()) {
            (Some(before), Some(after)) => Some(after.since(&before)),
            _ => None,
        };
        let report = RunReport {
            algorithm: engine.name().to_string(),
            dataset: data.name().to_string(),
            k: self.cfg.k,
            n: data.n(),
            seed: self.cfg.seed,
            iterations: engine.rounds(),
            converged: engine.converged(),
            mse,
            wall,
            threads: engine.threads(),
            phases: engine.phases(),
            counters: engine.counters(),
            round_times,
            batch: None,
            io,
            sched: engine.sched(),
        };
        Ok(RunOutput {
            assignments: engine.assignments().to_vec(),
            centroids: engine.centroids().to_vec(),
            iterations: engine.rounds(),
            converged: engine.converged(),
            mse,
            counters: engine.counters(),
            wall,
            report,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;

    #[test]
    fn sta_converges_on_blobs() {
        let ds = blobs(500, 4, 5, 0.05, 3);
        let cfg = RunConfig::new(Algorithm::Sta, 5).seed(1);
        let out = Runner::new(&cfg).run(&ds).unwrap();
        assert!(out.converged);
        assert!(out.iterations >= 1);
        assert!(out.mse.is_finite());
        assert_eq!(out.assignments.len(), 500);
        assert_eq!(out.centroids.len(), 5 * 4);
    }

    #[test]
    fn max_iters_cuts_off() {
        let ds = blobs(500, 4, 8, 0.4, 5);
        let cfg = RunConfig::new(Algorithm::Sta, 8).seed(1).max_iters(1);
        let out = Runner::new(&cfg).run(&ds).unwrap();
        assert_eq!(out.iterations, 1);
    }

    #[test]
    fn auto_resolves_by_dimension() {
        let ds = blobs(200, 2, 4, 0.1, 7);
        let cfg = RunConfig::new(Algorithm::Auto, 4).seed(2);
        let engine = Engine::new(&ds, &cfg).unwrap();
        assert_eq!(engine.name(), "exp-ns");
    }

    #[test]
    fn multithreaded_equals_single_threaded() {
        let ds = blobs(700, 5, 6, 0.1, 9);
        for alg in [Algorithm::Sta, Algorithm::Exp, Algorithm::SelkNs] {
            let out1 = Runner::new(&RunConfig::new(alg, 6).seed(4).threads(1))
                .run(&ds)
                .unwrap();
            let out4 = Runner::new(&RunConfig::new(alg, 6).seed(4).threads(4))
                .run(&ds)
                .unwrap();
            assert_eq!(out1.assignments, out4.assignments, "{alg}");
            assert_eq!(out1.iterations, out4.iterations, "{alg}");
            assert_eq!(out1.counters.assignment, out4.counters.assignment, "{alg}");
        }
    }

    #[test]
    fn shard_factor_never_changes_bits() {
        // 1500 rows → the floor admits up to 5 shards; cross shard
        // counts with thread widths and demand identical bits
        let ds = blobs(1500, 5, 6, 0.1, 9);
        let reference = Runner::new(&RunConfig::new(Algorithm::Exp, 6).seed(4).threads(1))
            .run(&ds)
            .unwrap();
        for shards in [1, 2, 5] {
            for threads in [1, 4] {
                let cfg = RunConfig::new(Algorithm::Exp, 6)
                    .seed(4)
                    .threads(threads)
                    .scan_shards(shards);
                let out = Runner::new(&cfg).run(&ds).unwrap();
                assert_eq!(out.assignments, reference.assignments, "S={shards} T={threads}");
                assert_eq!(out.counters, reference.counters, "S={shards} T={threads}");
                assert_eq!(out.mse.to_bits(), reference.mse.to_bits(), "S={shards} T={threads}");
                assert_eq!(out.report.sched.shards, shards);
                assert_eq!(
                    out.report.sched.dispatches,
                    out.iterations as u64 + 1 // init + one per round
                );
            }
        }
    }

    #[test]
    fn phase_telemetry_accumulates() {
        let ds = blobs(500, 4, 5, 0.05, 3);
        let cfg = RunConfig::new(Algorithm::ExpNs, 5).seed(1).threads(2);
        let out = Runner::new(&cfg).run(&ds).unwrap();
        assert_eq!(out.report.threads, 2);
        assert!(out.report.phases.total() > Duration::ZERO);
        // phases are a decomposition of the loop, not more than the wall
        assert!(out.report.phases.total() <= out.wall + Duration::from_millis(50));
    }

    #[test]
    fn engines_share_a_runtime_pool() {
        let ds = blobs(600, 4, 6, 0.1, 8);
        let rt = Runtime::new(3);
        let cfg = RunConfig::new(Algorithm::ExpNs, 6).seed(2);
        // two sequential engines borrow the same pool
        for _ in 0..2 {
            let mut engine = Engine::on_runtime(&ds, &cfg, &rt).unwrap();
            assert_eq!(engine.threads(), 3);
            while !engine.converged() && engine.rounds() < 100 {
                engine.step();
            }
            assert!(engine.converged());
        }
        // and match a run with a private pool of the same width
        let out = Runner::new(&cfg.clone().threads(3)).run(&ds).unwrap();
        let shared = Runner::new(&cfg).run_on(&rt, &ds).unwrap();
        assert_eq!(out.assignments, shared.assignments);
        assert_eq!(out.counters, shared.counters);
        assert_eq!(out.mse.to_bits(), shared.mse.to_bits());
        assert_eq!(shared.report.threads, 3);
    }

    #[test]
    fn mse_decreases_monotonically() {
        let ds = blobs(400, 3, 6, 0.3, 11);
        let cfg = RunConfig::new(Algorithm::Sta, 6).seed(3);
        let mut engine = Engine::new(&ds, &cfg).unwrap();
        let mut prev = f64::INFINITY;
        for _ in 0..30 {
            if engine.converged() {
                break;
            }
            engine.step();
            let mse = engine.mse();
            assert!(mse <= prev + 1e-9, "objective increased: {prev} → {mse}");
            prev = mse;
        }
    }
}
