//! Sharded execution of the assignment step — a thin façade over the
//! persistent [`WorkerPool`].
//!
//! Samples are processed independently (the paper's §4.2
//! parallelisation), but the shard geometry is *over-decomposed*: a
//! [`ScanPlan`](crate::coordinator::sched::ScanPlan) carves the rows
//! into many more shards than workers (geometry a function of `n`
//! alone), one persistent algorithm instance per shard, and
//! [`run_shards`] dispatches them onto the pool in the plan's
//! cost-guided LPT claim order. No threads are spawned here — the pool
//! outlives the round loop and is merely woken. Results (counters +
//! moved lists) are merged in ascending shard order, keeping the run
//! bit-deterministic regardless of thread count, shard count, or which
//! shard was claimed first.

use std::time::{Duration, Instant};

use crate::algorithms::common::{AssignStep, Moved, SharedRound};
use crate::coordinator::sched::ScanPlan;
use crate::data::DataSource;
use crate::metrics::Counters;
use crate::runtime::pool::WorkerPool;

/// Split `n` samples into `w` contiguous, balanced `(lo, len)` shards.
/// An empty dataset has no shards; `w > n` collapses to `n` single-row
/// shards (callers that must not degenerate this far use
/// [`make_shards_floored`]).
pub fn make_shards(n: usize, w: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let w = w.max(1).min(n);
    let base = n / w;
    let extra = n % w;
    let mut out = Vec::with_capacity(w);
    let mut lo = 0;
    for s in 0..w {
        let len = base + usize::from(s < extra);
        out.push((lo, len));
        lo += len;
    }
    out
}

/// [`make_shards`], with a documented minimum-rows floor: the requested
/// shard count is clamped so every shard spans at least `min_rows` rows
/// (a dataset smaller than the floor is one shard). Out-of-core cursors
/// hold a resident window per shard open, so degenerate geometry —
/// `w > n` collapsing to single-row shards — would multiply cursor
/// opens and window refills; the floor makes that impossible by
/// construction. `min_rows = 1` (or 0) is exactly `make_shards`.
pub fn make_shards_floored(n: usize, w: usize, min_rows: usize) -> Vec<(usize, usize)> {
    let cap = (n / min_rows.max(1)).max(1);
    make_shards(n, w.min(cap))
}

/// One shard's slice of the round: its algorithm instance, its window of
/// the assignment array, its shard range, and its private outputs.
struct ShardRun<'s> {
    alg: &'s mut Box<dyn AssignStep>,
    a: &'s mut [u32],
    lo: usize,
    len: usize,
    ctr: Counters,
    moved: Vec<Moved>,
    wall: Duration,
}

/// Run one assignment round (or the initial assignment when
/// `init == true`) across the plan's shards on the pool, claiming
/// shards in the plan's LPT order. Each shard's worker opens its own
/// [`BlockCursor`](crate::data::source::BlockCursor) for the shard
/// range — out-of-core sources thereby get one resident window per
/// in-flight shard. Returns merged counters and moves (ascending
/// sample order); the dispatch's per-shard costs and walls are folded
/// back into the plan for the next round's claim order and the run's
/// [`SchedTelemetry`](crate::metrics::SchedTelemetry).
pub fn run_shards(
    pool: &WorkerPool,
    algs: &mut [Box<dyn AssignStep>],
    plan: &mut ScanPlan,
    a: &mut [u32],
    sh: &SharedRound,
    init: bool,
) -> (Counters, Vec<Moved>) {
    let shards = plan.shards();
    debug_assert_eq!(algs.len(), shards.len());
    // split the assignment array to match the shards
    let mut tasks: Vec<ShardRun> = Vec::with_capacity(shards.len());
    let mut rest = a;
    for (alg, &(lo, len)) in algs.iter_mut().zip(shards) {
        let (head, tail) = rest.split_at_mut(len);
        tasks.push(ShardRun {
            alg,
            a: head,
            lo,
            len,
            ctr: Counters::default(),
            moved: Vec::new(),
            wall: Duration::ZERO,
        });
        rest = tail;
    }

    pool.run_tasks_ordered(&mut tasks, plan.order(), |_, t| {
        let t0 = Instant::now();
        let mut rows = sh.data.open(t.lo, t.len);
        if init {
            t.alg.init(sh, rows.as_mut(), t.a, &mut t.ctr);
        } else {
            t.alg.round(sh, rows.as_mut(), t.a, &mut t.ctr, &mut t.moved);
        }
        t.wall = t0.elapsed();
    });

    // merge in ascending shard order — this, not claim order, is what
    // pins the bits
    let mut ctr = Counters::default();
    let mut moved = Vec::with_capacity(tasks.iter().map(|t| t.moved.len()).sum());
    let mut costs = Vec::with_capacity(tasks.len());
    let mut walls = Vec::with_capacity(tasks.len());
    for t in tasks {
        ctr.merge(&t.ctr);
        // deterministic LPT key: distance work plus rows visited
        costs.push(t.ctr.total() + t.len as u64);
        walls.push(t.wall);
        moved.extend(t.moved); // shard order == ascending sample order
    }
    plan.record(&costs, &walls, init);
    (ctr, moved)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_everything() {
        for (n, w) in [(10, 3), (7, 7), (100, 4), (5, 8), (1, 1)] {
            let shards = make_shards(n, w);
            let total: usize = shards.iter().map(|s| s.1).sum();
            assert_eq!(total, n);
            // contiguous
            let mut expect = 0;
            for &(lo, len) in &shards {
                assert_eq!(lo, expect);
                expect += len;
            }
            // balanced within 1
            let lens: Vec<usize> = shards.iter().map(|s| s.1).collect();
            let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(mx - mn <= 1);
        }
    }

    #[test]
    fn more_workers_than_samples_collapses() {
        let shards = make_shards(3, 16);
        assert_eq!(shards.len(), 3);
    }

    #[test]
    fn floored_shards_respect_min_rows() {
        // regression: w > n used to hand degenerate single-row shards
        // to ooc cursors; the floor caps the count instead
        assert_eq!(make_shards_floored(3, 16, 8).len(), 1);
        assert_eq!(make_shards_floored(1000, 64, 256).len(), 3);
        for &(_, len) in &make_shards_floored(1000, 64, 256) {
            assert!(len >= 256);
        }
        // floor of 1 (or 0) is plain make_shards
        assert_eq!(make_shards_floored(3, 16, 1), make_shards(3, 16));
        assert_eq!(make_shards_floored(100, 7, 0), make_shards(100, 7));
        // empty input still yields no shards
        assert!(make_shards_floored(0, 4, 256).is_empty());
    }

    #[test]
    fn empty_dataset_yields_no_shards() {
        // regression: n = 0 used to produce a single degenerate (0, 0)
        // shard, which spawned a worker with nothing to do
        assert!(make_shards(0, 1).is_empty());
        assert!(make_shards(0, 8).is_empty());
    }
}
