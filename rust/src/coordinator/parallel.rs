//! Thread-sharded execution of the assignment step — a thin façade over
//! the persistent [`WorkerPool`].
//!
//! Samples are processed independently (the paper's §4.2
//! parallelisation): the coordinator splits them into contiguous shards,
//! one algorithm instance per shard, and dispatches every shard's round
//! onto the pool. No threads are spawned here — the pool outlives the
//! round loop and is merely woken. Results (counters + moved lists) are
//! merged in shard order, keeping the run bit-deterministic regardless
//! of thread count.

use crate::algorithms::common::{AssignStep, Moved, SharedRound};
use crate::data::DataSource;
use crate::metrics::Counters;
use crate::runtime::pool::WorkerPool;

/// Shard geometry for a [`DataSource`]: split its `n()` rows into `w`
/// contiguous balanced shards (see [`make_shards`]).
pub fn make_shards_for(data: &dyn DataSource, w: usize) -> Vec<(usize, usize)> {
    make_shards(data.n(), w)
}

/// Split `n` samples into `w` contiguous, balanced `(lo, len)` shards.
/// An empty dataset has no shards.
pub fn make_shards(n: usize, w: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let w = w.max(1).min(n);
    let base = n / w;
    let extra = n % w;
    let mut out = Vec::with_capacity(w);
    let mut lo = 0;
    for s in 0..w {
        let len = base + usize::from(s < extra);
        out.push((lo, len));
        lo += len;
    }
    out
}

/// One shard's slice of the round: its algorithm instance, its window of
/// the assignment array, its shard range, and its private outputs.
struct ShardRun<'s> {
    alg: &'s mut Box<dyn AssignStep>,
    a: &'s mut [u32],
    lo: usize,
    len: usize,
    ctr: Counters,
    moved: Vec<Moved>,
}

/// Run one assignment round (or the initial assignment when
/// `init == true`) across all shards on the pool. Each shard's worker
/// opens its own [`BlockCursor`](crate::data::source::BlockCursor) for
/// the shard range — out-of-core sources thereby get one resident
/// window per worker. Returns merged counters and moves (ascending
/// sample order).
pub fn run_shards(
    pool: &WorkerPool,
    algs: &mut [Box<dyn AssignStep>],
    shards: &[(usize, usize)],
    a: &mut [u32],
    sh: &SharedRound,
    init: bool,
) -> (Counters, Vec<Moved>) {
    debug_assert_eq!(algs.len(), shards.len());
    // split the assignment array to match the shards
    let mut tasks: Vec<ShardRun> = Vec::with_capacity(shards.len());
    let mut rest = a;
    for (alg, &(lo, len)) in algs.iter_mut().zip(shards) {
        let (head, tail) = rest.split_at_mut(len);
        tasks.push(ShardRun {
            alg,
            a: head,
            lo,
            len,
            ctr: Counters::default(),
            moved: Vec::new(),
        });
        rest = tail;
    }

    pool.run_tasks(&mut tasks, |_, t| {
        let mut rows = sh.data.open(t.lo, t.len);
        if init {
            t.alg.init(sh, rows.as_mut(), t.a, &mut t.ctr);
        } else {
            t.alg.round(sh, rows.as_mut(), t.a, &mut t.ctr, &mut t.moved);
        }
    });

    let mut ctr = Counters::default();
    let mut moved = Vec::new();
    for t in tasks {
        ctr.merge(&t.ctr);
        moved.extend(t.moved); // shard order == ascending sample order
    }
    (ctr, moved)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_everything() {
        for (n, w) in [(10, 3), (7, 7), (100, 4), (5, 8), (1, 1)] {
            let shards = make_shards(n, w);
            let total: usize = shards.iter().map(|s| s.1).sum();
            assert_eq!(total, n);
            // contiguous
            let mut expect = 0;
            for &(lo, len) in &shards {
                assert_eq!(lo, expect);
                expect += len;
            }
            // balanced within 1
            let lens: Vec<usize> = shards.iter().map(|s| s.1).collect();
            let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(mx - mn <= 1);
        }
    }

    #[test]
    fn more_workers_than_samples_collapses() {
        let shards = make_shards(3, 16);
        assert_eq!(shards.len(), 3);
    }

    #[test]
    fn empty_dataset_yields_no_shards() {
        // regression: n = 0 used to produce a single degenerate (0, 0)
        // shard, which spawned a worker with nothing to do
        assert!(make_shards(0, 1).is_empty());
        assert!(make_shards(0, 8).is_empty());
    }
}
