//! Thread-sharded execution of the assignment step.
//!
//! Samples are processed independently (the paper's §4.2 parallelisation),
//! so the coordinator splits them into contiguous shards, one algorithm
//! instance per shard, and runs every shard's round concurrently with
//! scoped threads. Results (counters + moved lists) are merged in shard
//! order, keeping the run bit-deterministic regardless of thread count.

use crate::algorithms::common::{AssignStep, Moved, SharedRound};
use crate::metrics::Counters;

/// Split `n` samples into `w` contiguous, balanced `(lo, len)` shards.
pub fn make_shards(n: usize, w: usize) -> Vec<(usize, usize)> {
    let w = w.max(1).min(n.max(1));
    let base = n / w;
    let extra = n % w;
    let mut out = Vec::with_capacity(w);
    let mut lo = 0;
    for s in 0..w {
        let len = base + usize::from(s < extra);
        out.push((lo, len));
        lo += len;
    }
    out
}

/// Run one assignment round (or the initial assignment when
/// `init == true`) across all shards, in parallel when there is more
/// than one. Returns merged counters and moves (ascending sample order).
pub fn run_shards(
    algs: &mut [Box<dyn AssignStep>],
    shards: &[(usize, usize)],
    a: &mut [u32],
    sh: &SharedRound,
    init: bool,
) -> (Counters, Vec<Moved>) {
    debug_assert_eq!(algs.len(), shards.len());
    if algs.len() == 1 {
        // fast path: no thread machinery on single-shard runs
        let mut ctr = Counters::default();
        let mut moved = Vec::new();
        if init {
            algs[0].init(sh, a, &mut ctr);
        } else {
            algs[0].round(sh, a, &mut ctr, &mut moved);
        }
        return (ctr, moved);
    }

    // split the assignment array to match the shards
    let mut slices: Vec<&mut [u32]> = Vec::with_capacity(shards.len());
    let mut rest = a;
    for &(_lo, len) in shards {
        let (head, tail) = rest.split_at_mut(len);
        slices.push(head);
        rest = tail;
    }

    let results: Vec<(Counters, Vec<Moved>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = algs
            .iter_mut()
            .zip(slices)
            .map(|(alg, slice)| {
                scope.spawn(move || {
                    let mut ctr = Counters::default();
                    let mut moved = Vec::new();
                    if init {
                        alg.init(sh, slice, &mut ctr);
                    } else {
                        alg.round(sh, slice, &mut ctr, &mut moved);
                    }
                    (ctr, moved)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let mut ctr = Counters::default();
    let mut moved = Vec::new();
    for (c, m) in results {
        ctr.merge(&c);
        moved.extend(m); // shard order == ascending sample order
    }
    (ctr, moved)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_everything() {
        for (n, w) in [(10, 3), (7, 7), (100, 4), (5, 8), (1, 1)] {
            let shards = make_shards(n, w);
            let total: usize = shards.iter().map(|s| s.1).sum();
            assert_eq!(total, n);
            // contiguous
            let mut expect = 0;
            for &(lo, len) in &shards {
                assert_eq!(lo, expect);
                expect += len;
            }
            // balanced within 1
            let lens: Vec<usize> = shards.iter().map(|s| s.1).collect();
            let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(mx - mn <= 1);
        }
    }

    #[test]
    fn more_workers_than_samples_collapses() {
        let shards = make_shards(3, 16);
        assert_eq!(shards.len(), 3);
    }
}
