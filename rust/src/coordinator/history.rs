//! Centroid history for ns-bounds (paper §3.2–3.4).
//!
//! sn-algorithms drift their bounds by accumulating per-round
//! displacement norms. ns-algorithms instead remember the centroid
//! positions `C(j,t)` at which each bound was last made tight and update
//! with the *norm of the sum* `P(j,t) = ‖c_now(j) − c_t(j)‖`, which the
//! triangle inequality makes tighter (SM-B.5).
//!
//! Memory is bounded the way the paper does it: after `cap` rounds
//! (`N/min(k,d)`, further clamped by a byte budget) the epoch is *reset* —
//! bounds are folded sn-style through the final `P` values and the stored
//! snapshots are cleared.

use crate::linalg::sqdist;
use crate::metrics::Counters;
use crate::runtime::pool::{SharedSliceMut, WorkerPool};

/// Per-round view of the history: `P(j,t)` for every epoch round `t`,
/// plus the maxima the exp-ns / syin-ns lower bounds need.
#[derive(Clone, Debug, Default)]
pub struct Epoch {
    /// Number of stored snapshots; the current round has index `len − 1`
    /// and `P(j, len−1) == 0`.
    pub len: usize,
    /// `P(j,t)` flattened `t*k + j`.
    pub p_to: Vec<f64>,
    /// Per `t`: `max_j P(j,t)`.
    pub max1: Vec<f64>,
    /// Per `t`: argmax of the above.
    pub arg1: Vec<u32>,
    /// Per `t`: second-largest `P(j,t)`.
    pub max2: Vec<f64>,
    /// Per `t×G`: `max_{j∈G(f)} P(j,t)` (empty unless groups requested).
    pub gmax: Vec<f64>,
    /// Number of groups (0 if no group maxima kept).
    pub g: usize,
    k: usize,
}

impl Epoch {
    /// `P(j, t)`.
    #[inline]
    pub fn p(&self, j: usize, t: usize) -> f64 {
        self.p_to[t * self.k + j]
    }

    /// `max_{j′ ≠ j} P(j′, t)` in O(1) via max/argmax/second-max.
    #[inline]
    pub fn maxp_excl(&self, j: usize, t: usize) -> f64 {
        if self.arg1[t] as usize == j {
            self.max2[t]
        } else {
            self.max1[t]
        }
    }

    /// `max_{j∈G(f)} P(j, t)`.
    #[inline]
    pub fn group_max(&self, f: usize, t: usize) -> f64 {
        self.gmax[t * self.g + f]
    }
}

/// The per-round history handed to algorithms. On a reset round, `fold`
/// carries the *previous* epoch's final `P` values (computed against the
/// current centroids) so per-sample bounds can be folded before `T`
/// indices restart at 0.
#[derive(Clone, Debug, Default)]
pub struct HistoryRound {
    /// Current epoch data (after any reset).
    pub epoch: Epoch,
    /// Present exactly on reset rounds.
    pub fold: Option<Epoch>,
}

/// Owns the centroid snapshots and produces a [`HistoryRound`] per round.
#[derive(Clone, Debug)]
pub struct HistoryStore {
    k: usize,
    d: usize,
    /// Max snapshots per epoch.
    cap: usize,
    /// Flattened snapshots, `len × k × d`.
    snaps: Vec<f64>,
    len: usize,
    /// Group membership for per-group maxima (empty = not tracked).
    group_of: Vec<u32>,
    g: usize,
}

impl HistoryStore {
    /// `cap` is the reset period; `group_of`/`g` enable per-group maxima.
    pub fn new(k: usize, d: usize, cap: usize, group_of: Vec<u32>, g: usize) -> Self {
        assert!(cap >= 2, "history cap must allow at least two rounds");
        HistoryStore {
            k,
            d,
            cap,
            snaps: Vec::new(),
            len: 0,
            group_of,
            g,
        }
    }

    /// The paper's reset period `N/min(k,d)`, clamped to `[2, byte-budget]`.
    pub fn paper_cap(n: usize, k: usize, d: usize, byte_budget: usize) -> usize {
        let paper = n / k.min(d).max(1);
        let by_mem = byte_budget / (k * d * 8).max(1);
        paper.clamp(2, by_mem.max(2))
    }

    /// Begin the first epoch at round 0 with the initial centroids.
    pub fn begin(&mut self, centroids: &[f64]) -> HistoryRound {
        debug_assert_eq!(centroids.len(), self.k * self.d);
        self.snaps.clear();
        self.snaps.extend_from_slice(centroids);
        self.len = 1;
        HistoryRound {
            epoch: self.epoch_for(centroids, &mut Counters::default()),
            fold: None,
        }
    }

    /// Advance to a new assignment round with updated centroids.
    /// Performs the sn-like reset when the epoch is full.
    pub fn advance(&mut self, centroids: &[f64], ctr: &mut Counters) -> HistoryRound {
        self.advance_pooled(centroids, ctr, &WorkerPool::serial())
    }

    /// As [`HistoryStore::advance`], building the `P(j,t)` table and its
    /// maxima in parallel over epoch rounds `t` on the pool. Rows are
    /// independent, so the result is bit-identical at any pool width.
    pub fn advance_pooled(
        &mut self,
        centroids: &[f64],
        ctr: &mut Counters,
        pool: &WorkerPool,
    ) -> HistoryRound {
        debug_assert_eq!(centroids.len(), self.k * self.d);
        let fold = if self.len >= self.cap {
            // Fold previous epoch against the *current* centroids. The new
            // epoch starts with TWO copies of the current centroids: folded
            // bounds point at snapshot 0 (valid forever, P grows as
            // centroids move) while snapshot 1 is "this round", so the
            // tightness check `T == len−1` correctly reports folded bounds
            // as loose.
            self.snaps.extend_from_slice(centroids);
            self.len += 1;
            let fold = self.epoch_for_pooled(centroids, ctr, pool);
            self.snaps.clear();
            self.snaps.extend_from_slice(centroids);
            self.snaps.extend_from_slice(centroids);
            self.len = 2;
            Some(fold)
        } else {
            self.snaps.extend_from_slice(centroids);
            self.len += 1;
            None
        };
        HistoryRound {
            epoch: self.epoch_for_pooled(centroids, ctr, pool),
            fold,
        }
    }

    /// Current epoch length (snapshots stored).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no snapshots stored yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Build the Epoch table (`P(j,t)` + maxima) vs `current` centroids.
    fn epoch_for(&self, current: &[f64], ctr: &mut Counters) -> Epoch {
        self.epoch_for_pooled(current, ctr, &WorkerPool::serial())
    }

    /// Build the Epoch table in parallel over the epoch rounds `t`: each
    /// round's `P(·,t)` row, maxima, and group maxima are independent of
    /// every other round's, so all writes are disjoint and the result is
    /// bit-identical at any pool width.
    fn epoch_for_pooled(&self, current: &[f64], ctr: &mut Counters, pool: &WorkerPool) -> Epoch {
        let (k, d, len) = (self.k, self.d, self.len);
        let g = self.g;
        let mut p_to = vec![0.0; len * k];
        let mut max1 = vec![0.0; len];
        let mut arg1 = vec![0u32; len];
        let mut max2 = vec![0.0; len];
        let mut gmax = vec![0.0; len * g];
        {
            let p_sh = SharedSliceMut::new(&mut p_to);
            let m1_sh = SharedSliceMut::new(&mut max1);
            let a1_sh = SharedSliceMut::new(&mut arg1);
            let m2_sh = SharedSliceMut::new(&mut max2);
            let gm_sh = SharedSliceMut::new(&mut gmax);
            pool.for_each_chunk(len, 4, |lo, hi| {
                let rows = unsafe { p_sh.range(lo * k, hi * k) };
                for t in lo..hi {
                    let row = &mut rows[(t - lo) * k..(t - lo + 1) * k];
                    if t < len - 1 {
                        let snap = &self.snaps[t * k * d..(t + 1) * k * d];
                        for (j, pv) in row.iter_mut().enumerate() {
                            *pv = sqdist(&snap[j * d..(j + 1) * d], &current[j * d..(j + 1) * d])
                                .sqrt();
                        }
                    }
                    // last row is the current round: all zeros already
                    let (mut m1, mut a1, mut m2) = (f64::NEG_INFINITY, 0u32, f64::NEG_INFINITY);
                    for (j, &v) in row.iter().enumerate() {
                        if v > m1 {
                            m2 = m1;
                            m1 = v;
                            a1 = j as u32;
                        } else if v > m2 {
                            m2 = v;
                        }
                    }
                    // sound: each t is handled by exactly one chunk
                    unsafe {
                        m1_sh.write(t, m1.max(0.0));
                        a1_sh.write(t, a1);
                        m2_sh.write(t, m2.max(0.0));
                    }
                    if g > 0 {
                        let grow = unsafe { gm_sh.range(t * g, (t + 1) * g) };
                        for (j, &v) in row.iter().enumerate() {
                            let f = self.group_of[j] as usize;
                            if v > grow[f] {
                                grow[f] = v;
                            }
                        }
                    }
                }
            });
        }
        ctr.displacement += (len.saturating_sub(1) * k) as u64;
        Epoch {
            len,
            p_to,
            max1,
            arg1,
            max2,
            gmax,
            g,
            k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store2() -> HistoryStore {
        HistoryStore::new(2, 1, 4, vec![0, 0], 1)
    }

    #[test]
    fn p_values_track_displacement() {
        let mut hs = store2();
        hs.begin(&[0.0, 10.0]);
        let mut ctr = Counters::default();
        // centroid 0 moves to 3, centroid 1 stays
        let h = hs.advance(&[3.0, 10.0], &mut ctr);
        assert_eq!(h.epoch.len, 2);
        assert_eq!(h.epoch.p(0, 0), 3.0); // vs snapshot at round 0
        assert_eq!(h.epoch.p(1, 0), 0.0);
        assert_eq!(h.epoch.p(0, 1), 0.0); // current round
        assert!(h.fold.is_none());
        assert_eq!(ctr.displacement, 2);
    }

    #[test]
    fn ns_tighter_than_sn_along_a_walk() {
        // centroid walks 0 → 1 → 0 → 1 …; sn accumulates, ns stays ≤ 1
        let mut hs = HistoryStore::new(1, 1, 64, vec![], 0);
        hs.begin(&[0.0]);
        let mut ctr = Counters::default();
        let mut sn = 0.0;
        for t in 1..10 {
            let pos = (t % 2) as f64;
            let h = hs.advance(&[pos], &mut ctr);
            sn += 1.0; // |p| each round is 1
            let ns = h.epoch.p(0, 0);
            assert!(ns <= sn + 1e-12);
            assert!(ns <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn reset_produces_fold_and_restarts() {
        let mut hs = HistoryStore::new(1, 1, 3, vec![], 0);
        hs.begin(&[0.0]);
        let mut ctr = Counters::default();
        let h1 = hs.advance(&[1.0], &mut ctr);
        assert!(h1.fold.is_none());
        let h2 = hs.advance(&[2.0], &mut ctr);
        assert!(h2.fold.is_none());
        assert_eq!(hs.len(), 3);
        // cap reached → next advance folds
        let h3 = hs.advance(&[3.0], &mut ctr);
        let fold = h3.fold.expect("reset expected");
        // fold P vs current (3.0): snapshots were 0,1,2,(3)
        assert_eq!(fold.p(0, 0), 3.0);
        assert_eq!(fold.p(0, 1), 2.0);
        assert_eq!(fold.p(0, 2), 1.0);
        // new epoch: snapshot 0 (fold target) + snapshot 1 (current round)
        assert_eq!(h3.epoch.len, 2);
        assert_eq!(hs.len(), 2);
        assert_eq!(h3.epoch.p(0, 0), 0.0);
    }

    #[test]
    fn maxp_excl_uses_second_max() {
        let mut hs = HistoryStore::new(3, 1, 8, vec![], 0);
        hs.begin(&[0.0, 0.0, 0.0]);
        let mut ctr = Counters::default();
        let h = hs.advance(&[5.0, 2.0, 1.0], &mut ctr);
        // P(·,0) = [5,2,1]
        assert_eq!(h.epoch.maxp_excl(0, 0), 2.0); // excluding the argmax
        assert_eq!(h.epoch.maxp_excl(1, 0), 5.0);
        assert_eq!(h.epoch.maxp_excl(2, 0), 5.0);
    }

    #[test]
    fn group_max_per_group() {
        let mut hs = HistoryStore::new(4, 1, 8, vec![0, 0, 1, 1], 2);
        hs.begin(&[0.0; 4]);
        let mut ctr = Counters::default();
        let h = hs.advance(&[1.0, 3.0, 0.5, 0.25], &mut ctr);
        assert_eq!(h.epoch.group_max(0, 0), 3.0);
        assert_eq!(h.epoch.group_max(1, 0), 0.5);
    }

    #[test]
    fn paper_cap_formula() {
        // N/min(k,d) with clamps
        assert_eq!(HistoryStore::paper_cap(10_000, 100, 8, usize::MAX), 1250);
        assert_eq!(HistoryStore::paper_cap(100, 100, 100, usize::MAX), 2); // clamp low
        // byte budget: k*d*8 = 800 bytes per snapshot, budget 8000 → 10
        assert_eq!(HistoryStore::paper_cap(1_000_000, 10, 10, 8_000), 10);
    }
}
