//! Yinyang cluster grouping (Ding et al. 2015, §2.6 of the paper).
//!
//! The k centroids are themselves clustered once, at initialisation, into
//! `G = max(1, k/10)` groups (the paper fixes the number of groups at one
//! tenth of the number of centroids); the grouping then stays fixed.
//! Each round, `q(f) = max_{j∈G(f)} p(j)` is refreshed for the group
//! bound update.

use crate::linalg::sqdist;
use crate::metrics::Counters;
use crate::rng::Rng;
use crate::runtime::pool::{SharedSliceMut, WorkerPool};

/// Fixed cluster grouping + per-round group displacement maxima.
#[derive(Clone, Debug)]
pub struct GroupData {
    /// Group of each cluster.
    pub group_of: Vec<u32>,
    /// Members of each group.
    pub members: Vec<Vec<u32>>,
    /// `q(f) = max_{j∈G(f)} p(j)` — refreshed by [`GroupData::refresh`].
    pub q: Vec<f64>,
}

impl GroupData {
    /// Number of groups the paper prescribes for k clusters.
    pub fn group_count(k: usize) -> usize {
        (k / 10).max(1)
    }

    /// Cluster the initial centroids into `G` groups with a few rounds of
    /// Lloyd (Ding et al. use the same trick). Distance evaluations are
    /// charged to `ctr.centroid`.
    pub fn build(centroids: &[f64], k: usize, d: usize, seed: u64, ctr: &mut Counters) -> Self {
        let g = Self::group_count(k);
        let mut rng = Rng::new(seed ^ 0x9179_7a79);
        // seed group centres with g distinct centroids
        let picks = rng.distinct(k, g);
        let mut centres: Vec<f64> = Vec::with_capacity(g * d);
        for &j in &picks {
            centres.extend_from_slice(&centroids[j * d..(j + 1) * d]);
        }
        let mut group_of = vec![0u32; k];
        const ROUNDS: usize = 5;
        for _ in 0..ROUNDS {
            // assign
            for j in 0..k {
                let cj = &centroids[j * d..(j + 1) * d];
                let mut best = 0u32;
                let mut bd = f64::INFINITY;
                for f in 0..g {
                    let dist = sqdist(cj, &centres[f * d..(f + 1) * d]);
                    if dist < bd {
                        bd = dist;
                        best = f as u32;
                    }
                }
                group_of[j] = best;
            }
            ctr.centroid += (k * g) as u64;
            // update
            let mut sums = vec![0.0; g * d];
            let mut counts = vec![0usize; g];
            for j in 0..k {
                let f = group_of[j] as usize;
                counts[f] += 1;
                for t in 0..d {
                    sums[f * d + t] += centroids[j * d + t];
                }
            }
            for f in 0..g {
                if counts[f] > 0 {
                    for t in 0..d {
                        centres[f * d + t] = sums[f * d + t] / counts[f] as f64;
                    }
                }
            }
        }
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); g];
        for j in 0..k {
            members[group_of[j] as usize].push(j as u32);
        }
        // A group can come out empty (fewer effective centre positions
        // than g); that is fine — its q stays 0 and no sample ever scans
        // it. Yinyang's correctness does not depend on balance.
        GroupData {
            group_of,
            members,
            q: vec![0.0; g],
        }
    }

    /// Refresh `q(f) = max_{j∈G(f)} p(j)` from this round's displacements.
    pub fn refresh(&mut self, p: &[f64]) {
        for (f, q) in self.q.iter_mut().enumerate() {
            *q = self.members[f]
                .iter()
                .map(|&j| p[j as usize])
                .fold(0.0, f64::max);
        }
    }

    /// As [`GroupData::refresh`], parallel over groups. Each `q(f)` is an
    /// independent max over that group's members, so the result is
    /// bit-identical at any pool width.
    pub fn refresh_pooled(&mut self, p: &[f64], pool: &WorkerPool) {
        let g = self.members.len();
        if pool.width() == 1 || g < 16 {
            self.refresh(p);
            return;
        }
        let members = &self.members;
        let q = SharedSliceMut::new(&mut self.q);
        pool.for_each_chunk(g, 4, |lo, hi| {
            let dst = unsafe { q.range(lo, hi) };
            for (off, out) in dst.iter_mut().enumerate() {
                *out = members[lo + off]
                    .iter()
                    .map(|&j| p[j as usize])
                    .fold(0.0, f64::max);
            }
        });
    }

    /// Number of groups.
    pub fn g(&self) -> usize {
        self.members.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_count_rule() {
        assert_eq!(GroupData::group_count(5), 1);
        assert_eq!(GroupData::group_count(100), 10);
        assert_eq!(GroupData::group_count(1000), 100);
    }

    #[test]
    fn build_partitions_all_clusters() {
        // 20 centroids in 2-D: two well-separated bands
        let mut c = Vec::new();
        for j in 0..20 {
            let off = if j < 10 { 0.0 } else { 100.0 };
            c.push(off + j as f64 * 0.01);
            c.push(off);
        }
        let mut ctr = Counters::default();
        let gd = GroupData::build(&c, 20, 2, 7, &mut ctr);
        assert_eq!(gd.g(), 2);
        assert_eq!(gd.group_of.len(), 20);
        let total: usize = gd.members.iter().map(|m| m.len()).sum();
        assert_eq!(total, 20);
        // the two bands should separate perfectly
        let g0 = gd.group_of[0];
        for j in 0..10 {
            assert_eq!(gd.group_of[j], g0);
        }
        for j in 10..20 {
            assert_ne!(gd.group_of[j], g0);
        }
        assert!(ctr.centroid > 0);
    }

    #[test]
    fn refresh_takes_group_max() {
        let gd0 = GroupData {
            group_of: vec![0, 0, 1],
            members: vec![vec![0, 1], vec![2]],
            q: vec![0.0; 2],
        };
        let mut gd = gd0;
        gd.refresh(&[0.5, 2.0, 0.25]);
        assert_eq!(gd.q, vec![2.0, 0.25]);
    }

    #[test]
    fn single_group_when_k_small() {
        let c = [0.0, 1.0, 2.0, 3.0];
        let mut ctr = Counters::default();
        let gd = GroupData::build(&c, 4, 1, 1, &mut ctr);
        assert_eq!(gd.g(), 1);
        assert!(gd.members[0].len() == 4);
    }
}
