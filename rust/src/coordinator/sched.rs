//! Deterministic work-balanced scan scheduling.
//!
//! The paper's §4.2 parallelisation splits samples into one contiguous
//! shard per thread — but its own thesis (bounds tests prune most
//! distance work) makes per-row cost skewed and *position-correlated*:
//! rows near moving centroids run full inner loops while settled
//! regions are near-free, so the slowest shard gates every round. A
//! [`ScanPlan`] fixes this by **over-decomposition**: carve `n` rows
//! into `S ≫ w` shards whose geometry is a function of `n` alone
//! (never the pool width), keep one persistent
//! [`AssignStep`](crate::algorithms::common::AssignStep) instance per
//! shard across rounds, and let the pool's dynamic task claiming do
//! the balancing.
//!
//! Claim order is **cost-guided**: shards are offered
//! longest-expected-first (greedy LPT), ranked by the *previous*
//! round's per-shard deterministic cost counters (distance
//! calculations plus rows visited). This is bit-deterministic twice
//! over — the ranking key is itself deterministic, and claim order
//! never affects per-row math because merges stay in ascending shard
//! order (see [`parallel::run_shards`](crate::coordinator::parallel::run_shards)).
//! Wall-clock measurements feed telemetry only, never scheduling.

use std::time::Duration;

use crate::coordinator::parallel::make_shards_floored;
use crate::metrics::SchedTelemetry;

/// Sentinel for "pick the shard count automatically" (mirrors
/// [`AUTO_THREADS`](crate::config::AUTO_THREADS)).
pub const AUTO_SCAN_SHARDS: usize = 0;

/// Minimum rows per shard (when `n` allows it): out-of-core cursors
/// hold one resident window per open, so shards below a couple of
/// lease blocks (`INIT_BLOCK` = 128 rows) would multiply cursor opens
/// and window refills without adding any balance. Requested shard
/// counts are clamped so no shard drops under this floor; a dataset
/// smaller than the floor is a single shard.
pub const MIN_SHARD_ROWS: usize = 256;

/// Auto geometry: target rows per shard. Small enough that a skewed
/// region splits across many claimable pieces, large enough that the
/// per-shard dispatch cost (cursor open + task claim) stays noise.
pub const TARGET_SHARD_ROWS: usize = 4096;

/// Auto geometry: shard-count ceiling, bounding per-round bookkeeping
/// (cost sort, merge loop) on huge datasets.
pub const MAX_AUTO_SHARDS: usize = 256;

/// Resolve a `--scan-shards` spec to a shard count for `n` rows:
/// `AUTO_SCAN_SHARDS` derives the count from [`TARGET_SHARD_ROWS`],
/// explicit counts are honoured; both are clamped by the
/// [`MIN_SHARD_ROWS`] floor. A function of `n` and the spec alone —
/// never of thread count.
pub fn shard_count(n: usize, spec: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let want = if spec == AUTO_SCAN_SHARDS {
        (n / TARGET_SHARD_ROWS).clamp(1, MAX_AUTO_SHARDS)
    } else {
        spec
    };
    want.clamp(1, (n / MIN_SHARD_ROWS).max(1))
}

/// Width-independent chunk size for the pooled label scans
/// (`nearest_labels` / predict): a function of `n` alone, floored at
/// one lease block and capped at [`MAX_AUTO_SHARDS`] chunks so cursor
/// opens stay bounded on huge inputs.
pub fn label_chunk(n: usize) -> usize {
    const LABEL_CHUNK: usize = 128;
    LABEL_CHUNK.max(n.div_ceil(MAX_AUTO_SHARDS.max(1)))
}

/// The over-decomposed scan plan for one engine: fixed shard geometry,
/// per-shard cost feedback, the LPT claim order derived from it, and
/// the accumulated [`SchedTelemetry`].
///
/// One plan lives as long as its engine; [`record`](ScanPlan::record)
/// is called after every dispatch with that dispatch's deterministic
/// per-shard costs (re-ranking the next round's claim order) and
/// measured shard walls (telemetry only).
pub struct ScanPlan {
    /// Global `(lo, len)` per shard, ascending, contiguous.
    shards: Vec<(usize, usize)>,
    /// Previous dispatch's deterministic cost per shard.
    cost: Vec<u64>,
    /// Claim order: shard indices, most expensive first.
    order: Vec<usize>,
    telemetry: SchedTelemetry,
}

impl ScanPlan {
    /// Plan a scan over rows `0..n`.
    pub fn for_rows(n: usize, spec: usize) -> Self {
        Self::for_range(0, n, spec)
    }

    /// Plan a scan over the global row range `[lo, lo + len)` — the
    /// distributed shard servers plan over their owned range, with
    /// geometry a function of `len` alone so every node's plan is
    /// reproducible from its range assignment.
    pub fn for_range(lo: usize, len: usize, spec: usize) -> Self {
        let shards: Vec<(usize, usize)> =
            make_shards_floored(len, shard_count(len, spec), MIN_SHARD_ROWS)
                .into_iter()
                .map(|(slo, slen)| (lo + slo, slen))
                .collect();
        let s = shards.len();
        ScanPlan {
            shards,
            cost: vec![0; s],
            // zero cost everywhere → identity order (stable sort)
            order: (0..s).collect(),
            telemetry: SchedTelemetry {
                shards: s,
                ..SchedTelemetry::default()
            },
        }
    }

    /// Shard geometry, ascending by `lo`.
    pub fn shards(&self) -> &[(usize, usize)] {
        &self.shards
    }

    /// Current claim order (shard indices, longest-expected-first).
    pub fn order(&self) -> &[usize] {
        &self.order
    }

    /// Telemetry accumulated so far.
    pub fn telemetry(&self) -> SchedTelemetry {
        self.telemetry
    }

    /// Fold one dispatch's results back into the plan: `costs[s]` is
    /// shard `s`'s deterministic work measure for the dispatch just
    /// run (it becomes the LPT key for the next one), `walls[s]` its
    /// measured wall time (telemetry only). `init` attributes the
    /// walls to the initial-assignment phase rather than the round
    /// scans.
    pub fn record(&mut self, costs: &[u64], walls: &[Duration], init: bool) {
        debug_assert_eq!(costs.len(), self.shards.len());
        debug_assert_eq!(walls.len(), self.shards.len());
        self.cost.copy_from_slice(costs);
        let prev = std::mem::take(&mut self.order);
        let mut order: Vec<usize> = (0..self.shards.len()).collect();
        // stable sort, descending cost: equal-cost shards stay in
        // ascending index order, so the order is a pure function of
        // the (deterministic) cost vector
        order.sort_by(|&a, &b| self.cost[b].cmp(&self.cost[a]).then(a.cmp(&b)));
        let t = &mut self.telemetry;
        t.dispatches += 1;
        if order != prev {
            t.reorders += 1;
        }
        self.order = order;
        if !walls.is_empty() {
            let max = walls.iter().max().copied().unwrap_or(Duration::ZERO);
            let mean = walls.iter().sum::<Duration>() / walls.len() as u32;
            if init {
                t.init_max += max;
                t.init_mean += mean;
            } else {
                t.scan_max += max;
                t.scan_mean += mean;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_is_a_function_of_n_alone() {
        // same n, any "thread count" context → same plan
        for n in [1, 100, 4096, 100_000, 1_000_000] {
            let a = ScanPlan::for_rows(n, AUTO_SCAN_SHARDS);
            let b = ScanPlan::for_rows(n, AUTO_SCAN_SHARDS);
            assert_eq!(a.shards(), b.shards());
        }
    }

    #[test]
    fn plan_covers_contiguously() {
        let cases = [
            (10_000, AUTO_SCAN_SHARDS),
            (10_000, 7),
            (4096, 16),
            (300, 16),
            (1, 5),
        ];
        for (n, spec) in cases {
            let plan = ScanPlan::for_rows(n, spec);
            let mut expect = 0;
            for &(lo, len) in plan.shards() {
                assert_eq!(lo, expect, "n={n} spec={spec}");
                assert!(len > 0);
                expect += len;
            }
            assert_eq!(expect, n, "n={n} spec={spec}");
        }
    }

    #[test]
    fn min_shard_rows_floor_holds() {
        // asking for 64 shards of a 1000-row set must not produce
        // 15-row shards: the floor clamps to ≤ 3 shards of ≥ 256 rows
        for (n, spec) in [(1000, 64), (10_000, 1000), (255, 16)] {
            let plan = ScanPlan::for_rows(n, spec);
            if n >= MIN_SHARD_ROWS {
                for &(_, len) in plan.shards() {
                    assert!(len >= MIN_SHARD_ROWS, "n={n} spec={spec} len={len}");
                }
            } else {
                assert_eq!(plan.shards().len(), 1);
            }
        }
    }

    #[test]
    fn auto_count_scales_with_n() {
        assert_eq!(shard_count(0, AUTO_SCAN_SHARDS), 0);
        assert_eq!(shard_count(300, AUTO_SCAN_SHARDS), 1);
        assert_eq!(shard_count(16 * TARGET_SHARD_ROWS, AUTO_SCAN_SHARDS), 16);
        // capped on huge n
        let huge = 10_000 * TARGET_SHARD_ROWS;
        assert_eq!(shard_count(huge, AUTO_SCAN_SHARDS), MAX_AUTO_SHARDS);
    }

    #[test]
    fn range_plans_offset_globally() {
        let plan = ScanPlan::for_range(5000, 2048, 4);
        assert_eq!(plan.shards().len(), 4);
        assert_eq!(plan.shards()[0].0, 5000);
        let covered: usize = plan.shards().iter().map(|s| s.1).sum();
        assert_eq!(covered, 2048);
        // geometry matches the zero-based plan of the same length
        let base = ScanPlan::for_rows(2048, 4);
        for (g, b) in plan.shards().iter().zip(base.shards()) {
            assert_eq!(g.0, b.0 + 5000);
            assert_eq!(g.1, b.1);
        }
    }

    #[test]
    fn lpt_order_follows_costs_deterministically() {
        let mut plan = ScanPlan::for_rows(4 * MIN_SHARD_ROWS, 4);
        assert_eq!(plan.order(), &[0, 1, 2, 3]);
        let walls = vec![Duration::from_micros(1); 4];
        plan.record(&[5, 40, 20, 40], &walls, true);
        // descending cost, ties broken by ascending shard index
        assert_eq!(plan.order(), &[1, 3, 2, 0]);
        let t = plan.telemetry();
        assert_eq!(t.dispatches, 1);
        assert_eq!(t.reorders, 1);
        assert!(t.init_mean > Duration::ZERO);
        assert_eq!(t.scan_mean, Duration::ZERO);
        // identical costs next round → no reorder counted
        plan.record(&[5, 40, 20, 40], &walls, false);
        let t = plan.telemetry();
        assert_eq!(t.dispatches, 2);
        assert_eq!(t.reorders, 1);
        assert!(t.scan_mean > Duration::ZERO);
    }

    #[test]
    fn label_chunk_is_width_independent_and_bounded() {
        assert_eq!(label_chunk(0), 128);
        assert_eq!(label_chunk(1000), 128);
        // huge n: at most MAX_AUTO_SHARDS chunks
        let n = 10_000_000;
        let c = label_chunk(n);
        assert!(n.div_ceil(c) <= MAX_AUTO_SHARDS);
    }
}
