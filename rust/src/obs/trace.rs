//! Trace IDs: one `u64` minted at the front door of a request or fit
//! and propagated through every layer that touches the work — the
//! batcher, the pool dispatch, and the dist wire — so a slow round or a
//! failed request is attributable end to end from logs alone.
//!
//! `0` is reserved as "unset" ([`TraceId::NONE`]): wire codecs and
//! event records treat a zero trace as absent, which keeps the field
//! free to ride in fixed positions of binary frames.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// A process-minted correlation ID (`0` = unset).
///
/// Displayed as 16 lowercase hex digits — the form that appears in
/// event logs, `EakmError::Net` messages, and `--progress` lines.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct TraceId(u64);

impl TraceId {
    /// The unset trace (wire value 0; never produced by [`mint`](TraceId::mint)).
    pub const NONE: TraceId = TraceId(0);

    /// Mint a fresh, non-zero trace ID. Uniqueness is best-effort
    /// (clock nanos ⊕ pid ⊕ a process-wide counter, finalised with a
    /// 64-bit mix) — collisions across a fleet are astronomically
    /// unlikely and harmless (two requests share a label).
    pub fn mint() -> TraceId {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let pid = u64::from(std::process::id());
        let mut id = nanos ^ pid.rotate_left(32) ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // splitmix-style finaliser so nearby timestamps don't produce
        // nearby IDs
        id ^= id >> 33;
        id = id.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
        id ^= id >> 33;
        if id == 0 {
            id = 1;
        }
        TraceId(id)
    }

    /// Whether this trace carries a real ID (non-zero).
    pub fn is_set(&self) -> bool {
        self.0 != 0
    }

    /// The raw wire value (0 = unset).
    pub fn as_u64(&self) -> u64 {
        self.0
    }

    /// Rebuild from a wire value (0 maps back to [`TraceId::NONE`]).
    pub fn from_u64(v: u64) -> TraceId {
        TraceId(v)
    }
}

impl fmt::Display for TraceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minted_ids_are_set_and_distinct() {
        let a = TraceId::mint();
        let b = TraceId::mint();
        assert!(a.is_set() && b.is_set());
        assert_ne!(a, b);
        assert!(!TraceId::NONE.is_set());
    }

    #[test]
    fn displays_as_16_hex_digits() {
        let t = TraceId::from_u64(0xAB);
        assert_eq!(t.to_string(), "00000000000000ab");
        assert_eq!(TraceId::mint().to_string().len(), 16);
    }

    #[test]
    fn wire_roundtrip() {
        let t = TraceId::mint();
        assert_eq!(TraceId::from_u64(t.as_u64()), t);
        assert_eq!(TraceId::from_u64(0), TraceId::NONE);
    }
}
