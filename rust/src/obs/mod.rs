//! The unified observability layer: metrics registry, latency
//! histograms, trace IDs, and the structured event log.
//!
//! The paper's whole methodology is *measurement* — wall time `q_t`
//! and distance-calculation counts `q_a`/`q_au` per algorithm — and
//! the crate already tracks those decompositions in
//! [`metrics::Counters`](crate::metrics::Counters), plus scheduler,
//! I/O, and serving telemetry in their own structs. This module is the
//! layer that makes all of it observable **while the process is
//! live**, without perturbing a single result bit:
//!
//! * [`Registry`] / [`Counter`] / [`Gauge`] / [`Histogram`] — named
//!   metric families rendered in the Prometheus text format. Latency
//!   histograms use fixed base-2 buckets over µs, so merges across
//!   pool workers and shards are exact bucket-wise adds and the
//!   derived p50/p99/p999 are deterministic. Served as `GET /metrics`
//!   on the serve HTTP shim (bypassing admission, like `healthz`) and
//!   by `eakm shardd` (the `STATS` wire frame and an optional metrics
//!   HTTP listener).
//! * [`TraceId`] — a correlation ID minted at the front door (serve
//!   request, `eakm run` fit) and propagated through the batcher and
//!   over the dist wire (`FIT_INIT`/`ROUND` carry it; shard replies
//!   and shard-side round events echo it), so a slow round is
//!   attributable to a specific shard from either end.
//! * [`EventLog`] / [`Event`] — a bounded overwrite-oldest ring of
//!   structured events (per-round fit progress, serve lifecycle),
//!   drained incrementally via `GET /v1/events?since=` or streamed to
//!   stderr by `eakm run --progress`.
//! * [`FitObserver`] — the hook the round loops call once per round.
//!   Observation is strictly read-only over engine state: every
//!   bit-identity and determinism test passes with instrumentation
//!   enabled, and runs without an observer skip even the reads.
//!
//! Everything here is std-only, matching the crate's dependency-free
//! build.

pub mod events;
pub mod registry;
pub mod trace;

pub use events::{events_json, Event, EventLog, Value, DEFAULT_EVENT_CAP};
pub use registry::{Counter, Gauge, Histogram, HistogramSnapshot, Registry, HIST_BUCKETS};
pub use trace::TraceId;

use std::sync::Arc;

use crate::metrics::Counters;

/// Everything one fit round reports to its [`FitObserver`].
#[derive(Clone, Debug)]
pub struct RoundObservation {
    /// Which engine emitted the round: `"fit"` (single-node exact),
    /// `"minibatch"`, `"dist"` (coordinator), or `"shard"`.
    pub site: &'static str,
    /// Round number (1-based; round 0 is the initial full assignment).
    pub round: usize,
    /// Samples that changed cluster this round.
    pub moved: usize,
    /// Objective after the round (mean squared distance). `NaN` when
    /// the emitting engine cannot compute it cheaply.
    pub mse: f64,
    /// Distance-calculation deltas for this round, by site.
    pub delta: Counters,
    /// Scan-scheduler straggler ratio so far
    /// ([`SchedTelemetry::imbalance`](crate::metrics::SchedTelemetry::imbalance)).
    pub imbalance: f64,
    /// Rows scanned this round for mini-batch engines (`None` on full
    /// scans).
    pub batch_rows: Option<usize>,
}

/// The per-fit observer: owns (or shares) an [`EventLog`], carries the
/// fit's [`TraceId`], and optionally mirrors each round to stderr for
/// `eakm run --progress`.
pub struct FitObserver {
    events: Arc<EventLog>,
    trace: TraceId,
    progress: bool,
}

impl FitObserver {
    /// An observer with its own event ring of [`DEFAULT_EVENT_CAP`].
    pub fn new(trace: TraceId, progress: bool) -> FitObserver {
        FitObserver::with_log(Arc::new(EventLog::new(DEFAULT_EVENT_CAP)), trace, progress)
    }

    /// An observer pushing into a shared event ring (the serve and
    /// shardd processes hold one log across many fits).
    pub fn with_log(events: Arc<EventLog>, trace: TraceId, progress: bool) -> FitObserver {
        FitObserver {
            events,
            trace,
            progress,
        }
    }

    /// The event ring this observer pushes into.
    pub fn events(&self) -> &Arc<EventLog> {
        &self.events
    }

    /// The fit's trace ID.
    pub fn trace(&self) -> TraceId {
        self.trace
    }

    /// Record one completed round: push a structured `"round"` event
    /// and, in progress mode, print one stderr line.
    pub fn round(&self, o: &RoundObservation) {
        let mut fields = vec![
            ("site", Value::Str(o.site.to_string())),
            ("round", Value::U64(o.round as u64)),
            ("moved", Value::U64(o.moved as u64)),
            ("mse", Value::F64(o.mse)),
            ("imbalance", Value::F64(o.imbalance)),
            ("dist_assignment", Value::U64(o.delta.assignment)),
            ("dist_centroid", Value::U64(o.delta.centroid)),
            ("dist_displacement", Value::U64(o.delta.displacement)),
            ("dist_init", Value::U64(o.delta.init)),
            ("dist_total", Value::U64(o.delta.total())),
        ];
        if let Some(rows) = o.batch_rows {
            fields.push(("batch_rows", Value::U64(rows as u64)));
        }
        self.events.push("round", self.trace, fields);
        if self.progress {
            let batch = match o.batch_rows {
                Some(rows) => format!(" batch={rows}"),
                None => String::new(),
            };
            let mse = if o.mse.is_nan() {
                String::new()
            } else {
                format!(" mse={:.6}", o.mse)
            };
            eprintln!(
                "[{} round {}] moved={}{mse} imb={:.2} dist=+{} (assign +{}){batch} trace={}",
                o.site,
                o.round,
                o.moved,
                o.imbalance,
                o.delta.total(),
                o.delta.assignment,
                self.trace,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observer_pushes_round_events_with_trace() {
        let trace = TraceId::from_u64(0x77);
        let obs = FitObserver::new(trace, false);
        obs.round(&RoundObservation {
            site: "fit",
            round: 3,
            moved: 12,
            mse: 0.25,
            delta: Counters {
                assignment: 100,
                centroid: 10,
                displacement: 5,
                init: 0,
            },
            imbalance: 1.25,
            batch_rows: Some(512),
        });
        let events = obs.events().since(0);
        assert_eq!(events.len(), 1);
        let e = &events[0];
        assert_eq!(e.kind, "round");
        assert_eq!(e.trace, trace);
        assert_eq!(e.field("round"), Some(&Value::U64(3)));
        assert_eq!(e.field("moved"), Some(&Value::U64(12)));
        assert_eq!(e.field("dist_total"), Some(&Value::U64(115)));
        assert_eq!(e.field("batch_rows"), Some(&Value::U64(512)));
    }
}
