//! The structured event log: a bounded, overwrite-oldest ring of typed
//! events with monotone sequence numbers.
//!
//! Producers ([`push`](EventLog::push)) never block on consumers and
//! never allocate beyond the fixed capacity: when the ring is full the
//! oldest event is dropped. Consumers drain incrementally with
//! [`since`](EventLog::since) — pass the last sequence number you saw
//! (0 to start) and you get everything newer that is still resident,
//! which is exactly the contract behind `GET /v1/events?since=` and the
//! `--progress` stream.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::json::Json;

use super::trace::TraceId;

/// Default ring capacity for the process-level logs (fit, serve,
/// shard): enough for thousands of rounds/lifecycle events without
/// unbounded growth.
pub const DEFAULT_EVENT_CAP: usize = 1024;

/// One typed field value of an [`Event`].
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// An unsigned integer field (counts, rounds, rows).
    U64(u64),
    /// A floating-point field (mse, imbalance, ratios).
    F64(f64),
    /// A string field (paths, op names, error text).
    Str(String),
}

impl From<&Value> for Json {
    fn from(v: &Value) -> Json {
        match v {
            Value::U64(x) => Json::from(*x),
            Value::F64(x) => Json::from(*x),
            Value::Str(s) => Json::from(s.as_str()),
        }
    }
}

/// One structured event: a kind tag, an optional trace ID, and a flat
/// list of typed fields.
#[derive(Clone, Debug)]
pub struct Event {
    /// Monotone sequence number, assigned by the log (starts at 1).
    pub seq: u64,
    /// Event kind (e.g. `"round"`, `"reload"`, `"breaker_open"`).
    pub kind: &'static str,
    /// Correlation ID ([`TraceId::NONE`] when the event is untraced).
    pub trace: TraceId,
    /// Typed payload fields, in insertion order.
    pub fields: Vec<(&'static str, Value)>,
}

impl Event {
    /// JSON rendering: `seq`/`kind`/`trace` plus every field flattened
    /// into the same object (field names are chosen not to collide).
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj().field("seq", self.seq).field("kind", self.kind);
        if self.trace.is_set() {
            obj = obj.field("trace", self.trace.to_string().as_str());
        }
        for (name, value) in &self.fields {
            obj = obj.field(name, Json::from(value));
        }
        obj
    }

    /// Look up a field by name.
    pub fn field(&self, name: &str) -> Option<&Value> {
        self.fields.iter().find(|(n, _)| *n == name).map(|(_, v)| v)
    }
}

struct Ring {
    next_seq: u64,
    buf: VecDeque<Event>,
}

/// A bounded ring buffer of [`Event`]s, shared across threads.
pub struct EventLog {
    cap: usize,
    inner: Mutex<Ring>,
}

impl EventLog {
    /// An empty log holding at most `cap` events (`cap` is clamped to
    /// at least 1).
    pub fn new(cap: usize) -> EventLog {
        EventLog {
            cap: cap.max(1),
            inner: Mutex::new(Ring {
                next_seq: 1,
                buf: VecDeque::new(),
            }),
        }
    }

    /// Append one event, overwriting the oldest if the ring is full.
    /// Returns the assigned sequence number.
    pub fn push(
        &self,
        kind: &'static str,
        trace: TraceId,
        fields: Vec<(&'static str, Value)>,
    ) -> u64 {
        let mut g = self.inner.lock().expect("event log poisoned");
        let seq = g.next_seq;
        g.next_seq += 1;
        if g.buf.len() == self.cap {
            g.buf.pop_front();
        }
        g.buf.push_back(Event {
            seq,
            kind,
            trace,
            fields,
        });
        seq
    }

    /// Every resident event with `seq > since`, oldest first. `since = 0`
    /// returns everything still in the ring; a `since` beyond the head
    /// returns an empty list.
    pub fn since(&self, since: u64) -> Vec<Event> {
        let g = self.inner.lock().expect("event log poisoned");
        g.buf.iter().filter(|e| e.seq > since).cloned().collect()
    }

    /// The sequence number of the newest event pushed so far (0 before
    /// the first push) — pass it back to [`since`](EventLog::since) to
    /// resume a drain.
    pub fn last_seq(&self) -> u64 {
        self.inner.lock().expect("event log poisoned").next_seq - 1
    }

    /// Events currently resident (≤ the configured capacity).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("event log poisoned").buf.len()
    }

    /// Whether the ring holds no events.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Render a drained slice of events as the JSON payload of
/// `GET /v1/events`: `{"ok":true,"last":N,"events":[…]}`.
pub fn events_json(events: &[Event], last_seq: u64) -> Json {
    Json::obj()
        .field("ok", true)
        .field("last", last_seq)
        .field("events", Json::Arr(events.iter().map(Event::to_json).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_assigns_monotone_seqs_and_since_filters() {
        let log = EventLog::new(8);
        assert_eq!(log.last_seq(), 0);
        assert!(log.is_empty());
        let s1 = log.push("round", TraceId::NONE, vec![("round", Value::U64(1))]);
        let s2 = log.push("round", TraceId::NONE, vec![("round", Value::U64(2))]);
        assert_eq!((s1, s2), (1, 2));
        assert_eq!(log.last_seq(), 2);
        let all = log.since(0);
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].seq, 1);
        let newer = log.since(1);
        assert_eq!(newer.len(), 1);
        assert_eq!(newer[0].seq, 2);
        assert!(log.since(2).is_empty());
        assert!(log.since(99).is_empty());
    }

    #[test]
    fn ring_overwrites_oldest_on_wrap() {
        let log = EventLog::new(3);
        for i in 1..=5u64 {
            log.push("e", TraceId::NONE, vec![("i", Value::U64(i))]);
        }
        // capacity 3, five pushes: events 1 and 2 were overwritten
        assert_eq!(log.len(), 3);
        let resident = log.since(0);
        let seqs: Vec<u64> = resident.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![3, 4, 5]);
        // sequence numbers keep counting past the wrap
        assert_eq!(log.push("e", TraceId::NONE, vec![]), 6);
        assert_eq!(log.last_seq(), 6);
    }

    #[test]
    fn event_json_flattens_fields_and_skips_unset_trace() {
        let log = EventLog::new(4);
        log.push(
            "reload",
            TraceId::from_u64(0xAB),
            vec![
                ("generation", Value::U64(2)),
                ("path", Value::Str("m.json".into())),
                ("mse", Value::F64(0.5)),
            ],
        );
        log.push("overload", TraceId::NONE, vec![]);
        let events = log.since(0);
        let j = events[0].to_json().to_string();
        assert!(j.contains("\"kind\":\"reload\""), "{j}");
        assert!(j.contains("\"trace\":\"00000000000000ab\""), "{j}");
        assert!(j.contains("\"generation\":2"), "{j}");
        assert!(j.contains("\"path\":\"m.json\""), "{j}");
        let j = events[1].to_json().to_string();
        assert!(!j.contains("trace"), "{j}");
        let body = events_json(&events, log.last_seq()).to_string();
        assert!(body.contains("\"ok\":true"), "{body}");
        assert!(body.contains("\"last\":2"), "{body}");
    }
}
