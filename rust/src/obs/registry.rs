//! The metrics registry: named counters, gauges, and log-bucketed
//! latency histograms, rendered in the Prometheus text exposition
//! format (version 0.0.4).
//!
//! Everything is dependency-free and deterministic by construction:
//!
//! * counters and gauges are single atomics;
//! * histograms use **fixed base-2 buckets over microseconds** — the
//!   bucket grid is a compile-time constant, so merging histograms
//!   across pool workers, shards, or processes is an exact bucket-wise
//!   integer add (no re-bucketing, no approximation drift), and the
//!   derived p50/p99/p999 are a deterministic function of the merged
//!   counts;
//! * rendering walks families in insertion order, so scrapes are
//!   stable and diffable.
//!
//! A [`Registry`] can be long-lived (register once, record forever) or
//! built at scrape time from snapshots — the serve and shardd
//! `/metrics` endpoints do the latter, which keeps the request hot
//! path free of any exposition cost.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Number of histogram buckets: upper bounds `2^0 … 2^30` µs plus a
/// final `+Inf` bucket. `2^30` µs ≈ 17.9 minutes — far beyond any op
/// latency this crate serves.
pub const HIST_BUCKETS: usize = 32;

/// A monotone counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// A fresh zero counter.
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `v`.
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge: a settable `f64` (stored as bits in one atomic).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// A fresh zero gauge.
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    /// Set the value.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// A log-bucketed latency histogram over microseconds.
///
/// Bucket `i < 31` counts observations `v` with `v ≤ 2^i` µs (and
/// above the previous bound); bucket 31 counts everything larger
/// (rendered as `+Inf`). Recording is three relaxed atomic adds.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_micros: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// A fresh empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
        }
    }

    /// The bucket index an observation of `micros` lands in.
    pub fn bucket_index(micros: u64) -> usize {
        if micros <= 1 {
            return 0;
        }
        // smallest i with micros ≤ 2^i, clamped to the +Inf bucket
        let i = 64 - (micros - 1).leading_zeros() as usize;
        i.min(HIST_BUCKETS - 1)
    }

    /// The inclusive upper bound of bucket `i` in µs (`None` = +Inf).
    pub fn bucket_upper(i: usize) -> Option<u64> {
        if i + 1 < HIST_BUCKETS {
            Some(1u64 << i)
        } else {
            None
        }
    }

    /// Record one observation of `micros`.
    pub fn record_micros(&self, micros: u64) {
        self.buckets[Self::bucket_index(micros)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add(micros, Ordering::Relaxed);
    }

    /// Record one observed duration (saturating to µs).
    pub fn record(&self, latency: Duration) {
        self.record_micros(latency.as_micros().min(u64::MAX as u128) as u64);
    }

    /// A point-in-time copy of the counts.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum_micros: self.sum_micros.load(Ordering::Relaxed),
        }
    }
}

/// An owned copy of a [`Histogram`]'s counts: mergeable, quantileable,
/// renderable.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Per-bucket observation counts (not cumulative).
    pub buckets: [u64; HIST_BUCKETS],
    /// Total observations.
    pub count: u64,
    /// Sum of all observed values, µs.
    pub sum_micros: u64,
}

impl HistogramSnapshot {
    /// Exact merge: bucket-wise integer add. Merging is associative and
    /// commutative, so any merge order across workers/shards yields the
    /// same result — the determinism contract of the fixed bucket grid.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum_micros += other.sum_micros;
    }

    /// Deterministic quantile estimate: the upper bound (µs) of the
    /// first bucket whose cumulative count reaches `q · count`. Returns
    /// 0 for an empty histogram and `u64::MAX` when the quantile falls
    /// in the `+Inf` bucket.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return Histogram::bucket_upper(i).unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }

    /// Mean observed value, µs (0.0 when empty).
    pub fn mean_micros(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_micros as f64 / self.count as f64
        }
    }
}

/// One registered metric family instance.
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
    /// A scrape-time histogram registered from an owned snapshot.
    HistogramSnap(HistogramSnapshot),
}

struct Family {
    name: String,
    help: String,
    labels: Vec<(String, String)>,
    metric: Metric,
}

/// An insertion-ordered registry of metric families, rendered as
/// Prometheus text format by [`render`](Registry::render).
///
/// Multiple families may share a name (differing in labels); the
/// `# HELP`/`# TYPE` header is emitted once per name, at the first
/// occurrence.
#[derive(Default)]
pub struct Registry {
    families: Mutex<Vec<Family>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    fn register(&self, name: &str, help: &str, labels: &[(&str, &str)], metric: Metric) {
        self.families.lock().expect("registry poisoned").push(Family {
            name: name.to_string(),
            help: help.to_string(),
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            metric,
        });
    }

    /// Register and return a counter.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let c = Arc::new(Counter::new());
        self.register(name, help, labels, Metric::Counter(c.clone()));
        c
    }

    /// Register and return a gauge.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let g = Arc::new(Gauge::new());
        self.register(name, help, labels, Metric::Gauge(g.clone()));
        g
    }

    /// Register and return a live histogram.
    pub fn histogram(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        let h = Arc::new(Histogram::new());
        self.register(name, help, labels, Metric::Histogram(h.clone()));
        h
    }

    /// Register a scrape-time counter sample with a fixed value — the
    /// shape the `/metrics` handlers use to render existing telemetry
    /// snapshots without touching the hot path.
    pub fn sample_counter(&self, name: &str, help: &str, labels: &[(&str, &str)], value: u64) {
        self.counter(name, help, labels).add(value);
    }

    /// Register a scrape-time gauge sample with a fixed value.
    pub fn sample_gauge(&self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.gauge(name, help, labels).set(value);
    }

    /// Register a scrape-time histogram sample from a snapshot.
    pub fn sample_histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        snap: &HistogramSnapshot,
    ) {
        self.register(name, help, labels, Metric::HistogramSnap(*snap));
    }

    /// Render every family in the Prometheus text exposition format
    /// (version 0.0.4). Histogram `le` bounds are integer microseconds
    /// — the metric names carry a `_micros` suffix to say so.
    pub fn render(&self) -> String {
        let families = self.families.lock().expect("registry poisoned");
        let mut out = String::new();
        let mut seen: Vec<&str> = Vec::new();
        for f in families.iter() {
            if !seen.contains(&f.name.as_str()) {
                seen.push(&f.name);
                let kind = match f.metric {
                    Metric::Counter(_) => "counter",
                    Metric::Gauge(_) => "gauge",
                    Metric::Histogram(_) | Metric::HistogramSnap(_) => "histogram",
                };
                out.push_str(&format!("# HELP {} {}\n", f.name, escape_help(&f.help)));
                out.push_str(&format!("# TYPE {} {}\n", f.name, kind));
            }
            let labels = label_body(&f.labels);
            match &f.metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("{}{} {}\n", f.name, braced(&labels), c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("{}{} {}\n", f.name, braced(&labels), g.get()));
                }
                Metric::Histogram(h) => render_hist(&mut out, &f.name, &labels, &h.snapshot()),
                Metric::HistogramSnap(s) => render_hist(&mut out, &f.name, &labels, s),
            }
        }
        out
    }
}

/// `key="escaped",…` without braces (empty string for no labels).
fn label_body(labels: &[(String, String)]) -> String {
    labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect::<Vec<_>>()
        .join(",")
}

/// Wrap a non-empty label body in braces.
fn braced(body: &str) -> String {
    if body.is_empty() {
        String::new()
    } else {
        format!("{{{body}}}")
    }
}

fn render_hist(out: &mut String, name: &str, labels: &str, s: &HistogramSnapshot) {
    let mut cum = 0u64;
    for (i, &b) in s.buckets.iter().enumerate() {
        cum += b;
        let le = match Histogram::bucket_upper(i) {
            Some(bound) => bound.to_string(),
            None => "+Inf".to_string(),
        };
        let body = if labels.is_empty() {
            format!("le=\"{le}\"")
        } else {
            format!("{labels},le=\"{le}\"")
        };
        out.push_str(&format!("{name}_bucket{{{body}}} {cum}\n"));
    }
    out.push_str(&format!("{name}_sum{} {}\n", braced(labels), s.sum_micros));
    out.push_str(&format!("{name}_count{} {}\n", braced(labels), s.count));
}

/// Escape a HELP string: backslash and newline.
fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Escape a label value: backslash, double quote, newline.
fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_boundaries() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 2);
        assert_eq!(Histogram::bucket_index(5), 3);
        assert_eq!(Histogram::bucket_index(1 << 30), 30);
        assert_eq!(Histogram::bucket_index((1 << 30) + 1), 31);
        assert_eq!(Histogram::bucket_index(u64::MAX), 31);
        assert_eq!(Histogram::bucket_upper(0), Some(1));
        assert_eq!(Histogram::bucket_upper(30), Some(1 << 30));
        assert_eq!(Histogram::bucket_upper(31), None);
    }

    #[test]
    fn quantiles_are_bucket_upper_bounds() {
        let h = Histogram::new();
        for v in [1u64, 2, 2, 100, 100, 100, 100, 5000] {
            h.record_micros(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 8);
        assert_eq!(s.sum_micros, 5505);
        // 100 µs lands in bucket 7 (≤128); the median is there
        assert_eq!(s.quantile(0.5), 128);
        assert_eq!(s.quantile(0.99), 8192); // 5000 ≤ 8192
        assert_eq!(s.quantile(0.0), 1);
        assert_eq!(HistogramSnapshot::default().quantile(0.5), 0);
        assert!((s.mean_micros() - 5505.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn merge_is_exact_and_order_independent() {
        let (a, b) = (Histogram::new(), Histogram::new());
        for v in [3u64, 70, 900] {
            a.record_micros(v);
        }
        for v in [1u64, 70, 1 << 40] {
            b.record_micros(v);
        }
        let (sa, sb) = (a.snapshot(), b.snapshot());
        let mut ab = sa;
        ab.merge(&sb);
        let mut ba = sb;
        ba.merge(&sa);
        assert_eq!(ab, ba);
        assert_eq!(ab.count, 6);
        let whole = Histogram::new();
        for v in [3u64, 70, 900, 1, 70, 1 << 40] {
            whole.record_micros(v);
        }
        assert_eq!(ab, whole.snapshot());
    }

    #[test]
    fn render_format_and_escaping() {
        let reg = Registry::new();
        reg.sample_counter("t_requests_total", "line1\nline2 \\ back", &[], 7);
        reg.sample_gauge(
            "t_imbalance",
            "gauge help",
            &[("site", "a\"b\\c\nd"), ("alg", "exp-ns")],
            1.5,
        );
        let h = Histogram::new();
        h.record_micros(3);
        h.record_micros(100);
        reg.sample_histogram("t_latency_micros", "hist help", &[("op", "predict")], &h.snapshot());
        let text = reg.render();
        assert!(text.contains("# HELP t_requests_total line1\\nline2 \\\\ back\n"), "{text}");
        assert!(text.contains("# TYPE t_requests_total counter\n"), "{text}");
        assert!(text.contains("t_requests_total 7\n"), "{text}");
        assert!(
            text.contains("t_imbalance{site=\"a\\\"b\\\\c\\nd\",alg=\"exp-ns\"} 1.5\n"),
            "{text}"
        );
        assert!(text.contains("# TYPE t_latency_micros histogram\n"), "{text}");
        assert!(text.contains("t_latency_micros_bucket{op=\"predict\",le=\"4\"} 1\n"), "{text}");
        assert!(
            text.contains("t_latency_micros_bucket{op=\"predict\",le=\"+Inf\"} 2\n"),
            "{text}"
        );
        assert!(text.contains("t_latency_micros_sum{op=\"predict\"} 103\n"), "{text}");
        assert!(text.contains("t_latency_micros_count{op=\"predict\"} 2\n"), "{text}");
        // buckets are cumulative: the 128 bound already includes the 4 one
        assert!(text.contains("t_latency_micros_bucket{op=\"predict\",le=\"128\"} 2\n"), "{text}");
    }

    #[test]
    fn help_type_emitted_once_per_name() {
        let reg = Registry::new();
        reg.sample_counter("multi_total", "help", &[("site", "a")], 1);
        reg.sample_counter("multi_total", "help", &[("site", "b")], 2);
        let text = reg.render();
        assert_eq!(text.matches("# HELP multi_total").count(), 1, "{text}");
        assert_eq!(text.matches("# TYPE multi_total").count(), 1, "{text}");
        assert!(text.contains("multi_total{site=\"a\"} 1\n"), "{text}");
        assert!(text.contains("multi_total{site=\"b\"} 2\n"), "{text}");
    }
}
